#!/usr/bin/env bash
# CI gate for the default (offline, zero-dependency) feature set:
#   1. release build   2. test suite   3. pjrt-stub check   4. bench smoke
#   5. clippy, warnings fatal
#
# Usage: ./ci.sh            (SKIP_CLIPPY=1 to skip the lint step, e.g. on
#                            toolchains without the clippy component)

set -euo pipefail
cd "$(dirname "$0")"

echo "==> cargo build --release"
cargo build --release

echo "==> cargo test -q"
cargo test -q

echo "==> cargo test -q (SPLITFC_SIMD=off: scalar kernel table)"
# the whole suite must pass identically with the vector kernels pinned off
SPLITFC_SIMD=off cargo test -q

echo "==> cargo check --features pjrt --all-targets"
# the stub-gated PJRT path must keep compiling even though CI never runs it
cargo check --features pjrt --all-targets

echo "==> concurrent coordinator smoke (4 devices, 2 threads, staleness 1)"
cargo run --release --bin splitfc -- train --preset tiny --devices 4 \
    --threads 2 --staleness 1 --rounds 3

echo "==> TCP transport smoke (4 devices over loopback, ephemeral port)"
# real sockets end to end: listener on 127.0.0.1:0, handshake, S=0 schedule
cargo run --release --bin splitfc -- train --preset tiny --devices 4 \
    --transport tcp --listen 127.0.0.1:0 --rounds 3

echo "==> codec registry matrix smoke (round trip + 1 train step per codec)"
# iterates CodecRegistry::names(): an unported or misregistered codec fails here
cargo run --release --bin splitfc -- codec-smoke

echo "==> bench smoke (THREADS=2, quick): BENCH_fwq.json / BENCH_e2e.json"
THREADS=2 cargo bench --bench bench_compression -- --quick
THREADS=2 cargo bench --bench bench_e2e_step -- --quick

echo "==> wire bench (quick, counting allocator): BENCH_wire.json + 0 allocs/step gate"
# the bench itself exits non-zero if a warm splitfc[ad,R=8,fwq] session
# allocates in steady state
THREADS=2 cargo bench --features alloc-count --bench bench_wire -- --quick

echo "==> steady-state allocation test (counting allocator, isolated)"
# process-global counter: run the one test single-threaded
cargo test --features alloc-count --test integration_codecs \
    steady_state_codec_steps_are_allocation_free -- --test-threads=1

echo "==> coordinator bench (quick): BENCH_coordinator.json"
cargo bench --bench bench_coordinator -- --quick

echo "==> transport bench (quick): BENCH_transport.json + lifecycle probes"
# fails on handshake-rejection or reconnect-replay regressions
cargo bench --bench bench_transport -- --quick

echo "==> seeded scenario smoke (straggler + mid-run cut + rejoin, TCP loopback)"
# the same --scenario seed twice must yield identical deterministic metrics
SCEN="seed=7,straggler[dev=2,slow=4x],cut[dev=1,step=3],dropout[p=0.1,rejoin=1r]"
for pass in a b; do
    cargo run --release --bin splitfc -- train --preset tiny --devices 4 \
        --transport tcp --listen 127.0.0.1:0 --rounds 4 \
        --scenario "$SCEN" --metrics "/tmp/splitfc_ci_scen_$pass.jsonl"
done
cargo run --release --bin splitfc -- metrics-diff \
    /tmp/splitfc_ci_scen_a.jsonl /tmp/splitfc_ci_scen_b.jsonl
rm -f /tmp/splitfc_ci_scen_a.jsonl /tmp/splitfc_ci_scen_b.jsonl

echo "==> chaos bench (quick): BENCH_chaos.json + determinism probe"
# fails if a repeated scenario seed diverges
cargo bench --bench bench_chaos -- --quick

echo "==> SIMD determinism (full train, scalar vs vector kernels)"
# the bit-exactness contract: SPLITFC_SIMD=off and the auto-detected AVX2
# path must produce byte-identical training trajectories
SPLITFC_SIMD=off cargo run --release --bin splitfc -- train --preset tiny \
    --devices 2 --rounds 3 --scheme splitfc --r 8 --up-bpe 0.2 \
    --metrics /tmp/splitfc_ci_simd_off.jsonl
SPLITFC_SIMD=auto cargo run --release --bin splitfc -- train --preset tiny \
    --devices 2 --rounds 3 --scheme splitfc --r 8 --up-bpe 0.2 \
    --metrics /tmp/splitfc_ci_simd_on.jsonl
cargo run --release --bin splitfc -- metrics-diff \
    /tmp/splitfc_ci_simd_off.jsonl /tmp/splitfc_ci_simd_on.jsonl
rm -f /tmp/splitfc_ci_simd_off.jsonl /tmp/splitfc_ci_simd_on.jsonl

echo "==> SIMD kernel bench (quick): BENCH_simd.json + 2x gates on AVX2 hosts"
# hard-asserts >= 2x on the matmul micro-kernel and the FWQ symbol quantize
# loop when AVX2 is available; skips (and says so) elsewhere
cargo bench --bench bench_simd -- --quick

echo "==> checkpoint/resume smoke (SIGKILL mid-run, byte-identical --resume)"
# 20 uninterrupted rounds vs 10 rounds + kill -9 + --resume into the same
# metrics file, both over TCP loopback: metrics-diff must find zero drift
CKDIR=/tmp/splitfc_ci_ckpt
rm -rf "$CKDIR" /tmp/splitfc_ci_ckpt_ref.jsonl /tmp/splitfc_ci_ckpt_live.jsonl
./target/release/splitfc train --preset tiny --devices 4 --rounds 20 \
    --transport tcp --listen 127.0.0.1:0 \
    --metrics /tmp/splitfc_ci_ckpt_ref.jsonl
./target/release/splitfc train --preset tiny --devices 4 --rounds 20 \
    --transport tcp --listen 127.0.0.1:0 \
    --checkpoint-every 10 --checkpoint-dir "$CKDIR" \
    --metrics /tmp/splitfc_ci_ckpt_live.jsonl &
CKPID=$!
for _ in $(seq 1 600); do
    [ -f "$CKDIR/ckpt-r00010.splitfc" ] && break
    sleep 0.1
done
[ -f "$CKDIR/ckpt-r00010.splitfc" ] || { echo "no snapshot appeared"; exit 1; }
kill -9 "$CKPID" 2>/dev/null || true   # the run may have already finished
wait "$CKPID" 2>/dev/null || true
./target/release/splitfc ckpt inspect "$CKDIR/ckpt-r00010.splitfc"
./target/release/splitfc train --preset tiny --devices 4 --rounds 20 \
    --transport tcp --listen 127.0.0.1:0 \
    --resume "$CKDIR/ckpt-r00010.splitfc" \
    --metrics /tmp/splitfc_ci_ckpt_live.jsonl
./target/release/splitfc metrics-diff \
    /tmp/splitfc_ci_ckpt_ref.jsonl /tmp/splitfc_ci_ckpt_live.jsonl
rm -rf "$CKDIR" /tmp/splitfc_ci_ckpt_ref.jsonl /tmp/splitfc_ci_ckpt_live.jsonl

echo "==> elastic-fleet recovery smoke (kill -9 the PS under live devices, same-port --resume)"
# four real `splitfc device` processes stay up while their PS is SIGKILLed
# at the round-4 barrier; a new PS incarnation rebinds the SAME port
# (SO_REUSEADDR) with --resume, the devices reconnect into it, and the
# finished metrics stream must be byte-identical to an uninterrupted
# reference. The scenario cuts every device's link right after the barrier
# so the kill always lands on a quiesced PS.
RCDIR=/tmp/splitfc_ci_recov
rm -rf "$RCDIR" /tmp/splitfc_ci_recov_ref.jsonl /tmp/splitfc_ci_recov.jsonl
RADDR="127.0.0.1:$(( 20000 + ($$ % 20000) ))"
RSCEN="seed=7,cut[dev=0,step=5],cut[dev=1,step=5],cut[dev=2,step=5],cut[dev=3,step=5]"
RCOMMON="--preset tiny --devices 4 --rounds 8 --seed 11"
RRETRY="--retry-base-ms 3000 --retry-cap-ms 6000 --retry-deadline-s 120"
./target/release/splitfc train $RCOMMON --metrics /tmp/splitfc_ci_recov_ref.jsonl
./target/release/splitfc train $RCOMMON --transport tcp --listen "$RADDR" \
    --devices-remote 4 --scenario "$RSCEN" $RRETRY \
    --checkpoint-every 4 --checkpoint-dir "$RCDIR" \
    --metrics /tmp/splitfc_ci_recov.jsonl &
RCPID=$!
RDEVPIDS=()
for K in 0 1 2 3; do
    ./target/release/splitfc device --connect "$RADDR" --device "$K" \
        $RCOMMON --scenario "$RSCEN" $RRETRY &
    RDEVPIDS+=($!)
done
for _ in $(seq 1 600); do
    [ -f "$RCDIR/ckpt-r00004.splitfc" ] && break
    sleep 0.1
done
[ -f "$RCDIR/ckpt-r00004.splitfc" ] || { echo "no snapshot appeared"; exit 1; }
kill -9 "$RCPID" 2>/dev/null
wait "$RCPID" 2>/dev/null || true
./target/release/splitfc ckpt inspect --json "$RCDIR/ckpt-r00004.splitfc"
./target/release/splitfc train $RCOMMON --transport tcp --listen "$RADDR" \
    --devices-remote 4 --scenario "$RSCEN" $RRETRY \
    --checkpoint-every 4 --checkpoint-dir "$RCDIR" \
    --resume "$RCDIR/ckpt-r00004.splitfc" \
    --metrics /tmp/splitfc_ci_recov.jsonl
for P in "${RDEVPIDS[@]}"; do wait "$P"; done
./target/release/splitfc metrics-diff \
    /tmp/splitfc_ci_recov_ref.jsonl /tmp/splitfc_ci_recov.jsonl
rm -rf "$RCDIR" /tmp/splitfc_ci_recov_ref.jsonl /tmp/splitfc_ci_recov.jsonl

echo "==> checkpoint bench (quick): BENCH_ckpt.json + resume byte-identity probe"
# fails non-zero if a resumed run's deterministic step fields diverge
cargo bench --bench bench_ckpt -- --quick

if [ "${SKIP_CLIPPY:-0}" = "1" ]; then
    echo "==> clippy skipped (SKIP_CLIPPY=1)"
elif cargo clippy --version >/dev/null 2>&1; then
    echo "==> cargo clippy --all-targets -- -W clippy::perf -D warnings"
    cargo clippy --all-targets -- -W clippy::perf -D warnings
else
    echo "==> clippy not installed; skipping lint step" >&2
fi

echo "CI OK"
