//! Quickstart: the whole SplitFC pipeline in ~60 lines.
//!
//! Trains the `tiny` split model for a few rounds with full SplitFC
//! compression (adaptive feature-wise dropout + quantization) on the
//! pure-Rust native backend — no artifacts, no external deps — and prints
//! accuracy + measured communication bits.
//!
//! Run:  cargo run --release --example quickstart
//! (This example takes no flags; to drive the same protocol through
//! compiled HLO, build with `--features pjrt` and set
//! `cfg.backend = BackendKind::Pjrt` — see e2e_train for a flag-driven
//! variant.)

use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::util::Result;

fn main() -> Result<()> {
    // 1. configure the tiny scenario: 2 devices, SplitFC at R=4 with a
    //    1 bit/entry uplink budget and 2 bits/entry downlink budget.
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 2;
    cfg.rounds = 6;
    cfg.scheme = parse_scheme("splitfc", 4.0)?;
    cfg.up_bits_per_entry = 1.0;
    cfg.down_bits_per_entry = 2.0;

    // 2. build the trainer: constructs the execution backend (native split
    //    MLP by default), deterministic initial parameters, and the
    //    synthesized non-IID dataset.
    let mut trainer = Trainer::new(cfg)?;

    // 3. train (Algorithm 1: round-robin over devices, compressed links).
    let summary = trainer.run()?;

    // 4. report.
    let (batch, dbar) = (trainer.preset().batch, trainer.preset().dbar);
    println!("final accuracy: {:.2}%", summary.final_acc * 100.0);
    println!(
        "uplink: {} bits total ({:.3} bits/entry vs 32 uncompressed = {:.0}x compression)",
        summary.total_up_bits,
        summary.uplink_bits_per_entry(batch, dbar),
        32.0 / summary.uplink_bits_per_entry(batch, dbar)
    );
    println!(
        "downlink: {} bits total; modeled transfer time {:.3}s on a 10 Mbps link",
        summary.total_down_bits, summary.link_s
    );
    println!("wall time: {:.2}s (backend exec {:.2}s)", summary.wall_s, summary.exec_s);
    Ok(())
}
