//! Extension study (paper Conclusion: "fading channels and device-specific
//! heterogeneous conditions"): SplitFC under a block-fading link with
//! heterogeneous per-device budgets, plus the error-feedback (SplitFC-EF)
//! variant — all at the codec/transport level (no PJRT needed).
//!
//! Run:  cargo run --release --example wireless_hetero

use splitfc::bench::print_table;
use splitfc::compression::feedback::ErrorFeedback;
use splitfc::compression::{encode_uplink, CodecParams, DropKind, FwqMode, Scheme};
use splitfc::tensor::{column_stats, normalized_sigma, Matrix};
use splitfc::transport::{device_budgets, per_device_ratio, FadingLink};
use splitfc::util::Rng;

fn main() {
    let (b, d, chan) = (64usize, 1152usize, 36usize);
    let mut rng = Rng::new(42);
    let f = Matrix::from_fn(b, d, |_, c| {
        ([3.0, 1.0, 0.2, 0.01, 0.0][c % 5]) * rng.normal_f32(0.0, 1.0) + (c % 11) as f32 * 0.1
    });
    let sigma = normalized_sigma(&column_stats(&f), chan);

    // --- heterogeneous budgets: each device gets its own C_e,d and an
    //     adaptive R chosen to fit (Remark-1 overhead model) -------------
    let devices = 12;
    let budgets = device_budgets(devices, 0.4, 0.7, 0.1, &mut rng);
    let candidates = [2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
    let mut rows = Vec::new();
    for (k, &bpe) in budgets.iter().enumerate() {
        let r = per_device_ratio(bpe, b, d, &candidates);
        let params = CodecParams::new(b, d, bpe);
        let mut krng = Rng::new(100 + k as u64);
        let enc = encode_uplink(&Scheme::splitfc(r), &f, &sigma, &params, &mut krng);
        let err = (f.sq_dist(&enc.f_hat) / f.sq_norm()).sqrt();
        rows.push((
            format!("device {k:>2}"),
            vec![
                format!("{bpe:.3}"),
                format!("R={r}"),
                format!("{}", enc.frame.payload_bits),
                format!("{err:.3}"),
            ],
        ));
    }
    print_table(
        "heterogeneous devices: personal budget -> adaptive R",
        &["C_e,d b/e".into(), "ratio".into(), "frame bits".into(), "rel err".into()],
        &rows,
    );
    println!(
        "(per-round rel err of the unbiased estimator scales like sqrt(R-1); \
         it averages out across rounds — see the EF section below)"
    );

    // --- fading link: modeled transfer time per round ------------------
    let params = CodecParams::new(b, d, 0.2);
    let mut frng = Rng::new(7);
    let enc = encode_uplink(&Scheme::splitfc(16.0), &f, &sigma, &params, &mut frng);
    let mut rows = Vec::new();
    for (label, outage) in [("mild fading (outage g<0.05)", 0.05), ("harsh fading (g<0.5)", 0.5)] {
        let mut link = FadingLink::new(10e6, outage, 0.01, 9);
        let t_c = link.transmit(enc.frame.total_bits());
        let retr_c = link.retransmissions;
        let mut link = FadingLink::new(10e6, outage, 0.01, 9);
        let t_u = link.transmit(32 * (b * d) as u64);
        rows.push((
            label.to_string(),
            vec![
                format!("{:.3}s", t_c),
                format!("{retr_c}"),
                format!("{:.3}s", t_u),
                format!("{:.0}x", t_u / t_c),
            ],
        ));
    }
    print_table(
        "block-fading link, one SplitFC frame vs uncompressed F",
        &["splitfc t".into(), "retx".into(), "vanilla t".into(), "speedup".into()],
        &rows,
    );

    // --- error feedback: long-run mean error at harsh compression -------
    let scheme = Scheme::SplitFc {
        drop: Some(DropKind::Deterministic),
        r: 16.0,
        quant: FwqMode::Optimal { use_mean: true },
    };
    let params = CodecParams::new(b, d, 0.2);
    let rounds = 24;
    let mut ef = ErrorFeedback::new(b, d);
    let mut rng_a = Rng::new(1);
    let mut rng_b = Rng::new(1);
    let mut mean_ef = Matrix::zeros(b, d);
    let mut mean_raw = Matrix::zeros(b, d);
    for _ in 0..rounds {
        let e = ef.encode_round(&scheme, &f, chan, &params, &mut rng_a);
        for (m, &v) in mean_ef.data.iter_mut().zip(&e.f_hat.data) {
            *m += v / rounds as f32;
        }
        let e = encode_uplink(&scheme, &f, &sigma, &params, &mut rng_b);
        for (m, &v) in mean_raw.data.iter_mut().zip(&e.f_hat.data) {
            *m += v / rounds as f32;
        }
    }
    println!(
        "\nSplitFC-EF extension: {rounds}-round mean reconstruction error \
         {:.4} (EF) vs {:.4} (memoryless), residual norm {:.2}",
        (f.sq_dist(&mean_ef) / f.sq_norm()).sqrt(),
        (f.sq_dist(&mean_raw) / f.sq_norm()).sqrt(),
        ef.residual_norm(),
    );
}
