//! Communication-budget planner: a deployment-facing tool built on the
//! paper's overhead model (Remark 1 + eq. 17 + the intro's latency math).
//!
//! Given a wireless link capacity and an SL deployment (devices, batch,
//! feature dim, rounds), it reports wall-clock transfer time for vanilla SL
//! and for SplitFC at several (R, C_e) operating points — including the
//! paper's intro example (10 Mbps, B=256, Dbar=8192, T=100, K=100
//! => ~1.34e5 s uncompressed).
//!
//! It also *measures* the real encoded sizes by running the actual codec on
//! a synthetic feature matrix with the requested dimensions, so the plan is
//! based on true frame bits, not just the formula.
//!
//! Run:  cargo run --release --example comm_budget_planner -- \
//!           [--capacity-bps 10e6 --batch 256 --dbar 8192 --devices 100 --iters 100]

use splitfc::bench::print_table;
use splitfc::compression::{encode_uplink, CodecParams, Scheme};
use splitfc::tensor::{column_stats, normalized_sigma, Matrix};
use splitfc::transport::channel::vanilla_sl_transfer_time_s;
use splitfc::util::{Args, Rng};

fn main() {
    let args = Args::from_env();
    let cap = args.get_f64("capacity-bps", 10e6);
    let batch = args.get_usize("batch", 256);
    let dbar = args.get_usize("dbar", 8192);
    let devices = args.get_usize("devices", 100);
    let iters = args.get_usize("iters", 100);

    let vanilla_s = vanilla_sl_transfer_time_s(cap, batch, dbar, iters, devices);
    println!(
        "deployment: {devices} devices x {iters} iterations, B={batch}, Dbar={dbar}, \
         link {:.1} Mbps",
        cap / 1e6
    );
    println!("vanilla SL total transfer time: {vanilla_s:.3e} s (paper intro: ~1.34e5 s)");

    // synth features with realistic heterogeneous dispersion
    let mut rng = Rng::new(7);
    let f = Matrix::from_fn(batch, dbar, |_, c| {
        let scale = match c % 5 {
            0 => 4.0,
            1 => 1.0,
            2 => 0.2,
            3 => 0.02,
            _ => 0.0,
        };
        scale * rng.normal_f32(0.0, 1.0) + (c % 17) as f32 * 0.05
    });
    let sigma = normalized_sigma(&column_stats(&f), 64.min(dbar));

    let mut rows = Vec::new();
    for (r, ce) in [(8.0, 0.4), (16.0, 0.2), (16.0, 0.133), (16.0, 0.1)] {
        let params = CodecParams::new(batch, dbar, ce);
        let mut rng = Rng::new(1);
        let enc = encode_uplink(&Scheme::splitfc(r), &f, &sigma, &params, &mut rng);
        let per_step_bits = enc.frame.payload_bits as f64;
        // downlink approximated as the same budget (paper Table II couples them)
        let total_s = 2.0 * per_step_bits * (iters * devices) as f64 / cap;
        rows.push((
            format!("SplitFC R={r} C_e={ce}"),
            vec![
                format!("{:.0}x", 32.0 / ce),
                format!("{:.2}", per_step_bits / 1e6),
                format!("{:.3e}", total_s),
                format!("{:.0}x", vanilla_s / total_s),
            ],
        ));
    }
    print_table(
        "SplitFC operating points (measured frame bits)",
        &[
            "target ratio".into(),
            "Mbit/step".into(),
            "total time s".into(),
            "speedup".into(),
        ],
        &rows,
    );
}
