//! Scenario example: the paper's MNIST setting, comparing SplitFC against
//! the strongest baselines at a 160x uplink compression budget, plus the
//! dropout-variant story of Fig. 3 — in one runnable binary.
//!
//! Run:  cargo run --release --example mnist_splitfc   (native backend)
//!       (shrink with --rounds/--devices for a faster pass)

use splitfc::bench::print_table;
use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::util::{Args, Result};

fn accuracy(scheme: &str, r: f64, up_bpe: f64, args: &Args) -> Result<(f32, f64)> {
    let mut cfg = TrainConfig::for_preset("mnist");
    cfg.rounds = args.get_usize("rounds", 10);
    cfg.devices = args.get_usize("devices", 8);
    cfg.scheme = parse_scheme(scheme, r)?;
    cfg.up_bits_per_entry = up_bpe;
    let mut tr = Trainer::new(cfg)?;
    let s = tr.run()?;
    let bpe = s.uplink_bits_per_entry(tr.preset().batch, tr.preset().dbar);
    Ok((s.final_acc, bpe))
}

fn main() -> Result<()> {
    let args = Args::from_env();

    println!("== SplitFC vs baselines, MNIST scenario, 160x uplink budget ==");
    let mut rows = Vec::new();
    for (label, scheme, r, bpe) in [
        ("Vanilla SL (1x)", "vanilla", 1.0, 32.0),
        ("SplitFC (160x)", "splitfc", 16.0, 0.2),
        ("FedLite (160x)", "fedlite", 1.0, 0.2),
        ("Top-S (160x)", "tops", 1.0, 0.2),
        ("RandTop-S (160x)", "randtops", 1.0, 0.2),
    ] {
        let (acc, measured) = accuracy(scheme, r, bpe, &args)?;
        rows.push((
            label.to_string(),
            vec![format!("{:.2}", acc * 100.0), format!("{measured:.3}")],
        ));
    }
    print_table(
        "accuracy at equal uplink budget",
        &["acc %".into(), "measured b/entry".into()],
        &rows,
    );

    println!("\n== dropout variants (Fig. 3 mechanism), R = 16, no quantization ==");
    let mut rows = Vec::new();
    for (label, scheme) in [
        ("adaptive (SplitFC-AD)", "splitfc-ad"),
        ("random", "splitfc-rand"),
        ("deterministic", "splitfc-det"),
    ] {
        let (acc, _) = accuracy(scheme, 16.0, 32.0, &args)?;
        rows.push((label.to_string(), vec![format!("{:.2}", acc * 100.0)]));
    }
    print_table("dropout variant accuracy", &["acc %".into()], &rows);
    println!("\nexpected shape: SplitFC ≈ vanilla >> sparsification baselines;");
    println!("adaptive dropout ≥ random > deterministic (paper Fig. 3, Table I).");
    Ok(())
}
