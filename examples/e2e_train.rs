//! End-to-end validation driver (DESIGN.md §5 "e2e", EXPERIMENTS.md §E2E).
//!
//! Trains the paper-exact MNIST split model (LeNet variant, N_d = 4,800,
//! N_s = 148,874, Dbar = 1,152) for a few hundred round-robin steps on the
//! synthetic non-IID corpus, side by side:
//!   * vanilla SL (lossless links), and
//!   * SplitFC at a 160x uplink compression budget (C_e,d = 0.2 bits/entry),
//! logging the loss curve and eval accuracy each round, proving every layer
//! composes: synthetic data -> device_fwd (Pallas matmul HLO via PJRT) ->
//! feature_stats (Pallas stats kernel) -> FWDP/FWQ bit-exact codec ->
//! server_fwd_bwd -> FWQ'd gradients -> device_bwd -> ADAM.
//!
//! Run:  make artifacts && cargo run --release --example e2e_train
//!       (flags: --rounds N --devices K --scheme S --up-bpe X)

use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::util::Args;

fn run(label: &str, scheme: &str, up_bpe: f64, args: &Args) -> anyhow::Result<()> {
    let mut cfg = TrainConfig::for_preset("mnist");
    cfg.rounds = args.get_usize("rounds", 25); // 25 rounds x 8 devices = 200 steps
    cfg.devices = args.get_usize("devices", 8);
    cfg.scheme = parse_scheme(scheme, args.get_f64("r", 16.0));
    cfg.up_bits_per_entry = up_bpe;
    cfg.eval_every = args.get_usize("eval-every", 5);
    cfg.metrics_path = format!("results/e2e_{label}.jsonl");
    std::fs::create_dir_all("results").ok();

    println!("\n=== {label}: {} @ C_e,d = {up_bpe} bits/entry ===", cfg.scheme.name());
    let mut tr = Trainer::new(cfg)?;
    let mut losses = Vec::new();
    let rounds = tr.cfg.rounds;
    let devices = tr.cfg.devices;
    for t in 1..=rounds {
        let mut round_loss = 0.0;
        for k in 0..devices {
            let rec = tr.step(t, k)?;
            round_loss += rec.loss;
        }
        losses.push(round_loss / devices as f32);
        if t % tr.cfg.eval_every.max(1) == 0 || t == rounds {
            let acc = tr.evaluate()?;
            println!(
                "round {t:>3}  steps {:>4}  mean-loss {:.4}  eval-acc {:.2}%",
                t * devices,
                losses.last().unwrap(),
                acc * 100.0
            );
        }
    }
    let rep = tr.link.report();
    println!(
        "loss curve: {} -> {} (first -> last round mean)",
        losses.first().unwrap(),
        losses.last().unwrap()
    );
    println!(
        "comm: up {:.2} Mbit, down {:.2} Mbit, modeled transfer {:.1}s @10Mbps",
        rep.up_bits as f64 / 1e6,
        rep.down_bits as f64 / 1e6,
        rep.elapsed_s
    );
    anyhow::ensure!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease"
    );
    Ok(())
}

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    run("vanilla", "vanilla", 32.0, &args)?;
    run("splitfc160x", "splitfc", 0.2, &args)?;
    println!("\nE2E OK: both runs learned; SplitFC at 160x uplink compression.");
    Ok(())
}
