//! End-to-end validation driver (DESIGN.md §5 "e2e", EXPERIMENTS.md §E2E).
//!
//! Trains the MNIST-scenario split model (28×28 inputs, cut-layer width
//! D̄ = 1,152 as in the paper) for a few hundred round-robin steps on the
//! synthetic non-IID corpus, side by side:
//!   * vanilla SL (lossless links), and
//!   * SplitFC at a 160x uplink compression budget (C_e,d = 0.2 bits/entry),
//! logging the loss curve and eval accuracy each round, proving every layer
//! composes: synthetic data -> device_fwd -> feature_stats (σ kernel, eq. 10)
//! -> FWDP/FWQ bit-exact codec -> server_fwd_bwd -> FWQ'd gradients ->
//! device_bwd -> ADAM. Runs on the native backend by default; pass
//! `--backend pjrt` (with `--features pjrt` + artifacts) for the HLO path.
//!
//! Run:  cargo run --release --example e2e_train
//!       (flags: --rounds N --devices K --r R --backend native|pjrt)

use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::ensure;
use splitfc::util::{Args, Result};

fn run(label: &str, scheme: &str, up_bpe: f64, args: &Args) -> Result<()> {
    let mut cfg = TrainConfig::for_preset("mnist");
    // generic overrides (--backend, --seed, ...) first; the per-run fields
    // below — scheme, budgets, metrics path — are fixed by this driver and
    // always win (each run writes its own metrics file)
    cfg.apply_overrides(args)?;
    cfg.rounds = args.get_usize("rounds", 25); // 25 rounds x 8 devices = 200 steps
    cfg.devices = args.get_usize("devices", 8);
    cfg.scheme = parse_scheme(scheme, args.get_f64("r", 16.0))?;
    cfg.up_bits_per_entry = up_bpe;
    cfg.down_bits_per_entry = 32.0;
    cfg.eval_every = args.get_usize("eval-every", 5);
    cfg.metrics_path = format!("results/e2e_{label}.jsonl");
    std::fs::create_dir_all("results").ok();

    println!("\n=== {label}: {} @ C_e,d = {up_bpe} bits/entry ===", cfg.scheme);
    let mut tr = Trainer::new(cfg)?;
    let mut losses = Vec::new();
    let rounds = tr.cfg.rounds;
    let devices = tr.cfg.devices;
    for t in 1..=rounds {
        let mut round_loss = 0.0;
        for k in 0..devices {
            let rec = tr.step(t, k)?;
            round_loss += rec.loss;
        }
        losses.push(round_loss / devices as f32);
        if t % tr.cfg.eval_every.max(1) == 0 || t == rounds {
            let acc = tr.evaluate()?;
            println!(
                "round {t:>3}  steps {:>4}  mean-loss {:.4}  eval-acc {:.2}%",
                t * devices,
                losses.last().unwrap(),
                acc * 100.0
            );
        }
    }
    let rep = tr.link_report();
    println!(
        "loss curve: {} -> {} (first -> last round mean)",
        losses.first().unwrap(),
        losses.last().unwrap()
    );
    println!(
        "comm: up {:.2} Mbit, down {:.2} Mbit, modeled transfer {:.1}s @10Mbps",
        rep.up_bits as f64 / 1e6,
        rep.down_bits as f64 / 1e6,
        rep.elapsed_s
    );
    ensure!(
        losses.last().unwrap() < losses.first().unwrap(),
        "loss did not decrease"
    );
    Ok(())
}

fn main() -> Result<()> {
    let args = Args::from_env();
    run("vanilla", "vanilla", 32.0, &args)?;
    run("splitfc160x", "splitfc", 0.2, &args)?;
    println!("\nE2E OK: both runs learned; SplitFC at 160x uplink compression.");
    Ok(())
}
