//! Threaded-runtime invariants: the parallel FWQ encoder must emit
//! bitstreams byte-identical to a single-threaded run, and the blocked
//! matmul kernels must match the scalar references, for arbitrary shapes
//! including degenerate (constant-column) inputs.
//!
//! Matrix widths here are deliberately ≥ the codec's parallelism gates
//! (candidate scan at D̂ ≥ 256, entry-code fan-out at > 8192/B columns,
//! column stats at > 512 columns) so the threaded paths genuinely run —
//! narrower fixtures would compare the serial encoder against itself.
//!
//! The pool size is process-global, and the harness runs these tests
//! concurrently — that is fine *because* the property under test is exactly
//! thread-count independence: whatever the global happens to be mid-call,
//! the outputs asserted equal must stay equal.

use splitfc::compression::{fwq_encode, FwqConfig};
use splitfc::tensor::{column_stats, Matrix};
use splitfc::testkit::hetero_matrix;
use splitfc::util::{par, Rng};

#[test]
fn threaded_fwq_bitstream_is_byte_identical_to_serial() {
    // widths straddle every parallelism gate (see module docs)
    for (i, &(b, d)) in [(8usize, 16usize), (32, 600), (64, 333), (16, 1200)].iter().enumerate() {
        let a = hetero_matrix(b, d, 100 + i as u64);
        for bpe in [0.5f64, 2.0] {
            let cfg = FwqConfig::paper_default(b, bpe * (b * d) as f64);
            par::set_threads(1);
            let (by1, bits1, info1) = fwq_encode(&a, &cfg);
            par::set_threads(4);
            let (by4, bits4, info4) = fwq_encode(&a, &cfg);
            par::set_threads(0);
            assert_eq!(by1, by4, "B={b} D={d} bpe={bpe}");
            assert_eq!(bits1, bits4);
            assert_eq!(info1.m_star, info4.m_star);
            assert_eq!(info1.candidates_tried, info4.candidates_tried);
        }
    }
}

#[test]
fn threaded_fwq_identical_on_degenerate_inputs() {
    // wide degenerates (600 columns — past the parallel gates): an
    // all-constant matrix and a half-constant-column matrix, plus a
    // single-column edge case
    let degenerates = [
        Matrix::from_fn(16, 600, |_, _| 1.5),
        Matrix::from_fn(16, 600, |r, c| if c % 2 == 0 { 3.0 } else { r as f32 * 0.1 }),
        Matrix::from_fn(32, 1, |r, _| (r % 5) as f32),
    ];
    for (i, a) in degenerates.iter().enumerate() {
        for bpe in [0.3f64, 1.0, 4.0] {
            let cfg = FwqConfig::paper_default(a.rows, bpe * (a.rows * a.cols) as f64);
            par::set_threads(1);
            let (by1, ..) = fwq_encode(a, &cfg);
            par::set_threads(3);
            let (by3, ..) = fwq_encode(a, &cfg);
            par::set_threads(0);
            assert_eq!(by1, by3, "degenerate {i} bpe={bpe}");
        }
    }
}

#[test]
fn column_stats_identical_across_thread_counts() {
    // past the element gate and wider than one column chunk, so the
    // parallel splice genuinely runs
    let m = hetero_matrix(128, 1200, 7);
    par::set_threads(1);
    let s1 = column_stats(&m);
    par::set_threads(4);
    let s4 = column_stats(&m);
    par::set_threads(0);
    assert_eq!(s1.min, s4.min);
    assert_eq!(s1.max, s4.max);
    assert_eq!(s1.mean, s4.mean);
    assert_eq!(s1.std, s4.std);
}

#[test]
fn blocked_matmul_matches_scalar_reference_on_random_shapes() {
    let shapes = [
        (1usize, 1usize, 1usize),
        (3, 5, 7),
        (4, 4, 4),
        (5, 17, 3),
        (2, 300, 2),
        (33, 64, 129),
        (65, 129, 33),
        // > PAR_WORK_MIN madds: exercises the multi-chunk parallel dispatch
        (48, 300, 100),
    ];
    for (s, &(n, m, p)) in shapes.iter().enumerate() {
        let mut rng = Rng::new(7 + s as u64);
        // sprinkle exact zeros: the regime the old kernels' zero-skip hit
        let mut gen = |_r: usize, _c: usize| {
            let v = rng.normal_f32(0.0, 1.0);
            if v < -0.3 {
                0.0
            } else {
                v
            }
        };
        let a = Matrix::from_fn(n, m, &mut gen);
        let b = Matrix::from_fn(m, p, &mut gen);
        let c = Matrix::from_fn(n, p, &mut gen);
        let d = Matrix::from_fn(p, m, &mut gen);
        for threads in [1usize, 4] {
            par::set_threads(threads);
            check_close(&a.matmul(&b), &a.matmul_ref(&b), n, m, p, "matmul");
            check_close(&a.matmul_tn(&c), &a.matmul_tn_ref(&c), n, m, p, "matmul_tn");
            check_close(&a.matmul_nt(&d), &a.matmul_nt_ref(&d), n, m, p, "matmul_nt");
        }
        par::set_threads(0);
    }
}

fn check_close(got: &Matrix, want: &Matrix, n: usize, m: usize, p: usize, name: &str) {
    assert_eq!((got.rows, got.cols), (want.rows, want.cols), "{name} {n}x{m}x{p}");
    let scale = want.sq_norm().sqrt().max(1.0);
    let dist = got.sq_dist(want).sqrt();
    assert!(
        dist <= 1e-5 * scale,
        "{name} {n}x{m}x{p}: rel err {} (dist {dist}, scale {scale})",
        dist / scale
    );
}
