//! Integration: the python->HLO->PJRT->rust contract, over the real `tiny`
//! artifacts (built by `make artifacts`). This test target only exists under
//! `--features pjrt` (see `required-features` in Cargo.toml) and needs a
//! real xla crate patched in place of `third_party/xla-stub`.

use std::path::{Path, PathBuf};

use splitfc::runtime::{literal_to_vec_f32, matrix_to_literal, vec_to_literal, Runtime};
use splitfc::tensor::{column_stats, normalized_sigma, Matrix};
use splitfc::util::Rng;

fn artifacts_dir() -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts")
}

fn runtime() -> Runtime {
    Runtime::load(&artifacts_dir(), "tiny").expect("run `make artifacts` before cargo test")
}

fn random_input(rt: &Runtime, seed: u64) -> (Vec<f32>, Vec<usize>) {
    let p = &rt.preset;
    let shape = vec![p.batch, p.in_shape[0], p.in_shape[1], p.in_shape[2]];
    let n: usize = shape.iter().product();
    let mut rng = Rng::new(seed);
    ((0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect(), shape)
}

fn param_literals(set: &splitfc::model::ParamSet) -> Vec<xla::Literal> {
    (0..set.n_tensors())
        .map(|i| vec_to_literal(set.tensor(i), &set.specs[i].shape).unwrap())
        .collect()
}

#[test]
fn loads_all_entries_and_params() {
    let rt = runtime();
    for entry in ["device_fwd", "server_fwd_bwd", "device_bwd", "eval_fwd", "feature_stats"] {
        assert!(rt.has_entry(entry), "{entry} missing");
    }
    let (wd, ws) = rt.load_params().unwrap();
    assert_eq!(wd.n_params(), rt.preset.nd_params);
    assert_eq!(ws.n_params(), rt.preset.ns_params);
}

#[test]
fn device_fwd_shape_and_determinism() {
    let rt = runtime();
    let (wd, _) = rt.load_params().unwrap();
    let (x, shape) = random_input(&rt, 1);
    let mut inputs = param_literals(&wd);
    inputs.push(vec_to_literal(&x, &shape).unwrap());
    let o1 = rt.exec("device_fwd", &inputs).unwrap();
    let f1 = literal_to_vec_f32(&o1[0]).unwrap();
    assert_eq!(f1.len(), rt.preset.batch * rt.preset.dbar);
    let o2 = rt.exec("device_fwd", &inputs).unwrap();
    let f2 = literal_to_vec_f32(&o2[0]).unwrap();
    assert_eq!(f1, f2, "PJRT CPU execution must be deterministic");
    // ReLU output: non-negative
    assert!(f1.iter().all(|&v| v >= 0.0 && v.is_finite()));
}

#[test]
fn eval_fwd_equals_device_then_server_composition() {
    // split consistency: h(w_s, g(w_d, x)) computed as two artifacts must
    // agree with the fused eval artifact.
    let rt = runtime();
    let (wd, ws) = rt.load_params().unwrap();
    let p = rt.preset.clone();
    let (x, shape) = random_input(&rt, 2);
    let mut inputs = param_literals(&wd);
    inputs.push(vec_to_literal(&x, &shape).unwrap());
    let f = rt.exec("device_fwd", &inputs).unwrap();
    let f_vec = literal_to_vec_f32(&f[0]).unwrap();

    // server forward piece of server_fwd_bwd: recover logits via loss on a
    // one-hot target is awkward — use eval_fwd against device_fwd+server math
    let mut inputs = param_literals(&wd);
    inputs.extend(param_literals(&ws));
    inputs.push(vec_to_literal(&x, &shape).unwrap());
    let logits = literal_to_vec_f32(&rt.exec("eval_fwd", &inputs).unwrap()[0]).unwrap();
    assert_eq!(logits.len(), p.batch * p.classes);

    // consistency check: loss from server_fwd_bwd on F equals softmax-xent
    // of eval_fwd's logits for the same labels.
    let mut y = vec![0.0f32; p.batch * p.classes];
    for b in 0..p.batch {
        y[b * p.classes + b % p.classes] = 1.0;
    }
    let mut s_in = param_literals(&ws);
    s_in.push(vec_to_literal(&f_vec, &[p.batch, p.dbar]).unwrap());
    s_in.push(vec_to_literal(&y, &[p.batch, p.classes]).unwrap());
    let outs = rt.exec("server_fwd_bwd", &s_in).unwrap();
    let loss = literal_to_vec_f32(&outs[0]).unwrap()[0];

    let mut expect = 0.0f64;
    for b in 0..p.batch {
        let row = &logits[b * p.classes..(b + 1) * p.classes];
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let lse = mx + row.iter().map(|&v| ((v - mx) as f64).exp()).sum::<f64>().ln() as f32;
        expect += (lse - row[b % p.classes]) as f64;
    }
    expect /= p.batch as f64;
    assert!(
        (loss as f64 - expect).abs() < 1e-4 * expect.abs().max(1.0),
        "loss {loss} vs recomputed {expect}"
    );
}

#[test]
fn feature_stats_artifact_matches_host_oracle() {
    // the L1 Pallas kernel (through the whole AOT+PJRT chain) vs the rust
    // host implementation — the strongest cross-layer correctness signal.
    let rt = runtime();
    let p = rt.preset.clone();
    let mut rng = Rng::new(3);
    let f = Matrix::from_fn(p.batch, p.dbar, |_, c| {
        (1.0 + (c % 7) as f32) * rng.normal_f32(0.0, 1.0) + c as f32 * 0.3
    });
    let outs = rt.exec("feature_stats", &[matrix_to_literal(&f).unwrap()]).unwrap();
    let k_min = literal_to_vec_f32(&outs[0]).unwrap();
    let k_max = literal_to_vec_f32(&outs[1]).unwrap();
    let k_mean = literal_to_vec_f32(&outs[2]).unwrap();
    let k_sigma = literal_to_vec_f32(&outs[3]).unwrap();

    let st = column_stats(&f);
    let sigma = normalized_sigma(&st, p.chan_size);
    for c in 0..p.dbar {
        assert!((k_min[c] - st.min[c]).abs() < 1e-4, "min col {c}");
        assert!((k_max[c] - st.max[c]).abs() < 1e-4, "max col {c}");
        assert!((k_mean[c] - st.mean[c]).abs() < 1e-4, "mean col {c}");
        assert!((k_sigma[c] - sigma[c]).abs() < 1e-3, "sigma col {c}: {} vs {}", k_sigma[c], sigma[c]);
    }
}

#[test]
fn device_bwd_zero_cotangent_gives_zero_grads() {
    let rt = runtime();
    let (wd, _) = rt.load_params().unwrap();
    let p = rt.preset.clone();
    let (x, shape) = random_input(&rt, 4);
    let zeros = vec![0.0f32; p.batch * p.dbar];
    let mut inputs = param_literals(&wd);
    inputs.push(vec_to_literal(&x, &shape).unwrap());
    inputs.push(vec_to_literal(&zeros, &[p.batch, p.dbar]).unwrap());
    let outs = rt.exec("device_bwd", &inputs).unwrap();
    for o in &outs {
        let v = literal_to_vec_f32(o).unwrap();
        assert!(v.iter().all(|&g| g == 0.0));
    }
}

#[test]
fn exec_arity_is_validated() {
    let rt = runtime();
    let err = rt.exec("device_fwd", &[]);
    assert!(err.is_err());
    assert!(rt.exec("nonexistent", &[]).is_err());
}
