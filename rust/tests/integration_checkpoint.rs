//! Integration: the checkpoint/resume subsystem end to end.
//!
//! The contracts under test:
//!  * checkpointing is metrics-neutral — a run that snapshots every N
//!    rounds emits byte-identical deterministic step fields to one that
//!    never snapshots;
//!  * `--resume` from a round-barrier snapshot replays the remainder of
//!    the run byte-identically into the *same* metrics file (append after
//!    truncating post-snapshot records) — inproc and TCP, calm and under
//!    `--scenario`;
//!  * corrupt, truncated, wrong-version, wrong-config and
//!    nothing-left-to-resume checkpoints are rejected with typed errors
//!    **before any state is mutated** (the metrics file is untouched);
//!  * `checkpoint::inspect` describes a file without decoding tensors; and
//!  * retention keeps only the newest `--checkpoint-keep` snapshots.

use splitfc::checkpoint::{self, Checkpoint};
use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::scenario::ScenarioSpec;
use splitfc::transport::TransportKind;
use splitfc::util::Json;

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("splitfc_ckpt_{tag}_{}", std::process::id()))
}

/// Base fleet: tiny preset, 4 devices, 6 rounds, the error-feedback codec
/// variant (its residual is the session state a resume must not lose).
fn base_cfg(metrics: &str, ckpt_dir: &str, ckpt_every: usize) -> TrainConfig {
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 4;
    cfg.rounds = 6;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.eval_every = 3;
    cfg.seed = 11;
    cfg.scheme = parse_scheme("splitfc[ad,R=4,fwq,ef]", 4.0).unwrap();
    cfg.up_bits_per_entry = 2.0;
    cfg.down_bits_per_entry = 4.0;
    cfg.metrics_path = metrics.to_string();
    cfg.checkpoint_every = ckpt_every;
    cfg.checkpoint_dir = ckpt_dir.to_string();
    cfg
}

/// The deterministic fields of every step record (wall-clock excluded).
fn step_fields(path: &std::path::Path) -> Vec<String> {
    let text = std::fs::read_to_string(path).expect("metrics file");
    let mut out = Vec::new();
    for line in text.lines() {
        let j = Json::parse(line).expect("valid JSONL");
        if j.get("g").is_none() {
            continue; // the trailing summary record
        }
        let mut fields = Vec::new();
        for key in [
            "t", "k", "g", "loss", "train_acc", "up_bits", "down_bits", "up_nominal",
            "down_nominal",
        ] {
            let v = j
                .get(key)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("field {key} in {line}"));
            fields.push(format!("{key}={v:?}"));
        }
        out.push(fields.join(" "));
    }
    out
}

fn run_with(cfg: TrainConfig) -> splitfc::coordinator::TrainSummary {
    let mut tr = Trainer::new(cfg).unwrap();
    tr.run().unwrap()
}

fn ckpt_file(dir: &std::path::Path, round: u32) -> std::path::PathBuf {
    dir.join(Checkpoint::file_name(round))
}

#[test]
fn resume_is_byte_identical_inproc() {
    let ref_path = tmp_path("inproc_ref.jsonl");
    let live_path = tmp_path("inproc_live.jsonl");
    let dir = tmp_path("inproc_dir");

    // reference: uninterrupted, never snapshots
    run_with(base_cfg(ref_path.to_str().unwrap(), "", 0));
    let want = step_fields(&ref_path);
    assert_eq!(want.len(), 24);

    // snapshotting every 2 rounds must not perturb a single field
    let s = run_with(base_cfg(live_path.to_str().unwrap(), dir.to_str().unwrap(), 2));
    assert_eq!(s.steps, 24);
    assert_eq!(step_fields(&live_path), want, "checkpointing perturbed the trajectory");
    for r in [2u32, 4, 6] {
        assert!(ckpt_file(&dir, r).exists(), "missing snapshot for round {r}");
    }

    // "kill" after round 4: resume from its snapshot into the SAME metrics
    // file — rounds 5..6 replay and the stream is byte-identical again
    let mut cfg = base_cfg(live_path.to_str().unwrap(), "", 0);
    cfg.resume = ckpt_file(&dir, 4).to_str().unwrap().to_string();
    let s = run_with(cfg);
    assert_eq!(s.steps, 24, "resumed summary must count the whole run");
    assert_eq!(step_fields(&live_path), want, "resume diverged from the uninterrupted run");

    std::fs::remove_file(&ref_path).ok();
    std::fs::remove_file(&live_path).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_is_byte_identical_over_tcp_concurrent() {
    let ref_path = tmp_path("tcp_ref.jsonl");
    let live_path = tmp_path("tcp_live.jsonl");
    let dir = tmp_path("tcp_dir");

    let mut cfg = base_cfg(ref_path.to_str().unwrap(), "", 0);
    cfg.transport = TransportKind::Tcp;
    cfg.concurrent_devices = 2;
    run_with(cfg);
    let want = step_fields(&ref_path);
    assert_eq!(want.len(), 24);

    let mut cfg = base_cfg(live_path.to_str().unwrap(), dir.to_str().unwrap(), 3);
    cfg.transport = TransportKind::Tcp;
    cfg.concurrent_devices = 2;
    run_with(cfg);
    assert_eq!(step_fields(&live_path), want);

    // resume from the round-3 barrier, still TCP + concurrent workers
    let mut cfg = base_cfg(live_path.to_str().unwrap(), dir.to_str().unwrap(), 3);
    cfg.transport = TransportKind::Tcp;
    cfg.concurrent_devices = 2;
    cfg.resume = ckpt_file(&dir, 3).to_str().unwrap().to_string();
    let s = run_with(cfg);
    assert_eq!(s.steps, 24);
    assert_eq!(step_fields(&live_path), want, "TCP resume diverged");

    std::fs::remove_file(&ref_path).ok();
    std::fs::remove_file(&live_path).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn resume_is_byte_identical_under_scenario() {
    // straggler stretches wall time; depart removes device 3 after the
    // resume point — the restored run must reproduce both exactly
    let spec = "seed=7,straggler[dev=1,slow=2x],depart[dev=3,round=5]";
    let ref_path = tmp_path("scen_ref.jsonl");
    let live_path = tmp_path("scen_live.jsonl");
    let dir = tmp_path("scen_dir");

    let mut cfg = base_cfg(ref_path.to_str().unwrap(), "", 0);
    cfg.scenario = ScenarioSpec::parse(spec).unwrap();
    let s = run_with(cfg);
    assert_eq!(s.steps, 22, "device 3 sits out rounds 5 and 6");
    let want = step_fields(&ref_path);

    let mut cfg = base_cfg(live_path.to_str().unwrap(), dir.to_str().unwrap(), 2);
    cfg.scenario = ScenarioSpec::parse(spec).unwrap();
    run_with(cfg);
    assert_eq!(step_fields(&live_path), want);

    let mut cfg = base_cfg(live_path.to_str().unwrap(), "", 0);
    cfg.scenario = ScenarioSpec::parse(spec).unwrap();
    cfg.resume = ckpt_file(&dir, 4).to_str().unwrap().to_string();
    let s = run_with(cfg);
    assert_eq!(s.steps, 22);
    assert_eq!(step_fields(&live_path), want, "scenario resume diverged");

    std::fs::remove_file(&ref_path).ok();
    std::fs::remove_file(&live_path).ok();
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn retention_keeps_only_the_newest_snapshots() {
    let dir = tmp_path("keep_dir");
    let mut cfg = base_cfg("", dir.to_str().unwrap(), 2);
    cfg.checkpoint_keep = 1;
    run_with(cfg);
    let found = checkpoint::list(&dir).unwrap();
    assert_eq!(found.len(), 1, "keep=1 must prune older snapshots: {found:?}");
    assert_eq!(found[0], ckpt_file(&dir, 6));
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn inspect_describes_a_snapshot_without_decoding_tensors() {
    let dir = tmp_path("inspect_dir");
    run_with(base_cfg("", dir.to_str().unwrap(), 2));
    let path = ckpt_file(&dir, 4);
    let info = checkpoint::inspect(&path).unwrap();
    assert_eq!(info.header.format, checkpoint::FORMAT_VERSION);
    assert_eq!(info.header.round, 4);
    assert_eq!(info.header.devices, 4);
    assert_eq!(info.header.rounds, 6);
    assert_eq!(info.header.seed, 11);
    assert_eq!(info.header.preset, "tiny");
    let names: Vec<&str> = info.sections.iter().map(|s| s.name.as_str()).collect();
    assert_eq!(names, ["server", "sched", "links"]);
    assert_eq!(info.file_len, std::fs::metadata(&path).unwrap().len());
    std::fs::remove_dir_all(&dir).ok();
}

#[test]
fn invalid_checkpoints_are_rejected_before_any_state_mutated() {
    let metrics = tmp_path("reject.jsonl");
    let dir = tmp_path("reject_dir");
    let mut cfg = base_cfg(metrics.to_str().unwrap(), dir.to_str().unwrap(), 2);
    cfg.devices = 2;
    cfg.rounds = 4;
    run_with(cfg);
    let good = ckpt_file(&dir, 2);
    let metrics_before = std::fs::read(&metrics).unwrap();
    let good_bytes = std::fs::read(&good).unwrap();

    // resume attempts below must fail BEFORE the metrics file is touched
    let resume_cfg = |resume: &std::path::Path| {
        let mut cfg = base_cfg(metrics.to_str().unwrap(), "", 0);
        cfg.devices = 2;
        cfg.rounds = 4;
        cfg.resume = resume.to_str().unwrap().to_string();
        cfg
    };
    let expect_reject = |tag: &str, path: &std::path::Path, needle: &str| {
        let err = Trainer::new(resume_cfg(path)).err().unwrap_or_else(|| {
            panic!("{tag}: a bad checkpoint must be rejected");
        });
        let msg = err.to_string();
        assert!(msg.contains(needle), "{tag}: {msg:?} should mention {needle:?}");
        assert_eq!(
            std::fs::read(&metrics).unwrap(),
            metrics_before,
            "{tag}: the metrics file was mutated by a rejected resume"
        );
    };

    // corrupt: flip one payload byte — a section CRC must catch it
    let bad = tmp_path("flip.splitfc");
    let mut bytes = good_bytes.clone();
    let last = bytes.len() - 1;
    bytes[last] ^= 0x40;
    std::fs::write(&bad, &bytes).unwrap();
    expect_reject("byte flip", &bad, "corrupt");

    // truncated mid-payload
    std::fs::write(&bad, &good_bytes[..good_bytes.len() - 7]).unwrap();
    expect_reject("truncation", &bad, "truncated");

    // a future format version must be refused, not misparsed
    let mut bytes = good_bytes.clone();
    bytes[8] = 0x63; // the u16 format field follows the 8-byte magic
    std::fs::write(&bad, &bytes).unwrap();
    expect_reject("future version", &bad, "not supported");

    // not a checkpoint at all
    let mut bytes = good_bytes.clone();
    bytes[0] = b'X';
    std::fs::write(&bad, &bytes).unwrap();
    expect_reject("bad magic", &bad, "magic");

    // config mismatches are named: the differing flag, not a hash dump
    {
        let mut cfg = resume_cfg(&good);
        cfg.seed = 12;
        let msg = Trainer::new(cfg).err().expect("seed mismatch").to_string();
        assert!(msg.contains("seed"), "{msg}");
    }
    {
        // lr is trajectory-critical but not a named header field: the
        // fingerprint is the catch-all
        let mut cfg = resume_cfg(&good);
        cfg.lr *= 2.0;
        let msg = Trainer::new(cfg).err().expect("lr mismatch").to_string();
        assert!(msg.contains("fingerprint"), "{msg}");
    }
    assert_eq!(std::fs::read(&metrics).unwrap(), metrics_before);

    // the final-round snapshot has nothing left to replay
    {
        let msg = Trainer::new(resume_cfg(&ckpt_file(&dir, 4)))
            .err()
            .expect("nothing to resume")
            .to_string();
        assert!(msg.contains("nothing to resume"), "{msg}");
    }

    // inspect rejects the corrupt file too (typed, no panic)
    std::fs::write(&bad, &good_bytes[..20]).unwrap();
    assert!(checkpoint::inspect(&bad).is_err());

    std::fs::remove_file(&bad).ok();
    std::fs::remove_file(&metrics).ok();
    std::fs::remove_dir_all(&dir).ok();
}
