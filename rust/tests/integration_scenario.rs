//! Integration: the seeded failure-scenario engine end to end.
//!
//! The contracts under test:
//!  * an empty (or wall-clock-only) scenario is trajectory-neutral — the
//!    deterministic step fields are byte-identical to a calm run;
//!  * scheduled absences (depart/wave clauses) pre-complete their steps so
//!    the surviving cohort finishes without deadlock, with exactly the
//!    expected step count and a finite loss mean;
//!  * the same `--scenario` spec twice reproduces the stream exactly, over
//!    TCP, churn and all;
//!  * the worker's seeded backoff surfaces its retry counters; and
//!  * a peer that vanishes mid-step (PS handler death) is departed by the
//!    liveness policy and the run completes degraded instead of wedging.

use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::scenario::ScenarioSpec;
use splitfc::transport::{Connection, Msg, TcpConn, TransportKind, WireLimits};
use splitfc::util::Json;

fn base_cfg(metrics: &str) -> TrainConfig {
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 4;
    cfg.rounds = 5;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.eval_every = 0;
    cfg.scheme = parse_scheme("splitfc", 4.0).unwrap();
    cfg.up_bits_per_entry = 2.0;
    cfg.down_bits_per_entry = 4.0;
    cfg.seed = 11;
    cfg.metrics_path = metrics.to_string();
    cfg
}

fn metrics_file(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("splitfc_scen_{tag}_{}.jsonl", std::process::id()))
}

/// The deterministic fields of every step record (wall-clock fields
/// excluded: stragglers stretch `step_s`/`exec_s` by design).
fn step_fields(path: &std::path::Path) -> Vec<String> {
    let text = std::fs::read_to_string(path).expect("metrics file");
    let mut out = Vec::new();
    for line in text.lines() {
        let j = Json::parse(line).expect("valid JSONL");
        if j.get("g").is_none() {
            continue; // the trailing summary record
        }
        let mut fields = Vec::new();
        for key in [
            "t", "k", "g", "loss", "train_acc", "up_bits", "down_bits", "up_nominal",
            "down_nominal",
        ] {
            let v = j
                .get(key)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("field {key} in {line}"));
            fields.push(format!("{key}={v:?}"));
        }
        out.push(fields.join(" "));
    }
    out
}

fn run_with(cfg: TrainConfig) -> splitfc::coordinator::TrainSummary {
    let mut tr = Trainer::new(cfg).unwrap();
    tr.run().unwrap()
}

#[test]
fn wall_clock_only_scenarios_are_trajectory_neutral() {
    let ref_path = metrics_file("calm");
    run_with(base_cfg(ref_path.to_str().unwrap()));
    let want = step_fields(&ref_path);
    assert_eq!(want.len(), 20);

    // a straggler stretches wall time only; a bare seed changes nothing
    for (tag, spec) in [
        ("straggler", "straggler[dev=1,slow=2x]"),
        ("seeded", "seed=12345"),
    ] {
        let path = metrics_file(tag);
        let mut cfg = base_cfg(path.to_str().unwrap());
        cfg.scenario = ScenarioSpec::parse(spec).unwrap();
        let s = run_with(cfg);
        assert_eq!(s.steps, 20, "{tag}: lost steps");
        assert_eq!(s.departed, 0, "{tag}: nothing should depart");
        assert_eq!(
            step_fields(&path),
            want,
            "{tag}: scenario {spec:?} perturbed the deterministic trajectory"
        );
        std::fs::remove_file(path).ok();
    }
    std::fs::remove_file(ref_path).ok();
}

#[test]
fn scheduled_departure_completes_with_the_surviving_cohort() {
    // device 2 departs before round 3: 2 rounds x 4 devices + 3 rounds x 3
    let mut cfg = base_cfg("");
    cfg.scenario = ScenarioSpec::parse("depart[dev=2,round=3]").unwrap();
    let s = run_with(cfg);
    assert_eq!(s.steps, 17, "survivors must run every remaining step");
    assert_eq!(s.departed, 0, "scheduled departures are not liveness departures");
    assert!(
        s.mean_loss_last_round.is_finite(),
        "the absent device's NaN loss must not poison the mean"
    );
}

#[test]
fn wave_joins_stagger_cohorts() {
    // cohorts of 2 join 2 rounds apart over 4 rounds: devices 0/1 run all 4
    // rounds, devices 2/3 join at round 3 -> 8 + 4 steps
    let mut cfg = base_cfg("");
    cfg.rounds = 4;
    cfg.scenario = ScenarioSpec::parse("wave[cohort=2,every=2r]").unwrap();
    let s = run_with(cfg);
    assert_eq!(s.steps, 12);
    assert!(s.mean_loss_last_round.is_finite());
}

#[test]
fn same_scenario_spec_reproduces_the_stream_over_tcp() {
    // cut -> a reconnect; dropout -> seeded outages; depart -> a guaranteed
    // scheduled absence (so the "<16 steps" check never hinges on the draws)
    let spec =
        "seed=7,cut[dev=0,step=2],dropout[p=0.2,rejoin=2r],depart[dev=3,round=4],straggler[p=0.5,slow=2x]";
    let mut streams = Vec::new();
    let mut steps = Vec::new();
    for pass in 0..2 {
        let path = metrics_file(&format!("det{pass}"));
        let mut cfg = base_cfg(path.to_str().unwrap());
        cfg.rounds = 4;
        cfg.transport = TransportKind::Tcp;
        cfg.scenario = ScenarioSpec::parse(spec).unwrap();
        let s = run_with(cfg);
        steps.push(s.steps);
        streams.push(step_fields(&path));
        std::fs::remove_file(path).ok();
    }
    assert_eq!(steps[0], steps[1], "same spec must schedule the same steps");
    assert!(steps[0] < 16, "the dropout clause should cost some steps");
    assert_eq!(
        streams[0], streams[1],
        "identical scenario seeds must give identical metrics streams"
    );
}

#[test]
fn backoff_retry_counters_surface_in_the_link_report() {
    // cut device 1 after its 3rd send (the round-1 Uplink): the worker must
    // recover through seeded backoff + reconnect, and say so in its report
    let ref_path = metrics_file("retry_ref");
    run_with(base_cfg(ref_path.to_str().unwrap()));
    let want = step_fields(&ref_path);

    let path = metrics_file("retry");
    let mut cfg = base_cfg(path.to_str().unwrap());
    cfg.transport = TransportKind::Tcp;
    cfg.scenario.push_cut(1, 3);
    let mut tr = Trainer::new(cfg).unwrap();
    let s = tr.run().unwrap();
    let rep = tr.link_report();
    drop(tr);
    assert_eq!(s.steps, 20, "the cut must not lose steps");
    assert!(rep.retry_attempts >= 1, "the recovery must be counted as a retry");
    assert!(rep.backoff_s > 0.0, "backoff sleep must be accounted");
    assert_eq!(step_fields(&path), want, "recovery must stay trajectory-neutral");
    std::fs::remove_file(ref_path).ok();
    std::fs::remove_file(path).ok();
}

#[test]
fn vanished_peer_is_departed_by_liveness_and_the_run_degrades() {
    // Device 3 "joins" remotely, requests its first step, receives StepGo,
    // and silently vanishes — the nastiest handler death: its serve loop
    // exits on the dead socket with the step still in flight. The liveness
    // policy must depart it and let the other 3 devices finish the run.
    let mut cfg = base_cfg("");
    cfg.rounds = 4;
    cfg.transport = TransportKind::Tcp;
    cfg.devices_remote = 1;
    cfg.liveness_timeout_s = 1.0;
    cfg.retry_deadline_s = 0.5; // only the fake peer faults; keep it short
    let codec = cfg.scheme.build().unwrap();
    let (codec_id, codec_version) = (codec.wire_id(), codec.wire_version());

    let mut tr = Trainer::new(cfg).unwrap();
    let addr = tr.listen_addr().expect("tcp trainer listens").to_string();
    let peer_addr = addr.clone();
    let peer = std::thread::spawn(move || {
        let limits = WireLimits::new(1 << 22);
        loop {
            let mut conn = TcpConn::connect(&peer_addr, limits).expect("dial");
            conn.send(Msg::Hello { device: 3, codec_id, codec_version }).expect("hello");
            match conn.recv().expect("hello ack") {
                Msg::HelloAck { err: Some(reason), .. } => panic!("rejected: {reason}"),
                Msg::HelloAck { rounds, .. } if rounds != u32::MAX => {
                    // the run is armed: enter step (t=1, l=3), then vanish
                    conn.send(Msg::StepStart { device: 3, round: 1, local: 3 })
                        .expect("step start");
                    match conn.recv().expect("step go") {
                        Msg::StepGo { .. } => {}
                        other => panic!("expected StepGo, got {other:?}"),
                    }
                    return; // connection drops with the step in flight
                }
                Msg::HelloAck { .. } => {
                    let _ = conn.send(Msg::Bye { device: 3 });
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
                other => panic!("expected HelloAck, got {other:?}"),
            }
        }
    });

    let s = tr.run().unwrap();
    peer.join().unwrap();
    assert_eq!(s.steps, 12, "the 3 survivors must finish all 4 rounds");
    assert_eq!(s.departed, 1, "the vanished device must be recorded as departed");
    assert!(s.mean_loss_last_round.is_finite());

    // a departed device that comes back is turned away at the handshake
    let mut conn = TcpConn::connect(&addr, WireLimits::new(1 << 22)).unwrap();
    conn.send(Msg::Hello { device: 3, codec_id, codec_version }).unwrap();
    match conn.recv().unwrap() {
        Msg::HelloAck { err: Some(reason), .. } => {
            assert!(reason.contains("departed"), "{reason}");
        }
        other => panic!("a departed device's hello must be rejected, got {other:?}"),
    }
}

#[test]
fn cut_clauses_require_a_reconnectable_transport() {
    let mut cfg = base_cfg("");
    cfg.scenario = ScenarioSpec::parse("cut[dev=0,step=2]").unwrap();
    // inproc links cannot reconnect: the trainer must refuse up front
    let err = Trainer::new(cfg).err().expect("cut on inproc must be rejected");
    assert!(err.to_string().contains("tcp"), "{err}");
}
