//! Property tests for the hardened wire layer: randomized protocol
//! messages must round-trip exactly through the byte encoding, and *any*
//! corruption — truncation at every boundary, random byte flips, hostile
//! length prefixes — must surface as a typed error, never a panic or an
//! attacker-sized allocation.

use splitfc::compression::GradMask;
use splitfc::transport::wire::{ByteCursor, Frame, FrameKind};
use splitfc::transport::{Msg, StepReport, WireLimits};
use splitfc::util::Rng;

fn limits() -> WireLimits {
    WireLimits::new(1 << 20)
}

fn rand_mask(rng: &mut Rng, dbar: usize) -> GradMask {
    match rng.next_u64() % 3 {
        0 => GradMask::All,
        1 => {
            let m = (rng.next_u64() as usize % dbar).max(1);
            GradMask::Columns {
                kept: (0..m).map(|_| rng.next_u64() as usize % dbar).collect(),
                scale: (0..m).map(|_| rng.next_f64() as f32).collect(),
            }
        }
        _ => {
            let rows = rng.next_u64() as usize % 9;
            GradMask::Entries(
                (0..rows)
                    .map(|_| {
                        let m = rng.next_u64() as usize % 7;
                        (0..m).map(|_| rng.next_u64() as usize % dbar).collect()
                    })
                    .collect(),
            )
        }
    }
}

fn rand_frame(rng: &mut Rng, kind: FrameKind) -> Frame {
    let n = rng.next_u64() as usize % 257;
    let payload: Vec<u8> = (0..n).map(|_| rng.next_u64() as u8).collect();
    let tail = if n == 0 { 0 } else { rng.next_u64() % 8 };
    let bits = (n as u64 * 8).saturating_sub(tail);
    Frame::new(kind, payload, bits)
}

fn rand_msg(rng: &mut Rng) -> Msg {
    let labels: Vec<f32> = (0..rng.next_u64() % 33).map(|_| rng.next_f64() as f32).collect();
    match rng.next_u64() % 8 {
        0 => Msg::Hello {
            device: rng.next_u64() as u32 % 64,
            codec_id: rng.next_u64() as u32,
            codec_version: rng.next_u64() as u16,
        },
        1 => Msg::StepStart {
            device: rng.next_u64() as u32 % 64,
            round: rng.next_u64() as u32 % 1000,
            local: rng.next_u64() % 100_000,
        },
        2 => Msg::StepGo {
            wd: rand_frame(rng, FrameKind::ModelSync),
            rng: None,
        },
        3 => Msg::Uplink {
            device: rng.next_u64() as u32 % 64,
            local: rng.next_u64() % 100_000,
            frame: rand_frame(rng, FrameKind::FeaturesUp),
            labels,
            mask: rand_mask(rng, 64),
            up_nominal: rng.next_f64() * 1e6,
            rng: None,
        },
        4 => Msg::Downlink {
            frame: rand_frame(rng, FrameKind::GradientsDown),
            loss: rng.next_f64() as f32,
            correct: (rng.next_u64() % 64) as f32,
            server_exec_s: rng.next_f64(),
            down_nominal: rng.next_f64() * 1e6,
        },
        5 => Msg::Commit {
            device: rng.next_u64() as u32 % 64,
            round: rng.next_u64() as u32 % 1000,
            local: rng.next_u64() % 100_000,
            grad: rand_frame(rng, FrameKind::ModelSync),
            report: StepReport {
                loss: rng.next_f64() as f32,
                train_acc: rng.next_f64() as f32,
                up_bits: rng.next_u64() % (1 << 30),
                down_bits: rng.next_u64() % (1 << 30),
                up_nominal: rng.next_f64() * 1e6,
                down_nominal: rng.next_f64() * 1e6,
                step_s: rng.next_f64(),
                device_exec_s: rng.next_f64(),
            },
        },
        6 => Msg::Abort { reason: format!("fault {:x}", rng.next_u64()) },
        _ => Msg::Bye { device: rng.next_u64() as u32 % 64 },
    }
}

/// Structural equality via re-encoding: the wire encoding is canonical, so
/// two messages are equal iff their byte encodings are.
fn bytes_of(m: &Msg) -> Vec<u8> {
    let mut out = Vec::new();
    m.encode(&mut out);
    out
}

#[test]
fn random_messages_roundtrip_exactly() {
    let mut rng = Rng::new(0xF4A3);
    for i in 0..500 {
        let msg = rand_msg(&mut rng);
        let bytes = bytes_of(&msg);
        let back = Msg::decode(&bytes, &limits())
            .unwrap_or_else(|e| panic!("iter {i}: {msg:?} failed to decode: {e}"));
        assert_eq!(bytes, bytes_of(&back), "iter {i}: {msg:?} changed across the wire");
    }
}

#[test]
fn truncation_at_every_boundary_is_a_typed_error() {
    let mut rng = Rng::new(0xBEEF);
    for _ in 0..60 {
        let msg = rand_msg(&mut rng);
        let bytes = bytes_of(&msg);
        for cut in 0..bytes.len() {
            // must return an error (never panic, never Ok on a prefix)
            assert!(
                Msg::decode(&bytes[..cut], &limits()).is_err(),
                "decode accepted a {cut}-byte prefix of {msg:?}"
            );
        }
    }
}

#[test]
fn random_byte_flips_never_panic() {
    let mut rng = Rng::new(0xD00D);
    for _ in 0..200 {
        let msg = rand_msg(&mut rng);
        let mut bytes = bytes_of(&msg);
        if bytes.is_empty() {
            continue;
        }
        for _ in 0..8 {
            let pos = rng.next_u64() as usize % bytes.len();
            let old = bytes[pos];
            bytes[pos] ^= (rng.next_u64() as u8).max(1);
            // any outcome is fine except a panic; a successful decode must
            // still re-encode without panicking
            if let Ok(m) = Msg::decode(&bytes, &limits()) {
                let _ = bytes_of(&m);
            }
            bytes[pos] = old;
        }
    }
}

#[test]
fn frame_headers_with_hostile_lengths_do_not_allocate() {
    // a wire frame whose header promises more payload than the limits
    // allow must be rejected by header validation alone
    let tight = WireLimits::new(64);
    for bits in [65 * 8, 1 << 20, u64::MAX - 7, u64::MAX] {
        let mut buf = Vec::new();
        Frame::new(FrameKind::Control, vec![0u8; 4], 32).write_to(&mut buf);
        // overwrite the length field (last 8 header bytes) with the lie
        let len_off = Frame::HEADER_BYTES - 8;
        buf[len_off..Frame::HEADER_BYTES].copy_from_slice(&bits.to_le_bytes());
        let mut cur = ByteCursor::new(&buf);
        assert!(
            Frame::read_from(&mut cur, &tight).is_err(),
            "{bits}-bit payload claim passed a 64-byte limit"
        );
    }
}

#[test]
fn frame_roundtrip_under_random_payload_sizes() {
    let mut rng = Rng::new(0xA11CE);
    let lim = limits();
    for _ in 0..200 {
        let f = rand_frame(
            &mut rng,
            match rng.next_u64() % 4 {
                0 => FrameKind::FeaturesUp,
                1 => FrameKind::GradientsDown,
                2 => FrameKind::ModelSync,
                _ => FrameKind::Control,
            },
        )
        .with_codec(rng.next_u64() as u32, rng.next_u64() as u16);
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        assert_eq!(buf.len(), f.wire_len());
        let mut cur = ByteCursor::new(&buf);
        let back = Frame::read_from(&mut cur, &lim).expect("well-formed frame");
        assert!(cur.is_empty());
        assert_eq!(back.kind, f.kind);
        assert_eq!(back.payload, f.payload);
        assert_eq!(back.payload_bits, f.payload_bits);
        assert_eq!(back.codec_id, f.codec_id);
        assert_eq!(back.codec_version, f.codec_version);
    }
}
