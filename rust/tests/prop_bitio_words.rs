//! Property tests for the word-level bitio kernels.
//!
//! The rewritten `BitWriter`/`BitReader` (64-bit accumulator, bulk byte
//! paths) must be **byte-identical** to the original per-bit
//! implementations, which survive as `BitWriterRef`/`BitReaderRef` oracles.
//! Mixed op sequences (write_bits / write_radix / write_f32 / write_u32 /
//! byte runs) are fuzzed against the oracle, and round trips are exercised
//! at every alignment offset 0..8 so no fast path ever depends on luck.

use splitfc::bitio::{BitReader, BitReaderRef, BitWriter, BitWriterRef};
use splitfc::testkit::{assert_prop, ParamSpace};
use splitfc::util::Rng;

#[derive(Debug, Clone)]
enum Op {
    Bits(u64, u32),
    F32(f32),
    U32(u32),
    Radix(Vec<u64>, u64),
    Bytes(Vec<u8>),
}

fn random_ops(rng: &mut Rng, n_ops: usize) -> Vec<Op> {
    (0..n_ops)
        .map(|_| match rng.gen_range(5) {
            0 => {
                let nbits = 1 + rng.gen_range(64) as u32;
                let v = rng.next_u64() & if nbits == 64 { u64::MAX } else { (1u64 << nbits) - 1 };
                Op::Bits(v, nbits)
            }
            1 => Op::F32(rng.normal_f32(0.0, 100.0)),
            2 => Op::U32(rng.next_u64() as u32),
            3 => {
                let q = 2 + rng.gen_range(999) as u64;
                let n = rng.gen_range(50);
                Op::Radix((0..n).map(|_| rng.next_u64() % q).collect(), q)
            }
            _ => {
                let n = rng.gen_range(40);
                Op::Bytes((0..n).map(|_| rng.next_u64() as u8).collect())
            }
        })
        .collect()
}

fn apply_word(w: &mut BitWriter, op: &Op) {
    match op {
        Op::Bits(v, n) => w.write_bits(*v, *n),
        Op::F32(v) => w.write_f32(*v),
        Op::U32(v) => w.write_u32(*v),
        Op::Radix(syms, q) => w.write_radix(syms, *q),
        Op::Bytes(b) => w.write_bytes(b),
    }
}

fn apply_ref(w: &mut BitWriterRef, op: &Op) {
    match op {
        Op::Bits(v, n) => w.write_bits(*v, *n),
        Op::F32(v) => w.write_f32(*v),
        Op::U32(v) => w.write_u32(*v),
        Op::Radix(syms, q) => w.write_radix(syms, *q),
        Op::Bytes(b) => w.write_bytes(b),
    }
}

#[test]
fn prop_word_writer_is_byte_identical_to_ref_oracle() {
    // params: [n_ops, seed]
    let space = ParamSpace::new(&[(1, 60), (0, 3000)]);
    assert_prop("bitio_word_vs_ref", 53, 150, &space, |p| {
        let (n_ops, seed) = (p[0], p[1] as u64);
        let mut rng = Rng::new(seed ^ 0xB17B_17B1);
        let ops = random_ops(&mut rng, n_ops);
        let mut w = BitWriter::new();
        let mut wr = BitWriterRef::new();
        for op in &ops {
            apply_word(&mut w, op);
            apply_ref(&mut wr, op);
        }
        if w.bit_len() != wr.bit_len() {
            return Err(format!("bit_len {} != ref {}", w.bit_len(), wr.bit_len()));
        }
        let bits = w.bit_len();
        let a = w.into_bytes();
        let b = wr.into_bytes();
        if a != b {
            return Err(format!("bytes differ after {} ops ({} bits)", ops.len(), bits));
        }

        // word reader and ref reader agree on the stream, op by op
        let mut r = BitReader::with_bit_len(&a, bits);
        let mut rr = BitReaderRef::with_bit_len(&a, bits);
        for (i, op) in ops.iter().enumerate() {
            match op {
                Op::Bits(v, n) => {
                    let got = r.try_read_bits(*n).map_err(|e| format!("op {i}: {e}"))?;
                    let oracle = rr.try_read_bits(*n).map_err(|e| format!("op {i}: {e}"))?;
                    if got != *v || oracle != *v {
                        return Err(format!("op {i}: {got}/{oracle} != {v}"));
                    }
                }
                Op::F32(v) => {
                    if r.read_f32().to_bits() != v.to_bits()
                        || rr.read_f32().to_bits() != v.to_bits()
                    {
                        return Err(format!("op {i}: f32 mismatch"));
                    }
                }
                Op::U32(v) => {
                    if r.read_u32() != *v || rr.read_u32() != *v {
                        return Err(format!("op {i}: u32 mismatch"));
                    }
                }
                Op::Radix(syms, q) => {
                    let got = r.try_read_radix(syms.len(), *q).map_err(|e| e.to_string())?;
                    let oracle = rr.try_read_radix(syms.len(), *q).map_err(|e| e.to_string())?;
                    if &got != syms || &oracle != syms {
                        return Err(format!("op {i}: radix mismatch q={q}"));
                    }
                }
                Op::Bytes(bytes) => {
                    let mut got = Vec::new();
                    r.try_read_bytes_into(bytes.len(), &mut got)
                        .map_err(|e| format!("op {i}: {e}"))?;
                    let mut oracle = Vec::with_capacity(bytes.len());
                    for _ in 0..bytes.len() {
                        oracle.push(
                            rr.try_read_bits(8).map_err(|e| format!("op {i}: {e}"))? as u8,
                        );
                    }
                    if &got != bytes || &oracle != bytes {
                        return Err(format!("op {i}: byte run mismatch"));
                    }
                }
            }
            if r.bits_consumed() != rr.bits_consumed() {
                return Err(format!(
                    "op {i}: consumed {} != ref {}",
                    r.bits_consumed(),
                    rr.bits_consumed()
                ));
            }
        }
        if r.bits_remaining() != 0 {
            return Err(format!("{} bits left over", r.bits_remaining()));
        }
        Ok(())
    });
}

#[test]
fn roundtrip_at_every_alignment_offset() {
    let mut rng = Rng::new(404);
    let ops = random_ops(&mut rng, 24);
    for off in 0..8u32 {
        let prefix = 0x6Du64 & ((1u64 << off.max(1)) - 1);
        let mut w = BitWriter::new();
        let mut wr = BitWriterRef::new();
        if off > 0 {
            w.write_bits(prefix, off);
            wr.write_bits(prefix, off);
        }
        for op in &ops {
            apply_word(&mut w, op);
            apply_ref(&mut wr, op);
        }
        let bits = w.bit_len();
        assert_eq!(bits, wr.bit_len(), "off={off}");
        let buf = w.into_bytes();
        assert_eq!(buf, wr.into_bytes(), "off={off}");

        let mut r = BitReader::with_bit_len(&buf, bits);
        if off > 0 {
            assert_eq!(r.read_bits(off), prefix, "off={off}");
        }
        for (i, op) in ops.iter().enumerate() {
            match op {
                Op::Bits(v, n) => assert_eq!(r.read_bits(*n), *v, "off={off} op={i}"),
                Op::F32(v) => assert_eq!(r.read_f32().to_bits(), v.to_bits(), "off={off} op={i}"),
                Op::U32(v) => assert_eq!(r.read_u32(), *v, "off={off} op={i}"),
                Op::Radix(syms, q) => {
                    assert_eq!(&r.read_radix(syms.len(), *q), syms, "off={off} op={i}")
                }
                Op::Bytes(bytes) => {
                    let mut got = Vec::new();
                    r.try_read_bytes_into(bytes.len(), &mut got).unwrap();
                    assert_eq!(&got, bytes, "off={off} op={i}");
                }
            }
        }
        assert_eq!(r.bits_remaining(), 0, "off={off}");
    }
}

#[test]
fn failed_reads_consume_nothing_word_reader() {
    let mut w = BitWriter::new();
    w.write_bits(0b1011, 4);
    w.write_f32(2.5);
    let bits = w.bit_len();
    let buf = w.into_bytes();
    let mut r = BitReader::with_bit_len(&buf, bits);
    assert_eq!(r.read_bits(4), 0b1011);
    // 32 bits remain: a 33-bit ask fails without consuming
    assert!(r.try_read_bits(33).is_err());
    let mut sink = Vec::new();
    assert!(r.try_read_bytes_into(5, &mut sink).is_err());
    assert_eq!(r.read_f32(), 2.5, "stream position must survive failed reads");
    assert_eq!(r.bits_remaining(), 0);
}
