//! Integration: the full Algorithm-1 coordinator over the `tiny` preset on
//! the default (native) execution backend — no artifacts or external deps.
//! The same suite drives the PJRT backend when built with `--features pjrt`
//! and `cfg.backend = BackendKind::Pjrt`.

use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;

fn base_cfg() -> TrainConfig {
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 2;
    cfg.rounds = 4;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg
}

#[test]
fn vanilla_training_reduces_loss_and_learns() {
    let mut cfg = base_cfg();
    cfg.rounds = 6;
    let mut tr = Trainer::new(cfg).unwrap();
    let first = tr.step(1, 0).unwrap();
    let mut last = first.clone();
    for t in 1..=6 {
        for k in 0..2 {
            last = tr.step(t, k).unwrap();
        }
    }
    assert!(last.loss < first.loss, "loss {} -> {}", first.loss, last.loss);
    let acc = tr.evaluate().unwrap();
    assert!(acc > 0.3, "accuracy {acc} should beat 4-class chance");
}

#[test]
fn splitfc_budget_respected_per_step() {
    let mut cfg = base_cfg();
    cfg.scheme = parse_scheme("splitfc", 4.0).unwrap();
    cfg.up_bits_per_entry = 1.0;
    cfg.down_bits_per_entry = 2.0;
    let mut tr = Trainer::new(cfg).unwrap();
    let p = tr.preset().clone();
    for t in 1..=3 {
        let rec = tr.step(t, 0).unwrap();
        let budget_up = 1.0 * (p.batch * p.dbar) as f64;
        let budget_down = 2.0 * (p.batch * p.dbar) as f64;
        assert!(
            (rec.up_bits as f64) <= budget_up * 1.15 + 512.0,
            "t={t} up {} vs {budget_up}",
            rec.up_bits
        );
        assert!(
            (rec.down_bits as f64) <= budget_down * 1.15 + 512.0,
            "t={t} down {} vs {budget_down}",
            rec.down_bits
        );
        assert!(rec.loss.is_finite());
    }
}

#[test]
fn run_is_deterministic_given_seed() {
    let acc = |seed: u64| {
        let mut cfg = base_cfg();
        cfg.seed = seed;
        cfg.scheme = parse_scheme("splitfc", 4.0).unwrap();
        cfg.up_bits_per_entry = 2.0;
        let mut tr = Trainer::new(cfg).unwrap();
        let s = tr.run().unwrap();
        (s.final_acc, s.total_up_bits)
    };
    let a = acc(7);
    let b = acc(7);
    assert_eq!(a, b, "same seed must reproduce exactly");
    let c = acc(8);
    assert!(a != c || a.1 != c.1, "different seeds should differ somewhere");
}

#[test]
fn all_table_schemes_run_one_step() {
    for name in [
        "vanilla",
        "splitfc",
        "splitfc-ad",
        "splitfc-rand",
        "splitfc-det",
        "splitfc-quant-only",
        "splitfc-no-mean",
        "splitfc-ad+pq",
        "splitfc-ad+eq",
        "splitfc-ad+nq",
        "tops",
        "randtops",
        "tops+eq",
        "fedlite",
    ] {
        let mut cfg = base_cfg();
        cfg.rounds = 1;
        cfg.scheme = parse_scheme(name, 4.0).unwrap();
        cfg.up_bits_per_entry = if name == "vanilla" { 32.0 } else { 1.0 };
        cfg.down_bits_per_entry = 32.0;
        let mut tr = Trainer::new(cfg).unwrap();
        let rec = tr.step(1, 0).unwrap_or_else(|e| panic!("{name}: {e:#}"));
        assert!(rec.loss.is_finite(), "{name}");
        assert!(rec.up_bits > 0, "{name}");
    }
}

#[test]
fn downlink_compression_couples_to_dropout() {
    // with dropout at R=4, the downlink (lossless) should carry ~1/4 of the
    // full gradient bits
    let mut cfg = base_cfg();
    cfg.scheme = parse_scheme("splitfc-ad", 4.0).unwrap();
    cfg.up_bits_per_entry = 32.0;
    cfg.down_bits_per_entry = 32.0;
    let mut tr = Trainer::new(cfg).unwrap();
    let p = tr.preset().clone();
    let full = 32 * p.batch * p.dbar;
    let mut total = 0u64;
    let n = 6;
    for t in 1..=n {
        total += tr.step(t, 0).unwrap().down_bits;
    }
    let mean = total as f64 / n as f64;
    assert!(
        mean < full as f64 * 0.55,
        "downlink {mean} should be ~25% of {full}"
    );
}

#[test]
fn eval_history_and_metrics_written() {
    let path = std::env::temp_dir().join("splitfc_it_metrics.jsonl");
    let mut cfg = base_cfg();
    cfg.eval_every = 2;
    cfg.metrics_path = path.to_str().unwrap().to_string();
    let mut tr = Trainer::new(cfg).unwrap();
    let s = tr.run().unwrap();
    assert!(!s.eval_history.is_empty());
    assert_eq!(s.steps, 8);
    let text = std::fs::read_to_string(&path).unwrap();
    // 8 step records + 1 summary
    assert_eq!(text.lines().count(), 9);
    std::fs::remove_file(path).ok();
}

#[test]
fn probe_features_exposes_dispersion() {
    let mut tr = Trainer::new(base_cfg()).unwrap();
    let (f, sigma) = tr.probe_features(0).unwrap();
    assert_eq!(f.rows, tr.preset().batch);
    assert_eq!(sigma.len(), tr.preset().dbar);
    // paper's Fig.-1 premise: dispersion varies across columns
    let mx = sigma.iter().cloned().fold(0.0f32, f32::max);
    let mn = sigma.iter().cloned().fold(f32::INFINITY, f32::min);
    assert!(mx > mn, "sigma must vary across columns");
}
