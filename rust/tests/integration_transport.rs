//! Integration: the message transport (inproc channels vs real TCP).
//!
//! The load-bearing contract: a staleness-0 run speaks the exact same
//! protocol messages over both backends, so its metrics are **byte
//! identical** — per-step losses, bits, tags, eval history; only the
//! wall-clock fields differ. On top of that, a TCP device whose socket is
//! cut mid-training (request delivered, reply lost — the nastiest cut)
//! must reconnect, replay its in-flight message through the PS couriers,
//! and still land on the identical trajectory.

use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::{run_remote_device, Trainer};
use splitfc::transport::{Connection, Msg, TcpConn, TransportKind, WireLimits};
use splitfc::util::Json;

fn base_cfg(metrics: &str) -> TrainConfig {
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 4;
    cfg.rounds = 5;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.eval_every = 2;
    cfg.scheme = parse_scheme("splitfc", 4.0).unwrap();
    cfg.up_bits_per_entry = 2.0;
    cfg.down_bits_per_entry = 4.0;
    cfg.seed = 11;
    cfg.metrics_path = metrics.to_string();
    cfg
}

fn metrics_file(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("splitfc_tx_{tag}_{}.jsonl", std::process::id()))
}

/// The deterministic fields of every step record in a metrics stream
/// (drops the wall-clock `step_s`/`exec_s` and the summary line).
fn step_fields(path: &std::path::Path) -> Vec<Vec<(String, String)>> {
    let text = std::fs::read_to_string(path).expect("metrics file");
    let mut out = Vec::new();
    for line in text.lines() {
        let j = Json::parse(line).expect("valid JSONL");
        if j.get("t").is_none() {
            continue; // the trailing summary record
        }
        let mut fields = Vec::new();
        for key in [
            "t", "k", "g", "loss", "train_acc", "up_bits", "down_bits", "up_nominal",
            "down_nominal",
        ] {
            let v = j.req(key).as_f64().unwrap_or_else(|| panic!("field {key} in {line}"));
            fields.push((key.to_string(), format!("{v:?}")));
        }
        out.push(fields);
    }
    out
}

fn run_with(cfg: TrainConfig) -> splitfc::coordinator::TrainSummary {
    let mut tr = Trainer::new(cfg).unwrap();
    tr.run().unwrap()
}

#[test]
fn tcp_staleness0_is_byte_identical_to_inproc() {
    let ref_path = metrics_file("inproc");
    let inproc = run_with(base_cfg(ref_path.to_str().unwrap()));

    let tcp_path = metrics_file("tcp");
    let mut cfg = base_cfg(tcp_path.to_str().unwrap());
    cfg.transport = TransportKind::Tcp;
    let tcp = run_with(cfg);

    assert_eq!(inproc.final_acc, tcp.final_acc, "final accuracy");
    assert_eq!(
        inproc.mean_loss_last_round.to_bits(),
        tcp.mean_loss_last_round.to_bits(),
        "mean last-round loss"
    );
    assert_eq!(inproc.total_up_bits, tcp.total_up_bits, "uplink bits");
    assert_eq!(inproc.total_down_bits, tcp.total_down_bits, "downlink bits");
    assert_eq!(inproc.steps, tcp.steps, "step count");
    assert_eq!(inproc.steps, 20);
    assert_eq!(inproc.eval_history, tcp.eval_history, "eval history");
    assert_eq!(inproc.link_s.to_bits(), tcp.link_s.to_bits(), "modeled link time");

    let a = step_fields(&ref_path);
    let b = step_fields(&tcp_path);
    assert_eq!(a.len(), 20);
    assert_eq!(a, b, "per-step metrics must match record-for-record across transports");
    std::fs::remove_file(ref_path).ok();
    std::fs::remove_file(tcp_path).ok();
}

#[test]
fn tcp_reconnect_mid_training_is_trajectory_neutral() {
    // reference trajectory over inproc
    let ref_path = metrics_file("chaos_ref");
    run_with(base_cfg(ref_path.to_str().unwrap()));
    let want = step_fields(&ref_path);
    assert_eq!(want.len(), 20);

    // device 1's sends: 1 = Hello, then 3 per step (StepStart, Uplink,
    // Commit). Cutting after each kind of request exercises each replay
    // path: a re-granted StepStart (identical snapshot + RNG re-export), a
    // duplicate Uplink (answered from the courier cache without re-running
    // the server pass), a duplicate Commit (acked without re-applying).
    for (tag, cut_after) in [("start", 8u64), ("uplink", 3), ("commit", 7)] {
        let path = metrics_file(&format!("chaos_{tag}"));
        let mut cfg = base_cfg(path.to_str().unwrap());
        cfg.transport = TransportKind::Tcp;
        cfg.scenario.push_cut(1, cut_after);
        let s = run_with(cfg);
        assert_eq!(s.steps, 20, "cut after send {cut_after} lost steps");
        let got = step_fields(&path);
        assert_eq!(
            got, want,
            "trajectory diverged after a link cut following send {cut_after} ({tag})"
        );
        std::fs::remove_file(path).ok();
    }
    std::fs::remove_file(ref_path).ok();
}

#[test]
fn remote_device_process_joins_over_tcp_byte_identically() {
    // reference: all four devices in-process
    let ref_path = metrics_file("remote_ref");
    run_with(base_cfg(ref_path.to_str().unwrap()));
    let want = step_fields(&ref_path);

    // device 3 lives "remotely": a separate fleet build that dials the
    // listener, exactly what the `splitfc device` subcommand runs
    let path = metrics_file("remote");
    let mut cfg = base_cfg(path.to_str().unwrap());
    cfg.transport = TransportKind::Tcp;
    cfg.devices_remote = 1;
    let mut tr = Trainer::new(cfg).unwrap();
    let addr = tr.listen_addr().expect("tcp trainer listens").to_string();
    let mut remote_cfg = base_cfg("");
    remote_cfg.transport = TransportKind::Tcp;
    let remote =
        std::thread::spawn(move || run_remote_device(&remote_cfg, 3, std::slice::from_ref(&addr)));
    let s = tr.run().unwrap();
    let rep = remote.join().unwrap().expect("remote device run");
    assert_eq!(s.steps, 20, "PS must count the remote device's commits");
    assert!(rep.up_bits > 0, "remote device accounted no uplink traffic");
    drop(tr);

    let got = step_fields(&path);
    assert_eq!(got, want, "a remote device must not perturb the trajectory");
    std::fs::remove_file(ref_path).ok();
    std::fs::remove_file(path).ok();
}

#[test]
fn handshake_rejects_codec_and_fleet_mismatch() {
    let mut cfg = base_cfg("");
    cfg.transport = TransportKind::Tcp;
    let tr = Trainer::new(cfg).unwrap();
    let addr = tr.listen_addr().unwrap().to_string();
    let limits = WireLimits::new(1 << 20);

    // wrong codec id: the PS must refuse before any step runs
    let mut conn = TcpConn::connect(&addr, limits).unwrap();
    conn.send(Msg::Hello { device: 0, codec_id: 0xDEAD_BEEF, codec_version: 9 }).unwrap();
    match conn.recv().unwrap() {
        Msg::HelloAck { err: Some(reason), .. } => {
            assert!(reason.contains("codec mismatch"), "{reason}");
        }
        other => panic!("expected rejection, got {other:?}"),
    }

    // device index beyond the fleet: refused with the fleet size
    let mut conn = TcpConn::connect(&addr, limits).unwrap();
    conn.send(Msg::Hello { device: 99, codec_id: 0, codec_version: 0 }).unwrap();
    match conn.recv().unwrap() {
        Msg::HelloAck { err: Some(reason), .. } => {
            assert!(reason.contains("out of range"), "{reason}");
        }
        other => panic!("expected rejection, got {other:?}"),
    }
}

#[test]
fn manual_steps_and_probes_work_over_tcp() {
    let mut cfg = base_cfg("");
    cfg.transport = TransportKind::Tcp;
    let mut tr = Trainer::new(cfg).unwrap();
    let rec = tr.step(1, 0).unwrap();
    assert!(rec.loss.is_finite());
    assert!(rec.up_bits > 0);
    let (f, sigma) = tr.probe_features(0).unwrap();
    assert!(f.rows > 0 && !sigma.is_empty());
}

#[test]
fn fading_sigma_disperses_links_without_touching_the_trajectory() {
    let ref_path = metrics_file("fade_ref");
    let flat = run_with(base_cfg(ref_path.to_str().unwrap()));
    let want = step_fields(&ref_path);

    let path = metrics_file("fade");
    let mut cfg = base_cfg(path.to_str().unwrap());
    cfg.fading_sigma = 0.8;
    let mut tr = Trainer::new(cfg).unwrap();
    let faded = tr.run().unwrap();

    // identical losses/bits — the capacity draw must come from its own RNG
    let got = step_fields(&path);
    assert_eq!(got, want, "fading capacities perturbed the training trajectory");
    assert_eq!(flat.total_up_bits, faded.total_up_bits);
    // but the modeled link time differs: per-device capacities dispersed
    assert_ne!(
        flat.link_s.to_bits(),
        faded.link_s.to_bits(),
        "fading-sigma run should model different transfer times"
    );
    std::fs::remove_file(ref_path).ok();
    std::fs::remove_file(path).ok();
}
