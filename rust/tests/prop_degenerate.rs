//! Property tests (via `splitfc::testkit`) for the degenerate FWQ inputs the
//! paper's Algorithm 3 must survive — constant columns (zero range),
//! single-row batches, D̂ below the candidate-set size, and the no-mean
//! ablation — plus a write/read fuzz loop over the bitio substrate with
//! checked over-read detection.

use splitfc::bitio::{BitReader, BitWriter};
use splitfc::compression::{fwq_decode, fwq_encode, FwqConfig};
use splitfc::tensor::Matrix;
use splitfc::testkit::{assert_prop, ParamSpace};
use splitfc::util::Rng;

/// Matrix where ~`pct`% of columns are constant and the rest mix scales.
fn degenerate_matrix(b: usize, d: usize, pct: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    let constants: Vec<Option<f32>> = (0..d)
        .map(|c| {
            if rng.gen_range(100) < pct {
                Some(c as f32 * 0.5 - 1.0)
            } else {
                None
            }
        })
        .collect();
    Matrix::from_fn(b, d, |_, c| match constants[c] {
        Some(v) => v,
        None => [4.0, 0.7, 0.02][c % 3] * rng.normal_f32(0.0, 1.0) + c as f32 * 0.1,
    })
}

/// Encode → decode invariants every FWQ frame must satisfy, however
/// degenerate the input: shape preserved, everything finite, M* in range,
/// and measured bits within the budget (+ the fixed-header slack that
/// dominates at tiny B·D̂).
fn check_roundtrip(a: &Matrix, cfg: &FwqConfig) -> Result<(), String> {
    let (bytes, bits, info) = fwq_encode(a, cfg);
    if !info.objective.is_finite() {
        return Err(format!("objective not finite: {}", info.objective));
    }
    if !info.nominal_bits.is_finite() {
        return Err(format!("nominal bits not finite: {}", info.nominal_bits));
    }
    if info.m_star > a.cols {
        return Err(format!("M*={} > D̂={}", info.m_star, a.cols));
    }
    let header_slack = 760.0 + a.cols as f64;
    if bits as f64 > cfg.c_ava * 1.15 + header_slack {
        return Err(format!("bits {bits} vs budget {}", cfg.c_ava));
    }
    let out = fwq_decode(&bytes, cfg);
    if (out.rows, out.cols) != (a.rows, a.cols) {
        return Err(format!("shape {:?} vs {:?}", (out.rows, out.cols), (a.rows, a.cols)));
    }
    if out.data.iter().any(|v| !v.is_finite()) {
        return Err("non-finite reconstruction".into());
    }
    Ok(())
}

#[test]
fn prop_fwq_constant_columns_roundtrip() {
    // params: [batch, dhat, pct_constant, bpe_x10, seed]
    let space = ParamSpace::new(&[(2, 24), (1, 48), (0, 100), (5, 40), (0, 500)]);
    assert_prop("fwq_constant_cols", 31, 60, &space, |p| {
        let (b, d, pct, bpe, seed) = (p[0], p[1], p[2], p[3] as f64 / 10.0, p[4] as u64);
        let a = degenerate_matrix(b, d, pct, seed);
        let cfg = FwqConfig::paper_default(b, bpe * (b * d) as f64);
        check_roundtrip(&a, &cfg)
    });
}

#[test]
fn prop_fwq_single_row_batch() {
    // B = 1: every column has zero range (min == max) — the all-degenerate
    // regime that used to produce zero-width / NaN endpoint intervals.
    let space = ParamSpace::new(&[(1, 64), (5, 40), (0, 400)]);
    assert_prop("fwq_single_row", 37, 80, &space, |p| {
        let (d, bpe, seed) = (p[0], p[1] as f64 / 10.0, p[2] as u64);
        let a = degenerate_matrix(1, d, 30, seed);
        let cfg = FwqConfig::paper_default(1, bpe * d as f64);
        check_roundtrip(&a, &cfg)
    });
}

#[test]
fn prop_fwq_dhat_below_candidate_set() {
    // D̂ < N (the paper's candidate count 10): the M-scan must still produce
    // a valid plan from a candidate set smaller than N.
    let space = ParamSpace::new(&[(2, 16), (1, 9), (5, 60), (0, 400)]);
    assert_prop("fwq_small_dhat", 41, 80, &space, |p| {
        let (b, d, bpe, seed) = (p[0], p[1], p[2] as f64 / 10.0, p[3] as u64);
        let a = degenerate_matrix(b, d, 20, seed);
        let mut cfg = FwqConfig::paper_default(b, bpe * (b * d) as f64);
        assert!(d < cfg.n_candidates);
        cfg.n_candidates = 10;
        check_roundtrip(&a, &cfg)
    });
}

#[test]
fn prop_fwq_no_mean_ablation() {
    // use_mean = false (ablation Case 3): columns beyond M* are not
    // transmitted and must reconstruct as exact zeros.
    let space = ParamSpace::new(&[(2, 16), (1, 40), (5, 40), (0, 400)]);
    assert_prop("fwq_no_mean", 43, 60, &space, |p| {
        let (b, d, bpe, seed) = (p[0], p[1], p[2] as f64 / 10.0, p[3] as u64);
        let a = degenerate_matrix(b, d, 25, seed);
        let mut cfg = FwqConfig::paper_default(b, bpe * (b * d) as f64);
        cfg.use_mean = false;
        check_roundtrip(&a, &cfg)?;
        let (bytes, _, info) = fwq_encode(&a, &cfg);
        if info.q0.is_some() {
            return Err("no-mean mode reported a mean quantizer".into());
        }
        let out = fwq_decode(&bytes, &cfg);
        let zero_cols = (0..d)
            .filter(|&c| (0..b).all(|r| out.at(r, c) == 0.0))
            .count();
        if zero_cols < d - info.m_star {
            return Err(format!(
                "untransmitted columns leaked: {zero_cols} zero cols, M*={} of D̂={d}",
                info.m_star
            ));
        }
        Ok(())
    });
}

/// One recorded write, so the fuzz loop can replay reads in order.
enum Op {
    Bits(u64, u32),
    F32(f32),
    U32(u32),
    Radix(Vec<u64>, u64),
}

#[test]
fn prop_bitio_fuzz_write_read_loop() {
    // params: [n_ops, seed]
    let space = ParamSpace::new(&[(1, 60), (0, 2000)]);
    assert_prop("bitio_fuzz", 47, 120, &space, |p| {
        let (n_ops, seed) = (p[0], p[1] as u64);
        let mut rng = Rng::new(seed ^ 0xB17F);
        let mut w = BitWriter::new();
        let mut ops = Vec::with_capacity(n_ops);
        for _ in 0..n_ops {
            match rng.gen_range(4) {
                0 => {
                    let nbits = 1 + rng.gen_range(64) as u32;
                    let v = rng.next_u64()
                        & if nbits == 64 { u64::MAX } else { (1u64 << nbits) - 1 };
                    w.write_bits(v, nbits);
                    ops.push(Op::Bits(v, nbits));
                }
                1 => {
                    let v = rng.normal_f32(0.0, 100.0);
                    w.write_f32(v);
                    ops.push(Op::F32(v));
                }
                2 => {
                    let v = rng.next_u64() as u32;
                    w.write_u32(v);
                    ops.push(Op::U32(v));
                }
                _ => {
                    let q = 2 + rng.gen_range(999) as u64;
                    let n = rng.gen_range(50);
                    let syms: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
                    w.write_radix(&syms, q);
                    ops.push(Op::Radix(syms, q));
                }
            }
        }
        let bits = w.bit_len();
        let buf = w.into_bytes();
        let mut r = BitReader::with_bit_len(&buf, bits);
        for (i, op) in ops.iter().enumerate() {
            match op {
                Op::Bits(v, nbits) => {
                    let got = r.try_read_bits(*nbits).map_err(|e| format!("op {i}: {e}"))?;
                    if got != *v {
                        return Err(format!("op {i}: bits {got} != {v}"));
                    }
                }
                Op::F32(v) => {
                    if r.read_f32().to_bits() != v.to_bits() {
                        return Err(format!("op {i}: f32 mismatch"));
                    }
                }
                Op::U32(v) => {
                    if r.read_u32() != *v {
                        return Err(format!("op {i}: u32 mismatch"));
                    }
                }
                Op::Radix(syms, q) => {
                    let got =
                        r.try_read_radix(syms.len(), *q).map_err(|e| format!("op {i}: {e}"))?;
                    if &got != syms {
                        return Err(format!("op {i}: radix mismatch"));
                    }
                }
            }
        }
        // stream fully consumed: one more bit must be a checked over-read
        if r.bits_remaining() != 0 {
            return Err(format!("{} bits left over", r.bits_remaining()));
        }
        if r.try_read_bits(1).is_ok() {
            return Err("over-read past the end succeeded".into());
        }
        Ok(())
    });
}
