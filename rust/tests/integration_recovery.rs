//! Integration: the elastic-fleet recovery surface end to end.
//!
//! The contracts under test:
//!  * a PS killed with SIGKILL at a round barrier — live TCP devices still
//!    running — restarts with `--resume` on the same port and the fleet
//!    completes the run with metrics byte-identical to an uninterrupted
//!    reference (the devices ride out the crash in their reconnect loops);
//!  * a device started with a fallback `--connect` list migrates to a
//!    *different* PS mid-run and the handover is invisible: finite loss,
//!    full step accounting, identical trajectory;
//!  * the in-process `pscrash[round=T]` / `pscrash[send=N]` scenario
//!    clauses are deterministic (same spec ⇒ identical metrics) and
//!    trajectory-neutral (identical to a calm run);
//!  * a checkpoint written under a pscrash scenario refuses a calm-config
//!    resume with a typed fingerprint error, before any state is mutated.

use std::io::Read as _;
use std::time::{Duration, Instant};

use splitfc::checkpoint::Checkpoint;
use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::{run_remote_device, Trainer};
use splitfc::scenario::ScenarioSpec;
use splitfc::transport::TransportKind;
use splitfc::util::Json;

fn tmp_path(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("splitfc_recov_{tag}_{}", std::process::id()))
}

/// Reserve a concrete loopback address: bind an ephemeral port, read it
/// back, release it. The PS must listen on a *known* port so a restarted
/// incarnation (and the devices' fallback lists) can find it again.
fn free_addr() -> String {
    let l = std::net::TcpListener::bind("127.0.0.1:0").expect("reserve port");
    let addr = l.local_addr().expect("local addr").to_string();
    drop(l);
    addr
}

/// Base fleet: tiny preset, 4 devices, 6 rounds, the error-feedback codec
/// (its residual is session state a recovery must not lose).
fn base_cfg(metrics: &str, ckpt_dir: &str, ckpt_every: usize) -> TrainConfig {
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 4;
    cfg.rounds = 6;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.eval_every = 3;
    cfg.seed = 11;
    cfg.scheme = parse_scheme("splitfc[ad,R=4,fwq,ef]", 4.0).unwrap();
    cfg.up_bits_per_entry = 2.0;
    cfg.down_bits_per_entry = 4.0;
    cfg.metrics_path = metrics.to_string();
    cfg.checkpoint_every = ckpt_every;
    cfg.checkpoint_dir = ckpt_dir.to_string();
    cfg
}

/// The deterministic fields of every step record (wall-clock excluded).
fn step_fields(path: &std::path::Path) -> Vec<String> {
    let text = std::fs::read_to_string(path).expect("metrics file");
    let mut out = Vec::new();
    for line in text.lines() {
        let j = Json::parse(line).expect("valid JSONL");
        if j.get("g").is_none() {
            continue; // the trailing summary record
        }
        let mut fields = Vec::new();
        for key in [
            "t", "k", "g", "loss", "train_acc", "up_bits", "down_bits", "up_nominal",
            "down_nominal",
        ] {
            let v = j
                .get(key)
                .and_then(|v| v.as_f64())
                .unwrap_or_else(|| panic!("field {key} in {line}"));
            fields.push(format!("{key}={v:?}"));
        }
        out.push(fields.join(" "));
    }
    out
}

/// The run-summary record the PS appends after the last step.
fn summary_json(path: &std::path::Path) -> Json {
    let text = std::fs::read_to_string(path).expect("metrics file");
    text.lines()
        .rev()
        .find_map(|l| {
            let j = Json::parse(l).ok()?;
            j.get("ps_restarts").map(|_| j.clone())
        })
        .expect("summary record with recovery telemetry")
}

fn run_with(cfg: TrainConfig) -> splitfc::coordinator::TrainSummary {
    let mut tr = Trainer::new(cfg).unwrap();
    tr.run().unwrap()
}

/// Every device severs its own link at the start of its round-6 step, then
/// sits in seeded backoff (base 3 s) — a guaranteed quiet window after the
/// round-5 checkpoint barrier in which SIGKILL lands on a quiesced PS.
const KILL_WINDOW_SPEC: &str =
    "seed=7,cut[dev=0,step=6],cut[dev=1,step=6],cut[dev=2,step=6],cut[dev=3,step=6]";

/// A real PS process (`splitfc train`), all four devices joining remotely.
fn ps_command(listen: &str, metrics: &std::path::Path, dir: &std::path::Path) -> std::process::Command {
    let mut c = std::process::Command::new(env!("CARGO_BIN_EXE_splitfc"));
    c.args([
        "train",
        "--preset",
        "tiny",
        "--devices",
        "4",
        "--rounds",
        "6",
        "--n-train",
        "256",
        "--n-test",
        "64",
        "--eval-every",
        "3",
        "--seed",
        "11",
        "--scheme",
        "splitfc[ad,R=4,fwq,ef]",
        "--up-bpe",
        "2.0",
        "--down-bpe",
        "4.0",
        "--transport",
        "tcp",
        "--devices-remote",
        "4",
        "--checkpoint-every",
        "5",
        "--scenario",
        KILL_WINDOW_SPEC,
        "--retry-base-ms",
        "3000",
        "--retry-cap-ms",
        "6000",
        "--retry-deadline-s",
        "120",
    ]);
    c.arg("--listen").arg(listen);
    c.arg("--metrics").arg(metrics);
    c.arg("--checkpoint-dir").arg(dir);
    c.stdout(std::process::Stdio::null());
    c.stderr(std::process::Stdio::piped());
    c
}

/// The matching device-side config for in-test `run_remote_device` threads.
fn device_cfg() -> TrainConfig {
    let mut cfg = base_cfg("", "", 0);
    cfg.transport = TransportKind::Tcp;
    cfg.scenario = ScenarioSpec::parse(KILL_WINDOW_SPEC).unwrap();
    cfg.retry_base_ms = 3000;
    cfg.retry_cap_ms = 6000;
    cfg.retry_deadline_s = 120.0;
    cfg
}

fn wait_until(what: &str, deadline: Duration, mut done: impl FnMut() -> bool) {
    let t0 = Instant::now();
    while !done() {
        assert!(t0.elapsed() < deadline, "timed out waiting for {what}");
        std::thread::sleep(Duration::from_millis(5));
    }
}

/// Wait for a spawned PS to exit cleanly, surfacing its stderr on failure.
fn expect_exit(tag: &str, mut child: std::process::Child) {
    let t0 = Instant::now();
    let status = loop {
        match child.try_wait().expect("try_wait") {
            Some(s) => break s,
            None if t0.elapsed() > Duration::from_secs(180) => {
                let _ = child.kill();
                panic!("{tag}: PS did not finish within 180s");
            }
            None => std::thread::sleep(Duration::from_millis(20)),
        }
    };
    let mut err = String::new();
    if let Some(mut pipe) = child.stderr.take() {
        let _ = pipe.read_to_string(&mut err);
    }
    assert!(status.success(), "{tag}: PS failed ({status}): {err}");
}

/// (a) SIGKILL at the round-5 barrier, restart with `--resume` on the SAME
/// port: the four live device threads reconnect into the resumed run and
/// the metrics stream is byte-identical to an uninterrupted reference.
#[test]
fn ps_kill9_at_a_barrier_resumes_byte_identically_under_live_devices() {
    let ref_path = tmp_path("kill_ref.jsonl");
    let metrics = tmp_path("kill.jsonl");
    let dir = tmp_path("kill_dir");
    run_with(base_cfg(ref_path.to_str().unwrap(), "", 0));
    let want = step_fields(&ref_path);
    assert_eq!(want.len(), 24);

    let listen = free_addr();
    let mut ps1 = ps_command(&listen, &metrics, &dir).spawn().expect("spawn PS1");
    let devices: Vec<_> = (0..4)
        .map(|k| {
            let cfg = device_cfg();
            let addrs = vec![listen.clone()];
            std::thread::spawn(move || run_remote_device(&cfg, k, &addrs))
        })
        .collect();

    // the round-5 snapshot appearing == the barrier has quiesced; every
    // device has already cut its own link for round 6 and sits in ≥1.5 s
    // of backoff, so the SIGKILL below hits an idle PS
    let snap = dir.join(Checkpoint::file_name(5));
    wait_until("the round-5 checkpoint", Duration::from_secs(120), || snap.exists());
    ps1.kill().expect("SIGKILL PS1");
    let _ = ps1.wait();

    // restart on the SAME port (SO_REUSEADDR) with --resume; the devices'
    // retry loops re-Hello into the resumed run
    let mut cmd = ps_command(&listen, &metrics, &dir);
    cmd.arg("--resume").arg(&snap);
    let ps2 = cmd.spawn().expect("spawn PS2");
    for (k, h) in devices.into_iter().enumerate() {
        let rep = h.join().unwrap().unwrap_or_else(|e| panic!("device {k} died: {e}"));
        assert!(rep.up_bits > 0, "device {k} accounted no uplink traffic");
        assert!(rep.retry_attempts > 0, "device {k} never exercised its retry loop");
    }
    expect_exit("resume", ps2);

    assert_eq!(step_fields(&metrics), want, "recovery diverged from the uninterrupted run");
    let s = summary_json(&metrics);
    assert_eq!(s.get("ps_restarts").and_then(|v| v.as_f64()), Some(1.0));
    assert!(
        s.get("recover_s").and_then(|v| v.as_f64()).unwrap() >= 0.0,
        "time-to-recover must be reported"
    );

    std::fs::remove_file(&ref_path).ok();
    std::fs::remove_file(&metrics).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// (b) Device migration: the devices carry a fallback address list, the
/// primary PS dies at the barrier, and its successor — listening on a
/// DIFFERENT port — adopts them from its loaded snapshot. The handover
/// must be invisible to the trajectory.
#[test]
fn devices_migrate_to_a_second_ps_mid_run() {
    let ref_path = tmp_path("mig_ref.jsonl");
    let metrics = tmp_path("mig.jsonl");
    let dir = tmp_path("mig_dir");
    run_with(base_cfg(ref_path.to_str().unwrap(), "", 0));
    let want = step_fields(&ref_path);

    let (addr_a, addr_b) = (free_addr(), free_addr());
    let mut ps1 = ps_command(&addr_a, &metrics, &dir).spawn().expect("spawn PS1");
    let devices: Vec<_> = (0..4)
        .map(|k| {
            let cfg = device_cfg();
            let addrs = vec![addr_a.clone(), addr_b.clone()];
            std::thread::spawn(move || run_remote_device(&cfg, k, &addrs))
        })
        .collect();

    let snap = dir.join(Checkpoint::file_name(5));
    wait_until("the round-5 checkpoint", Duration::from_secs(120), || snap.exists());
    ps1.kill().expect("SIGKILL PS1");
    let _ = ps1.wait();

    let mut cmd = ps_command(&addr_b, &metrics, &dir);
    cmd.arg("--resume").arg(&snap);
    let ps2 = cmd.spawn().expect("spawn PS2");
    for (k, h) in devices.into_iter().enumerate() {
        let rep = h.join().unwrap().unwrap_or_else(|e| panic!("device {k} died: {e}"));
        assert!(rep.up_bits > 0 && rep.down_bits > 0, "device {k}: step accounting broken");
    }
    expect_exit("migration", ps2);

    // full step accounting, finite losses, and the exact trajectory
    let got = step_fields(&metrics);
    assert_eq!(got.len(), 24, "migrated fleet must complete all 24 steps");
    assert_eq!(got, want, "migration perturbed the trajectory");

    std::fs::remove_file(&ref_path).ok();
    std::fs::remove_file(&metrics).ok();
    std::fs::remove_dir_all(&dir).ok();
}

/// (c) Deterministic server-side chaos: `pscrash[round=T]` and
/// `pscrash[send=N]` runs are reproducible AND trajectory-neutral — the
/// in-process crash restores from the just-written snapshot through the
/// real CRC-checked decode path, so metrics match a calm run exactly.
#[test]
fn pscrash_scenario_is_deterministic_and_trajectory_neutral() {
    let calm_path = tmp_path("pscrash_calm.jsonl");
    run_with(base_cfg(calm_path.to_str().unwrap(), "", 0));
    let want = step_fields(&calm_path);

    let crash_run = |tag: &str, spec: &str| -> (Vec<String>, splitfc::coordinator::TrainSummary) {
        let metrics = tmp_path(&format!("pscrash_{tag}.jsonl"));
        let dir = tmp_path(&format!("pscrash_{tag}_dir"));
        let mut cfg = base_cfg(metrics.to_str().unwrap(), dir.to_str().unwrap(), 2);
        cfg.transport = TransportKind::Tcp;
        cfg.scenario = ScenarioSpec::parse(spec).unwrap();
        let s = run_with(cfg);
        let fields = step_fields(&metrics);
        std::fs::remove_file(&metrics).ok();
        std::fs::remove_dir_all(&dir).ok();
        (fields, s)
    };

    let (a, sa) = crash_run("r2_a", "pscrash[round=2]");
    let (b, sb) = crash_run("r2_b", "pscrash[round=2]");
    assert_eq!(a, b, "pscrash[round=2] must be deterministic across runs");
    assert_eq!(a, want, "an in-process PS crash must not perturb the trajectory");
    assert_eq!(sa.ps_restarts, 1, "exactly one restart per pscrash clause");
    assert_eq!(sb.ps_restarts, 1);
    assert!(sa.recover_s >= 0.0 && sa.recover_s.is_finite());

    // the send-ordinal form fires at the first barrier past the threshold
    let (c, sc) = crash_run("s1", "pscrash[send=1]");
    assert_eq!(c, want, "pscrash[send=N] must be trajectory-neutral too");
    assert_eq!(sc.ps_restarts, 1);

    std::fs::remove_file(&calm_path).ok();
}

/// (d) A snapshot written under a pscrash scenario names a different
/// trajectory than a calm config: resuming it without the scenario must
/// fail with the typed fingerprint mismatch, leaving the metrics file
/// untouched.
#[test]
fn pscrash_checkpoint_refuses_a_calm_resume_without_mutating_state() {
    let metrics = tmp_path("refuse.jsonl");
    let dir = tmp_path("refuse_dir");
    let mut cfg = base_cfg(metrics.to_str().unwrap(), dir.to_str().unwrap(), 2);
    cfg.transport = TransportKind::Tcp;
    cfg.scenario = ScenarioSpec::parse("pscrash[round=2]").unwrap();
    run_with(cfg);
    let snap = dir.join(Checkpoint::file_name(4));
    assert!(snap.exists());
    let metrics_before = std::fs::read(&metrics).unwrap();

    // calm config, same everything else: only the scenario (and therefore
    // the fingerprint) differs
    let mut cfg = base_cfg(metrics.to_str().unwrap(), "", 0);
    cfg.resume = snap.to_str().unwrap().to_string();
    let msg = Trainer::new(cfg).err().expect("calm resume must be refused").to_string();
    assert!(msg.contains("fingerprint"), "want a fingerprint mismatch, got: {msg}");
    assert_eq!(
        std::fs::read(&metrics).unwrap(),
        metrics_before,
        "a refused resume must not touch the metrics file"
    );

    std::fs::remove_file(&metrics).ok();
    std::fs::remove_dir_all(&dir).ok();
}
