//! Property tests for `RngState` export/restore: every stream purpose the
//! fleet derives — loader forks, the shared Algorithm-1 encode stream,
//! per-device worker forks, the retry-backoff stream — must continue bit
//! for bit from an exported state, at any cut point, through every draw
//! kind (including a cut that lands mid Box-Muller pair, where the gauss
//! cache is the state that would silently drift if dropped).

use splitfc::util::{Rng, RngState};

/// The streams `build_parts` + `arm_worker` derive, in fork order, plus the
/// device backoff stream — one entry per distinct stream purpose.
fn fleet_streams(seed: u64, devices: usize) -> Vec<(String, Rng)> {
    let mut root = Rng::new(seed.wrapping_mul(0x9E3779B9).wrapping_add(7));
    let mut out = Vec::new();
    for k in 0..devices {
        out.push((format!("loader[{k}]"), root.fork(k as u64)));
    }
    out.push(("shared-encode".to_string(), root.fork(0xFFFF)));
    for k in 0..devices {
        out.push((format!("worker[{k}]"), root.fork(0x1_0000 + k as u64)));
    }
    for k in 0..devices {
        let s = seed ^ 0xBAC0_FF5E ^ (k as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15);
        out.push((format!("backoff[{k}]"), Rng::new(s)));
    }
    out
}

/// A deterministic sequence of draw kinds (the kinds the trainer actually
/// uses), precomputed so a tape can be split at any cut point.
fn draw_kinds(seed: u64, n: usize) -> Vec<u8> {
    let mut kinds = Rng::new(seed);
    (0..n).map(|_| kinds.gen_range(5) as u8).collect()
}

/// Drive `rng` through the given draw kinds, recording every value as bits
/// for exact comparison.
fn drive(rng: &mut Rng, kinds: &[u8]) -> Vec<u64> {
    kinds
        .iter()
        .map(|kind| match kind {
            0 => rng.next_u64(),
            1 => rng.next_f64().to_bits(),
            2 => rng.gen_range(1_000_003) as u64,
            3 => rng.normal().to_bits(),
            _ => rng.bernoulli(0.3) as u64,
        })
        .collect()
}

#[test]
fn every_stream_continues_from_export_at_any_cut() {
    for seed in [0u64, 1, 7, 0xDEAD_BEEF, u64::MAX] {
        let kinds = draw_kinds(seed ^ 0x51, 96);
        for (name, rng) in fleet_streams(seed, 3) {
            // reference: one uninterrupted tape of 96 mixed draws
            let tape = drive(&mut rng.clone(), &kinds);

            // cut the stream anywhere, export, restore, continue: the
            // spliced tape must equal the uninterrupted one bit for bit
            for cut in [0usize, 1, 2, 31, 64, 95] {
                let mut a = rng.clone();
                let mut spliced = drive(&mut a, &kinds[..cut]);
                let st = a.export_state();

                let mut b = Rng::from_state(&st);
                spliced.extend(drive(&mut b, &kinds[cut..]));
                assert_eq!(
                    spliced, tape,
                    "stream {name}: restored continuation diverged at cut {cut} (seed {seed:#x})"
                );

                // restore_state into a polluted generator is equivalent
                let mut d = Rng::new(seed ^ 0x77);
                drive(&mut d, &kinds[..13]);
                d.restore_state(&st);
                assert_eq!(
                    drive(&mut d, &kinds[cut..]),
                    tape[cut..],
                    "stream {name}: restore_state != from_state at cut {cut}"
                );
            }
        }
    }
}

#[test]
fn export_mid_gaussian_pair_preserves_the_cache() {
    let kinds = draw_kinds(9, 40);
    for seed in [3u64, 1234, 0xABCD_EF01] {
        for (name, mut rng) in fleet_streams(seed, 2) {
            // one normal() draw fills the Box-Muller cache with its twin
            let _ = rng.normal();
            let st = rng.export_state();
            assert!(
                st.gauss.is_some(),
                "stream {name}: gauss cache empty after an odd normal draw"
            );
            let mut restored = Rng::from_state(&st);
            // the very next normal must be the cached twin, then the
            // streams stay locked through more mixed draws
            assert_eq!(rng.normal().to_bits(), restored.normal().to_bits(), "{name}");
            assert_eq!(drive(&mut rng, &kinds), drive(&mut restored, &kinds), "{name}");
        }
    }
}

#[test]
fn forks_after_restore_match_forks_after_original() {
    // forking consumes a draw from the parent, so a restored parent must
    // produce bit-identical children in the same order
    for seed in [11u64, 0x5EED] {
        let mut parent = Rng::new(seed);
        parent.normal(); // leave a gauss cache in the exported state
        let st = parent.export_state();
        let mut twin = Rng::from_state(&st);
        for stream in [0u64, 1, 0xFFFF, 0x1_0000] {
            let mut a = parent.fork(stream);
            let mut b = twin.fork(stream);
            for _ in 0..16 {
                assert_eq!(a.next_u64(), b.next_u64(), "fork {stream:#x} diverged");
            }
        }
    }
}

#[test]
fn state_roundtrips_through_plain_fields() {
    // RngState is plain data: rebuilding one field-by-field (as the wire
    // and checkpoint codecs do) loses nothing
    let kinds = draw_kinds(2, 64);
    let mut rng = Rng::new(42);
    rng.normal();
    drive(&mut rng, &draw_kinds(1, 17));
    let st = rng.export_state();
    let rebuilt = RngState { s: st.s, gauss: st.gauss };
    assert_eq!(st, rebuilt);
    let mut a = Rng::from_state(&st);
    let mut b = Rng::from_state(&rebuilt);
    assert_eq!(drive(&mut a, &kinds), drive(&mut b, &kinds));
}
