//! Property-based tests (via `splitfc::testkit`) of the coordinator/codec
//! invariants: routing (kept-column bookkeeping), batching (wire-format
//! round-trips at arbitrary shapes), and state (budget accounting).

use splitfc::bitio::{BitReader, BitWriter};
use splitfc::compression::dropout::{adaptive_probs, plan, DropKind};
use splitfc::compression::pipeline::decode_uplink_splitfc;
use splitfc::compression::waterfill::{solve, LevelSpec};
use splitfc::compression::{
    encode_downlink, encode_uplink, CodecParams, FwqConfig, GradMask, Scheme,
};
use splitfc::tensor::{column_stats, normalized_sigma, Matrix};
use splitfc::testkit::{assert_prop, ParamSpace};
use splitfc::util::Rng;

fn random_matrix(b: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(b, d, |_, c| {
        let scale = [3.0, 1.0, 0.1, 0.0][c % 4];
        scale * rng.normal_f32(0.0, 1.0) + (c % 9) as f32 * 0.2
    })
}

#[test]
fn prop_fwq_roundtrip_any_shape_within_budget() {
    // params: [batch, dhat, bpe_x10, seed]
    let space = ParamSpace::new(&[(2, 48), (1, 96), (5, 60), (0, 1000)]);
    assert_prop("fwq_roundtrip", 42, 60, &space, |p| {
        let (b, d, bpe, seed) = (p[0], p[1], p[2] as f64 / 10.0, p[3] as u64);
        let a = random_matrix(b, d, seed);
        let cfg = FwqConfig::paper_default(b, bpe * (b * d) as f64);
        let (bytes, bits, info) = splitfc::compression::fwq_encode(&a, &cfg);
        let out = splitfc::compression::fwq_decode(&bytes, &cfg);
        if (out.rows, out.cols) != (b, d) {
            return Err(format!("shape {:?}", (out.rows, out.cols)));
        }
        if out.data.iter().any(|v| !v.is_finite()) {
            return Err("non-finite".into());
        }
        // budget (generous slack for the degenerate-budget fallback at tiny
        // b*d where the fixed header dominates)
        let header_slack = 720.0 + d as f64;
        if bits as f64 > cfg.c_ava * 1.1 + header_slack {
            return Err(format!("bits {bits} > budget {}", cfg.c_ava));
        }
        if info.m_star > d {
            return Err("M* > D̂".into());
        }
        Ok(())
    });
}

#[test]
fn prop_dropout_probabilities_axioms() {
    // params: [dbar, r_x10, seed]
    let space = ParamSpace::new(&[(1, 400), (10, 640), (0, 500)]);
    assert_prop("dropout_axioms", 7, 120, &space, |p| {
        let (d, r, seed) = (p[0], (p[1] as f64 / 10.0).max(1.0), p[2] as u64);
        let mut rng = Rng::new(seed);
        let sigma: Vec<f32> = (0..d).map(|_| rng.next_f32() * 0.5).collect();
        let probs = adaptive_probs(&sigma, r);
        if probs.iter().any(|&x| !(0.0..=1.0).contains(&x)) {
            return Err(format!("p out of [0,1]: {probs:?}"));
        }
        let e_keep: f64 = probs.iter().map(|&x| 1.0 - x).sum();
        let target = d as f64 / r;
        if (e_keep - target).abs() > target * 0.1 + 1.0 {
            return Err(format!("E[D̂]={e_keep} vs D={target}"));
        }
        Ok(())
    });
}

#[test]
fn prop_dropout_plan_routing_invariants() {
    let space = ParamSpace::new(&[(1, 300), (10, 320), (0, 300)]);
    assert_prop("dropout_routing", 11, 120, &space, |p| {
        let (d, r, seed) = (p[0], (p[1] as f64 / 10.0).max(1.0), p[2] as u64);
        let mut rng = Rng::new(seed);
        let sigma: Vec<f32> = (0..d).map(|_| rng.next_f32()).collect();
        for kind in [DropKind::Adaptive, DropKind::Random, DropKind::Deterministic] {
            let pl = plan(kind, &sigma, r, &mut rng);
            // kept indices sorted, unique, within range, consistent with δ
            let mut sorted = pl.kept.clone();
            sorted.sort_unstable();
            sorted.dedup();
            if sorted != pl.kept {
                return Err(format!("{kind:?}: kept not sorted/unique"));
            }
            if pl.kept.iter().any(|&i| i >= d) {
                return Err("kept out of range".into());
            }
            if pl.kept.len() != pl.delta.iter().filter(|&&x| x).count() {
                return Err("kept/delta mismatch".into());
            }
            if pl.scale.len() != pl.kept.len() {
                return Err("scale/kept mismatch".into());
            }
            for (j, &c) in pl.kept.iter().enumerate() {
                let expect = 1.0 / (1.0 - pl.p[c]);
                if (pl.scale[j] as f64 - expect).abs() > 1e-4 * expect {
                    return Err(format!("scale[{j}] wrong"));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_uplink_downlink_mask_coupling() {
    // eq. (8): downlink Ĝ is zero exactly on dropped columns
    let space = ParamSpace::new(&[(2, 24), (4, 64), (0, 400)]);
    assert_prop("mask_coupling", 13, 40, &space, |p| {
        let (b, d, seed) = (p[0], p[1], p[2] as u64);
        let f = random_matrix(b, d, seed);
        let sigma = normalized_sigma(&column_stats(&f), 1);
        let params = CodecParams::new(b, d, 1.0);
        let mut rng = Rng::new(seed ^ 0xA5);
        let scheme = Scheme::splitfc(2.0);
        let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
        let GradMask::Columns { kept, .. } = &enc.mask else {
            return Err("expected column mask".into());
        };
        let g = random_matrix(b, d, seed ^ 0xF0);
        let dn = encode_downlink(&scheme, &g, &enc.mask, &CodecParams::new(b, d, 32.0));
        for c in 0..d {
            let zero = (0..b).all(|r| dn.g_hat.at(r, c) == 0.0);
            let is_kept = kept.contains(&c);
            if is_kept && zero && (0..b).any(|r| g.at(r, c) != 0.0) {
                return Err(format!("kept col {c} zeroed"));
            }
            if !is_kept && !zero {
                return Err(format!("dropped col {c} leaked"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_wire_decode_inverts_encode() {
    let space = ParamSpace::new(&[(2, 24), (2, 64), (5, 40), (0, 200)]);
    assert_prop("wire_decode", 17, 40, &space, |p| {
        let (b, d, bpe, seed) = (p[0], p[1], p[2] as f64 / 10.0, p[3] as u64);
        let f = random_matrix(b, d, seed);
        let sigma = normalized_sigma(&column_stats(&f), 1);
        let params = CodecParams::new(b, d, bpe);
        let mut rng = Rng::new(seed);
        let scheme = Scheme::splitfc(2.0);
        let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
        let (decoded, _) = decode_uplink_splitfc(&enc.frame, &scheme, &params);
        if decoded != enc.f_hat {
            return Err("PS decode != encoder reconstruction".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bitio_radix_roundtrip() {
    let space = ParamSpace::new(&[(2, 70000), (0, 500), (0, 1000)]);
    assert_prop("radix", 19, 150, &space, |p| {
        let (q, n, seed) = (p[0] as u64, p[1], p[2] as u64);
        let mut rng = Rng::new(seed);
        let syms: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
        let mut w = BitWriter::new();
        w.write_radix(&syms, q);
        let bits = w.bit_len();
        let nominal = n as f64 * (q as f64).log2();
        if bits as f64 > nominal + 65.0 + 0.13 * n as f64 {
            return Err(format!("q={q} n={n}: {bits} bits vs nominal {nominal}"));
        }
        let bytes = w.into_bytes();
        let mut r = BitReader::new(&bytes);
        if r.read_radix(n, q) != syms {
            return Err("roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_waterfill_budget_and_monotonicity() {
    let space = ParamSpace::new(&[(1, 40), (1, 64), (1, 12), (0, 300)]);
    assert_prop("waterfill", 23, 80, &space, |p| {
        let (m, batch, bits_per, seed) = (p[0], p[1], p[2] as f64, p[3] as u64);
        let mut rng = Rng::new(seed);
        let specs: Vec<LevelSpec> = (0..m)
            .map(|_| LevelSpec::entry(rng.next_f64() * 10.0, batch))
            .collect();
        let budget = bits_per * batch as f64 * m as f64;
        match solve(&specs, budget) {
            None => {
                if budget >= batch as f64 * m as f64 {
                    return Err("feasible but returned None".into());
                }
            }
            Some(q) => {
                let bits: f64 = specs
                    .iter()
                    .zip(&q)
                    .map(|(s, &qi)| s.bit_weight * (qi as f64).log2())
                    .sum();
                if bits > budget + 1e-6 {
                    return Err(format!("over budget {bits} > {budget}"));
                }
                if q.iter().any(|&x| x < 2) {
                    return Err("level < 2".into());
                }
                // monotone in ã: among equal-weight specs, bigger range never
                // gets fewer levels
                for i in 0..m {
                    for j in 0..m {
                        if specs[i].a_tilde > specs[j].a_tilde && q[i] < q[j] {
                            return Err(format!("monotonicity: {i} vs {j}"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}
