//! Integration: the pluggable codec API.
//!
//! * **Golden parity** — every registry codec emits byte-identical frames
//!   and reconstructions (`f_hat`/`g_hat`) to the pre-refactor `Scheme`
//!   enum path for a fixed seed, both link directions, and its true wire
//!   decode inverts its encode.
//! * **Self-describing frames** — decoders reject frames stamped by a
//!   different codec or wire version.
//! * **Sessionful error feedback** — a `splitfc[...,ef]` codec carries its
//!   residual across rounds and the accumulated reconstruction error
//!   shrinks, beating the memoryless codec.
//! * **Out-of-core codec** — a sign-SGD codec defined *in this test file*
//!   registers through `register_codec` and trains end-to-end via
//!   `--scheme sign`, without touching `compression/pipeline.rs`.

use splitfc::bitio::{BitReader, BitWriter};
use splitfc::compression::{
    encode_downlink, encode_uplink, register_codec, registered_names, Codec, CodecParams,
    CodecRequirements, CodecSpec, DecodedUplink, DropKind, EncodedUplink, FwqMode, GradMask,
    ScalarKind, Scheme, SigmaStats, SplitFcCodec,
};
use splitfc::config::parse_scheme;
use splitfc::tensor::{column_stats, normalized_sigma, Matrix};
use splitfc::testkit::hetero_matrix;
use splitfc::transport::wire::{Frame, FrameKind};
use splitfc::util::error::Result;
use splitfc::util::Rng;

const B: usize = 16;
const D: usize = 64;

fn fixtures() -> (Matrix, SigmaStats, Matrix) {
    let f = hetero_matrix(B, D, 7);
    let stats = SigmaStats::new(normalized_sigma(&column_stats(&f), 4));
    let g = Matrix::from_fn(B, D, |r, c| ((r * 13 + c * 3) % 11) as f32 * 0.03 - 0.15);
    (f, stats, g)
}

/// The 16 registry names and the legacy enum value each must match
/// bit-for-bit (the pre-refactor `parse_scheme` table at R = 8).
fn legacy_rows() -> Vec<(&'static str, Scheme)> {
    let ad = Some(DropKind::Adaptive);
    vec![
        ("vanilla", Scheme::Vanilla),
        ("splitfc", Scheme::splitfc(8.0)),
        ("splitfc-ad", Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::NoQuant }),
        (
            "splitfc-rand",
            Scheme::SplitFc { drop: Some(DropKind::Random), r: 8.0, quant: FwqMode::NoQuant },
        ),
        (
            "splitfc-det",
            Scheme::SplitFc {
                drop: Some(DropKind::Deterministic),
                r: 8.0,
                quant: FwqMode::NoQuant,
            },
        ),
        (
            "splitfc-quant-only",
            Scheme::SplitFc { drop: None, r: 1.0, quant: FwqMode::Optimal { use_mean: true } },
        ),
        (
            "splitfc-no-mean",
            Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::Optimal { use_mean: false } },
        ),
        ("splitfc-ad+pq", Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::Scalar(ScalarKind::Pq) }),
        ("splitfc-ad+eq", Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::Scalar(ScalarKind::Eq) }),
        ("splitfc-ad+nq", Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::Scalar(ScalarKind::Nq) }),
        ("tops", Scheme::TopS { theta: 0.0, quant: None }),
        ("randtops", Scheme::TopS { theta: 0.2, quant: None }),
        ("tops+pq", Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Pq) }),
        ("tops+eq", Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Eq) }),
        ("tops+nq", Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Nq) }),
        ("fedlite", Scheme::FedLite { num_subvectors: 16 }),
    ]
}

#[test]
fn every_registry_codec_matches_legacy_scheme_path_bit_exactly() {
    let (f, stats, g) = fixtures();
    for (name, scheme) in legacy_rows() {
        let bpe = if name == "vanilla" { 32.0 } else { 1.0 };
        let up = CodecParams::new(B, D, bpe);

        // legacy enum path
        let mut rng_a = Rng::new(33);
        let legacy = encode_uplink(&scheme, &f, &stats.sigma_norm, &up, &mut rng_a);

        // registry path
        let spec = parse_scheme(name, 8.0).unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut codec = spec.build().unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut rng_b = Rng::new(33);
        let enc = codec
            .encode_uplink(&f, Some(&stats), &up, &mut rng_b)
            .unwrap_or_else(|e| panic!("{name}: {e}"));

        assert_eq!(enc.frame.payload, legacy.frame.payload, "{name}: uplink payload differs");
        assert_eq!(enc.frame.payload_bits, legacy.frame.payload_bits, "{name}");
        assert_eq!(enc.f_hat, legacy.f_hat, "{name}: f_hat differs");
        assert_eq!(enc.nominal_bits, legacy.nominal_bits, "{name}");
        assert_eq!(enc.m_star, legacy.m_star, "{name}");

        // downlink parity at both a lossless and a tight budget
        for down_bpe in [32.0, 2.0] {
            let down = CodecParams::new(B, D, down_bpe);
            let legacy_dn = encode_downlink(&scheme, &g, &legacy.mask, &down);
            let dn = codec
                .encode_downlink(&g, &enc.mask, &down)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(
                dn.frame.payload, legacy_dn.frame.payload,
                "{name}@{down_bpe}: downlink payload differs"
            );
            assert_eq!(dn.g_hat, legacy_dn.g_hat, "{name}@{down_bpe}: g_hat differs");

            // true wire decode inverts encode, both directions
            let g_dec = codec
                .decode_downlink(&dn.frame, &enc.mask, &down)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(g_dec, dn.g_hat, "{name}@{down_bpe}: downlink wire decode");
        }
        let dec = codec.decode_uplink(&enc.frame, &up).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(dec.f_hat, enc.f_hat, "{name}: uplink wire decode");
        if let GradMask::Columns { kept, .. } = &enc.mask {
            assert_eq!(&dec.kept, kept, "{name}: kept set");
        }
    }
}

#[test]
fn encoding_is_deterministic_across_sessions() {
    let (f, stats, _) = fixtures();
    for name in ["splitfc", "tops", "fedlite", "randtops"] {
        let spec = parse_scheme(name, 8.0).unwrap();
        let params = CodecParams::new(B, D, 1.0);
        let encode = |spec: &CodecSpec| {
            let mut codec = spec.build().unwrap();
            let mut rng = Rng::new(12);
            codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap()
        };
        let a = encode(&spec);
        let b = encode(&spec);
        assert_eq!(a.frame.payload, b.frame.payload, "{name}: fresh sessions must agree");
    }
}

#[test]
fn frames_from_a_different_codec_or_version_are_rejected() {
    let (f, stats, _) = fixtures();
    let params = CodecParams::new(B, D, 1.0);
    let splitfc = parse_scheme("splitfc", 8.0).unwrap().build().unwrap();
    let mut splitfc_mut = parse_scheme("splitfc", 8.0).unwrap().build().unwrap();
    let mut rng = Rng::new(3);
    let enc = splitfc_mut.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();

    // same codec accepts its own frame
    assert!(splitfc.decode_uplink(&enc.frame, &params).is_ok());

    // a different codec rejects it instead of misparsing
    let vanilla = parse_scheme("vanilla", 1.0).unwrap().build().unwrap();
    let err = vanilla.decode_uplink(&enc.frame, &params).unwrap_err();
    assert!(err.to_string().contains("codec id"), "{err}");

    // a differently-parameterized session of the same family rejects too
    let splitfc_r16 = parse_scheme("splitfc", 16.0).unwrap().build().unwrap();
    assert!(splitfc_r16.decode_uplink(&enc.frame, &params).is_err());

    // and so does a future wire version of the same codec
    let future = enc.frame.clone().with_codec(enc.frame.codec_id, 99);
    let err = splitfc.decode_uplink(&future, &params).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");

    // unstamped frames (legacy/control) are also rejected by codec decoders
    let unstamped = Frame::new(FrameKind::FeaturesUp, enc.frame.payload.clone(), enc.frame.payload_bits);
    assert!(splitfc.decode_uplink(&unstamped, &params).is_err());
}

#[test]
fn error_feedback_session_shrinks_accumulated_error() {
    // splitfc[det,...]: deterministic keep-top-σ dropout is a contractive
    // compressor — classic EF territory. The sessionful codec carries the
    // residual, so the running mean of transmitted features converges to F;
    // the memoryless codec resends the same columns forever and cannot.
    let (f, stats, _) = fixtures();
    let params = CodecParams::new(B, D, 0.5);
    let spec = CodecSpec::parse_with_r("splitfc[det,R=8,fwq,ef]", 8.0).unwrap();
    let mut ef_codec = spec.build().unwrap();
    assert!(ef_codec.requirements().stateful, "ef codec must report session state");
    assert!(!parse_scheme("splitfc", 8.0).unwrap().build().unwrap().requirements().stateful);

    let mut rng = Rng::new(5);
    let mut mean_ef = Matrix::zeros(B, D);
    let mut err_at = Vec::new(); // accumulated-mean error after each round
    let rounds = 30;
    for t in 1..=rounds {
        let enc = ef_codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();
        for (m, &v) in mean_ef.data.iter_mut().zip(&enc.f_hat.data) {
            *m += v;
        }
        let mut snapshot = mean_ef.clone();
        for v in &mut snapshot.data {
            *v /= t as f32;
        }
        err_at.push(f.sq_dist(&snapshot));
    }
    assert!(
        err_at[rounds - 1] < err_at[2],
        "EF accumulated error must shrink across rounds: {err_at:?}"
    );

    // memoryless baseline (same spec minus ef) for the same budget/seed
    let mut raw_codec =
        CodecSpec::parse_with_r("splitfc[det,R=8,fwq]", 8.0).unwrap().build().unwrap();
    let mut rng = Rng::new(5);
    let mut mean_raw = Matrix::zeros(B, D);
    for _ in 0..rounds {
        let enc = raw_codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();
        for (m, &v) in mean_raw.data.iter_mut().zip(&enc.f_hat.data) {
            *m += v / rounds as f32;
        }
    }
    let err_raw = f.sq_dist(&mean_raw);
    assert!(
        err_at[rounds - 1] < err_raw,
        "EF mean error {} should beat memoryless {err_raw}",
        err_at[rounds - 1]
    );
}

#[test]
fn error_feedback_residual_stays_bounded_and_inspectable() {
    let (f, stats, _) = fixtures();
    let params = CodecParams::new(B, D, 0.5);
    let mut codec = SplitFcCodec::new(
        Some(DropKind::Deterministic),
        8.0,
        FwqMode::Optimal { use_mean: true },
    )
    .with_error_feedback(1.0);
    assert_eq!(codec.ef_residual_norm(), None, "no residual before the first round");
    let mut rng = Rng::new(9);
    let mut norms = Vec::new();
    for _ in 0..40 {
        codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();
        norms.push(codec.ef_residual_norm().expect("residual after encode"));
    }
    assert!(norms.iter().all(|n| n.is_finite()));
    let early = norms[..5].iter().cloned().fold(0.0f64, f64::max);
    let late = norms[35..].iter().cloned().fold(0.0f64, f64::max);
    assert!(
        late < 10.0 * early.max(f.sq_norm().sqrt()),
        "residual blow-up: early {early} late {late}"
    );
}

// ---------------------------------------------------------------------------
// Out-of-core demo codec: sign-SGD, defined HERE (outside compression/),
// registered through the public API, trained end-to-end via --scheme sign.
// ---------------------------------------------------------------------------

/// 1-bit sign compression: per row, one f32 magnitude (mean |x|) + D sign
/// bits. The mask-coupled downlink and frame stamping/checking come free
/// from the trait defaults — only the uplink pair is codec-specific.
struct SignCodec;

impl Codec for SignCodec {
    fn name(&self) -> String {
        "sign-sgd".to_string()
    }

    fn requirements(&self) -> CodecRequirements {
        CodecRequirements::default()
    }

    fn encode_uplink(
        &mut self,
        f: &Matrix,
        _stats: Option<&SigmaStats>,
        _params: &CodecParams,
        _rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        let (b, d) = (f.rows, f.cols);
        let mut w = BitWriter::new();
        let mut f_hat = Matrix::zeros(b, d);
        for r in 0..b {
            let mag = (0..d).map(|c| f.at(r, c).abs()).sum::<f32>() / d as f32;
            w.write_f32(mag);
            for c in 0..d {
                let neg = f.at(r, c) < 0.0;
                w.write_bits(neg as u64, 1);
                *f_hat.at_mut(r, c) = if neg { -mag } else { mag };
            }
        }
        let bits = w.bit_len();
        Ok(EncodedUplink {
            frame: self.stamp(Frame::new(FrameKind::FeaturesUp, w.into_bytes(), bits)),
            f_hat,
            mask: GradMask::All,
            nominal_bits: (b * (32 + d)) as f64,
            m_star: None,
        })
    }

    fn decode_uplink(&self, frame: &Frame, params: &CodecParams) -> Result<DecodedUplink> {
        self.check_frame(frame)?;
        let (b, d) = (params.batch, params.dbar);
        let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
        let mut f_hat = Matrix::zeros(b, d);
        for r in 0..b {
            let mag = rd.read_f32();
            for c in 0..d {
                let neg = rd.read_bits(1) == 1;
                *f_hat.at_mut(r, c) = if neg { -mag } else { mag };
            }
        }
        Ok(DecodedUplink { f_hat, kept: (0..d).collect() })
    }
}

fn register_sign_codec() {
    register_codec("sign", |_spec: &CodecSpec| -> Result<Box<dyn Codec>> {
        Ok(Box::new(SignCodec))
    });
}

#[test]
fn out_of_core_codec_registers_and_round_trips() {
    register_sign_codec();
    assert!(registered_names().iter().any(|n| n == "sign"));

    let (f, stats, g) = fixtures();
    let params = CodecParams::new(B, D, 32.0);
    let spec = parse_scheme("sign", 1.0).expect("registered out-of-core codec parses");
    let mut codec = spec.build().unwrap();
    let mut rng = Rng::new(1);
    let enc = codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();
    assert_eq!(enc.frame.payload_bits as usize, B * (32 + D));
    let dec = codec.decode_uplink(&enc.frame, &params).unwrap();
    assert_eq!(dec.f_hat, enc.f_hat, "sign wire decode");
    // signs survive exactly
    for r in 0..B {
        for c in 0..D {
            if f.at(r, c) != 0.0 && enc.f_hat.at(r, c) != 0.0 {
                assert_eq!(f.at(r, c) < 0.0, enc.f_hat.at(r, c) < 0.0);
            }
        }
    }
    let dn = codec.encode_downlink(&g, &enc.mask, &params).unwrap();
    let g_dec = codec.decode_downlink(&dn.frame, &enc.mask, &params).unwrap();
    assert_eq!(g_dec, dn.g_hat);
}

#[test]
fn out_of_core_codec_trains_end_to_end_via_scheme_flag() {
    use splitfc::config::TrainConfig;
    use splitfc::coordinator::Trainer;
    use splitfc::util::Args;

    register_sign_codec();
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 2;
    cfg.rounds = 2;
    cfg.n_train = 128;
    cfg.n_test = 32;
    let args = Args::parse(
        &"x --scheme sign --up-bpe 32 --down-bpe 32"
            .split_whitespace()
            .map(String::from)
            .collect::<Vec<_>>(),
    );
    cfg.apply_overrides(&args).expect("out-of-core scheme resolves through config");
    assert_eq!(cfg.scheme.base, "sign");
    let mut tr = Trainer::new(cfg).unwrap();
    let rec = tr.step(1, 0).unwrap();
    assert!(rec.loss.is_finite());
    // B rows × (32-bit magnitude + D̄ sign bits)
    let p = tr.preset().clone();
    assert_eq!(rec.up_bits as usize, p.batch * (32 + p.dbar));
    let s = tr.run().unwrap();
    assert!(s.final_acc.is_finite());
}
