//! Integration: the pluggable codec API.
//!
//! * **Golden parity** — every registry codec emits byte-identical frames
//!   and reconstructions (`f_hat`/`g_hat`) to the pre-refactor `Scheme`
//!   enum path for a fixed seed, both link directions, and its true wire
//!   decode inverts its encode.
//! * **Self-describing frames** — decoders reject frames stamped by a
//!   different codec or wire version.
//! * **Sessionful error feedback** — a `splitfc[...,ef]` codec carries its
//!   residual across rounds and the accumulated reconstruction error
//!   shrinks, beating the memoryless codec.
//! * **Out-of-core codec** — a sign-SGD codec defined *in this test file*
//!   registers through `register_codec` and trains end-to-end via
//!   `--scheme sign`, without touching `compression/pipeline.rs`.

use splitfc::bitio::{BitReader, BitWriter};
use splitfc::compression::{
    encode_downlink, encode_uplink, register_codec, registered_names, Codec, CodecParams,
    CodecRequirements, CodecSpec, DecodedUplink, DropKind, EncodedUplink, FwqMode, GradMask,
    ScalarKind, Scheme, SigmaStats, SplitFcCodec,
};
use splitfc::config::parse_scheme;
use splitfc::tensor::{column_stats, normalized_sigma, Matrix};
use splitfc::testkit::hetero_matrix;
use splitfc::transport::wire::{Frame, FrameKind};
use splitfc::util::error::Result;
use splitfc::util::Rng;

const B: usize = 16;
const D: usize = 64;

fn fixtures() -> (Matrix, SigmaStats, Matrix) {
    let f = hetero_matrix(B, D, 7);
    let stats = SigmaStats::new(normalized_sigma(&column_stats(&f), 4));
    let g = Matrix::from_fn(B, D, |r, c| ((r * 13 + c * 3) % 11) as f32 * 0.03 - 0.15);
    (f, stats, g)
}

/// The 16 registry names and the legacy enum value each must match
/// bit-for-bit (the pre-refactor `parse_scheme` table at R = 8).
fn legacy_rows() -> Vec<(&'static str, Scheme)> {
    let ad = Some(DropKind::Adaptive);
    vec![
        ("vanilla", Scheme::Vanilla),
        ("splitfc", Scheme::splitfc(8.0)),
        ("splitfc-ad", Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::NoQuant }),
        (
            "splitfc-rand",
            Scheme::SplitFc { drop: Some(DropKind::Random), r: 8.0, quant: FwqMode::NoQuant },
        ),
        (
            "splitfc-det",
            Scheme::SplitFc {
                drop: Some(DropKind::Deterministic),
                r: 8.0,
                quant: FwqMode::NoQuant,
            },
        ),
        (
            "splitfc-quant-only",
            Scheme::SplitFc { drop: None, r: 1.0, quant: FwqMode::Optimal { use_mean: true } },
        ),
        (
            "splitfc-no-mean",
            Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::Optimal { use_mean: false } },
        ),
        ("splitfc-ad+pq", Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::Scalar(ScalarKind::Pq) }),
        ("splitfc-ad+eq", Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::Scalar(ScalarKind::Eq) }),
        ("splitfc-ad+nq", Scheme::SplitFc { drop: ad, r: 8.0, quant: FwqMode::Scalar(ScalarKind::Nq) }),
        ("tops", Scheme::TopS { theta: 0.0, quant: None }),
        ("randtops", Scheme::TopS { theta: 0.2, quant: None }),
        ("tops+pq", Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Pq) }),
        ("tops+eq", Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Eq) }),
        ("tops+nq", Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Nq) }),
        ("fedlite", Scheme::FedLite { num_subvectors: 16 }),
    ]
}

#[test]
fn every_registry_codec_matches_legacy_scheme_path_bit_exactly() {
    let (f, stats, g) = fixtures();
    for (name, scheme) in legacy_rows() {
        let bpe = if name == "vanilla" { 32.0 } else { 1.0 };
        let up = CodecParams::new(B, D, bpe);

        // legacy enum path
        let mut rng_a = Rng::new(33);
        let legacy = encode_uplink(&scheme, &f, &stats.sigma_norm, &up, &mut rng_a);

        // registry path
        let spec = parse_scheme(name, 8.0).unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut codec = spec.build().unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut rng_b = Rng::new(33);
        let enc = codec
            .encode_uplink(&f, Some(&stats), &up, &mut rng_b)
            .unwrap_or_else(|e| panic!("{name}: {e}"));

        assert_eq!(enc.frame.payload, legacy.frame.payload, "{name}: uplink payload differs");
        assert_eq!(enc.frame.payload_bits, legacy.frame.payload_bits, "{name}");
        assert_eq!(enc.f_hat, legacy.f_hat, "{name}: f_hat differs");
        assert_eq!(enc.nominal_bits, legacy.nominal_bits, "{name}");
        assert_eq!(enc.m_star, legacy.m_star, "{name}");

        // downlink parity at both a lossless and a tight budget
        for down_bpe in [32.0, 2.0] {
            let down = CodecParams::new(B, D, down_bpe);
            let legacy_dn = encode_downlink(&scheme, &g, &legacy.mask, &down);
            let dn = codec
                .encode_downlink(&g, &enc.mask, &down)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(
                dn.frame.payload, legacy_dn.frame.payload,
                "{name}@{down_bpe}: downlink payload differs"
            );
            assert_eq!(dn.g_hat, legacy_dn.g_hat, "{name}@{down_bpe}: g_hat differs");

            // true wire decode inverts encode, both directions
            let g_dec = codec
                .decode_downlink(&dn.frame, &enc.mask, &down)
                .unwrap_or_else(|e| panic!("{name}: {e}"));
            assert_eq!(g_dec, dn.g_hat, "{name}@{down_bpe}: downlink wire decode");
        }
        let dec = codec.decode_uplink(&enc.frame, &up).unwrap_or_else(|e| panic!("{name}: {e}"));
        assert_eq!(dec.f_hat, enc.f_hat, "{name}: uplink wire decode");
        if let GradMask::Columns { kept, .. } = &enc.mask {
            assert_eq!(&dec.kept, kept, "{name}: kept set");
        }
    }
}

#[test]
fn encoding_is_deterministic_across_sessions() {
    let (f, stats, _) = fixtures();
    for name in ["splitfc", "tops", "fedlite", "randtops"] {
        let spec = parse_scheme(name, 8.0).unwrap();
        let params = CodecParams::new(B, D, 1.0);
        let encode = |spec: &CodecSpec| {
            let mut codec = spec.build().unwrap();
            let mut rng = Rng::new(12);
            codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap()
        };
        let a = encode(&spec);
        let b = encode(&spec);
        assert_eq!(a.frame.payload, b.frame.payload, "{name}: fresh sessions must agree");
    }
}

#[test]
fn frames_from_a_different_codec_or_version_are_rejected() {
    let (f, stats, _) = fixtures();
    let params = CodecParams::new(B, D, 1.0);
    let splitfc = parse_scheme("splitfc", 8.0).unwrap().build().unwrap();
    let mut splitfc_mut = parse_scheme("splitfc", 8.0).unwrap().build().unwrap();
    let mut rng = Rng::new(3);
    let enc = splitfc_mut.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();

    // same codec accepts its own frame
    assert!(splitfc.decode_uplink(&enc.frame, &params).is_ok());

    // a different codec rejects it instead of misparsing
    let vanilla = parse_scheme("vanilla", 1.0).unwrap().build().unwrap();
    let err = vanilla.decode_uplink(&enc.frame, &params).unwrap_err();
    assert!(err.to_string().contains("codec id"), "{err}");

    // a differently-parameterized session of the same family rejects too
    let splitfc_r16 = parse_scheme("splitfc", 16.0).unwrap().build().unwrap();
    assert!(splitfc_r16.decode_uplink(&enc.frame, &params).is_err());

    // and so does a future wire version of the same codec
    let future = enc.frame.clone().with_codec(enc.frame.codec_id, 99);
    let err = splitfc.decode_uplink(&future, &params).unwrap_err();
    assert!(err.to_string().contains("version"), "{err}");

    // unstamped frames (legacy/control) are also rejected by codec decoders
    let unstamped = Frame::new(FrameKind::FeaturesUp, enc.frame.payload.clone(), enc.frame.payload_bits);
    assert!(splitfc.decode_uplink(&unstamped, &params).is_err());
}

#[test]
fn error_feedback_session_shrinks_accumulated_error() {
    // splitfc[det,...]: deterministic keep-top-σ dropout is a contractive
    // compressor — classic EF territory. The sessionful codec carries the
    // residual, so the running mean of transmitted features converges to F;
    // the memoryless codec resends the same columns forever and cannot.
    let (f, stats, _) = fixtures();
    let params = CodecParams::new(B, D, 0.5);
    let spec = CodecSpec::parse_with_r("splitfc[det,R=8,fwq,ef]", 8.0).unwrap();
    let mut ef_codec = spec.build().unwrap();
    assert!(ef_codec.requirements().stateful, "ef codec must report session state");
    assert!(!parse_scheme("splitfc", 8.0).unwrap().build().unwrap().requirements().stateful);

    let mut rng = Rng::new(5);
    let mut mean_ef = Matrix::zeros(B, D);
    let mut err_at = Vec::new(); // accumulated-mean error after each round
    let rounds = 30;
    for t in 1..=rounds {
        let enc = ef_codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();
        for (m, &v) in mean_ef.data.iter_mut().zip(&enc.f_hat.data) {
            *m += v;
        }
        let mut snapshot = mean_ef.clone();
        for v in &mut snapshot.data {
            *v /= t as f32;
        }
        err_at.push(f.sq_dist(&snapshot));
    }
    assert!(
        err_at[rounds - 1] < err_at[2],
        "EF accumulated error must shrink across rounds: {err_at:?}"
    );

    // memoryless baseline (same spec minus ef) for the same budget/seed
    let mut raw_codec =
        CodecSpec::parse_with_r("splitfc[det,R=8,fwq]", 8.0).unwrap().build().unwrap();
    let mut rng = Rng::new(5);
    let mut mean_raw = Matrix::zeros(B, D);
    for _ in 0..rounds {
        let enc = raw_codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();
        for (m, &v) in mean_raw.data.iter_mut().zip(&enc.f_hat.data) {
            *m += v / rounds as f32;
        }
    }
    let err_raw = f.sq_dist(&mean_raw);
    assert!(
        err_at[rounds - 1] < err_raw,
        "EF mean error {} should beat memoryless {err_raw}",
        err_at[rounds - 1]
    );
}

#[test]
fn error_feedback_residual_stays_bounded_and_inspectable() {
    let (f, stats, _) = fixtures();
    let params = CodecParams::new(B, D, 0.5);
    let mut codec = SplitFcCodec::new(
        Some(DropKind::Deterministic),
        8.0,
        FwqMode::Optimal { use_mean: true },
    )
    .with_error_feedback(1.0);
    assert_eq!(codec.ef_residual_norm(), None, "no residual before the first round");
    let mut rng = Rng::new(9);
    let mut norms = Vec::new();
    for _ in 0..40 {
        codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();
        norms.push(codec.ef_residual_norm().expect("residual after encode"));
    }
    assert!(norms.iter().all(|n| n.is_finite()));
    let early = norms[..5].iter().cloned().fold(0.0f64, f64::max);
    let late = norms[35..].iter().cloned().fold(0.0f64, f64::max);
    assert!(
        late < 10.0 * early.max(f.sq_norm().sqrt()),
        "residual blow-up: early {early} late {late}"
    );
}

// ---------------------------------------------------------------------------
// Out-of-core demo codec: sign-SGD, defined HERE (outside compression/),
// registered through the public API, trained end-to-end via --scheme sign.
// ---------------------------------------------------------------------------

/// 1-bit sign compression: per row, one f32 magnitude (mean |x|) + D sign
/// bits. The mask-coupled downlink and frame stamping/checking come free
/// from the trait defaults — only the uplink pair is codec-specific.
struct SignCodec;

impl Codec for SignCodec {
    fn name(&self) -> String {
        "sign-sgd".to_string()
    }

    fn requirements(&self) -> CodecRequirements {
        CodecRequirements::default()
    }

    fn encode_uplink(
        &mut self,
        f: &Matrix,
        _stats: Option<&SigmaStats>,
        _params: &CodecParams,
        _rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        let (b, d) = (f.rows, f.cols);
        let mut w = BitWriter::new();
        let mut f_hat = Matrix::zeros(b, d);
        for r in 0..b {
            let mag = (0..d).map(|c| f.at(r, c).abs()).sum::<f32>() / d as f32;
            w.write_f32(mag);
            for c in 0..d {
                let neg = f.at(r, c) < 0.0;
                w.write_bits(neg as u64, 1);
                *f_hat.at_mut(r, c) = if neg { -mag } else { mag };
            }
        }
        let bits = w.bit_len();
        Ok(EncodedUplink {
            frame: self.stamp(Frame::new(FrameKind::FeaturesUp, w.into_bytes(), bits)),
            f_hat,
            mask: GradMask::All,
            nominal_bits: (b * (32 + d)) as f64,
            m_star: None,
        })
    }

    fn decode_uplink(&self, frame: &Frame, params: &CodecParams) -> Result<DecodedUplink> {
        self.check_frame(frame)?;
        let (b, d) = (params.batch, params.dbar);
        let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
        let mut f_hat = Matrix::zeros(b, d);
        for r in 0..b {
            let mag = rd.read_f32();
            for c in 0..d {
                let neg = rd.read_bits(1) == 1;
                *f_hat.at_mut(r, c) = if neg { -mag } else { mag };
            }
        }
        Ok(DecodedUplink { f_hat, kept: (0..d).collect() })
    }
}

fn register_sign_codec() {
    register_codec("sign", |_spec: &CodecSpec| -> Result<Box<dyn Codec>> {
        Ok(Box::new(SignCodec))
    });
}

#[test]
fn out_of_core_codec_registers_and_round_trips() {
    register_sign_codec();
    assert!(registered_names().iter().any(|n| n == "sign"));

    let (f, stats, g) = fixtures();
    let params = CodecParams::new(B, D, 32.0);
    let spec = parse_scheme("sign", 1.0).expect("registered out-of-core codec parses");
    let mut codec = spec.build().unwrap();
    let mut rng = Rng::new(1);
    let enc = codec.encode_uplink(&f, Some(&stats), &params, &mut rng).unwrap();
    assert_eq!(enc.frame.payload_bits as usize, B * (32 + D));
    let dec = codec.decode_uplink(&enc.frame, &params).unwrap();
    assert_eq!(dec.f_hat, enc.f_hat, "sign wire decode");
    // signs survive exactly
    for r in 0..B {
        for c in 0..D {
            if f.at(r, c) != 0.0 && enc.f_hat.at(r, c) != 0.0 {
                assert_eq!(f.at(r, c) < 0.0, enc.f_hat.at(r, c) < 0.0);
            }
        }
    }
    let dn = codec.encode_downlink(&g, &enc.mask, &params).unwrap();
    let g_dec = codec.decode_downlink(&dn.frame, &enc.mask, &params).unwrap();
    assert_eq!(g_dec, dn.g_hat);
}

#[test]
fn out_of_core_codec_trains_end_to_end_via_scheme_flag() {
    use splitfc::config::TrainConfig;
    use splitfc::coordinator::Trainer;
    use splitfc::util::Args;

    register_sign_codec();
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 2;
    cfg.rounds = 2;
    cfg.n_train = 128;
    cfg.n_test = 32;
    let args = Args::parse(
        &"x --scheme sign --up-bpe 32 --down-bpe 32"
            .split_whitespace()
            .map(String::from)
            .collect::<Vec<_>>(),
    );
    cfg.apply_overrides(&args).expect("out-of-core scheme resolves through config");
    assert_eq!(cfg.scheme.base, "sign");
    let mut tr = Trainer::new(cfg).unwrap();
    let rec = tr.step(1, 0).unwrap();
    assert!(rec.loss.is_finite());
    // B rows × (32-bit magnitude + D̄ sign bits)
    let p = tr.preset().clone();
    assert_eq!(rec.up_bits as usize, p.batch * (32 + p.dbar));
    let s = tr.run().unwrap();
    assert!(s.final_acc.is_finite());
}

// ---------------------------------------------------------------------------
// Wire hot path (PR 5): scratch-arena reuse + steady-state zero allocation.
// ---------------------------------------------------------------------------

use splitfc::compression::Reclaim;
use splitfc::util::alloc_count;
use splitfc::util::par;

/// One full protocol round through a codec session, returning every output
/// to the session afterwards (the worker's reclaim discipline). Mirrors the
/// worker exactly: σ statistics are passed only when the codec's capability
/// report asks for them, so the `stats = None` fallback path (the one
/// production hits for splitfc-rand / splitfc-quant-only) is the one gated.
fn round_trip_step(
    codec: &mut dyn Codec,
    f: &Matrix,
    g: &Matrix,
    stats: &SigmaStats,
    up: &CodecParams,
    down: &CodecParams,
    rng: &mut Rng,
) {
    let stats = if codec.requirements().needs_sigma { Some(stats) } else { None };
    let enc = codec.encode_uplink(f, stats, up, rng).expect("encode_uplink");
    let dec = codec.decode_uplink(&enc.frame, up).expect("decode_uplink");
    let dn = codec.encode_downlink(g, &enc.mask, down).expect("encode_downlink");
    let g_hat = codec.decode_downlink(&dn.frame, &enc.mask, down).expect("decode_downlink");
    codec.reclaim(Reclaim::Decoded(dec));
    codec.reclaim(Reclaim::Grad(g_hat));
    codec.reclaim(Reclaim::Downlink(dn));
    codec.reclaim(Reclaim::Uplink(enc));
}

/// Steady-state allocation gate: after a warm-up, N further protocol rounds
/// through each registry codec are measured under the counting allocator
/// (`--features alloc-count`; without the feature the loop still runs,
/// exercising the reclaim paths, and the assertion is skipped). Arena-backed
/// codecs (vanilla + every non-scalar splitfc row) must allocate **zero**
/// times per step. Run it isolated (`-- --test-threads=1`): the counter is
/// process-global.
#[test]
fn steady_state_codec_steps_are_allocation_free() {
    // the parallel pool spawns scoped threads (which allocate); pin to one
    // worker so the serial zero-allocation paths are the ones measured
    par::set_threads(1);
    let (f, stats, g) = fixtures();
    let down = CodecParams::new(B, D, 2.0);
    // codecs whose sessions are fully arena-backed — including the
    // scalar-quantizer splitfc rows (pq/eq/nq) now that their encode/decode
    // streams through `scalar_{en,de}code_into`; tops and fedlite keep
    // their allocating inner algorithms
    let zero_set = [
        "vanilla",
        "splitfc",
        "splitfc-ad",
        "splitfc-rand",
        "splitfc-det",
        "splitfc-quant-only",
        "splitfc-no-mean",
        "splitfc-ad+pq",
        "splitfc-ad+eq",
        "splitfc-ad+nq",
    ];
    for name in registered_names() {
        if name == "sign" {
            continue; // out-of-core demo codec from the tests above
        }
        let bpe = if name == "vanilla" { 32.0 } else { 1.0 };
        let up = CodecParams::new(B, D, bpe);
        let spec = parse_scheme(&name, 8.0).unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut codec = spec.build().unwrap_or_else(|e| panic!("{name}: {e}"));
        let mut rng = Rng::new(71);
        for _ in 0..4 {
            round_trip_step(codec.as_mut(), &f, &g, &stats, &up, &down, &mut rng);
        }
        let before = alloc_count::allocations();
        let steps = 6;
        for _ in 0..steps {
            round_trip_step(codec.as_mut(), &f, &g, &stats, &up, &down, &mut rng);
        }
        let after = alloc_count::allocations();
        if let (Some(a), Some(b)) = (before, after) {
            let per_step = (b - a) as f64 / steps as f64;
            if zero_set.contains(&name.as_str()) {
                assert_eq!(
                    b - a,
                    0,
                    "{name}: {per_step} allocations/step in steady state (want 0)"
                );
            } else {
                println!("{name}: {per_step} allocations/step (arena not required)");
            }
        }
    }
    par::set_threads(0);
}

/// Scratch reuse must never change bytes: the 1st and Nth encodes of the
/// same input through ONE session (fresh RNG each round) are byte-identical,
/// and both match a fresh session — for every registry codec.
#[test]
fn warm_session_frames_match_fresh_session_frames() {
    let (f, stats, g) = fixtures();
    let down = CodecParams::new(B, D, 2.0);
    for name in registered_names() {
        if name == "sign" {
            continue;
        }
        let bpe = if name == "vanilla" { 32.0 } else { 1.0 };
        let up = CodecParams::new(B, D, bpe);
        let spec = parse_scheme(&name, 8.0).unwrap_or_else(|e| panic!("{name}: {e}"));
        if spec.has("ef") {
            continue; // EF sessions intentionally evolve across rounds
        }
        let mut warm = spec.build().unwrap();
        let mut first = None;
        for round in 0..5 {
            let mut rng = Rng::new(29);
            let enc = warm.encode_uplink(&f, Some(&stats), &up, &mut rng).unwrap();
            let dn = warm.encode_downlink(&g, &enc.mask, &down).unwrap();
            match &first {
                None => first = Some((enc.frame.payload.clone(), dn.frame.payload.clone())),
                Some((u0, d0)) => {
                    assert_eq!(&enc.frame.payload, u0, "{name}: uplink drifted at round {round}");
                    assert_eq!(&dn.frame.payload, d0, "{name}: downlink drifted at round {round}");
                }
            }
            warm.reclaim(Reclaim::Downlink(dn));
            warm.reclaim(Reclaim::Uplink(enc));
        }
        let mut fresh = spec.build().unwrap();
        let mut rng = Rng::new(29);
        let enc = fresh.encode_uplink(&f, Some(&stats), &up, &mut rng).unwrap();
        assert_eq!(
            Some(&enc.frame.payload),
            first.as_ref().map(|(u, _)| u),
            "{name}: warm session diverged from a fresh one"
        );
    }
}
