//! SIMD kernel parity: the AVX2 dispatch tables must be **bit-identical**
//! to the scalar reference kernels — not merely close. Two layers:
//!
//! * whole-pipeline tests flip the global mode (`force_mode`) around full
//!   `matmul` / `column_stats` / `fwq_encode` calls and compare outputs
//!   bit for bit — these also pass trivially (Off vs Off) on hosts
//!   without AVX2;
//! * kernel-level tests pit `kernels_for(Off)` against
//!   `kernels_for(Avx2)` head to head on crafted inputs (half-integer
//!   rounding ties, NaN/±inf, denormals, ±0.0, degenerate spans, strided
//!   columns, non-multiple-of-lane tails) — these are guarded by
//!   `avx2_available()` because calling the AVX2 table on a host without
//!   AVX2 is undefined behavior.
//!
//! Tests that touch the process-global mode serialize on a mutex and
//! restore the previous mode even on panic, so the rest of the binary
//! never observes a forced mode.

use std::sync::Mutex;

use splitfc::compression::{fwq_decode, fwq_encode, FwqConfig};
use splitfc::tensor::{column_stats, Matrix};
use splitfc::testkit::hetero_matrix;
use splitfc::util::simd::{self, ColSrc, SimdMode};
use splitfc::util::Rng;

static LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with the global SIMD mode pinned to `m`, serialized against the
/// other mode-flipping tests, restoring the prior mode afterwards (also on
/// panic, so one failure doesn't cascade through the binary).
fn with_mode<T>(m: SimdMode, f: impl FnOnce() -> T) -> T {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    struct Restore(SimdMode);
    impl Drop for Restore {
        fn drop(&mut self) {
            simd::force_mode(self.0);
        }
    }
    let _restore = Restore(simd::mode());
    simd::force_mode(m);
    f()
}

/// The fastest mode this host can actually run.
fn best_mode() -> SimdMode {
    if simd::avx2_available() {
        SimdMode::Avx2
    } else {
        SimdMode::Off
    }
}

fn assert_bits_eq(a: &Matrix, b: &Matrix, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape mismatch");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: elem {i}: {x} vs {y}");
    }
}

fn assert_close(a: &Matrix, b: &Matrix, tol: f32, what: &str) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "{what}: shape mismatch");
    for (i, (x, y)) in a.data.iter().zip(&b.data).enumerate() {
        assert!(
            (x - y).abs() <= tol * (1.0 + y.abs()),
            "{what}: elem {i}: {x} vs {y}"
        );
    }
}

// ---- whole-pipeline bit-exactness across modes ----

#[test]
fn matmul_family_bit_exact_across_modes() {
    // awkward shapes: odd columns, non-multiple-of-8 widths, tail rows
    for &(m, k, p) in &[(1, 1, 1), (3, 5, 7), (4, 8, 16), (5, 9, 8), (13, 31, 17), (64, 33, 40)] {
        let seed = (m * 1000 + k * 10 + p) as u64;
        let a = hetero_matrix(m, k, seed);
        let b = hetero_matrix(k, p, seed + 1);
        let off = with_mode(SimdMode::Off, || a.matmul(&b));
        let on = with_mode(best_mode(), || a.matmul(&b));
        assert_bits_eq(&off, &on, &format!("matmul {m}x{k}·{k}x{p}"));
        assert_close(&on, &a.matmul_ref(&b), 1e-4, "matmul vs ref");

        let at = hetero_matrix(k, m, seed + 2);
        let off = with_mode(SimdMode::Off, || at.matmul_tn(&b));
        let on = with_mode(best_mode(), || at.matmul_tn(&b));
        assert_bits_eq(&off, &on, &format!("matmul_tn {k}x{m}ᵀ·{k}x{p}"));
        assert_close(&on, &at.matmul_tn_ref(&b), 1e-4, "matmul_tn vs ref");

        let bt = hetero_matrix(p, k, seed + 3);
        let off = with_mode(SimdMode::Off, || a.matmul_nt(&bt));
        let on = with_mode(best_mode(), || a.matmul_nt(&bt));
        assert_bits_eq(&off, &on, &format!("matmul_nt {m}x{k}·{p}x{k}ᵀ"));
        assert_close(&on, &a.matmul_nt_ref(&bt), 1e-4, "matmul_nt vs ref");
    }
}

#[test]
fn column_stats_bit_exact_across_modes() {
    // hetero has constant columns baked in (the 0-scale class); add a
    // crafted matrix exercising denormals, ±0.0, and single-value columns
    let mut cases = vec![hetero_matrix(16, 37, 11), hetero_matrix(8, 1030, 12), hetero_matrix(1, 9, 13)];
    cases.push(Matrix::from_fn(6, 7, |r, c| match c {
        0 => 1e-40,                                   // denormal column
        1 => if r % 2 == 0 { -0.0 } else { 0.0 },     // signed-zero mix
        2 => 3.25,                                    // constant (σ = 0)
        3 => (r as f32 - 2.5) * 1e30,                 // huge magnitudes
        4 => -(r as f32),                             // strictly decreasing
        _ => (r as f32 * 0.1) - (c as f32),
    }));
    for (i, m) in cases.iter().enumerate() {
        let off = with_mode(SimdMode::Off, || column_stats(m));
        let on = with_mode(best_mode(), || column_stats(m));
        for c in 0..m.cols {
            assert_eq!(off.min[c].to_bits(), on.min[c].to_bits(), "case {i} min[{c}]");
            assert_eq!(off.max[c].to_bits(), on.max[c].to_bits(), "case {i} max[{c}]");
            assert_eq!(off.mean[c].to_bits(), on.mean[c].to_bits(), "case {i} mean[{c}]");
            assert_eq!(off.std[c].to_bits(), on.std[c].to_bits(), "case {i} std[{c}]");
        }
    }
}

#[test]
fn fwq_stream_and_decode_bit_exact_across_modes() {
    let b = 16;
    let d = 96;
    let f = hetero_matrix(b, d, 21);
    let configs = [
        FwqConfig::paper_default(b, 4.0 * (b * d) as f64),
        FwqConfig::paper_default(b, 0.5 * (b * d) as f64),
        FwqConfig { q_fixed: Some(17), ..FwqConfig::paper_default(b, 4.0 * (b * d) as f64) },
        FwqConfig { use_mean: false, ..FwqConfig::paper_default(b, 2.0 * (b * d) as f64) },
    ];
    for (i, cfg) in configs.iter().enumerate() {
        let (bytes_off, bits_off, _) = with_mode(SimdMode::Off, || fwq_encode(&f, cfg));
        let (bytes_on, bits_on, _) = with_mode(best_mode(), || fwq_encode(&f, cfg));
        assert_eq!(bits_off, bits_on, "cfg {i}: bit length");
        assert_eq!(bytes_off, bytes_on, "cfg {i}: encoded stream");
        let dec_off = with_mode(SimdMode::Off, || fwq_decode(&bytes_off, cfg));
        let dec_on = with_mode(best_mode(), || fwq_decode(&bytes_off, cfg));
        assert_bits_eq(&dec_off, &dec_on, &format!("cfg {i}: decode"));
    }
    // degenerate: a constant matrix (every column collapses to its mean)
    let flat = Matrix::from_fn(b, 24, |_, c| (c % 3) as f32);
    let cfg = FwqConfig::paper_default(b, 2.0 * (b * 24) as f64);
    let (bytes_off, _, _) = with_mode(SimdMode::Off, || fwq_encode(&flat, &cfg));
    let (bytes_on, _, _) = with_mode(best_mode(), || fwq_encode(&flat, &cfg));
    assert_eq!(bytes_off, bytes_on, "constant matrix stream");
}

#[test]
fn configure_knob_parses_and_pins() {
    let _g = LOCK.lock().unwrap_or_else(|e| e.into_inner());
    let prev = simd::mode();
    assert!(simd::configure("bogus").is_err());
    simd::configure("off").unwrap();
    assert_eq!(simd::mode(), SimdMode::Off);
    simd::configure("auto").unwrap();
    assert_eq!(simd::mode() == SimdMode::Avx2, simd::avx2_available());
    simd::configure("avx2").unwrap(); // degrades to Off without AVX2
    assert_eq!(simd::mode() == SimdMode::Avx2, simd::avx2_available());
    simd::force_mode(prev);
}

// ---- kernel-level parity: AVX2 table vs scalar table, head to head ----
// (no global state touched — the tables are compared directly)

/// Crafted f32 inputs: rounding ties, specials, denormals, huge values.
fn crafted_values() -> Vec<f32> {
    vec![
        0.5, -0.5, 1.5, -1.5, 2.5, -2.5, 3.5, 6.5, 0.499_999_97, -0.499_999_97, 0.0, -0.0, 1e-40,
        -1e-40, 1e30, -1e30, f32::NAN, f32::INFINITY, f32::NEG_INFINITY, 3.141_592_7, -2.718_281_8,
    ]
}

#[test]
fn fwq_quant_kernel_matches_scalar_oracle() {
    if !simd::avx2_available() {
        eprintln!("skipping: host lacks AVX2");
        return;
    }
    let ks = simd::kernels_for(SimdMode::Off);
    let ka = simd::kernels_for(SimdMode::Avx2);
    let mut rng = Rng::new(31);
    // lo = 0, span = q-1 makes t == v exactly: the half-integer inputs in
    // crafted_values() then land precisely on round-half-away ties
    let param_sets: &[(f64, f64, u64)] = &[
        (0.0, 7.0, 8),
        (-1.2, 3.7, 17),
        (0.25, 1.0, 2),
        (-4.0, 8.0, 256),
        (-1.0, 2.0, 65_536),
        (0.0, 0.0, 8),  // degenerate span
        (2.0, -1.0, 8), // negative span
        (0.0, 1.0, 1),  // q < 2
    ];
    for &(lo, span, q) in param_sets {
        for &rows in &[1usize, 3, 4, 5, 7, 8, 31, 100] {
            for &(offset, stride, scale) in
                &[(0usize, 1usize, None), (3, 5, None), (2, 3, Some(0.3f32)), (0, 1, Some(2.5))]
            {
                let mut src = vec![0.0f32; offset + rows * stride + 1];
                let crafted = crafted_values();
                for (i, v) in src.iter_mut().enumerate() {
                    *v = if i % 3 == 0 {
                        crafted[i % crafted.len()]
                    } else {
                        rng.normal_f32(0.0, 2.0)
                    };
                }
                let col = ColSrc { src: &src, offset, stride, scale };
                let mut out_s = vec![u64::MAX; rows];
                let mut out_a = vec![u64::MAX; rows];
                (ks.fwq_quant_col)(col, rows, lo, span, q, &mut out_s);
                (ka.fwq_quant_col)(col, rows, lo, span, q, &mut out_a);
                assert_eq!(
                    out_s, out_a,
                    "quant mismatch: lo={lo} span={span} q={q} rows={rows} offset={offset} stride={stride} scale={scale:?}"
                );
            }
        }
    }
}

#[test]
fn fwq_dequant_kernel_matches_scalar_oracle() {
    if !simd::avx2_available() {
        eprintln!("skipping: host lacks AVX2");
        return;
    }
    let ks = simd::kernels_for(SimdMode::Off);
    let ka = simd::kernels_for(SimdMode::Avx2);
    let param_sets: &[(f64, f64, u64)] =
        &[(0.0, 7.0, 8), (-1.2, 3.7, 17), (0.25, 1.0, 2), (-4.0, 8.0, 256), (-1.0, 2.0, 65_536), (0.0, 0.0, 8), (5.0, 1.0, 1)];
    for &(lo, span, q) in param_sets {
        for &n in &[1usize, 2, 4, 5, 7, 8, 9, 33] {
            let syms: Vec<u64> = (0..n as u64).map(|i| (i * 7919) % q.max(1)).collect();
            for &(offset, stride) in &[(0usize, 1usize), (2, 3)] {
                let mut dst_s = vec![f32::NAN; offset + n * stride + 1];
                let mut dst_a = dst_s.clone();
                (ks.fwq_dequant_col)(&syms, lo, span, q, &mut dst_s, offset, stride);
                (ka.fwq_dequant_col)(&syms, lo, span, q, &mut dst_a, offset, stride);
                for (i, (x, y)) in dst_s.iter().zip(&dst_a).enumerate() {
                    assert_eq!(
                        x.to_bits(),
                        y.to_bits(),
                        "dequant mismatch at {i}: lo={lo} span={span} q={q} n={n} offset={offset} stride={stride}"
                    );
                }
            }
        }
    }
}

#[test]
fn matmul_micro_kernels_match_scalar() {
    if !simd::avx2_available() {
        eprintln!("skipping: host lacks AVX2");
        return;
    }
    let ks = simd::kernels_for(SimdMode::Off);
    let ka = simd::kernels_for(SimdMode::Avx2);
    let mut rng = Rng::new(47);
    let mut gen = |n: usize| -> Vec<f32> { (0..n).map(|_| rng.normal_f32(0.0, 1.0)).collect() };
    for p in 0..=33 {
        let bk = gen(p);
        let (b1, b2, b3) = (gen(p), gen(p), gen(p));
        let x = [0.7f32, -1.3, 0.0, 2.5];
        let base = gen(p);

        let mut rows_s: Vec<Vec<f32>> = (0..4).map(|_| base.clone()).collect();
        let mut rows_a = rows_s.clone();
        {
            let (s0, rest) = rows_s.split_at_mut(1);
            let (s1, rest) = rest.split_at_mut(1);
            let (s2, s3) = rest.split_at_mut(1);
            (ks.mm4)(&mut s0[0], &mut s1[0], &mut s2[0], &mut s3[0], x, &bk);
        }
        {
            let (s0, rest) = rows_a.split_at_mut(1);
            let (s1, rest) = rest.split_at_mut(1);
            let (s2, s3) = rest.split_at_mut(1);
            (ka.mm4)(&mut s0[0], &mut s1[0], &mut s2[0], &mut s3[0], x, &bk);
        }
        for r in 0..4 {
            for j in 0..p {
                assert_eq!(rows_s[r][j].to_bits(), rows_a[r][j].to_bits(), "mm4 p={p} r={r} j={j}");
            }
        }

        let mut o_s = base.clone();
        let mut o_a = base.clone();
        (ks.axpy)(&mut o_s, -0.9, &bk);
        (ka.axpy)(&mut o_a, -0.9, &bk);
        for j in 0..p {
            assert_eq!(o_s[j].to_bits(), o_a[j].to_bits(), "axpy p={p} j={j}");
        }

        let mut o_s = base.clone();
        let mut o_a = base;
        (ks.tn4)(&mut o_s, x, &bk, &b1, &b2, &b3);
        (ka.tn4)(&mut o_a, x, &bk, &b1, &b2, &b3);
        for j in 0..p {
            assert_eq!(o_s[j].to_bits(), o_a[j].to_bits(), "tn4 p={p} j={j}");
        }
    }
}

#[test]
fn stats_row_kernel_matches_scalar() {
    if !simd::avx2_available() {
        eprintln!("skipping: host lacks AVX2");
        return;
    }
    let ks = simd::kernels_for(SimdMode::Off);
    let ka = simd::kernels_for(SimdMode::Avx2);
    let mut rng = Rng::new(59);
    let crafted = crafted_values();
    for d in 0..=33 {
        let row: Vec<f32> = (0..d)
            .map(|i| if i % 4 == 0 { crafted[i % crafted.len()] } else { rng.normal_f32(0.0, 3.0) })
            .collect();
        let mn0 = vec![f32::INFINITY; d];
        let mx0 = vec![f32::NEG_INFINITY; d];
        let sum0: Vec<f64> = (0..d).map(|i| i as f64 * 0.25).collect();
        let sq0: Vec<f64> = (0..d).map(|i| i as f64 * 0.5).collect();

        let (mut mn_s, mut mx_s, mut sum_s, mut sq_s) = (mn0.clone(), mx0.clone(), sum0.clone(), sq0.clone());
        let (mut mn_a, mut mx_a, mut sum_a, mut sq_a) = (mn0, mx0, sum0, sq0);
        (ks.stats_row)(&row, &mut mn_s, &mut mx_s, &mut sum_s, &mut sq_s);
        (ka.stats_row)(&row, &mut mn_a, &mut mx_a, &mut sum_a, &mut sq_a);
        for c in 0..d {
            assert_eq!(mn_s[c].to_bits(), mn_a[c].to_bits(), "stats min d={d} c={c}");
            assert_eq!(mx_s[c].to_bits(), mx_a[c].to_bits(), "stats max d={d} c={c}");
            assert_eq!(sum_s[c].to_bits(), sum_a[c].to_bits(), "stats sum d={d} c={c}");
            assert_eq!(sq_s[c].to_bits(), sq_a[c].to_bits(), "stats sumsq d={d} c={c}");
        }
    }
}
