//! Pre-PR-5 FWQ reference oracle.
//!
//! The wire rewrite replaced both layers under the FWQ frame — the bitio
//! kernels AND the planner/emitter (`fwq_encode_view` over `ColView` +
//! `FwqScratch`). The in-tree "legacy" parity tests route through the same
//! rewritten code on both sides, so they cannot catch a semantics change
//! that moves both sides equally. This file carries a **verbatim port of
//! the pre-rewrite pipeline** — `column_stats` → std stable `sort_by` →
//! per-candidate `plan_for_m` with the lazy early-stop scan →
//! allocate-per-column emission, serialized through the per-bit
//! `BitWriterRef` — and locks the production `fwq_encode` byte-identical
//! to it across a battery of shapes, budgets and degenerate configs.

use splitfc::bitio::BitWriterRef;
use splitfc::compression::waterfill::{self, LevelSpec};
use splitfc::compression::{fwq_decode, fwq_encode, FwqConfig};
use splitfc::tensor::{column_stats, Matrix};
use splitfc::testkit::hetero_matrix;

const HEADER_BITS: f64 = 32.0 + 32.0 + 4.0 * 32.0;

fn delta_ep(a_min: f32, a_max: f32, q_ep: u64) -> f64 {
    if q_ep <= 1 || a_max <= a_min {
        return 0.0;
    }
    (a_max as f64 - a_min as f64) / (q_ep as f64 - 1.0)
}

fn ep_radix(q_ep: u64) -> u64 {
    q_ep.max(2)
}

fn lg_ep(q_ep: u64) -> f64 {
    (ep_radix(q_ep) as f64).log2()
}

fn quantize_endpoints(lo: f32, hi: f32, a_min: f32, d_ep: f64, q_ep: u64) -> (u64, u64) {
    if d_ep <= 0.0 {
        return (0, 0);
    }
    let umin = (((lo as f64 - a_min as f64) / d_ep).floor() as i64).clamp(0, q_ep as i64 - 1);
    let umax = (((hi as f64 - a_min as f64) / d_ep).ceil() as i64).clamp(0, q_ep as i64 - 1);
    (umin as u64, umax.max(umin) as u64)
}

#[inline]
fn quant_code(v: f64, lo: f64, span: f64, q: u64) -> u64 {
    if span <= 0.0 || q < 2 {
        return 0;
    }
    let t = ((v - lo) / span * (q as f64 - 1.0)).round();
    (t.max(0.0) as u64).min(q - 1)
}

struct Plan {
    m: usize,
    two_stage: Vec<usize>,
    mean_cols: Vec<usize>,
    a_min: f32,
    a_max: f32,
    abar_min: f32,
    abar_max: f32,
    ep_codes: Vec<(u64, u64)>,
    levels: Vec<u64>,
    objective: f64,
}

#[allow(clippy::too_many_arguments)]
fn plan_for_m(
    cfg: &FwqConfig,
    order: &[usize],
    mins: &[f32],
    maxs: &[f32],
    means: &[f32],
    m: usize,
) -> Option<Plan> {
    let dhat = order.len();
    let b = cfg.batch as f64;
    let mut two_stage: Vec<usize> = order[..m].to_vec();
    let mut mean_cols: Vec<usize> = order[m..].to_vec();
    two_stage.sort_unstable();
    mean_cols.sort_unstable();

    let (mut a_min, mut a_max) = (f32::INFINITY, f32::NEG_INFINITY);
    for &c in &two_stage {
        a_min = a_min.min(mins[c]);
        a_max = a_max.max(maxs[c]);
    }
    if two_stage.is_empty() {
        a_min = 0.0;
        a_max = 0.0;
    }
    let d_ep = delta_ep(a_min, a_max, cfg.q_ep);
    let ep_codes: Vec<(u64, u64)> = two_stage
        .iter()
        .map(|&c| quantize_endpoints(mins[c], maxs[c], a_min, d_ep, cfg.q_ep))
        .collect();

    let (mut abar_min, mut abar_max) = (f32::INFINITY, f32::NEG_INFINITY);
    for &c in &mean_cols {
        abar_min = abar_min.min(means[c]);
        abar_max = abar_max.max(means[c]);
    }
    if mean_cols.is_empty() {
        abar_min = 0.0;
        abar_max = 0.0;
    }

    let c_const = 2.0 * m as f64 * lg_ep(cfg.q_ep) + dhat as f64 + HEADER_BITS;
    let c_levels = cfg.c_ava - c_const;

    let mut specs: Vec<LevelSpec> = ep_codes
        .iter()
        .map(|&(umin, umax)| LevelSpec::entry((umax - umin) as f64 * d_ep, cfg.batch))
        .collect();
    let use_mean_q = cfg.use_mean && !mean_cols.is_empty();
    if use_mean_q {
        specs.push(LevelSpec::mean((abar_max - abar_min) as f64, cfg.batch, mean_cols.len()));
    }

    let levels = match cfg.q_fixed {
        Some(q) => vec![q.max(2); specs.len()],
        None => match waterfill::solve(&specs, c_levels) {
            Some(l) => l,
            None if m == 0 => vec![2; specs.len()],
            None => return None,
        },
    };

    let mut obj = waterfill::objective(&specs, &levels);
    if cfg.use_mean {
        for &c in &mean_cols {
            let r = (maxs[c] - mins[c]) as f64;
            obj += r * r * b / 2.0;
        }
    } else {
        for &c in &mean_cols {
            let r = (maxs[c] - mins[c]).max(means[c].abs()) as f64;
            obj += r * r * b;
        }
    }

    Some(Plan {
        m,
        two_stage,
        mean_cols,
        a_min,
        a_max,
        abar_min,
        abar_max,
        ep_codes,
        levels,
        objective: obj,
    })
}

fn d_max(cfg: &FwqConfig, dhat: usize) -> usize {
    let lg = lg_ep(cfg.q_ep);
    match cfg.q_fixed {
        None => {
            let num = cfg.c_ava - 2.0 * dhat as f64 - HEADER_BITS;
            let den = cfg.batch as f64 + 2.0 * lg - 1.0;
            ((num / den).floor().max(0.0) as usize).min(dhat)
        }
        Some(q) => {
            let lq = (q.max(2) as f64).log2();
            let num = cfg.c_ava - dhat as f64 - HEADER_BITS - dhat as f64 * lq;
            let den = cfg.batch as f64 * lq + 2.0 * lg - lq;
            ((num / den).floor().max(0.0) as usize).min(dhat)
        }
    }
}

fn search_m(cfg: &FwqConfig, order: &[usize], mins: &[f32], maxs: &[f32], means: &[f32]) -> Plan {
    let dhat = order.len();
    let dmax = d_max(cfg, dhat);
    let mut candidates: Vec<usize> = if cfg.use_mean {
        (1..=cfg.n_candidates)
            .map(|n| (dmax * n + cfg.n_candidates - 1) / cfg.n_candidates)
            .collect()
    } else {
        vec![dmax]
    };
    candidates.push(0);
    candidates.sort_unstable();
    candidates.dedup();
    candidates.reverse();

    let mut best: Option<Plan> = None;
    let mut prev_obj = f64::INFINITY;
    for &m in &candidates {
        let Some(p) = plan_for_m(cfg, order, mins, maxs, means, m) else { continue };
        let obj = p.objective;
        if best.as_ref().map(|b| obj < b.objective).unwrap_or(true) {
            best = Some(p);
        }
        if obj > prev_obj {
            break;
        }
        prev_obj = obj;
    }
    best.expect("candidate scan includes M = 0, which always constructs")
}

/// The pre-PR pipeline, stats → stable sort_by → plan → allocate-per-column
/// emission through the per-bit reference writer.
fn fwq_encode_ref(a: &Matrix, cfg: &FwqConfig) -> (Vec<u8>, u64) {
    let dhat = a.cols;
    assert_eq!(a.rows, cfg.batch);
    if dhat == 0 {
        return (Vec::new(), 0);
    }
    let st = column_stats(a);
    let ranges: Vec<f32> = st.ranges();
    let mut order: Vec<usize> = (0..dhat).collect();
    order.sort_by(|&x, &y| ranges[y].partial_cmp(&ranges[x]).unwrap_or(std::cmp::Ordering::Equal));

    let plan = search_m(cfg, &order, &st.min, &st.max, &st.mean);

    let mut w = BitWriterRef::new();
    w.write_u32(dhat as u32);
    w.write_u32(plan.m as u32);
    w.write_f32(plan.a_min);
    w.write_f32(plan.a_max);
    w.write_f32(plan.abar_min);
    w.write_f32(plan.abar_max);
    let mut is_two = vec![false; dhat];
    for &c in &plan.two_stage {
        is_two[c] = true;
    }
    for &f in &is_two {
        w.write_bits(f as u64, 1);
    }
    let mut ep_syms = Vec::with_capacity(2 * plan.m);
    for &(umin, umax) in &plan.ep_codes {
        ep_syms.push(umin);
        ep_syms.push(umax);
    }
    w.write_radix(&ep_syms, ep_radix(cfg.q_ep));

    let d_ep = delta_ep(plan.a_min, plan.a_max, cfg.q_ep);
    let use_mean_q = cfg.use_mean && !plan.mean_cols.is_empty();
    let q0 = if use_mean_q { Some(*plan.levels.last().unwrap()) } else { None };

    if let Some(q0v) = q0 {
        let lo = plan.abar_min as f64;
        let span = (plan.abar_max - plan.abar_min) as f64;
        let syms: Vec<u64> = plan
            .mean_cols
            .iter()
            .map(|&c| quant_code(st.mean[c] as f64, lo, span, q0v))
            .collect();
        w.write_radix(&syms, q0v);
    }
    for (j, &c) in plan.two_stage.iter().enumerate() {
        let (umin, umax) = plan.ep_codes[j];
        let lo = plan.a_min as f64 + umin as f64 * d_ep;
        let span = (umax - umin) as f64 * d_ep;
        let qj = plan.levels[j];
        let syms: Vec<u64> = a.col_iter(c).map(|v| quant_code(v as f64, lo, span, qj)).collect();
        w.write_radix(&syms, qj);
    }
    let bits = w.bit_len();
    (w.into_bytes(), bits)
}

fn battery() -> Vec<(Matrix, f64)> {
    let mut out = Vec::new();
    for (b, d, seed) in [(8usize, 16usize, 1u64), (16, 64, 2), (32, 96, 3), (64, 200, 4)] {
        for bpe in [0.2f64, 1.0, 4.0] {
            out.push((hetero_matrix(b, d, seed), bpe));
        }
    }
    // degenerate: constant matrix (all ranges tie at zero — the stable-sort
    // tie-handling case) and a half-constant one
    out.push((Matrix::from_fn(8, 24, |_, _| 3.25), 1.0));
    out.push((
        Matrix::from_fn(16, 20, |r, c| if c % 2 == 0 { 2.5 } else { (r as f32) * 0.1 - 0.8 }),
        2.0,
    ));
    out
}

#[test]
fn new_fwq_pipeline_is_byte_identical_to_pre_rewrite_reference() {
    splitfc::util::par::set_threads(1);
    for (a, bpe) in battery() {
        let base = FwqConfig::paper_default(a.rows, bpe * (a.rows * a.cols) as f64);
        let mut variants = vec![base.clone()];
        let mut v = base.clone();
        v.use_mean = false;
        variants.push(v);
        let mut v = base.clone();
        v.q_fixed = Some(8);
        variants.push(v);
        let mut v = base.clone();
        v.q_ep = 1; // degenerate shared endpoint quantizer
        variants.push(v);
        for cfg in variants {
            let (bytes_new, bits_new, _) = fwq_encode(&a, &cfg);
            let (bytes_ref, bits_ref) = fwq_encode_ref(&a, &cfg);
            assert_eq!(
                bits_new, bits_ref,
                "bit length drifted: B={} D={} bpe={bpe} use_mean={} q_fixed={:?} q_ep={}",
                a.rows, a.cols, cfg.use_mean, cfg.q_fixed, cfg.q_ep
            );
            assert_eq!(
                bytes_new, bytes_ref,
                "bitstream drifted from the pre-rewrite pipeline: B={} D={} bpe={bpe} \
                 use_mean={} q_fixed={:?} q_ep={}",
                a.rows, a.cols, cfg.use_mean, cfg.q_fixed, cfg.q_ep
            );
            // and the production decoder inverts the reference bytes
            let dec = fwq_decode(&bytes_ref, &cfg);
            assert_eq!((dec.rows, dec.cols), (a.rows, a.cols));
        }
    }
    splitfc::util::par::set_threads(0);
}

#[test]
fn threaded_encode_matches_reference_too() {
    // the speculative parallel plan scan + threaded symbol fan-out must not
    // drift from the reference either (byte-identity across thread counts
    // is separately locked by prop_parallel; this pins it to the oracle)
    let a = hetero_matrix(32, 512, 9);
    let cfg = FwqConfig::paper_default(32, 0.5 * (32 * 512) as f64);
    splitfc::util::par::set_threads(4);
    let (bytes_new, _, _) = fwq_encode(&a, &cfg);
    splitfc::util::par::set_threads(0);
    let (bytes_ref, _) = fwq_encode_ref(&a, &cfg);
    assert_eq!(bytes_new, bytes_ref);
}
