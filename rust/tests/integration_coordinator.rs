//! Integration: the concurrent ParameterServer/DeviceWorker coordinator.
//!
//! The load-bearing contract: a K-device run driven by concurrent worker
//! threads at `staleness = 0` is **metric-identical** to the sequential
//! Algorithm-1 round-robin — same per-step losses, bits, global-step tags,
//! and eval history (timing fields excluded, they are wall-clock). A
//! `staleness > 0` run relaxes the ordering but must still converge on the
//! tiny preset.

use splitfc::config::{parse_scheme, TrainConfig};
use splitfc::coordinator::Trainer;
use splitfc::util::Json;

fn base_cfg(metrics: &str) -> TrainConfig {
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 4;
    cfg.rounds = 5;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.eval_every = 2;
    cfg.scheme = parse_scheme("splitfc", 4.0).unwrap();
    cfg.up_bits_per_entry = 2.0;
    cfg.down_bits_per_entry = 4.0;
    cfg.seed = 11;
    cfg.metrics_path = metrics.to_string();
    cfg
}

fn metrics_file(tag: &str) -> std::path::PathBuf {
    std::env::temp_dir().join(format!("splitfc_coord_{tag}_{}.jsonl", std::process::id()))
}

/// The deterministic fields of every step record in a metrics stream
/// (drops the wall-clock `step_s`/`exec_s` and the summary line).
fn step_fields(path: &std::path::Path) -> Vec<Vec<(String, String)>> {
    let text = std::fs::read_to_string(path).expect("metrics file");
    let mut out = Vec::new();
    for line in text.lines() {
        let j = Json::parse(line).expect("valid JSONL");
        if j.get("t").is_none() {
            continue; // the trailing summary record
        }
        let mut fields = Vec::new();
        for key in [
            "t", "k", "g", "loss", "train_acc", "up_bits", "down_bits", "up_nominal",
            "down_nominal",
        ] {
            let v = j.req(key).as_f64().unwrap_or_else(|| panic!("field {key} in {line}"));
            fields.push((key.to_string(), format!("{v:?}")));
        }
        out.push(fields);
    }
    out
}

#[test]
fn concurrent_staleness0_is_metric_identical_to_sequential() {
    // reference: the sequential Algorithm-1 path (auto concurrency = 1)
    let seq_path = metrics_file("seq");
    let mut cfg = base_cfg(seq_path.to_str().unwrap());
    assert_eq!(cfg.resolved_concurrency(), 1);
    let mut tr = Trainer::new(cfg).unwrap();
    let seq = tr.run().unwrap();

    // same run driven by 4 concurrent device-worker threads, strict window
    let conc_path = metrics_file("conc");
    let mut cfg = base_cfg(conc_path.to_str().unwrap());
    cfg.concurrent_devices = 4;
    assert_eq!(cfg.resolved_concurrency(), 4);
    let mut tr = Trainer::new(cfg).unwrap();
    let conc = tr.run().unwrap();

    // summary: accuracy, losses, bits, step counts, eval history all match
    assert_eq!(seq.final_acc, conc.final_acc, "final accuracy");
    assert_eq!(
        seq.mean_loss_last_round.to_bits(),
        conc.mean_loss_last_round.to_bits(),
        "mean last-round loss"
    );
    assert_eq!(seq.total_up_bits, conc.total_up_bits, "uplink bits");
    assert_eq!(seq.total_down_bits, conc.total_down_bits, "downlink bits");
    assert_eq!(seq.steps, conc.steps, "step count");
    assert_eq!(seq.steps, 20);
    assert_eq!(seq.eval_history, conc.eval_history, "eval history");
    assert!(!seq.eval_history.is_empty());
    // the modeled link time is a deterministic per-device sum
    assert_eq!(seq.link_s.to_bits(), conc.link_s.to_bits(), "modeled link time");

    // per-step records: byte-identical deterministic fields, same order
    let a = step_fields(&seq_path);
    let b = step_fields(&conc_path);
    assert_eq!(a.len(), 20);
    assert_eq!(a, b, "per-step metrics must match record-for-record");
    std::fs::remove_file(seq_path).ok();
    std::fs::remove_file(conc_path).ok();
}

#[test]
fn concurrent_staleness0_repeats_deterministically() {
    let run = || {
        let mut cfg = base_cfg("");
        cfg.concurrent_devices = 4;
        cfg.eval_every = 0;
        let mut tr = Trainer::new(cfg).unwrap();
        let s = tr.run().unwrap();
        (s.final_acc, s.total_up_bits, s.mean_loss_last_round.to_bits())
    };
    assert_eq!(run(), run(), "strict concurrent runs must reproduce exactly");
}

#[test]
fn stale_concurrent_run_converges_on_tiny() {
    // bounded staleness: 4 devices, 2 rounds of lookahead, lossless links —
    // updates interleave nondeterministically but training must still learn
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 4;
    cfg.rounds = 10;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.staleness = 2;
    assert_eq!(cfg.resolved_concurrency(), 4);
    let mut tr = Trainer::new(cfg).unwrap();
    let s = tr.run().unwrap();
    assert_eq!(s.steps, 40);
    assert!(s.mean_loss_last_round.is_finite());
    assert!(
        s.final_acc > 0.3,
        "staleness-2 run should beat 4-class chance, got {}",
        s.final_acc
    );
}

#[test]
fn stale_run_respects_budgets_and_accounting() {
    let mut cfg = base_cfg("");
    cfg.staleness = 1;
    cfg.eval_every = 0;
    let mut tr = Trainer::new(cfg).unwrap();
    let p = tr.preset().clone();
    let s = tr.run().unwrap();
    assert_eq!(s.steps, 20);
    // every step respects the per-step budget within codec tolerance
    let budget_up = 2.0 * (p.batch * p.dbar) as f64 * s.steps as f64;
    assert!(
        (s.total_up_bits as f64) <= budget_up * 1.15 + 512.0 * s.steps as f64,
        "uplink total {} vs budget {budget_up}",
        s.total_up_bits
    );
    // the aggregate link report saw every frame
    let rep = tr.link_report();
    assert_eq!(rep.up_frames, 20);
    assert_eq!(rep.down_frames, 20);
}

#[test]
fn per_device_opt_slots_train_too() {
    // lossless links isolate the per-device ADAM slots as the only change
    let mut cfg = TrainConfig::for_preset("tiny");
    cfg.devices = 4;
    cfg.rounds = 10;
    cfg.n_train = 256;
    cfg.n_test = 64;
    cfg.per_device_opt = true;
    cfg.staleness = 1;
    let mut tr = Trainer::new(cfg).unwrap();
    let s = tr.run().unwrap();
    assert_eq!(s.steps, 40);
    assert!(s.mean_loss_last_round.is_finite());
    assert!(s.final_acc > 0.25, "per-device-opt run collapsed: {}", s.final_acc);
}
