//! Named parameter sets over flat storage.

use crate::util::Json;

#[derive(Debug, Clone, PartialEq)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

impl ParamSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn from_json(j: &Json) -> ParamSpec {
        ParamSpec {
            name: j.req("name").as_str().unwrap().to_string(),
            shape: j.req("shape").usize_arr().unwrap(),
        }
    }
}

/// A sub-model's parameters: contiguous f32 storage + per-tensor views.
#[derive(Debug, Clone)]
pub struct ParamSet {
    pub specs: Vec<ParamSpec>,
    offsets: Vec<usize>,
    pub data: Vec<f32>,
}

impl ParamSet {
    pub fn new(specs: Vec<ParamSpec>, data: Vec<f32>) -> ParamSet {
        let mut offsets = Vec::with_capacity(specs.len());
        let mut off = 0;
        for s in &specs {
            offsets.push(off);
            off += s.numel();
        }
        assert_eq!(off, data.len(), "param blob size mismatch");
        ParamSet { specs, offsets, data }
    }

    pub fn n_params(&self) -> usize {
        self.data.len()
    }

    pub fn n_tensors(&self) -> usize {
        self.specs.len()
    }

    pub fn tensor(&self, i: usize) -> &[f32] {
        let lo = self.offsets[i];
        &self.data[lo..lo + self.specs[i].numel()]
    }

    pub fn tensor_by_name(&self, name: &str) -> Option<&[f32]> {
        self.specs.iter().position(|s| s.name == name).map(|i| self.tensor(i))
    }

    /// Split a flat gradient vector into per-tensor slices (same layout).
    pub fn split_flat<'a>(&self, flat: &'a [f32]) -> Vec<&'a [f32]> {
        assert_eq!(flat.len(), self.data.len());
        (0..self.specs.len())
            .map(|i| {
                let lo = self.offsets[i];
                &flat[lo..lo + self.specs[i].numel()]
            })
            .collect()
    }

    /// Concatenate per-tensor blobs (in spec order) into a flat vector.
    pub fn concat(tensors: &[Vec<f32>]) -> Vec<f32> {
        let mut out = Vec::with_capacity(tensors.iter().map(|t| t.len()).sum());
        for t in tensors {
            out.extend_from_slice(t);
        }
        out
    }
}

/// Decode a little-endian f32 blob.
pub fn f32_from_le_bytes(bytes: &[u8]) -> Vec<f32> {
    assert_eq!(bytes.len() % 4, 0, "blob not f32-aligned");
    bytes
        .chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn specs() -> Vec<ParamSpec> {
        vec![
            ParamSpec { name: "w".into(), shape: vec![2, 3] },
            ParamSpec { name: "b".into(), shape: vec![3] },
        ]
    }

    #[test]
    fn layout_and_views() {
        let data: Vec<f32> = (0..9).map(|i| i as f32).collect();
        let ps = ParamSet::new(specs(), data);
        assert_eq!(ps.n_params(), 9);
        assert_eq!(ps.tensor(0), &[0., 1., 2., 3., 4., 5.]);
        assert_eq!(ps.tensor(1), &[6., 7., 8.]);
        assert_eq!(ps.tensor_by_name("b").unwrap(), &[6., 7., 8.]);
        assert!(ps.tensor_by_name("missing").is_none());
    }

    #[test]
    fn split_flat_matches_layout() {
        let ps = ParamSet::new(specs(), vec![0.0; 9]);
        let grads: Vec<f32> = (0..9).map(|i| -(i as f32)).collect();
        let parts = ps.split_flat(&grads);
        assert_eq!(parts.len(), 2);
        assert_eq!(parts[1], &[-6., -7., -8.]);
    }

    #[test]
    #[should_panic]
    fn size_mismatch_panics() {
        ParamSet::new(specs(), vec![0.0; 7]);
    }

    #[test]
    fn f32_le_roundtrip() {
        let vals = [1.5f32, -0.25, 1e20];
        let bytes: Vec<u8> = vals.iter().flat_map(|v| v.to_le_bytes()).collect();
        assert_eq!(f32_from_le_bytes(&bytes), vals);
    }

    #[test]
    fn spec_from_json() {
        let j = Json::parse(r#"{"name": "conv1_w", "shape": [9, 16]}"#).unwrap();
        let s = ParamSpec::from_json(&j);
        assert_eq!(s.name, "conv1_w");
        assert_eq!(s.numel(), 144);
    }
}
