//! Preset metadata mirrored from the Python manifest (`artifacts/manifest.json`).

use std::collections::BTreeMap;

use crate::model::params::ParamSpec;
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct EntryInfo {
    pub file: String,
    pub num_inputs: usize,
    pub num_outputs: usize,
    pub input_shapes: Vec<Vec<usize>>,
}

#[derive(Debug, Clone)]
pub struct PresetInfo {
    pub name: String,
    pub batch: usize,
    pub dbar: usize,
    pub num_channels: usize,
    pub chan_size: usize,
    pub classes: usize,
    pub in_shape: Vec<usize>,
    pub nd_params: usize,
    pub ns_params: usize,
    pub device_params: Vec<ParamSpec>,
    pub server_params: Vec<ParamSpec>,
    pub params_file: String,
    pub entries: BTreeMap<String, EntryInfo>,
}

impl PresetInfo {
    pub fn from_json(name: &str, j: &Json) -> PresetInfo {
        let specs = |key: &str| -> Vec<ParamSpec> {
            j.req(key)
                .as_arr()
                .unwrap()
                .iter()
                .map(ParamSpec::from_json)
                .collect()
        };
        let mut entries = BTreeMap::new();
        for (k, v) in j.req("entries").as_obj().unwrap() {
            entries.insert(
                k.clone(),
                EntryInfo {
                    file: v.req("file").as_str().unwrap().to_string(),
                    num_inputs: v.req("num_inputs").as_usize().unwrap(),
                    num_outputs: v.req("num_outputs").as_usize().unwrap(),
                    input_shapes: v
                        .req("input_shapes")
                        .as_arr()
                        .unwrap()
                        .iter()
                        .map(|s| s.usize_arr().unwrap())
                        .collect(),
                },
            );
        }
        PresetInfo {
            name: name.to_string(),
            batch: j.req("batch").as_usize().unwrap(),
            dbar: j.req("dbar").as_usize().unwrap(),
            num_channels: j.req("num_channels").as_usize().unwrap(),
            chan_size: j.req("chan_size").as_usize().unwrap(),
            classes: j.req("classes").as_usize().unwrap(),
            in_shape: j.req("in_shape").usize_arr().unwrap(),
            nd_params: j.req("nd_params").as_usize().unwrap(),
            ns_params: j.req("ns_params").as_usize().unwrap(),
            device_params: specs("device_params"),
            server_params: specs("server_params"),
            params_file: j.req("params_file").as_str().unwrap().to_string(),
            entries,
        }
    }

    pub fn sample_dim(&self) -> usize {
        self.in_shape.iter().product()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
        "batch": 8, "dbar": 32, "num_channels": 8, "chan_size": 4,
        "classes": 4, "in_shape": [1, 8, 8], "hidden": 16,
        "nd_params": 336, "ns_params": 596,
        "device_params": [{"name": "conv1_w", "shape": [9, 4]}],
        "server_params": [{"name": "fc1_w", "shape": [32, 16]}],
        "params_file": "tiny/params.bin",
        "entries": {
            "device_fwd": {"file": "tiny/device_fwd.hlo.txt",
                "num_inputs": 5, "num_outputs": 1,
                "input_shapes": [[9, 4], [4], [36, 8], [8], [8, 1, 8, 8]]}
        }
    }"#;

    #[test]
    fn parses_preset() {
        let j = Json::parse(SAMPLE).unwrap();
        let p = PresetInfo::from_json("tiny", &j);
        assert_eq!(p.batch, 8);
        assert_eq!(p.dbar, 32);
        assert_eq!(p.sample_dim(), 64);
        assert_eq!(p.device_params[0].numel(), 36);
        let e = &p.entries["device_fwd"];
        assert_eq!(e.num_inputs, 5);
        assert_eq!(e.input_shapes[4], vec![8, 1, 8, 8]);
    }
}
