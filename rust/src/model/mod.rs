//! Host-side model state: named parameter sets loaded from the AOT artifact
//! bundle (`params.bin` + manifest), kept as one flat f32 vector per
//! sub-model so optimizers can step over them in place.

pub mod arch;
pub mod params;

pub use arch::{EntryInfo, PresetInfo};
pub use params::{f32_from_le_bytes, ParamSet, ParamSpec};
