//! # SplitFC — communication-efficient split learning (paper reproduction)
//!
//! Three-layer architecture (see DESIGN.md):
//! * **L3 (this crate)**: the split-learning coordinator — Algorithm 1's
//!   round-robin decomposed into ParameterServer / DeviceWorker roles under
//!   a bounded-staleness scheduler — the adaptive feature-wise dropout
//!   (FWDP) + quantization (FWQ) compression pipeline over real bit-packed
//!   frames, baselines, simulated transport, metrics, and the experiment
//!   harness for every paper table/figure.
//! * **Execution backends (`runtime`)**: the coordinator drives the split
//!   model through the `runtime::Backend` trait. The default is the
//!   dependency-free pure-Rust native backend; `--features pjrt` enables
//!   the AOT HLO-artifact path below.
//! * **L2/L1 (build-time Python, `python/compile/`)**: the split CNN model
//!   in JAX calling Pallas kernels, AOT-lowered to HLO text artifacts that
//!   `runtime::pjrt` loads through PJRT. Python never runs on the training
//!   path.

pub mod bench;
pub mod bitio;
pub mod checkpoint;
pub mod compression;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod model;
pub mod optim;
pub mod runtime;
pub mod scenario;
pub mod tensor;
pub mod testkit;
pub mod transport;
pub mod util;

pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
