//! The device <-> PS protocol messages.
//!
//! Every exchange of the split protocol is an explicit [`Msg`] sent over a
//! [`Connection`](crate::transport::Connection). One protocol step is three
//! request/reply pairs, all initiated by the device:
//!
//! ```text
//! device                                parameter server
//!   | -- StepStart{device,round,local} --> |  (blocks in the staleness gate)
//!   | <-- StepGo{w_d ModelSync, rng?} ---- |
//!   | -- Uplink{frame,labels,mask,...} --> |  (decode, fwd/bwd, w_s step)
//!   | <-- Downlink{frame,loss,...} ------- |
//!   | -- Commit{grad ModelSync, report} -> |  (w_d step, metrics, watermark)
//!   | <-- CommitAck ---------------------- |
//! ```
//!
//! `Hello`/`HelloAck` open a connection (carrying the codec id + wire
//! version so mismatched codecs are rejected at handshake, not mid-run),
//! `Bye` closes it cleanly, and `Abort` is the PS's typed failure reply.
//! The request/reply discipline gives per-connection backpressure for free:
//! a device never has more than one message in flight.
//!
//! On the TCP backend each message crosses the socket as one
//! [`FrameKind::Control`] frame whose payload is the byte encoding below;
//! in-process channels move the enum directly (zero copies). All multi-byte
//! fields are little-endian; decoding is bounds-checked via [`ByteCursor`]
//! and returns typed [`CodecError`]s on truncated or malformed input.

use crate::compression::error::CodecError;
use crate::compression::GradMask;
use crate::transport::wire::{ByteCursor, Frame, WireLimits};
use crate::util::RngState;

/// The deterministic per-step measurements a device reports at `Commit`;
/// the PS combines them with its own half (server exec time, global-step
/// tag) into the metrics [`StepRecord`](crate::coordinator::StepRecord).
#[derive(Debug, Clone, PartialEq)]
pub struct StepReport {
    pub loss: f32,
    pub train_acc: f32,
    pub up_bits: u64,
    pub down_bits: u64,
    pub up_nominal: f64,
    pub down_nominal: f64,
    pub step_s: f64,
    /// backend time spent on the device (fwd, σ stats, bwd)
    pub device_exec_s: f64,
}

#[derive(Debug, Clone)]
pub enum Msg {
    /// Device -> PS connection opener. `codec_id`/`codec_version` are the
    /// device codec session's frame stamp; the PS rejects a mismatch.
    Hello { device: u32, codec_id: u32, codec_version: u16 },
    /// PS -> device handshake reply; `err` is `Some` on rejection.
    /// `first_round` is where the schedule begins (1 on a fresh run,
    /// `checkpoint round + 1` after `--resume`); `ckpt_every` tells the
    /// device whether to attach its state blob at `Commit`; `state` is the
    /// device's restored [`DeviceSnap`](crate::checkpoint::DeviceSnap)
    /// encoding when the PS holds one for it.
    HelloAck {
        devices: u32,
        rounds: u32,
        staleness: u32,
        first_round: u32,
        ckpt_every: u32,
        state: Option<Vec<u8>>,
        err: Option<String>,
    },
    /// Device -> PS: request entry for schedule-local step `local` of
    /// `round`. Blocks server-side in the staleness/eval gate.
    StepStart { device: u32, round: u32, local: u64 },
    /// PS -> device: step granted. `wd` is the current device-side model as
    /// a `ModelSync` frame; `rng` is the shared Algorithm-1 encode stream
    /// (present iff staleness = 0).
    StepGo { wd: Frame, rng: Option<RngState> },
    /// Device -> PS: the compressed feature frame plus everything the PS
    /// needs for its half — one-hot labels, the eq.-8 gradient mask, the
    /// nominal bit count, and (shared-stream mode) the advanced RNG state.
    Uplink {
        device: u32,
        local: u64,
        frame: Frame,
        labels: Vec<f32>,
        mask: GradMask,
        up_nominal: f64,
        rng: Option<RngState>,
    },
    /// PS -> device: the mask-coupled compressed gradient frame plus the
    /// step's server-side outputs.
    Downlink {
        frame: Frame,
        loss: f32,
        correct: f32,
        server_exec_s: f64,
        down_nominal: f64,
    },
    /// Device -> PS: the device-model gradient (`ModelSync` frame, little-
    /// endian f32) and the step report. Completes the step. `state` is the
    /// device's post-step checkpoint blob, attached whenever the run
    /// checkpoints (`ckpt_every > 0` in the handshake) so the PS always
    /// holds the freshest device state at a snapshot barrier.
    Commit {
        device: u32,
        round: u32,
        local: u64,
        grad: Frame,
        report: StepReport,
        state: Option<Vec<u8>>,
    },
    /// PS -> device: step committed (watermark advanced).
    CommitAck,
    /// Device -> PS: request a fresh w_d snapshot (diagnostics/probes).
    FetchModel { device: u32 },
    /// PS -> device: the snapshot as a `ModelSync` frame.
    ModelReply { wd: Frame },
    /// Device -> PS: clean leave.
    Bye { device: u32 },
    /// PS -> device: typed failure reply (protocol error, scheduler abort).
    Abort { reason: String },
}

fn put_str(out: &mut Vec<u8>, s: &str) {
    out.extend_from_slice(&(s.len() as u32).to_le_bytes());
    out.extend_from_slice(s.as_bytes());
}

fn get_str(cur: &mut ByteCursor<'_>) -> Result<String, CodecError> {
    let n = cur.u32()? as usize;
    let bytes = cur.take(n)?;
    String::from_utf8(bytes.to_vec()).map_err(|_| CodecError::MalformedHeader {
        reason: "non-UTF-8 string field".to_string(),
    })
}

fn put_opt_bytes(out: &mut Vec<u8>, bytes: &Option<Vec<u8>>) {
    match bytes {
        None => out.push(0),
        Some(b) => {
            out.push(1);
            out.extend_from_slice(&(b.len() as u32).to_le_bytes());
            out.extend_from_slice(b);
        }
    }
}

fn get_opt_bytes(cur: &mut ByteCursor<'_>) -> Result<Option<Vec<u8>>, CodecError> {
    match cur.u8()? {
        0 => Ok(None),
        1 => {
            let n = cur.u32()? as usize;
            Ok(Some(cur.take(n)?.to_vec()))
        }
        other => Err(CodecError::MalformedHeader {
            reason: format!("bad byte-blob flag {other}"),
        }),
    }
}

fn put_f32s(out: &mut Vec<u8>, xs: &[f32]) {
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for x in xs {
        out.extend_from_slice(&x.to_le_bytes());
    }
}

fn get_f32s(cur: &mut ByteCursor<'_>) -> Result<Vec<f32>, CodecError> {
    let n = cur.u32()? as usize;
    // length sanity before allocating: each element needs 4 bytes
    if cur.remaining() < n.saturating_mul(4) {
        return Err(CodecError::TruncatedFrame {
            needed: n as u64 * 4,
            available: cur.remaining() as u64,
        });
    }
    (0..n).map(|_| cur.f32()).collect()
}

fn put_indices(out: &mut Vec<u8>, xs: &[usize]) {
    out.extend_from_slice(&(xs.len() as u32).to_le_bytes());
    for &x in xs {
        out.extend_from_slice(&(x as u32).to_le_bytes());
    }
}

fn get_indices(cur: &mut ByteCursor<'_>) -> Result<Vec<usize>, CodecError> {
    let n = cur.u32()? as usize;
    if cur.remaining() < n.saturating_mul(4) {
        return Err(CodecError::TruncatedFrame {
            needed: n as u64 * 4,
            available: cur.remaining() as u64,
        });
    }
    (0..n).map(|_| cur.u32().map(|v| v as usize)).collect()
}

fn put_rng(out: &mut Vec<u8>, rng: &Option<RngState>) {
    match rng {
        None => out.push(0),
        Some(st) => {
            out.push(1);
            for w in st.s {
                out.extend_from_slice(&w.to_le_bytes());
            }
            match st.gauss {
                None => out.push(0),
                Some(g) => {
                    out.push(1);
                    out.extend_from_slice(&g.to_bits().to_le_bytes());
                }
            }
        }
    }
}

fn get_rng(cur: &mut ByteCursor<'_>) -> Result<Option<RngState>, CodecError> {
    match cur.u8()? {
        0 => Ok(None),
        1 => {
            let s = [cur.u64()?, cur.u64()?, cur.u64()?, cur.u64()?];
            let gauss = match cur.u8()? {
                0 => None,
                1 => Some(cur.f64()?),
                other => {
                    return Err(CodecError::MalformedHeader {
                        reason: format!("bad gauss-cache flag {other}"),
                    })
                }
            };
            Ok(Some(RngState { s, gauss }))
        }
        other => Err(CodecError::MalformedHeader {
            reason: format!("bad rng-state flag {other}"),
        }),
    }
}

fn put_mask(out: &mut Vec<u8>, mask: &GradMask) {
    match mask {
        GradMask::All => out.push(0),
        GradMask::Columns { kept, scale } => {
            out.push(1);
            put_indices(out, kept);
            put_f32s(out, scale);
        }
        GradMask::Entries(rows) => {
            out.push(2);
            out.extend_from_slice(&(rows.len() as u32).to_le_bytes());
            for row in rows {
                put_indices(out, row);
            }
        }
    }
}

fn get_mask(cur: &mut ByteCursor<'_>) -> Result<GradMask, CodecError> {
    match cur.u8()? {
        0 => Ok(GradMask::All),
        1 => {
            let kept = get_indices(cur)?;
            let scale = get_f32s(cur)?;
            if kept.len() != scale.len() {
                return Err(CodecError::MalformedHeader {
                    reason: format!(
                        "column mask length mismatch: {} kept vs {} scales",
                        kept.len(),
                        scale.len()
                    ),
                });
            }
            Ok(GradMask::Columns { kept, scale })
        }
        2 => {
            let n = cur.u32()? as usize;
            let mut rows = Vec::with_capacity(n.min(cur.remaining()));
            for _ in 0..n {
                rows.push(get_indices(cur)?);
            }
            Ok(GradMask::Entries(rows))
        }
        other => Err(CodecError::MalformedHeader {
            reason: format!("unknown grad-mask tag {other}"),
        }),
    }
}

fn put_report(out: &mut Vec<u8>, r: &StepReport) {
    out.extend_from_slice(&r.loss.to_le_bytes());
    out.extend_from_slice(&r.train_acc.to_le_bytes());
    out.extend_from_slice(&r.up_bits.to_le_bytes());
    out.extend_from_slice(&r.down_bits.to_le_bytes());
    out.extend_from_slice(&r.up_nominal.to_bits().to_le_bytes());
    out.extend_from_slice(&r.down_nominal.to_bits().to_le_bytes());
    out.extend_from_slice(&r.step_s.to_bits().to_le_bytes());
    out.extend_from_slice(&r.device_exec_s.to_bits().to_le_bytes());
}

fn get_report(cur: &mut ByteCursor<'_>) -> Result<StepReport, CodecError> {
    Ok(StepReport {
        loss: cur.f32()?,
        train_acc: cur.f32()?,
        up_bits: cur.u64()?,
        down_bits: cur.u64()?,
        up_nominal: cur.f64()?,
        down_nominal: cur.f64()?,
        step_s: cur.f64()?,
        device_exec_s: cur.f64()?,
    })
}

impl Msg {
    fn tag(&self) -> u8 {
        match self {
            Msg::Hello { .. } => 1,
            Msg::HelloAck { .. } => 2,
            Msg::StepStart { .. } => 3,
            Msg::StepGo { .. } => 4,
            Msg::Uplink { .. } => 5,
            Msg::Downlink { .. } => 6,
            Msg::Commit { .. } => 7,
            Msg::CommitAck => 8,
            Msg::FetchModel { .. } => 9,
            Msg::ModelReply { .. } => 10,
            Msg::Bye { .. } => 11,
            Msg::Abort { .. } => 12,
        }
    }

    /// Short name for error messages ("expected X, got Y").
    pub fn name(&self) -> &'static str {
        match self {
            Msg::Hello { .. } => "Hello",
            Msg::HelloAck { .. } => "HelloAck",
            Msg::StepStart { .. } => "StepStart",
            Msg::StepGo { .. } => "StepGo",
            Msg::Uplink { .. } => "Uplink",
            Msg::Downlink { .. } => "Downlink",
            Msg::Commit { .. } => "Commit",
            Msg::CommitAck => "CommitAck",
            Msg::FetchModel { .. } => "FetchModel",
            Msg::ModelReply { .. } => "ModelReply",
            Msg::Bye { .. } => "Bye",
            Msg::Abort { .. } => "Abort",
        }
    }

    /// The device id a request speaks for, when it carries one — the PS
    /// liveness tracker binds connections to devices through this.
    pub fn device(&self) -> Option<u32> {
        match self {
            Msg::Hello { device, .. }
            | Msg::StepStart { device, .. }
            | Msg::Uplink { device, .. }
            | Msg::Commit { device, .. }
            | Msg::FetchModel { device }
            | Msg::Bye { device } => Some(*device),
            _ => None,
        }
    }

    /// Append the byte encoding (tag + fields, little-endian) to `out`.
    pub fn encode(&self, out: &mut Vec<u8>) {
        out.push(self.tag());
        match self {
            Msg::Hello { device, codec_id, codec_version } => {
                out.extend_from_slice(&device.to_le_bytes());
                out.extend_from_slice(&codec_id.to_le_bytes());
                out.extend_from_slice(&codec_version.to_le_bytes());
            }
            Msg::HelloAck { devices, rounds, staleness, first_round, ckpt_every, state, err } => {
                out.extend_from_slice(&devices.to_le_bytes());
                out.extend_from_slice(&rounds.to_le_bytes());
                out.extend_from_slice(&staleness.to_le_bytes());
                out.extend_from_slice(&first_round.to_le_bytes());
                out.extend_from_slice(&ckpt_every.to_le_bytes());
                put_opt_bytes(out, state);
                match err {
                    None => out.push(0),
                    Some(e) => {
                        out.push(1);
                        put_str(out, e);
                    }
                }
            }
            Msg::StepStart { device, round, local } => {
                out.extend_from_slice(&device.to_le_bytes());
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&local.to_le_bytes());
            }
            Msg::StepGo { wd, rng } => {
                wd.write_to(out);
                put_rng(out, rng);
            }
            Msg::Uplink { device, local, frame, labels, mask, up_nominal, rng } => {
                out.extend_from_slice(&device.to_le_bytes());
                out.extend_from_slice(&local.to_le_bytes());
                frame.write_to(out);
                put_f32s(out, labels);
                put_mask(out, mask);
                out.extend_from_slice(&up_nominal.to_bits().to_le_bytes());
                put_rng(out, rng);
            }
            Msg::Downlink { frame, loss, correct, server_exec_s, down_nominal } => {
                frame.write_to(out);
                out.extend_from_slice(&loss.to_le_bytes());
                out.extend_from_slice(&correct.to_le_bytes());
                out.extend_from_slice(&server_exec_s.to_bits().to_le_bytes());
                out.extend_from_slice(&down_nominal.to_bits().to_le_bytes());
            }
            Msg::Commit { device, round, local, grad, report, state } => {
                out.extend_from_slice(&device.to_le_bytes());
                out.extend_from_slice(&round.to_le_bytes());
                out.extend_from_slice(&local.to_le_bytes());
                grad.write_to(out);
                put_report(out, report);
                put_opt_bytes(out, state);
            }
            Msg::CommitAck => {}
            Msg::FetchModel { device } => {
                out.extend_from_slice(&device.to_le_bytes());
            }
            Msg::ModelReply { wd } => {
                wd.write_to(out);
            }
            Msg::Bye { device } => {
                out.extend_from_slice(&device.to_le_bytes());
            }
            Msg::Abort { reason } => {
                put_str(out, reason);
            }
        }
    }

    /// Decode one message from `buf`, enforcing `limits` on embedded
    /// frames. The whole buffer must be consumed — trailing bytes are a
    /// framing error.
    pub fn decode(buf: &[u8], limits: &WireLimits) -> Result<Msg, CodecError> {
        let mut cur = ByteCursor::new(buf);
        let tag = cur.u8()?;
        let msg = match tag {
            1 => Msg::Hello {
                device: cur.u32()?,
                codec_id: cur.u32()?,
                codec_version: cur.u16()?,
            },
            2 => {
                let devices = cur.u32()?;
                let rounds = cur.u32()?;
                let staleness = cur.u32()?;
                let first_round = cur.u32()?;
                let ckpt_every = cur.u32()?;
                let state = get_opt_bytes(&mut cur)?;
                let err = match cur.u8()? {
                    0 => None,
                    1 => Some(get_str(&mut cur)?),
                    other => {
                        return Err(CodecError::MalformedHeader {
                            reason: format!("bad error flag {other}"),
                        })
                    }
                };
                Msg::HelloAck { devices, rounds, staleness, first_round, ckpt_every, state, err }
            }
            3 => Msg::StepStart {
                device: cur.u32()?,
                round: cur.u32()?,
                local: cur.u64()?,
            },
            4 => Msg::StepGo {
                wd: Frame::read_from(&mut cur, limits)?,
                rng: get_rng(&mut cur)?,
            },
            5 => Msg::Uplink {
                device: cur.u32()?,
                local: cur.u64()?,
                frame: Frame::read_from(&mut cur, limits)?,
                labels: get_f32s(&mut cur)?,
                mask: get_mask(&mut cur)?,
                up_nominal: cur.f64()?,
                rng: get_rng(&mut cur)?,
            },
            6 => Msg::Downlink {
                frame: Frame::read_from(&mut cur, limits)?,
                loss: cur.f32()?,
                correct: cur.f32()?,
                server_exec_s: cur.f64()?,
                down_nominal: cur.f64()?,
            },
            7 => Msg::Commit {
                device: cur.u32()?,
                round: cur.u32()?,
                local: cur.u64()?,
                grad: Frame::read_from(&mut cur, limits)?,
                report: get_report(&mut cur)?,
                state: get_opt_bytes(&mut cur)?,
            },
            8 => Msg::CommitAck,
            9 => Msg::FetchModel { device: cur.u32()? },
            10 => Msg::ModelReply { wd: Frame::read_from(&mut cur, limits)? },
            11 => Msg::Bye { device: cur.u32()? },
            12 => Msg::Abort { reason: get_str(&mut cur)? },
            other => {
                return Err(CodecError::MalformedHeader {
                    reason: format!("unknown message tag {other}"),
                })
            }
        };
        if !cur.is_empty() {
            return Err(CodecError::MalformedHeader {
                reason: format!("{} trailing bytes after message", cur.remaining()),
            });
        }
        Ok(msg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::wire::FrameKind;

    fn limits() -> WireLimits {
        WireLimits::new(1 << 16)
    }

    fn roundtrip(msg: &Msg) -> Msg {
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        Msg::decode(&buf, &limits()).unwrap_or_else(|e| panic!("{}: {e}", msg.name()))
    }

    #[test]
    fn control_messages_roundtrip() {
        match roundtrip(&Msg::Hello { device: 3, codec_id: 0xABCD, codec_version: 2 }) {
            Msg::Hello { device: 3, codec_id: 0xABCD, codec_version: 2 } => {}
            other => panic!("{other:?}"),
        }
        match roundtrip(&Msg::HelloAck {
            devices: 4,
            rounds: 9,
            staleness: 1,
            first_round: 6,
            ckpt_every: 5,
            state: Some(vec![0xDE, 0xAD, 0xBE]),
            err: Some("codec mismatch".into()),
        }) {
            Msg::HelloAck {
                devices: 4,
                rounds: 9,
                staleness: 1,
                first_round: 6,
                ckpt_every: 5,
                state: Some(st),
                err: Some(e),
            } => {
                assert_eq!(st, vec![0xDE, 0xAD, 0xBE]);
                assert_eq!(e, "codec mismatch");
            }
            other => panic!("{other:?}"),
        }
        match roundtrip(&Msg::HelloAck {
            devices: 2,
            rounds: 3,
            staleness: 0,
            first_round: 1,
            ckpt_every: 0,
            state: None,
            err: None,
        }) {
            Msg::HelloAck { first_round: 1, ckpt_every: 0, state: None, err: None, .. } => {}
            other => panic!("{other:?}"),
        }
        assert!(matches!(roundtrip(&Msg::CommitAck), Msg::CommitAck));
        assert!(matches!(roundtrip(&Msg::Bye { device: 2 }), Msg::Bye { device: 2 }));
        match roundtrip(&Msg::Abort { reason: "nope".into() }) {
            Msg::Abort { reason } => assert_eq!(reason, "nope"),
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn step_messages_roundtrip_with_rng_and_mask() {
        let wd = Frame::new(FrameKind::ModelSync, vec![1, 2, 3, 4], 32);
        let rng = Some(RngState { s: [1, u64::MAX, 3, 4], gauss: Some(-0.25) });
        match roundtrip(&Msg::StepGo { wd: wd.clone(), rng }) {
            Msg::StepGo { wd: w, rng: r } => {
                assert_eq!(w.payload, wd.payload);
                assert_eq!(r, rng);
            }
            other => panic!("{other:?}"),
        }

        let frame =
            Frame::new(FrameKind::FeaturesUp, vec![9, 8, 7], 23).with_codec(0x77, 1);
        let mask = GradMask::Columns { kept: vec![0, 5, 9], scale: vec![1.0, 2.0, 4.0] };
        let up = Msg::Uplink {
            device: 1,
            local: 42,
            frame: frame.clone(),
            labels: vec![0.0, 1.0, 0.0],
            mask,
            up_nominal: 123.5,
            rng: None,
        };
        match roundtrip(&up) {
            Msg::Uplink { device: 1, local: 42, frame: f, labels, mask, up_nominal, rng } => {
                assert_eq!(f.payload, frame.payload);
                assert_eq!((f.codec_id, f.codec_version), (0x77, 1));
                assert_eq!(labels, vec![0.0, 1.0, 0.0]);
                assert_eq!(up_nominal, 123.5);
                assert_eq!(rng, None);
                match mask {
                    GradMask::Columns { kept, scale } => {
                        assert_eq!(kept, vec![0, 5, 9]);
                        assert_eq!(scale, vec![1.0, 2.0, 4.0]);
                    }
                    other => panic!("{other:?}"),
                }
            }
            other => panic!("{other:?}"),
        }

        let report = StepReport {
            loss: 0.5,
            train_acc: 0.75,
            up_bits: 1000,
            down_bits: 2000,
            up_nominal: 990.0,
            down_nominal: 1990.0,
            step_s: 0.25,
            device_exec_s: 0.125,
        };
        let grad = Frame::new(FrameKind::ModelSync, vec![0u8; 8], 64);
        match roundtrip(&Msg::Commit {
            device: 2,
            round: 3,
            local: 11,
            grad,
            report: report.clone(),
            state: Some(vec![1, 2, 3, 4, 5]),
        }) {
            Msg::Commit { device: 2, round: 3, local: 11, report: r, state: Some(st), .. } => {
                assert_eq!(r, report);
                assert_eq!(st, vec![1, 2, 3, 4, 5]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn entries_mask_roundtrips() {
        let mask = GradMask::Entries(vec![vec![0, 2], vec![], vec![1]]);
        let mut buf = Vec::new();
        put_mask(&mut buf, &mask);
        match get_mask(&mut ByteCursor::new(&buf)).unwrap() {
            GradMask::Entries(rows) => {
                assert_eq!(rows, vec![vec![0, 2], vec![], vec![1]]);
            }
            other => panic!("{other:?}"),
        }
    }

    #[test]
    fn truncated_and_malformed_messages_are_typed_errors() {
        let msg = Msg::Uplink {
            device: 0,
            local: 7,
            frame: Frame::new(FrameKind::FeaturesUp, vec![1, 2, 3], 24),
            labels: vec![1.0, 0.0],
            mask: GradMask::All,
            up_nominal: 1.0,
            rng: Some(RngState { s: [1, 2, 3, 4], gauss: None }),
        };
        let mut buf = Vec::new();
        msg.encode(&mut buf);
        // every truncation point fails with a typed error, never a panic
        for cut in 0..buf.len() {
            assert!(
                Msg::decode(&buf[..cut], &limits()).is_err(),
                "cut={cut} decoded"
            );
        }
        // trailing garbage is rejected too
        buf.push(0xFF);
        assert!(matches!(
            Msg::decode(&buf, &limits()),
            Err(CodecError::MalformedHeader { .. })
        ));
        // unknown message tag
        assert!(matches!(
            Msg::decode(&[0xEE], &limits()),
            Err(CodecError::MalformedHeader { .. })
        ));
        // a label count far beyond the buffer must not allocate/overflow
        let mut evil = vec![5u8]; // Uplink tag
        evil.extend_from_slice(&0u32.to_le_bytes()); // device
        evil.extend_from_slice(&0u64.to_le_bytes()); // local
        Frame::new(FrameKind::FeaturesUp, vec![], 0).write_to(&mut evil);
        evil.extend_from_slice(&u32::MAX.to_le_bytes()); // label count
        assert!(matches!(
            Msg::decode(&evil, &limits()),
            Err(CodecError::TruncatedFrame { .. })
        ));
    }
}
