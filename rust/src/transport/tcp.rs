//! TCP transport backend: length-prefixed frames over real sockets.
//!
//! Every [`Msg`] crosses the socket as one wire frame of kind
//! [`FrameKind::Control`]: the fixed 15-byte header (tag, version, id,
//! payload-bit count — see `wire.rs`) followed by the message's byte
//! encoding. The receive path parses the header first, validates the
//! length prefix against [`WireLimits`] **before** allocating or reading
//! the payload, then decodes the message — malformed or hostile input
//! fails with a typed [`CodecError`], never a panic or an
//! attacker-controlled allocation.
//!
//! Errors that come from the socket itself (reset, EOF, refused) are
//! stringly tagged with the `"transport io"` prefix so the worker-side
//! rpc loop can tell a retriable transport fault apart from a protocol
//! rejection; client-side connections remember their dial address and can
//! `reconnect()` mid-training.
//!
//! `TCP_NODELAY` is set on every stream: the protocol is strict
//! request/reply with small control frames, exactly the pattern Nagle's
//! algorithm penalizes.

use std::io::{ErrorKind, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use crate::compression::error::CodecError;
use crate::transport::message::Msg;
use crate::transport::wire::{ByteCursor, Frame, FrameKind, WireLimits};
use crate::transport::Connection;
use crate::util::error::{Error, Result};

const IO: &str = "transport io";

fn io_err(what: &str, e: std::io::Error) -> Error {
    Error::msg(format!("{IO}: {what}: {e}"))
}

/// Returns true when `err` came from the socket layer (and a reconnect may
/// succeed) rather than from the protocol (which must not be retried).
pub fn is_io_error(err: &Error) -> bool {
    err.to_string().contains(IO)
}

/// Bind a listener with `SO_REUSEADDR` set, so an immediately-restarted PS
/// can rebind its port while connections from the previous incarnation
/// still sit in TIME_WAIT — a plain bind would die with AddrInUse and turn
/// every crash recovery into a port lottery. Falls back to the std bind
/// when the platform or address form rules the raw-socket path out.
pub fn bind_reuse(addr: &str) -> Result<std::net::TcpListener> {
    #[cfg(unix)]
    if let Ok(sa) = addr.parse::<std::net::SocketAddrV4>() {
        return bind_reuse_v4(sa);
    }
    std::net::TcpListener::bind(addr).map_err(|e| io_err("bind", e))
}

/// The crate is dependency-free, so the tiny libc surface this needs is
/// declared by hand: socket / setsockopt(SO_REUSEADDR) / bind / listen,
/// then the fd is adopted by `TcpListener`.
#[cfg(unix)]
fn bind_reuse_v4(sa: std::net::SocketAddrV4) -> Result<std::net::TcpListener> {
    use std::os::unix::io::FromRawFd;

    const AF_INET: i32 = 2;
    const SOCK_STREAM: i32 = 1;
    const SOL_SOCKET: i32 = 1;
    const SO_REUSEADDR: i32 = 2;

    /// `struct sockaddr_in`; port and address in network byte order.
    #[repr(C)]
    struct SockaddrIn {
        sin_family: u16,
        sin_port: u16,
        sin_addr: u32,
        sin_zero: [u8; 8],
    }

    extern "C" {
        fn socket(domain: i32, ty: i32, protocol: i32) -> i32;
        fn setsockopt(
            fd: i32,
            level: i32,
            name: i32,
            value: *const std::ffi::c_void,
            len: u32,
        ) -> i32;
        fn bind(fd: i32, addr: *const SockaddrIn, len: u32) -> i32;
        fn listen(fd: i32, backlog: i32) -> i32;
        fn close(fd: i32) -> i32;
    }

    let os_err = |what: &str| io_err(what, std::io::Error::last_os_error());
    unsafe {
        let fd = socket(AF_INET, SOCK_STREAM, 0);
        if fd < 0 {
            return Err(os_err("socket"));
        }
        let fail = |what: &str| {
            let e = os_err(what);
            close(fd);
            Err(e)
        };
        let one: i32 = 1;
        if setsockopt(fd, SOL_SOCKET, SO_REUSEADDR, &one as *const i32 as *const _, 4) != 0 {
            return fail("setsockopt(SO_REUSEADDR)");
        }
        let sin = SockaddrIn {
            sin_family: AF_INET as u16,
            sin_port: sa.port().to_be(),
            // from_ne_bytes: the u32's memory bytes ARE the octets, which
            // is exactly network byte order regardless of host endianness
            sin_addr: u32::from_ne_bytes(sa.ip().octets()),
            sin_zero: [0; 8],
        };
        if bind(fd, &sin, std::mem::size_of::<SockaddrIn>() as u32) != 0 {
            return fail("bind");
        }
        if listen(fd, 128) != 0 {
            return fail("listen");
        }
        Ok(std::net::TcpListener::from_raw_fd(fd))
    }
}

/// A TCP connection speaking the control-frame protocol.
pub struct TcpConn {
    stream: Option<TcpStream>,
    /// dial addresses; non-empty on client-side connections, which makes
    /// them reconnectable. `peers[peer_at]` is the live address; when it
    /// stops answering, `reconnect()` rotates through the fallbacks, which
    /// is how a device migrates to a standby PS mid-run. Server-accepted
    /// sockets have no dial address and cannot reconnect.
    peers: Vec<String>,
    peer_at: usize,
    limits: WireLimits,
    /// reusable tx scratch — one flat buffer per connection, written with a
    /// single `write_all` so a message is never interleaved on the socket
    buf: Vec<u8>,
    /// chaos hook: cut the socket right *after* each of these absolute
    /// send ordinals (1-based, counted across reconnects) — the request is
    /// delivered but the reply is lost, the exact fault the PS-side replay
    /// couriers exist for (exercised in tests/CI). Sorted ascending.
    fault_at_sends: Vec<u64>,
    sends: u64,
    /// applied to the stream on every (re)dial; `None` = block forever
    recv_deadline: Option<Duration>,
}

impl TcpConn {
    /// Dial `addr` (client side — reconnectable).
    pub fn connect(addr: &str, limits: WireLimits) -> Result<TcpConn> {
        Self::connect_any(std::slice::from_ref(&addr.to_string()), limits)
    }

    /// Dial the first reachable address in `addrs`; the others stay armed
    /// as fallbacks that `reconnect()` rotates through (device migration).
    pub fn connect_any(addrs: &[String], limits: WireLimits) -> Result<TcpConn> {
        if addrs.is_empty() {
            return Err(Error::msg("connect_any wants at least one address"));
        }
        let mut last = None;
        for (at, addr) in addrs.iter().enumerate() {
            match Self::dial(addr) {
                Ok(stream) => {
                    return Ok(TcpConn {
                        stream: Some(stream),
                        peers: addrs.to_vec(),
                        peer_at: at,
                        limits,
                        buf: Vec::new(),
                        fault_at_sends: Vec::new(),
                        sends: 0,
                        recv_deadline: None,
                    })
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap())
    }

    /// Adopt an accepted socket (server side — not reconnectable).
    pub fn from_stream(stream: TcpStream, limits: WireLimits) -> TcpConn {
        let _ = stream.set_nodelay(true);
        TcpConn {
            stream: Some(stream),
            peers: Vec::new(),
            peer_at: 0,
            limits,
            buf: Vec::new(),
            fault_at_sends: Vec::new(),
            sends: 0,
            recv_deadline: None,
        }
    }

    fn dial(addr: &str) -> Result<TcpStream> {
        let stream = TcpStream::connect(addr).map_err(|e| io_err("connect", e))?;
        stream.set_nodelay(true).map_err(|e| io_err("set_nodelay", e))?;
        Ok(stream)
    }

    /// Arm the chaos hook: the link is cut immediately after the `n`-th
    /// send from now *succeeds* — the peer receives the request, the reply
    /// is lost, and the next operation here fails with a transport io
    /// error, as if the network died mid-exchange. One-shot.
    pub fn set_fault_after_sends(&mut self, n: u64) {
        self.fault_at_sends = vec![self.sends + n];
    }

    /// Arm multiple cut points at absolute send ordinals (1-based across
    /// the connection's whole life, reconnects included; Hello = send #1).
    /// The scenario engine's `cut[dev=K,send=N]` clauses land here.
    pub fn set_fault_at_sends(&mut self, at: &[u64]) {
        self.fault_at_sends = at.to_vec();
        self.fault_at_sends.sort_unstable();
        self.fault_at_sends.dedup();
    }

    fn stream(&mut self) -> Result<&mut TcpStream> {
        self.stream
            .as_mut()
            .ok_or_else(|| Error::msg(format!("{IO}: connection is down (reconnect required)")))
    }
}

impl Connection for TcpConn {
    fn send(&mut self, msg: Msg) -> Result<()> {
        // serialize into the connection-owned scratch: message bytes become
        // the payload of one Control frame
        let mut payload = std::mem::take(&mut self.buf);
        payload.clear();
        msg.encode(&mut payload);
        let bits = payload.len() as u64 * 8;
        let frame = Frame::new(FrameKind::Control, payload, bits);
        let mut out = Vec::with_capacity(frame.wire_len());
        frame.write_to(&mut out);
        self.buf = frame.payload; // reclaim the scratch
        let res = self.stream()?.write_all(&out).map_err(|e| io_err("send", e));
        if res.is_ok() {
            self.sends += 1;
            while matches!(self.fault_at_sends.first(), Some(&n) if n <= self.sends) {
                // chaos hook: the request just left, now the link dies —
                // the pending reply is lost and the next recv/send fails
                self.fault_at_sends.remove(0);
                self.stream = None;
            }
        } else {
            self.stream = None;
        }
        res
    }

    fn recv(&mut self) -> Result<Msg> {
        let limits = self.limits;
        let stream = self.stream()?;
        let mut header = [0u8; Frame::HEADER_BYTES];
        if let Err(e) = stream.read_exact(&mut header) {
            let e = if e.kind() == ErrorKind::UnexpectedEof {
                std::io::Error::new(ErrorKind::UnexpectedEof, "peer closed the connection")
            } else {
                e
            };
            self.stream = None;
            return Err(io_err("recv header", e));
        }
        // parse + validate the header before touching the payload
        let mut cur = ByteCursor::new(&header);
        let kind = FrameKind::from_tag(cur.u8()?)?;
        let codec_version = cur.u16()?;
        let codec_id = cur.u32()?;
        let payload_bits = cur.u64()?;
        let payload_len = Frame::check_payload_len(payload_bits, &limits)?;
        if kind != FrameKind::Control {
            return Err(Error::msg(format!(
                "protocol error: expected a Control frame, got {kind:?} \
                 (codec {codec_id:#x} v{codec_version})"
            )));
        }
        let mut payload = vec![0u8; payload_len];
        if let Err(e) = self.stream()?.read_exact(&mut payload) {
            self.stream = None;
            return Err(io_err("recv payload", e));
        }
        let msg = Msg::decode(&payload, &limits)?;
        Ok(msg)
    }

    fn reconnect(&mut self) -> Result<()> {
        if self.peers.is_empty() {
            return Err(Error::msg("server-side connection cannot reconnect"));
        }
        // brief pause: the far end needs a moment to tear down the dead
        // handler and get back to accept()
        std::thread::sleep(Duration::from_millis(10));
        // try the live peer first, then rotate through the fallbacks; a
        // refused dial hands the device to the next PS on the list
        let mut last = None;
        for i in 0..self.peers.len() {
            let at = (self.peer_at + i) % self.peers.len();
            match Self::dial(&self.peers[at]) {
                Ok(stream) => {
                    if let Some(d) = self.recv_deadline {
                        let _ = stream.set_read_timeout(Some(d));
                    }
                    self.peer_at = at;
                    self.stream = Some(stream);
                    return Ok(());
                }
                Err(e) => last = Some(e),
            }
        }
        Err(last.unwrap())
    }

    fn is_reconnectable(&self) -> bool {
        !self.peers.is_empty()
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        // Duration::ZERO means "no timeout" to set_read_timeout callers but
        // is an invalid argument to the OS call — normalize it to None
        self.recv_deadline = deadline.filter(|d| !d.is_zero());
        if let Some(s) = self.stream.as_ref() {
            let _ = s.set_read_timeout(self.recv_deadline);
        }
    }

    fn inject_cut(&mut self) {
        // a deadline expiry or cut leaves the frame stream unsynchronized,
        // so the stream is dropped wholesale; the client re-dials
        self.stream = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::net::TcpListener;

    fn limits() -> WireLimits {
        WireLimits::new(1 << 16)
    }

    #[test]
    fn loopback_roundtrip_and_reconnect() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let server = std::thread::spawn(move || {
            // serve three sequential connections, echoing device ids; a
            // reply may race the client-side chaos cut, so send errors are
            // tolerated (the client retries on a fresh connection)
            for _ in 0..3 {
                let (sock, _) = listener.accept().unwrap();
                let mut conn = TcpConn::from_stream(sock, limits());
                assert!(!conn.is_reconnectable());
                while let Ok(msg) = conn.recv() {
                    match msg {
                        Msg::Hello { device, .. } => {
                            let _ = conn.send(Msg::HelloAck {
                                devices: device + 1,
                                rounds: 0,
                                staleness: 0,
                                first_round: 1,
                                ckpt_every: 0,
                                state: None,
                                err: None,
                            });
                        }
                        Msg::Bye { .. } => break,
                        other => panic!("{other:?}"),
                    }
                }
            }
        });

        let mut conn = TcpConn::connect(&addr, limits()).unwrap();
        assert!(conn.is_reconnectable());
        conn.send(Msg::Hello { device: 4, codec_id: 1, codec_version: 1 }).unwrap();
        match conn.recv().unwrap() {
            Msg::HelloAck { devices: 5, .. } => {}
            other => panic!("{other:?}"),
        }
        conn.send(Msg::Bye { device: 4 }).unwrap();

        // cut the link right after the next request is delivered: the reply
        // is lost mid-air, then resume on a fresh socket
        conn.set_fault_after_sends(1);
        conn.send(Msg::Hello { device: 8, codec_id: 1, codec_version: 1 }).unwrap();
        let err = conn.recv().unwrap_err();
        assert!(is_io_error(&err), "{err}");
        conn.reconnect().unwrap();
        conn.send(Msg::Hello { device: 9, codec_id: 1, codec_version: 1 }).unwrap();
        match conn.recv().unwrap() {
            Msg::HelloAck { devices: 10, .. } => {}
            other => panic!("{other:?}"),
        }
        conn.send(Msg::Bye { device: 9 }).unwrap();
        server.join().unwrap();
    }

    #[test]
    fn reconnect_rotates_to_a_fallback_address() {
        // a dead address (bound once, then released -> refused) and a live
        // server: the exact shape of a device migrating off a crashed PS
        let dead = {
            let l = TcpListener::bind("127.0.0.1:0").unwrap();
            l.local_addr().unwrap().to_string()
        };
        let live_listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let live = live_listener.local_addr().unwrap().to_string();
        let srv = std::thread::spawn(move || {
            // two sequential connections: the fallback dial, then the
            // post-cut reconnect
            for _ in 0..2 {
                let (sock, _) = live_listener.accept().unwrap();
                let mut conn = TcpConn::from_stream(sock, limits());
                while let Ok(msg) = conn.recv() {
                    match msg {
                        Msg::Hello { device, .. } => {
                            let _ = conn.send(Msg::HelloAck {
                                devices: device + 1,
                                rounds: 0,
                                staleness: 0,
                                first_round: 1,
                                ckpt_every: 0,
                                state: None,
                                err: None,
                            });
                        }
                        Msg::Bye { .. } => break,
                        other => panic!("{other:?}"),
                    }
                }
            }
        });

        let addrs = vec![dead, live];
        let mut conn = TcpConn::connect_any(&addrs, limits()).unwrap();
        assert!(conn.is_reconnectable());
        conn.send(Msg::Hello { device: 1, codec_id: 1, codec_version: 1 }).unwrap();
        match conn.recv().unwrap() {
            Msg::HelloAck { devices: 2, .. } => {}
            other => panic!("{other:?}"),
        }
        conn.send(Msg::Bye { device: 1 }).unwrap();

        // cut the link: reconnect must stay on the live peer it rotated to,
        // not start over from the dead head of the list and give up
        conn.inject_cut();
        conn.reconnect().unwrap();
        conn.send(Msg::Hello { device: 2, codec_id: 1, codec_version: 1 }).unwrap();
        match conn.recv().unwrap() {
            Msg::HelloAck { devices: 3, .. } => {}
            other => panic!("{other:?}"),
        }
        conn.send(Msg::Bye { device: 2 }).unwrap();
        srv.join().unwrap();
    }

    #[test]
    fn bind_reuse_rebinds_a_port_with_lingering_connections() {
        let l1 = bind_reuse("127.0.0.1:0").unwrap();
        let addr = l1.local_addr().unwrap().to_string();
        // establish a connection and close the server side first, leaving
        // the 4-tuple in TIME_WAIT on the listener's port — the state a
        // crashed-and-restarted PS has to rebind through
        let client = std::thread::spawn({
            let addr = addr.clone();
            move || {
                let mut c = TcpStream::connect(addr).unwrap();
                let mut b = [0u8; 1];
                let _ = c.read(&mut b); // until the server closes
            }
        });
        let (sock, _) = l1.accept().unwrap();
        drop(sock);
        client.join().unwrap();
        drop(l1);
        let l2 = bind_reuse(&addr).expect("immediate rebind must not AddrInUse");
        assert_eq!(l2.local_addr().unwrap().to_string(), addr);
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocation() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let evil = std::thread::spawn(move || {
            let (mut sock, _) = listener.accept().unwrap();
            // hand-rolled hostile header: Control tag, absurd bit count
            let mut hdr = vec![4u8];
            hdr.extend_from_slice(&1u16.to_le_bytes());
            hdr.extend_from_slice(&0u32.to_le_bytes());
            hdr.extend_from_slice(&u64::MAX.to_le_bytes());
            sock.write_all(&hdr).unwrap();
            sock.flush().unwrap();
            // keep the socket open so the client error is the validation,
            // not an EOF race
            let mut sink = [0u8; 1];
            let _ = sock.read(&mut sink);
        });
        let mut conn = TcpConn::connect(&addr, limits()).unwrap();
        let err = conn.recv().unwrap_err().to_string();
        assert!(err.contains("too large"), "{err}");
        drop(conn);
        evil.join().unwrap();
    }

    #[test]
    fn peer_eof_is_a_transport_io_error() {
        let listener = TcpListener::bind("127.0.0.1:0").unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let srv = std::thread::spawn(move || {
            let (sock, _) = listener.accept().unwrap();
            drop(sock);
        });
        let mut conn = TcpConn::connect(&addr, limits()).unwrap();
        srv.join().unwrap();
        let err = conn.recv().unwrap_err();
        assert!(is_io_error(&err), "{err}");
    }
}
