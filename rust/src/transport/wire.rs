//! Wire frames: what actually crosses the link.
//!
//! A frame is an opaque bit-exact payload (produced by a codec in
//! `compression::*`) plus a small fixed header. The *payload bit length* is
//! the paper's communication-overhead quantity; the header models framing
//! cost and is reported separately so tables can match the paper's
//! accounting (which counts payload bits only).
//!
//! Frames also have a real byte encoding ([`Frame::write_to`] /
//! [`Frame::read_from`]): a 15-byte header — tag (u8), codec wire version
//! (u16 LE), codec id (u32 LE), payload bit length (u64 LE) — followed by
//! `ceil(payload_bits / 8)` payload bytes. The encoded size is exactly
//! `HEADER_BITS + payload_bits` rounded up to bytes, so the byte stream
//! costs what the accounting model says it costs. Decoding is hardened:
//! unknown tags, length prefixes over the receiver's [`WireLimits`] budget,
//! truncated headers/payloads and inconsistent length fields all return a
//! typed [`CodecError`] instead of panicking or over-allocating.

use crate::compression::error::CodecError;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Device -> PS: compressed intermediate feature matrix (+ index vector).
    FeaturesUp,
    /// PS -> device: compressed intermediate gradient matrix.
    GradientsDown,
    /// Device-side model / gradient hand-off (w_d down, ∇w_d up).
    ModelSync,
    /// Transport control plane: a serialized protocol message
    /// (`transport::message::Msg`) rides as the payload.
    Control,
}

impl FrameKind {
    pub fn tag(self) -> u8 {
        match self {
            FrameKind::FeaturesUp => 1,
            FrameKind::GradientsDown => 2,
            FrameKind::ModelSync => 3,
            FrameKind::Control => 4,
        }
    }

    pub fn from_tag(tag: u8) -> Result<FrameKind, CodecError> {
        match tag {
            1 => Ok(FrameKind::FeaturesUp),
            2 => Ok(FrameKind::GradientsDown),
            3 => Ok(FrameKind::ModelSync),
            4 => Ok(FrameKind::Control),
            other => Err(CodecError::MalformedHeader {
                reason: format!("unknown frame tag {other}"),
            }),
        }
    }
}

/// Receiver-side decode budget: the largest payload a peer is allowed to
/// declare. Derived from the model preset by the coordinator (features,
/// gradients and parameter blobs all fit with headroom); a malicious or
/// corrupt length prefix beyond it is rejected before any allocation.
#[derive(Debug, Clone, Copy)]
pub struct WireLimits {
    pub max_payload_bytes: u64,
}

impl WireLimits {
    pub fn new(max_payload_bytes: u64) -> WireLimits {
        WireLimits { max_payload_bytes }
    }

    /// A budget sized for a model preset: the largest of the uncompressed
    /// feature matrix, the parameter blobs and the label block, with 4x
    /// headroom for codec overhead plus 1 MiB of fixed slack.
    pub fn for_shapes(batch: usize, dbar: usize, nd_params: usize, classes: usize) -> WireLimits {
        let feats = (batch * dbar * 4) as u64;
        let params = (nd_params * 4) as u64;
        let labels = (batch * classes * 4) as u64;
        WireLimits { max_payload_bytes: 4 * feats.max(params).max(labels) + (1 << 20) }
    }
}

/// Bounds-checked little-endian reader over a byte buffer. Every accessor
/// returns [`CodecError::TruncatedFrame`] instead of panicking when the
/// buffer runs dry, so malformed wire input surfaces as a typed error.
pub struct ByteCursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> ByteCursor<'a> {
    pub fn new(buf: &'a [u8]) -> ByteCursor<'a> {
        ByteCursor { buf, pos: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.buf.len() - self.pos
    }

    pub fn is_empty(&self) -> bool {
        self.remaining() == 0
    }

    pub fn take(&mut self, n: usize) -> Result<&'a [u8], CodecError> {
        if self.remaining() < n {
            return Err(CodecError::TruncatedFrame {
                needed: n as u64,
                available: self.remaining() as u64,
            });
        }
        let out = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8, CodecError> {
        Ok(self.take(1)?[0])
    }

    pub fn u16(&mut self) -> Result<u16, CodecError> {
        let b = self.take(2)?;
        Ok(u16::from_le_bytes([b[0], b[1]]))
    }

    pub fn u32(&mut self) -> Result<u32, CodecError> {
        let b = self.take(4)?;
        Ok(u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
    }

    pub fn u64(&mut self) -> Result<u64, CodecError> {
        let b = self.take(8)?;
        Ok(u64::from_le_bytes([b[0], b[1], b[2], b[3], b[4], b[5], b[6], b[7]]))
    }

    pub fn f32(&mut self) -> Result<f32, CodecError> {
        Ok(f32::from_bits(self.u32()?))
    }

    pub fn f64(&mut self) -> Result<f64, CodecError> {
        Ok(f64::from_bits(self.u64()?))
    }
}

#[derive(Debug, Clone)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
    /// Exact number of meaningful payload bits (payload.len()*8 rounds up).
    pub payload_bits: u64,
    /// Versioned codec id: FNV-1a of the emitting codec's canonical name
    /// (`compression::codec_id`). 0 = unstamped (control frames). Decoders
    /// reject frames stamped by a different codec instead of misparsing.
    pub codec_id: u32,
    /// Wire-format version of the emitting codec (0 = unstamped).
    pub codec_version: u16,
}

impl Frame {
    pub fn new(kind: FrameKind, payload: Vec<u8>, payload_bits: u64) -> Frame {
        debug_assert!(payload_bits <= payload.len() as u64 * 8);
        debug_assert!(payload.len() as u64 * 8 < payload_bits + 8);
        Frame { kind, payload, payload_bits, codec_id: 0, codec_version: 0 }
    }

    /// Stamp the self-describing codec header (`Codec::stamp` calls this).
    pub fn with_codec(mut self, codec_id: u32, codec_version: u16) -> Frame {
        self.codec_id = codec_id;
        self.codec_version = codec_version;
        self
    }

    /// Header cost: 8-bit tag + 16-bit codec wire version + 32-bit codec id
    /// + 64-bit length field — exactly the 15 bytes `write_to` emits.
    pub const HEADER_BITS: u64 = 120;

    /// Header size of the byte encoding (`HEADER_BITS / 8`).
    pub const HEADER_BYTES: usize = 15;

    pub fn total_bits(&self) -> u64 {
        Self::HEADER_BITS + self.payload_bits
    }

    /// Size of the byte encoding: header + payload bytes.
    pub fn wire_len(&self) -> usize {
        Self::HEADER_BYTES + self.payload.len()
    }

    /// Append the byte encoding (15-byte header + payload) to `out`.
    pub fn write_to(&self, out: &mut Vec<u8>) {
        out.reserve(self.wire_len());
        out.push(self.kind.tag());
        out.extend_from_slice(&self.codec_version.to_le_bytes());
        out.extend_from_slice(&self.codec_id.to_le_bytes());
        out.extend_from_slice(&self.payload_bits.to_le_bytes());
        out.extend_from_slice(&self.payload);
    }

    /// Decode one frame from the cursor, enforcing `limits`. Rejects
    /// unknown tags, oversized length prefixes, truncated headers/payloads
    /// and bit/byte length mismatches with a typed [`CodecError`].
    pub fn read_from(cur: &mut ByteCursor<'_>, limits: &WireLimits) -> Result<Frame, CodecError> {
        let kind = FrameKind::from_tag(cur.u8()?)?;
        let codec_version = cur.u16()?;
        let codec_id = cur.u32()?;
        let payload_bits = cur.u64()?;
        let payload_bytes = Self::check_payload_len(payload_bits, limits)?;
        let payload = cur.take(payload_bytes)?.to_vec();
        Ok(Frame { kind, payload, payload_bits, codec_id, codec_version })
    }

    /// Validate a declared payload bit length against the receiver budget
    /// and return the byte count it implies. Shared by [`Frame::read_from`]
    /// and the streaming TCP receive path (which must size-check the length
    /// prefix *before* reading the payload off the socket).
    pub fn check_payload_len(
        payload_bits: u64,
        limits: &WireLimits,
    ) -> Result<usize, CodecError> {
        // div_ceil without overflow on adversarial u64::MAX prefixes
        let payload_bytes = payload_bits / 8 + u64::from(payload_bits % 8 != 0);
        if payload_bytes > limits.max_payload_bytes {
            return Err(CodecError::FrameTooLarge {
                bytes: payload_bytes,
                max: limits.max_payload_bytes,
            });
        }
        Ok(payload_bytes as usize)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_bit_accounting() {
        let f = Frame::new(FrameKind::FeaturesUp, vec![0xFF, 0x01], 9);
        assert_eq!(f.payload_bits, 9);
        assert_eq!(f.total_bits(), 9 + Frame::HEADER_BITS);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn frame_rejects_inconsistent_bits() {
        // 2 bytes but claims 20 bits of payload in 1 byte? 20 > 16
        let _ = Frame::new(FrameKind::ModelSync, vec![0u8], 20);
    }

    #[test]
    fn codec_stamp_sets_header_not_payload() {
        let f = Frame::new(FrameKind::FeaturesUp, vec![0xAB, 0x01], 10);
        assert_eq!((f.codec_id, f.codec_version), (0, 0));
        let stamped = f.clone().with_codec(0xDEAD_BEEF, 3);
        assert_eq!(stamped.codec_id, 0xDEAD_BEEF);
        assert_eq!(stamped.codec_version, 3);
        assert_eq!(stamped.payload, f.payload);
        assert_eq!(stamped.payload_bits, f.payload_bits);
        assert_eq!(stamped.total_bits(), f.total_bits());
    }

    #[test]
    fn kinds_have_distinct_tags() {
        let kinds = [
            FrameKind::FeaturesUp,
            FrameKind::GradientsDown,
            FrameKind::ModelSync,
            FrameKind::Control,
        ];
        let mut t: Vec<u8> = kinds.iter().map(|k| k.tag()).collect();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), kinds.len());
        for k in kinds {
            assert_eq!(FrameKind::from_tag(k.tag()).unwrap(), k);
        }
        assert!(FrameKind::from_tag(0).is_err());
        assert!(FrameKind::from_tag(5).is_err());
    }

    #[test]
    fn byte_encoding_roundtrip_and_size() {
        let limits = WireLimits::new(1 << 16);
        let f = Frame::new(FrameKind::GradientsDown, vec![0xAB, 0xCD, 0x01], 17)
            .with_codec(0x1234_5678, 9);
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        assert_eq!(buf.len(), f.wire_len());
        assert_eq!(buf.len() as u64 * 8, Frame::HEADER_BITS + 24);
        let mut cur = ByteCursor::new(&buf);
        let g = Frame::read_from(&mut cur, &limits).unwrap();
        assert!(cur.is_empty());
        assert_eq!(g.kind, f.kind);
        assert_eq!(g.payload, f.payload);
        assert_eq!(g.payload_bits, f.payload_bits);
        assert_eq!((g.codec_id, g.codec_version), (f.codec_id, f.codec_version));
    }

    #[test]
    fn oversized_length_prefix_is_rejected_before_allocation() {
        let limits = WireLimits::new(64);
        let f = Frame::new(FrameKind::ModelSync, vec![0u8; 100], 800);
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        let err = Frame::read_from(&mut ByteCursor::new(&buf), &limits).unwrap_err();
        assert!(matches!(err, CodecError::FrameTooLarge { bytes: 100, max: 64 }));
        // an adversarial u64::MAX bit count must not overflow the byte math
        assert!(matches!(
            Frame::check_payload_len(u64::MAX, &limits),
            Err(CodecError::FrameTooLarge { .. })
        ));
    }

    #[test]
    fn truncation_at_every_byte_is_a_typed_error() {
        let limits = WireLimits::new(1 << 16);
        let f = Frame::new(FrameKind::FeaturesUp, vec![1, 2, 3, 4], 32).with_codec(7, 1);
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        for cut in 0..buf.len() {
            let err = Frame::read_from(&mut ByteCursor::new(&buf[..cut]), &limits)
                .expect_err("truncated frame must not decode");
            assert!(
                matches!(err, CodecError::TruncatedFrame { .. }),
                "cut={cut}: {err}"
            );
        }
        assert!(Frame::read_from(&mut ByteCursor::new(&buf), &limits).is_ok());
    }

    #[test]
    fn unknown_tag_is_malformed() {
        let limits = WireLimits::new(64);
        let f = Frame::new(FrameKind::FeaturesUp, vec![0u8], 8);
        let mut buf = Vec::new();
        f.write_to(&mut buf);
        buf[0] = 0xEE;
        let err = Frame::read_from(&mut ByteCursor::new(&buf), &limits).unwrap_err();
        assert!(matches!(err, CodecError::MalformedHeader { .. }), "{err}");
    }
}
