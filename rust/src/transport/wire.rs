//! Wire frames: what actually crosses the simulated link.
//!
//! A frame is an opaque bit-exact payload (produced by a codec in
//! `compression::*`) plus a small fixed header. The *payload bit length* is
//! the paper's communication-overhead quantity; the header models framing
//! cost and is reported separately so tables can match the paper's
//! accounting (which counts payload bits only).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Device -> PS: compressed intermediate feature matrix (+ index vector).
    FeaturesUp,
    /// PS -> device: compressed intermediate gradient matrix.
    GradientsDown,
    /// Device-side model / optimizer state hand-off (round-robin).
    ModelSync,
}

impl FrameKind {
    pub fn tag(self) -> u8 {
        match self {
            FrameKind::FeaturesUp => 1,
            FrameKind::GradientsDown => 2,
            FrameKind::ModelSync => 3,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
    /// Exact number of meaningful payload bits (payload.len()*8 rounds up).
    pub payload_bits: u64,
    /// Versioned codec id: FNV-1a of the emitting codec's canonical name
    /// (`compression::codec_id`). 0 = unstamped (control frames). Decoders
    /// reject frames stamped by a different codec instead of misparsing.
    pub codec_id: u32,
    /// Wire-format version of the emitting codec (0 = unstamped).
    pub codec_version: u16,
}

impl Frame {
    pub fn new(kind: FrameKind, payload: Vec<u8>, payload_bits: u64) -> Frame {
        debug_assert!(payload_bits <= payload.len() as u64 * 8);
        debug_assert!(payload.len() as u64 * 8 < payload_bits + 8);
        Frame { kind, payload, payload_bits, codec_id: 0, codec_version: 0 }
    }

    /// Stamp the self-describing codec header (`Codec::stamp` calls this).
    pub fn with_codec(mut self, codec_id: u32, codec_version: u16) -> Frame {
        self.codec_id = codec_id;
        self.codec_version = codec_version;
        self
    }

    /// Header cost: 8-bit tag + 64-bit length field + 32-bit codec id +
    /// 16-bit codec wire version.
    pub const HEADER_BITS: u64 = 120;

    pub fn total_bits(&self) -> u64 {
        Self::HEADER_BITS + self.payload_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_bit_accounting() {
        let f = Frame::new(FrameKind::FeaturesUp, vec![0xFF, 0x01], 9);
        assert_eq!(f.payload_bits, 9);
        assert_eq!(f.total_bits(), 9 + Frame::HEADER_BITS);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn frame_rejects_inconsistent_bits() {
        // 2 bytes but claims 20 bits of payload in 1 byte? 20 > 16
        let _ = Frame::new(FrameKind::ModelSync, vec![0u8], 20);
    }

    #[test]
    fn codec_stamp_sets_header_not_payload() {
        let f = Frame::new(FrameKind::FeaturesUp, vec![0xAB, 0x01], 10);
        assert_eq!((f.codec_id, f.codec_version), (0, 0));
        let stamped = f.clone().with_codec(0xDEAD_BEEF, 3);
        assert_eq!(stamped.codec_id, 0xDEAD_BEEF);
        assert_eq!(stamped.codec_version, 3);
        assert_eq!(stamped.payload, f.payload);
        assert_eq!(stamped.payload_bits, f.payload_bits);
        assert_eq!(stamped.total_bits(), f.total_bits());
    }

    #[test]
    fn kinds_have_distinct_tags() {
        let tags = [
            FrameKind::FeaturesUp.tag(),
            FrameKind::GradientsDown.tag(),
            FrameKind::ModelSync.tag(),
        ];
        let mut t = tags.to_vec();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 3);
    }
}
