//! Wire frames: what actually crosses the simulated link.
//!
//! A frame is an opaque bit-exact payload (produced by a codec in
//! `compression::*`) plus a small fixed header. The *payload bit length* is
//! the paper's communication-overhead quantity; the header models framing
//! cost and is reported separately so tables can match the paper's
//! accounting (which counts payload bits only).

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameKind {
    /// Device -> PS: compressed intermediate feature matrix (+ index vector).
    FeaturesUp,
    /// PS -> device: compressed intermediate gradient matrix.
    GradientsDown,
    /// Device-side model / optimizer state hand-off (round-robin).
    ModelSync,
}

impl FrameKind {
    pub fn tag(self) -> u8 {
        match self {
            FrameKind::FeaturesUp => 1,
            FrameKind::GradientsDown => 2,
            FrameKind::ModelSync => 3,
        }
    }
}

#[derive(Debug, Clone)]
pub struct Frame {
    pub kind: FrameKind,
    pub payload: Vec<u8>,
    /// Exact number of meaningful payload bits (payload.len()*8 rounds up).
    pub payload_bits: u64,
}

impl Frame {
    pub fn new(kind: FrameKind, payload: Vec<u8>, payload_bits: u64) -> Frame {
        debug_assert!(payload_bits <= payload.len() as u64 * 8);
        debug_assert!(payload.len() as u64 * 8 < payload_bits + 8);
        Frame { kind, payload, payload_bits }
    }

    /// Header cost: 8-bit tag + 64-bit length field.
    pub const HEADER_BITS: u64 = 72;

    pub fn total_bits(&self) -> u64 {
        Self::HEADER_BITS + self.payload_bits
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_bit_accounting() {
        let f = Frame::new(FrameKind::FeaturesUp, vec![0xFF, 0x01], 9);
        assert_eq!(f.payload_bits, 9);
        assert_eq!(f.total_bits(), 9 + Frame::HEADER_BITS);
    }

    #[test]
    #[should_panic]
    #[cfg(debug_assertions)]
    fn frame_rejects_inconsistent_bits() {
        // 2 bytes but claims 20 bits of payload in 1 byte? 20 > 16
        let _ = Frame::new(FrameKind::ModelSync, vec![0u8], 20);
    }

    #[test]
    fn kinds_have_distinct_tags() {
        let tags = [
            FrameKind::FeaturesUp.tag(),
            FrameKind::GradientsDown.tag(),
            FrameKind::ModelSync.tag(),
        ];
        let mut t = tags.to_vec();
        t.sort_unstable();
        t.dedup();
        assert_eq!(t.len(), 3);
    }
}
