//! Simulated link: capacity/latency model + per-direction bit accounting.

use super::wire::Frame;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Direction {
    Uplink,
    Downlink,
}

/// A (device <-> PS) link. Transfer time = latency + bits / capacity.
///
/// Each device worker owns its own `Link`; the parameter-server-side view is
/// the sum of the per-device reports ([`LinkReport::aggregate`]).
///
/// Feature/gradient traffic (the paper's communication-overhead quantity)
/// and model-sync traffic (w_d snapshots down, ∇w_d hand-offs up — which a
/// real wire also carries) are accounted in separate counters so tables can
/// still quote the paper's numbers while the wire totals stay honest.
#[derive(Debug, Clone)]
pub struct Link {
    pub capacity_bps: f64,
    pub latency_s: f64,
    up_bits: u64,
    down_bits: u64,
    up_frames: u64,
    down_frames: u64,
    sync_up_bits: u64,
    sync_down_bits: u64,
    sync_up_frames: u64,
    sync_down_frames: u64,
    pub elapsed_s: f64,
}

#[derive(Debug, Clone, Copy, Default)]
pub struct LinkReport {
    pub up_bits: u64,
    pub down_bits: u64,
    pub up_frames: u64,
    pub down_frames: u64,
    /// `ModelSync` traffic, counted apart from the paper's feature/gradient
    /// overhead: ∇w_d hand-offs (uplink) ...
    pub sync_up_bits: u64,
    /// ... and w_d snapshots (downlink).
    pub sync_down_bits: u64,
    pub sync_up_frames: u64,
    pub sync_down_frames: u64,
    pub elapsed_s: f64,
    /// Transport-fault retries the worker performed on this link (seeded
    /// exponential backoff; 0 on a calm run).
    pub retry_attempts: u64,
    /// Wall time spent sleeping in backoff before those retries.
    pub backoff_s: f64,
}

impl LinkReport {
    /// Fold another report into this one (field-wise sum).
    pub fn merge(&mut self, other: &LinkReport) {
        self.up_bits += other.up_bits;
        self.down_bits += other.down_bits;
        self.up_frames += other.up_frames;
        self.down_frames += other.down_frames;
        self.sync_up_bits += other.sync_up_bits;
        self.sync_down_bits += other.sync_down_bits;
        self.sync_up_frames += other.sync_up_frames;
        self.sync_down_frames += other.sync_down_frames;
        self.elapsed_s += other.elapsed_s;
        self.retry_attempts += other.retry_attempts;
        self.backoff_s += other.backoff_s;
    }

    /// Aggregate per-device reports into the PS-side total, in device order
    /// (so the f64 time sum is deterministic across runs).
    pub fn aggregate(reports: impl IntoIterator<Item = LinkReport>) -> LinkReport {
        let mut total = LinkReport::default();
        for r in reports {
            total.merge(&r);
        }
        total
    }
}

impl Link {
    pub fn new(capacity_bps: f64, latency_s: f64) -> Link {
        assert!(capacity_bps > 0.0);
        Link {
            capacity_bps,
            latency_s,
            up_bits: 0,
            down_bits: 0,
            up_frames: 0,
            down_frames: 0,
            sync_up_bits: 0,
            sync_down_bits: 0,
            sync_up_frames: 0,
            sync_down_frames: 0,
            elapsed_s: 0.0,
        }
    }

    /// "Transmit" a feature/gradient frame; returns the modeled transfer
    /// time in seconds.
    pub fn transmit(&mut self, dir: Direction, frame: &Frame) -> f64 {
        let bits = frame.total_bits();
        match dir {
            Direction::Uplink => {
                self.up_bits += bits;
                self.up_frames += 1;
            }
            Direction::Downlink => {
                self.down_bits += bits;
                self.down_frames += 1;
            }
        }
        self.clock(bits)
    }

    /// "Transmit" a `ModelSync` frame (w_d snapshot down / ∇w_d up). Same
    /// time model, separate counters — the paper's overhead tables count
    /// feature/gradient bits only.
    pub fn transmit_sync(&mut self, dir: Direction, frame: &Frame) -> f64 {
        let bits = frame.total_bits();
        match dir {
            Direction::Uplink => {
                self.sync_up_bits += bits;
                self.sync_up_frames += 1;
            }
            Direction::Downlink => {
                self.sync_down_bits += bits;
                self.sync_down_frames += 1;
            }
        }
        self.clock(bits)
    }

    fn clock(&mut self, bits: u64) -> f64 {
        let t = self.latency_s + bits as f64 / self.capacity_bps;
        self.elapsed_s += t;
        t
    }

    pub fn report(&self) -> LinkReport {
        LinkReport {
            up_bits: self.up_bits,
            down_bits: self.down_bits,
            up_frames: self.up_frames,
            down_frames: self.down_frames,
            sync_up_bits: self.sync_up_bits,
            sync_down_bits: self.sync_down_bits,
            sync_up_frames: self.sync_up_frames,
            sync_down_frames: self.sync_down_frames,
            elapsed_s: self.elapsed_s,
            // the worker owns these counters and patches them into its
            // report — the link model itself never retries
            retry_attempts: 0,
            backoff_s: 0.0,
        }
    }

    pub fn reset(&mut self) {
        self.up_bits = 0;
        self.down_bits = 0;
        self.up_frames = 0;
        self.down_frames = 0;
        self.sync_up_bits = 0;
        self.sync_down_bits = 0;
        self.sync_up_frames = 0;
        self.sync_down_frames = 0;
        self.elapsed_s = 0.0;
    }
}

/// The paper's introductory latency estimate: transmitting uncompressed F and
/// G (32-bit floats) for `iters` iterations across `devices` devices over a
/// link of `capacity_bps`: time = 2 * 32 * B * Dbar * iters * devices / cap.
pub fn vanilla_sl_transfer_time_s(
    capacity_bps: f64,
    batch: usize,
    dbar: usize,
    iters: usize,
    devices: usize,
) -> f64 {
    let bits = 2.0 * 32.0 * batch as f64 * dbar as f64 * iters as f64 * devices as f64;
    bits / capacity_bps
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::wire::FrameKind;

    #[test]
    fn paper_intro_example() {
        // "10 Mbps, batch 256, Dbar 8192, 100 iterations, 100 devices
        //  => about 1.34e5 seconds"
        let t = vanilla_sl_transfer_time_s(10e6, 256, 8192, 100, 100);
        assert!((t - 1.342e5).abs() / 1.342e5 < 0.01, "t={t}");
    }

    #[test]
    fn accounting_accumulates_per_direction() {
        let mut link = Link::new(1e6, 0.0);
        let f = Frame::new(FrameKind::FeaturesUp, vec![0u8; 125], 1000);
        let g = Frame::new(FrameKind::GradientsDown, vec![0u8; 25], 200);
        link.transmit(Direction::Uplink, &f);
        link.transmit(Direction::Uplink, &f);
        link.transmit(Direction::Downlink, &g);
        let r = link.report();
        assert_eq!(r.up_bits, 2 * (1000 + Frame::HEADER_BITS));
        assert_eq!(r.down_bits, 200 + Frame::HEADER_BITS);
        assert_eq!((r.up_frames, r.down_frames), (2, 1));
        assert_eq!((r.sync_up_bits, r.sync_down_bits), (0, 0));
    }

    #[test]
    fn sync_traffic_counts_apart_but_costs_time() {
        let mut link = Link::new(1000.0, 0.0);
        let wd = Frame::new(FrameKind::ModelSync, vec![0u8; 125], 1000);
        let t = link.transmit_sync(Direction::Downlink, &wd);
        link.transmit_sync(Direction::Uplink, &wd);
        let r = link.report();
        // paper-quantity counters untouched...
        assert_eq!((r.up_bits, r.down_bits, r.up_frames, r.down_frames), (0, 0, 0, 0));
        // ...sync counters and the clock both moved
        assert_eq!(r.sync_down_bits, 1000 + Frame::HEADER_BITS);
        assert_eq!(r.sync_up_bits, 1000 + Frame::HEADER_BITS);
        assert_eq!((r.sync_up_frames, r.sync_down_frames), (1, 1));
        assert!((t - (1000.0 + Frame::HEADER_BITS as f64) / 1000.0).abs() < 1e-12);
        assert!((r.elapsed_s - 2.0 * t).abs() < 1e-12);
    }

    #[test]
    fn transfer_time_model() {
        let mut link = Link::new(1000.0, 0.5);
        let f = Frame::new(FrameKind::FeaturesUp, vec![0u8; 110], 1000 - Frame::HEADER_BITS);
        let t = link.transmit(Direction::Uplink, &f);
        assert!((t - 1.5).abs() < 1e-9, "t={t}"); // 0.5 latency + 1000/1000
    }

    #[test]
    fn aggregate_sums_per_device_reports() {
        let mut a = Link::new(1e6, 0.0);
        let mut b = Link::new(1e6, 0.25);
        let f = Frame::new(FrameKind::FeaturesUp, vec![0u8; 125], 1000);
        let g = Frame::new(FrameKind::GradientsDown, vec![0u8; 25], 200);
        a.transmit(Direction::Uplink, &f);
        b.transmit(Direction::Uplink, &f);
        b.transmit(Direction::Downlink, &g);
        b.transmit_sync(Direction::Downlink, &g);
        let total = LinkReport::aggregate([a.report(), b.report()]);
        assert_eq!(total.up_bits, 2 * (1000 + Frame::HEADER_BITS));
        assert_eq!(total.down_bits, 200 + Frame::HEADER_BITS);
        assert_eq!((total.up_frames, total.down_frames), (2, 1));
        assert_eq!(total.sync_down_bits, 200 + Frame::HEADER_BITS);
        assert_eq!(total.sync_down_frames, 1);
        let expect = a.report().elapsed_s + b.report().elapsed_s;
        assert!((total.elapsed_s - expect).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let mut link = Link::new(1.0, 0.0);
        link.transmit(
            Direction::Uplink,
            &Frame::new(FrameKind::ModelSync, vec![1], 8),
        );
        link.transmit_sync(
            Direction::Downlink,
            &Frame::new(FrameKind::ModelSync, vec![1], 8),
        );
        link.reset();
        let r = link.report();
        assert_eq!(r.up_bits + r.down_bits + r.sync_up_bits + r.sync_down_bits, 0);
        assert_eq!(r.elapsed_s, 0.0);
    }
}
