//! Fading-channel extension (the paper's Conclusion names "fading channels
//! and device-specific heterogeneous conditions" as future work).
//!
//! Block-fading link model: per-round capacity C_t = C̄ · g_t with Rayleigh
//! power gain g_t ~ Exp(1) (clamped), plus an outage rule — when the gain
//! drops below `outage_gain` the frame is retransmitted next block. Also
//! provides a heterogeneous-device budget sampler: per-device bits/entry
//! budgets drawn log-normally around the nominal, so experiments can assign
//! device k a personal C_e,d^{(k)} (the adaptive-R policy in
//! `per_device_ratio`).

use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct FadingLink {
    pub mean_capacity_bps: f64,
    /// gains below this are outages (retransmission next block)
    pub outage_gain: f64,
    /// block length in seconds (one gain draw per block)
    pub block_s: f64,
    rng: Rng,
    pub retransmissions: u64,
    pub blocks_used: u64,
}

impl FadingLink {
    pub fn new(mean_capacity_bps: f64, outage_gain: f64, block_s: f64, seed: u64) -> FadingLink {
        assert!(mean_capacity_bps > 0.0 && block_s > 0.0);
        FadingLink {
            mean_capacity_bps,
            outage_gain,
            block_s,
            rng: Rng::new(seed),
            retransmissions: 0,
            blocks_used: 0,
        }
    }

    /// Rayleigh power gain ~ Exp(1).
    fn gain(&mut self) -> f64 {
        -(1.0 - self.rng.next_f64()).ln()
    }

    /// Transmit `bits`; returns total elapsed seconds including outages.
    pub fn transmit(&mut self, bits: u64) -> f64 {
        let mut remaining = bits as f64;
        let mut t = 0.0;
        while remaining > 0.0 {
            self.blocks_used += 1;
            let g = self.gain();
            t += self.block_s;
            if g < self.outage_gain {
                self.retransmissions += 1;
                continue; // whole block lost
            }
            remaining -= self.mean_capacity_bps * g.min(4.0) * self.block_s;
        }
        t
    }

    /// Expected throughput degradation factor vs a non-fading link
    /// (Monte-Carlo; used by the planner example).
    pub fn efficiency_estimate(&mut self, trials: usize) -> f64 {
        let mut good = 0.0;
        for _ in 0..trials {
            let g = self.gain();
            if g >= self.outage_gain {
                good += g.min(4.0);
            }
        }
        good / trials as f64
    }
}

/// Heterogeneous per-device budgets: log-normal around `nominal_bpe`,
/// clamped to [min_bpe, 32].
pub fn device_budgets(
    devices: usize,
    nominal_bpe: f64,
    sigma_ln: f64,
    min_bpe: f64,
    rng: &mut Rng,
) -> Vec<f64> {
    (0..devices)
        .map(|_| {
            let z = rng.normal();
            (nominal_bpe * (sigma_ln * z).exp()).clamp(min_bpe, 32.0)
        })
        .collect()
}

/// Heterogeneous per-device link capacities for the coordinator's
/// `--fading-sigma` flag: log-normal around `mean_bps`, clamped to two
/// decades either side so no device's modeled transfer time degenerates.
/// Draws from a dedicated generator seeded independently of the training
/// RNG chain, so turning fading on cannot perturb model trajectories.
pub fn fading_capacities(devices: usize, mean_bps: f64, sigma_ln: f64, seed: u64) -> Vec<f64> {
    let mut rng = Rng::new(seed);
    (0..devices)
        .map(|_| {
            (mean_bps * (sigma_ln * rng.normal()).exp())
                .clamp(mean_bps / 100.0, mean_bps * 100.0)
        })
        .collect()
}

/// Adaptive-R policy for heterogeneous budgets: pick the smallest R from the
/// candidate grid whose AD-only overhead (Remark 1: 32BD̄/R + D̄ bits) fits
/// the device's budget; devices with more headroom keep more features.
pub fn per_device_ratio(
    budget_bpe: f64,
    batch: usize,
    dbar: usize,
    candidates: &[f64],
) -> f64 {
    let budget_bits = budget_bpe * (batch * dbar) as f64;
    for &r in candidates {
        let overhead = 32.0 * (batch * dbar) as f64 / r + dbar as f64;
        if overhead <= budget_bits {
            return r;
        }
    }
    *candidates.last().unwrap_or(&1.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fading_transmit_takes_longer_than_ideal() {
        let mut link = FadingLink::new(1e6, 0.1, 0.01, 1);
        let bits = 5_000_000u64; // ideal: 5 s
        let t = link.transmit(bits);
        assert!(t >= 2.0, "t={t} suspiciously fast for fading");
        assert!(t.is_finite());
        assert!(link.blocks_used > 0);
    }

    #[test]
    fn higher_outage_threshold_more_retransmissions() {
        let runs = |outage: f64| {
            let mut link = FadingLink::new(1e6, outage, 0.01, 2);
            link.transmit(2_000_000);
            link.retransmissions
        };
        assert!(runs(0.5) > runs(0.01));
    }

    #[test]
    fn efficiency_estimate_in_unit_range_ish() {
        let mut link = FadingLink::new(1e6, 0.1, 0.01, 3);
        let e = link.efficiency_estimate(20_000);
        // E[min(g,4)·1{g>0.1}] for g~Exp(1) ≈ 0.88
        assert!((0.7..=1.1).contains(&e), "e={e}");
    }

    #[test]
    fn device_budgets_clamped_and_dispersed() {
        let mut rng = Rng::new(4);
        let b = device_budgets(200, 0.2, 0.8, 0.05, &mut rng);
        assert_eq!(b.len(), 200);
        assert!(b.iter().all(|&x| (0.05..=32.0).contains(&x)));
        let mean: f64 = b.iter().sum::<f64>() / 200.0;
        assert!((0.1..=0.6).contains(&mean), "mean={mean}");
        let mn = b.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = b.iter().cloned().fold(0.0, f64::max);
        assert!(mx > 2.0 * mn, "should be heterogeneous: {mn}..{mx}");
    }

    #[test]
    fn fading_capacities_are_deterministic_dispersed_and_clamped() {
        let a = fading_capacities(64, 10e6, 0.6, 0x5EED);
        let b = fading_capacities(64, 10e6, 0.6, 0x5EED);
        assert_eq!(
            a.iter().map(|v| v.to_bits()).collect::<Vec<_>>(),
            b.iter().map(|v| v.to_bits()).collect::<Vec<_>>()
        );
        assert!(a.iter().all(|&c| (1e5..=1e9).contains(&c)));
        let mn = a.iter().cloned().fold(f64::INFINITY, f64::min);
        let mx = a.iter().cloned().fold(0.0, f64::max);
        assert!(mx > 1.5 * mn, "should be heterogeneous: {mn}..{mx}");
        // sigma 0 degenerates to the uniform capacity
        assert!(fading_capacities(8, 10e6, 0.0, 1).iter().all(|&c| c == 10e6));
    }

    #[test]
    fn per_device_ratio_fits_budget() {
        let candidates = [1.0, 2.0, 4.0, 8.0, 16.0, 32.0, 64.0, 128.0];
        for &bpe in &[0.1, 0.2, 0.5, 1.0, 4.0, 32.0] {
            let r = per_device_ratio(bpe, 64, 1152, &candidates);
            let overhead = 32.0 * (64.0 * 1152.0) / r + 1152.0;
            if r < 128.0 {
                assert!(
                    overhead <= bpe * 64.0 * 1152.0 + 1e-6,
                    "bpe={bpe} r={r} overhead={overhead}"
                );
            }
        }
        // generous budget keeps R small (more features kept)
        assert!(
            per_device_ratio(32.0, 64, 1152, &candidates)
                < per_device_ratio(0.2, 64, 1152, &candidates)
        );
    }
}
