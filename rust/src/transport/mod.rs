//! Communication substrate: wire frames, protocol messages, link models,
//! and the transport backends that move them.
//!
//! The paper's testbed is a wireless uplink/downlink between devices and
//! the PS. Two layers coexist here:
//!
//! * **Accounting** (`channel::Link`, `fading::FadingLink`): every transfer
//!   is a serialized frame (`wire::Frame`) pushed through a link model that
//!   counts bits and models transfer time at a configured capacity —
//!   reproducing, e.g., the intro's 1.34e5 s example.
//! * **Movement** ([`Connection`] + backends): since the transport
//!   refactor, devices and the PS exchange explicit protocol messages
//!   (`message::Msg`). The in-process backend (`inproc`) moves them over
//!   bounded channels between threads; the TCP backend (`tcp`) moves them
//!   over real sockets with length-prefixed framing. Both carry the exact
//!   same messages, so a staleness-0 run is byte-identical across
//!   backends.

pub mod channel;
pub mod fading;
pub mod inproc;
pub mod message;
pub mod tcp;
pub mod wire;

pub use channel::{Direction, Link, LinkReport};
pub use fading::{device_budgets, fading_capacities, per_device_ratio, FadingLink};
pub use inproc::{inproc_pair, InProcConn};
pub use message::{Msg, StepReport};
pub use tcp::TcpConn;
pub use wire::{Frame, FrameKind, WireLimits};

use crate::util::error::Result;

/// A bidirectional, ordered, reliable message pipe between one device and
/// the parameter server. Implementations: [`InProcConn`] (bounded
/// channels, zero-copy) and [`TcpConn`] (length-prefixed frames over a
/// socket).
///
/// Errors whose message carries the `"transport io"` prefix are transport
/// faults (peer gone, socket reset) — the caller may [`reconnect`]
/// (if [`is_reconnectable`]) and retry. Anything else is a protocol
/// error and must not be retried.
///
/// [`reconnect`]: Connection::reconnect
/// [`is_reconnectable`]: Connection::is_reconnectable
pub trait Connection: Send {
    fn send(&mut self, msg: Msg) -> Result<()>;
    fn recv(&mut self) -> Result<Msg>;

    /// Re-establish a dropped connection (client side of TCP only).
    fn reconnect(&mut self) -> Result<()> {
        Err(crate::util::error::Error::msg(
            "this transport cannot reconnect",
        ))
    }

    fn is_reconnectable(&self) -> bool {
        false
    }

    /// Bound how long a single `recv` may block (`None` = wait forever).
    /// A deadline expiry surfaces as a `"transport io"` error, so the
    /// caller's retry/backoff path treats it like any other link fault.
    /// Backends without timeout support ignore this.
    fn set_recv_deadline(&mut self, _deadline: Option<std::time::Duration>) {}

    /// Scenario fault injection: drop the underlying link now, so the next
    /// operation fails with a `"transport io"` error. No-op on transports
    /// that cannot be cut (in-process channels).
    fn inject_cut(&mut self) {}
}

/// Which transport backend carries device<->PS messages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum TransportKind {
    /// Bounded in-process channels between worker threads and the PS.
    #[default]
    InProc,
    /// Length-prefixed frames over TCP sockets (loopback or remote).
    Tcp,
}

impl TransportKind {
    pub fn parse(s: &str) -> Result<TransportKind> {
        match s {
            "inproc" => Ok(TransportKind::InProc),
            "tcp" => Ok(TransportKind::Tcp),
            other => Err(crate::util::error::Error::msg(format!(
                "unknown transport '{other}' (expected inproc|tcp)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            TransportKind::InProc => "inproc",
            TransportKind::Tcp => "tcp",
        }
    }
}

#[cfg(test)]
mod kind_tests {
    use super::TransportKind;

    #[test]
    fn parse_roundtrips() {
        for k in [TransportKind::InProc, TransportKind::Tcp] {
            assert_eq!(TransportKind::parse(k.name()).unwrap(), k);
        }
        assert!(TransportKind::parse("udp").is_err());
    }
}
