//! Simulated communication substrate.
//!
//! The paper's testbed is a wireless uplink/downlink between devices and the
//! PS. Here every transfer is a real serialized frame (`wire::Frame`) pushed
//! through a `channel::Link` that accounts bits and models transfer time at a
//! configured capacity — reproducing, e.g., the intro's 1.34e5 s example.

pub mod channel;
pub mod fading;
pub mod wire;

pub use channel::{Direction, Link, LinkReport};
pub use fading::{device_budgets, per_device_ratio, FadingLink};
pub use wire::Frame;
