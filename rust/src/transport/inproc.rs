//! In-process transport backend: bounded channels between worker threads
//! and the parameter server.
//!
//! This is the zero-copy baseline the TCP backend is locked against —
//! messages move as `Msg` values over `std::sync::mpsc::sync_channel`
//! without ever being serialized. The channels are *bounded* so the
//! backpressure semantics match a socket with a small send buffer: a
//! sender blocks once the peer falls `depth` messages behind (with the
//! request/reply protocol each side has at most one message in flight, so
//! the bound never bites in practice — it exists to keep the contract
//! honest).

use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, SyncSender};
use std::time::Duration;

use crate::transport::message::Msg;
use crate::transport::Connection;
use crate::util::error::{Error, Result};

/// One endpoint of a bidirectional in-process message pipe.
pub struct InProcConn {
    tx: SyncSender<Msg>,
    rx: Receiver<Msg>,
    /// per-recv deadline; `None` (default) blocks forever
    deadline: Option<Duration>,
}

/// Create a connected pair of in-process endpoints with `depth` messages
/// of buffering in each direction.
pub fn inproc_pair(depth: usize) -> (InProcConn, InProcConn) {
    let (atx, brx) = sync_channel(depth);
    let (btx, arx) = sync_channel(depth);
    (
        InProcConn { tx: atx, rx: arx, deadline: None },
        InProcConn { tx: btx, rx: brx, deadline: None },
    )
}

impl Connection for InProcConn {
    fn send(&mut self, msg: Msg) -> Result<()> {
        self.tx
            .send(msg)
            .map_err(|_| Error::msg("transport io: in-process peer hung up on send"))
    }

    fn recv(&mut self) -> Result<Msg> {
        match self.deadline {
            None => self
                .rx
                .recv()
                .map_err(|_| Error::msg("transport io: in-process peer hung up on recv")),
            Some(d) => self.rx.recv_timeout(d).map_err(|e| match e {
                RecvTimeoutError::Timeout => {
                    Error::msg("transport io: in-process recv deadline expired")
                }
                RecvTimeoutError::Disconnected => {
                    Error::msg("transport io: in-process peer hung up on recv")
                }
            }),
        }
    }

    fn set_recv_deadline(&mut self, deadline: Option<Duration>) {
        self.deadline = deadline.filter(|d| !d.is_zero());
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pair_ferries_messages_both_ways() {
        let (mut a, mut b) = inproc_pair(4);
        a.send(Msg::Bye { device: 7 }).unwrap();
        match b.recv().unwrap() {
            Msg::Bye { device: 7 } => {}
            other => panic!("{other:?}"),
        }
        b.send(Msg::CommitAck).unwrap();
        assert!(matches!(a.recv().unwrap(), Msg::CommitAck));
    }

    #[test]
    fn hangup_is_an_io_error_not_a_panic() {
        let (mut a, b) = inproc_pair(1);
        drop(b);
        let err = a.recv().unwrap_err().to_string();
        assert!(err.contains("transport io"), "{err}");
        let err = a.send(Msg::CommitAck).unwrap_err().to_string();
        assert!(err.contains("transport io"), "{err}");
    }

    #[test]
    fn not_reconnectable() {
        let (a, _b) = inproc_pair(1);
        assert!(!a.is_reconnectable());
    }

    #[test]
    fn recv_deadline_expires_as_a_transport_io_error() {
        let (mut a, _b) = inproc_pair(1); // peer alive: expiry, not hangup
        a.set_recv_deadline(Some(Duration::from_millis(5)));
        let err = a.recv().unwrap_err().to_string();
        assert!(err.contains("transport io"), "{err}");
        assert!(err.contains("deadline"), "{err}");
    }
}
