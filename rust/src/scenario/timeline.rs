//! Compile a [`ScenarioSpec`](crate::scenario::ScenarioSpec) into a
//! deterministic per-device event timeline.
//!
//! Every stochastic clause draws from its own `(seed, purpose, device)`
//! RNG stream, so adding a clause, reordering clauses, or resizing the
//! fleet never perturbs the draws another clause/device sees. Nothing here
//! touches the training RNGs: scenario randomness is a separate universe,
//! and a calm timeline leaves the trajectory byte-identical.

use crate::scenario::spec::{Clause, ScenarioSpec};
use crate::util::error::Result;
use crate::util::Rng;
use crate::{bail, ensure};

/// The compiled failure script for one device. `Default` is the calm
/// script: full-speed, joined from round 1, never departs, no cuts.
#[derive(Debug, Clone, PartialEq)]
pub struct DeviceScript {
    /// Compute-delay multiplier (wall clock only); 1.0 = full speed.
    pub slow: f64,
    /// First round this device participates in (1 = from the start).
    pub join_round: usize,
    /// First round this device no longer participates in (0 = never departs).
    pub depart_round: usize,
    /// Dropout windows as half-open round ranges `[start, end)`.
    pub outages: Vec<(usize, usize)>,
    /// Cut the link at entry of these 1-based device-local step ordinals.
    pub cut_steps: Vec<u64>,
    /// Cut the link after these 1-based wire-send ordinals (Hello = 1).
    pub cut_sends: Vec<u64>,
}

impl Default for DeviceScript {
    fn default() -> DeviceScript {
        DeviceScript {
            slow: 1.0,
            join_round: 1,
            depart_round: 0,
            outages: Vec::new(),
            cut_steps: Vec::new(),
            cut_sends: Vec::new(),
        }
    }
}

impl DeviceScript {
    /// Does this device run its step in `round` (1-based)?
    pub fn participates(&self, round: usize) -> bool {
        if round < self.join_round {
            return false;
        }
        if self.depart_round != 0 && round >= self.depart_round {
            return false;
        }
        !self.outages.iter().any(|&(a, b)| round >= a && round < b)
    }

    /// True when the script changes nothing about the calm run.
    pub fn is_neutral(&self) -> bool {
        self == &DeviceScript::default()
    }
}

/// The compiled fleet-wide timeline for one run.
#[derive(Debug, Clone)]
pub struct Timeline {
    pub scripts: Vec<DeviceScript>,
    pub seed: u64,
    pub devices: usize,
    pub rounds: usize,
    /// Crash+restart the PS endpoint at these 1-based round barriers
    /// (each must be a checkpoint barrier — the trainer validates that).
    pub ps_crash_rounds: Vec<usize>,
    /// Crash the PS at the first checkpoint barrier once its cumulative
    /// step-reply send count has reached each of these thresholds.
    pub ps_crash_sends: Vec<u64>,
}

/// Independent RNG stream per (seed, clause purpose, device).
fn stream(seed: u64, purpose: u64, device: usize) -> Rng {
    Rng::new(seed ^ purpose ^ (device as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

const PURPOSE_STRAGGLER: u64 = 0x57A6_617E_57A6_617E;
const PURPOSE_DROPOUT: u64 = 0xD809_0D07_D809_0D07;

impl Timeline {
    /// Compile `spec` for a fleet of `devices` over `rounds` rounds. The
    /// scenario seed defaults to `fallback_seed` (the run seed) so a bare
    /// clause list is still reproducible per run config.
    pub fn compile(
        spec: &ScenarioSpec,
        devices: usize,
        rounds: usize,
        fallback_seed: u64,
    ) -> Result<Timeline> {
        ensure!(devices > 0, "scenario timeline wants at least one device");
        let seed = spec.seed.unwrap_or(fallback_seed);
        let mut scripts = vec![DeviceScript::default(); devices];
        let mut ps_crash_rounds = Vec::new();
        let mut ps_crash_sends = Vec::new();
        let check_dev = |k: usize| -> Result<()> {
            if k >= devices {
                bail!("scenario names dev={k} but the fleet has {devices} device(s)");
            }
            Ok(())
        };
        for clause in &spec.clauses {
            match clause {
                Clause::Straggler { dev, p, slow } => match dev {
                    Some(k) => {
                        check_dev(*k)?;
                        scripts[*k].slow = scripts[*k].slow.max(*slow);
                    }
                    None => {
                        for (k, s) in scripts.iter_mut().enumerate() {
                            let mut r = stream(seed, PURPOSE_STRAGGLER, k);
                            if r.bernoulli(*p) {
                                s.slow = s.slow.max(*slow);
                            }
                        }
                    }
                },
                Clause::Dropout { p, rejoin } => {
                    for (k, s) in scripts.iter_mut().enumerate() {
                        let mut r = stream(seed, PURPOSE_DROPOUT, k);
                        let mut t = 1usize;
                        while t <= rounds {
                            if r.bernoulli(*p) {
                                s.outages.push((t, t + rejoin));
                                t += rejoin;
                            } else {
                                t += 1;
                            }
                        }
                    }
                }
                Clause::Cut { dev, step, send } => {
                    check_dev(*dev)?;
                    if let Some(n) = step {
                        scripts[*dev].cut_steps.push(*n);
                    }
                    if let Some(n) = send {
                        scripts[*dev].cut_sends.push(*n);
                    }
                }
                Clause::Wave { cohort, every } => {
                    for (k, s) in scripts.iter_mut().enumerate() {
                        let join = 1 + (k / cohort) * every;
                        s.join_round = s.join_round.max(join);
                    }
                }
                Clause::Depart { dev, round } => {
                    check_dev(*dev)?;
                    let s = &mut scripts[*dev];
                    s.depart_round =
                        if s.depart_round == 0 { *round } else { s.depart_round.min(*round) };
                }
                Clause::PsCrash { round, send } => {
                    if let Some(t) = round {
                        ensure!(
                            *t >= 1 && *t < rounds,
                            "scenario pscrash[round={t}] is out of range: the PS can only \
                             crash at a barrier with rounds left to replay (1..{rounds})"
                        );
                        ps_crash_rounds.push(*t);
                    }
                    if let Some(n) = send {
                        ps_crash_sends.push(*n);
                    }
                }
            }
        }
        for s in &mut scripts {
            s.cut_steps.sort_unstable();
            s.cut_steps.dedup();
            s.cut_sends.sort_unstable();
            s.cut_sends.dedup();
        }
        ps_crash_rounds.sort_unstable();
        ps_crash_rounds.dedup();
        ps_crash_sends.sort_unstable();
        ps_crash_sends.dedup();
        Ok(Timeline { scripts, seed, devices, rounds, ps_crash_rounds, ps_crash_sends })
    }

    /// Schedule-local step indices (`l = (t-1)·K + k`) that no device will
    /// run this schedule — the gate pre-completes them so the surviving
    /// cohort is never blocked on an absent peer.
    pub fn skipped_locals(&self) -> Vec<usize> {
        let mut out = Vec::new();
        for t in 1..=self.rounds {
            for (k, s) in self.scripts.iter().enumerate() {
                if !s.participates(t) {
                    out.push((t - 1) * self.devices + k);
                }
            }
        }
        out
    }

    /// Any deterministic socket cuts scheduled? (Cuts need a reconnectable
    /// transport — the trainer rejects them on in-process channels.)
    pub fn has_cuts(&self) -> bool {
        self.scripts.iter().any(|s| !s.cut_steps.is_empty() || !s.cut_sends.is_empty())
    }

    /// Any server-side crashes scheduled? (They need TCP + checkpointing
    /// armed — the trainer validates both.)
    pub fn has_ps_crashes(&self) -> bool {
        !self.ps_crash_rounds.is_empty() || !self.ps_crash_sends.is_empty()
    }

    /// True when every device runs the calm script and the PS never crashes.
    pub fn is_calm(&self) -> bool {
        self.scripts.iter().all(|s| s.is_neutral()) && !self.has_ps_crashes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn compile(text: &str, devices: usize, rounds: usize) -> Timeline {
        let spec = ScenarioSpec::parse(text).unwrap();
        Timeline::compile(&spec, devices, rounds, 11).unwrap()
    }

    #[test]
    fn empty_spec_compiles_calm() {
        let tl = compile("", 4, 6);
        assert!(tl.is_calm());
        assert!(!tl.has_cuts());
        assert!(tl.skipped_locals().is_empty());
        for s in &tl.scripts {
            for t in 1..=6 {
                assert!(s.participates(t));
            }
        }
    }

    #[test]
    fn compilation_is_deterministic_and_seed_sensitive() {
        let a = compile("seed=7,dropout[p=0.3,rejoin=2r],straggler[p=0.5,slow=4x]", 8, 20);
        let b = compile("seed=7,dropout[p=0.3,rejoin=2r],straggler[p=0.5,slow=4x]", 8, 20);
        assert_eq!(a.scripts, b.scripts);
        // a different seed should (overwhelmingly) give different draws
        let c = compile("seed=8,dropout[p=0.3,rejoin=2r],straggler[p=0.5,slow=4x]", 8, 20);
        assert_ne!(a.scripts, c.scripts);
    }

    #[test]
    fn clause_order_does_not_cross_perturb_draws() {
        // dropout draws must be identical whether or not a straggler clause
        // precedes the dropout clause: streams are keyed per purpose.
        let a = compile("seed=3,dropout[p=0.4,rejoin=1r]", 6, 12);
        let b = compile("seed=3,straggler[p=0.5,slow=2x],dropout[p=0.4,rejoin=1r]", 6, 12);
        for (sa, sb) in a.scripts.iter().zip(&b.scripts) {
            assert_eq!(sa.outages, sb.outages);
        }
    }

    #[test]
    fn wave_staggers_cohorts() {
        let tl = compile("wave[cohort=2,every=3r]", 5, 10);
        assert_eq!(
            tl.scripts.iter().map(|s| s.join_round).collect::<Vec<_>>(),
            vec![1, 1, 4, 4, 7]
        );
        assert!(!tl.scripts[2].participates(3));
        assert!(tl.scripts[2].participates(4));
        // skipped locals cover exactly the pre-join rounds
        let skipped = tl.skipped_locals();
        assert!(skipped.contains(&2)); // dev 2, round 1
        assert!(!skipped.contains(&(3 * 5 + 2))); // dev 2, round 4 runs
    }

    #[test]
    fn depart_and_outages_gate_participation() {
        let tl = compile("depart[dev=1,round=3]", 3, 5);
        assert!(tl.scripts[1].participates(2));
        assert!(!tl.scripts[1].participates(3));
        assert!(!tl.scripts[1].participates(5));
        assert_eq!(tl.skipped_locals(), vec![7, 10, 13]); // dev 1 in rounds 3..=5

        let mut s = DeviceScript { outages: vec![(2, 4)], ..DeviceScript::default() };
        assert!(s.participates(1));
        assert!(!s.participates(2));
        assert!(!s.participates(3));
        assert!(s.participates(4));
        s.depart_round = 5;
        assert!(!s.participates(5));
    }

    #[test]
    fn cuts_sort_and_dedup() {
        let tl = compile("cut[dev=0,send=9],cut[dev=0,send=3],cut[dev=0,send=9],cut[dev=0,step=2]", 2, 4);
        assert_eq!(tl.scripts[0].cut_sends, vec![3, 9]);
        assert_eq!(tl.scripts[0].cut_steps, vec![2]);
        assert!(tl.has_cuts());
        assert!(Timeline::compile(
            &ScenarioSpec::parse("cut[dev=5,send=1]").unwrap(),
            2,
            4,
            0
        )
        .is_err());
    }

    #[test]
    fn ps_crashes_are_fleet_level_and_range_checked() {
        let tl = compile("pscrash[round=3],pscrash[round=2],pscrash[round=3],pscrash[send=24]", 4, 6);
        assert_eq!(tl.ps_crash_rounds, vec![2, 3]);
        assert_eq!(tl.ps_crash_sends, vec![24]);
        assert!(tl.has_ps_crashes());
        assert!(!tl.is_calm(), "a pscrash timeline is not calm");
        // the device scripts stay neutral: pscrash is server-side only
        assert!(tl.scripts.iter().all(|s| s.is_neutral()));
        assert!(tl.skipped_locals().is_empty());

        // a crash at or past the final barrier has nothing left to replay
        for bad in ["pscrash[round=6]", "pscrash[round=7]"] {
            assert!(
                Timeline::compile(&ScenarioSpec::parse(bad).unwrap(), 4, 6, 0).is_err(),
                "{bad} must be rejected"
            );
        }
    }
}
