//! Seeded failure-scenario engine.
//!
//! `--scenario` strings parse into a [`ScenarioSpec`] (same bracketed
//! grammar as the codec specs) and compile into a [`Timeline`]: one
//! [`DeviceScript`] per device holding compute-delay multipliers, join /
//! departure rounds, dropout windows, and deterministic socket cuts. The
//! trainer injects cuts at the `Connection` layer, workers honor slowdowns
//! and backoff, and the parameter server pre-completes the steps of absent
//! devices so the bounded-staleness gate never deadlocks on a missing peer.
//!
//! Everything is keyed on the scenario seed — never wall clock — so the
//! same spec yields the same event timeline and the same metrics, run
//! after run. An empty spec is the calm scenario and changes nothing.

pub mod spec;
pub mod timeline;

pub use spec::{Clause, ScenarioSpec};
pub use timeline::{DeviceScript, Timeline};
