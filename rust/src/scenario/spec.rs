//! The seeded failure-scenario spec grammar.
//!
//! A scenario is a comma-separated list of clauses in the same bracketed
//! style as the PR 4 codec specs:
//!
//! ```text
//! seed=7,straggler[dev=2,slow=8x],dropout[p=0.05,rejoin=2r],cut[dev=1,step=40]
//! ```
//!
//! Clauses:
//!
//! | clause                        | meaning                                      |
//! |-------------------------------|----------------------------------------------|
//! | `seed=N`                      | scenario RNG seed (default: the run seed)    |
//! | `straggler[dev=K,slow=Sx]`    | device K computes S× slower (wall clock)     |
//! | `straggler[p=P,slow=Sx]`      | each device straggles with probability P     |
//! | `dropout[p=P,rejoin=Nr]`      | per-round dropout; an affected device sits   |
//! |                               | out N rounds then rejoins                    |
//! | `cut[dev=K,step=N]`           | cut K's socket at entry of its N-th step     |
//! | `cut[dev=K,send=N]`           | cut K's socket after its N-th send (Hello=1) |
//! | `wave[cohort=C,every=Nr]`     | devices join in cohorts of C, N rounds apart |
//! | `depart[dev=K,round=T]`       | device K departs permanently before round T  |
//! | `pscrash[round=T]`            | crash+restart the PS at the round-T barrier  |
//! | `pscrash[send=N]`             | crash the PS at the first checkpoint barrier |
//! |                               | once it has sent N step replies              |
//!
//! Parsing and the compiled timeline are fully deterministic: the same spec
//! string and seed always produce the same per-device event timeline, and an
//! empty spec compiles to a calm (no-op) timeline.

use crate::util::error::Result;
use crate::{bail, ensure, err};

/// One parsed scenario clause. Numeric fields are validated again at
/// compile time (where the fleet size is known).
#[derive(Debug, Clone, PartialEq)]
pub enum Clause {
    /// Compute-delay multiplier: `dev` pins one device, otherwise each
    /// device draws Bernoulli(`p`) from its own seeded stream.
    Straggler { dev: Option<usize>, p: f64, slow: f64 },
    /// Per-round dropout with probability `p`; an affected device sits out
    /// `rejoin` rounds, then re-enters through the normal handshake path.
    Dropout { p: f64, rejoin: usize },
    /// Deterministic socket cut: exactly one of `step` (entry of the
    /// device's N-th protocol step) or `send` (after the N-th wire send,
    /// Hello = send #1) is set.
    Cut { dev: usize, step: Option<u64>, send: Option<u64> },
    /// Staggered joins: device K enters at round `1 + (K / cohort) * every`.
    Wave { cohort: usize, every: usize },
    /// Permanent departure: the device participates in rounds `< round`.
    Depart { dev: usize, round: usize },
    /// Server-side chaos: kill and restart the PS endpoint in-process at a
    /// checkpoint barrier. Exactly one of `round` (crash at the round-T
    /// barrier, which must be a checkpoint barrier) or `send` (crash at the
    /// first checkpoint barrier once the PS has sent N step replies) is set.
    PsCrash { round: Option<usize>, send: Option<u64> },
}

/// A parsed `--scenario` spec: optional seed plus an ordered clause list.
/// The default value is the empty (calm) scenario.
#[derive(Debug, Clone, PartialEq, Default)]
pub struct ScenarioSpec {
    pub seed: Option<u64>,
    pub clauses: Vec<Clause>,
}

impl ScenarioSpec {
    /// Parse a spec string. The empty string is the calm scenario.
    pub fn parse(s: &str) -> Result<ScenarioSpec> {
        let s = s.trim();
        let mut spec = ScenarioSpec::default();
        if s.is_empty() {
            return Ok(spec);
        }
        for item in split_top_level(s)? {
            let item = item.trim();
            if item.is_empty() {
                continue;
            }
            if let Some(v) = item.strip_prefix("seed=") {
                let n = v
                    .parse::<u64>()
                    .map_err(|_| err!("scenario seed {v:?} is not a number"))?;
                spec.seed = Some(n);
            } else {
                spec.clauses.push(parse_clause(item)?);
            }
        }
        Ok(spec)
    }

    /// True when the spec carries no clauses (a bare `seed=` is still calm).
    pub fn is_empty(&self) -> bool {
        self.clauses.is_empty()
    }

    /// Append a deterministic socket cut (the `--chaos-drop` compatibility
    /// path routes through here).
    pub fn push_cut(&mut self, dev: usize, send: u64) {
        self.clauses.push(Clause::Cut { dev, step: None, send: Some(send) });
    }
}

impl std::fmt::Display for ScenarioSpec {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let mut first = true;
        let mut sep = |f: &mut std::fmt::Formatter<'_>| -> std::fmt::Result {
            if first {
                first = false;
                Ok(())
            } else {
                write!(f, ",")
            }
        };
        if let Some(seed) = self.seed {
            sep(f)?;
            write!(f, "seed={seed}")?;
        }
        for c in &self.clauses {
            sep(f)?;
            match c {
                Clause::Straggler { dev: Some(k), slow, .. } => {
                    write!(f, "straggler[dev={k},slow={slow}x]")?
                }
                Clause::Straggler { dev: None, p, slow } => {
                    write!(f, "straggler[p={p},slow={slow}x]")?
                }
                Clause::Dropout { p, rejoin } => write!(f, "dropout[p={p},rejoin={rejoin}r]")?,
                Clause::Cut { dev, step: Some(n), .. } => write!(f, "cut[dev={dev},step={n}]")?,
                Clause::Cut { dev, step: None, send } => {
                    write!(f, "cut[dev={dev},send={}]", send.unwrap_or(0))?
                }
                Clause::Wave { cohort, every } => write!(f, "wave[cohort={cohort},every={every}r]")?,
                Clause::Depart { dev, round } => write!(f, "depart[dev={dev},round={round}]")?,
                Clause::PsCrash { round: Some(t), .. } => write!(f, "pscrash[round={t}]")?,
                Clause::PsCrash { round: None, send } => {
                    write!(f, "pscrash[send={}]", send.unwrap_or(0))?
                }
            }
        }
        Ok(())
    }
}

/// Split on commas that sit outside `[...]` brackets; rejects unbalanced
/// brackets up front so clause parsing can assume well-formed pieces.
fn split_top_level(s: &str) -> Result<Vec<&str>> {
    let mut out = Vec::new();
    let mut depth = 0usize;
    let mut start = 0usize;
    for (i, c) in s.char_indices() {
        match c {
            '[' => depth += 1,
            ']' => {
                ensure!(depth > 0, "scenario {s:?}: unbalanced ']'");
                depth -= 1;
            }
            ',' if depth == 0 => {
                out.push(&s[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    ensure!(depth == 0, "scenario {s:?}: unbalanced '['");
    out.push(&s[start..]);
    Ok(out)
}

/// `key=value` argument list inside one clause's brackets; every key must
/// be consumed or `finish` reports it as unknown.
struct ClauseArgs {
    pairs: Vec<(String, String)>,
}

impl ClauseArgs {
    fn parse(clause: &str, inner: &str) -> Result<ClauseArgs> {
        let mut pairs = Vec::new();
        for a in inner.split(',') {
            let a = a.trim();
            if a.is_empty() {
                continue;
            }
            let (k, v) = a
                .split_once('=')
                .ok_or_else(|| err!("scenario clause {clause:?}: argument {a:?} wants key=value"))?;
            pairs.push((k.trim().to_string(), v.trim().to_string()));
        }
        Ok(ClauseArgs { pairs })
    }

    fn take(&mut self, key: &str) -> Option<String> {
        let at = self.pairs.iter().position(|(k, _)| k == key)?;
        Some(self.pairs.remove(at).1)
    }

    fn finish(self, clause: &str) -> Result<()> {
        if let Some((k, _)) = self.pairs.first() {
            bail!("scenario clause {clause:?}: unknown key {k:?}");
        }
        Ok(())
    }
}

fn num_usize(clause: &str, key: &str, v: &str) -> Result<usize> {
    v.parse::<usize>()
        .map_err(|_| err!("scenario clause {clause:?}: {key}={v:?} is not a number"))
}

fn num_u64(clause: &str, key: &str, v: &str) -> Result<u64> {
    v.parse::<u64>()
        .map_err(|_| err!("scenario clause {clause:?}: {key}={v:?} is not a number"))
}

fn num_f64(clause: &str, key: &str, v: &str) -> Result<f64> {
    v.parse::<f64>()
        .map_err(|_| err!("scenario clause {clause:?}: {key}={v:?} is not a number"))
}

fn parse_clause(item: &str) -> Result<Clause> {
    let (name, inner) = match item.find('[') {
        Some(open) => {
            ensure!(item.ends_with(']'), "scenario clause {item:?}: missing closing ']'");
            (&item[..open], &item[open + 1..item.len() - 1])
        }
        None => (item, ""),
    };
    let mut args = ClauseArgs::parse(item, inner)?;
    let clause = match name {
        "straggler" => {
            let dev = match args.take("dev") {
                Some(v) => Some(num_usize(item, "dev", &v)?),
                None => None,
            };
            let p = match args.take("p") {
                Some(v) => num_f64(item, "p", &v)?,
                None => 1.0,
            };
            ensure!(
                dev.is_none() || p == 1.0,
                "scenario clause {item:?}: give dev= or p=, not both"
            );
            ensure!(dev.is_some() || p < 1.0 || inner.contains("p="),
                "scenario clause {item:?}: wants dev=K or p=P");
            let slow = match args.take("slow") {
                Some(v) => num_f64(item, "slow", v.trim_end_matches('x'))?,
                None => 4.0,
            };
            ensure!(slow >= 1.0, "scenario clause {item:?}: slow={slow} must be >= 1");
            ensure!((0.0..=1.0).contains(&p), "scenario clause {item:?}: p={p} not in [0, 1]");
            Clause::Straggler { dev, p, slow }
        }
        "dropout" => {
            let p = match args.take("p") {
                Some(v) => num_f64(item, "p", &v)?,
                None => bail!("scenario clause {item:?}: wants p=P"),
            };
            ensure!((0.0..=1.0).contains(&p), "scenario clause {item:?}: p={p} not in [0, 1]");
            let rejoin = match args.take("rejoin") {
                Some(v) => num_usize(item, "rejoin", v.trim_end_matches('r'))?,
                None => 1,
            };
            ensure!(rejoin >= 1, "scenario clause {item:?}: rejoin must be >= 1");
            Clause::Dropout { p, rejoin }
        }
        "cut" => {
            let dev = match args.take("dev") {
                Some(v) => num_usize(item, "dev", &v)?,
                None => bail!("scenario clause {item:?}: wants dev=K"),
            };
            let step = match args.take("step") {
                Some(v) => Some(num_u64(item, "step", &v)?),
                None => None,
            };
            let send = match args.take("send") {
                Some(v) => Some(num_u64(item, "send", &v)?),
                None => None,
            };
            ensure!(
                step.is_some() != send.is_some(),
                "scenario clause {item:?}: wants exactly one of step=N or send=N"
            );
            ensure!(
                step.unwrap_or(1) >= 1 && send.unwrap_or(1) >= 1,
                "scenario clause {item:?}: step/send are 1-based"
            );
            Clause::Cut { dev, step, send }
        }
        "wave" => {
            let cohort = match args.take("cohort") {
                Some(v) => num_usize(item, "cohort", &v)?,
                None => bail!("scenario clause {item:?}: wants cohort=C"),
            };
            let every = match args.take("every") {
                Some(v) => num_usize(item, "every", v.trim_end_matches('r'))?,
                None => bail!("scenario clause {item:?}: wants every=Nr"),
            };
            ensure!(cohort >= 1 && every >= 1, "scenario clause {item:?}: cohort/every must be >= 1");
            Clause::Wave { cohort, every }
        }
        "depart" => {
            let dev = match args.take("dev") {
                Some(v) => num_usize(item, "dev", &v)?,
                None => bail!("scenario clause {item:?}: wants dev=K"),
            };
            let round = match args.take("round") {
                Some(v) => num_usize(item, "round", &v)?,
                None => bail!("scenario clause {item:?}: wants round=T"),
            };
            ensure!(round >= 1, "scenario clause {item:?}: round is 1-based");
            Clause::Depart { dev, round }
        }
        "pscrash" => {
            let round = match args.take("round") {
                Some(v) => Some(num_usize(item, "round", &v)?),
                None => None,
            };
            let send = match args.take("send") {
                Some(v) => Some(num_u64(item, "send", &v)?),
                None => None,
            };
            ensure!(
                round.is_some() != send.is_some(),
                "scenario clause {item:?}: wants exactly one of round=T or send=N"
            );
            ensure!(
                round.unwrap_or(1) >= 1 && send.unwrap_or(1) >= 1,
                "scenario clause {item:?}: round/send are 1-based"
            );
            Clause::PsCrash { round, send }
        }
        other => bail!(
            "unknown scenario clause {other:?} (want straggler, dropout, cut, wave, depart, \
             pscrash or seed=N)"
        ),
    };
    args.finish(item)?;
    Ok(clause)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_spec_is_calm() {
        let s = ScenarioSpec::parse("").unwrap();
        assert!(s.is_empty());
        assert_eq!(s.seed, None);
        let s = ScenarioSpec::parse("seed=9").unwrap();
        assert!(s.is_empty());
        assert_eq!(s.seed, Some(9));
    }

    #[test]
    fn issue_example_parses() {
        let s = ScenarioSpec::parse(
            "seed=7,straggler[dev=2,slow=8x],dropout[p=0.05,rejoin=2r],cut[dev=1,step=40],\
             wave[cohort=4,every=5r]",
        )
        .unwrap();
        assert_eq!(s.seed, Some(7));
        assert_eq!(s.clauses.len(), 4);
        assert_eq!(s.clauses[0], Clause::Straggler { dev: Some(2), p: 1.0, slow: 8.0 });
        assert_eq!(s.clauses[1], Clause::Dropout { p: 0.05, rejoin: 2 });
        assert_eq!(s.clauses[2], Clause::Cut { dev: 1, step: Some(40), send: None });
        assert_eq!(s.clauses[3], Clause::Wave { cohort: 4, every: 5 });
    }

    #[test]
    fn display_roundtrips() {
        for text in [
            "seed=7,straggler[dev=2,slow=8x]",
            "straggler[p=0.3,slow=2x],dropout[p=0.05,rejoin=2r]",
            "cut[dev=1,send=13],cut[dev=0,step=4],depart[dev=3,round=5]",
            "wave[cohort=2,every=3r]",
            "pscrash[round=2],pscrash[send=24]",
        ] {
            let spec = ScenarioSpec::parse(text).unwrap();
            let printed = spec.to_string();
            assert_eq!(ScenarioSpec::parse(&printed).unwrap(), spec, "{text} -> {printed}");
        }
    }

    #[test]
    fn rejects_malformed_specs() {
        for bad in [
            "straggler[dev=2,slow=8x", // missing ]
            "bogus[x=1]",              // unknown clause
            "cut[dev=0]",              // neither step nor send
            "cut[dev=0,step=1,send=2]",
            "cut[step=3]",             // no dev
            "dropout[p=1.5]",          // p out of range
            "straggler[dev=1,typo=2]", // unknown key
            "seed=abc",
            "depart[dev=0,round=0]",   // rounds are 1-based
            "wave[cohort=0,every=1r]",
            "pscrash",                 // neither round nor send
            "pscrash[round=2,send=9]", // both
            "pscrash[round=0]",        // 1-based
            "pscrash[dev=1]",          // pscrash is fleet-level, no dev=
        ] {
            assert!(ScenarioSpec::parse(bad).is_err(), "{bad:?} should not parse");
        }
    }

    #[test]
    fn push_cut_matches_grammar() {
        let mut s = ScenarioSpec::default();
        s.push_cut(1, 13);
        s.push_cut(0, 3);
        assert_eq!(s.to_string(), "cut[dev=1,send=13],cut[dev=0,send=3]");
        assert_eq!(ScenarioSpec::parse(&s.to_string()).unwrap(), s);
    }
}
