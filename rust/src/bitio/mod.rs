//! Bit-granular serialization substrate.
//!
//! Every "transmitted" object in the simulator is a real byte buffer built
//! here, so reported communication overheads are *measured*, not estimated.
//!
//! `write_radix` / `read_radix` implement near-entropy packing of symbols
//! drawn from an alphabet of arbitrary (non-power-of-2) size `q`: groups of
//! `k = floor(64 / log2 q)` symbols are combined into one base-q integer and
//! written in `ceil(k*log2 q)` bits, wasting < 1 bit per group. This matters
//! because the paper's optimal quantization levels (Theorem 1) are integers
//! like 3 or 5 whose ideal cost `log2 Q` is fractional.

pub mod reader;
pub mod writer;

pub use reader::{BitReader, BitReaderRef};
pub use writer::{BitWriter, BitWriterRef};

/// Symbols per 64-bit group for radix packing of base-`q` digits.
pub fn radix_group_len(q: u64) -> usize {
    assert!(q >= 2);
    let mut k = 0usize;
    let mut acc: u128 = 1;
    while acc * (q as u128) <= (u64::MAX as u128) + 1 {
        acc *= q as u128;
        k += 1;
    }
    k.max(1)
}

/// Bits needed to store one group of `k` base-`q` digits.
pub fn radix_group_bits(q: u64, k: usize) -> u32 {
    // ceil(log2(q^k)) computed exactly in u128
    let mut acc: u128 = 1;
    for _ in 0..k {
        acc *= q as u128;
    }
    128 - (acc - 1).leading_zeros()
}

/// Effective bits/symbol achieved by radix packing (for budget checks).
pub fn radix_bits_per_symbol(q: u64) -> f64 {
    let k = radix_group_len(q);
    radix_group_bits(q, k) as f64 / k as f64
}

/// Exact number of bits `write_radix(&[_; n], q)` emits — the stream length
/// is a pure function of (n, q), which is what lets encoders predict blob
/// sizes without a staging buffer.
pub fn radix_stream_bits(n: usize, q: u64) -> u64 {
    assert!(q >= 2);
    if q.is_power_of_two() {
        return n as u64 * q.trailing_zeros() as u64;
    }
    let k = radix_group_len(q);
    let full = (n / k) as u64 * radix_group_bits(q, k) as u64;
    let rem = n % k;
    full + if rem > 0 { radix_group_bits(q, rem) as u64 } else { 0 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn writer_reader_roundtrip_bits() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        w.write_bits(0xFFFF, 16);
        w.write_bits(0, 1);
        w.write_bits(0x1234_5678_9ABC_DEF0, 64);
        let bits = w.bit_len();
        let buf = w.into_bytes();
        assert_eq!(bits, 3 + 16 + 1 + 64);
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(3), 0b101);
        assert_eq!(r.read_bits(16), 0xFFFF);
        assert_eq!(r.read_bits(1), 0);
        assert_eq!(r.read_bits(64), 0x1234_5678_9ABC_DEF0);
    }

    #[test]
    fn f32_roundtrip() {
        let vals = [0.0f32, -1.5, f32::MAX, f32::MIN_POSITIVE, 3.14159];
        let mut w = BitWriter::new();
        for &v in &vals {
            w.write_f32(v);
        }
        let buf = w.into_bytes();
        let mut r = BitReader::new(&buf);
        for &v in &vals {
            assert_eq!(r.read_f32().to_bits(), v.to_bits());
        }
    }

    #[test]
    fn unaligned_f32_roundtrip() {
        let mut w = BitWriter::new();
        w.write_bits(0b11, 2);
        w.write_f32(-42.25);
        let buf = w.into_bytes();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(2), 0b11);
        assert_eq!(r.read_f32(), -42.25);
    }

    #[test]
    fn property_random_bit_sequences_roundtrip() {
        let mut rng = Rng::new(99);
        for _case in 0..50 {
            let n = 1 + rng.gen_range(64);
            let items: Vec<(u64, u32)> = (0..n)
                .map(|_| {
                    let bits = 1 + rng.gen_range(64) as u32;
                    let v = rng.next_u64() & if bits == 64 { u64::MAX } else { (1 << bits) - 1 };
                    (v, bits)
                })
                .collect();
            let mut w = BitWriter::new();
            for &(v, b) in &items {
                w.write_bits(v, b);
            }
            let buf = w.into_bytes();
            let mut r = BitReader::new(&buf);
            for &(v, b) in &items {
                assert_eq!(r.read_bits(b), v, "bits={b}");
            }
        }
    }

    #[test]
    fn radix_group_len_examples() {
        assert_eq!(radix_group_len(2), 64);
        assert_eq!(radix_group_len(3), 40); // 3^40 < 2^64 < 3^41
        assert_eq!(radix_group_len(256), 8);
        assert_eq!(radix_group_len(5), 27);
    }

    #[test]
    fn radix_efficiency_close_to_entropy() {
        for q in [2u64, 3, 5, 6, 7, 9, 100, 1000] {
            let ideal = (q as f64).log2();
            let eff = radix_bits_per_symbol(q);
            assert!(eff >= ideal - 1e-9, "q={q}");
            assert!(eff <= ideal + 0.05, "q={q} eff={eff} ideal={ideal}");
        }
    }

    #[test]
    fn radix_roundtrip_random() {
        let mut rng = Rng::new(5);
        for &q in &[2u64, 3, 5, 17, 200, 65536] {
            for _ in 0..5 {
                let n = rng.gen_range(200);
                let syms: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
                let mut w = BitWriter::new();
                w.write_radix(&syms, q);
                let nominal = n as f64 * (q as f64).log2();
                let actual = w.bit_len() as f64;
                assert!(actual <= nominal + 65.0, "q={q} n={n} actual={actual} nominal={nominal}");
                let buf = w.into_bytes();
                let mut r = BitReader::new(&buf);
                assert_eq!(r.read_radix(n, q), syms, "q={q}");
            }
        }
    }

    #[test]
    fn over_read_is_checked_error() {
        let mut w = BitWriter::new();
        w.write_bits(0b101, 3);
        let bits = w.bit_len();
        let buf = w.into_bytes();
        assert_eq!(buf.len(), 1); // 5 padding bits in the final byte

        // byte-bounded reader: the padding is still fenced at the byte edge
        let mut r = BitReader::new(&buf);
        assert_eq!(r.try_read_bits(8).unwrap(), 0b101);
        let e = r.try_read_bits(1).unwrap_err();
        assert_eq!(
            e,
            crate::compression::error::CodecError::BitstreamOverread {
                requested: 1,
                available: 0
            }
        );

        // bit-exact reader: reading INTO the final partial byte's padding is
        // an over-read, not a silent zero-fill
        let mut r = BitReader::with_bit_len(&buf, bits);
        assert_eq!(r.try_read_bits(2).unwrap(), 0b01);
        assert_eq!(r.bits_remaining(), 1);
        let e = r.try_read_bits(4).unwrap_err();
        assert_eq!(
            e,
            crate::compression::error::CodecError::BitstreamOverread {
                requested: 4,
                available: 1
            }
        );
        // the failed read consumed nothing
        assert_eq!(r.try_read_bits(1).unwrap(), 0b1);
    }

    #[test]
    fn over_read_radix_is_checked() {
        let mut w = BitWriter::new();
        w.write_radix(&[2, 1, 0, 2], 3);
        let bits = w.bit_len();
        let buf = w.into_bytes();
        let mut r = BitReader::with_bit_len(&buf, bits);
        assert!(r.try_read_radix(5, 3).is_err(), "5 symbols from a 4-symbol stream");
        let mut r = BitReader::with_bit_len(&buf, bits);
        assert_eq!(r.try_read_radix(4, 3).unwrap(), vec![2, 1, 0, 2]);
    }

    #[test]
    #[should_panic(expected = "over-read")]
    fn unchecked_read_past_end_panics() {
        let mut r = BitReader::new(&[0xAB]);
        r.read_bits(9);
    }

    #[test]
    #[should_panic]
    fn with_bit_len_validates_length() {
        BitReader::with_bit_len(&[0u8], 9);
    }

    #[test]
    fn radix_stream_bits_matches_writer() {
        let mut rng = Rng::new(17);
        for &q in &[2u64, 3, 5, 16, 17, 200, 1000, 65536] {
            for &n in &[0usize, 1, 7, 40, 41, 200] {
                let syms: Vec<u64> = (0..n).map(|_| rng.next_u64() % q).collect();
                let mut w = BitWriter::new();
                w.write_radix(&syms, q);
                assert_eq!(w.bit_len(), radix_stream_bits(n, q), "q={q} n={n}");
            }
        }
    }

    #[test]
    fn write_bytes_matches_per_byte_writes_at_every_alignment() {
        let payload: Vec<u8> = (0..37u8).map(|i| i.wrapping_mul(41).wrapping_add(7)).collect();
        for off in 0..8u32 {
            let mut a = BitWriter::new();
            let mut b = BitWriterRef::new();
            if off > 0 {
                a.write_bits(0x2A & ((1 << off) - 1), off);
                b.write_bits(0x2A & ((1 << off) - 1), off);
            }
            a.write_bytes(&payload);
            b.write_bytes(&payload);
            assert_eq!(a.bit_len(), b.bit_len(), "off={off}");
            assert_eq!(a.into_bytes(), b.into_bytes(), "off={off}");
        }
    }

    #[test]
    fn read_bytes_into_round_trips_at_every_alignment() {
        let payload: Vec<u8> = (0..29u8).map(|i| i.wrapping_mul(73).wrapping_add(3)).collect();
        for off in 0..8u32 {
            let mut w = BitWriter::new();
            if off > 0 {
                w.write_bits(0x55 & ((1 << off) - 1), off);
            }
            w.write_bytes(&payload);
            w.write_bits(0b11, 2); // trailing bits after the byte run
            let bits = w.bit_len();
            let buf = w.into_bytes();
            let mut r = BitReader::with_bit_len(&buf, bits);
            if off > 0 {
                r.read_bits(off);
            }
            let mut out = Vec::new();
            r.try_read_bytes_into(payload.len(), &mut out).unwrap();
            assert_eq!(out, payload, "off={off}");
            assert_eq!(r.read_bits(2), 0b11, "off={off}");
            // over-read past the limit is checked
            let mut out2 = Vec::new();
            assert!(r.try_read_bytes_into(1, &mut out2).is_err());
        }
    }

    #[test]
    fn radix_empty() {
        let mut w = BitWriter::new();
        w.write_radix(&[], 7);
        assert_eq!(w.bit_len(), 0);
        let buf = w.into_bytes();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_radix(0, 7), Vec::<u64>::new());
    }

    #[test]
    fn mixed_stream_roundtrip() {
        let mut w = BitWriter::new();
        w.write_bits(5, 4);
        w.write_radix(&[0, 1, 2, 1, 0, 2, 2], 3);
        w.write_f32(1.25);
        w.write_bits(1, 1);
        let buf = w.into_bytes();
        let mut r = BitReader::new(&buf);
        assert_eq!(r.read_bits(4), 5);
        assert_eq!(r.read_radix(7, 3), vec![0, 1, 2, 1, 0, 2, 2]);
        assert_eq!(r.read_f32(), 1.25);
        assert_eq!(r.read_bits(1), 1);
    }
}
