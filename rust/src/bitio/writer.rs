//! Bit-level writer: MSB-first within each appended field, LSB-packed bytes.

use super::{radix_group_bits, radix_group_len};

#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// Number of valid bits in the last byte (0 when aligned).
    bitpos: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { buf: Vec::with_capacity(bytes), bitpos: 0 }
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> u64 {
        if self.bitpos == 0 {
            self.buf.len() as u64 * 8
        } else {
            (self.buf.len() as u64 - 1) * 8 + self.bitpos as u64
        }
    }

    /// Append the low `nbits` of `value` (nbits in 0..=64).
    pub fn write_bits(&mut self, value: u64, nbits: u32) {
        debug_assert!(nbits <= 64);
        debug_assert!(nbits == 64 || value < (1u64 << nbits) || nbits == 0);
        let mut remaining = nbits;
        let mut v = value;
        while remaining > 0 {
            if self.bitpos == 0 {
                self.buf.push(0);
            }
            let free = 8 - self.bitpos;
            let take = free.min(remaining);
            let chunk = (v & ((1u64 << take) - 1)) as u8; // take <= 8 here
            let last = self.buf.len() - 1;
            self.buf[last] |= chunk << self.bitpos;
            self.bitpos = (self.bitpos + take) % 8;
            v >>= take;
            remaining -= take;
        }
    }

    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    pub fn write_u32(&mut self, x: u32) {
        self.write_bits(x as u64, 32);
    }

    /// Near-entropy packing of base-`q` symbols (see module docs).
    pub fn write_radix(&mut self, symbols: &[u64], q: u64) {
        assert!(q >= 2);
        debug_assert!(symbols.iter().all(|&s| s < q));
        if q.is_power_of_two() {
            let bits = q.trailing_zeros();
            for &s in symbols {
                self.write_bits(s, bits);
            }
            return;
        }
        let k = radix_group_len(q);
        let gbits = radix_group_bits(q, k);
        for group in symbols.chunks(k) {
            // little-endian base-q: group[0] is the least-significant digit
            let mut acc: u128 = 0;
            for &s in group.iter().rev() {
                acc = acc * q as u128 + s as u128;
            }
            let bits = if group.len() == k {
                gbits
            } else {
                radix_group_bits(q, group.len())
            };
            self.write_bits(acc as u64, bits);
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }

    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }
}
