//! Bit-level writer: MSB-first within each appended field, LSB-packed bytes.
//!
//! [`BitWriter`] is the word-level production implementation: bits collect in
//! a 64-bit accumulator and spill to the byte buffer a whole word at a time
//! (one `extend_from_slice` per 64 bits instead of a branchy `Vec::push` per
//! byte), with a byte-aligned bulk path for blob runs ([`BitWriter::write_bytes`]).
//! [`BitWriterRef`] keeps the original ≤8-bits-per-iteration implementation
//! as the oracle the property tests compare against, the same way the matmul
//! kernels keep their scalar `*_ref` twins.

use super::{radix_group_bits, radix_group_len};

#[derive(Default, Debug, Clone)]
pub struct BitWriter {
    buf: Vec<u8>,
    /// pending bits not yet spilled to `buf` (low `nbits` bits are valid)
    acc: u64,
    /// number of valid bits in `acc` (always < 64)
    nbits: u32,
}

impl BitWriter {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_capacity(bytes: usize) -> Self {
        Self { buf: Vec::with_capacity(bytes), acc: 0, nbits: 0 }
    }

    /// Reuse an existing buffer's capacity (scratch-arena path): the buffer
    /// is cleared, not reallocated.
    pub fn from_buf(mut buf: Vec<u8>) -> Self {
        buf.clear();
        Self { buf, acc: 0, nbits: 0 }
    }

    /// Pre-size the byte buffer for `bytes` more output (no-op when the
    /// capacity is already there — the steady-state arena case).
    pub fn reserve(&mut self, bytes: usize) {
        self.buf.reserve(bytes);
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> u64 {
        self.buf.len() as u64 * 8 + self.nbits as u64
    }

    /// Append the low `nbits` of `value` (nbits in 0..=64).
    #[inline]
    pub fn write_bits(&mut self, value: u64, nbits: u32) {
        debug_assert!(nbits <= 64);
        debug_assert!(nbits == 64 || value < (1u64 << nbits) || nbits == 0);
        if nbits == 0 {
            return;
        }
        let v = if nbits == 64 { value } else { value & ((1u64 << nbits) - 1) };
        // `nbits` of `v` land at bit position `self.nbits`; anything shifted
        // past bit 63 is recovered from `v` after the word spills.
        self.acc |= v << self.nbits;
        let filled = self.nbits + nbits;
        if filled >= 64 {
            self.buf.extend_from_slice(&self.acc.to_le_bytes());
            let consumed = 64 - self.nbits;
            self.acc = if consumed == 64 { 0 } else { v >> consumed };
            self.nbits = filled - 64;
        } else {
            self.nbits = filled;
        }
    }

    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    pub fn write_u32(&mut self, x: u32) {
        self.write_bits(x as u64, 32);
    }

    /// Append whole bytes. When the stream is byte-aligned this is a bulk
    /// `extend_from_slice` (the blob-embedding fast path); otherwise the
    /// bytes funnel through the accumulator a word at a time.
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        if self.nbits % 8 == 0 {
            // spill the accumulator's whole bytes, then memcpy
            while self.nbits > 0 {
                self.buf.push((self.acc & 0xFF) as u8);
                self.acc >>= 8;
                self.nbits -= 8;
            }
            self.buf.extend_from_slice(bytes);
            return;
        }
        let mut chunks = bytes.chunks_exact(8);
        for ch in chunks.by_ref() {
            let word = u64::from_le_bytes(ch.try_into().expect("8-byte chunk"));
            self.write_bits(word, 64);
        }
        for &b in chunks.remainder() {
            self.write_bits(b as u64, 8);
        }
    }

    /// Near-entropy packing of base-`q` symbols (see module docs).
    pub fn write_radix(&mut self, symbols: &[u64], q: u64) {
        assert!(q >= 2);
        debug_assert!(symbols.iter().all(|&s| s < q));
        if q.is_power_of_two() {
            let bits = q.trailing_zeros();
            for &s in symbols {
                self.write_bits(s, bits);
            }
            return;
        }
        let k = radix_group_len(q);
        let gbits = radix_group_bits(q, k);
        for group in symbols.chunks(k) {
            // little-endian base-q: group[0] is the least-significant digit
            let mut acc: u128 = 0;
            for &s in group.iter().rev() {
                acc = acc * q as u128 + s as u128;
            }
            let bits = if group.len() == k {
                gbits
            } else {
                radix_group_bits(q, group.len())
            };
            self.write_bits(acc as u64, bits);
        }
    }

    /// OR the low `nbits` of `value` into already-written bits starting at
    /// absolute position `bit_offset`. The target bits must have been
    /// written as zeros (the reserved-slot pattern: write a zero field,
    /// stream past it, patch the real value in once known) — patching ORs,
    /// it does not clear. Handles targets spanning the spilled-buffer /
    /// pending-accumulator boundary.
    pub fn patch_bits(&mut self, bit_offset: u64, value: u64, nbits: u32) {
        debug_assert!(nbits <= 64);
        debug_assert!(bit_offset + nbits as u64 <= self.bit_len());
        let buf_bits = self.buf.len() as u64 * 8;
        let mut off = bit_offset;
        let mut v = value;
        let mut remaining = nbits;
        while remaining > 0 && off < buf_bits {
            let byte = (off / 8) as usize;
            let bit = (off % 8) as u32;
            let take = (8 - bit).min(remaining);
            let chunk = (v & ((1u64 << take) - 1)) as u8;
            self.buf[byte] |= chunk << bit;
            v >>= take;
            off += take as u64;
            remaining -= take;
        }
        if remaining > 0 {
            // the rest of the target range is still in the accumulator
            self.acc |= (v & ((1u64 << remaining) - 1)) << (off - buf_bits);
        }
    }

    pub fn into_bytes(mut self) -> Vec<u8> {
        self.flush_partial();
        self.buf
    }

    /// Spill any pending accumulator bits as zero-padded bytes.
    fn flush_partial(&mut self) {
        let mut nb = self.nbits;
        let mut acc = self.acc;
        while nb > 0 {
            self.buf.push((acc & 0xFF) as u8);
            acc >>= 8;
            nb = nb.saturating_sub(8);
        }
        self.acc = 0;
        self.nbits = 0;
    }
}

/// The original per-bit writer, kept verbatim as the property-test oracle
/// (`rust/tests/prop_bitio_words.rs` asserts `BitWriter` output is
/// byte-identical to this for arbitrary op sequences).
#[derive(Default, Debug, Clone)]
pub struct BitWriterRef {
    buf: Vec<u8>,
    /// Number of valid bits in the last byte (0 when aligned).
    bitpos: u32,
}

impl BitWriterRef {
    pub fn new() -> Self {
        Self::default()
    }

    /// Total bits written so far.
    pub fn bit_len(&self) -> u64 {
        if self.bitpos == 0 {
            self.buf.len() as u64 * 8
        } else {
            (self.buf.len() as u64 - 1) * 8 + self.bitpos as u64
        }
    }

    /// Append the low `nbits` of `value` (nbits in 0..=64).
    pub fn write_bits(&mut self, value: u64, nbits: u32) {
        debug_assert!(nbits <= 64);
        debug_assert!(nbits == 64 || value < (1u64 << nbits) || nbits == 0);
        let mut remaining = nbits;
        let mut v = value;
        while remaining > 0 {
            if self.bitpos == 0 {
                self.buf.push(0);
            }
            let free = 8 - self.bitpos;
            let take = free.min(remaining);
            let chunk = (v & ((1u64 << take) - 1)) as u8; // take <= 8 here
            let last = self.buf.len() - 1;
            self.buf[last] |= chunk << self.bitpos;
            self.bitpos = (self.bitpos + take) % 8;
            v >>= take;
            remaining -= take;
        }
    }

    pub fn write_f32(&mut self, x: f32) {
        self.write_bits(x.to_bits() as u64, 32);
    }

    pub fn write_u32(&mut self, x: u32) {
        self.write_bits(x as u64, 32);
    }

    /// Byte run via the per-byte loop (the pre-word-level blob path).
    pub fn write_bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.write_bits(b as u64, 8);
        }
    }

    /// Near-entropy packing of base-`q` symbols (see module docs).
    pub fn write_radix(&mut self, symbols: &[u64], q: u64) {
        assert!(q >= 2);
        debug_assert!(symbols.iter().all(|&s| s < q));
        if q.is_power_of_two() {
            let bits = q.trailing_zeros();
            for &s in symbols {
                self.write_bits(s, bits);
            }
            return;
        }
        let k = radix_group_len(q);
        let gbits = radix_group_bits(q, k);
        for group in symbols.chunks(k) {
            let mut acc: u128 = 0;
            for &s in group.iter().rev() {
                acc = acc * q as u128 + s as u128;
            }
            let bits = if group.len() == k {
                gbits
            } else {
                radix_group_bits(q, group.len())
            };
            self.write_bits(acc as u64, bits);
        }
    }

    pub fn into_bytes(self) -> Vec<u8> {
        self.buf
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn patch_bits_matches_straight_line_write() {
        // reserve-zero-then-patch must equal writing the value in place,
        // across unaligned offsets and the buffer/accumulator boundary
        for (pre, nbits, post) in
            [(3u32, 40u32, 9u32), (0, 40, 0), (13, 40, 200), (64, 17, 5), (7, 63, 121)]
        {
            let mask = if nbits == 64 { u64::MAX } else { (1u64 << nbits) - 1 };
            let val: u64 = 0xA5B1_2345_6789_ABCD & mask;
            let mut patched = BitWriter::new();
            let mut straight = BitWriter::new();
            for i in 0..pre {
                patched.write_bits(((i / 3) % 2) as u64, 1);
                straight.write_bits(((i / 3) % 2) as u64, 1);
            }
            let at = patched.bit_len();
            patched.write_bits(0, nbits);
            straight.write_bits(val, nbits);
            for i in 0..post {
                patched.write_bits((i % 2) as u64, 1);
                straight.write_bits((i % 2) as u64, 1);
            }
            patched.patch_bits(at, val, nbits);
            assert_eq!(
                patched.into_bytes(),
                straight.into_bytes(),
                "pre={pre} nbits={nbits} post={post}"
            );
        }
    }
}
