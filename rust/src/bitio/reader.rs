//! Bit-level reader mirroring `BitWriter`'s layout.
//!
//! Reads are bounds-checked against a bit limit. `BitReader::new` bounds the
//! stream at whole bytes; when the producer knows the exact payload length
//! (`Frame::payload_bits`, blob headers), [`BitReader::with_bit_len`] tightens
//! the limit to the bit so that reading into the final partial byte's padding
//! is a [`CodecError::BitstreamOverread`] instead of a silent zero-fill.

use super::{radix_group_bits, radix_group_len};
use crate::compression::error::CodecError;

#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    byte: usize,
    bitpos: u32,
    /// Total readable bits (≤ buf.len() * 8).
    limit: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, byte: 0, bitpos: 0, limit: buf.len() as u64 * 8 }
    }

    /// Reader over a stream whose exact bit length is known (the writer's
    /// `bit_len()`): the padding bits of the last partial byte are fenced off.
    pub fn with_bit_len(buf: &'a [u8], bits: u64) -> Self {
        assert!(
            bits <= buf.len() as u64 * 8,
            "bit length {bits} exceeds buffer of {} bytes",
            buf.len()
        );
        Self { buf, byte: 0, bitpos: 0, limit: bits }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.byte as u64 * 8 + self.bitpos as u64
    }

    pub fn bits_remaining(&self) -> u64 {
        self.limit - self.bits_consumed()
    }

    /// Checked read of `nbits` (≤ 64): errors instead of reading past the
    /// stream's bit limit.
    pub fn try_read_bits(&mut self, nbits: u32) -> Result<u64, CodecError> {
        debug_assert!(nbits <= 64);
        if nbits as u64 > self.bits_remaining() {
            return Err(CodecError::BitstreamOverread {
                requested: nbits as u64,
                available: self.bits_remaining(),
            });
        }
        let mut out: u64 = 0;
        let mut got = 0u32;
        while got < nbits {
            let avail = 8 - self.bitpos;
            let take = avail.min(nbits - got);
            let mask = if take == 8 { 0xFFu8 } else { (1u8 << take) - 1 };
            let chunk = (self.buf[self.byte] >> self.bitpos) & mask;
            out |= (chunk as u64) << got;
            got += take;
            self.bitpos += take;
            if self.bitpos == 8 {
                self.bitpos = 0;
                self.byte += 1;
            }
        }
        Ok(out)
    }

    pub fn read_bits(&mut self, nbits: u32) -> u64 {
        self.try_read_bits(nbits)
            .unwrap_or_else(|e| panic!("BitReader: {e}"))
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    pub fn read_u32(&mut self) -> u32 {
        self.read_bits(32) as u32
    }

    /// Checked radix read of `n` base-`q` symbols.
    pub fn try_read_radix(&mut self, n: usize, q: u64) -> Result<Vec<u64>, CodecError> {
        assert!(q >= 2);
        if q.is_power_of_two() {
            let bits = q.trailing_zeros();
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                out.push(self.try_read_bits(bits)?);
            }
            return Ok(out);
        }
        let k = radix_group_len(q);
        let gbits = radix_group_bits(q, k);
        let mut out = Vec::with_capacity(n);
        let mut remaining = n;
        while remaining > 0 {
            let glen = remaining.min(k);
            let bits = if glen == k { gbits } else { radix_group_bits(q, glen) };
            let mut acc = self.try_read_bits(bits)? as u128;
            for _ in 0..glen {
                out.push((acc % q as u128) as u64);
                acc /= q as u128;
            }
            remaining -= glen;
        }
        Ok(out)
    }

    pub fn read_radix(&mut self, n: usize, q: u64) -> Vec<u64> {
        self.try_read_radix(n, q)
            .unwrap_or_else(|e| panic!("BitReader: {e}"))
    }
}
