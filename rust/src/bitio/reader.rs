//! Bit-level reader mirroring `BitWriter`'s layout.
//!
//! Reads are bounds-checked against a bit limit. `BitReader::new` bounds the
//! stream at whole bytes; when the producer knows the exact payload length
//! (`Frame::payload_bits`, blob headers), [`BitReader::with_bit_len`] tightens
//! the limit to the bit so that reading into the final partial byte's padding
//! is a [`CodecError::BitstreamOverread`] instead of a silent zero-fill.
//!
//! [`BitReader`] is the word-level production implementation: the stream
//! refills a 64-bit accumulator eight bytes at a time, with a byte-aligned
//! bulk path for blob runs ([`BitReader::try_read_bytes_into`]).
//! [`BitReaderRef`] keeps the original ≤8-bits-per-iteration implementation
//! as the property-test oracle.

use super::{radix_group_bits, radix_group_len};
use crate::compression::error::CodecError;

#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    /// byte offset of the next byte to load into the accumulator
    pos: usize,
    /// buffered bits (low `acc_bits` bits are valid, stream order from bit 0)
    acc: u64,
    acc_bits: u32,
    /// Total readable bits (≤ buf.len() * 8).
    limit: u64,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, pos: 0, acc: 0, acc_bits: 0, limit: buf.len() as u64 * 8 }
    }

    /// Reader over a stream whose exact bit length is known (the writer's
    /// `bit_len()`): the padding bits of the last partial byte are fenced off.
    pub fn with_bit_len(buf: &'a [u8], bits: u64) -> Self {
        assert!(
            bits <= buf.len() as u64 * 8,
            "bit length {bits} exceeds buffer of {} bytes",
            buf.len()
        );
        Self { buf, pos: 0, acc: 0, acc_bits: 0, limit: bits }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.pos as u64 * 8 - self.acc_bits as u64
    }

    pub fn bits_remaining(&self) -> u64 {
        self.limit - self.bits_consumed()
    }

    /// Checked read of `nbits` (≤ 64): errors instead of reading past the
    /// stream's bit limit. A failed read consumes nothing.
    #[inline]
    pub fn try_read_bits(&mut self, nbits: u32) -> Result<u64, CodecError> {
        debug_assert!(nbits <= 64);
        if nbits as u64 > self.bits_remaining() {
            return Err(CodecError::BitstreamOverread {
                requested: nbits as u64,
                available: self.bits_remaining(),
            });
        }
        if nbits == 0 {
            return Ok(0);
        }
        if self.acc_bits >= nbits {
            let out = if nbits == 64 { self.acc } else { self.acc & ((1u64 << nbits) - 1) };
            self.acc = if nbits == 64 { 0 } else { self.acc >> nbits };
            self.acc_bits -= nbits;
            return Ok(out);
        }
        // drain the accumulator, refill a word, take the remainder
        let got = self.acc_bits;
        let mut out = self.acc;
        self.acc = 0;
        self.acc_bits = 0;
        if self.pos + 8 <= self.buf.len() {
            self.acc =
                u64::from_le_bytes(self.buf[self.pos..self.pos + 8].try_into().expect("8 bytes"));
            self.pos += 8;
            self.acc_bits = 64;
        } else {
            while self.pos < self.buf.len() && self.acc_bits < 64 {
                self.acc |= (self.buf[self.pos] as u64) << self.acc_bits;
                self.pos += 1;
                self.acc_bits += 8;
            }
        }
        let need = nbits - got;
        debug_assert!(self.acc_bits >= need, "limit check guarantees buffered bits");
        let take = if need == 64 { self.acc } else { self.acc & ((1u64 << need) - 1) };
        out |= take << got; // got < 64 here (otherwise the fast path returned)
        self.acc = if need == 64 { 0 } else { self.acc >> need };
        self.acc_bits -= need;
        Ok(out)
    }

    pub fn read_bits(&mut self, nbits: u32) -> u64 {
        self.try_read_bits(nbits)
            .unwrap_or_else(|e| panic!("BitReader: {e}"))
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    pub fn read_u32(&mut self) -> u32 {
        self.read_bits(32) as u32
    }

    /// Checked read of `nbytes` whole bytes appended to `out`. When the
    /// stream is byte-aligned this is a bulk slice copy (the blob fast
    /// path); otherwise bytes funnel through the accumulator.
    pub fn try_read_bytes_into(
        &mut self,
        nbytes: usize,
        out: &mut Vec<u8>,
    ) -> Result<(), CodecError> {
        let need = nbytes as u64 * 8;
        if need > self.bits_remaining() {
            return Err(CodecError::BitstreamOverread {
                requested: need,
                available: self.bits_remaining(),
            });
        }
        out.reserve(nbytes);
        let mut left = nbytes;
        if self.acc_bits % 8 == 0 {
            // drain the accumulator's whole bytes, then memcpy the rest
            while self.acc_bits > 0 && left > 0 {
                out.push((self.acc & 0xFF) as u8);
                self.acc >>= 8;
                self.acc_bits -= 8;
                left -= 1;
            }
            out.extend_from_slice(&self.buf[self.pos..self.pos + left]);
            self.pos += left;
        } else {
            for _ in 0..left {
                let b = self.try_read_bits(8)?;
                out.push(b as u8);
            }
        }
        Ok(())
    }

    /// Checked radix read of `n` base-`q` symbols into a reusable buffer
    /// (cleared first).
    pub fn try_read_radix_into(
        &mut self,
        n: usize,
        q: u64,
        out: &mut Vec<u64>,
    ) -> Result<(), CodecError> {
        assert!(q >= 2);
        out.clear();
        out.reserve(n);
        if q.is_power_of_two() {
            let bits = q.trailing_zeros();
            for _ in 0..n {
                out.push(self.try_read_bits(bits)?);
            }
            return Ok(());
        }
        let k = radix_group_len(q);
        let gbits = radix_group_bits(q, k);
        let mut remaining = n;
        while remaining > 0 {
            let glen = remaining.min(k);
            let bits = if glen == k { gbits } else { radix_group_bits(q, glen) };
            let mut acc = self.try_read_bits(bits)? as u128;
            for _ in 0..glen {
                out.push((acc % q as u128) as u64);
                acc /= q as u128;
            }
            remaining -= glen;
        }
        Ok(())
    }

    /// Checked radix read of `n` base-`q` symbols.
    pub fn try_read_radix(&mut self, n: usize, q: u64) -> Result<Vec<u64>, CodecError> {
        let mut out = Vec::with_capacity(n);
        self.try_read_radix_into(n, q, &mut out)?;
        Ok(out)
    }

    pub fn read_radix(&mut self, n: usize, q: u64) -> Vec<u64> {
        self.try_read_radix(n, q)
            .unwrap_or_else(|e| panic!("BitReader: {e}"))
    }

    /// Panicking form of [`Self::try_read_radix_into`].
    pub fn read_radix_into(&mut self, n: usize, q: u64, out: &mut Vec<u64>) {
        self.try_read_radix_into(n, q, out)
            .unwrap_or_else(|e| panic!("BitReader: {e}"));
    }
}

/// The original per-bit reader, kept verbatim as the property-test oracle.
#[derive(Debug)]
pub struct BitReaderRef<'a> {
    buf: &'a [u8],
    byte: usize,
    bitpos: u32,
    /// Total readable bits (≤ buf.len() * 8).
    limit: u64,
}

impl<'a> BitReaderRef<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, byte: 0, bitpos: 0, limit: buf.len() as u64 * 8 }
    }

    pub fn with_bit_len(buf: &'a [u8], bits: u64) -> Self {
        assert!(
            bits <= buf.len() as u64 * 8,
            "bit length {bits} exceeds buffer of {} bytes",
            buf.len()
        );
        Self { buf, byte: 0, bitpos: 0, limit: bits }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.byte as u64 * 8 + self.bitpos as u64
    }

    pub fn bits_remaining(&self) -> u64 {
        self.limit - self.bits_consumed()
    }

    pub fn try_read_bits(&mut self, nbits: u32) -> Result<u64, CodecError> {
        debug_assert!(nbits <= 64);
        if nbits as u64 > self.bits_remaining() {
            return Err(CodecError::BitstreamOverread {
                requested: nbits as u64,
                available: self.bits_remaining(),
            });
        }
        let mut out: u64 = 0;
        let mut got = 0u32;
        while got < nbits {
            let avail = 8 - self.bitpos;
            let take = avail.min(nbits - got);
            let mask = if take == 8 { 0xFFu8 } else { (1u8 << take) - 1 };
            let chunk = (self.buf[self.byte] >> self.bitpos) & mask;
            out |= (chunk as u64) << got;
            got += take;
            self.bitpos += take;
            if self.bitpos == 8 {
                self.bitpos = 0;
                self.byte += 1;
            }
        }
        Ok(out)
    }

    pub fn read_bits(&mut self, nbits: u32) -> u64 {
        self.try_read_bits(nbits)
            .unwrap_or_else(|e| panic!("BitReaderRef: {e}"))
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    pub fn read_u32(&mut self) -> u32 {
        self.read_bits(32) as u32
    }

    pub fn try_read_radix(&mut self, n: usize, q: u64) -> Result<Vec<u64>, CodecError> {
        assert!(q >= 2);
        if q.is_power_of_two() {
            let bits = q.trailing_zeros();
            let mut out = Vec::with_capacity(n);
            for _ in 0..n {
                out.push(self.try_read_bits(bits)?);
            }
            return Ok(out);
        }
        let k = radix_group_len(q);
        let gbits = radix_group_bits(q, k);
        let mut out = Vec::with_capacity(n);
        let mut remaining = n;
        while remaining > 0 {
            let glen = remaining.min(k);
            let bits = if glen == k { gbits } else { radix_group_bits(q, glen) };
            let mut acc = self.try_read_bits(bits)? as u128;
            for _ in 0..glen {
                out.push((acc % q as u128) as u64);
                acc /= q as u128;
            }
            remaining -= glen;
        }
        Ok(out)
    }

    pub fn read_radix(&mut self, n: usize, q: u64) -> Vec<u64> {
        self.try_read_radix(n, q)
            .unwrap_or_else(|e| panic!("BitReaderRef: {e}"))
    }
}
