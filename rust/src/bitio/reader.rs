//! Bit-level reader mirroring `BitWriter`'s layout.

use super::{radix_group_bits, radix_group_len};

#[derive(Debug)]
pub struct BitReader<'a> {
    buf: &'a [u8],
    byte: usize,
    bitpos: u32,
}

impl<'a> BitReader<'a> {
    pub fn new(buf: &'a [u8]) -> Self {
        Self { buf, byte: 0, bitpos: 0 }
    }

    pub fn bits_consumed(&self) -> u64 {
        self.byte as u64 * 8 + self.bitpos as u64
    }

    pub fn read_bits(&mut self, nbits: u32) -> u64 {
        debug_assert!(nbits <= 64);
        let mut out: u64 = 0;
        let mut got = 0u32;
        while got < nbits {
            assert!(self.byte < self.buf.len(), "BitReader: out of data");
            let avail = 8 - self.bitpos;
            let take = avail.min(nbits - got);
            let mask = if take == 8 { 0xFFu8 } else { (1u8 << take) - 1 };
            let chunk = (self.buf[self.byte] >> self.bitpos) & mask;
            out |= (chunk as u64) << got;
            got += take;
            self.bitpos += take;
            if self.bitpos == 8 {
                self.bitpos = 0;
                self.byte += 1;
            }
        }
        out
    }

    pub fn read_f32(&mut self) -> f32 {
        f32::from_bits(self.read_bits(32) as u32)
    }

    pub fn read_u32(&mut self) -> u32 {
        self.read_bits(32) as u32
    }

    pub fn read_radix(&mut self, n: usize, q: u64) -> Vec<u64> {
        assert!(q >= 2);
        if q.is_power_of_two() {
            let bits = q.trailing_zeros();
            return (0..n).map(|_| self.read_bits(bits)).collect();
        }
        let k = radix_group_len(q);
        let gbits = radix_group_bits(q, k);
        let mut out = Vec::with_capacity(n);
        let mut remaining = n;
        while remaining > 0 {
            let glen = remaining.min(k);
            let bits = if glen == k { gbits } else { radix_group_bits(q, glen) };
            let mut acc = self.read_bits(bits) as u128;
            for _ in 0..glen {
                out.push((acc % q as u128) as u64);
                acc /= q as u128;
            }
            remaining -= glen;
        }
        out
    }
}
