//! Tiny property-testing harness (the offline registry has no `proptest`):
//! seeded random case generation with automatic shrinking of failing usize
//! parameter vectors. Used for coordinator/codec invariants.

use crate::tensor::Matrix;
use crate::util::Rng;

/// Heterogeneous-range feature matrix (the paper's Fig.-1 regime): column
/// scales cycle {4, 1, 0.2, 0.02, 0} — the 0-scale class yields constant
/// columns, so degenerate inputs are always represented. Shared fixture for
/// the hot-path benches and the cross-thread determinism tests.
pub fn hetero_matrix(b: usize, d: usize, seed: u64) -> Matrix {
    let mut rng = Rng::new(seed);
    Matrix::from_fn(b, d, |_, c| {
        let scale = [4.0, 1.0, 0.2, 0.02, 0.0][c % 5];
        scale * rng.normal_f32(0.0, 1.0) + (c % 13) as f32 * 0.1
    })
}

/// A parameter vector drawn from per-dimension inclusive ranges.
#[derive(Debug, Clone)]
pub struct ParamSpace {
    pub ranges: Vec<(usize, usize)>,
}

impl ParamSpace {
    pub fn new(ranges: &[(usize, usize)]) -> ParamSpace {
        assert!(ranges.iter().all(|&(lo, hi)| lo <= hi));
        ParamSpace { ranges: ranges.to_vec() }
    }

    fn sample(&self, rng: &mut Rng) -> Vec<usize> {
        self.ranges
            .iter()
            .map(|&(lo, hi)| lo + rng.gen_range(hi - lo + 1))
            .collect()
    }
}

/// Outcome of a property check over `cases` random parameter vectors.
pub enum PropResult {
    Ok { cases: usize },
    Failed { minimal: Vec<usize>, seed: u64, message: String },
}

/// Run `prop` on `cases` random draws from `space`; on failure, shrink each
/// coordinate toward its lower bound while the property still fails and
/// return the minimized counterexample.
pub fn check(
    seed: u64,
    cases: usize,
    space: &ParamSpace,
    prop: impl Fn(&[usize]) -> Result<(), String>,
) -> PropResult {
    let mut rng = Rng::new(seed);
    for case in 0..cases {
        let params = space.sample(&mut rng);
        if let Err(msg) = prop(&params) {
            let minimal = shrink(space, params, &prop);
            return PropResult::Failed { minimal, seed: seed + case as u64, message: msg };
        }
    }
    PropResult::Ok { cases }
}

fn shrink(
    space: &ParamSpace,
    mut failing: Vec<usize>,
    prop: &impl Fn(&[usize]) -> Result<(), String>,
) -> Vec<usize> {
    let mut progress = true;
    while progress {
        progress = false;
        for i in 0..failing.len() {
            let lo = space.ranges[i].0;
            while failing[i] > lo {
                // try halving the distance to the lower bound
                let trial_val = lo + (failing[i] - lo) / 2;
                let mut trial = failing.clone();
                trial[i] = trial_val;
                if prop(&trial).is_err() {
                    failing = trial;
                    progress = true;
                } else {
                    break;
                }
            }
            // linear refinement: halving overshoots the boundary by up to 2x
            while failing[i] > lo {
                let mut trial = failing.clone();
                trial[i] -= 1;
                if prop(&trial).is_err() {
                    failing = trial;
                    progress = true;
                } else {
                    break;
                }
            }
        }
    }
    failing
}

/// Assert helper: panics with the minimal counterexample on failure.
pub fn assert_prop(
    name: &str,
    seed: u64,
    cases: usize,
    space: &ParamSpace,
    prop: impl Fn(&[usize]) -> Result<(), String>,
) {
    match check(seed, cases, space, prop) {
        PropResult::Ok { .. } => {}
        PropResult::Failed { minimal, seed, message } => {
            panic!("property {name} failed (seed {seed}): {message}\n  minimal counterexample: {minimal:?}")
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_ok() {
        let space = ParamSpace::new(&[(1, 100), (1, 100)]);
        match check(0, 200, &space, |p| {
            if p[0] + p[1] >= 2 {
                Ok(())
            } else {
                Err("impossible".into())
            }
        }) {
            PropResult::Ok { cases } => assert_eq!(cases, 200),
            PropResult::Failed { .. } => panic!("should pass"),
        }
    }

    #[test]
    fn failing_property_shrinks_to_boundary() {
        let space = ParamSpace::new(&[(0, 1000)]);
        // fails iff x >= 17; minimal counterexample is 17
        match check(1, 500, &space, |p| {
            if p[0] >= 17 {
                Err(format!("{} >= 17", p[0]))
            } else {
                Ok(())
            }
        }) {
            PropResult::Ok { .. } => panic!("should fail"),
            PropResult::Failed { minimal, .. } => assert_eq!(minimal, vec![17]),
        }
    }

    #[test]
    fn samples_respect_ranges() {
        let space = ParamSpace::new(&[(5, 7), (0, 0)]);
        assert_prop("ranges", 2, 300, &space, |p| {
            if (5..=7).contains(&p[0]) && p[1] == 0 {
                Ok(())
            } else {
                Err(format!("{p:?}"))
            }
        });
    }
}
