//! Adaptive feature-wise dropout — FWDP (paper Sec. V, Algorithm 2).
//!
//! Columns of the intermediate feature matrix are dropped with probabilities
//! that *decrease* with the column's channel-normalized standard deviation
//! (eqs. 9-12), so high-dispersion (informative) features survive. Kept
//! columns are scaled by 1/(1-p_i) (eq. 7) to keep the compression unbiased:
//! E[F_hat] = F. The Bernoulli index vector δ is transmitted (D̄ bits) so the
//! PS can place the D̂ received columns; by the chain rule the PS only returns
//! gradient columns in the kept set I (eq. 8).
//!
//! `Random` (p_i = 1-1/R) and `Deterministic` (drop the D̄-D smallest-σ
//! columns) are the paper's Fig.-3 ablation variants.

use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DropKind {
    Adaptive,
    Random,
    Deterministic,
}

/// Everything the device derives before transmitting: probabilities, the
/// sampled mask, kept indices and the per-kept-column scale factors.
#[derive(Debug, Clone, Default)]
pub struct DropoutPlan {
    pub p: Vec<f64>,
    pub delta: Vec<bool>,
    pub kept: Vec<usize>,
    /// 1/(1-p_j) for each kept column j (aligned with `kept`).
    pub scale: Vec<f32>,
    /// merge scratch for the deterministic variant's allocation-free sort
    pub(crate) sort_aux: Vec<usize>,
}

impl DropoutPlan {
    /// No-dropout plan (R = 1 or vanilla frameworks).
    pub fn keep_all(dbar: usize) -> DropoutPlan {
        DropoutPlan {
            p: vec![0.0; dbar],
            delta: vec![true; dbar],
            kept: (0..dbar).collect(),
            scale: vec![1.0; dbar],
            sort_aux: Vec::new(),
        }
    }

    pub fn dhat(&self) -> usize {
        self.kept.len()
    }
}

/// Adaptive dropout probabilities (eqs. 11-12).
///
/// `sigma_norm` — per-column stddev of the channel-normalized features
/// (eq. 10, produced by the `feature_stats` artifact on the hot path);
/// `r` — dimensionality-reduction ratio R = D̄/D > 1.
pub fn adaptive_probs(sigma_norm: &[f32], r: f64) -> Vec<f64> {
    let mut p = Vec::new();
    adaptive_probs_into(sigma_norm, r, &mut p);
    p
}

/// Allocation-reusing form of [`adaptive_probs`]: `p` is cleared and
/// refilled (identical values — the fused wire path's per-step plan reuses
/// the session's buffer).
pub fn adaptive_probs_into(sigma_norm: &[f32], r: f64, p: &mut Vec<f64>) {
    let dbar = sigma_norm.len();
    assert!(dbar > 0);
    assert!(r >= 1.0, "R must be >= 1 (got {r})");
    p.clear();
    let d_target = dbar as f64 / r;
    let sum_sigma: f64 = sigma_norm.iter().map(|&s| s as f64).sum();
    if sum_sigma <= 0.0 || r <= 1.0 {
        // all-constant features (degenerate) or no reduction: uniform keep.
        let pi = (1.0 - d_target / dbar as f64).max(0.0);
        p.resize(dbar, pi);
        return;
    }
    // q_i staged in `p`, then transformed in place
    p.extend(sigma_norm.iter().map(|&s| s as f64 * d_target / sum_sigma));
    let q_max = p.iter().cloned().fold(0.0, f64::max);
    if q_max <= 1.0 {
        for qi in p.iter_mut() {
            *qi = (1.0 - *qi).clamp(0.0, 1.0);
        }
    } else {
        // eq. (12) second branch with the paper's minimal C_bias
        // C = (sigma_max * D - sum_sigma) / (Dbar - D)  (Sec. VII setup)
        let sigma_max = sigma_norm.iter().cloned().fold(0.0f32, f32::max) as f64;
        let denom = dbar as f64 - d_target;
        if denom <= 0.0 {
            p.clear();
            p.resize(dbar, 0.0);
            return;
        }
        let c_bias = ((sigma_max * d_target - sum_sigma) / denom).max(0.0);
        let adj_sum = sum_sigma + dbar as f64 * c_bias;
        for (pi, &s) in p.iter_mut().zip(sigma_norm) {
            *pi = (1.0 - (s as f64 + c_bias) * d_target / adj_sum).clamp(0.0, 1.0);
        }
    }
}

/// Fig.-3 "SplitFC-Rand": uniform p_i = 1 - 1/R.
pub fn random_probs(dbar: usize, r: f64) -> Vec<f64> {
    vec![(1.0 - 1.0 / r).clamp(0.0, 1.0); dbar]
}

/// Sample the Bernoulli index vector δ (Alg. 2 line 10).
pub fn sample_mask(p: &[f64], rng: &mut Rng) -> Vec<bool> {
    p.iter().map(|&pi| !rng.bernoulli(pi)).collect()
}

/// Build a full plan for the given variant.
pub fn plan(kind: DropKind, sigma_norm: &[f32], r: f64, rng: &mut Rng) -> DropoutPlan {
    let mut out = DropoutPlan::default();
    plan_into(kind, sigma_norm, r, rng, &mut out);
    out
}

/// Fill `out` with the no-dropout plan, reusing its buffers.
pub fn keep_all_into(dbar: usize, out: &mut DropoutPlan) {
    out.p.clear();
    out.p.resize(dbar, 0.0);
    out.delta.clear();
    out.delta.resize(dbar, true);
    out.kept.clear();
    out.kept.extend(0..dbar);
    out.scale.clear();
    out.scale.resize(dbar, 1.0);
}

/// Allocation-reusing form of [`plan`]: identical probabilities, identical
/// RNG draw order, identical kept set — the fused wire path's per-step plan
/// lives in the codec session's scratch arena.
pub fn plan_into(
    kind: DropKind,
    sigma_norm: &[f32],
    r: f64,
    rng: &mut Rng,
    out: &mut DropoutPlan,
) {
    let dbar = sigma_norm.len();
    // buffers are bounded by D̄, so capacity is pinned on the first step and
    // never regrows (the steady-state zero-allocation invariant); absolute
    // reservations — the buffers still hold the previous round's plan
    crate::util::reserve_total(&mut out.p, dbar);
    crate::util::reserve_total(&mut out.delta, dbar);
    crate::util::reserve_total(&mut out.kept, dbar);
    crate::util::reserve_total(&mut out.scale, dbar);
    crate::util::reserve_total(&mut out.sort_aux, dbar);
    if r <= 1.0 {
        keep_all_into(dbar, out);
        return;
    }
    match kind {
        DropKind::Adaptive => {
            adaptive_probs_into(sigma_norm, r, &mut out.p);
            out.delta.clear();
            out.delta.extend(out.p.iter().map(|&pi| !rng.bernoulli(pi)));
        }
        DropKind::Random => {
            out.p.clear();
            out.p.resize(dbar, (1.0 - 1.0 / r).clamp(0.0, 1.0));
            out.delta.clear();
            out.delta.extend(out.p.iter().map(|&pi| !rng.bernoulli(pi)));
        }
        DropKind::Deterministic => {
            // Fig.-3 "SplitFC-Deterministic": drop the (D̄ - D) columns with
            // the smallest normalized stddev; no stochastic scaling (p=0 on
            // kept columns so scale = 1; dropped have p = 1 conceptually).
            // `kept` doubles as the sort buffer and is rebuilt below.
            let d_keep = (dbar as f64 / r).round().max(1.0) as usize;
            out.kept.clear();
            out.kept.extend(0..dbar);
            // stable descending by σ without std's per-call merge-buffer
            // allocation (same permutation as the old `sort_by`)
            crate::util::sort::stable_sort_desc_by(&mut out.kept, &mut out.sort_aux, sigma_norm);
            out.delta.clear();
            out.delta.resize(dbar, false);
            for &i in out.kept.iter().take(d_keep) {
                out.delta[i] = true;
            }
            out.p.clear();
            out.p.extend(out.delta.iter().map(|&d| if d { 0.0 } else { 1.0 }));
        }
    }
    // rebuild kept/scale from (p, delta) — DropoutPlan::from_mask in place
    out.kept.clear();
    out.scale.clear();
    for (i, &d) in out.delta.iter().enumerate() {
        if d {
            out.kept.push(i);
            out.scale.push((1.0 / (1.0 - out.p[i])) as f32);
        }
    }
}

/// MSE of the dropout estimator (paper eq. 13):
/// E||F_hat - F||_F^2 = Σ_i p_i/(1-p_i) ||f_i||².
pub fn dropout_mse(p: &[f64], col_sq_norms: &[f64]) -> f64 {
    p.iter()
        .zip(col_sq_norms)
        .map(|(&pi, &n2)| {
            if pi >= 1.0 {
                n2 // dropped surely: error is ||f||^2 (limit)
            } else {
                pi / (1.0 - pi) * n2
            }
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sigma_ramp(d: usize) -> Vec<f32> {
        (0..d).map(|i| 0.01 + 0.49 * i as f32 / (d - 1) as f32).collect()
    }

    #[test]
    fn probs_are_valid_and_sum_matches_d() {
        let sigma = sigma_ramp(128);
        for &r in &[2.0, 4.0, 16.0, 64.0] {
            let p = adaptive_probs(&sigma, r);
            assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)), "r={r}");
            // E[D̂] = Σ(1-p_i) = D = D̄/R (Remark 1)
            let e_keep: f64 = p.iter().map(|&x| 1.0 - x).sum();
            let d = 128.0 / r;
            assert!((e_keep - d).abs() < d * 0.05 + 1e-6, "r={r} E={e_keep} D={d}");
        }
    }

    #[test]
    fn higher_sigma_lower_dropout() {
        let sigma = sigma_ramp(64);
        let p = adaptive_probs(&sigma, 8.0);
        for i in 1..64 {
            assert!(p[i] <= p[i - 1] + 1e-12, "monotone in sigma");
        }
    }

    #[test]
    fn cbias_branch_when_qmax_exceeds_one() {
        // One dominant sigma makes q_max > 1 at moderate R.
        let mut sigma = vec![0.001f32; 64];
        sigma[0] = 0.5;
        let p = adaptive_probs(&sigma, 4.0); // D = 16, q_0 = 0.5*16/0.563 >> 1
        assert!(p.iter().all(|&x| (0.0..=1.0).contains(&x)));
        // dominant column must never be dropped more than the others
        assert!(p[0] < p[1]);
        // with the paper's minimal C_bias the max-σ column gets p = 0
        assert!(p[0] < 1e-9, "p0={}", p[0]);
    }

    #[test]
    fn degenerate_all_zero_sigma_uniform() {
        let p = adaptive_probs(&vec![0.0f32; 32], 4.0);
        assert!(p.iter().all(|&x| (x - 0.75).abs() < 1e-12));
    }

    #[test]
    fn r_one_keeps_all() {
        let mut rng = Rng::new(0);
        let plan = plan(DropKind::Adaptive, &sigma_ramp(16), 1.0, &mut rng);
        assert_eq!(plan.dhat(), 16);
        assert!(plan.scale.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn sampled_dhat_concentrates_around_d() {
        let sigma = sigma_ramp(512);
        let mut rng = Rng::new(1);
        let p = adaptive_probs(&sigma, 16.0);
        let mut total = 0usize;
        let trials = 200;
        for _ in 0..trials {
            total += sample_mask(&p, &mut rng).iter().filter(|&&d| d).count();
        }
        let mean = total as f64 / trials as f64;
        assert!((mean - 32.0).abs() < 2.0, "mean D̂ = {mean}, expected ~32");
    }

    #[test]
    fn scale_is_inverse_keep_probability() {
        let sigma = sigma_ramp(64);
        let mut rng = Rng::new(2);
        let pl = plan(DropKind::Adaptive, &sigma, 4.0, &mut rng);
        for (j, &col) in pl.kept.iter().enumerate() {
            let expect = 1.0 / (1.0 - pl.p[col]);
            assert!((pl.scale[j] as f64 - expect).abs() < 1e-5);
        }
    }

    #[test]
    fn deterministic_keeps_top_sigma() {
        let sigma = sigma_ramp(32);
        let mut rng = Rng::new(3);
        let pl = plan(DropKind::Deterministic, &sigma, 4.0, &mut rng);
        assert_eq!(pl.dhat(), 8);
        // top-8 sigmas are indices 24..32
        assert_eq!(pl.kept, (24..32).collect::<Vec<_>>());
        assert!(pl.scale.iter().all(|&s| s == 1.0));
    }

    #[test]
    fn random_probs_uniform() {
        let p = random_probs(10, 8.0);
        assert!(p.iter().all(|&x| (x - 0.875).abs() < 1e-12));
    }

    #[test]
    fn dropout_mse_eq13() {
        let p = vec![0.5, 0.0, 0.75];
        let n2 = vec![4.0, 100.0, 8.0];
        // 0.5/0.5*4 + 0 + 0.75/0.25*8 = 4 + 24
        assert!((dropout_mse(&p, &n2) - 28.0).abs() < 1e-12);
    }

    #[test]
    fn unbiasedness_monte_carlo() {
        // E[δ/(1-p) f] = f: average reconstruction over many masks ≈ column.
        let sigma = sigma_ramp(16);
        let p = adaptive_probs(&sigma, 4.0);
        let f: Vec<f64> = (0..16).map(|i| (i as f64) - 8.0).collect();
        let mut rng = Rng::new(7);
        let mut acc = vec![0.0f64; 16];
        let trials = 30_000;
        for _ in 0..trials {
            let mask = sample_mask(&p, &mut rng);
            for i in 0..16 {
                if mask[i] {
                    acc[i] += f[i] / (1.0 - p[i]);
                }
            }
        }
        for i in 0..16 {
            let est = acc[i] / trials as f64;
            assert!(
                (est - f[i]).abs() < 0.35 + 0.05 * f[i].abs(),
                "i={i} est={est} f={}",
                f[i]
            );
        }
    }
}
