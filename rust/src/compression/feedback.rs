//! Error-feedback extension (SplitFC-EF).
//!
//! The paper's Sec. II cites error-feedback compression [20] as the FL-side
//! analogue of its dropout; a natural extension — flagged as such in
//! DESIGN.md — is to keep, per device, the residual F - F̂ of what the
//! uplink codec destroyed and add it back to the next round's feature
//! matrix before compressing. EF turns the per-round unbiased-but-noisy
//! estimator into a contraction: the *accumulated* error stays bounded and
//! the long-run average of transmitted features converges to the true
//! average even at extreme compression.
//!
//! This module is codec-level (state in, state out) so it composes with any
//! `Scheme`; `bench_ablation` quantifies the MSE effect over simulated
//! rounds without touching the training protocol.

use crate::compression::pipeline::{encode_uplink, CodecParams, EncodedUplink, Scheme};
use crate::tensor::{column_stats, normalized_sigma, Matrix};
use crate::util::Rng;

/// Per-device error-feedback state: the residual memory e_t (B×D̄).
#[derive(Debug, Clone)]
pub struct ErrorFeedback {
    pub residual: Matrix,
    /// decay on the carried residual (1.0 = classic EF; <1 damps staleness)
    pub decay: f32,
}

impl ErrorFeedback {
    pub fn new(batch: usize, dbar: usize) -> ErrorFeedback {
        ErrorFeedback { residual: Matrix::zeros(batch, dbar), decay: 1.0 }
    }

    /// The matrix to feed the codec: F + decay·e_t.
    pub fn compensate(&self, f: &Matrix) -> Matrix {
        let mut out = f.clone();
        for (o, &e) in out.data.iter_mut().zip(&self.residual.data) {
            *o += self.decay * e;
        }
        out
    }

    /// After encoding: e_{t+1} = (F + e_t) - F̂.
    pub fn update(&mut self, compensated: &Matrix, reconstructed: &Matrix) {
        for i in 0..self.residual.data.len() {
            self.residual.data[i] = compensated.data[i] - reconstructed.data[i];
        }
    }

    /// Residual update against a codec result's **unscaled** reconstruction.
    ///
    /// EF theory wants a *contractive* compressor; FWDP's 1/(1-p) inflation
    /// is unbiased but expansive, so the residual is computed against the
    /// reconstruction with kept columns divided back by their scale — with
    /// `DropKind::Deterministic` (scale = 1, keep-top-σ) this is exactly
    /// classic EF over a contractive operator. Shared by `encode_round` and
    /// the sessionful `splitfc[...,ef]` codec.
    pub fn absorb(&mut self, compensated: &Matrix, enc: &EncodedUplink) {
        let mut recon = enc.f_hat.clone();
        if let crate::compression::GradMask::Columns { kept, scale } = &enc.mask {
            for (j, &c) in kept.iter().enumerate() {
                if scale[j] != 1.0 {
                    recon.scale_col(c, 1.0 / scale[j]);
                }
            }
        }
        self.update(compensated, &recon);
    }

    pub fn residual_norm(&self) -> f64 {
        self.residual.sq_norm().sqrt()
    }

    /// One EF-compressed uplink round; returns the codec result.
    ///
    /// σ statistics are recomputed from the **compensated** matrix — the
    /// residual must be visible to the dropout plan, or stat-driven
    /// variants keep dropping the same columns and the error in them never
    /// rotates back in. The residual update goes through [`Self::absorb`].
    pub fn encode_round(
        &mut self,
        scheme: &Scheme,
        f: &Matrix,
        chan_size: usize,
        params: &CodecParams,
        rng: &mut Rng,
    ) -> EncodedUplink {
        let comp = self.compensate(f);
        let sigma = normalized_sigma(&column_stats(&comp), chan_size);
        let enc = encode_uplink(scheme, &comp, &sigma, params, rng);
        self.absorb(&comp, &enc);
        enc
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::Scheme;

    fn features(b: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(b, d, |_, c| {
            ([2.0, 0.5, 0.05, 0.0][c % 4]) * rng.normal_f32(0.0, 1.0) + 0.1 * c as f32
        })
    }

    #[test]
    fn residual_starts_zero_and_tracks_error() {
        let f = features(8, 16, 1);
        let mut ef = ErrorFeedback::new(8, 16);
        assert_eq!(ef.residual_norm(), 0.0);
        let comp = ef.compensate(&f);
        assert_eq!(comp, f); // zero residual: identity
        // pretend the codec destroyed half of every entry
        let mut rec = f.clone();
        for v in &mut rec.data {
            *v *= 0.5;
        }
        ef.update(&comp, &rec);
        let expect = (f.sq_norm() * 0.25).sqrt();
        assert!((ef.residual_norm() - expect).abs() < 1e-4 * expect.max(1.0));
    }

    #[test]
    fn ef_reduces_long_run_mean_error_vs_memoryless() {
        // The EF contraction: averaging F̂ over rounds approaches F much
        // faster with feedback than without at a fixed, harsh budget.
        // Deterministic dropout = contractive compressor (keep-top-σ, no
        // inflation): memoryless repeats the same columns forever, EF's
        // residual forces rotation through all of them.
        let f = features(16, 32, 2);
        let scheme = Scheme::SplitFc {
            drop: Some(crate::compression::DropKind::Deterministic),
            r: 8.0,
            quant: crate::compression::FwqMode::Optimal { use_mean: true },
        };
        let params = CodecParams::new(16, 32, 0.5);
        let rounds = 30;

        let mut ef = ErrorFeedback::new(16, 32);
        let mut rng = Rng::new(3);
        let mut mean_ef = Matrix::zeros(16, 32);
        for _ in 0..rounds {
            let enc = ef.encode_round(&scheme, &f, 4, &params, &mut rng);
            for (m, &v) in mean_ef.data.iter_mut().zip(&enc.f_hat.data) {
                *m += v / rounds as f32;
            }
        }

        let mut rng = Rng::new(3);
        let sigma = normalized_sigma(&column_stats(&f), 4);
        let mut mean_raw = Matrix::zeros(16, 32);
        for _ in 0..rounds {
            let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
            for (m, &v) in mean_raw.data.iter_mut().zip(&enc.f_hat.data) {
                *m += v / rounds as f32;
            }
        }
        let err_ef = f.sq_dist(&mean_ef);
        let err_raw = f.sq_dist(&mean_raw);
        assert!(
            err_ef < err_raw,
            "EF mean error {err_ef} should beat memoryless {err_raw}"
        );
    }

    #[test]
    fn residual_stays_bounded_over_many_rounds() {
        let f = features(8, 24, 4);
        let scheme = Scheme::SplitFc {
            drop: Some(crate::compression::DropKind::Deterministic),
            r: 4.0,
            quant: crate::compression::FwqMode::Optimal { use_mean: true },
        };
        let params = CodecParams::new(8, 24, 1.0);
        let mut ef = ErrorFeedback::new(8, 24);
        let mut rng = Rng::new(5);
        let mut norms = Vec::new();
        for _ in 0..50 {
            ef.encode_round(&scheme, &f, 3, &params, &mut rng);
            norms.push(ef.residual_norm());
        }
        let early = norms[..10].iter().cloned().fold(0.0, f64::max);
        let late = norms[40..].iter().cloned().fold(0.0, f64::max);
        assert!(
            late < 10.0 * early.max(f.sq_norm().sqrt()),
            "residual blow-up: early {early} late {late}"
        );
        assert!(norms.iter().all(|n| n.is_finite()));
    }

    #[test]
    fn decay_damps_residual() {
        let f = features(8, 16, 6);
        let scheme = Scheme::splitfc(8.0);
        let params = CodecParams::new(8, 16, 0.5);
        let run = |decay: f32| {
            let mut ef = ErrorFeedback::new(8, 16);
            ef.decay = decay;
            let mut rng = Rng::new(7);
            for _ in 0..20 {
                ef.encode_round(&scheme, &f, 2, &params, &mut rng);
            }
            ef.residual_norm()
        };
        // with decay < 1 the compensated signal carries less stale error
        assert!(run(0.5).is_finite() && run(1.0).is_finite());
    }
}
