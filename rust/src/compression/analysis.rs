//! Analysis replication: the paper's Remark-1 overhead model and the
//! Remark-2 convergence-bound machinery (eqs. 13-14), evaluated empirically
//! so tests can confirm the identities the proofs rely on.

use crate::compression::dropout::{dropout_mse, sample_mask};
use crate::tensor::Matrix;
use crate::util::Rng;

/// Remark 1: average uplink overhead of FWDP at ratio R (bits):
/// C_d = 32·B·D̄/R + D̄ (the second term is the index vector δ).
pub fn remark1_uplink_bits(batch: usize, dbar: usize, r: f64) -> f64 {
    32.0 * (batch * dbar) as f64 / r + dbar as f64
}

/// Remark 1: downlink overhead C_s = 32·B·D̄/R.
pub fn remark1_downlink_bits(batch: usize, dbar: usize, r: f64) -> f64 {
    32.0 * (batch * dbar) as f64 / r
}

/// The compression-error term of the convergence bound (eq. 14, last line):
/// Σ_i p_i/(1-p_i)·||f_i||² — identical to the dropout MSE of eq. (13).
pub fn eq14_error_term(f: &Matrix, p: &[f64]) -> f64 {
    let col_sq: Vec<f64> = (0..f.cols)
        .map(|c| f.col_iter(c).map(|v| (v as f64).powi(2)).sum())
        .collect();
    dropout_mse(p, &col_sq)
}

/// Monte-Carlo estimate of E‖F̂−F‖²_F under FWDP — must match eq. (13).
pub fn empirical_dropout_mse(f: &Matrix, p: &[f64], trials: usize, rng: &mut Rng) -> f64 {
    let mut total = 0.0;
    for _ in 0..trials {
        let mask = sample_mask(p, rng);
        let mut err = 0.0;
        for c in 0..f.cols {
            if mask[c] {
                let s = 1.0 / (1.0 - p[c]);
                for v in f.col_iter(c) {
                    let d = (s - 1.0) * v as f64;
                    err += d * d;
                }
            } else {
                for v in f.col_iter(c) {
                    err += (v as f64).powi(2);
                }
            }
        }
        total += err;
    }
    total / trials as f64
}

/// O(1/√(TK)) convergence-rate envelope of eq. (14) — the non-compression
/// part — for plotting/diagnostic purposes.
pub fn eq14_envelope(f_gap: f64, l_smooth: f64, sigma_sq: f64, t: usize, k: usize) -> f64 {
    let tk = (t * k) as f64;
    4.0 * f_gap / tk.sqrt() + 4.0 * l_smooth * sigma_sq / tk.sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compression::dropout::adaptive_probs;
    use crate::tensor::{column_stats, normalized_sigma};

    fn feature_matrix(b: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(b, d, |_, c| (0.2 + (c % 5) as f32) * rng.normal_f32(0.0, 1.0))
    }

    #[test]
    fn remark1_matches_paper_intro_numbers() {
        // B=256, D̄=8192, R=1: 32·B·D̄ bits per matrix
        let c = remark1_uplink_bits(256, 8192, 1.0);
        assert!((c - (32.0 * 256.0 * 8192.0 + 8192.0)).abs() < 1.0);
        // R halves → bits halve (minus the constant δ term)
        let a = remark1_uplink_bits(64, 1152, 8.0) - 1152.0;
        let b = remark1_uplink_bits(64, 1152, 16.0) - 1152.0;
        assert!((a / b - 2.0).abs() < 1e-9);
        assert!(remark1_downlink_bits(64, 1152, 8.0) < remark1_uplink_bits(64, 1152, 8.0));
    }

    #[test]
    fn eq13_identity_matches_monte_carlo() {
        let f = feature_matrix(12, 24, 1);
        let sigma = normalized_sigma(&column_stats(&f), 4);
        let p = adaptive_probs(&sigma, 4.0);
        let analytic = eq14_error_term(&f, &p);
        let mut rng = Rng::new(2);
        let empirical = empirical_dropout_mse(&f, &p, 4000, &mut rng);
        let rel = (analytic - empirical).abs() / analytic.max(1e-9);
        assert!(rel < 0.08, "analytic {analytic} vs empirical {empirical}");
    }

    #[test]
    fn error_term_zero_without_dropout() {
        let f = feature_matrix(6, 10, 3);
        assert_eq!(eq14_error_term(&f, &vec![0.0; 10]), 0.0);
    }

    #[test]
    fn error_term_grows_with_r() {
        let f = feature_matrix(16, 32, 4);
        let sigma = normalized_sigma(&column_stats(&f), 4);
        let mut last = 0.0;
        for r in [2.0, 4.0, 8.0, 16.0] {
            let e = eq14_error_term(&f, &adaptive_probs(&sigma, r));
            assert!(e > last, "r={r}: {e} <= {last}");
            last = e;
        }
    }

    #[test]
    fn envelope_decays_with_tk() {
        let e1 = eq14_envelope(1.0, 1.0, 1.0, 10, 10);
        let e2 = eq14_envelope(1.0, 1.0, 1.0, 40, 10);
        assert!((e1 / e2 - 2.0).abs() < 1e-9); // √4 = 2
    }
}
