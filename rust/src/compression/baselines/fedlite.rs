//! FedLite baseline [18]: K-means product (subvector) quantization of the
//! intermediate feature matrix.
//!
//! Each per-sample feature row (D̄ entries) is split into `s` subvectors of
//! length L = D̄/s; all B·s subvectors are clustered into q centroids with
//! K-means (one group, as in the paper's setup). The wire carries the q×L
//! f32 codebook + one ⌈log2 q⌉-symbol index per subvector. q is the largest
//! power of two whose codebook + indices fit the bit budget.

use crate::bitio::{BitReader, BitWriter};
use crate::tensor::Matrix;
use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct FedLiteConfig {
    /// number of subvectors per row (divides the matrix width)
    pub num_subvectors: usize,
    /// k-means iterations
    pub iters: usize,
}

impl Default for FedLiteConfig {
    fn default() -> Self {
        FedLiteConfig { num_subvectors: 16, iters: 12 }
    }
}

/// Largest centroid count q (power of two, >= 2) such that
/// q*L*32 + n_sub*log2(q) <= budget_bits. None if even q=2 doesn't fit.
pub fn pick_q(budget_bits: f64, sub_len: usize, n_subvectors_total: usize) -> Option<u64> {
    let mut best = None;
    for m in 1..=16u32 {
        let q = 1u64 << m;
        let cost = q as f64 * sub_len as f64 * 32.0 + n_subvectors_total as f64 * m as f64;
        if cost <= budget_bits {
            best = Some(q);
        } else {
            break;
        }
    }
    best
}

/// Standard K-means with k-means++ seeding and empty-cluster reseeding.
pub fn kmeans(
    points: &[Vec<f32>],
    k: usize,
    iters: usize,
    rng: &mut Rng,
) -> (Vec<Vec<f32>>, Vec<usize>) {
    assert!(!points.is_empty());
    let k = k.min(points.len()).max(1);
    let dim = points[0].len();
    let d2 = |a: &[f32], b: &[f32]| -> f64 {
        a.iter().zip(b).map(|(&x, &y)| ((x - y) as f64).powi(2)).sum()
    };
    // k-means++ init
    let mut centroids: Vec<Vec<f32>> = Vec::with_capacity(k);
    centroids.push(points[rng.gen_range(points.len())].clone());
    let mut dist: Vec<f64> = points.iter().map(|p| d2(p, &centroids[0])).collect();
    while centroids.len() < k {
        let total: f64 = dist.iter().sum();
        let pick = if total <= 0.0 {
            rng.gen_range(points.len())
        } else {
            let mut target = rng.next_f64() * total;
            let mut idx = 0;
            for (i, &d) in dist.iter().enumerate() {
                target -= d;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        centroids.push(points[pick].clone());
        let c = centroids.last().unwrap();
        for (i, p) in points.iter().enumerate() {
            let d = d2(p, c);
            if d < dist[i] {
                dist[i] = d;
            }
        }
    }
    let mut assign = vec![0usize; points.len()];
    for _ in 0..iters {
        // assignment
        for (i, p) in points.iter().enumerate() {
            let mut best = 0;
            let mut bd = f64::INFINITY;
            for (c, cent) in centroids.iter().enumerate() {
                let d = d2(p, cent);
                if d < bd {
                    bd = d;
                    best = c;
                }
            }
            assign[i] = best;
        }
        // update
        let mut sums = vec![vec![0.0f64; dim]; centroids.len()];
        let mut counts = vec![0usize; centroids.len()];
        for (i, p) in points.iter().enumerate() {
            counts[assign[i]] += 1;
            for (j, &v) in p.iter().enumerate() {
                sums[assign[i]][j] += v as f64;
            }
        }
        for c in 0..centroids.len() {
            if counts[c] == 0 {
                centroids[c] = points[rng.gen_range(points.len())].clone();
            } else {
                for j in 0..dim {
                    centroids[c][j] = (sums[c][j] / counts[c] as f64) as f32;
                }
            }
        }
    }
    // final assignment
    for (i, p) in points.iter().enumerate() {
        let mut best = 0;
        let mut bd = f64::INFINITY;
        for (c, cent) in centroids.iter().enumerate() {
            let d = d2(p, cent);
            if d < bd {
                bd = d;
                best = c;
            }
        }
        assign[i] = best;
    }
    (centroids, assign)
}

/// Encode F with subvector K-means under `budget_bits`. Returns (bytes, bits).
pub fn fedlite_encode(
    f: &Matrix,
    cfg: &FedLiteConfig,
    budget_bits: f64,
    rng: &mut Rng,
) -> (Vec<u8>, u64) {
    let d = f.cols;
    let s = cfg.num_subvectors.clamp(1, d);
    // force divisibility: shrink s to the nearest divisor of d
    let s = (1..=s).rev().find(|x| d % x == 0).unwrap_or(1);
    let sub_len = d / s;
    let n_sub = f.rows * s;
    let q = pick_q(budget_bits - 96.0, sub_len, n_sub).unwrap_or(2);

    let mut points = Vec::with_capacity(n_sub);
    for r in 0..f.rows {
        let row = f.row(r);
        for j in 0..s {
            points.push(row[j * sub_len..(j + 1) * sub_len].to_vec());
        }
    }
    let (centroids, assign) = kmeans(&points, q as usize, cfg.iters, rng);

    let mut w = BitWriter::new();
    w.write_u32(f.rows as u32);
    w.write_u32(s as u32);
    w.write_u32(sub_len as u32);
    w.write_bits(centroids.len() as u64, 17);
    for c in &centroids {
        for &v in c {
            w.write_f32(v);
        }
    }
    let syms: Vec<u64> = assign.iter().map(|&a| a as u64).collect();
    w.write_radix(&syms, centroids.len().max(2) as u64);
    let bits = w.bit_len();
    (w.into_bytes(), bits)
}

pub fn fedlite_decode(bytes: &[u8]) -> Matrix {
    let mut r = BitReader::new(bytes);
    let rows = r.read_u32() as usize;
    let s = r.read_u32() as usize;
    let sub_len = r.read_u32() as usize;
    let q = r.read_bits(17) as usize;
    let mut centroids = Vec::with_capacity(q);
    for _ in 0..q {
        centroids.push((0..sub_len).map(|_| r.read_f32()).collect::<Vec<f32>>());
    }
    let assign = r.read_radix(rows * s, q.max(2) as u64);
    let mut out = Matrix::zeros(rows, s * sub_len);
    for row in 0..rows {
        for j in 0..s {
            let cent = &centroids[assign[row * s + j] as usize];
            for (t, &v) in cent.iter().enumerate() {
                *out.at_mut(row, j * sub_len + t) = v;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn kmeans_separates_clear_clusters() {
        let mut rng = Rng::new(0);
        let mut pts = Vec::new();
        for i in 0..60 {
            let center = if i % 3 == 0 { 0.0 } else if i % 3 == 1 { 10.0 } else { -10.0 };
            pts.push(vec![center + rng.normal_f32(0.0, 0.1), center]);
        }
        let (cents, assign) = kmeans(&pts, 3, 15, &mut rng);
        assert_eq!(cents.len(), 3);
        // points in the same true cluster share an assignment
        for i in (0..60).step_by(3) {
            assert_eq!(assign[i], assign[(i + 3) % 60]);
        }
    }

    #[test]
    fn kmeans_handles_k_ge_n() {
        let mut rng = Rng::new(1);
        let pts = vec![vec![1.0], vec![2.0]];
        let (cents, assign) = kmeans(&pts, 8, 5, &mut rng);
        assert!(cents.len() <= 2);
        assert_eq!(assign.len(), 2);
    }

    #[test]
    fn pick_q_respects_budget() {
        // sub_len 8, 100 subvectors: q=2 costs 2*8*32+100 = 612
        assert_eq!(pick_q(611.0, 8, 100), None);
        assert_eq!(pick_q(612.0, 8, 100), Some(2));
        // generous budget should allow larger q
        assert!(pick_q(1e6, 8, 100).unwrap() >= 256);
    }

    #[test]
    fn roundtrip_shapes_and_compression() {
        let mut rng = Rng::new(2);
        let f = Matrix::from_fn(16, 32, |r, c| ((r + c) % 5) as f32 + 0.1 * rng.next_f32());
        let budget = 0.5 * 16.0 * 32.0 * 32.0; // half the raw size
        let (bytes, bits, ) = {
            let (b, bits) = fedlite_encode(&f, &FedLiteConfig { num_subvectors: 8, iters: 8 }, budget, &mut rng);
            (b, bits, )
        };
        assert!((bits as f64) <= budget + 256.0, "bits={bits}");
        let out = fedlite_decode(&bytes);
        assert_eq!((out.rows, out.cols), (16, 32));
        // structured data should compress with modest error
        let rel = (f.sq_dist(&out) / f.sq_norm()).sqrt();
        assert!(rel < 0.6, "rel={rel}");
    }

    #[test]
    fn subvector_count_snaps_to_divisor() {
        let mut rng = Rng::new(3);
        let f = Matrix::from_fn(4, 30, |_, c| c as f32);
        // 16 doesn't divide 30 -> snaps to 15
        let (bytes, _) = fedlite_encode(&f, &FedLiteConfig { num_subvectors: 16, iters: 2 }, 1e6, &mut rng);
        let out = fedlite_decode(&bytes);
        assert_eq!(out.cols, 30);
    }

    #[test]
    fn identical_rows_reconstruct_well() {
        let mut rng = Rng::new(4);
        let f = Matrix::from_fn(8, 16, |_, c| (c % 4) as f32);
        let (bytes, _) = fedlite_encode(&f, &FedLiteConfig::default(), 1e5, &mut rng);
        let out = fedlite_decode(&bytes);
        let rel = f.sq_dist(&out) / f.sq_norm().max(1.0);
        assert!(rel < 1e-3, "rel={rel}");
    }
}
