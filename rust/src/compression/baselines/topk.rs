//! Top-S and RandTop-S sparsification baselines ([16], [17]).
//!
//! Per paper Sec. VII: each *per-sample* intermediate feature vector (a row
//! of F, D̄ entries) keeps only S entries; RandTop-S picks S uniformly from
//! the top ⌈(1+θ)S⌉ magnitudes (the randomization of [17]). The budget rule
//! is the paper's: largest S with  S·v + log2(C(D̄, S)) ≤ D̄·C_e  where v is
//! the per-value cost (32 for raw floats, log2 Q̄ when composed with a scalar
//! quantizer).
//!
//! Wire format per row: kept indices (bitmap or fixed-width list, whichever
//! is smaller — real bits, slightly above the combinatorial bound the paper
//! accounts) + values.

use crate::bitio::{BitReader, BitWriter};
use crate::tensor::Matrix;
use crate::util::Rng;

/// ln C(n, k) via lgamma-free Stirling-exact sum (exact enough for budgets).
pub fn log2_binomial(n: usize, k: usize) -> f64 {
    if k == 0 || k >= n {
        return 0.0;
    }
    let k = k.min(n - k);
    let mut s = 0.0f64;
    for i in 0..k {
        s += ((n - i) as f64).log2() - ((i + 1) as f64).log2();
    }
    s
}

/// Paper's sparsification-level rule: largest S with
/// S*value_bits + log2 C(d, S) <= d * bits_per_entry.
pub fn sparsity_level(d: usize, bits_per_entry: f64, value_bits: f64) -> usize {
    let budget = d as f64 * bits_per_entry;
    let mut lo = 0usize;
    let mut hi = d;
    while lo < hi {
        let mid = (lo + hi + 1) / 2;
        let cost = mid as f64 * value_bits + log2_binomial(d, mid);
        if cost <= budget {
            lo = mid;
        } else {
            hi = mid - 1;
        }
    }
    lo
}

#[derive(Debug, Clone)]
pub struct TopSConfig {
    /// kept entries per row
    pub s: usize,
    /// RandTop-S randomization θ (0 = plain Top-S) [17]
    pub theta: f64,
}

/// Row-wise top-S mask of |value| (with optional RandTop-S randomization).
pub fn top_s_mask(f: &Matrix, cfg: &TopSConfig, rng: &mut Rng) -> Vec<Vec<usize>> {
    let s = cfg.s.min(f.cols).max(1);
    let mut out = Vec::with_capacity(f.rows);
    for r in 0..f.rows {
        let row = f.row(r);
        let pool = if cfg.theta > 0.0 {
            ((1.0 + cfg.theta) * s as f64).ceil() as usize
        } else {
            s
        }
        .min(f.cols);
        let mut idx: Vec<usize> = (0..f.cols).collect();
        // partial selection of the top `pool` by |v|
        idx.select_nth_unstable_by(pool.saturating_sub(1), |&a, &b| {
            row[b].abs().partial_cmp(&row[a].abs()).unwrap_or(std::cmp::Ordering::Equal)
        });
        idx.truncate(pool);
        let mut kept: Vec<usize> = if pool > s {
            // RandTop-S: uniform S-subset of the top pool
            let chosen = rng.sample_indices(pool, s);
            chosen.into_iter().map(|i| idx[i]).collect()
        } else {
            idx
        };
        kept.sort_unstable();
        out.push(kept);
    }
    out
}

/// Index coding cost decision: bitmap (d bits) vs fixed-width list.
fn index_bits(d: usize, s: usize) -> (bool, u32) {
    let iw = (usize::BITS - (d.max(2) - 1).leading_zeros()).max(1);
    let list = s as u64 * iw as u64;
    if (d as u64) <= list {
        (true, iw)
    } else {
        (false, iw)
    }
}

/// Encode: per row [index block][values f32]. Returns (bytes, bits, masks).
pub fn top_s_encode(
    f: &Matrix,
    cfg: &TopSConfig,
    rng: &mut Rng,
) -> (Vec<u8>, u64, Vec<Vec<usize>>) {
    let masks = top_s_mask(f, cfg, rng);
    let mut w = BitWriter::new();
    w.write_u32(f.rows as u32);
    w.write_u32(f.cols as u32);
    w.write_u32(cfg.s.min(f.cols).max(1) as u32);
    let (bitmap, iw) = index_bits(f.cols, cfg.s.min(f.cols).max(1));
    w.write_bits(bitmap as u64, 1);
    for (r, kept) in masks.iter().enumerate() {
        if bitmap {
            let mut flags = vec![false; f.cols];
            for &c in kept {
                flags[c] = true;
            }
            for &fl in &flags {
                w.write_bits(fl as u64, 1);
            }
        } else {
            for &c in kept {
                w.write_bits(c as u64, iw);
            }
        }
        for &c in kept {
            w.write_f32(f.at(r, c));
        }
    }
    let bits = w.bit_len();
    (w.into_bytes(), bits, masks)
}

pub fn top_s_decode(bytes: &[u8]) -> Matrix {
    let mut r = BitReader::new(bytes);
    let rows = r.read_u32() as usize;
    let cols = r.read_u32() as usize;
    let s = r.read_u32() as usize;
    let bitmap = r.read_bits(1) == 1;
    let iw = (usize::BITS - (cols.max(2) - 1).leading_zeros()).max(1);
    let mut out = Matrix::zeros(rows, cols);
    for row in 0..rows {
        let kept: Vec<usize> = if bitmap {
            let mut v = Vec::with_capacity(s);
            for c in 0..cols {
                if r.read_bits(1) == 1 {
                    v.push(c);
                }
            }
            v
        } else {
            (0..s).map(|_| r.read_bits(iw) as usize).collect()
        };
        for &c in &kept {
            *out.at_mut(row, c) = r.read_f32();
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mat(seed: u64, rows: usize, cols: usize) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| rng.normal_f32(0.0, 1.0))
    }

    #[test]
    fn log2_binomial_matches_small_cases() {
        // C(5,2)=10, C(10,3)=120
        assert!((log2_binomial(5, 2) - 10f64.log2()).abs() < 1e-9);
        assert!((log2_binomial(10, 3) - 120f64.log2()).abs() < 1e-9);
        assert_eq!(log2_binomial(7, 0), 0.0);
        assert_eq!(log2_binomial(7, 7), 0.0);
    }

    #[test]
    fn sparsity_level_respects_budget() {
        for &(d, bpe, vb) in &[(1152usize, 0.2, 32.0), (1152, 0.1, 32.0), (4096, 0.133, 8.0)] {
            let s = sparsity_level(d, bpe, vb);
            let cost = s as f64 * vb + log2_binomial(d, s);
            assert!(cost <= d as f64 * bpe + 1e-6, "d={d} s={s}");
            // maximality: s+1 must exceed
            let cost1 = (s + 1) as f64 * vb + log2_binomial(d, s + 1);
            assert!(cost1 > d as f64 * bpe, "s not maximal");
        }
    }

    #[test]
    fn top_s_keeps_largest_magnitudes() {
        let f = Matrix::from_vec(1, 6, vec![0.1, -5.0, 2.0, -0.2, 3.0, 0.05]);
        let mut rng = Rng::new(0);
        let masks = top_s_mask(&f, &TopSConfig { s: 3, theta: 0.0 }, &mut rng);
        assert_eq!(masks[0], vec![1, 2, 4]);
    }

    #[test]
    fn rand_top_s_subset_of_pool() {
        let f = mat(1, 4, 100);
        let mut rng = Rng::new(2);
        let cfg = TopSConfig { s: 10, theta: 0.3 };
        let masks = top_s_mask(&f, &cfg, &mut rng);
        for (r, kept) in masks.iter().enumerate() {
            assert_eq!(kept.len(), 10);
            // kept entries are within the top 13 by magnitude
            let row = f.row(r);
            let mut idx: Vec<usize> = (0..100).collect();
            idx.sort_by(|&a, &b| row[b].abs().partial_cmp(&row[a].abs()).unwrap());
            let top13: Vec<usize> = idx[..13].to_vec();
            for &c in kept {
                assert!(top13.contains(&c), "row {r}: {c} not in top pool");
            }
        }
    }

    #[test]
    fn encode_decode_roundtrip_values_exact() {
        let f = mat(3, 8, 64);
        let mut rng = Rng::new(4);
        let cfg = TopSConfig { s: 6, theta: 0.0 };
        let (bytes, bits, masks) = top_s_encode(&f, &cfg, &mut rng);
        assert!(bits > 0);
        let out = top_s_decode(&bytes);
        assert_eq!((out.rows, out.cols), (8, 64));
        for (r, kept) in masks.iter().enumerate() {
            for c in 0..64 {
                if kept.contains(&c) {
                    assert_eq!(out.at(r, c), f.at(r, c));
                } else {
                    assert_eq!(out.at(r, c), 0.0);
                }
            }
        }
    }

    #[test]
    fn bitmap_vs_list_picks_smaller() {
        // dense: s*iw > d -> bitmap; sparse: list
        let (bm_dense, _) = index_bits(64, 32); // 32*6=192 > 64
        assert!(bm_dense);
        let (bm_sparse, _) = index_bits(1024, 8); // 8*10=80 < 1024
        assert!(!bm_sparse);
    }

    #[test]
    fn mask_rows_sorted_unique() {
        let f = mat(5, 16, 40);
        let mut rng = Rng::new(6);
        for theta in [0.0, 0.2] {
            let masks = top_s_mask(&f, &TopSConfig { s: 5, theta }, &mut rng);
            for kept in &masks {
                let mut s = kept.clone();
                s.sort_unstable();
                s.dedup();
                assert_eq!(&s, kept);
                assert_eq!(kept.len(), 5);
            }
        }
    }
}
