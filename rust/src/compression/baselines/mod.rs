//! Baseline compressors the paper compares against (Table I/II).

pub mod fedlite;
pub mod scalarq;
pub mod topk;

pub use fedlite::{fedlite_decode, fedlite_encode, FedLiteConfig};
pub use scalarq::{
    qbar_levels, scalar_decode, scalar_decode_into, scalar_encode, scalar_encode_into, ScalarKind,
};
pub use topk::{sparsity_level, top_s_decode, top_s_encode, TopSConfig};
