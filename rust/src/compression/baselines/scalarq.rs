//! SOTA scalar-quantization baselines: PowerQuant [23], EasyQuant [24],
//! NoisyQuant [25] — re-implemented from the cited papers' core ideas as
//! entry-wise post-training quantizers at a given level count Q̄.
//!
//! * **EQ** — uniform quantizer whose clipping scale is grid-searched to
//!   minimize MSE (EasyQuant's scale optimization).
//! * **PQ** — power-law companding: quantize sign(v)·|v|^α uniformly and
//!   invert; the automorphism exponent α is grid-searched for MSE
//!   (PowerQuant's automorphism search).
//! * **NQ** — adds a shared pseudo-random uniform noise bias before uniform
//!   quantization and subtracts it after dequantization (NoisyQuant's
//!   noisy-bias trick); the noise seed is shared config, so the decoder
//!   regenerates the identical bias.
//!
//! Per paper Sec. VII these are combined with SplitFC-AD or Top-S to reach
//! sub-1-bit budgets; the level count is Q̄ = 2^{C_ava·R/(B·D̄)}.

use crate::bitio::{BitReader, BitWriter};
use crate::tensor::Matrix;
use crate::util::Rng;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScalarKind {
    Pq,
    Eq,
    Nq,
}

impl ScalarKind {
    pub fn name(self) -> &'static str {
        match self {
            ScalarKind::Pq => "PQ",
            ScalarKind::Eq => "EQ",
            ScalarKind::Nq => "NQ",
        }
    }
}

/// Paper's level rule for AD+scalar frameworks: Q̄ = 2^{C_ava R / (B D̄)}.
pub fn qbar_levels(c_ava: f64, r: f64, batch: usize, dbar: usize) -> u64 {
    let bits = c_ava * r / (batch as f64 * dbar as f64);
    (2f64.powf(bits).round() as u64).clamp(2, 1 << 16)
}

fn uniform_q(v: f64, lo: f64, hi: f64, q: u64) -> u64 {
    if hi <= lo || q < 2 {
        return 0;
    }
    let t = ((v.clamp(lo, hi) - lo) / (hi - lo) * (q as f64 - 1.0)).round();
    (t.max(0.0) as u64).min(q - 1)
}

fn uniform_dq(code: u64, lo: f64, hi: f64, q: u64) -> f64 {
    if hi <= lo || q < 2 {
        return lo;
    }
    lo + code as f64 * (hi - lo) / (q as f64 - 1.0)
}

fn mse_of(values: &[f32], deq: impl Fn(f32) -> f64) -> f64 {
    values.iter().map(|&v| (v as f64 - deq(v)).powi(2)).sum::<f64>() / values.len().max(1) as f64
}

/// EasyQuant: grid-search the symmetric clip scale for minimum MSE.
pub fn eq_params(values: &[f32], q: u64) -> f64 {
    let maxabs = values.iter().fold(0f32, |a, &v| a.max(v.abs())) as f64;
    if maxabs == 0.0 {
        return 1.0;
    }
    let mut best = (f64::INFINITY, maxabs);
    for i in 1..=20 {
        let s = maxabs * i as f64 / 20.0;
        let m = mse_of(values, |v| uniform_dq(uniform_q(v as f64, -s, s, q), -s, s, q));
        if m < best.0 {
            best = (m, s);
        }
    }
    best.1
}

/// PowerQuant: grid-search the companding exponent α for minimum MSE.
pub fn pq_params(values: &[f32], q: u64) -> (f64, f64) {
    let maxabs = values.iter().fold(0f32, |a, &v| a.max(v.abs())) as f64;
    if maxabs == 0.0 {
        return (1.0, 1.0);
    }
    let comp = |v: f64, alpha: f64| v.signum() * v.abs().powf(alpha);
    let mut best = (f64::INFINITY, 1.0);
    for i in 0..=14 {
        let alpha = 0.3 + 0.05 * i as f64;
        let s = comp(maxabs, alpha);
        let m = mse_of(values, |v| {
            let t = comp(v as f64, alpha);
            let dq = uniform_dq(uniform_q(t, -s, s, q), -s, s, q);
            dq.signum() * dq.abs().powf(1.0 / alpha)
        });
        if m < best.0 {
            best = (m, alpha);
        }
    }
    (best.1, comp(maxabs, best.1))
}

/// Encode a dense matrix entry-wise with the given scalar quantizer at q
/// levels. Wire: rows, cols, q (17b), kind params (f32s), radix codes.
///
/// Allocating wrapper over [`scalar_encode_into`].
pub fn scalar_encode(f: &Matrix, kind: ScalarKind, q: u64, noise_seed: u64) -> (Vec<u8>, u64) {
    let mut w = BitWriter::new();
    let mut codes = Vec::new();
    scalar_encode_into(f, kind, q, noise_seed, &mut w, &mut codes, None);
    let bits = w.bit_len();
    (w.into_bytes(), bits)
}

/// Scatter `codes` (row-major over the gathered matrix) into the kept
/// columns of the full-width reconstruction `rc`, dequantizing each code.
/// The closure is FnMut so the NQ path can regenerate its noise stream in
/// the decoder's exact (row-major index) order.
fn scatter_recon(codes: &[u64], kept: &[usize], rc: &mut Matrix, mut deq: impl FnMut(u64) -> f32) {
    let k = kept.len();
    for (r, row_codes) in codes.chunks_exact(k).enumerate() {
        let dst = &mut rc.data[r * rc.cols..(r + 1) * rc.cols];
        for (&c, &kc) in row_codes.iter().zip(kept) {
            dst[kc] = deq(c);
        }
    }
}

/// Streaming [`scalar_encode`]: the identical bit sequence goes straight
/// into the caller's `w` (no intermediate byte buffer), symbols stage in the
/// caller's `codes`, and — when `recon` is `Some((g_hat, kept))` — the
/// decoder-exact reconstruction is scattered into the kept columns of
/// `g_hat` in the same pass, so arena-backed codecs skip the
/// decode-own-frame round trip.
///
/// Reconstruction fidelity rule: quantization uses the full-precision f64
/// parameters (matching the historical `scalar_encode` bitstream), but
/// dequantization for `recon` uses the f32-**roundtripped** parameters,
/// because that is all the decoder ever sees on the wire.
#[allow(clippy::too_many_arguments)]
pub fn scalar_encode_into(
    f: &Matrix,
    kind: ScalarKind,
    q: u64,
    noise_seed: u64,
    w: &mut BitWriter,
    codes: &mut Vec<u64>,
    mut recon: Option<(&mut Matrix, &[usize])>,
) {
    let q = q.max(2);
    if let Some((rc, kept)) = recon.as_ref() {
        assert_eq!(rc.rows, f.rows, "recon row mismatch");
        assert_eq!(kept.len(), f.cols, "kept/gathered width mismatch");
    }
    w.write_u32(f.rows as u32);
    w.write_u32(f.cols as u32);
    w.write_bits(q, 17);
    codes.clear();
    match kind {
        ScalarKind::Eq => {
            let s = eq_params(&f.data, q);
            w.write_f32(s as f32);
            codes.extend(f.data.iter().map(|&v| uniform_q(v as f64, -s, s, q)));
            w.write_radix(codes, q);
            if let Some((rc, kept)) = recon.as_mut() {
                let sd = (s as f32) as f64;
                scatter_recon(codes, kept, rc, |c| uniform_dq(c, -sd, sd, q) as f32);
            }
        }
        ScalarKind::Pq => {
            let (alpha, s) = pq_params(&f.data, q);
            w.write_f32(alpha as f32);
            w.write_f32(s as f32);
            codes.extend(f.data.iter().map(|&v| {
                let t = (v as f64).signum() * (v as f64).abs().powf(alpha);
                uniform_q(t, -s, s, q)
            }));
            w.write_radix(codes, q);
            if let Some((rc, kept)) = recon.as_mut() {
                let ad = (alpha as f32) as f64;
                let sd = (s as f32) as f64;
                scatter_recon(codes, kept, rc, |c| {
                    let dq = uniform_dq(c, -sd, sd, q);
                    (dq.signum() * dq.abs().powf(1.0 / ad)) as f32
                });
            }
        }
        ScalarKind::Nq => {
            let maxabs = f.data.iter().fold(0f32, |a, &v| a.max(v.abs())) as f64;
            let s = if maxabs == 0.0 { 1.0 } else { maxabs };
            w.write_f32(s as f32);
            let delta = 2.0 * s / (q as f64 - 1.0);
            let mut nrng = Rng::new(noise_seed);
            codes.extend(f.data.iter().map(|&v| {
                let n = (nrng.next_f64() - 0.5) * delta;
                uniform_q(v as f64 + n, -s, s, q)
            }));
            w.write_radix(codes, q);
            if let Some((rc, kept)) = recon.as_mut() {
                let sd = (s as f32) as f64;
                let dd = 2.0 * sd / (q as f64 - 1.0);
                let mut drng = Rng::new(noise_seed);
                scatter_recon(codes, kept, rc, |c| {
                    let n = (drng.next_f64() - 0.5) * dd;
                    (uniform_dq(c, -sd, sd, q) - n) as f32
                });
            }
        }
    }
}

/// Allocating wrapper over [`scalar_decode_into`].
pub fn scalar_decode(bytes: &[u8], kind: ScalarKind, noise_seed: u64) -> Matrix {
    let mut codes = Vec::new();
    let mut out = Matrix::zeros(0, 0);
    scalar_decode_into(bytes, kind, noise_seed, &mut codes, &mut out);
    out
}

/// Scratch-reusing scalar decode: symbols stage in `codes`, the matrix is
/// rebuilt in `out` (capacity reused) — zero steady-state allocations.
pub fn scalar_decode_into(
    bytes: &[u8],
    kind: ScalarKind,
    noise_seed: u64,
    codes: &mut Vec<u64>,
    out: &mut Matrix,
) {
    let mut r = BitReader::new(bytes);
    let rows = r.read_u32() as usize;
    let cols = r.read_u32() as usize;
    let q = r.read_bits(17);
    out.rows = rows;
    out.cols = cols;
    out.data.clear();
    out.data.resize(rows * cols, 0.0);
    match kind {
        ScalarKind::Eq => {
            let s = r.read_f32() as f64;
            r.read_radix_into(rows * cols, q, codes);
            for (o, &c) in out.data.iter_mut().zip(codes.iter()) {
                *o = uniform_dq(c, -s, s, q) as f32;
            }
        }
        ScalarKind::Pq => {
            let alpha = r.read_f32() as f64;
            let s = r.read_f32() as f64;
            r.read_radix_into(rows * cols, q, codes);
            for (o, &c) in out.data.iter_mut().zip(codes.iter()) {
                let dq = uniform_dq(c, -s, s, q);
                *o = (dq.signum() * dq.abs().powf(1.0 / alpha)) as f32;
            }
        }
        ScalarKind::Nq => {
            let s = r.read_f32() as f64;
            let delta = 2.0 * s / (q as f64 - 1.0);
            r.read_radix_into(rows * cols, q, codes);
            let mut nrng = Rng::new(noise_seed);
            for (o, &c) in out.data.iter_mut().zip(codes.iter()) {
                let n = (nrng.next_f64() - 0.5) * delta;
                *o = (uniform_dq(c, -s, s, q) - n) as f32;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gaussian(seed: u64, rows: usize, cols: usize, scale: f32) -> Matrix {
        let mut rng = Rng::new(seed);
        Matrix::from_fn(rows, cols, |_, _| scale * rng.normal_f32(0.0, 1.0))
    }

    #[test]
    fn qbar_rule_matches_paper() {
        // C_ava = B*D*0.2 bits, R = 16 -> 3.2 bits/kept-entry -> Q̄ ≈ 9
        let q = qbar_levels(0.2 * 64.0 * 128.0, 16.0, 64, 128);
        assert_eq!(q, 9);
        assert!(qbar_levels(1.0, 1.0, 1000, 1000) >= 2); // floor at 2
    }

    #[test]
    fn all_kinds_roundtrip_with_bounded_error() {
        let f = gaussian(1, 16, 32, 2.0);
        for kind in [ScalarKind::Pq, ScalarKind::Eq, ScalarKind::Nq] {
            let (bytes, bits, ) = {
                let (b, bits) = scalar_encode(&f, kind, 64, 7);
                (b, bits)
            };
            assert!(bits > 0);
            let out = scalar_decode(&bytes, kind, 7);
            assert_eq!((out.rows, out.cols), (16, 32));
            let rel = (f.sq_dist(&out) / f.sq_norm()).sqrt();
            assert!(rel < 0.15, "{}: rel={rel}", kind.name());
        }
    }

    #[test]
    fn error_shrinks_with_levels() {
        let f = gaussian(2, 16, 16, 1.0);
        for kind in [ScalarKind::Pq, ScalarKind::Eq, ScalarKind::Nq] {
            let e = |q: u64| {
                let (b, _) = scalar_encode(&f, kind, q, 3);
                f.sq_dist(&scalar_decode(&b, kind, 3))
            };
            assert!(e(64) < e(4), "{}", kind.name());
        }
    }

    #[test]
    fn pq_helps_heavy_tails() {
        // Heavy-tailed values: companding should beat plain uniform (EQ with
        // s = maxabs) at very low levels.
        let mut rng = Rng::new(3);
        let f = Matrix::from_fn(32, 32, |_, _| {
            let z = rng.normal_f32(0.0, 1.0);
            z * z * z // cubed gaussian = heavy tails
        });
        let (bp, _) = scalar_encode(&f, ScalarKind::Pq, 8, 0);
        let ep = f.sq_dist(&scalar_decode(&bp, ScalarKind::Pq, 0));
        // naive uniform at full range for comparison
        let maxabs = f.data.iter().fold(0f32, |a, &v| a.max(v.abs())) as f64;
        let naive: f64 = f
            .data
            .iter()
            .map(|&v| {
                let c = uniform_q(v as f64, -maxabs, maxabs, 8);
                (v as f64 - uniform_dq(c, -maxabs, maxabs, 8)).powi(2)
            })
            .sum();
        assert!(ep < naive, "pq={ep} naive={naive}");
    }

    #[test]
    fn nq_decoder_needs_matching_seed() {
        let f = gaussian(4, 8, 8, 1.0);
        let (b, _) = scalar_encode(&f, ScalarKind::Nq, 16, 42);
        let good = scalar_decode(&b, ScalarKind::Nq, 42);
        let bad = scalar_decode(&b, ScalarKind::Nq, 43);
        assert!(f.sq_dist(&good) < f.sq_dist(&bad));
    }

    #[test]
    fn zero_matrix_roundtrips() {
        let f = Matrix::zeros(4, 4);
        for kind in [ScalarKind::Pq, ScalarKind::Eq, ScalarKind::Nq] {
            let (b, _) = scalar_encode(&f, kind, 8, 0);
            let out = scalar_decode(&b, kind, 0);
            assert!(out.data.iter().all(|&v| v.abs() < 0.2));
        }
    }

    #[test]
    fn streaming_encode_is_byte_identical_and_recon_matches_decode() {
        let b = 12;
        let dbar = 40;
        let full = gaussian(9, b, dbar, 1.5);
        let kept: Vec<usize> = (0..dbar).filter(|i| i % 3 != 2).collect();
        let f = full.gather_cols(&kept);
        for kind in [ScalarKind::Pq, ScalarKind::Eq, ScalarKind::Nq] {
            for q in [2u64, 9, 64] {
                let (bytes_ref, bits_ref) = scalar_encode(&f, kind, q, 77);
                let mut w = BitWriter::new();
                let mut codes = Vec::new();
                let mut recon = Matrix::zeros(b, dbar);
                scalar_encode_into(
                    &f,
                    kind,
                    q,
                    77,
                    &mut w,
                    &mut codes,
                    Some((&mut recon, &kept)),
                );
                assert_eq!(w.bit_len(), bits_ref, "{} q={q}", kind.name());
                assert_eq!(w.into_bytes(), bytes_ref, "{} q={q}", kind.name());
                // recon must be bit-exact with decode + scatter
                let dec = scalar_decode(&bytes_ref, kind, 77);
                let mut expect = Matrix::zeros(b, dbar);
                dec.scatter_cols_into(&kept, &mut expect);
                assert_eq!(recon, expect, "{} q={q}", kind.name());
                // and the _into decoder matches the allocating one
                let mut out = Matrix::zeros(0, 0);
                let mut syms = Vec::new();
                scalar_decode_into(&bytes_ref, kind, 77, &mut syms, &mut out);
                assert_eq!(out, dec, "{} q={q}", kind.name());
            }
        }
    }

    #[test]
    fn eq_scale_never_exceeds_maxabs() {
        let f = gaussian(5, 10, 10, 3.0);
        let s = eq_params(&f.data, 16);
        let maxabs = f.data.iter().fold(0f32, |a, &v| a.max(v.abs())) as f64;
        assert!(s <= maxabs + 1e-9 && s > 0.0);
    }
}
