//! Adaptive feature-wise quantization — FWQ (paper Sec. VI, Algorithm 3).
//!
//! The columns of a (compressed) intermediate matrix A ∈ R^{B×D̂} are split by
//! range: the M largest-range columns go through the **two-stage quantizer**
//! (endpoint quantizer with shared Q_ep levels → per-column uniform entry
//! quantizer with optimized Q_j levels); the remaining D̂-M columns are
//! collapsed to their means, quantized by the shared **mean-value quantizer**
//! (Q_0 levels). Levels solve problem (P) via `waterfill` (Theorem 1), and
//! M* is found by scanning a candidate set with the early-stop rule
//! (Alg. 3 lines 12-21).
//!
//! Everything is serialized to a real bit buffer; the decoder reconstructs
//! the matrix from the buffer and the *shared configuration only* (Q_ep,
//! C_ava, B — paper Sec. VI-B: both sides regenerate identical quantizers by
//! re-running the allocation on the transmitted endpoints/means, so no
//! codebooks are exchanged).

use crate::bitio::{BitReader, BitWriter};
use crate::compression::waterfill::{self, LevelSpec};
use crate::tensor::{column_stats, Matrix};
use crate::util::par;

/// Shared FWQ configuration — identical at device and PS.
#[derive(Debug, Clone)]
pub struct FwqConfig {
    /// Endpoint-quantizer levels Q_ep (paper Sec. VII: 200).
    pub q_ep: u64,
    /// Total bit budget C_ava for this matrix (eq. after (21)).
    pub c_ava: f64,
    pub batch: usize,
    /// false ⇒ ablation Case 3: no mean-value quantizer — columns beyond M*
    /// are not transmitted at all (reconstructed as zero).
    pub use_mean: bool,
    /// Some(q) ⇒ Fig. 5: fixed level q for every quantizer, no optimization.
    pub q_fixed: Option<u64>,
    /// Candidate-set size N (paper: 10, M = {D^max n/N}).
    pub n_candidates: usize,
}

impl FwqConfig {
    pub fn paper_default(batch: usize, c_ava: f64) -> FwqConfig {
        FwqConfig {
            q_ep: 200,
            c_ava,
            batch,
            use_mean: true,
            q_fixed: None,
            n_candidates: 10,
        }
    }
}

/// Encoder-side report (levels, M*, nominal bits per eq. 17).
#[derive(Debug, Clone)]
pub struct FwqInfo {
    pub m_star: usize,
    pub dhat: usize,
    /// nominal overhead per the paper's accounting (eq. 17), in bits
    pub nominal_bits: f64,
    /// objective value f(Q̂_0..Q̂_M) at the chosen solution
    pub objective: f64,
    pub q0: Option<u64>,
    pub candidates_tried: usize,
}

const HEADER_BITS: f64 = 32.0 + 32.0 + 4.0 * 32.0; // D̂, M, 4 range floats

struct Plan {
    m: usize,
    /// columns (original indices) using the two-stage quantizer, column order
    two_stage: Vec<usize>,
    /// remaining columns, column order
    mean_cols: Vec<usize>,
    a_min: f32,
    a_max: f32,
    abar_min: f32,
    abar_max: f32,
    /// endpoint codes per two-stage column (aligned with `two_stage`)
    ep_codes: Vec<(u64, u64)>,
    /// integer levels: entry levels aligned with `two_stage`, then the mean
    /// level (if any) last.
    levels: Vec<u64>,
    objective: f64,
}

fn delta_ep(a_min: f32, a_max: f32, q_ep: u64) -> f64 {
    // Degenerate quantizers — a single shared level (Q_ep ≤ 1) or a
    // constant/empty column set (a_max ≤ a_min) — get a 0-width interval:
    // every endpoint code collapses to 0 and columns decode exactly to
    // their endpoint a_min. The unguarded division produced NaN (0/0) or
    // ±inf deltas here, which poisoned the waterfill objective.
    if q_ep <= 1 || a_max <= a_min {
        return 0.0;
    }
    (a_max as f64 - a_min as f64) / (q_ep as f64 - 1.0)
}

/// Radix base for endpoint codes: `write_radix`/`read_radix` need q ≥ 2,
/// and a degenerate Q_ep ≤ 1 only ever produces 0-codes anyway.
fn ep_radix(q_ep: u64) -> u64 {
    q_ep.max(2)
}

/// Bits per endpoint symbol as actually serialized — log2 of the radix base,
/// so budget accounting (C_const, D^max, nominal bits) matches the stream
/// even for the degenerate Q_ep ≤ 1 case (1 bit/symbol, not 0).
fn lg_ep(q_ep: u64) -> f64 {
    (ep_radix(q_ep) as f64).log2()
}

/// Endpoint quantizer (eq. 15-16). Floor for the minimum, ceil for the
/// maximum so the decoded interval encloses the column:
/// â_{u_min} ≤ a_{b,j} ≤ â_{u_max} (the containment Sec. VI-A claims).
fn quantize_endpoints(
    lo: f32,
    hi: f32,
    a_min: f32,
    d_ep: f64,
    q_ep: u64,
) -> (u64, u64) {
    if d_ep <= 0.0 {
        return (0, 0);
    }
    let umin = (((lo as f64 - a_min as f64) / d_ep).floor() as i64).clamp(0, q_ep as i64 - 1);
    let umax = (((hi as f64 - a_min as f64) / d_ep).ceil() as i64).clamp(0, q_ep as i64 - 1);
    (umin as u64, umax.max(umin) as u64)
}

/// Build the quantization plan for one candidate M (levels + objective).
#[allow(clippy::too_many_arguments)]
fn plan_for_m(
    cfg: &FwqConfig,
    order: &[usize], // columns sorted by range descending
    mins: &[f32],
    maxs: &[f32],
    means: &[f32],
    m: usize,
) -> Option<Plan> {
    let dhat = order.len();
    let b = cfg.batch as f64;
    let mut two_stage: Vec<usize> = order[..m].to_vec();
    let mut mean_cols: Vec<usize> = order[m..].to_vec();
    two_stage.sort_unstable(); // column order for a canonical wire layout
    mean_cols.sort_unstable();

    // global endpoint range over the two-stage set (eq. 15)
    let (mut a_min, mut a_max) = (f32::INFINITY, f32::NEG_INFINITY);
    for &c in &two_stage {
        a_min = a_min.min(mins[c]);
        a_max = a_max.max(maxs[c]);
    }
    if two_stage.is_empty() {
        a_min = 0.0;
        a_max = 0.0;
    }
    let d_ep = delta_ep(a_min, a_max, cfg.q_ep);
    let ep_codes: Vec<(u64, u64)> = two_stage
        .iter()
        .map(|&c| quantize_endpoints(mins[c], maxs[c], a_min, d_ep, cfg.q_ep))
        .collect();

    // mean range over the mean set
    let (mut abar_min, mut abar_max) = (f32::INFINITY, f32::NEG_INFINITY);
    for &c in &mean_cols {
        abar_min = abar_min.min(means[c]);
        abar_max = abar_max.max(means[c]);
    }
    if mean_cols.is_empty() {
        abar_min = 0.0;
        abar_max = 0.0;
    }

    // constant overhead C_const (eq. 17 minus the level-dependent terms)
    let c_const = 2.0 * m as f64 * lg_ep(cfg.q_ep) + dhat as f64 + HEADER_BITS;
    let c_levels = cfg.c_ava - c_const;

    // level specs in canonical order: entries (column order), then mean
    let mut specs: Vec<LevelSpec> = ep_codes
        .iter()
        .map(|&(umin, umax)| LevelSpec::entry((umax - umin) as f64 * d_ep, cfg.batch))
        .collect();
    let use_mean_q = cfg.use_mean && !mean_cols.is_empty();
    if use_mean_q {
        specs.push(LevelSpec::mean(
            (abar_max - abar_min) as f64,
            cfg.batch,
            mean_cols.len(),
        ));
    }

    let levels = match cfg.q_fixed {
        Some(q) => vec![q.max(2); specs.len()],
        None => match waterfill::solve(&specs, c_levels) {
            Some(l) => l,
            // degenerate budget (< header + flags): fall back to minimum
            // levels for the all-means plan so a frame can always be built;
            // the overshoot shows up in the measured bits.
            None if m == 0 => vec![2; specs.len()],
            None => return None,
        },
    };

    // objective (eq. 22): level terms + the constant mean-residual term,
    // which *does* depend on M and must participate in the M* scan.
    let mut obj = waterfill::objective(&specs, &levels);
    if cfg.use_mean {
        for &c in &mean_cols {
            let r = (maxs[c] - mins[c]) as f64;
            obj += r * r * b / 2.0;
        }
    } else {
        // untransmitted columns reconstruct to 0: count their full energy
        // proxy via range² (upper bound flavour, keeps the scan meaningful)
        for &c in &mean_cols {
            let r = (maxs[c] - mins[c]).max(means[c].abs()) as f64;
            obj += r * r * b;
        }
    }

    Some(Plan {
        m,
        two_stage,
        mean_cols,
        a_min,
        a_max,
        abar_min,
        abar_max,
        ep_codes,
        levels,
        objective: obj,
    })
}

/// Largest feasible M for the budget (the paper's D^max in Sec. VII):
/// all-minimum allocation must fit: M(B + 2log2Qep - 1) ≤ C_ava - 2D̂ - 128.
fn d_max(cfg: &FwqConfig, dhat: usize) -> usize {
    let lg = lg_ep(cfg.q_ep);
    match cfg.q_fixed {
        None => {
            let num = cfg.c_ava - 2.0 * dhat as f64 - HEADER_BITS;
            let den = cfg.batch as f64 + 2.0 * lg - 1.0;
            ((num / den).floor().max(0.0) as usize).min(dhat)
        }
        Some(q) => {
            // Fig. 5 formula with fixed level q
            let lq = (q.max(2) as f64).log2();
            let num = cfg.c_ava - dhat as f64 - HEADER_BITS - dhat as f64 * lq;
            let den = cfg.batch as f64 * lq + 2.0 * lg - lq;
            ((num / den).floor().max(0.0) as usize).min(dhat)
        }
    }
}

/// Algorithm 3: scan the candidate set in descending order of M with the
/// early-stop rule, returning the best plan.
///
/// The candidates are planned **speculatively in parallel** (each
/// `plan_for_m` is a pure function of the shared stats), then the serial
/// early-stop rule (Alg. 3 l.12-21) is replayed over the results in
/// descending-M order. The selected plan — and therefore the emitted
/// bitstream — is identical to a sequential scan; plans past the stop point
/// are simply discarded.
fn search_m(
    cfg: &FwqConfig,
    order: &[usize],
    mins: &[f32],
    maxs: &[f32],
    means: &[f32],
) -> (Plan, usize) {
    let dhat = order.len();
    let dmax = d_max(cfg, dhat);
    let mut candidates: Vec<usize> = if cfg.use_mean {
        (1..=cfg.n_candidates)
            .map(|n| (dmax * n + cfg.n_candidates - 1) / cfg.n_candidates)
            .collect()
    } else {
        vec![dmax] // Case 3: as many two-stage columns as the budget allows
    };
    candidates.push(0); // pure mean-value fallback is always feasible-ish
    candidates.sort_unstable();
    candidates.dedup();
    candidates.reverse(); // descending M, the order Alg. 3 scans

    // The early-stop merge (Alg. 3 l.12-21) over descending-M plan results.
    // Lazy input iterators stop *planning* at the early stop, exactly like
    // the pre-parallel encoder.
    fn scan(plans: impl IntoIterator<Item = Option<Plan>>) -> (Option<Plan>, usize) {
        let mut best: Option<Plan> = None;
        let mut prev_obj = f64::INFINITY;
        let mut tried = 0;
        for p in plans {
            let Some(p) = p else { continue };
            tried += 1;
            let obj = p.objective;
            if best.as_ref().map(|b| obj < b.objective).unwrap_or(true) {
                best = Some(p);
            }
            if obj > prev_obj {
                break; // early stop
            }
            prev_obj = obj;
        }
        (best, tried)
    }

    // Speculate only when the pool will actually run the candidates
    // concurrently; on one worker, or below ~256 columns where a plan costs
    // microseconds, the lazy serial scan (with its genuine early stop and no
    // thread spawns) is strictly better. Even at 2 workers speculation
    // breaks even: plan cost scales with M, and the serial early stop
    // typically still pays for the few *largest* candidates (ΣM over all
    // candidates ≈ 5.5·M_max, so wall ≈ ΣM/workers vs ≈ 2-3·M_max serially).
    let (best, tried) = if dhat >= 256 && par::threads() > 1 {
        scan(par::par_map_idx(candidates.len(), 1, |i| {
            plan_for_m(cfg, order, mins, maxs, means, candidates[i])
        }))
    } else {
        scan(candidates.iter().map(|&m| plan_for_m(cfg, order, mins, maxs, means, m)))
    };
    // the scan set always contains M = 0, and the M = 0 plan always
    // constructs (the degenerate-budget fallback inside `plan_for_m`), so
    // the scan cannot come back empty: an early stop implies at least one
    // plan succeeded first. No second `plan_for_m` call is needed.
    let best = best.expect("candidate scan includes M = 0, which always constructs");
    (best, tried)
}

/// Quantize + serialize A (Alg. 3 lines 19-23 + the paper's overhead terms).
pub fn fwq_encode(a: &Matrix, cfg: &FwqConfig) -> (Vec<u8>, u64, FwqInfo) {
    let dhat = a.cols;
    assert_eq!(a.rows, cfg.batch);
    if dhat == 0 {
        let w = BitWriter::new();
        return (
            w.into_bytes(),
            0,
            FwqInfo { m_star: 0, dhat: 0, nominal_bits: 0.0, objective: 0.0, q0: None, candidates_tried: 0 },
        );
    }
    let st = column_stats(a);
    let ranges: Vec<f32> = st.ranges();
    let mut order: Vec<usize> = (0..dhat).collect();
    order.sort_by(|&x, &y| ranges[y].partial_cmp(&ranges[x]).unwrap_or(std::cmp::Ordering::Equal));

    let (plan, tried) = search_m(cfg, &order, &st.min, &st.max, &st.mean);

    // ---- serialize ----
    let mut w = BitWriter::with_capacity((cfg.c_ava / 8.0) as usize + 64);
    w.write_u32(dhat as u32);
    w.write_u32(plan.m as u32);
    w.write_f32(plan.a_min);
    w.write_f32(plan.a_max);
    w.write_f32(plan.abar_min);
    w.write_f32(plan.abar_max);
    // flags in column order
    let mut is_two = vec![false; dhat];
    for &c in &plan.two_stage {
        is_two[c] = true;
    }
    for &f in &is_two {
        w.write_bits(f as u64, 1);
    }
    // endpoint codes (column order, min then max), radix base Q_ep
    let mut ep_syms = Vec::with_capacity(2 * plan.m);
    for &(umin, umax) in &plan.ep_codes {
        ep_syms.push(umin);
        ep_syms.push(umax);
    }
    w.write_radix(&ep_syms, ep_radix(cfg.q_ep));

    let d_ep = delta_ep(plan.a_min, plan.a_max, cfg.q_ep);
    let use_mean_q = cfg.use_mean && !plan.mean_cols.is_empty();
    let q0 = if use_mean_q { Some(*plan.levels.last().unwrap()) } else { None };

    // mean codes
    if let Some(q0v) = q0 {
        let lo = plan.abar_min as f64;
        let span = (plan.abar_max - plan.abar_min) as f64;
        let syms: Vec<u64> = plan
            .mean_cols
            .iter()
            .map(|&c| quant_code(st.mean[c] as f64, lo, span, q0v))
            .collect();
        w.write_radix(&syms, q0v);
    }
    // entry codes per two-stage column: symbol computation fans out over the
    // pool (strided col_iter, no per-column Vec<f32> copy); serialization
    // stays sequential in column order, so the stream is byte-identical to a
    // single-threaded encode.
    // ≥ ~8k quantizations per claimed chunk so small frames stay inline
    let cols_per_chunk = (8192 / cfg.batch.max(1)).max(1);
    let col_syms: Vec<Vec<u64>> = par::par_map_idx(plan.two_stage.len(), cols_per_chunk, |j| {
        let c = plan.two_stage[j];
        let (umin, umax) = plan.ep_codes[j];
        let lo = plan.a_min as f64 + umin as f64 * d_ep;
        let span = (umax - umin) as f64 * d_ep;
        let qj = plan.levels[j];
        a.col_iter(c)
            .map(|v| quant_code(v as f64, lo, span, qj))
            .collect()
    });
    for (syms, &qj) in col_syms.iter().zip(&plan.levels) {
        w.write_radix(syms, qj);
    }

    // nominal accounting (eq. 17): 2M log2 Qep + B Σ log2 Qj
    //   + (D̂-M) log2 Q0 + D̂ + 32*4
    let mut nominal = 2.0 * plan.m as f64 * lg_ep(cfg.q_ep) + dhat as f64 + 128.0;
    for (j, _) in plan.two_stage.iter().enumerate() {
        nominal += cfg.batch as f64 * (plan.levels[j] as f64).log2();
    }
    if let Some(q0v) = q0 {
        nominal += plan.mean_cols.len() as f64 * (q0v as f64).log2();
    }

    let bits = w.bit_len();
    let info = FwqInfo {
        m_star: plan.m,
        dhat,
        nominal_bits: nominal,
        objective: plan.objective,
        q0,
        candidates_tried: tried,
    };
    (w.into_bytes(), bits, info)
}

#[inline]
fn quant_code(v: f64, lo: f64, span: f64, q: u64) -> u64 {
    if span <= 0.0 || q < 2 {
        return 0;
    }
    let t = ((v - lo) / span * (q as f64 - 1.0)).round();
    (t.max(0.0) as u64).min(q - 1)
}

#[inline]
fn dequant(code: u64, lo: f64, span: f64, q: u64) -> f32 {
    if q < 2 || span <= 0.0 {
        return lo as f32;
    }
    (lo + code as f64 * span / (q as f64 - 1.0)) as f32
}

/// Decode a FWQ frame back to a B×D̂ matrix. Needs only the shared config:
/// levels are re-derived by re-running the allocation on the decoded
/// endpoints/means (Sec. VI-B — both sides build identical quantizers).
pub fn fwq_decode(bytes: &[u8], cfg: &FwqConfig) -> Matrix {
    if bytes.is_empty() {
        return Matrix::zeros(cfg.batch, 0);
    }
    let mut r = BitReader::new(bytes);
    let dhat = r.read_u32() as usize;
    let m = r.read_u32() as usize;
    let a_min = r.read_f32();
    let a_max = r.read_f32();
    let abar_min = r.read_f32();
    let abar_max = r.read_f32();
    let is_two: Vec<bool> = (0..dhat).map(|_| r.read_bits(1) == 1).collect();
    let ep_syms = r.read_radix(2 * m, ep_radix(cfg.q_ep));
    let d_ep = delta_ep(a_min, a_max, cfg.q_ep);

    let two_stage: Vec<usize> = (0..dhat).filter(|&c| is_two[c]).collect();
    assert_eq!(two_stage.len(), m, "flag/M mismatch in frame");
    let mean_cols: Vec<usize> = (0..dhat).filter(|&c| !is_two[c]).collect();

    // re-derive the levels exactly as the encoder did
    let c_const = 2.0 * m as f64 * lg_ep(cfg.q_ep) + dhat as f64 + HEADER_BITS;
    let c_levels = cfg.c_ava - c_const;
    let mut specs: Vec<LevelSpec> = (0..m)
        .map(|j| {
            let (umin, umax) = (ep_syms[2 * j], ep_syms[2 * j + 1]);
            LevelSpec::entry((umax - umin) as f64 * d_ep, cfg.batch)
        })
        .collect();
    let use_mean_q = cfg.use_mean && !mean_cols.is_empty();
    if use_mean_q {
        specs.push(LevelSpec::mean(
            (abar_max - abar_min) as f64,
            cfg.batch,
            mean_cols.len(),
        ));
    }
    let levels = match cfg.q_fixed {
        Some(q) => vec![q.max(2); specs.len()],
        // mirrors the encoder exactly, including the degenerate-budget
        // minimum-level fallback for the all-means plan
        None => waterfill::solve(&specs, c_levels).unwrap_or_else(|| vec![2; specs.len()]),
    };

    let mut out = Matrix::zeros(cfg.batch, dhat);
    // mean codes
    if use_mean_q {
        let q0 = *levels.last().unwrap();
        let lo = abar_min as f64;
        let span = (abar_max - abar_min) as f64;
        let syms = r.read_radix(mean_cols.len(), q0);
        for (k, &c) in mean_cols.iter().enumerate() {
            let v = dequant(syms[k], lo, span, q0);
            for b in 0..cfg.batch {
                *out.at_mut(b, c) = v;
            }
        }
    }
    // entry codes
    for (j, &c) in two_stage.iter().enumerate() {
        let (umin, umax) = (ep_syms[2 * j], ep_syms[2 * j + 1]);
        let lo = a_min as f64 + umin as f64 * d_ep;
        let span = (umax - umin) as f64 * d_ep;
        let qj = levels[j];
        let syms = r.read_radix(cfg.batch, qj);
        for b in 0..cfg.batch {
            *out.at_mut(b, c) = dequant(syms[b], lo, span, qj);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Matrix with heterogeneous column ranges (the paper's Fig.-1 regime).
    fn hetero(b: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let scales: Vec<f32> = (0..d)
            .map(|i| if i % 3 == 0 { 10.0 } else if i % 3 == 1 { 0.5 } else { 0.01 })
            .collect();
        Matrix::from_fn(b, d, |_r, c| {
            scales[c] * rng.normal_f32(0.0, 1.0) + c as f32 * 0.1
        })
    }

    fn cfg(b: usize, d: usize, bits_per_entry: f64) -> FwqConfig {
        FwqConfig::paper_default(b, bits_per_entry * b as f64 * d as f64)
    }

    #[test]
    fn roundtrip_within_budget() {
        let a = hetero(32, 64, 1);
        let c = cfg(32, 64, 2.0);
        let (bytes, bits, info) = fwq_encode(&a, &c);
        // measured bits within budget (+ radix slack < 1 bit/group)
        assert!(bits as f64 <= c.c_ava * 1.02 + 256.0, "bits={bits} c_ava={}", c.c_ava);
        assert!(info.nominal_bits <= c.c_ava + 1e-6);
        let out = fwq_decode(&bytes, &c);
        assert_eq!((out.rows, out.cols), (32, 64));
        // two-stage columns should be far more accurate than raw range
        let rel = (a.sq_dist(&out) / a.sq_norm()).sqrt();
        assert!(rel < 0.5, "relative error {rel}");
    }

    #[test]
    fn decode_is_exact_inverse_of_encode_quantization() {
        // re-encoding the decoded matrix must be a fixed point (codes stable)
        let a = hetero(16, 24, 2);
        let c = cfg(16, 24, 3.0);
        let (bytes, _, _) = fwq_encode(&a, &c);
        let out1 = fwq_decode(&bytes, &c);
        let (bytes2, _, _) = fwq_encode(&out1, &c);
        let out2 = fwq_decode(&bytes2, &c);
        let d = out1.sq_dist(&out2).sqrt();
        let scale = out1.sq_norm().sqrt().max(1.0);
        // second pass re-derives grids from decoded (already on-grid) stats,
        // so it should move the matrix far less than the first quantization
        assert!(d < 0.05 * scale, "not a near-fixed-point: {d} vs {scale}");
    }

    #[test]
    fn error_bound_eq19_holds_per_two_stage_column() {
        let a = hetero(24, 32, 3);
        let c = cfg(24, 32, 4.0);
        let (bytes, _, info) = fwq_encode(&a, &c);
        let out = fwq_decode(&bytes, &c);
        // total error is bounded by the objective at the solution (eqs. 19-21
        // are upper bounds, and the objective adds the mean-residual term)
        let err: f64 = a.sq_dist(&out);
        assert!(
            err <= info.objective * 1.5 + 1e-6,
            "err={err} bound={}",
            info.objective
        );
    }

    #[test]
    fn more_budget_less_error() {
        let a = hetero(32, 48, 4);
        let mut last = f64::INFINITY;
        for bpe in [0.5, 1.0, 2.0, 4.0, 8.0] {
            let c = cfg(32, 48, bpe);
            let (bytes, _, _) = fwq_encode(&a, &c);
            let out = fwq_decode(&bytes, &c);
            let err = a.sq_dist(&out);
            assert!(
                err <= last * 1.3 + 1e-9,
                "bpe={bpe}: err={err} prev={last}"
            );
            last = err.min(last);
        }
    }

    #[test]
    fn small_range_columns_use_mean_quantizer() {
        let a = hetero(16, 30, 5);
        let c = cfg(16, 30, 1.0); // tight budget forces mean usage
        let (_, _, info) = fwq_encode(&a, &c);
        assert!(info.m_star < 30, "M*={} should leave mean columns", info.m_star);
        assert!(info.q0.is_some());
    }

    #[test]
    fn sub_one_bit_per_entry_regime() {
        // the paper's headline: < 1 bit/entry uplink. 0.2 bits/entry here.
        let a = hetero(64, 128, 6);
        let c = cfg(64, 128, 0.2);
        let (bytes, bits, info) = fwq_encode(&a, &c);
        assert!(bits as f64 <= c.c_ava * 1.05 + 512.0, "bits={bits}");
        let out = fwq_decode(&bytes, &c);
        assert_eq!(out.cols, 128);
        assert!(info.m_star <= 128);
        // constant columns must reconstruct near-exactly via means
        let rel = (a.sq_dist(&out) / a.sq_norm()).sqrt();
        assert!(rel < 1.0, "rel={rel}");
    }

    #[test]
    fn constant_matrix_reconstructs_exactly() {
        let a = Matrix::from_fn(8, 16, |_, _| 3.25);
        let c = cfg(8, 16, 1.0);
        let (bytes, _, _) = fwq_encode(&a, &c);
        let out = fwq_decode(&bytes, &c);
        for v in &out.data {
            assert!((v - 3.25).abs() < 1e-5, "v={v}");
        }
    }

    #[test]
    fn fixed_q_mode_fig5() {
        let a = hetero(32, 64, 7);
        for q in [2u64, 4, 8, 32] {
            let mut c = cfg(32, 64, 2.0);
            c.q_fixed = Some(q);
            let (bytes, bits, info) = fwq_encode(&a, &c);
            let out = fwq_decode(&bytes, &c);
            assert_eq!(out.cols, 64);
            assert!(bits > 0);
            assert!(info.m_star <= 64);
        }
    }

    #[test]
    fn optimized_beats_worst_fixed_q() {
        // Fig. 5's claim at matrix level: optimal levels ≤ error of Q=32.
        let a = hetero(32, 96, 8);
        let c_opt = cfg(32, 96, 1.0);
        let (b1, _, _) = fwq_encode(&a, &c_opt);
        let e_opt = a.sq_dist(&fwq_decode(&b1, &c_opt));
        let mut c_fix = cfg(32, 96, 1.0);
        c_fix.q_fixed = Some(32);
        let (b2, _, _) = fwq_encode(&a, &c_fix);
        let e_fix = a.sq_dist(&fwq_decode(&b2, &c_fix));
        assert!(e_opt <= e_fix * 1.05, "opt={e_opt} fixed32={e_fix}");
    }

    #[test]
    fn no_mean_mode_case3() {
        let a = hetero(16, 40, 9);
        let mut c = cfg(16, 40, 1.0);
        c.use_mean = false;
        let (bytes, _, info) = fwq_encode(&a, &c);
        let out = fwq_decode(&bytes, &c);
        assert!(info.q0.is_none());
        // untransmitted columns are zero
        let mut is_zero_col = 0;
        for col in 0..40 {
            if (0..16).all(|r| out.at(r, col) == 0.0) {
                is_zero_col += 1;
            }
        }
        assert_eq!(is_zero_col, 40 - info.m_star);
    }

    #[test]
    fn radix_packing_close_to_nominal() {
        let a = hetero(64, 64, 10);
        let c = cfg(64, 64, 2.0);
        let (_, bits, info) = fwq_encode(&a, &c);
        // measured bits ≤ nominal + (per-symbol packing slack ≈ eps) + header
        let slack = 0.05 * info.nominal_bits + 512.0;
        assert!(
            (bits as f64) <= info.nominal_bits + slack,
            "bits={bits} nominal={}",
            info.nominal_bits
        );
    }

    #[test]
    fn empty_matrix() {
        let a = Matrix::zeros(8, 0);
        let c = cfg(8, 1, 1.0);
        let (bytes, bits, _) = fwq_encode(&a, &c);
        assert_eq!(bits, 0);
        let out = fwq_decode(&bytes, &c);
        assert_eq!(out.cols, 0);
    }

    #[test]
    fn delta_ep_degenerate_cases_are_zero_width() {
        // q_ep == 1 used to divide by zero: (max-min)/0 = inf, 0/0 = NaN
        assert_eq!(delta_ep(0.0, 5.0, 1), 0.0);
        assert_eq!(delta_ep(1.0, 1.0, 1), 0.0);
        assert_eq!(delta_ep(3.0, 3.0, 200), 0.0); // constant column set
        assert_eq!(delta_ep(5.0, 2.0, 200), 0.0); // inverted (empty set)
        let d = delta_ep(0.0, 199.0, 200);
        assert!((d - 1.0).abs() < 1e-12);
    }

    #[test]
    fn q_ep_one_encodes_columns_as_endpoints() {
        // Degenerate shared endpoint quantizer: frames must stay NaN-free
        // and decode every two-stage column to a finite constant.
        let a = hetero(8, 12, 21);
        let mut c = cfg(8, 12, 4.0);
        c.q_ep = 1;
        let (bytes, bits, info) = fwq_encode(&a, &c);
        assert!(bits > 0);
        assert!(info.objective.is_finite(), "objective {:?}", info.objective);
        assert!(info.nominal_bits.is_finite());
        // accounting charges the 1-bit-per-symbol endpoint codes actually
        // written, so the degenerate config still respects the budget
        assert!(
            bits as f64 <= c.c_ava * 1.02 + 256.0,
            "bits={bits} c_ava={}",
            c.c_ava
        );
        let out = fwq_decode(&bytes, &c);
        assert_eq!((out.rows, out.cols), (8, 12));
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn constant_columns_do_not_poison_objective() {
        // half the columns constant: ranges 0 → zero-width endpoint spans
        let a = Matrix::from_fn(16, 20, |r, c| {
            if c % 2 == 0 { 2.5 } else { (r as f32) * 0.1 - 0.8 }
        });
        let c = cfg(16, 20, 2.0);
        let (bytes, _, info) = fwq_encode(&a, &c);
        assert!(info.objective.is_finite());
        let out = fwq_decode(&bytes, &c);
        assert!(out.data.iter().all(|v| v.is_finite()));
        // constant columns reconstruct their value (endpoint or mean path)
        for col in (0..20).step_by(2) {
            for r in 0..16 {
                assert!((out.at(r, col) - 2.5).abs() < 0.2, "col {col}: {}", out.at(r, col));
            }
        }
    }

    #[test]
    fn degenerate_budget_lands_on_the_scanned_m0_plan() {
        // budget below even the header: every M > 0 candidate is infeasible,
        // so the scan must fall through to the M = 0 plan it already built
        let a = hetero(8, 16, 30);
        let c = FwqConfig::paper_default(8, 10.0);
        let (bytes, bits, info) = fwq_encode(&a, &c);
        assert_eq!(info.m_star, 0);
        assert!(bits > 0);
        let out = fwq_decode(&bytes, &c);
        assert_eq!((out.rows, out.cols), (8, 16));
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    // (byte-identity of threaded vs serial encodes — including wide and
    // degenerate inputs past every parallelism gate — is covered by
    // rust/tests/prop_parallel.rs)

    #[test]
    fn radix_bits_helper_sane() {
        use crate::bitio::radix_bits_per_symbol;
        // Q_ep = 200 packs 8 symbols/62 bits: 7.75 vs ideal 7.64 bits/symbol
        assert!((radix_bits_per_symbol(200) - (200f64).log2()).abs() < 0.15);
    }
}
