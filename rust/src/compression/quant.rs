//! Adaptive feature-wise quantization — FWQ (paper Sec. VI, Algorithm 3).
//!
//! The columns of a (compressed) intermediate matrix A ∈ R^{B×D̂} are split by
//! range: the M largest-range columns go through the **two-stage quantizer**
//! (endpoint quantizer with shared Q_ep levels → per-column uniform entry
//! quantizer with optimized Q_j levels); the remaining D̂-M columns are
//! collapsed to their means, quantized by the shared **mean-value quantizer**
//! (Q_0 levels). Levels solve problem (P) via `waterfill` (Theorem 1), and
//! M* is found by scanning a candidate set with the early-stop rule
//! (Alg. 3 lines 12-21).
//!
//! Everything is serialized to a real bit buffer; the decoder reconstructs
//! the matrix from the buffer and the *shared configuration only* (Q_ep,
//! C_ava, B — paper Sec. VI-B: both sides regenerate identical quantizers by
//! re-running the allocation on the transmitted endpoints/means, so no
//! codebooks are exchanged).
//!
//! ## The fused wire path
//!
//! [`fwq_encode_view`] is the hot-path entry: it reads the feature matrix
//! through a [`ColView`] (kept columns + optional 1/(1-p) rescale applied on
//! the fly), computes the column statistics in the same streaming pass the
//! dropout gather used to need a materialized copy for, and emits quantized
//! symbols straight into the caller's [`BitWriter`]. All intermediate state
//! (stats, candidate plans, level buffers, symbol staging) lives in a
//! caller-owned [`FwqScratch`], so steady-state encodes perform zero heap
//! allocations. The bitstream is byte-identical to the pre-fusion
//! gather-then-encode pipeline (locked by the `view_encode_matches_*` tests
//! below and the codec golden tests).

use crate::bitio::{BitReader, BitWriter};
use crate::compression::waterfill::{self, LevelSpec};
use crate::tensor::Matrix;
use crate::util::{par, reserve_total};

/// Shared FWQ configuration — identical at device and PS.
#[derive(Debug, Clone)]
pub struct FwqConfig {
    /// Endpoint-quantizer levels Q_ep (paper Sec. VII: 200).
    pub q_ep: u64,
    /// Total bit budget C_ava for this matrix (eq. after (21)).
    pub c_ava: f64,
    pub batch: usize,
    /// false ⇒ ablation Case 3: no mean-value quantizer — columns beyond M*
    /// are not transmitted at all (reconstructed as zero).
    pub use_mean: bool,
    /// Some(q) ⇒ Fig. 5: fixed level q for every quantizer, no optimization.
    pub q_fixed: Option<u64>,
    /// Candidate-set size N (paper: 10, M = {D^max n/N}).
    pub n_candidates: usize,
}

impl FwqConfig {
    pub fn paper_default(batch: usize, c_ava: f64) -> FwqConfig {
        FwqConfig {
            q_ep: 200,
            c_ava,
            batch,
            use_mean: true,
            q_fixed: None,
            n_candidates: 10,
        }
    }
}

/// Encoder-side report (levels, M*, nominal bits per eq. 17).
#[derive(Debug, Clone)]
pub struct FwqInfo {
    pub m_star: usize,
    pub dhat: usize,
    /// nominal overhead per the paper's accounting (eq. 17), in bits
    pub nominal_bits: f64,
    /// objective value f(Q̂_0..Q̂_M) at the chosen solution
    pub objective: f64,
    pub q0: Option<u64>,
    pub candidates_tried: usize,
}

impl FwqInfo {
    fn empty() -> FwqInfo {
        FwqInfo {
            m_star: 0,
            dhat: 0,
            nominal_bits: 0.0,
            objective: 0.0,
            q0: None,
            candidates_tried: 0,
        }
    }
}

const HEADER_BITS: f64 = 32.0 + 32.0 + 4.0 * 32.0; // D̂, M, 4 range floats

/// A read-only view of selected (optionally 1/(1-p)-rescaled) columns of a
/// row-major matrix — what the fused FWDP→FWQ path encodes from instead of
/// materializing `gather_cols_scaled`. `at(r, j)` is bit-identical to the
/// materialized copy's entry (one f32 multiply either way).
#[derive(Clone, Copy)]
pub struct ColView<'a> {
    m: &'a Matrix,
    kept: &'a [usize],
    scale: Option<&'a [f32]>,
}

impl<'a> ColView<'a> {
    /// Kept columns with per-column scale factors (the FWDP uplink).
    pub fn scaled(m: &'a Matrix, kept: &'a [usize], scale: &'a [f32]) -> ColView<'a> {
        assert_eq!(kept.len(), scale.len());
        debug_assert!(kept.iter().all(|&c| c < m.cols));
        ColView { m, kept, scale: Some(scale) }
    }

    /// Kept columns verbatim (the mask-coupled downlink).
    pub fn unscaled(m: &'a Matrix, kept: &'a [usize]) -> ColView<'a> {
        debug_assert!(kept.iter().all(|&c| c < m.cols));
        ColView { m, kept, scale: None }
    }

    pub fn rows(&self) -> usize {
        self.m.rows
    }

    pub fn ncols(&self) -> usize {
        self.kept.len()
    }

    /// Upper bound for per-column scratch buffers (the source width — kept
    /// sets fluctuate per round, the source matrix's shape does not).
    pub fn width_bound(&self) -> usize {
        self.m.cols.max(self.kept.len())
    }

    #[inline]
    pub fn at(&self, r: usize, j: usize) -> f32 {
        let x = self.m.data[r * self.m.cols + self.kept[j]];
        match self.scale {
            Some(s) => x * s[j],
            None => x,
        }
    }

    /// Walk view column `j` in row order.
    pub fn col_iter(&self, j: usize) -> impl Iterator<Item = f32> + '_ {
        (0..self.m.rows).map(move |r| self.at(r, j))
    }

    /// Strided-column descriptor for the SIMD kernel table — `at(r)` on the
    /// result is bit-identical to `self.at(r, j)`.
    #[inline]
    pub(crate) fn col_src(&self, j: usize) -> crate::util::simd::ColSrc<'_> {
        crate::util::simd::ColSrc {
            src: &self.m.data,
            offset: self.kept[j],
            stride: self.m.cols,
            scale: self.scale.map(|s| s[j]),
        }
    }

    /// Source column index behind view column `j` (where inline
    /// reconstruction scatters its dequantized values).
    #[inline]
    fn src_col(&self, j: usize) -> usize {
        self.kept[j]
    }

    /// Width of the source matrix (the reconstruction target's column count).
    fn src_width(&self) -> usize {
        self.m.cols
    }
}

/// One candidate-M quantization plan, built into reusable buffers.
#[derive(Debug, Default)]
struct Plan {
    m: usize,
    /// columns (view indices) using the two-stage quantizer, column order
    two_stage: Vec<usize>,
    /// remaining columns, column order
    mean_cols: Vec<usize>,
    a_min: f32,
    a_max: f32,
    abar_min: f32,
    abar_max: f32,
    /// endpoint codes per two-stage column (aligned with `two_stage`)
    ep_codes: Vec<(u64, u64)>,
    /// integer levels: entry levels aligned with `two_stage`, then the mean
    /// level (if any) last.
    levels: Vec<u64>,
    objective: f64,
}

impl Plan {
    fn reserve(&mut self, max_cols: usize) {
        reserve_total(&mut self.two_stage, max_cols);
        reserve_total(&mut self.mean_cols, max_cols);
        reserve_total(&mut self.ep_codes, max_cols);
        reserve_total(&mut self.levels, max_cols + 1);
    }
}

/// Reusable state for [`fwq_encode_view`] / [`fwq_decode_into`]: column
/// stats, the candidate-scan plan buffers, waterfill staging, and symbol
/// staging. One instance per codec session (inside
/// [`crate::compression::WireScratch`]); steady-state FWQ rounds touch the
/// heap zero times.
#[derive(Debug, Default)]
pub struct FwqScratch {
    mins: Vec<f32>,
    maxs: Vec<f32>,
    means: Vec<f32>,
    sums: Vec<f64>,
    ranges: Vec<f32>,
    order: Vec<usize>,
    candidates: Vec<usize>,
    specs: Vec<LevelSpec>,
    cont: Vec<f64>,
    best: Plan,
    trial: Plan,
    is_two: Vec<bool>,
    ep_syms: Vec<u64>,
    syms: Vec<u64>,
    dec_levels: Vec<u64>,
    sort_aux: Vec<usize>,
}

impl FwqScratch {
    pub fn new() -> FwqScratch {
        FwqScratch::default()
    }

    /// Pin every buffer's capacity to its (batch, D̄)-derived bound so
    /// steady-state rounds never regrow: kept-set sizes fluctuate round to
    /// round, and a post-warm-up high-water mark must not trigger a
    /// realloc. Absolute (total-capacity) reservations — the buffers still
    /// hold the previous round's contents when this runs.
    pub fn reserve(&mut self, batch: usize, max_cols: usize) {
        reserve_total(&mut self.mins, max_cols);
        reserve_total(&mut self.maxs, max_cols);
        reserve_total(&mut self.means, max_cols);
        reserve_total(&mut self.sums, max_cols);
        reserve_total(&mut self.ranges, max_cols);
        reserve_total(&mut self.order, max_cols);
        reserve_total(&mut self.candidates, 16);
        reserve_total(&mut self.specs, max_cols + 1);
        reserve_total(&mut self.cont, max_cols + 1);
        self.best.reserve(max_cols);
        self.trial.reserve(max_cols);
        reserve_total(&mut self.is_two, max_cols);
        reserve_total(&mut self.ep_syms, 2 * max_cols);
        reserve_total(&mut self.syms, batch.max(max_cols));
        reserve_total(&mut self.dec_levels, max_cols + 1);
        reserve_total(&mut self.sort_aux, max_cols);
    }
}

fn delta_ep(a_min: f32, a_max: f32, q_ep: u64) -> f64 {
    // Degenerate quantizers — a single shared level (Q_ep ≤ 1) or a
    // constant/empty column set (a_max ≤ a_min) — get a 0-width interval:
    // every endpoint code collapses to 0 and columns decode exactly to
    // their endpoint a_min. The unguarded division produced NaN (0/0) or
    // ±inf deltas here, which poisoned the waterfill objective.
    if q_ep <= 1 || a_max <= a_min {
        return 0.0;
    }
    (a_max as f64 - a_min as f64) / (q_ep as f64 - 1.0)
}

/// Radix base for endpoint codes: `write_radix`/`read_radix` need q ≥ 2,
/// and a degenerate Q_ep ≤ 1 only ever produces 0-codes anyway.
fn ep_radix(q_ep: u64) -> u64 {
    q_ep.max(2)
}

/// Bits per endpoint symbol as actually serialized — log2 of the radix base,
/// so budget accounting (C_const, D^max, nominal bits) matches the stream
/// even for the degenerate Q_ep ≤ 1 case (1 bit/symbol, not 0).
fn lg_ep(q_ep: u64) -> f64 {
    (ep_radix(q_ep) as f64).log2()
}

/// Endpoint quantizer (eq. 15-16). Floor for the minimum, ceil for the
/// maximum so the decoded interval encloses the column:
/// â_{u_min} ≤ a_{b,j} ≤ â_{u_max} (the containment Sec. VI-A claims).
fn quantize_endpoints(
    lo: f32,
    hi: f32,
    a_min: f32,
    d_ep: f64,
    q_ep: u64,
) -> (u64, u64) {
    if d_ep <= 0.0 {
        return (0, 0);
    }
    let umin = (((lo as f64 - a_min as f64) / d_ep).floor() as i64).clamp(0, q_ep as i64 - 1);
    let umax = (((hi as f64 - a_min as f64) / d_ep).ceil() as i64).clamp(0, q_ep as i64 - 1);
    (umin as u64, umax.max(umin) as u64)
}

/// Build the quantization plan for one candidate M into `out` (levels +
/// objective), reusing `specs`/`cont` as waterfill staging. Returns false
/// when the candidate is infeasible for the budget.
#[allow(clippy::too_many_arguments)]
fn plan_build(
    cfg: &FwqConfig,
    order: &[usize], // columns sorted by range descending
    mins: &[f32],
    maxs: &[f32],
    means: &[f32],
    m: usize,
    specs: &mut Vec<LevelSpec>,
    cont: &mut Vec<f64>,
    out: &mut Plan,
) -> bool {
    let dhat = order.len();
    let b = cfg.batch as f64;
    out.m = m;
    out.two_stage.clear();
    out.two_stage.extend_from_slice(&order[..m]);
    out.two_stage.sort_unstable(); // column order for a canonical wire layout
    out.mean_cols.clear();
    out.mean_cols.extend_from_slice(&order[m..]);
    out.mean_cols.sort_unstable();

    // global endpoint range over the two-stage set (eq. 15)
    let (mut a_min, mut a_max) = (f32::INFINITY, f32::NEG_INFINITY);
    for &c in &out.two_stage {
        a_min = a_min.min(mins[c]);
        a_max = a_max.max(maxs[c]);
    }
    if out.two_stage.is_empty() {
        a_min = 0.0;
        a_max = 0.0;
    }
    let d_ep = delta_ep(a_min, a_max, cfg.q_ep);
    out.ep_codes.clear();
    out.ep_codes.extend(
        out.two_stage
            .iter()
            .map(|&c| quantize_endpoints(mins[c], maxs[c], a_min, d_ep, cfg.q_ep)),
    );

    // mean range over the mean set
    let (mut abar_min, mut abar_max) = (f32::INFINITY, f32::NEG_INFINITY);
    for &c in &out.mean_cols {
        abar_min = abar_min.min(means[c]);
        abar_max = abar_max.max(means[c]);
    }
    if out.mean_cols.is_empty() {
        abar_min = 0.0;
        abar_max = 0.0;
    }

    // constant overhead C_const (eq. 17 minus the level-dependent terms)
    let c_const = 2.0 * m as f64 * lg_ep(cfg.q_ep) + dhat as f64 + HEADER_BITS;
    let c_levels = cfg.c_ava - c_const;

    // level specs in canonical order: entries (column order), then mean
    specs.clear();
    specs.extend(
        out.ep_codes
            .iter()
            .map(|&(umin, umax)| LevelSpec::entry((umax - umin) as f64 * d_ep, cfg.batch)),
    );
    let use_mean_q = cfg.use_mean && !out.mean_cols.is_empty();
    if use_mean_q {
        specs.push(LevelSpec::mean(
            (abar_max - abar_min) as f64,
            cfg.batch,
            out.mean_cols.len(),
        ));
    }

    match cfg.q_fixed {
        Some(q) => {
            out.levels.clear();
            out.levels.resize(specs.len(), q.max(2));
        }
        None => {
            if !waterfill::solve_into(specs, c_levels, cont, &mut out.levels) {
                if m == 0 {
                    // degenerate budget (< header + flags): fall back to
                    // minimum levels for the all-means plan so a frame can
                    // always be built; the overshoot shows up in the
                    // measured bits.
                    out.levels.clear();
                    out.levels.resize(specs.len(), 2);
                } else {
                    return false;
                }
            }
        }
    }

    // objective (eq. 22): level terms + the constant mean-residual term,
    // which *does* depend on M and must participate in the M* scan.
    let mut obj = waterfill::objective(specs, &out.levels);
    if cfg.use_mean {
        for &c in &out.mean_cols {
            let r = (maxs[c] - mins[c]) as f64;
            obj += r * r * b / 2.0;
        }
    } else {
        // untransmitted columns reconstruct to 0: count their full energy
        // proxy via range² (upper bound flavour, keeps the scan meaningful)
        for &c in &out.mean_cols {
            let r = (maxs[c] - mins[c]).max(means[c].abs()) as f64;
            obj += r * r * b;
        }
    }

    out.a_min = a_min;
    out.a_max = a_max;
    out.abar_min = abar_min;
    out.abar_max = abar_max;
    out.objective = obj;
    true
}

/// Largest feasible M for the budget (the paper's D^max in Sec. VII):
/// all-minimum allocation must fit: M(B + 2log2Qep - 1) ≤ C_ava - 2D̂ - 128.
fn d_max(cfg: &FwqConfig, dhat: usize) -> usize {
    let lg = lg_ep(cfg.q_ep);
    match cfg.q_fixed {
        None => {
            let num = cfg.c_ava - 2.0 * dhat as f64 - HEADER_BITS;
            let den = cfg.batch as f64 + 2.0 * lg - 1.0;
            ((num / den).floor().max(0.0) as usize).min(dhat)
        }
        Some(q) => {
            // Fig. 5 formula with fixed level q
            let lq = (q.max(2) as f64).log2();
            let num = cfg.c_ava - dhat as f64 - HEADER_BITS - dhat as f64 * lq;
            let den = cfg.batch as f64 * lq + 2.0 * lg - lq;
            ((num / den).floor().max(0.0) as usize).min(dhat)
        }
    }
}

/// Algorithm 3: scan the candidate set in descending order of M with the
/// early-stop rule, leaving the best plan in `best` and returning the number
/// of feasible candidates examined.
///
/// On a multi-worker pool (and wide matrices) the candidates are planned
/// **speculatively in parallel** (each `plan_build` is a pure function of
/// the shared stats), then the serial early-stop rule (Alg. 3 l.12-21) is
/// replayed over the results in descending-M order. The selected plan — and
/// therefore the emitted bitstream — is identical to a sequential scan;
/// plans past the stop point are simply discarded. The serial path builds
/// candidates lazily into two ping-pong buffers (`best`/`trial`), keeping
/// both the genuine early stop and the zero-allocation invariant.
#[allow(clippy::too_many_arguments)]
fn search_m_into(
    cfg: &FwqConfig,
    order: &[usize],
    mins: &[f32],
    maxs: &[f32],
    means: &[f32],
    candidates: &mut Vec<usize>,
    specs: &mut Vec<LevelSpec>,
    cont: &mut Vec<f64>,
    best: &mut Plan,
    trial: &mut Plan,
) -> usize {
    let dhat = order.len();
    let dmax = d_max(cfg, dhat);
    candidates.clear();
    if cfg.use_mean {
        candidates.extend(
            (1..=cfg.n_candidates)
                .map(|n| (dmax * n + cfg.n_candidates - 1) / cfg.n_candidates),
        );
    } else {
        candidates.push(dmax); // Case 3: as many two-stage columns as the budget allows
    }
    candidates.push(0); // pure mean-value fallback is always feasible-ish
    candidates.sort_unstable();
    candidates.dedup();
    candidates.reverse(); // descending M, the order Alg. 3 scans

    let mut found = false;
    let mut prev_obj = f64::INFINITY;
    let mut tried = 0usize;

    // Speculate only when the pool will actually run the candidates
    // concurrently; on one worker, or below ~256 columns where a plan costs
    // microseconds, the lazy serial scan (with its genuine early stop, no
    // thread spawns, and no per-candidate allocation) is strictly better.
    if dhat >= 256 && par::threads() > 1 {
        let cands: &[usize] = candidates;
        let plans: Vec<(bool, Plan)> = par::par_map_idx(cands.len(), 1, |i| {
            let mut p = Plan::default();
            let mut sp = Vec::new();
            let mut ct = Vec::new();
            let ok = plan_build(cfg, order, mins, maxs, means, cands[i], &mut sp, &mut ct, &mut p);
            (ok, p)
        });
        for (ok, p) in plans {
            if !ok {
                continue;
            }
            tried += 1;
            let obj = p.objective;
            if !found || obj < best.objective {
                *best = p;
                found = true;
            }
            if obj > prev_obj {
                break; // early stop
            }
            prev_obj = obj;
        }
    } else {
        for &m in candidates.iter() {
            if !plan_build(cfg, order, mins, maxs, means, m, specs, cont, trial) {
                continue;
            }
            tried += 1;
            let obj = trial.objective;
            if !found || obj < best.objective {
                std::mem::swap(best, trial);
                found = true;
            }
            if obj > prev_obj {
                break; // early stop
            }
            prev_obj = obj;
        }
    }
    // the scan set always contains M = 0, and the M = 0 plan always
    // constructs (the degenerate-budget fallback inside `plan_build`), so
    // the scan cannot come back empty: an early stop implies at least one
    // plan succeeded first.
    assert!(found, "candidate scan includes M = 0, which always constructs");
    tried
}

/// Fused single-pass per-column stats over the view (min / max / mean in
/// row-ascending accumulation order — bit-identical to
/// `tensor::column_stats` over the materialized gather).
fn view_stats(
    v: &ColView,
    mins: &mut Vec<f32>,
    maxs: &mut Vec<f32>,
    means: &mut Vec<f32>,
    sums: &mut Vec<f64>,
) {
    let (b, d) = (v.rows(), v.ncols());
    assert!(b > 0 && d > 0);
    mins.clear();
    mins.resize(d, f32::INFINITY);
    maxs.clear();
    maxs.resize(d, f32::NEG_INFINITY);
    sums.clear();
    sums.resize(d, 0.0);
    for r in 0..b {
        for j in 0..d {
            let x = v.at(r, j);
            if x < mins[j] {
                mins[j] = x;
            }
            if x > maxs[j] {
                maxs[j] = x;
            }
            sums[j] += x as f64;
        }
    }
    means.clear();
    means.extend(sums.iter().map(|&s| (s / b as f64) as f32));
}

/// Quantize + serialize A (Alg. 3 lines 19-23 + the paper's overhead terms).
///
/// Compatibility wrapper over [`fwq_encode_view`] for callers holding a
/// materialized matrix (benches, legacy paths); allocates its own scratch.
pub fn fwq_encode(a: &Matrix, cfg: &FwqConfig) -> (Vec<u8>, u64, FwqInfo) {
    assert_eq!(a.rows, cfg.batch);
    if a.cols == 0 {
        return (Vec::new(), 0, FwqInfo::empty());
    }
    let all: Vec<usize> = (0..a.cols).collect();
    let mut w = BitWriter::with_capacity((cfg.c_ava / 8.0) as usize + 64);
    let mut fs = FwqScratch::default();
    let info = fwq_encode_view(&ColView::unscaled(a, &all), cfg, &mut w, &mut fs);
    let bits = w.bit_len();
    (w.into_bytes(), bits, info)
}

/// The fused hot-path encoder: stats → M* scan → symbols emitted directly
/// into `w`, reading features through `v` (no gathered/scaled intermediate,
/// no per-column staging vectors — `fs` owns every reusable buffer).
pub fn fwq_encode_view(
    v: &ColView,
    cfg: &FwqConfig,
    w: &mut BitWriter,
    fs: &mut FwqScratch,
) -> FwqInfo {
    fwq_encode_view_core(v, cfg, w, fs, None)
}

/// [`fwq_encode_view`] plus **inline reconstruction**: the encoder already
/// holds every quantized symbol, so instead of the codec re-decoding its own
/// frame (a full parse + dequant pass over a staging blob), the dequantized
/// matrix is scattered into `recon` — resized to B × source-width, kept
/// columns written at their source positions, everything else zero — while
/// the symbols stream out. The reconstruction is bit-identical to
/// `fwq_decode_into` + column scatter: both sides derive levels from the
/// serialized f32 endpoints/means through the same deterministic waterfill
/// (locked by the `inline_recon_*` tests below).
pub fn fwq_encode_view_recon(
    v: &ColView,
    cfg: &FwqConfig,
    w: &mut BitWriter,
    fs: &mut FwqScratch,
    recon: &mut Matrix,
) -> FwqInfo {
    fwq_encode_view_core(v, cfg, w, fs, Some(recon))
}

fn fwq_encode_view_core(
    v: &ColView,
    cfg: &FwqConfig,
    w: &mut BitWriter,
    fs: &mut FwqScratch,
    mut recon: Option<&mut Matrix>,
) -> FwqInfo {
    let dhat = v.ncols();
    assert_eq!(v.rows(), cfg.batch);
    if let Some(rc) = recon.as_deref_mut() {
        rc.rows = cfg.batch;
        rc.cols = v.src_width();
        rc.data.clear();
        rc.data.resize(cfg.batch * v.src_width(), 0.0);
    }
    if dhat == 0 {
        return FwqInfo::empty();
    }
    fs.reserve(cfg.batch, v.width_bound());
    let FwqScratch {
        mins,
        maxs,
        means,
        sums,
        ranges,
        order,
        candidates,
        specs,
        cont,
        best,
        trial,
        is_two,
        ep_syms,
        syms,
        sort_aux,
        ..
    } = fs;

    view_stats(v, mins, maxs, means, sums);
    ranges.clear();
    ranges.extend(mins.iter().zip(maxs.iter()).map(|(&lo, &hi)| hi - lo));
    order.clear();
    order.extend(0..dhat);
    // stable descending by range — the allocation-free twin of
    // `sort_by(|&x, &y| ranges[y].partial_cmp(&ranges[x]))`, same permutation
    crate::util::sort::stable_sort_desc_by(order, sort_aux, ranges);

    let tried = search_m_into(cfg, order, mins, maxs, means, candidates, specs, cont, best, trial);
    let plan: &Plan = best;

    // ---- serialize ----
    w.write_u32(dhat as u32);
    w.write_u32(plan.m as u32);
    w.write_f32(plan.a_min);
    w.write_f32(plan.a_max);
    w.write_f32(plan.abar_min);
    w.write_f32(plan.abar_max);
    // flags in column order
    is_two.clear();
    is_two.resize(dhat, false);
    for &c in &plan.two_stage {
        is_two[c] = true;
    }
    for &f in is_two.iter() {
        w.write_bits(f as u64, 1);
    }
    // endpoint codes (column order, min then max), radix base Q_ep
    ep_syms.clear();
    for &(umin, umax) in &plan.ep_codes {
        ep_syms.push(umin);
        ep_syms.push(umax);
    }
    w.write_radix(ep_syms, ep_radix(cfg.q_ep));

    let d_ep = delta_ep(plan.a_min, plan.a_max, cfg.q_ep);
    let use_mean_q = cfg.use_mean && !plan.mean_cols.is_empty();
    let q0 = if use_mean_q { Some(*plan.levels.last().unwrap()) } else { None };

    // mean codes
    if let Some(q0v) = q0 {
        let lo = plan.abar_min as f64;
        let span = (plan.abar_max - plan.abar_min) as f64;
        syms.clear();
        syms.extend(
            plan.mean_cols
                .iter()
                .map(|&c| quant_code(means[c] as f64, lo, span, q0v)),
        );
        w.write_radix(syms, q0v);
        if let Some(rc) = recon.as_deref_mut() {
            // mirror the decoder's mean fill: each mean column becomes the
            // per-column constant dequant(code) — not the raw mean
            let rw = rc.cols;
            for (&c, &s) in plan.mean_cols.iter().zip(syms.iter()) {
                let val = dequant(s, lo, span, q0v);
                let sc = v.src_col(c);
                for row in 0..cfg.batch {
                    rc.data[row * rw + sc] = val;
                }
            }
        }
    }
    // entry codes per two-stage column: symbols come straight off the view
    // (strided reads + on-the-fly rescale, no per-column copy).
    // Serialization stays sequential in column order, so the stream is
    // byte-identical whether symbols are computed inline (serial, zero
    // allocation) or fanned out over the pool.
    let cols_per_chunk = (8192 / cfg.batch.max(1)).max(1); // ≥ ~8k quantizations per claimed chunk
    let nts = plan.two_stage.len();
    let col_lo_span = |j: usize| {
        let (umin, umax) = plan.ep_codes[j];
        let lo = plan.a_min as f64 + umin as f64 * d_ep;
        let span = (umax - umin) as f64 * d_ep;
        (lo, span)
    };
    let kr = crate::util::simd::kernels();
    if nts > cols_per_chunk && par::threads() > 1 {
        let col_syms: Vec<Vec<u64>> = par::par_map_idx(nts, cols_per_chunk, |j| {
            let (lo, span) = col_lo_span(j);
            let qj = plan.levels[j];
            let mut s = vec![0u64; cfg.batch];
            (kr.fwq_quant_col)(v.col_src(plan.two_stage[j]), cfg.batch, lo, span, qj, &mut s);
            s
        });
        for (j, (s, &qj)) in col_syms.iter().zip(&plan.levels).enumerate() {
            w.write_radix(s, qj);
            if let Some(rc) = recon.as_deref_mut() {
                let (lo, span) = col_lo_span(j);
                let stride = rc.cols;
                let sc = v.src_col(plan.two_stage[j]);
                (kr.fwq_dequant_col)(s, lo, span, qj, &mut rc.data, sc, stride);
            }
        }
    } else {
        for j in 0..nts {
            let (lo, span) = col_lo_span(j);
            let qj = plan.levels[j];
            syms.clear();
            syms.resize(cfg.batch, 0);
            (kr.fwq_quant_col)(v.col_src(plan.two_stage[j]), cfg.batch, lo, span, qj, syms);
            w.write_radix(syms, qj);
            if let Some(rc) = recon.as_deref_mut() {
                let stride = rc.cols;
                let sc = v.src_col(plan.two_stage[j]);
                (kr.fwq_dequant_col)(syms, lo, span, qj, &mut rc.data, sc, stride);
            }
        }
    }

    // nominal accounting (eq. 17): 2M log2 Qep + B Σ log2 Qj
    //   + (D̂-M) log2 Q0 + D̂ + 32*4
    let mut nominal = 2.0 * plan.m as f64 * lg_ep(cfg.q_ep) + dhat as f64 + 128.0;
    for (j, _) in plan.two_stage.iter().enumerate() {
        nominal += cfg.batch as f64 * (plan.levels[j] as f64).log2();
    }
    if let Some(q0v) = q0 {
        nominal += plan.mean_cols.len() as f64 * (q0v as f64).log2();
    }

    FwqInfo {
        m_star: plan.m,
        dhat,
        nominal_bits: nominal,
        objective: plan.objective,
        q0,
        candidates_tried: tried,
    }
}

#[inline]
pub(crate) fn quant_code(v: f64, lo: f64, span: f64, q: u64) -> u64 {
    if span <= 0.0 || q < 2 {
        return 0;
    }
    let t = ((v - lo) / span * (q as f64 - 1.0)).round();
    (t.max(0.0) as u64).min(q - 1)
}

#[inline]
pub(crate) fn dequant(code: u64, lo: f64, span: f64, q: u64) -> f32 {
    if q < 2 || span <= 0.0 {
        return lo as f32;
    }
    (lo + code as f64 * span / (q as f64 - 1.0)) as f32
}

/// Decode a FWQ frame back to a B×D̂ matrix. Needs only the shared config:
/// levels are re-derived by re-running the allocation on the decoded
/// endpoints/means (Sec. VI-B — both sides build identical quantizers).
///
/// Compatibility wrapper over [`fwq_decode_into`]; allocates its own
/// scratch and output.
pub fn fwq_decode(bytes: &[u8], cfg: &FwqConfig) -> Matrix {
    let mut fs = FwqScratch::default();
    let mut out = Matrix::zeros(cfg.batch, 0);
    fwq_decode_into(bytes, cfg, &mut fs, &mut out);
    out
}

/// Scratch-reusing FWQ decode: `out` is resized (capacity reused) and
/// refilled; all staging lives in `fs`. Steady-state decodes of
/// constant-shape frames perform zero heap allocations.
pub fn fwq_decode_into(bytes: &[u8], cfg: &FwqConfig, fs: &mut FwqScratch, out: &mut Matrix) {
    if bytes.is_empty() {
        out.rows = cfg.batch;
        out.cols = 0;
        out.data.clear();
        return;
    }
    let mut r = BitReader::new(bytes);
    let dhat = r.read_u32() as usize;
    let m = r.read_u32() as usize;
    let a_min = r.read_f32();
    let a_max = r.read_f32();
    let abar_min = r.read_f32();
    let abar_max = r.read_f32();
    fs.reserve(cfg.batch, dhat);
    let FwqScratch { is_two, ep_syms, specs, cont, syms, dec_levels, .. } = fs;
    is_two.clear();
    for _ in 0..dhat {
        is_two.push(r.read_bits(1) == 1);
    }
    r.read_radix_into(2 * m, ep_radix(cfg.q_ep), ep_syms);
    let d_ep = delta_ep(a_min, a_max, cfg.q_ep);

    let n_two = is_two.iter().filter(|&&f| f).count();
    assert_eq!(n_two, m, "flag/M mismatch in frame");
    let n_mean = dhat - m;

    // re-derive the levels exactly as the encoder did
    let c_const = 2.0 * m as f64 * lg_ep(cfg.q_ep) + dhat as f64 + HEADER_BITS;
    let c_levels = cfg.c_ava - c_const;
    specs.clear();
    specs.extend((0..m).map(|j| {
        let (umin, umax) = (ep_syms[2 * j], ep_syms[2 * j + 1]);
        LevelSpec::entry((umax - umin) as f64 * d_ep, cfg.batch)
    }));
    let use_mean_q = cfg.use_mean && n_mean > 0;
    if use_mean_q {
        specs.push(LevelSpec::mean((abar_max - abar_min) as f64, cfg.batch, n_mean));
    }
    match cfg.q_fixed {
        Some(q) => {
            dec_levels.clear();
            dec_levels.resize(specs.len(), q.max(2));
        }
        None => {
            // mirrors the encoder exactly, including the degenerate-budget
            // minimum-level fallback for the all-means plan
            if !waterfill::solve_into(specs, c_levels, cont, dec_levels) {
                dec_levels.clear();
                dec_levels.resize(specs.len(), 2);
            }
        }
    }

    out.rows = cfg.batch;
    out.cols = dhat;
    out.data.clear();
    out.data.resize(cfg.batch * dhat, 0.0);
    // mean codes
    if use_mean_q {
        let q0 = *dec_levels.last().unwrap();
        let lo = abar_min as f64;
        let span = (abar_max - abar_min) as f64;
        r.read_radix_into(n_mean, q0, syms);
        let mut k = 0usize;
        for c in 0..dhat {
            if is_two[c] {
                continue;
            }
            let val = dequant(syms[k], lo, span, q0);
            k += 1;
            for b in 0..cfg.batch {
                out.data[b * dhat + c] = val;
            }
        }
    }
    // entry codes (lanes = the 4 symbols of a column chunk — independent
    // outputs, so the SIMD and scalar dequant agree bit-for-bit)
    let kr = crate::util::simd::kernels();
    let mut j = 0usize;
    for c in 0..dhat {
        if !is_two[c] {
            continue;
        }
        let (umin, umax) = (ep_syms[2 * j], ep_syms[2 * j + 1]);
        let lo = a_min as f64 + umin as f64 * d_ep;
        let span = (umax - umin) as f64 * d_ep;
        let qj = dec_levels[j];
        j += 1;
        r.read_radix_into(cfg.batch, qj, syms);
        (kr.fwq_dequant_col)(&syms[..cfg.batch], lo, span, qj, &mut out.data, c, dhat);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    /// Matrix with heterogeneous column ranges (the paper's Fig.-1 regime).
    fn hetero(b: usize, d: usize, seed: u64) -> Matrix {
        let mut rng = Rng::new(seed);
        let scales: Vec<f32> = (0..d)
            .map(|i| if i % 3 == 0 { 10.0 } else if i % 3 == 1 { 0.5 } else { 0.01 })
            .collect();
        Matrix::from_fn(b, d, |_r, c| {
            scales[c] * rng.normal_f32(0.0, 1.0) + c as f32 * 0.1
        })
    }

    fn cfg(b: usize, d: usize, bits_per_entry: f64) -> FwqConfig {
        FwqConfig::paper_default(b, bits_per_entry * b as f64 * d as f64)
    }

    #[test]
    fn roundtrip_within_budget() {
        let a = hetero(32, 64, 1);
        let c = cfg(32, 64, 2.0);
        let (bytes, bits, info) = fwq_encode(&a, &c);
        // measured bits within budget (+ radix slack < 1 bit/group)
        assert!(bits as f64 <= c.c_ava * 1.02 + 256.0, "bits={bits} c_ava={}", c.c_ava);
        assert!(info.nominal_bits <= c.c_ava + 1e-6);
        let out = fwq_decode(&bytes, &c);
        assert_eq!((out.rows, out.cols), (32, 64));
        // two-stage columns should be far more accurate than raw range
        let rel = (a.sq_dist(&out) / a.sq_norm()).sqrt();
        assert!(rel < 0.5, "relative error {rel}");
    }

    #[test]
    fn decode_is_exact_inverse_of_encode_quantization() {
        // re-encoding the decoded matrix must be a fixed point (codes stable)
        let a = hetero(16, 24, 2);
        let c = cfg(16, 24, 3.0);
        let (bytes, _, _) = fwq_encode(&a, &c);
        let out1 = fwq_decode(&bytes, &c);
        let (bytes2, _, _) = fwq_encode(&out1, &c);
        let out2 = fwq_decode(&bytes2, &c);
        let d = out1.sq_dist(&out2).sqrt();
        let scale = out1.sq_norm().sqrt().max(1.0);
        // second pass re-derives grids from decoded (already on-grid) stats,
        // so it should move the matrix far less than the first quantization
        assert!(d < 0.05 * scale, "not a near-fixed-point: {d} vs {scale}");
    }

    #[test]
    fn error_bound_eq19_holds_per_two_stage_column() {
        let a = hetero(24, 32, 3);
        let c = cfg(24, 32, 4.0);
        let (bytes, _, info) = fwq_encode(&a, &c);
        let out = fwq_decode(&bytes, &c);
        // total error is bounded by the objective at the solution (eqs. 19-21
        // are upper bounds, and the objective adds the mean-residual term)
        let err: f64 = a.sq_dist(&out);
        assert!(
            err <= info.objective * 1.5 + 1e-6,
            "err={err} bound={}",
            info.objective
        );
    }

    #[test]
    fn more_budget_less_error() {
        let a = hetero(32, 48, 4);
        let mut last = f64::INFINITY;
        for bpe in [0.5, 1.0, 2.0, 4.0, 8.0] {
            let c = cfg(32, 48, bpe);
            let (bytes, _, _) = fwq_encode(&a, &c);
            let out = fwq_decode(&bytes, &c);
            let err = a.sq_dist(&out);
            assert!(
                err <= last * 1.3 + 1e-9,
                "bpe={bpe}: err={err} prev={last}"
            );
            last = err.min(last);
        }
    }

    #[test]
    fn small_range_columns_use_mean_quantizer() {
        let a = hetero(16, 30, 5);
        let c = cfg(16, 30, 1.0); // tight budget forces mean usage
        let (_, _, info) = fwq_encode(&a, &c);
        assert!(info.m_star < 30, "M*={} should leave mean columns", info.m_star);
        assert!(info.q0.is_some());
    }

    #[test]
    fn sub_one_bit_per_entry_regime() {
        // the paper's headline: < 1 bit/entry uplink. 0.2 bits/entry here.
        let a = hetero(64, 128, 6);
        let c = cfg(64, 128, 0.2);
        let (bytes, bits, info) = fwq_encode(&a, &c);
        assert!(bits as f64 <= c.c_ava * 1.05 + 512.0, "bits={bits}");
        let out = fwq_decode(&bytes, &c);
        assert_eq!(out.cols, 128);
        assert!(info.m_star <= 128);
        // constant columns must reconstruct near-exactly via means
        let rel = (a.sq_dist(&out) / a.sq_norm()).sqrt();
        assert!(rel < 1.0, "rel={rel}");
    }

    #[test]
    fn constant_matrix_reconstructs_exactly() {
        let a = Matrix::from_fn(8, 16, |_, _| 3.25);
        let c = cfg(8, 16, 1.0);
        let (bytes, _, _) = fwq_encode(&a, &c);
        let out = fwq_decode(&bytes, &c);
        for v in &out.data {
            assert!((v - 3.25).abs() < 1e-5, "v={v}");
        }
    }

    #[test]
    fn fixed_q_mode_fig5() {
        let a = hetero(32, 64, 7);
        for q in [2u64, 4, 8, 32] {
            let mut c = cfg(32, 64, 2.0);
            c.q_fixed = Some(q);
            let (bytes, bits, info) = fwq_encode(&a, &c);
            let out = fwq_decode(&bytes, &c);
            assert_eq!(out.cols, 64);
            assert!(bits > 0);
            assert!(info.m_star <= 64);
        }
    }

    #[test]
    fn optimized_beats_worst_fixed_q() {
        // Fig. 5's claim at matrix level: optimal levels ≤ error of Q=32.
        let a = hetero(32, 96, 8);
        let c_opt = cfg(32, 96, 1.0);
        let (b1, _, _) = fwq_encode(&a, &c_opt);
        let e_opt = a.sq_dist(&fwq_decode(&b1, &c_opt));
        let mut c_fix = cfg(32, 96, 1.0);
        c_fix.q_fixed = Some(32);
        let (b2, _, _) = fwq_encode(&a, &c_fix);
        let e_fix = a.sq_dist(&fwq_decode(&b2, &c_fix));
        assert!(e_opt <= e_fix * 1.05, "opt={e_opt} fixed32={e_fix}");
    }

    #[test]
    fn no_mean_mode_case3() {
        let a = hetero(16, 40, 9);
        let mut c = cfg(16, 40, 1.0);
        c.use_mean = false;
        let (bytes, _, info) = fwq_encode(&a, &c);
        let out = fwq_decode(&bytes, &c);
        assert!(info.q0.is_none());
        // untransmitted columns are zero
        let mut is_zero_col = 0;
        for col in 0..40 {
            if (0..16).all(|r| out.at(r, col) == 0.0) {
                is_zero_col += 1;
            }
        }
        assert_eq!(is_zero_col, 40 - info.m_star);
    }

    #[test]
    fn radix_packing_close_to_nominal() {
        let a = hetero(64, 64, 10);
        let c = cfg(64, 64, 2.0);
        let (_, bits, info) = fwq_encode(&a, &c);
        // measured bits ≤ nominal + (per-symbol packing slack ≈ eps) + header
        let slack = 0.05 * info.nominal_bits + 512.0;
        assert!(
            (bits as f64) <= info.nominal_bits + slack,
            "bits={bits} nominal={}",
            info.nominal_bits
        );
    }

    #[test]
    fn empty_matrix() {
        let a = Matrix::zeros(8, 0);
        let c = cfg(8, 1, 1.0);
        let (bytes, bits, _) = fwq_encode(&a, &c);
        assert_eq!(bits, 0);
        let out = fwq_decode(&bytes, &c);
        assert_eq!(out.cols, 0);
    }

    #[test]
    fn delta_ep_degenerate_cases_are_zero_width() {
        // q_ep == 1 used to divide by zero: (max-min)/0 = inf, 0/0 = NaN
        assert_eq!(delta_ep(0.0, 5.0, 1), 0.0);
        assert_eq!(delta_ep(1.0, 1.0, 1), 0.0);
        assert_eq!(delta_ep(3.0, 3.0, 200), 0.0); // constant column set
        assert_eq!(delta_ep(5.0, 2.0, 200), 0.0); // inverted (empty set)
        let d = delta_ep(0.0, 199.0, 200);
        assert!((d - 1.0).abs() < 1e-12);
    }

    #[test]
    fn q_ep_one_encodes_columns_as_endpoints() {
        // Degenerate shared endpoint quantizer: frames must stay NaN-free
        // and decode every two-stage column to a finite constant.
        let a = hetero(8, 12, 21);
        let mut c = cfg(8, 12, 4.0);
        c.q_ep = 1;
        let (bytes, bits, info) = fwq_encode(&a, &c);
        assert!(bits > 0);
        assert!(info.objective.is_finite(), "objective {:?}", info.objective);
        assert!(info.nominal_bits.is_finite());
        // accounting charges the 1-bit-per-symbol endpoint codes actually
        // written, so the degenerate config still respects the budget
        assert!(
            bits as f64 <= c.c_ava * 1.02 + 256.0,
            "bits={bits} c_ava={}",
            c.c_ava
        );
        let out = fwq_decode(&bytes, &c);
        assert_eq!((out.rows, out.cols), (8, 12));
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn constant_columns_do_not_poison_objective() {
        // half the columns constant: ranges 0 → zero-width endpoint spans
        let a = Matrix::from_fn(16, 20, |r, c| {
            if c % 2 == 0 { 2.5 } else { (r as f32) * 0.1 - 0.8 }
        });
        let c = cfg(16, 20, 2.0);
        let (bytes, _, info) = fwq_encode(&a, &c);
        assert!(info.objective.is_finite());
        let out = fwq_decode(&bytes, &c);
        assert!(out.data.iter().all(|v| v.is_finite()));
        // constant columns reconstruct their value (endpoint or mean path)
        for col in (0..20).step_by(2) {
            for r in 0..16 {
                assert!((out.at(r, col) - 2.5).abs() < 0.2, "col {col}: {}", out.at(r, col));
            }
        }
    }

    #[test]
    fn degenerate_budget_lands_on_the_scanned_m0_plan() {
        // budget below even the header: every M > 0 candidate is infeasible,
        // so the scan must fall through to the M = 0 plan it already built
        let a = hetero(8, 16, 30);
        let c = FwqConfig::paper_default(8, 10.0);
        let (bytes, bits, info) = fwq_encode(&a, &c);
        assert_eq!(info.m_star, 0);
        assert!(bits > 0);
        let out = fwq_decode(&bytes, &c);
        assert_eq!((out.rows, out.cols), (8, 16));
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    // (byte-identity of threaded vs serial encodes — including wide and
    // degenerate inputs past every parallelism gate — is covered by
    // rust/tests/prop_parallel.rs)

    #[test]
    fn radix_bits_helper_sane() {
        use crate::bitio::radix_bits_per_symbol;
        // Q_ep = 200 packs 8 symbols/62 bits: 7.75 vs ideal 7.64 bits/symbol
        assert!((radix_bits_per_symbol(200) - (200f64).log2()).abs() < 0.15);
    }

    // ---- fusion oracles: the ColView path vs the materialized pipeline ----

    #[test]
    fn view_encode_matches_materialized_gather_scaled() {
        // The fused encoder (stats + quantization off the scaled view) must
        // be byte-identical to gather_cols_scaled + fwq_encode, which is the
        // pre-fusion FWDP→FWQ pipeline.
        let f = hetero(32, 96, 11);
        let kept: Vec<usize> = (0..96).filter(|i| i % 3 != 0).collect();
        let scale: Vec<f32> = kept.iter().map(|&i| 1.0 + (i % 5) as f32 * 0.21).collect();
        for bpe in [0.2, 1.0, 4.0] {
            let c = FwqConfig::paper_default(32, bpe * 32.0 * kept.len() as f64);
            let ft = f.gather_cols_scaled(&kept, &scale);
            let (bytes_ref, bits_ref, info_ref) = fwq_encode(&ft, &c);
            let mut w = BitWriter::new();
            let mut fs = FwqScratch::default();
            let info = fwq_encode_view(&ColView::scaled(&f, &kept, &scale), &c, &mut w, &mut fs);
            assert_eq!(w.bit_len(), bits_ref, "bpe={bpe}");
            assert_eq!(w.into_bytes(), bytes_ref, "bpe={bpe}");
            assert_eq!(info.m_star, info_ref.m_star, "bpe={bpe}");
            assert_eq!(info.nominal_bits, info_ref.nominal_bits, "bpe={bpe}");
            assert_eq!(info.q0, info_ref.q0, "bpe={bpe}");
        }
    }

    #[test]
    fn scratch_reuse_is_byte_stable_across_varying_shapes() {
        // one scratch across frames of different kept-set sizes: outputs must
        // match fresh-scratch encodes (stale state must never leak through)
        let f = hetero(16, 64, 12);
        let mut fs = FwqScratch::default();
        for round in 0..4usize {
            let kept: Vec<usize> = (0..64).filter(|i| (i + round) % (2 + round) != 0).collect();
            let c = FwqConfig::paper_default(16, 1.5 * 16.0 * kept.len() as f64);
            let v = ColView::unscaled(&f, &kept);
            let mut w = BitWriter::new();
            fwq_encode_view(&v, &c, &mut w, &mut fs);
            let reused = w.into_bytes();
            let (fresh, _, _) = fwq_encode(&f.gather_cols(&kept), &c);
            assert_eq!(reused, fresh, "round {round}");
            // decode through the same scratch round-trips too
            let mut out = Matrix::zeros(16, 0);
            fwq_decode_into(&reused, &c, &mut fs, &mut out);
            assert_eq!(out, fwq_decode(&fresh, &c), "round {round}");
        }
    }

    // ---- inline reconstruction vs the decode-own-frame path ----

    fn scatter_to_source(dec: &Matrix, kept: &[usize], src_cols: usize) -> Matrix {
        let mut out = Matrix::zeros(dec.rows, src_cols);
        for r in 0..dec.rows {
            for (j, &kc) in kept.iter().enumerate() {
                out.data[r * src_cols + kc] = dec.at(r, j);
            }
        }
        out
    }

    #[test]
    fn inline_recon_matches_decode_scatter() {
        let f = hetero(16, 48, 13);
        let kept: Vec<usize> = (0..48).filter(|i| i % 4 != 1).collect();
        let scale: Vec<f32> = kept.iter().map(|&i| 1.0 + (i % 7) as f32 * 0.13).collect();
        for (bpe, use_mean) in [(0.2, true), (1.0, true), (4.0, true), (1.0, false)] {
            let mut c = FwqConfig::paper_default(16, bpe * 16.0 * kept.len() as f64);
            c.use_mean = use_mean;
            let v = ColView::scaled(&f, &kept, &scale);
            let mut w = BitWriter::new();
            let mut fs = FwqScratch::default();
            let mut recon = Matrix::zeros(0, 0);
            fwq_encode_view_recon(&v, &c, &mut w, &mut fs, &mut recon);
            let bytes = w.into_bytes();
            // the stream is untouched by reconstruction
            let mut w2 = BitWriter::new();
            let mut fs2 = FwqScratch::default();
            fwq_encode_view(&v, &c, &mut w2, &mut fs2);
            assert_eq!(bytes, w2.into_bytes(), "bpe={bpe} use_mean={use_mean}");
            // recon == what the decoder + kept-column scatter produces
            let expect = scatter_to_source(&fwq_decode(&bytes, &c), &kept, 48);
            assert_eq!(recon, expect, "bpe={bpe} use_mean={use_mean}");
        }
    }

    #[test]
    fn inline_recon_threaded_matches_serial() {
        // wide enough (nts > 8192/B column chunk) to cross the parallel gate
        let f = hetero(8, 2400, 14);
        let kept: Vec<usize> = (0..2400).collect();
        let c = FwqConfig::paper_default(8, 6.0 * 8.0 * 2400.0);
        let v = ColView::unscaled(&f, &kept);
        let encode = || {
            let mut w = BitWriter::new();
            let mut fs = FwqScratch::default();
            let mut recon = Matrix::zeros(0, 0);
            fwq_encode_view_recon(&v, &c, &mut w, &mut fs, &mut recon);
            (w.into_bytes(), recon)
        };
        crate::util::par::set_threads(1);
        let (b1, r1) = encode();
        crate::util::par::set_threads(4);
        let (b4, r4) = encode();
        crate::util::par::set_threads(0);
        assert_eq!(b1, b4);
        assert_eq!(r1, r4);
        assert_eq!(r1, scatter_to_source(&fwq_decode(&b1, &c), &kept, 2400));
    }
}
