//! Quantization-level allocation — problem (P) (eqs. 22-24) and Theorem 1.
//!
//! Minimize   Σ_{j=1..M} ã_j² B / (4 (Q_j - 1)²)  +  ã_0² B (D̂-M) / (2 (Q_0 - 1)²)
//! subject to B Σ log2 Q_j + (D̂-M) log2 Q_0  ≤  C_target,  2 ≤ Q_l ≤ 2^32.
//!
//! The KKT stationarity condition gives the paper's cubic (eq. 40)
//!     (Q - 1)³ = u · Q,   u_j = ã_j² ln2 / (2ν),  u_0 = ã_0² B ln2 / ν,
//! whose positive root (eq. 41 / Theorem 1) we compute with a robust cubic
//! solver (Cardano one-real-root branch == the paper's closed form; the
//! trigonometric branch covers u > 27/4 where eq. 41's inner sqrt goes
//! negative). The Lagrange multiplier ν is found by bisection — bits(ν) is
//! monotone non-increasing — and real integer levels are obtained by
//! flooring + greedy residual-bit redistribution (the Chow-style adjust the
//! paper cites [48]).

pub const Q_MIN: f64 = 2.0;
pub const Q_MAX: f64 = 4294967296.0; // 2^32

/// Per-quantizer inputs: the error-weight constant ã and the bit multiplier
/// (B for entry quantizers, D̂-M for the shared mean quantizer).
#[derive(Debug, Clone, Copy)]
pub struct LevelSpec {
    /// ã_l — quantization range constant from eq. (19)/(20).
    pub a_tilde: f64,
    /// error coefficient: err = coeff / (Q-1)^2  (ã²B/4 or ã²B(D̂-M)/2)
    pub err_coeff: f64,
    /// bits used = bit_weight * log2(Q)
    pub bit_weight: f64,
}

impl LevelSpec {
    /// Entry quantizer for a two-stage column (eq. 19): err = ã²B/4(Q-1)².
    pub fn entry(a_tilde: f64, batch: usize) -> LevelSpec {
        LevelSpec {
            a_tilde,
            err_coeff: a_tilde * a_tilde * batch as f64 / 4.0,
            bit_weight: batch as f64,
        }
    }

    /// Shared mean-value quantizer (eq. 20): err = ã_0²B(D̂-M)/2(Q_0-1)².
    pub fn mean(a_tilde0: f64, batch: usize, n_mean_cols: usize) -> LevelSpec {
        LevelSpec {
            a_tilde: a_tilde0,
            err_coeff: a_tilde0 * a_tilde0 * batch as f64 * n_mean_cols as f64 / 2.0,
            bit_weight: n_mean_cols as f64,
        }
    }

    /// The paper's u_l(ν): stationarity constant of the cubic (eq. 40).
    /// Derived generically: d/dQ [coeff/(Q-1)²] + ν·w/(Q ln2) = 0
    ///   ⇒ (Q-1)³ = (2 coeff ln2 / (ν w)) · Q.
    fn u(&self, nu: f64) -> f64 {
        2.0 * self.err_coeff * std::f64::consts::LN_2 / (nu * self.bit_weight)
    }
}

/// Largest real root of (Q-1)^3 = u*Q for u > 0 (always > 1).
pub fn cubic_root(u: f64) -> f64 {
    debug_assert!(u > 0.0, "cubic_root needs u > 0 (got {u}); level_at guards this");
    // x = Q-1: x³ - u x - u = 0, depressed cubic p = -u, q = -u.
    let p = -u;
    let q = -u;
    let disc = -4.0 * p * p * p - 27.0 * q * q; // Δ = 4u³ - 27u²
    let x = if disc > 0.0 {
        // three real roots (u > 27/4): trigonometric method, take largest.
        let m = 2.0 * (-p / 3.0).sqrt();
        let theta = (3.0 * q / (p * m)).clamp(-1.0, 1.0).acos() / 3.0;
        m * theta.cos()
    } else {
        // one real root — Cardano; algebraically equal to the paper's
        // closed form (eq. 41) on its valid domain.
        let t = (q * q / 4.0 + p * p * p / 27.0).sqrt();
        let c1 = -q / 2.0 + t;
        let c2 = -q / 2.0 - t;
        c1.cbrt() + c2.cbrt()
    };
    1.0 + x
}

/// The paper's Theorem-1 closed form (eq. 25 / 41) on its valid domain —
/// used by tests to cross-check `cubic_root`.
pub fn theorem1_closed_form(u: f64) -> Option<f64> {
    let inner = 81.0 - 12.0 * u;
    if inner < 0.0 {
        return None;
    }
    let v = (u * inner.sqrt() + 9.0 * u).cbrt();
    Some((2.0f64 / 3.0).cbrt() * u / v + v / (2.0f64.cbrt() * 3.0f64.powf(2.0 / 3.0)) + 1.0)
}

/// Continuous optimal level for one quantizer at multiplier ν (eq. 42/43).
pub fn level_at(spec: &LevelSpec, nu: f64) -> f64 {
    let u = spec.u(nu);
    if !(u > 0.0) {
        // zero-range quantizer: any level is exact — use the minimum
        return Q_MIN;
    }
    // (Q-1)³ = uQ crosses Q_MAX at u = (Q_MAX-1)³/Q_MAX ≈ 1.85e19; beyond
    // that (or at f64 overflow territory) the clamp is the answer.
    if u >= 1.8e19 {
        return Q_MAX;
    }
    let q = cubic_root(u);
    if !q.is_finite() {
        return Q_MAX;
    }
    q.clamp(Q_MIN, Q_MAX)
}

fn total_bits(specs: &[LevelSpec], nu: f64) -> f64 {
    specs
        .iter()
        .map(|s| s.bit_weight * level_at(s, nu).log2())
        .sum()
}

/// Solve (P): continuous levels via ν-bisection, then integer rounding with
/// greedy redistribution. Returns integer levels (aligned with `specs`) or
/// None when even all-minimum levels (Q=2) exceed the budget.
pub fn solve(specs: &[LevelSpec], c_target: f64) -> Option<Vec<u64>> {
    let mut cont = Vec::new();
    let mut out = Vec::new();
    if solve_into(specs, c_target, &mut cont, &mut out) {
        Some(out)
    } else {
        None
    }
}

/// Allocation-reusing form of [`solve`]: `cont` is the continuous-level
/// staging buffer and `out` receives the integer levels (both cleared
/// first). Returns false when even all-minimum levels exceed the budget.
/// This is the hot-path entry — the FWQ candidate scan calls it once per
/// candidate M with buffers owned by the encoder's scratch arena.
pub fn solve_into(
    specs: &[LevelSpec],
    c_target: f64,
    cont: &mut Vec<f64>,
    out: &mut Vec<u64>,
) -> bool {
    out.clear();
    if specs.is_empty() {
        return true;
    }
    let min_bits: f64 = specs.iter().map(|s| s.bit_weight).sum(); // all Q=2
    if min_bits > c_target + 1e-9 {
        return false;
    }
    // Degenerate: all ranges zero -> minimum levels everywhere.
    if specs.iter().all(|s| s.a_tilde <= 0.0) {
        out.resize(specs.len(), 2);
        return true;
    }

    // Bisection bounds: bits(ν) is non-increasing. Bracket from the data:
    // at ν ≥ max_l u_l(ν)=... every level hits Q=2 (eq. 36), so ν_hi =
    // 4·max_l(2·coeff·ln2/w) forces the all-minimum allocation; ν_lo scaled
    // down to where every level saturates at Q_MAX (eq. 39). A fixed
    // iteration count then resolves ν* to ~1e-20 relative — this bracket
    // (vs a blind 1e-300..1e300 sweep) is perf iteration L3-1 in
    // EXPERIMENTS.md §Perf.
    let qmax_bits: f64 = specs.iter().map(|s| s.bit_weight * Q_MAX.log2()).sum();
    if qmax_bits <= c_target {
        // even the most generous allocation fits: everything at Q_MAX
        cont.clear();
        cont.resize(specs.len(), Q_MAX);
        round_and_redistribute_into(specs, cont, c_target, out);
        return true;
    }
    let u_max = specs
        .iter()
        .map(|s| 2.0 * s.err_coeff * std::f64::consts::LN_2 / s.bit_weight.max(1e-300))
        .fold(f64::MIN_POSITIVE, f64::max);
    let mut hi: f64 = 4.0 * u_max;
    let mut lo: f64 = hi * 1e-25;
    // ensure the bracket actually spans the target (a handful of widenings
    // at most — bits(ν) saturates at both ends)
    for _ in 0..12 {
        if total_bits(specs, lo) >= c_target {
            break;
        }
        lo *= 1e-20;
    }
    for _ in 0..90 {
        let mid = (lo.ln() * 0.5 + hi.ln() * 0.5).exp(); // geometric midpoint
        if total_bits(specs, mid) > c_target {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    let nu = hi;
    cont.clear();
    cont.extend(specs.iter().map(|s| level_at(s, nu)));
    round_and_redistribute_into(specs, cont, c_target, out);
    true
}

/// Floor the continuous levels to integers (>= 2), then greedily spend the
/// residual bit budget on the increments with the best error-reduction /
/// bit-cost ratio — Chow-style bit reuse [48].
fn round_and_redistribute_into(specs: &[LevelSpec], cont: &[f64], c_target: f64, q: &mut Vec<u64>) {
    q.clear();
    q.extend(cont.iter().map(|&c| (c.floor() as u64).clamp(2, Q_MAX as u64)));
    let bits = |q: &[u64]| -> f64 {
        specs
            .iter()
            .zip(q)
            .map(|(s, &qi)| s.bit_weight * (qi as f64).log2())
            .sum()
    };
    let mut used = bits(q);
    // Greedy improvement: each step, the +1-level move with the best
    // Δerror/Δbits that still fits. Flooring loses < 1 level per quantizer,
    // so a handful of rounds recovers the residual budget; the step cap
    // guards against the near-free increments at very large Q (where the
    // marginal error gain is negligible anyway).
    let max_steps = 8 * specs.len() + 16;
    for _ in 0..max_steps {
        let mut best: Option<(usize, f64, f64)> = None; // (idx, gain_per_bit, cost)
        for (i, s) in specs.iter().enumerate() {
            if q[i] >= Q_MAX as u64 {
                continue;
            }
            let cost = s.bit_weight * ((q[i] + 1) as f64).log2() - s.bit_weight * (q[i] as f64).log2();
            if used + cost > c_target + 1e-9 {
                continue;
            }
            let e_now = s.err_coeff / ((q[i] as f64 - 1.0) * (q[i] as f64 - 1.0));
            let e_next = s.err_coeff / ((q[i] as f64) * (q[i] as f64));
            let gain = (e_now - e_next) / cost.max(1e-12);
            if best.map(|(_, g, _)| gain > g).unwrap_or(true) && gain > 0.0 {
                best = Some((i, gain, cost));
            }
        }
        match best {
            Some((i, _, cost)) => {
                q[i] += 1;
                used += cost;
            }
            None => break,
        }
    }
    let _ = used;
}

/// Objective f(Q_0..Q_M) of (P) for given integer levels (eq. 22, without the
/// constant middle term which doesn't depend on the levels).
pub fn objective(specs: &[LevelSpec], q: &[u64]) -> f64 {
    specs
        .iter()
        .zip(q)
        .map(|(s, &qi)| s.err_coeff / (((qi - 1) as f64) * ((qi - 1) as f64)))
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn cubic_root_satisfies_cubic() {
        for &u in &[1e-6, 0.1, 0.5, 1.0, 6.0, 6.75, 7.0, 100.0, 1e6, 1e12] {
            let q = cubic_root(u);
            assert!(q > 1.0, "u={u} q={q}");
            let lhs = (q - 1.0).powi(3);
            let rhs = u * q;
            assert!(
                (lhs - rhs).abs() <= 1e-6 * rhs.max(1.0),
                "u={u}: {lhs} vs {rhs}"
            );
        }
    }

    #[test]
    fn cubic_root_matches_theorem1_closed_form() {
        for &u in &[0.01, 0.3, 1.0, 3.0, 6.0, 6.74] {
            let ours = cubic_root(u);
            let paper = theorem1_closed_form(u).unwrap();
            assert!((ours - paper).abs() < 1e-9 * paper, "u={u}: {ours} vs {paper}");
        }
    }

    #[test]
    fn larger_range_gets_more_levels() {
        // Theorem 1 discussion: bigger ã ⇒ higher Q at the same ν.
        let nu = 0.01;
        let a = level_at(&LevelSpec::entry(10.0, 64), nu);
        let b = level_at(&LevelSpec::entry(0.1, 64), nu);
        assert!(a > b, "{a} vs {b}");
    }

    #[test]
    fn solve_meets_budget_exactly_enough() {
        let specs: Vec<LevelSpec> = (0..16)
            .map(|i| LevelSpec::entry(0.1 * (i + 1) as f64, 32))
            .collect();
        let target = 3200.0; // ~6.25 bits/entry avg
        let q = solve(&specs, target).unwrap();
        let bits: f64 = specs
            .iter()
            .zip(&q)
            .map(|(s, &qi)| s.bit_weight * (qi as f64).log2())
            .sum();
        assert!(bits <= target + 1e-6, "bits={bits}");
        // Should use most of the budget (within one max increment).
        assert!(bits >= target - 32.0 * 17.0_f64.log2(), "bits={bits} target={target}");
        // Monotone: larger ã gets >= levels
        for i in 1..16 {
            assert!(q[i] >= q[i - 1], "{q:?}");
        }
    }

    #[test]
    fn solve_infeasible_returns_none() {
        let specs = vec![LevelSpec::entry(1.0, 64); 4];
        // all-minimum needs 4*64 = 256 bits
        assert!(solve(&specs, 100.0).is_none());
        assert!(solve(&specs, 256.0).is_some());
    }

    #[test]
    fn solve_with_mean_quantizer_balances() {
        let mut specs: Vec<LevelSpec> =
            (0..8).map(|i| LevelSpec::entry(0.5 + i as f64, 16)).collect();
        specs.push(LevelSpec::mean(2.0, 16, 100));
        let q = solve(&specs, 2000.0).unwrap();
        assert_eq!(q.len(), 9);
        assert!(q.iter().all(|&x| (2..=(Q_MAX as u64)).contains(&x)));
    }

    #[test]
    fn abundant_budget_caps_at_qmax() {
        let specs = vec![LevelSpec::entry(1.0, 2); 2];
        let q = solve(&specs, 1e9).unwrap();
        assert!(q.iter().all(|&x| x == Q_MAX as u64));
    }

    #[test]
    fn zero_ranges_minimum_levels() {
        let specs = vec![LevelSpec::entry(0.0, 8); 3];
        let q = solve(&specs, 1000.0).unwrap();
        assert!(q.iter().all(|&x| x == 2));
    }

    #[test]
    fn optimal_beats_uniform_allocation() {
        // Fig.-5 claim: optimized levels yield lower total error than any
        // fixed Q with the same bit budget.
        let specs: Vec<LevelSpec> = [20.0, 8.0, 1.0, 0.4, 0.1, 0.05]
            .iter()
            .map(|&a| LevelSpec::entry(a, 64))
            .collect();
        let budget = 6.0 * 64.0 * 4.0; // avg 4 bits/level
        let opt = solve(&specs, budget).unwrap();
        let err_opt = objective(&specs, &opt);
        let fixed = vec![16u64; 6]; // exactly 4 bits each
        let err_fixed = objective(&specs, &fixed);
        assert!(err_opt < err_fixed, "opt={err_opt} fixed={err_fixed}");
    }

    #[test]
    fn objective_decreases_with_budget() {
        let specs: Vec<LevelSpec> =
            (0..10).map(|i| LevelSpec::entry(0.2 * (i + 1) as f64, 32)).collect();
        let mut last = f64::INFINITY;
        for &budget in &[320.0, 640.0, 1280.0, 2560.0] {
            let q = solve(&specs, budget).unwrap();
            let e = objective(&specs, &q);
            assert!(e <= last + 1e-9, "budget={budget}: {e} > {last}");
            last = e;
        }
    }
}
