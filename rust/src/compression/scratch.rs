//! Session-owned scratch arena for the wire hot path.
//!
//! Every codec session (PR 4 made codecs per-link sessions) may own a
//! [`WireScratch`]: pools of byte/float/index buffers plus the structured
//! per-step state (dropout plan, FWQ scratch, decode staging). Encode and
//! decode take buffers from the pools; the protocol hands finished outputs
//! back through [`crate::compression::Codec::reclaim`], so after a warm-up
//! step the steady-state encode/decode loop performs **zero heap
//! allocations** (verified by the `alloc-count` counting-allocator harness
//! in `bench_wire` and `integration_codecs`).
//!
//! Lifetime rules for codec authors:
//! * `take_*` returns an empty buffer with whatever capacity past rounds
//!   established; fill it and let it escape inside the `EncodedUplink` /
//!   `Frame` / `DecodedUplink` you return.
//! * When the caller is done with an output it calls `Codec::reclaim`,
//!   which routes the buffers back here via [`WireScratch::reclaim`].
//!   Unreturned buffers are simply dropped — reclaim is an optimization,
//!   never a correctness requirement.
//! * Buffers whose size tracks the kept set (which fluctuates round to
//!   round) must be `reserve`d to their D̄-derived upper bound, not their
//!   current need, or a post-warm-up high-water mark reallocates.

use crate::compression::codec::{GradMask, Reclaim};
use crate::compression::dropout::DropoutPlan;
use crate::compression::quant::FwqScratch;
use crate::tensor::Matrix;

/// Cap on pooled buffers per kind — enough for every in-flight output of a
/// protocol step (frame, reconstruction, mask, decode) with headroom, small
/// enough that a misbehaving caller can't grow the pool without bound.
const POOL_CAP: usize = 16;

#[derive(Debug, Default)]
pub struct WireScratch {
    bytes_pool: Vec<Vec<u8>>,
    f32_pool: Vec<Vec<f32>>,
    usize_pool: Vec<Vec<usize>>,
    /// session-wide high-water capacity bounds: pooled buffers cycle through
    /// roles of different sizes, so every `take_*` pre-reserves to the
    /// LARGEST bound any role has declared — a buffer can then never hit a
    /// fresh high-water mark (and realloc) after warm-up
    bytes_bound: usize,
    f32_bound: usize,
    usize_bound: usize,
    /// per-step dropout plan (FWDP) — reused across rounds
    pub plan: DropoutPlan,
    /// FWQ encoder/decoder scratch (stats, candidate plans, symbol staging)
    pub fwq: FwqScratch,
    /// decode staging: the B×D̂ matrix reconstructed from a frame before it
    /// is scattered back to B×D̄ (the `g_hat`/`f_hat` staging)
    pub stage: Matrix,
    /// blob staging for `read_blob_into`
    pub blob: Vec<u8>,
    /// symbol staging for the streaming scalar-quantizer paths
    /// (`scalar_encode_into` / `scalar_decode_into`)
    pub scalar_syms: Vec<u64>,
    /// all-zero σ fallback for codecs whose dropout ignores the statistics
    /// (the worker passes `stats = None` when `needs_sigma` is false)
    pub sigma_zeros: Vec<f32>,
}

impl WireScratch {
    pub fn new() -> WireScratch {
        WireScratch::default()
    }

    /// Raise the session-wide byte-buffer capacity bound (callers pass the
    /// worst-case frame size their role can produce, not this round's need).
    pub fn note_bytes_bound(&mut self, cap: usize) {
        self.bytes_bound = self.bytes_bound.max(cap);
    }

    /// An empty byte buffer (capacity reused from the pool when available),
    /// pre-reserved to the session's high-water bound.
    pub fn take_bytes(&mut self) -> Vec<u8> {
        let mut b = self.bytes_pool.pop().unwrap_or_default();
        b.reserve(self.bytes_bound);
        b
    }

    pub fn give_bytes(&mut self, mut b: Vec<u8>) {
        if self.bytes_pool.len() < POOL_CAP {
            b.clear();
            self.bytes_pool.push(b);
        }
    }

    /// An empty f32 buffer (capacity reused from the pool when available),
    /// pre-reserved to the session's high-water bound.
    pub fn take_f32(&mut self) -> Vec<f32> {
        let mut v = self.f32_pool.pop().unwrap_or_default();
        v.reserve(self.f32_bound);
        v
    }

    pub fn give_f32(&mut self, mut v: Vec<f32>) {
        if self.f32_pool.len() < POOL_CAP {
            v.clear();
            self.f32_pool.push(v);
        }
    }

    /// An empty index buffer (capacity reused from the pool when available),
    /// pre-reserved to the session's high-water bound.
    pub fn take_usize(&mut self) -> Vec<usize> {
        let mut v = self.usize_pool.pop().unwrap_or_default();
        v.reserve(self.usize_bound);
        v
    }

    /// Raise the session-wide index-buffer capacity bound.
    pub fn note_usize_bound(&mut self, cap: usize) {
        self.usize_bound = self.usize_bound.max(cap);
    }

    pub fn give_usize(&mut self, mut v: Vec<usize>) {
        if self.usize_pool.len() < POOL_CAP {
            v.clear();
            self.usize_pool.push(v);
        }
    }

    /// A zeroed `rows × cols` matrix backed by a pooled buffer.
    pub fn take_matrix(&mut self, rows: usize, cols: usize) -> Matrix {
        self.f32_bound = self.f32_bound.max(rows * cols);
        let mut data = self.take_f32();
        data.resize(rows * cols, 0.0);
        Matrix { rows, cols, data }
    }

    pub fn give_matrix(&mut self, m: Matrix) {
        self.give_f32(m.data);
    }

    /// Disassemble a finished protocol output into the pools. This is what
    /// [`crate::compression::Codec::reclaim`] forwards to for arena-backed
    /// sessions.
    pub fn reclaim(&mut self, buffers: Reclaim) {
        match buffers {
            Reclaim::Uplink(enc) => {
                self.give_bytes(enc.frame.payload);
                self.give_matrix(enc.f_hat);
                if let GradMask::Columns { kept, scale } = enc.mask {
                    self.give_usize(kept);
                    self.give_f32(scale);
                }
            }
            Reclaim::Downlink(dn) => {
                self.give_bytes(dn.frame.payload);
                self.give_matrix(dn.g_hat);
            }
            Reclaim::Decoded(dec) => {
                self.give_matrix(dec.f_hat);
                self.give_usize(dec.kept);
            }
            Reclaim::Frame(f) => {
                self.give_bytes(f.payload);
            }
            Reclaim::Grad(m) => {
                self.give_matrix(m);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pools_recycle_capacity() {
        let mut ws = WireScratch::new();
        let mut b = ws.take_bytes();
        b.extend_from_slice(&[1, 2, 3, 4]);
        let cap = b.capacity();
        ws.give_bytes(b);
        let b2 = ws.take_bytes();
        assert!(b2.is_empty());
        assert_eq!(b2.capacity(), cap, "capacity must survive the pool");
    }

    #[test]
    fn take_matrix_is_zeroed_after_reuse() {
        let mut ws = WireScratch::new();
        let mut m = ws.take_matrix(2, 3);
        m.data.iter_mut().for_each(|v| *v = 7.0);
        ws.give_matrix(m);
        let m2 = ws.take_matrix(3, 2);
        assert_eq!((m2.rows, m2.cols), (3, 2));
        assert!(m2.data.iter().all(|&v| v == 0.0), "pooled matrix must re-zero");
    }

    #[test]
    fn pool_is_bounded() {
        let mut ws = WireScratch::new();
        for _ in 0..100 {
            let b = Vec::with_capacity(8);
            ws.give_bytes(b);
        }
        assert!(ws.bytes_pool.len() <= POOL_CAP);
    }
}
