//! **Deprecated thin shim** over the pluggable codec API.
//!
//! The closed [`Scheme`] enum and the free `encode_uplink` /
//! `encode_downlink` / `decode_uplink_splitfc` functions survive for one
//! release as a compatibility layer: every call now delegates to the
//! [`crate::compression::Codec`] trait implementations in
//! `compression::codecs::*`, constructed per call. New code should build a
//! codec session from a [`crate::compression::CodecSpec`] through the
//! [`crate::compression::CodecRegistry`] instead (see the README "Codec
//! architecture" section); this shim will be removed once nothing in-tree
//! names `Scheme`.
//!
//! The golden tests below (plus `rust/tests/integration_codecs.rs`) lock
//! the ported codecs byte-identical to the historical enum pipeline.

use crate::compression::baselines::ScalarKind;
use crate::compression::codec::{Codec, SigmaStats};
use crate::compression::codecs::fedlite::FedLiteCodec;
use crate::compression::codecs::splitfc::SplitFcCodec;
use crate::compression::codecs::tops::TopSCodec;
use crate::compression::codecs::vanilla::VanillaCodec;
use crate::compression::dropout::DropKind;
use crate::tensor::Matrix;
use crate::transport::wire::Frame;
use crate::util::Rng;

pub use crate::compression::codec::{
    CodecParams, DecodedUplink, EncodedDownlink, EncodedUplink, GradMask,
};
pub use crate::compression::codecs::splitfc::FwqMode;

/// One row of the paper's comparison tables. **Deprecated**: a closed enum
/// duplicate of what the codec registry expresses openly; kept as a shim
/// for one release.
#[derive(Debug, Clone, PartialEq)]
pub enum Scheme {
    /// lossless 32-bit transmission (the "Vanilla SL" row)
    Vanilla,
    SplitFc {
        drop: Option<DropKind>,
        /// dimensionality-reduction ratio R = D̄/D (ignored if drop = None)
        r: f64,
        quant: FwqMode,
    },
    TopS {
        /// RandTop-S randomization θ (0 ⇒ plain Top-S)
        theta: f64,
        quant: Option<ScalarKind>,
    },
    FedLite { num_subvectors: usize },
}

impl Scheme {
    pub fn splitfc(r: f64) -> Scheme {
        Scheme::SplitFc {
            drop: Some(DropKind::Adaptive),
            r,
            quant: FwqMode::Optimal { use_mean: true },
        }
    }

    /// The equivalent codec session (fresh, no error-feedback state).
    pub fn to_codec(&self) -> Box<dyn Codec> {
        match self {
            Scheme::Vanilla => Box::new(VanillaCodec::default()),
            Scheme::SplitFc { drop, r, quant } => {
                Box::new(SplitFcCodec::new(*drop, *r, *quant))
            }
            Scheme::TopS { theta, quant } => {
                Box::new(TopSCodec { theta: *theta, quant: *quant })
            }
            Scheme::FedLite { num_subvectors } => {
                Box::new(FedLiteCodec { num_subvectors: *num_subvectors })
            }
        }
    }

    /// The registry spec string this scheme corresponds to. Codec canonical
    /// names ARE valid spec grammar, so this is just the codec name —
    /// `CodecSpec::parse(&scheme.spec())` builds an equivalent codec.
    pub fn spec(&self) -> String {
        self.name()
    }

    pub fn name(&self) -> String {
        self.to_codec().name()
    }
}

/// Uplink: compress the intermediate feature matrix F at the device.
/// **Deprecated** free-function form of [`Codec::encode_uplink`].
pub fn encode_uplink(
    scheme: &Scheme,
    f: &Matrix,
    sigma_norm: &[f32],
    params: &CodecParams,
    rng: &mut Rng,
) -> EncodedUplink {
    let stats = SigmaStats::new(sigma_norm.to_vec());
    scheme
        .to_codec()
        .encode_uplink(f, Some(&stats), params, rng)
        .unwrap_or_else(|e| panic!("encode_uplink({}): {e}", scheme.name()))
}

/// Downlink: compress the intermediate gradient matrix G at the PS.
/// **Deprecated** free-function form of [`Codec::encode_downlink`].
pub fn encode_downlink(
    scheme: &Scheme,
    g: &Matrix,
    mask: &GradMask,
    params: &CodecParams,
) -> EncodedDownlink {
    scheme
        .to_codec()
        .encode_downlink(g, mask, params)
        .unwrap_or_else(|e| panic!("encode_downlink({}): {e}", scheme.name()))
}

/// PS-side decode of an uplink frame (the true wire path; the value
/// returned by `encode_uplink` in `f_hat` must be byte-identical to this).
/// **Deprecated** free-function form of [`Codec::decode_uplink`].
pub fn decode_uplink_splitfc(
    frame: &Frame,
    scheme: &Scheme,
    params: &CodecParams,
) -> (Matrix, Vec<usize>) {
    let d = scheme
        .to_codec()
        .decode_uplink(frame, params)
        .unwrap_or_else(|e| panic!("decode_uplink({}): {e}", scheme.name()));
    (d.f_hat, d.kept)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{column_stats, normalized_sigma};

    fn feature_matrix(b: usize, d: usize, seed: u64) -> (Matrix, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let f = Matrix::from_fn(b, d, |_, c| {
            let scale = match c % 4 {
                0 => 5.0,
                1 => 1.0,
                2 => 0.05,
                _ => 0.0, // constant column
            };
            scale * rng.normal_f32(0.0, 1.0) + (c as f32 * 0.01)
        });
        let sigma = normalized_sigma(&column_stats(&f), d.min(4));
        (f, sigma)
    }

    fn all_schemes() -> Vec<Scheme> {
        vec![
            Scheme::Vanilla,
            Scheme::splitfc(8.0),
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::NoQuant },
            Scheme::SplitFc { drop: Some(DropKind::Random), r: 8.0, quant: FwqMode::NoQuant },
            Scheme::SplitFc {
                drop: Some(DropKind::Deterministic),
                r: 8.0,
                quant: FwqMode::NoQuant,
            },
            Scheme::SplitFc { drop: None, r: 1.0, quant: FwqMode::Optimal { use_mean: true } },
            Scheme::SplitFc {
                drop: Some(DropKind::Adaptive),
                r: 8.0,
                quant: FwqMode::Optimal { use_mean: false },
            },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Fixed { q: 8 } },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Scalar(ScalarKind::Eq) },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Scalar(ScalarKind::Pq) },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Scalar(ScalarKind::Nq) },
            Scheme::TopS { theta: 0.0, quant: None },
            Scheme::TopS { theta: 0.2, quant: None },
            Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Eq) },
            Scheme::FedLite { num_subvectors: 8 },
        ]
    }

    #[test]
    fn every_scheme_roundtrips_uplink() {
        let (f, sigma) = feature_matrix(16, 64, 1);
        for scheme in all_schemes() {
            let params = CodecParams::new(16, 64, 1.0);
            let mut rng = Rng::new(9);
            let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
            assert_eq!((enc.f_hat.rows, enc.f_hat.cols), (16, 64), "{}", scheme.name());
            assert!(enc.frame.payload_bits > 0, "{}", scheme.name());
            assert!(
                enc.f_hat.data.iter().all(|v| v.is_finite()),
                "{}",
                scheme.name()
            );
        }
    }

    #[test]
    fn vanilla_is_lossless() {
        let (f, sigma) = feature_matrix(8, 32, 2);
        let params = CodecParams::new(8, 32, 32.0);
        let mut rng = Rng::new(0);
        let enc = encode_uplink(&Scheme::Vanilla, &f, &sigma, &params, &mut rng);
        assert_eq!(enc.f_hat, f);
        assert_eq!(enc.frame.payload_bits, 32 * 8 * 32);
    }

    #[test]
    fn compressed_schemes_respect_budget() {
        let (f, sigma) = feature_matrix(32, 128, 3);
        for bpe in [0.5f64, 1.0, 2.0] {
            let params = CodecParams::new(32, 128, bpe);
            let budget = params.total_budget();
            for scheme in [
                Scheme::splitfc(8.0),
                Scheme::TopS { theta: 0.0, quant: None },
                Scheme::FedLite { num_subvectors: 16 },
            ] {
                let mut rng = Rng::new(4);
                let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
                let bits = enc.frame.payload_bits as f64;
                assert!(
                    bits <= budget * 1.10 + 512.0,
                    "{} bpe={bpe}: bits={bits} budget={budget}",
                    scheme.name()
                );
            }
        }
    }

    #[test]
    fn splitfc_beats_vanilla_bits_dramatically() {
        let (f, sigma) = feature_matrix(32, 128, 5);
        let params = CodecParams::new(32, 128, 0.2);
        let mut rng = Rng::new(1);
        let enc = encode_uplink(&Scheme::splitfc(16.0), &f, &sigma, &params, &mut rng);
        let ratio = (32.0 * 32.0 * 128.0) / enc.frame.payload_bits as f64;
        assert!(ratio > 100.0, "compression ratio {ratio}");
    }

    #[test]
    fn dropout_mask_propagates_to_downlink() {
        let (f, sigma) = feature_matrix(16, 64, 6);
        let params = CodecParams::new(16, 64, 1.0);
        let mut rng = Rng::new(2);
        let scheme = Scheme::splitfc(4.0);
        let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
        let GradMask::Columns { kept, .. } = &enc.mask else {
            panic!("expected column mask")
        };
        // fake gradient
        let g = Matrix::from_fn(16, 64, |r, c| (r + c) as f32 * 0.01);
        let dn = encode_downlink(&scheme, &g, &enc.mask, &CodecParams::new(16, 64, 32.0));
        // dropped columns must be zero in Ĝ, kept columns exact (lossless dn)
        for c in 0..64 {
            let is_kept = kept.contains(&c);
            for r_i in 0..16 {
                if is_kept {
                    assert_eq!(dn.g_hat.at(r_i, c), g.at(r_i, c));
                } else {
                    assert_eq!(dn.g_hat.at(r_i, c), 0.0);
                }
            }
        }
        // downlink bits ≈ 32 * B * D̂ (kept only)
        let expect = 32 * 16 * kept.len();
        assert_eq!(dn.frame.payload_bits as usize, expect);
    }

    #[test]
    fn downlink_quantized_when_budgeted() {
        let (f, sigma) = feature_matrix(16, 64, 7);
        let scheme = Scheme::splitfc(4.0);
        let mut rng = Rng::new(3);
        let up = CodecParams::new(16, 64, 0.4);
        let enc = encode_uplink(&scheme, &f, &sigma, &up, &mut rng);
        let g = Matrix::from_fn(16, 64, |r, c| ((r * c) % 7) as f32 * 0.1 - 0.3);
        let dn_params = CodecParams::new(16, 64, 0.4);
        let dn = encode_downlink(&scheme, &g, &enc.mask, &dn_params);
        assert!(
            (dn.frame.payload_bits as f64) <= dn_params.total_budget() * 1.1 + 512.0,
            "bits={}",
            dn.frame.payload_bits
        );
        // kept columns approximate, dropped zero
        if let GradMask::Columns { kept, .. } = &enc.mask {
            for c in 0..64 {
                if !kept.contains(&c) {
                    for r_i in 0..16 {
                        assert_eq!(dn.g_hat.at(r_i, c), 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn tops_downlink_only_sends_masked_entries() {
        let (f, sigma) = feature_matrix(8, 32, 8);
        let scheme = Scheme::TopS { theta: 0.0, quant: None };
        let params = CodecParams::new(8, 32, 2.0);
        let mut rng = Rng::new(4);
        let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
        let GradMask::Entries(masks) = &enc.mask else { panic!() };
        let s: usize = masks.iter().map(|m| m.len()).sum();
        let g = Matrix::from_fn(8, 32, |r, c| (r as f32 - c as f32) * 0.02);
        let dn = encode_downlink(&scheme, &g, &enc.mask, &CodecParams::new(8, 32, 32.0));
        assert_eq!(dn.frame.payload_bits as usize, 32 * s);
        for (r_i, kept) in masks.iter().enumerate() {
            for c in 0..32 {
                if kept.contains(&c) {
                    assert_eq!(dn.g_hat.at(r_i, c), g.at(r_i, c));
                } else {
                    assert_eq!(dn.g_hat.at(r_i, c), 0.0);
                }
            }
        }
    }

    #[test]
    fn adaptive_dropout_has_lower_mse_than_random_at_same_r() {
        // the Fig.-3 mechanism at matrix level: averaged over masks, AD keeps
        // high-σ columns and loses less energy than uniform-random dropout.
        let (f, sigma) = feature_matrix(32, 64, 9);
        let params = CodecParams::new(32, 64, 32.0);
        let mut err_ad = 0.0;
        let mut err_rand = 0.0;
        for trial in 0..20 {
            let mut rng = Rng::new(100 + trial);
            let ad = encode_uplink(
                &Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::NoQuant },
                &f,
                &sigma,
                &params,
                &mut rng,
            );
            let mut rng = Rng::new(100 + trial);
            let rd = encode_uplink(
                &Scheme::SplitFc { drop: Some(DropKind::Random), r: 8.0, quant: FwqMode::NoQuant },
                &f,
                &sigma,
                &params,
                &mut rng,
            );
            err_ad += f.sq_dist(&ad.f_hat);
            err_rand += f.sq_dist(&rd.f_hat);
        }
        assert!(err_ad < err_rand, "ad={err_ad} rand={err_rand}");
    }

    #[test]
    fn ps_side_decode_matches_encoder_reconstruction() {
        // the true wire path: PS decodes the frame bytes and must get exactly
        // the F̂ the encoder reported.
        let (f, sigma) = feature_matrix(16, 64, 11);
        let params = CodecParams::new(16, 64, 0.8);
        for scheme in [
            Scheme::splitfc(8.0),
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::NoQuant },
            Scheme::SplitFc { drop: None, r: 1.0, quant: FwqMode::Optimal { use_mean: true } },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Fixed { q: 4 } },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Scalar(ScalarKind::Nq) },
        ] {
            let mut rng = Rng::new(21);
            let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
            let (decoded, kept) = decode_uplink_splitfc(&enc.frame, &scheme, &params);
            assert_eq!(decoded, enc.f_hat, "{}", scheme.name());
            if let GradMask::Columns { kept: k2, .. } = &enc.mask {
                assert_eq!(&kept, k2, "{}", scheme.name());
            }
        }
    }

    #[test]
    fn scheme_names_are_unique() {
        let names: Vec<String> = all_schemes().iter().map(|s| s.name()).collect();
        let mut d = names.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), names.len(), "{names:?}");
    }

    #[test]
    fn scheme_spec_strings_build_equivalent_codecs() {
        // the shim's registry bridge: Scheme::spec() round-trips through the
        // spec grammar to a codec with the identical canonical name
        use crate::compression::codec::CodecSpec;
        for scheme in all_schemes() {
            let spec = CodecSpec::parse(&scheme.spec())
                .unwrap_or_else(|e| panic!("{}: {e}", scheme.spec()));
            let codec = spec.build().unwrap_or_else(|e| panic!("{}: {e}", scheme.spec()));
            assert_eq!(codec.name(), scheme.name(), "spec {}", scheme.spec());
        }
    }
}
