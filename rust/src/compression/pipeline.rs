//! Framework-level codecs: the scheme enum covering every row of the paper's
//! Tables I-III and Figs. 3-5, with real encode → frame → decode round trips
//! for both the uplink (features, eq. 7) and the downlink (gradients, eq. 8).
//!
//! The uplink encoder runs at the *device*: it consumes F plus the
//! σ-statistics (from the `feature_stats` HLO artifact) and emits a wire
//! frame; `f_hat` is what the PS reconstructs from that frame (we decode our
//! own bytes — the tested path IS the wire path). The downlink mirrors this
//! for G with the dropout coupling of eq. (8) (only kept columns / entries
//! travel back).

use crate::bitio::{BitReader, BitWriter};
use crate::compression::baselines::{
    fedlite_decode, fedlite_encode, qbar_levels, scalar_decode, scalar_encode, sparsity_level,
    top_s_decode, top_s_encode, FedLiteConfig, ScalarKind, TopSConfig,
};
use crate::compression::dropout::{self, DropKind, DropoutPlan};
use crate::compression::quant::{fwq_decode, fwq_encode, FwqConfig};
use crate::tensor::Matrix;
use crate::transport::wire::{Frame, FrameKind};
use crate::util::Rng;

/// How the (post-dropout) matrix entries are represented on the wire.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FwqMode {
    /// raw f32 entries (SplitFC-AD, Fig. 3)
    NoQuant,
    /// the paper's FWQ with optimal level allocation; `use_mean = false` is
    /// ablation Case 3 (two-stage only)
    Optimal { use_mean: bool },
    /// Fig. 5: fixed levels, no optimization
    Fixed { q: u64 },
    /// SplitFC-AD + {PQ, EQ, NQ} rows of Tables I/II
    Scalar(ScalarKind),
}

/// One row of the paper's comparison tables.
#[derive(Debug, Clone, PartialEq)]
pub enum Scheme {
    /// lossless 32-bit transmission (the "Vanilla SL" row)
    Vanilla,
    SplitFc {
        drop: Option<DropKind>,
        /// dimensionality-reduction ratio R = D̄/D (ignored if drop = None)
        r: f64,
        quant: FwqMode,
    },
    TopS {
        /// RandTop-S randomization θ (0 ⇒ plain Top-S)
        theta: f64,
        quant: Option<ScalarKind>,
    },
    FedLite { num_subvectors: usize },
}

impl Scheme {
    pub fn splitfc(r: f64) -> Scheme {
        Scheme::SplitFc {
            drop: Some(DropKind::Adaptive),
            r,
            quant: FwqMode::Optimal { use_mean: true },
        }
    }

    pub fn name(&self) -> String {
        match self {
            Scheme::Vanilla => "vanilla".into(),
            Scheme::SplitFc { drop, r, quant } => {
                let d: String = match drop {
                    None => "none".into(),
                    Some(DropKind::Adaptive) => "ad".into(),
                    Some(DropKind::Random) => "rand".into(),
                    Some(DropKind::Deterministic) => "det".into(),
                };
                let q = match quant {
                    FwqMode::NoQuant => "fp32".into(),
                    FwqMode::Optimal { use_mean: true } => "fwq".into(),
                    FwqMode::Optimal { use_mean: false } => "fwq-2stage".into(),
                    FwqMode::Fixed { q } => format!("fixedQ{q}"),
                    FwqMode::Scalar(k) => k.name().to_lowercase(),
                };
                format!("splitfc[{d},R={r},{q}]")
            }
            Scheme::TopS { theta, quant } => {
                let q = quant.map(|k| format!("+{}", k.name())).unwrap_or_default();
                if *theta > 0.0 {
                    format!("randtopS(θ={theta}){q}")
                } else {
                    format!("topS{q}")
                }
            }
            Scheme::FedLite { num_subvectors } => format!("fedlite(s={num_subvectors})"),
        }
    }
}

/// Shared codec parameters (identical at device and PS).
#[derive(Debug, Clone)]
pub struct CodecParams {
    pub batch: usize,
    pub dbar: usize,
    /// C_e — budget in bits per entry of the full B×D̄ matrix (32 = lossless)
    pub bits_per_entry: f64,
    pub q_ep: u64,
    /// shared seed for NoisyQuant's regenerable noise
    pub noise_seed: u64,
}

impl CodecParams {
    pub fn new(batch: usize, dbar: usize, bits_per_entry: f64) -> CodecParams {
        CodecParams { batch, dbar, bits_per_entry, q_ep: 200, noise_seed: 0x5EED }
    }

    pub fn total_budget(&self) -> f64 {
        self.bits_per_entry * self.batch as f64 * self.dbar as f64
    }
}

/// What the downlink must drop, mirroring the uplink decision (eq. 8).
#[derive(Debug, Clone)]
pub enum GradMask {
    /// no coupling: full G travels back
    All,
    /// column dropout: kept index set I + chain-rule scales 1/(1-p_j)
    Columns { kept: Vec<usize>, scale: Vec<f32> },
    /// entry-level sparsification: per-row kept indices
    Entries(Vec<Vec<usize>>),
}

#[derive(Debug, Clone)]
pub struct EncodedUplink {
    pub frame: Frame,
    /// the PS-side reconstruction F̂ (decoded from the frame bytes)
    pub f_hat: Matrix,
    pub mask: GradMask,
    /// paper-formula overhead (for reporting next to measured frame bits)
    pub nominal_bits: f64,
    /// FWQ M* when applicable (diagnostics)
    pub m_star: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct EncodedDownlink {
    pub frame: Frame,
    /// the device-side reconstruction Ĝ (B×D̄, chain-rule scale NOT applied;
    /// the trainer applies δ_j/(1-p_j) per eq. 7's backward path)
    pub g_hat: Matrix,
    pub nominal_bits: f64,
}

fn f32_dump(m: &Matrix, w: &mut BitWriter) {
    for &v in &m.data {
        w.write_f32(v);
    }
}

fn f32_undump(r: &mut BitReader, rows: usize, cols: usize) -> Matrix {
    let mut out = Matrix::zeros(rows, cols);
    for i in 0..rows * cols {
        out.data[i] = r.read_f32();
    }
    out
}

/// Embed a sub-codec's byte payload in an outer bit stream.
fn write_blob(w: &mut BitWriter, bytes: &[u8], bits: u64) {
    w.write_bits(bits, 40);
    for &b in bytes {
        w.write_bits(b as u64, 8);
    }
}

fn read_blob(r: &mut BitReader) -> (Vec<u8>, u64) {
    let bits = r.read_bits(40);
    let nbytes = ((bits + 7) / 8) as usize;
    let bytes: Vec<u8> = (0..nbytes).map(|_| r.read_bits(8) as u8).collect();
    (bytes, bits)
}

/// PS-side decode of a SplitFC uplink frame (the true wire path; the value
/// returned by `encode_uplink` in `f_hat` must be byte-identical to this).
pub fn decode_uplink_splitfc(
    frame: &Frame,
    scheme: &Scheme,
    params: &CodecParams,
) -> (Matrix, Vec<usize>) {
    let Scheme::SplitFc { drop, r, quant } = scheme else {
        panic!("decode_uplink_splitfc: not a SplitFc scheme");
    };
    // bit-exact fence: reading past the declared payload length is a codec
    // bug and should fail loudly, not zero-fill from the padding byte
    let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
    let dbar = params.dbar;
    let (kept, delta_bits): (Vec<usize>, f64) = if drop.is_some() {
        let delta: Vec<bool> = (0..dbar).map(|_| rd.read_bits(1) == 1).collect();
        ((0..dbar).filter(|&i| delta[i]).collect(), dbar as f64)
    } else {
        ((0..dbar).collect(), 0.0)
    };
    let c_ava = params.total_budget() - delta_bits;
    let ft_hat = match quant {
        FwqMode::NoQuant => f32_undump(&mut rd, params.batch, kept.len()),
        FwqMode::Optimal { use_mean } => {
            let (bytes, _) = read_blob(&mut rd);
            let mut cfg = FwqConfig::paper_default(params.batch, c_ava);
            cfg.q_ep = params.q_ep;
            cfg.use_mean = *use_mean;
            fwq_decode(&bytes, &cfg)
        }
        FwqMode::Fixed { q } => {
            let (bytes, _) = read_blob(&mut rd);
            let mut cfg = FwqConfig::paper_default(params.batch, c_ava);
            cfg.q_ep = params.q_ep;
            cfg.q_fixed = Some(*q);
            fwq_decode(&bytes, &cfg)
        }
        FwqMode::Scalar(kind) => {
            let (bytes, _) = read_blob(&mut rd);
            let _ = qbar_levels(c_ava, r.max(1.0), params.batch, dbar);
            scalar_decode(&bytes, *kind, params.noise_seed)
        }
    };
    (ft_hat.scatter_cols(&kept, dbar), kept)
}

fn apply_dropout(f: &Matrix, plan: &DropoutPlan) -> Matrix {
    // gather + 1/(1-p_j) rescale fused into one row-major pass (no strided
    // per-column sweeps on the uplink hot path)
    f.gather_cols_scaled(&plan.kept, &plan.scale)
}

/// Uplink: compress the intermediate feature matrix F at the device.
///
/// `sigma_norm` is the channel-normalized per-column stddev (eq. 10),
/// computed on the hot path by the `feature_stats` HLO artifact.
pub fn encode_uplink(
    scheme: &Scheme,
    f: &Matrix,
    sigma_norm: &[f32],
    params: &CodecParams,
    rng: &mut Rng,
) -> EncodedUplink {
    let (b, dbar) = (f.rows, f.cols);
    assert_eq!(b, params.batch);
    assert_eq!(dbar, params.dbar);
    match scheme {
        Scheme::Vanilla => {
            let mut w = BitWriter::with_capacity(4 * b * dbar);
            f32_dump(f, &mut w);
            let bits = w.bit_len();
            EncodedUplink {
                frame: Frame::new(FrameKind::FeaturesUp, w.into_bytes(), bits),
                f_hat: f.clone(),
                mask: GradMask::All,
                nominal_bits: 32.0 * (b * dbar) as f64,
                m_star: None,
            }
        }
        Scheme::SplitFc { drop, r, quant } => {
            let plan = match drop {
                Some(kind) => dropout::plan(*kind, sigma_norm, *r, rng),
                None => DropoutPlan::keep_all(dbar),
            };
            let ft = apply_dropout(f, &plan);
            let mut w = BitWriter::new();
            // δ index vector (D̄ bits) — only when dropout is active
            let delta_bits = if drop.is_some() { dbar as f64 } else { 0.0 };
            if drop.is_some() {
                for &d in &plan.delta {
                    w.write_bits(d as u64, 1);
                }
            }
            let c_ava = params.total_budget() - delta_bits;
            let (ft_hat, nominal, m_star) = match quant {
                FwqMode::NoQuant => {
                    f32_dump(&ft, &mut w);
                    (ft.clone(), delta_bits + 32.0 * ft.len() as f64, None)
                }
                FwqMode::Optimal { use_mean } => {
                    let mut cfg = FwqConfig::paper_default(b, c_ava);
                    cfg.q_ep = params.q_ep;
                    cfg.use_mean = *use_mean;
                    let (bytes, bits, info) = fwq_encode(&ft, &cfg);
                    write_blob(&mut w, &bytes, bits);
                    let out = fwq_decode(&bytes, &cfg);
                    (out, delta_bits + info.nominal_bits, Some(info.m_star))
                }
                FwqMode::Fixed { q } => {
                    let mut cfg = FwqConfig::paper_default(b, c_ava);
                    cfg.q_ep = params.q_ep;
                    cfg.q_fixed = Some(*q);
                    let (bytes, bits, info) = fwq_encode(&ft, &cfg);
                    write_blob(&mut w, &bytes, bits);
                    let out = fwq_decode(&bytes, &cfg);
                    (out, delta_bits + info.nominal_bits, Some(info.m_star))
                }
                FwqMode::Scalar(kind) => {
                    let q = qbar_levels(c_ava, r.max(1.0), b, dbar);
                    let (bytes, bits) = scalar_encode(&ft, *kind, q, params.noise_seed);
                    write_blob(&mut w, &bytes, bits);
                    let out = scalar_decode(&bytes, *kind, params.noise_seed);
                    let nominal =
                        delta_bits + ft.len() as f64 * (q as f64).log2() + 96.0;
                    (out, nominal, None)
                }
            };
            let f_hat = ft_hat.scatter_cols(&plan.kept, dbar);
            let bits = w.bit_len();
            EncodedUplink {
                frame: Frame::new(FrameKind::FeaturesUp, w.into_bytes(), bits),
                f_hat,
                mask: GradMask::Columns { kept: plan.kept, scale: plan.scale },
                nominal_bits: nominal,
                m_star,
            }
        }
        Scheme::TopS { theta, quant } => {
            let value_bits = match quant {
                None => 32.0,
                Some(_) => {
                    let q = qbar_levels(params.total_budget(), 16.0, b, dbar);
                    (q as f64).log2()
                }
            };
            let s = sparsity_level(dbar, params.bits_per_entry, value_bits).max(1);
            let cfg = TopSConfig { s, theta: *theta };
            match quant {
                None => {
                    let (bytes, bits, masks) = top_s_encode(f, &cfg, rng);
                    let f_hat = top_s_decode(&bytes);
                    let nominal = b as f64
                        * (s as f64 * 32.0
                            + crate::compression::baselines::topk::log2_binomial(dbar, s));
                    EncodedUplink {
                        frame: Frame::new(FrameKind::FeaturesUp, bytes, bits),
                        f_hat,
                        mask: GradMask::Entries(masks),
                        nominal_bits: nominal,
                        m_star: None,
                    }
                }
                Some(kind) => {
                    // sparse + scalar: sparsify first, quantize the masked matrix
                    let masks = crate::compression::baselines::topk::top_s_mask(f, &cfg, rng);
                    let mut sparse = Matrix::zeros(b, dbar);
                    for (r_i, kept) in masks.iter().enumerate() {
                        for &c in kept {
                            *sparse.at_mut(r_i, c) = f.at(r_i, c);
                        }
                    }
                    let q = qbar_levels(params.total_budget(), 16.0, b, dbar);
                    let mut w = BitWriter::new();
                    // indices per row (device-side mask must reach the PS)
                    let iw =
                        (usize::BITS - (dbar.max(2) - 1).leading_zeros()).max(1);
                    w.write_u32(s as u32);
                    for kept in &masks {
                        for &c in kept {
                            w.write_bits(c as u64, iw);
                        }
                    }
                    let (bytes, bits) = scalar_encode(&sparse, *kind, q, params.noise_seed);
                    write_blob(&mut w, &bytes, bits);
                    let f_hat = scalar_decode(&bytes, *kind, params.noise_seed);
                    // zero out the entries the mask dropped (quantizer noise)
                    let mut f_hat_sp = Matrix::zeros(b, dbar);
                    for (r_i, kept) in masks.iter().enumerate() {
                        for &c in kept {
                            *f_hat_sp.at_mut(r_i, c) = f_hat.at(r_i, c);
                        }
                    }
                    let nominal = b as f64
                        * (s as f64 * (q as f64).log2()
                            + crate::compression::baselines::topk::log2_binomial(dbar, s));
                    let bits_total = w.bit_len();
                    EncodedUplink {
                        frame: Frame::new(FrameKind::FeaturesUp, w.into_bytes(), bits_total),
                        f_hat: f_hat_sp,
                        mask: GradMask::Entries(masks),
                        nominal_bits: nominal,
                        m_star: None,
                    }
                }
            }
        }
        Scheme::FedLite { num_subvectors } => {
            let cfg = FedLiteConfig { num_subvectors: *num_subvectors, iters: 10 };
            let (bytes, bits) = fedlite_encode(f, &cfg, params.total_budget(), rng);
            let f_hat = fedlite_decode(&bytes);
            EncodedUplink {
                frame: Frame::new(FrameKind::FeaturesUp, bytes, bits),
                f_hat,
                mask: GradMask::All, // FedLite leaves G uncompressed (Sec. VII)
                nominal_bits: bits as f64,
                m_star: None,
            }
        }
    }
}

/// Downlink: compress the intermediate gradient matrix G at the PS,
/// honouring the uplink coupling (eq. 8). `params.bits_per_entry` is C_e,s;
/// 32.0 means lossless (the Table-I setting).
pub fn encode_downlink(
    scheme: &Scheme,
    g: &Matrix,
    mask: &GradMask,
    params: &CodecParams,
) -> EncodedDownlink {
    let (b, dbar) = (g.rows, g.cols);
    let lossless = params.bits_per_entry >= 32.0;
    match mask {
        GradMask::All => {
            let mut w = BitWriter::with_capacity(4 * b * dbar);
            f32_dump(g, &mut w);
            let bits = w.bit_len();
            EncodedDownlink {
                frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits),
                g_hat: g.clone(),
                nominal_bits: 32.0 * (b * dbar) as f64,
            }
        }
        GradMask::Columns { kept, .. } => {
            let gt = g.gather_cols(kept);
            let mut w = BitWriter::new();
            let c_ava = params.total_budget();
            let (gt_hat, nominal) = if lossless {
                f32_dump(&gt, &mut w);
                (gt.clone(), 32.0 * gt.len() as f64)
            } else {
                match scheme {
                    Scheme::SplitFc { quant: FwqMode::Scalar(kind), r, .. } => {
                        let q = qbar_levels(c_ava, r.max(1.0), b, dbar);
                        let (bytes, bits) = scalar_encode(&gt, *kind, q, params.noise_seed ^ 1);
                        write_blob(&mut w, &bytes, bits);
                        let out = scalar_decode(&bytes, *kind, params.noise_seed ^ 1);
                        (out, gt.len() as f64 * (q as f64).log2() + 96.0)
                    }
                    Scheme::SplitFc { quant: FwqMode::Fixed { q }, .. } => {
                        let mut cfg = FwqConfig::paper_default(b, c_ava);
                        cfg.q_ep = params.q_ep;
                        cfg.q_fixed = Some(*q);
                        let (bytes, bits, info) = fwq_encode(&gt, &cfg);
                        write_blob(&mut w, &bytes, bits);
                        (fwq_decode(&bytes, &cfg), info.nominal_bits)
                    }
                    Scheme::SplitFc { quant: FwqMode::Optimal { use_mean }, .. } => {
                        let mut cfg = FwqConfig::paper_default(b, c_ava);
                        cfg.q_ep = params.q_ep;
                        cfg.use_mean = *use_mean;
                        let (bytes, bits, info) = fwq_encode(&gt, &cfg);
                        write_blob(&mut w, &bytes, bits);
                        (fwq_decode(&bytes, &cfg), info.nominal_bits)
                    }
                    _ => {
                        // any other scheme with column masks: paper FWQ
                        let cfg = FwqConfig::paper_default(b, c_ava);
                        let (bytes, bits, info) = fwq_encode(&gt, &cfg);
                        write_blob(&mut w, &bytes, bits);
                        (fwq_decode(&bytes, &cfg), info.nominal_bits)
                    }
                }
            };
            let g_hat = gt_hat.scatter_cols(kept, dbar);
            let bits = w.bit_len();
            EncodedDownlink {
                frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits),
                g_hat,
                nominal_bits: nominal,
            }
        }
        GradMask::Entries(masks) => {
            // the device knows the masks it sent: only values travel back
            let mut w = BitWriter::new();
            let mut g_hat = Matrix::zeros(b, dbar);
            if lossless {
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        w.write_f32(g.at(r_i, c));
                        *g_hat.at_mut(r_i, c) = g.at(r_i, c);
                    }
                }
                let bits = w.bit_len();
                let n: usize = masks.iter().map(|m| m.len()).sum();
                EncodedDownlink {
                    frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits),
                    g_hat,
                    nominal_bits: 32.0 * n as f64,
                }
            } else {
                // gather masked values into a dense vector, scalar-quantize
                let vals: Vec<f32> = masks
                    .iter()
                    .enumerate()
                    .flat_map(|(r_i, kept)| kept.iter().map(move |&c| (r_i, c)))
                    .map(|(r_i, c)| g.at(r_i, c))
                    .collect();
                let kind = match scheme {
                    Scheme::TopS { quant: Some(k), .. } => *k,
                    _ => ScalarKind::Eq,
                };
                let q = qbar_levels(params.total_budget(), 16.0, b, dbar);
                let vm = Matrix::from_vec(1, vals.len(), vals);
                let (bytes, bits) = scalar_encode(&vm, kind, q, params.noise_seed ^ 2);
                write_blob(&mut w, &bytes, bits);
                let deq = scalar_decode(&bytes, kind, params.noise_seed ^ 2);
                let mut it = deq.data.iter();
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        *g_hat.at_mut(r_i, c) = *it.next().unwrap();
                    }
                }
                let bits_total = w.bit_len();
                EncodedDownlink {
                    frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits_total),
                    g_hat,
                    nominal_bits: deq.len() as f64 * (q as f64).log2(),
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::tensor::{column_stats, normalized_sigma};

    fn feature_matrix(b: usize, d: usize, seed: u64) -> (Matrix, Vec<f32>) {
        let mut rng = Rng::new(seed);
        let f = Matrix::from_fn(b, d, |_, c| {
            let scale = match c % 4 {
                0 => 5.0,
                1 => 1.0,
                2 => 0.05,
                _ => 0.0, // constant column
            };
            scale * rng.normal_f32(0.0, 1.0) + (c as f32 * 0.01)
        });
        let sigma = normalized_sigma(&column_stats(&f), d.min(4));
        (f, sigma)
    }

    fn all_schemes() -> Vec<Scheme> {
        vec![
            Scheme::Vanilla,
            Scheme::splitfc(8.0),
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::NoQuant },
            Scheme::SplitFc { drop: Some(DropKind::Random), r: 8.0, quant: FwqMode::NoQuant },
            Scheme::SplitFc {
                drop: Some(DropKind::Deterministic),
                r: 8.0,
                quant: FwqMode::NoQuant,
            },
            Scheme::SplitFc { drop: None, r: 1.0, quant: FwqMode::Optimal { use_mean: true } },
            Scheme::SplitFc {
                drop: Some(DropKind::Adaptive),
                r: 8.0,
                quant: FwqMode::Optimal { use_mean: false },
            },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Fixed { q: 8 } },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Scalar(ScalarKind::Eq) },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Scalar(ScalarKind::Pq) },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Scalar(ScalarKind::Nq) },
            Scheme::TopS { theta: 0.0, quant: None },
            Scheme::TopS { theta: 0.2, quant: None },
            Scheme::TopS { theta: 0.0, quant: Some(ScalarKind::Eq) },
            Scheme::FedLite { num_subvectors: 8 },
        ]
    }

    #[test]
    fn every_scheme_roundtrips_uplink() {
        let (f, sigma) = feature_matrix(16, 64, 1);
        for scheme in all_schemes() {
            let params = CodecParams::new(16, 64, 1.0);
            let mut rng = Rng::new(9);
            let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
            assert_eq!((enc.f_hat.rows, enc.f_hat.cols), (16, 64), "{}", scheme.name());
            assert!(enc.frame.payload_bits > 0, "{}", scheme.name());
            assert!(
                enc.f_hat.data.iter().all(|v| v.is_finite()),
                "{}",
                scheme.name()
            );
        }
    }

    #[test]
    fn vanilla_is_lossless() {
        let (f, sigma) = feature_matrix(8, 32, 2);
        let params = CodecParams::new(8, 32, 32.0);
        let mut rng = Rng::new(0);
        let enc = encode_uplink(&Scheme::Vanilla, &f, &sigma, &params, &mut rng);
        assert_eq!(enc.f_hat, f);
        assert_eq!(enc.frame.payload_bits, 32 * 8 * 32);
    }

    #[test]
    fn compressed_schemes_respect_budget() {
        let (f, sigma) = feature_matrix(32, 128, 3);
        for bpe in [0.5f64, 1.0, 2.0] {
            let params = CodecParams::new(32, 128, bpe);
            let budget = params.total_budget();
            for scheme in [
                Scheme::splitfc(8.0),
                Scheme::TopS { theta: 0.0, quant: None },
                Scheme::FedLite { num_subvectors: 16 },
            ] {
                let mut rng = Rng::new(4);
                let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
                let bits = enc.frame.payload_bits as f64;
                assert!(
                    bits <= budget * 1.10 + 512.0,
                    "{} bpe={bpe}: bits={bits} budget={budget}",
                    scheme.name()
                );
            }
        }
    }

    #[test]
    fn splitfc_beats_vanilla_bits_dramatically() {
        let (f, sigma) = feature_matrix(32, 128, 5);
        let params = CodecParams::new(32, 128, 0.2);
        let mut rng = Rng::new(1);
        let enc = encode_uplink(&Scheme::splitfc(16.0), &f, &sigma, &params, &mut rng);
        let ratio = (32.0 * 32.0 * 128.0) / enc.frame.payload_bits as f64;
        assert!(ratio > 100.0, "compression ratio {ratio}");
    }

    #[test]
    fn dropout_mask_propagates_to_downlink() {
        let (f, sigma) = feature_matrix(16, 64, 6);
        let params = CodecParams::new(16, 64, 1.0);
        let mut rng = Rng::new(2);
        let scheme = Scheme::splitfc(4.0);
        let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
        let GradMask::Columns { kept, .. } = &enc.mask else {
            panic!("expected column mask")
        };
        // fake gradient
        let g = Matrix::from_fn(16, 64, |r, c| (r + c) as f32 * 0.01);
        let dn = encode_downlink(&scheme, &g, &enc.mask, &CodecParams::new(16, 64, 32.0));
        // dropped columns must be zero in Ĝ, kept columns exact (lossless dn)
        for c in 0..64 {
            let is_kept = kept.contains(&c);
            for r_i in 0..16 {
                if is_kept {
                    assert_eq!(dn.g_hat.at(r_i, c), g.at(r_i, c));
                } else {
                    assert_eq!(dn.g_hat.at(r_i, c), 0.0);
                }
            }
        }
        // downlink bits ≈ 32 * B * D̂ (kept only)
        let expect = 32 * 16 * kept.len();
        assert_eq!(dn.frame.payload_bits as usize, expect);
    }

    #[test]
    fn downlink_quantized_when_budgeted() {
        let (f, sigma) = feature_matrix(16, 64, 7);
        let scheme = Scheme::splitfc(4.0);
        let mut rng = Rng::new(3);
        let up = CodecParams::new(16, 64, 0.4);
        let enc = encode_uplink(&scheme, &f, &sigma, &up, &mut rng);
        let g = Matrix::from_fn(16, 64, |r, c| ((r * c) % 7) as f32 * 0.1 - 0.3);
        let dn_params = CodecParams::new(16, 64, 0.4);
        let dn = encode_downlink(&scheme, &g, &enc.mask, &dn_params);
        assert!(
            (dn.frame.payload_bits as f64) <= dn_params.total_budget() * 1.1 + 512.0,
            "bits={}",
            dn.frame.payload_bits
        );
        // kept columns approximate, dropped zero
        if let GradMask::Columns { kept, .. } = &enc.mask {
            for c in 0..64 {
                if !kept.contains(&c) {
                    for r_i in 0..16 {
                        assert_eq!(dn.g_hat.at(r_i, c), 0.0);
                    }
                }
            }
        }
    }

    #[test]
    fn tops_downlink_only_sends_masked_entries() {
        let (f, sigma) = feature_matrix(8, 32, 8);
        let scheme = Scheme::TopS { theta: 0.0, quant: None };
        let params = CodecParams::new(8, 32, 2.0);
        let mut rng = Rng::new(4);
        let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
        let GradMask::Entries(masks) = &enc.mask else { panic!() };
        let s: usize = masks.iter().map(|m| m.len()).sum();
        let g = Matrix::from_fn(8, 32, |r, c| (r as f32 - c as f32) * 0.02);
        let dn = encode_downlink(&scheme, &g, &enc.mask, &CodecParams::new(8, 32, 32.0));
        assert_eq!(dn.frame.payload_bits as usize, 32 * s);
        for (r_i, kept) in masks.iter().enumerate() {
            for c in 0..32 {
                if kept.contains(&c) {
                    assert_eq!(dn.g_hat.at(r_i, c), g.at(r_i, c));
                } else {
                    assert_eq!(dn.g_hat.at(r_i, c), 0.0);
                }
            }
        }
    }

    #[test]
    fn adaptive_dropout_has_lower_mse_than_random_at_same_r() {
        // the Fig.-3 mechanism at matrix level: averaged over masks, AD keeps
        // high-σ columns and loses less energy than uniform-random dropout.
        let (f, sigma) = feature_matrix(32, 64, 9);
        let params = CodecParams::new(32, 64, 32.0);
        let mut err_ad = 0.0;
        let mut err_rand = 0.0;
        for trial in 0..20 {
            let mut rng = Rng::new(100 + trial);
            let ad = encode_uplink(
                &Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::NoQuant },
                &f,
                &sigma,
                &params,
                &mut rng,
            );
            let mut rng = Rng::new(100 + trial);
            let rd = encode_uplink(
                &Scheme::SplitFc { drop: Some(DropKind::Random), r: 8.0, quant: FwqMode::NoQuant },
                &f,
                &sigma,
                &params,
                &mut rng,
            );
            err_ad += f.sq_dist(&ad.f_hat);
            err_rand += f.sq_dist(&rd.f_hat);
        }
        assert!(err_ad < err_rand, "ad={err_ad} rand={err_rand}");
    }

    #[test]
    fn ps_side_decode_matches_encoder_reconstruction() {
        // the true wire path: PS decodes the frame bytes and must get exactly
        // the F̂ the encoder reported.
        let (f, sigma) = feature_matrix(16, 64, 11);
        let params = CodecParams::new(16, 64, 0.8);
        for scheme in [
            Scheme::splitfc(8.0),
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::NoQuant },
            Scheme::SplitFc { drop: None, r: 1.0, quant: FwqMode::Optimal { use_mean: true } },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Fixed { q: 4 } },
            Scheme::SplitFc { drop: Some(DropKind::Adaptive), r: 8.0, quant: FwqMode::Scalar(ScalarKind::Nq) },
        ] {
            let mut rng = Rng::new(21);
            let enc = encode_uplink(&scheme, &f, &sigma, &params, &mut rng);
            let (decoded, kept) = decode_uplink_splitfc(&enc.frame, &scheme, &params);
            assert_eq!(decoded, enc.f_hat, "{}", scheme.name());
            if let GradMask::Columns { kept: k2, .. } = &enc.mask {
                assert_eq!(&kept, k2, "{}", scheme.name());
            }
        }
    }

    #[test]
    fn scheme_names_are_unique() {
        let names: Vec<String> = all_schemes().iter().map(|s| s.name()).collect();
        let mut d = names.clone();
        d.sort();
        d.dedup();
        assert_eq!(d.len(), names.len(), "{names:?}");
    }
}
