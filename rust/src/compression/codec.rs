//! The pluggable compression API: the [`Codec`] trait, codec capability
//! reports, spec grammar, and the string-keyed [`CodecRegistry`].
//!
//! A codec is a *session*: one instance per device link, owning any
//! cross-round state (e.g. the error-feedback residual of
//! `splitfc[...,ef]`). It encodes the uplink feature matrix F into a wire
//! [`Frame`], decodes its own frames back (the tested path IS the wire
//! path), and mirrors the same for the downlink gradient matrix G under the
//! uplink's [`GradMask`] coupling (paper eq. 8).
//!
//! Frames are *self-describing*: every codec stamps the frames it emits
//! with a versioned codec id (FNV-1a of the canonical codec name + a wire
//! version), and every decoder rejects frames stamped by a different
//! codec/version instead of misparsing them.
//!
//! Schemes are constructed from string specs (`splitfc[ad,R=8,fwq]`,
//! `tops[theta=0.2,eq]`, `fedlite[s=16]`, or any registered legacy alias
//! like `splitfc-ad+pq`) through a [`CodecRegistry`] of builder closures.
//! New codecs register without touching any core file:
//!
//! ```ignore
//! splitfc::compression::register_codec("sign", |_spec| Ok(Box::new(SignCodec)));
//! // ... then `--scheme sign` resolves like any built-in.
//! ```

use std::collections::BTreeMap;
use std::fmt;
use std::sync::{OnceLock, RwLock};

use crate::compression::codecs::common::{
    decode_downlink_styled, encode_downlink_styled, DownlinkStyle,
};
use crate::tensor::Matrix;
use crate::transport::wire::Frame;
use crate::util::error::{Context, Result};
use crate::util::Rng;
use crate::{ensure, err};

/// Shared codec parameters (identical at device and PS).
#[derive(Debug, Clone)]
pub struct CodecParams {
    pub batch: usize,
    pub dbar: usize,
    /// C_e — budget in bits per entry of the full B×D̄ matrix (32 = lossless)
    pub bits_per_entry: f64,
    /// endpoint-quantizer levels Q_ep for FWQ (paper Sec. VII: 200)
    pub q_ep: u64,
    /// shared seed for NoisyQuant's regenerable noise
    pub noise_seed: u64,
    /// columns per feature channel (eq. 10 normalization groups); codecs
    /// that recompute σ statistics themselves (error feedback) need it.
    /// Defaults to D̄ = one global channel.
    pub chan_size: usize,
}

impl CodecParams {
    pub fn new(batch: usize, dbar: usize, bits_per_entry: f64) -> CodecParams {
        CodecParams {
            batch,
            dbar,
            bits_per_entry,
            q_ep: 200,
            noise_seed: 0x5EED,
            chan_size: dbar.max(1),
        }
    }

    /// Override the per-channel column count (the model preset's value).
    pub fn with_chan_size(mut self, chan_size: usize) -> CodecParams {
        self.chan_size = chan_size.max(1);
        self
    }

    /// Override Q_ep (the `--q-ep` flag).
    pub fn with_q_ep(mut self, q_ep: u64) -> CodecParams {
        self.q_ep = q_ep;
        self
    }

    /// Override the NoisyQuant noise seed (the `--noise-seed` flag).
    pub fn with_noise_seed(mut self, seed: u64) -> CodecParams {
        self.noise_seed = seed;
        self
    }

    pub fn total_budget(&self) -> f64 {
        self.bits_per_entry * self.batch as f64 * self.dbar as f64
    }
}

/// What the downlink must drop, mirroring the uplink decision (eq. 8).
#[derive(Debug, Clone)]
pub enum GradMask {
    /// no coupling: full G travels back
    All,
    /// column dropout: kept index set I + chain-rule scales 1/(1-p_j)
    Columns { kept: Vec<usize>, scale: Vec<f32> },
    /// entry-level sparsification: per-row kept indices
    Entries(Vec<Vec<usize>>),
}

#[derive(Debug, Clone)]
pub struct EncodedUplink {
    pub frame: Frame,
    /// the PS-side reconstruction F̂ (decoded from the frame bytes)
    pub f_hat: Matrix,
    pub mask: GradMask,
    /// paper-formula overhead (for reporting next to measured frame bits)
    pub nominal_bits: f64,
    /// FWQ M* when applicable (diagnostics)
    pub m_star: Option<usize>,
}

#[derive(Debug, Clone)]
pub struct EncodedDownlink {
    pub frame: Frame,
    /// the device-side reconstruction Ĝ (B×D̄, chain-rule scale NOT applied;
    /// the worker applies δ_j/(1-p_j) per eq. 7's backward path)
    pub g_hat: Matrix,
    pub nominal_bits: f64,
}

/// PS-side result of decoding an uplink frame.
#[derive(Debug, Clone)]
pub struct DecodedUplink {
    pub f_hat: Matrix,
    /// kept column indices (all columns for codecs without column dropout)
    pub kept: Vec<usize>,
}

/// The σ statistics an uplink encoder may consume (eq. 10): the per-column
/// stddev of the channel-normalized features, produced on the hot path by
/// the backend's `feature_stats` kernel.
#[derive(Debug, Clone)]
pub struct SigmaStats {
    pub sigma_norm: Vec<f32>,
}

impl SigmaStats {
    pub fn new(sigma_norm: Vec<f32>) -> SigmaStats {
        SigmaStats { sigma_norm }
    }
}

/// Capability report: what a codec needs from the protocol around it.
/// Replaces the coordinator's hand-written matches on scheme internals.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct CodecRequirements {
    /// needs the `feature_stats` σ kernel run before `encode_uplink`
    pub needs_sigma: bool,
    /// carries cross-round session state (e.g. an error-feedback residual);
    /// such a codec instance must not be shared across devices
    pub stateful: bool,
}

/// Stable 32-bit id for a codec name (FNV-1a), stamped into every frame.
/// `const` so sessions can cache their id instead of re-formatting their
/// canonical name on every frame (the wire hot path stamps one frame per
/// link direction per step).
pub const fn codec_id(name: &str) -> u32 {
    let bytes = name.as_bytes();
    let mut h: u32 = 0x811C_9DC5;
    let mut i = 0;
    while i < bytes.len() {
        h ^= bytes[i] as u32;
        h = h.wrapping_mul(0x0100_0193);
        i += 1;
    }
    h
}

/// Finished protocol outputs handed back to a codec session so their
/// buffers can seed the next round (see
/// [`crate::compression::WireScratch`]). Codecs without an arena ignore
/// reclaims — dropping the value is always correct.
#[derive(Debug)]
pub enum Reclaim {
    /// a consumed uplink encode result (frame + reconstruction + mask)
    Uplink(EncodedUplink),
    /// a consumed downlink encode result (frame + reconstruction)
    Downlink(EncodedDownlink),
    /// a consumed PS-side uplink decode result
    Decoded(DecodedUplink),
    /// a lone consumed frame
    Frame(Frame),
    /// a consumed gradient/feature reconstruction matrix
    Grad(Matrix),
}

/// A compression scheme as a session object (object-safe, `Send + Sync`).
///
/// One instance per device link. `encode_uplink` takes `&mut self` so
/// sessionful codecs (error feedback) can update their state per round.
pub trait Codec: Send + Sync {
    /// Canonical, fully-parameterized name, e.g. `splitfc[ad,R=8,fwq]`.
    /// Must be valid spec-grammar: `CodecSpec::parse(&codec.name())` builds
    /// an equivalent codec, so logged names paste straight back into
    /// `--scheme`.
    fn name(&self) -> String;

    /// Wire-format version stamped into frames; bump on layout changes.
    fn wire_version(&self) -> u16 {
        1
    }

    /// The 32-bit id stamped into frames — `codec_id(&self.name())` by
    /// default. Hot-path sessions override this with a cached value so
    /// stamping/checking a frame stops formatting the canonical name;
    /// overrides must return the id of the *current* configuration.
    fn wire_id(&self) -> u32 {
        codec_id(&self.name())
    }

    /// What this codec needs from the protocol (σ stats, session state).
    fn requirements(&self) -> CodecRequirements;

    /// Device side: compress the feature matrix F into a wire frame.
    /// `stats` is `Some` iff `requirements().needs_sigma` asked for it.
    fn encode_uplink(
        &mut self,
        f: &Matrix,
        stats: Option<&SigmaStats>,
        params: &CodecParams,
        rng: &mut Rng,
    ) -> Result<EncodedUplink>;

    /// PS side: reconstruct F̂ from the frame bytes (the true wire path;
    /// must equal the `f_hat` the encoder reported, byte-for-byte).
    fn decode_uplink(&self, frame: &Frame, params: &CodecParams) -> Result<DecodedUplink>;

    /// The downlink policy this codec applies under each [`GradMask`]
    /// shape; the default `encode_downlink`/`decode_downlink` pair is
    /// driven by it (override those only for a custom downlink wire
    /// format).
    fn downlink_style(&self) -> DownlinkStyle {
        DownlinkStyle::default()
    }

    /// PS side: compress the gradient matrix G under the uplink coupling.
    /// Default: the eq.-8 mask-coupled downlink at `downlink_style()`,
    /// codec-stamped.
    fn encode_downlink(
        &mut self,
        g: &Matrix,
        mask: &GradMask,
        params: &CodecParams,
    ) -> Result<EncodedDownlink> {
        let mut dn = encode_downlink_styled(&self.downlink_style(), g, mask, params);
        dn.frame = self.stamp(dn.frame);
        Ok(dn)
    }

    /// Device side: reconstruct Ĝ from the downlink frame (the device knows
    /// the mask it sent uplink). Default mirrors `encode_downlink`, frame
    /// check included.
    fn decode_downlink(
        &self,
        frame: &Frame,
        mask: &GradMask,
        params: &CodecParams,
    ) -> Result<Matrix> {
        self.check_frame(frame)?;
        decode_downlink_styled(&self.downlink_style(), frame, mask, params)
    }

    /// Stamp a frame with this codec's versioned id (encoders call this on
    /// every frame they emit).
    fn stamp(&self, frame: Frame) -> Frame {
        frame.with_codec(self.wire_id(), self.wire_version())
    }

    /// Hand a finished round's outputs back to the session so their buffers
    /// can be reused by the next encode/decode (steady-state zero
    /// allocation). Default: drop them — codecs without a scratch arena
    /// need no pool.
    fn reclaim(&mut self, buffers: Reclaim) {
        let _ = buffers;
    }

    /// Serialize this session's cross-round state (e.g. the error-feedback
    /// residual) for checkpointing. The bytes are opaque to the caller;
    /// stateless codecs return empty (the default), so only sessions that
    /// actually carry state pay for it.
    fn export_session(&self) -> Vec<u8> {
        Vec::new()
    }

    /// Restore a session from [`Codec::export_session`] bytes. The default
    /// accepts only the stateless empty export; stateful codecs must
    /// override both hooks together.
    fn restore_session(&mut self, bytes: &[u8]) -> Result<()> {
        ensure!(
            bytes.is_empty(),
            "codec {:?} has no session restore but the checkpoint carries \
             {} bytes of session state",
            self.name(),
            bytes.len()
        );
        Ok(())
    }

    /// Reject frames emitted by a different codec or wire version
    /// (decoders call this before touching the payload).
    fn check_frame(&self, frame: &Frame) -> Result<()> {
        let id = self.wire_id();
        ensure!(
            frame.codec_id == id,
            "frame codec id {:#010x} does not match codec {:?} ({:#010x}): \
             encoder/decoder scheme mismatch",
            frame.codec_id,
            self.name(),
            id
        );
        ensure!(
            frame.codec_version == self.wire_version(),
            "frame wire version {} does not match codec {:?} version {}",
            frame.codec_version,
            self.name(),
            self.wire_version()
        );
        Ok(())
    }
}

/// A parsed codec spec: `base[arg,key=value,...]` plus the CLI-level default
/// dimensionality-reduction ratio R (used when the args don't carry `R=`).
#[derive(Debug, Clone, PartialEq)]
pub struct CodecSpec {
    /// registry key, e.g. `splitfc`, `tops`, `splitfc-ad+pq`
    pub base: String,
    /// raw bracket arguments, order-preserved
    pub args: Vec<String>,
    /// default R when `args` carry no `R=` (from `--r`)
    pub r: f64,
}

impl CodecSpec {
    /// Parse `name` or `name[arg,...]` with an explicit default R.
    pub fn parse_with_r(s: &str, r: f64) -> Result<CodecSpec> {
        let s = s.trim();
        ensure!(!s.is_empty(), "empty codec spec");
        let (base, args) = match s.find('[') {
            None => (s.to_string(), Vec::new()),
            Some(i) => {
                ensure!(s.ends_with(']'), "codec spec {s:?}: missing closing ']'");
                let inner = &s[i + 1..s.len() - 1];
                let args: Vec<String> = inner
                    .split(',')
                    .map(|a| a.trim().to_string())
                    .filter(|a| !a.is_empty())
                    .collect();
                (s[..i].to_string(), args)
            }
        };
        ensure!(!base.is_empty(), "codec spec {s:?}: empty codec name");
        ensure!(
            base.chars().all(|c| c.is_ascii_alphanumeric() || "+-_.".contains(c)),
            "codec spec {s:?}: invalid codec name {base:?}"
        );
        Ok(CodecSpec { base, args, r })
    }

    /// Parse with the conventional default R = 16 (the paper's Table-I R).
    pub fn parse(s: &str) -> Result<CodecSpec> {
        CodecSpec::parse_with_r(s, 16.0)
    }

    /// The default (lossless) spec.
    pub fn vanilla() -> CodecSpec {
        CodecSpec { base: "vanilla".to_string(), args: Vec::new(), r: 1.0 }
    }

    /// Value of a `key=value` argument.
    pub fn get(&self, key: &str) -> Option<&str> {
        self.args.iter().find_map(|a| {
            a.strip_prefix(key).and_then(|rest| rest.strip_prefix('='))
        })
    }

    /// Is a bare flag argument present?
    pub fn has(&self, flag: &str) -> bool {
        self.args.iter().any(|a| a == flag)
    }

    /// Build a fresh codec session from the process-global registry.
    pub fn build(&self) -> Result<Box<dyn Codec>> {
        build_codec(self)
    }

    /// The canonical, fully-resolved codec name this spec builds (e.g.
    /// `splitfc[ad,R=8,fwq]` for `--scheme splitfc --r 8`), falling back to
    /// the spec string when the codec cannot be built. This is the value to
    /// record in run metadata: alias defaults (like `splitfc-quant-only`
    /// pinning R=1) are resolved by the builder, not guessable from the
    /// spec alone.
    pub fn canonical_name(&self) -> String {
        self.build().map(|c| c.name()).unwrap_or_else(|_| self.to_string())
    }
}

impl fmt::Display for CodecSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if self.args.is_empty() {
            write!(f, "{}", self.base)
        } else {
            write!(f, "{}[{}]", self.base, self.args.join(","))
        }
    }
}

type CodecBuilder = Box<dyn Fn(&CodecSpec) -> Result<Box<dyn Codec>> + Send + Sync>;

/// String-keyed registry of codec builders. Keys are spec base names; each
/// builder turns a parsed [`CodecSpec`] into a fresh codec session.
pub struct CodecRegistry {
    builders: BTreeMap<String, CodecBuilder>,
}

impl CodecRegistry {
    /// An empty registry (no built-ins).
    pub fn new() -> CodecRegistry {
        CodecRegistry { builders: BTreeMap::new() }
    }

    /// A registry pre-populated with every built-in scheme (all rows of the
    /// paper's Tables I-III).
    pub fn with_builtins() -> CodecRegistry {
        let mut reg = CodecRegistry::new();
        crate::compression::codecs::register_builtins(&mut reg);
        reg
    }

    /// Register (or replace) a builder under `name`.
    pub fn register<F>(&mut self, name: &str, build: F)
    where
        F: Fn(&CodecSpec) -> Result<Box<dyn Codec>> + Send + Sync + 'static,
    {
        self.builders.insert(name.to_string(), Box::new(build));
    }

    pub fn contains(&self, name: &str) -> bool {
        self.builders.contains_key(name)
    }

    /// All registered base names, sorted.
    pub fn names(&self) -> Vec<String> {
        self.builders.keys().cloned().collect()
    }

    /// Build a fresh codec session for `spec`.
    pub fn build(&self, spec: &CodecSpec) -> Result<Box<dyn Codec>> {
        let builder = self.builders.get(&spec.base).ok_or_else(|| {
            err!(
                "unknown codec {:?}; registered codecs: {}",
                spec.base,
                self.names().join(", ")
            )
        })?;
        builder(spec).with_context(|| format!("building codec spec {spec:?}"))
    }
}

impl Default for CodecRegistry {
    fn default() -> CodecRegistry {
        CodecRegistry::with_builtins()
    }
}

static GLOBAL_REGISTRY: OnceLock<RwLock<CodecRegistry>> = OnceLock::new();

fn global_registry() -> &'static RwLock<CodecRegistry> {
    GLOBAL_REGISTRY.get_or_init(|| RwLock::new(CodecRegistry::with_builtins()))
}

/// Register a codec into the process-global registry (out-of-core codecs
/// call this once at startup; no core file changes needed).
pub fn register_codec<F>(name: &str, build: F)
where
    F: Fn(&CodecSpec) -> Result<Box<dyn Codec>> + Send + Sync + 'static,
{
    global_registry().write().expect("codec registry poisoned").register(name, build);
}

/// All names in the process-global registry, sorted.
pub fn registered_names() -> Vec<String> {
    global_registry().read().expect("codec registry poisoned").names()
}

pub fn is_registered(name: &str) -> bool {
    global_registry().read().expect("codec registry poisoned").contains(name)
}

/// Build a fresh codec session from the process-global registry.
pub fn build_codec(spec: &CodecSpec) -> Result<Box<dyn Codec>> {
    global_registry().read().expect("codec registry poisoned").build(spec)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_grammar_parses() {
        let s = CodecSpec::parse_with_r("splitfc[ad,R=8,fwq]", 16.0).unwrap();
        assert_eq!(s.base, "splitfc");
        assert_eq!(s.args, vec!["ad", "R=8", "fwq"]);
        assert_eq!(s.get("R"), Some("8"));
        assert!(s.has("ad"));
        assert!(!s.has("rand"));
        assert_eq!(s.to_string(), "splitfc[ad,R=8,fwq]");

        let bare = CodecSpec::parse("tops").unwrap();
        assert_eq!(bare.base, "tops");
        assert!(bare.args.is_empty());
        assert_eq!(bare.to_string(), "tops");
    }

    #[test]
    fn spec_grammar_rejects_malformed() {
        assert!(CodecSpec::parse("").is_err());
        assert!(CodecSpec::parse("splitfc[ad").is_err());
        assert!(CodecSpec::parse("[ad]").is_err());
        assert!(CodecSpec::parse("bad name[x]").is_err());
    }

    #[test]
    fn codec_id_is_stable_and_discriminating() {
        assert_eq!(codec_id("vanilla"), codec_id("vanilla"));
        assert_ne!(codec_id("vanilla"), codec_id("splitfc[ad,R=8,fwq]"));
        assert_ne!(codec_id("splitfc[ad,R=8,fwq]"), codec_id("splitfc[ad,R=16,fwq]"));
    }

    #[test]
    fn registry_unknown_name_lists_choices() {
        let reg = CodecRegistry::with_builtins();
        let err = reg.build(&CodecSpec::parse("nope").unwrap()).unwrap_err();
        let msg = err.to_string();
        assert!(msg.contains("unknown codec"), "{msg}");
        assert!(msg.contains("splitfc"), "error should list registered names: {msg}");
        assert!(msg.contains("vanilla"), "{msg}");
    }

    #[test]
    fn builtin_registry_covers_all_table_rows() {
        let names = CodecRegistry::with_builtins().names();
        for want in [
            "vanilla",
            "splitfc",
            "splitfc-ad",
            "splitfc-rand",
            "splitfc-det",
            "splitfc-quant-only",
            "splitfc-no-mean",
            "splitfc-ad+pq",
            "splitfc-ad+eq",
            "splitfc-ad+nq",
            "tops",
            "randtops",
            "tops+pq",
            "tops+eq",
            "tops+nq",
            "fedlite",
        ] {
            assert!(names.iter().any(|n| n == want), "{want} missing from {names:?}");
        }
        assert_eq!(names.len(), 16);
    }
}
