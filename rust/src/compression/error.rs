//! Quantization/compression error identities used across the crate and by
//! the analysis-replication tests (eqs. 13, 19-21), plus the codec failure
//! type for malformed/truncated wire frames.

use std::fmt;

use crate::tensor::Matrix;

/// A decode-side failure of the bit-level codec layer.
///
/// `BitstreamOverread` is raised by `bitio::BitReader`'s checked reads when
/// a frame asks for more bits than the stream holds — previously the final
/// partial byte was silently zero-filled, which made truncated frames decode
/// to garbage instead of failing loudly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CodecError {
    BitstreamOverread {
        /// bits the caller asked for
        requested: u64,
        /// bits actually left in the stream
        available: u64,
    },
    /// A wire frame (or message) claims more bytes than the buffer holds —
    /// a truncated transmission must fail loudly, not zero-fill.
    TruncatedFrame {
        /// bytes the header/field required
        needed: u64,
        /// bytes actually available
        available: u64,
    },
    /// A length prefix exceeds the receiver's declared payload budget;
    /// rejecting it up front prevents malformed input from driving an
    /// attacker-controlled allocation.
    FrameTooLarge {
        /// bytes the length prefix asked for
        bytes: u64,
        /// the receiver's budget
        max: u64,
    },
    /// Structurally invalid header bytes (unknown tag, inconsistent
    /// length/bit fields, trailing garbage, ...).
    MalformedHeader { reason: String },
}

impl fmt::Display for CodecError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CodecError::BitstreamOverread { requested, available } => write!(
                f,
                "bitstream over-read: {requested} bits requested, {available} remaining"
            ),
            CodecError::TruncatedFrame { needed, available } => write!(
                f,
                "truncated frame: {needed} bytes required, {available} available"
            ),
            CodecError::FrameTooLarge { bytes, max } => write!(
                f,
                "frame too large: length prefix asks for {bytes} bytes, budget is {max}"
            ),
            CodecError::MalformedHeader { reason } => {
                write!(f, "malformed frame header: {reason}")
            }
        }
    }
}

impl std::error::Error for CodecError {}

impl From<CodecError> for crate::util::error::Error {
    fn from(e: CodecError) -> crate::util::error::Error {
        crate::util::error::Error::msg(e.to_string())
    }
}

/// Relative Frobenius error ||A - Â||_F / ||A||_F.
pub fn relative_error(a: &Matrix, a_hat: &Matrix) -> f64 {
    let n = a.sq_norm();
    if n == 0.0 {
        return a_hat.sq_norm().sqrt();
    }
    (a.sq_dist(a_hat) / n).sqrt()
}

/// Uniform-quantizer worst-case squared error per entry: (Δ/2)² with
/// Δ = range/(Q-1) — the bound behind eqs. (19)-(20) [44].
pub fn uniform_sq_err_bound(range: f64, q: u64) -> f64 {
    if q < 2 {
        return range * range;
    }
    let d = range / (q as f64 - 1.0);
    d * d / 4.0
}

/// eq. (21): ||a - ā·1||² ≤ (a_max - a_min)²·B/4 for any B-vector.
pub fn mean_residual_bound(range: f64, batch: usize) -> f64 {
    range * range * batch as f64 / 4.0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    #[test]
    fn codec_error_displays_counts() {
        let e = CodecError::BitstreamOverread { requested: 12, available: 3 };
        let s = e.to_string();
        assert!(s.contains("12") && s.contains('3'), "{s}");
    }

    #[test]
    fn wire_error_variants_display_and_convert() {
        let e = CodecError::TruncatedFrame { needed: 15, available: 7 };
        assert!(e.to_string().contains("15") && e.to_string().contains('7'));
        let e = CodecError::FrameTooLarge { bytes: 1 << 40, max: 1 << 20 };
        assert!(e.to_string().contains("too large"), "{e}");
        let e = CodecError::MalformedHeader { reason: "unknown tag 9".into() };
        assert!(e.to_string().contains("unknown tag 9"), "{e}");
        // converts into the crate error for `?` in decode paths
        let err: crate::util::error::Error = e.into();
        assert!(err.to_string().contains("malformed"), "{err}");
    }

    #[test]
    fn relative_error_zero_for_identical() {
        let a = Matrix::from_fn(3, 3, |r, c| (r + c) as f32);
        assert_eq!(relative_error(&a, &a.clone()), 0.0);
    }

    #[test]
    fn uniform_bound_holds_empirically() {
        let mut rng = Rng::new(0);
        for &q in &[2u64, 5, 16, 200] {
            let (lo, hi) = (-3.0f64, 5.0f64);
            let bound = uniform_sq_err_bound(hi - lo, q);
            for _ in 0..500 {
                let v = lo + rng.next_f64() * (hi - lo);
                let code = ((v - lo) / (hi - lo) * (q as f64 - 1.0)).round();
                let dq = lo + code * (hi - lo) / (q as f64 - 1.0);
                assert!((v - dq).powi(2) <= bound + 1e-12, "q={q}");
            }
        }
    }

    #[test]
    fn mean_residual_bound_eq21_holds() {
        let mut rng = Rng::new(1);
        for _ in 0..100 {
            let b = 2 + rng.gen_range(30);
            let col: Vec<f64> = (0..b).map(|_| rng.normal()).collect();
            let mean = col.iter().sum::<f64>() / b as f64;
            let resid: f64 = col.iter().map(|&v| (v - mean).powi(2)).sum();
            let mn = col.iter().cloned().fold(f64::INFINITY, f64::min);
            let mx = col.iter().cloned().fold(f64::NEG_INFINITY, f64::max);
            assert!(resid <= mean_residual_bound(mx - mn, b) + 1e-9);
        }
    }
}
