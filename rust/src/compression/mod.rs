//! The paper's contribution: adaptive feature-wise compression.
//!
//! * `dropout` — FWDP, Algorithm 2 (Sec. V)
//! * `quant` — FWQ, Algorithm 3 (Sec. VI) over real bit streams
//! * `waterfill` — problem (P) + Theorem 1 level allocation (Sec. VI-B/C)
//! * `error` — the error identities/bounds (eqs. 13, 19-21)
//! * `baselines` — Top-S [16], RandTop-S [17], FedLite [18], PQ/EQ/NQ [23-25]
//! * `pipeline` — framework-level uplink/downlink codecs for every row of
//!   Tables I-III and Figs. 3-5

pub mod analysis;
pub mod baselines;
pub mod dropout;
pub mod error;
pub mod feedback;
pub mod pipeline;
pub mod quant;
pub mod waterfill;

pub use baselines::ScalarKind;
pub use dropout::DropKind;
pub use error::CodecError;
pub use pipeline::{
    encode_downlink, encode_uplink, CodecParams, EncodedDownlink, EncodedUplink, FwqMode,
    GradMask, Scheme,
};
pub use quant::{fwq_decode, fwq_encode, FwqConfig};
