//! The paper's contribution: adaptive feature-wise compression, behind a
//! pluggable, sessionful codec API.
//!
//! * `codec` — the [`Codec`] trait, capability reports, spec grammar, and
//!   the string-keyed [`CodecRegistry`] (+ the process-global registry)
//! * `codecs` — one module per compressor family (vanilla, SplitFC, Top-S,
//!   FedLite) plus shared wire-format helpers (`codecs::common`)
//! * `dropout` — FWDP, Algorithm 2 (Sec. V)
//! * `quant` — FWQ, Algorithm 3 (Sec. VI) over real bit streams
//! * `waterfill` — problem (P) + Theorem 1 level allocation (Sec. VI-B/C)
//! * `error` — the error identities/bounds (eqs. 13, 19-21)
//! * `feedback` — the per-device error-feedback residual state that
//!   sessionful codecs (`splitfc[...,ef]`) carry across rounds
//! * `baselines` — Top-S [16], RandTop-S [17], FedLite [18], PQ/EQ/NQ [23-25]
//! * `pipeline` — DEPRECATED: the old closed `Scheme` enum + free-function
//!   pipeline, now a thin shim over the registry (one release, then gone)

pub mod analysis;
pub mod baselines;
pub mod codec;
pub mod codecs;
pub mod dropout;
pub mod error;
pub mod feedback;
pub mod pipeline;
pub mod quant;
pub mod scratch;
pub mod waterfill;

pub use baselines::ScalarKind;
pub use codec::{
    build_codec, codec_id, is_registered, register_codec, registered_names, Codec, CodecParams,
    CodecRegistry, CodecRequirements, CodecSpec, DecodedUplink, EncodedDownlink, EncodedUplink,
    GradMask, Reclaim, SigmaStats,
};
pub use codecs::fedlite::FedLiteCodec;
pub use codecs::splitfc::{FwqMode, SplitFcCodec};
pub use codecs::tops::TopSCodec;
pub use codecs::vanilla::VanillaCodec;
pub use dropout::DropKind;
pub use error::CodecError;
pub use feedback::ErrorFeedback;
pub use pipeline::{decode_uplink_splitfc, encode_downlink, encode_uplink, Scheme};
pub use quant::{
    fwq_decode, fwq_decode_into, fwq_encode, fwq_encode_view, fwq_encode_view_recon, ColView,
    FwqConfig, FwqScratch,
};
pub use scratch::WireScratch;
