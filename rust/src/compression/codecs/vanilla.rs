//! Lossless 32-bit transmission (the "Vanilla SL" row) as a [`Codec`].
//!
//! Even the lossless row is arena-backed: frame buffers and the F̂/Ĝ
//! copies come from the session's [`WireScratch`], so vanilla's steady
//! state is allocation-free too (it is the baseline every compressed row
//! is measured against in `bench_wire`).

use std::sync::Mutex;

use crate::bitio::{BitReader, BitWriter};
use crate::compression::codec::{
    codec_id, Codec, CodecParams, CodecRequirements, DecodedUplink, EncodedDownlink,
    EncodedUplink, GradMask, Reclaim, SigmaStats,
};
use crate::compression::codecs::common::{
    decode_downlink_styled_with, encode_downlink_styled_with, f32_dump, f32_undump_into,
};
use crate::compression::scratch::WireScratch;
use crate::ensure;
use crate::tensor::Matrix;
use crate::transport::wire::{Frame, FrameKind};
use crate::util::error::Result;
use crate::util::Rng;

const VANILLA_ID: u32 = codec_id("vanilla");

#[derive(Debug, Default)]
pub struct VanillaCodec {
    scratch: Mutex<WireScratch>,
}

impl Codec for VanillaCodec {
    fn name(&self) -> String {
        "vanilla".to_string()
    }

    fn requirements(&self) -> CodecRequirements {
        CodecRequirements::default()
    }

    fn wire_id(&self) -> u32 {
        VANILLA_ID
    }

    fn reclaim(&mut self, buffers: Reclaim) {
        self.scratch.get_mut().expect("codec scratch poisoned").reclaim(buffers);
    }

    fn encode_uplink(
        &mut self,
        f: &Matrix,
        _stats: Option<&SigmaStats>,
        params: &CodecParams,
        _rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        let (b, dbar) = (f.rows, f.cols);
        ensure!(b == params.batch, "batch {b} != params.batch {}", params.batch);
        ensure!(dbar == params.dbar, "dbar {dbar} != params.dbar {}", params.dbar);
        let ws = self.scratch.get_mut().expect("codec scratch poisoned");
        ws.note_bytes_bound(4 * b * dbar + 8);
        let mut w = BitWriter::from_buf(ws.take_bytes());
        f32_dump(f, &mut w);
        let bits = w.bit_len();
        let payload = w.into_bytes();
        let mut data = ws.take_f32();
        data.extend_from_slice(&f.data);
        Ok(EncodedUplink {
            frame: self.stamp(Frame::new(FrameKind::FeaturesUp, payload, bits)),
            f_hat: Matrix { rows: b, cols: dbar, data },
            mask: GradMask::All,
            nominal_bits: 32.0 * (b * dbar) as f64,
            m_star: None,
        })
    }

    fn decode_uplink(&self, frame: &Frame, params: &CodecParams) -> Result<DecodedUplink> {
        self.check_frame(frame)?;
        ensure!(frame.kind == FrameKind::FeaturesUp, "uplink decode on {:?} frame", frame.kind);
        let mut guard = self.scratch.lock().expect("codec scratch poisoned");
        let ws = &mut *guard;
        let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
        let mut f_hat = ws.take_matrix(params.batch, params.dbar);
        f32_undump_into(&mut rd, &mut f_hat);
        let mut kept = ws.take_usize();
        kept.extend(0..params.dbar);
        Ok(DecodedUplink { f_hat, kept })
    }

    fn encode_downlink(
        &mut self,
        g: &Matrix,
        mask: &GradMask,
        params: &CodecParams,
    ) -> Result<EncodedDownlink> {
        let style = self.downlink_style();
        let mut dn = {
            let ws = self.scratch.get_mut().expect("codec scratch poisoned");
            encode_downlink_styled_with(&style, g, mask, params, ws)
        };
        dn.frame = self.stamp(dn.frame);
        Ok(dn)
    }

    fn decode_downlink(
        &self,
        frame: &Frame,
        mask: &GradMask,
        params: &CodecParams,
    ) -> Result<Matrix> {
        self.check_frame(frame)?;
        let mut guard = self.scratch.lock().expect("codec scratch poisoned");
        decode_downlink_styled_with(&self.downlink_style(), frame, mask, params, &mut guard)
    }
}
