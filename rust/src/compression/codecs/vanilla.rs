//! Lossless 32-bit transmission (the "Vanilla SL" row) as a [`Codec`].

use crate::bitio::{BitReader, BitWriter};
use crate::compression::codec::{
    Codec, CodecParams, CodecRequirements, DecodedUplink, EncodedUplink, GradMask, SigmaStats,
};
use crate::compression::codecs::common::{f32_dump, f32_undump};
use crate::ensure;
use crate::tensor::Matrix;
use crate::transport::wire::{Frame, FrameKind};
use crate::util::error::Result;
use crate::util::Rng;

#[derive(Debug, Clone, Copy, Default)]
pub struct VanillaCodec;

impl Codec for VanillaCodec {
    fn name(&self) -> String {
        "vanilla".to_string()
    }

    fn requirements(&self) -> CodecRequirements {
        CodecRequirements::default()
    }

    fn encode_uplink(
        &mut self,
        f: &Matrix,
        _stats: Option<&SigmaStats>,
        params: &CodecParams,
        _rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        let (b, dbar) = (f.rows, f.cols);
        ensure!(b == params.batch, "batch {b} != params.batch {}", params.batch);
        ensure!(dbar == params.dbar, "dbar {dbar} != params.dbar {}", params.dbar);
        let mut w = BitWriter::with_capacity(4 * b * dbar);
        f32_dump(f, &mut w);
        let bits = w.bit_len();
        Ok(EncodedUplink {
            frame: self.stamp(Frame::new(FrameKind::FeaturesUp, w.into_bytes(), bits)),
            f_hat: f.clone(),
            mask: GradMask::All,
            nominal_bits: 32.0 * (b * dbar) as f64,
            m_star: None,
        })
    }

    fn decode_uplink(&self, frame: &Frame, params: &CodecParams) -> Result<DecodedUplink> {
        self.check_frame(frame)?;
        ensure!(frame.kind == FrameKind::FeaturesUp, "uplink decode on {:?} frame", frame.kind);
        let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
        let f_hat = f32_undump(&mut rd, params.batch, params.dbar);
        Ok(DecodedUplink { f_hat, kept: (0..params.dbar).collect() })
    }
}
