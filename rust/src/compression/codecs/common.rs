//! Shared wire-format building blocks for codec implementations.
//!
//! These are public on purpose: an out-of-core codec (registered via
//! [`crate::compression::register_codec`]) can reuse the raw-f32 dump, the
//! length-prefixed blob embedding, and the whole mask-coupled downlink
//! (eq. 8) instead of reimplementing them.

use crate::bitio::{BitReader, BitWriter};
use crate::compression::baselines::{qbar_levels, scalar_decode, scalar_encode, ScalarKind};
use crate::compression::codec::{CodecParams, EncodedDownlink, GradMask};
use crate::compression::quant::{fwq_decode, fwq_encode, FwqConfig};
use crate::ensure;
use crate::tensor::Matrix;
use crate::transport::wire::{Frame, FrameKind};
use crate::util::error::Result;

/// Dump every entry of `m` as raw f32 bits.
pub fn f32_dump(m: &Matrix, w: &mut BitWriter) {
    for &v in &m.data {
        w.write_f32(v);
    }
}

/// Inverse of [`f32_dump`] at a known shape.
pub fn f32_undump(r: &mut BitReader, rows: usize, cols: usize) -> Matrix {
    let mut out = Matrix::zeros(rows, cols);
    for i in 0..rows * cols {
        out.data[i] = r.read_f32();
    }
    out
}

/// Embed a sub-codec's byte payload in an outer bit stream
/// (40-bit length prefix + bytes).
pub fn write_blob(w: &mut BitWriter, bytes: &[u8], bits: u64) {
    w.write_bits(bits, 40);
    for &b in bytes {
        w.write_bits(b as u64, 8);
    }
}

/// Inverse of [`write_blob`]: returns (bytes, declared bit length).
pub fn read_blob(r: &mut BitReader) -> (Vec<u8>, u64) {
    let bits = r.read_bits(40);
    let nbytes = ((bits + 7) / 8) as usize;
    let bytes: Vec<u8> = (0..nbytes).map(|_| r.read_bits(8) as u8).collect();
    (bytes, bits)
}

/// How a codec quantizes the column-masked downlink when the budget is
/// below 32 bits/entry.
#[derive(Debug, Clone, Copy)]
pub enum ColumnQuant {
    /// the paper's FWQ over the kept gradient columns
    Fwq { use_mean: bool, q_fixed: Option<u64> },
    /// entry-wise scalar quantizer at the Q̄ = 2^{C·R/(B·D̄)} level rule
    Scalar { kind: ScalarKind, r: f64 },
}

/// The downlink policy of a codec: what to do under each [`GradMask`]
/// shape when the budget forces lossy transmission.
#[derive(Debug, Clone, Copy)]
pub struct DownlinkStyle {
    /// quantizer for `GradMask::Columns` (SplitFC-style column coupling)
    pub columns: ColumnQuant,
    /// scalar kind for `GradMask::Entries` (Top-S-style entry coupling)
    pub entries: ScalarKind,
}

impl Default for DownlinkStyle {
    fn default() -> DownlinkStyle {
        DownlinkStyle {
            columns: ColumnQuant::Fwq { use_mean: true, q_fixed: None },
            entries: ScalarKind::Eq,
        }
    }
}

/// Downlink: compress the intermediate gradient matrix G at the PS,
/// honouring the uplink coupling (eq. 8). `params.bits_per_entry` is C_e,s;
/// 32.0 means lossless (the Table-I setting). The returned frame is NOT yet
/// codec-stamped — the calling codec stamps it.
pub fn encode_downlink_styled(
    style: &DownlinkStyle,
    g: &Matrix,
    mask: &GradMask,
    params: &CodecParams,
) -> EncodedDownlink {
    let (b, dbar) = (g.rows, g.cols);
    let lossless = params.bits_per_entry >= 32.0;
    match mask {
        GradMask::All => {
            let mut w = BitWriter::with_capacity(4 * b * dbar);
            f32_dump(g, &mut w);
            let bits = w.bit_len();
            EncodedDownlink {
                frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits),
                g_hat: g.clone(),
                nominal_bits: 32.0 * (b * dbar) as f64,
            }
        }
        GradMask::Columns { kept, .. } => {
            let gt = g.gather_cols(kept);
            let mut w = BitWriter::new();
            let c_ava = params.total_budget();
            let (gt_hat, nominal) = if lossless {
                f32_dump(&gt, &mut w);
                (gt.clone(), 32.0 * gt.len() as f64)
            } else {
                match style.columns {
                    ColumnQuant::Scalar { kind, r } => {
                        let q = qbar_levels(c_ava, r.max(1.0), b, dbar);
                        let (bytes, bits) = scalar_encode(&gt, kind, q, params.noise_seed ^ 1);
                        write_blob(&mut w, &bytes, bits);
                        let out = scalar_decode(&bytes, kind, params.noise_seed ^ 1);
                        (out, gt.len() as f64 * (q as f64).log2() + 96.0)
                    }
                    ColumnQuant::Fwq { use_mean, q_fixed } => {
                        let mut cfg = FwqConfig::paper_default(b, c_ava);
                        cfg.q_ep = params.q_ep;
                        cfg.use_mean = use_mean;
                        cfg.q_fixed = q_fixed;
                        let (bytes, bits, info) = fwq_encode(&gt, &cfg);
                        write_blob(&mut w, &bytes, bits);
                        (fwq_decode(&bytes, &cfg), info.nominal_bits)
                    }
                }
            };
            let g_hat = gt_hat.scatter_cols(kept, dbar);
            let bits = w.bit_len();
            EncodedDownlink {
                frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits),
                g_hat,
                nominal_bits: nominal,
            }
        }
        GradMask::Entries(masks) => {
            // the device knows the masks it sent: only values travel back
            let mut w = BitWriter::new();
            let mut g_hat = Matrix::zeros(b, dbar);
            if lossless {
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        w.write_f32(g.at(r_i, c));
                        *g_hat.at_mut(r_i, c) = g.at(r_i, c);
                    }
                }
                let bits = w.bit_len();
                let n: usize = masks.iter().map(|m| m.len()).sum();
                EncodedDownlink {
                    frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits),
                    g_hat,
                    nominal_bits: 32.0 * n as f64,
                }
            } else {
                // gather masked values into a dense vector, scalar-quantize
                let vals: Vec<f32> = masks
                    .iter()
                    .enumerate()
                    .flat_map(|(r_i, kept)| kept.iter().map(move |&c| (r_i, c)))
                    .map(|(r_i, c)| g.at(r_i, c))
                    .collect();
                let q = qbar_levels(params.total_budget(), 16.0, b, dbar);
                let vm = Matrix::from_vec(1, vals.len(), vals);
                let (bytes, bits) = scalar_encode(&vm, style.entries, q, params.noise_seed ^ 2);
                write_blob(&mut w, &bytes, bits);
                let deq = scalar_decode(&bytes, style.entries, params.noise_seed ^ 2);
                let mut it = deq.data.iter();
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        *g_hat.at_mut(r_i, c) = *it.next().expect("mask/value count");
                    }
                }
                let bits_total = w.bit_len();
                EncodedDownlink {
                    frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits_total),
                    g_hat,
                    nominal_bits: deq.len() as f64 * (q as f64).log2(),
                }
            }
        }
    }
}

/// Device-side inverse of [`encode_downlink_styled`] from the frame bytes
/// alone (plus the mask the device itself sent uplink).
pub fn decode_downlink_styled(
    style: &DownlinkStyle,
    frame: &Frame,
    mask: &GradMask,
    params: &CodecParams,
) -> Result<Matrix> {
    ensure!(
        frame.kind == FrameKind::GradientsDown,
        "downlink decode on a {:?} frame",
        frame.kind
    );
    let (b, dbar) = (params.batch, params.dbar);
    let lossless = params.bits_per_entry >= 32.0;
    let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
    match mask {
        GradMask::All => Ok(f32_undump(&mut rd, b, dbar)),
        GradMask::Columns { kept, .. } => {
            let gt_hat = if lossless {
                f32_undump(&mut rd, b, kept.len())
            } else {
                let (bytes, _) = read_blob(&mut rd);
                match style.columns {
                    ColumnQuant::Scalar { kind, .. } => {
                        scalar_decode(&bytes, kind, params.noise_seed ^ 1)
                    }
                    ColumnQuant::Fwq { use_mean, q_fixed } => {
                        let mut cfg = FwqConfig::paper_default(b, params.total_budget());
                        cfg.q_ep = params.q_ep;
                        cfg.use_mean = use_mean;
                        cfg.q_fixed = q_fixed;
                        fwq_decode(&bytes, &cfg)
                    }
                }
            };
            Ok(gt_hat.scatter_cols(kept, dbar))
        }
        GradMask::Entries(masks) => {
            let mut g_hat = Matrix::zeros(b, dbar);
            if lossless {
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        *g_hat.at_mut(r_i, c) = rd.read_f32();
                    }
                }
            } else {
                let (bytes, _) = read_blob(&mut rd);
                let deq = scalar_decode(&bytes, style.entries, params.noise_seed ^ 2);
                let mut it = deq.data.iter();
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        *g_hat.at_mut(r_i, c) = *it
                            .next()
                            .ok_or_else(|| crate::err!("downlink frame short of mask entries"))?;
                    }
                }
            }
            Ok(g_hat)
        }
    }
}
