//! Shared wire-format building blocks for codec implementations.
//!
//! These are public on purpose: an out-of-core codec (registered via
//! [`crate::compression::register_codec`]) can reuse the raw-f32 dump, the
//! length-prefixed blob embedding, and the whole mask-coupled downlink
//! (eq. 8) instead of reimplementing them.
//!
//! The `*_with` variants thread a session-owned
//! [`crate::compression::WireScratch`] through the downlink so arena-backed
//! codecs run it allocation-free; the plain variants keep the old
//! signatures and spin up a throwaway arena.

use crate::bitio::{BitReader, BitWriter};
use crate::compression::baselines::{
    qbar_levels, scalar_decode, scalar_decode_into, scalar_encode, scalar_encode_into, ScalarKind,
};
use crate::compression::codec::{CodecParams, EncodedDownlink, GradMask};
use crate::compression::quant::{fwq_decode_into, fwq_encode_view_recon, ColView, FwqConfig};
use crate::compression::scratch::WireScratch;
use crate::ensure;
use crate::tensor::Matrix;
use crate::transport::wire::{Frame, FrameKind};
use crate::util::error::Result;

/// Dump every entry of `m` as raw f32 bits.
pub fn f32_dump(m: &Matrix, w: &mut BitWriter) {
    for &v in &m.data {
        w.write_f32(v);
    }
}

/// Inverse of [`f32_dump`] at a known shape.
pub fn f32_undump(r: &mut BitReader, rows: usize, cols: usize) -> Matrix {
    let mut out = Matrix::zeros(rows, cols);
    f32_undump_into(r, &mut out);
    out
}

/// [`f32_undump`] into a caller-owned matrix (shape taken from `out`).
pub fn f32_undump_into(r: &mut BitReader, out: &mut Matrix) {
    for v in out.data.iter_mut() {
        *v = r.read_f32();
    }
}

/// Embed a sub-codec's byte payload in an outer bit stream
/// (40-bit length prefix + bytes; bulk-copied when byte-aligned).
pub fn write_blob(w: &mut BitWriter, bytes: &[u8], bits: u64) {
    w.write_bits(bits, 40);
    w.write_bytes(bytes);
}

/// An open blob slot in an outer bit stream — see [`begin_blob`].
#[derive(Debug, Clone, Copy)]
pub struct BlobSlot {
    /// absolute bit offset of the 40-bit length field
    len_at: u64,
}

/// Open a length-prefixed blob **in place**: reserves the 40-bit length
/// field as zeros and lets the sub-codec stream its frame straight into `w`.
/// Close with [`end_blob`], which zero-pads the body to a byte boundary and
/// patches the true bit length into the reserved field — producing the exact
/// bytes of encode-to-buffer + [`write_blob`], without the staging buffer or
/// the memcpy.
pub fn begin_blob(w: &mut BitWriter) -> BlobSlot {
    let len_at = w.bit_len();
    w.write_bits(0, 40);
    BlobSlot { len_at }
}

/// Close a blob opened by [`begin_blob`] (see there for the layout claim).
pub fn end_blob(w: &mut BitWriter, slot: BlobSlot) {
    let bits = w.bit_len() - slot.len_at - 40;
    let pad = (8 - (bits % 8) as u32) % 8;
    w.write_bits(0, pad);
    w.patch_bits(slot.len_at, bits, 40);
}

/// Inverse of [`write_blob`]: returns (bytes, declared bit length).
pub fn read_blob(r: &mut BitReader) -> (Vec<u8>, u64) {
    let mut out = Vec::new();
    let bits = read_blob_into(r, &mut out);
    (out, bits)
}

/// [`read_blob`] into a reusable buffer (cleared first); a byte-aligned
/// reader position turns the body into one bulk slice copy instead of the
/// old per-byte `read_bits(8)` loop.
pub fn read_blob_into(r: &mut BitReader, out: &mut Vec<u8>) -> u64 {
    let bits = r.read_bits(40);
    let nbytes = ((bits + 7) / 8) as usize;
    out.clear();
    r.try_read_bytes_into(nbytes, out)
        .unwrap_or_else(|e| panic!("BitReader: {e}"));
    bits
}

/// How a codec quantizes the column-masked downlink when the budget is
/// below 32 bits/entry.
#[derive(Debug, Clone, Copy)]
pub enum ColumnQuant {
    /// the paper's FWQ over the kept gradient columns
    Fwq { use_mean: bool, q_fixed: Option<u64> },
    /// entry-wise scalar quantizer at the Q̄ = 2^{C·R/(B·D̄)} level rule
    Scalar { kind: ScalarKind, r: f64 },
}

/// The downlink policy of a codec: what to do under each [`GradMask`]
/// shape when the budget forces lossy transmission.
#[derive(Debug, Clone, Copy)]
pub struct DownlinkStyle {
    /// quantizer for `GradMask::Columns` (SplitFC-style column coupling)
    pub columns: ColumnQuant,
    /// scalar kind for `GradMask::Entries` (Top-S-style entry coupling)
    pub entries: ScalarKind,
}

impl Default for DownlinkStyle {
    fn default() -> DownlinkStyle {
        DownlinkStyle {
            columns: ColumnQuant::Fwq { use_mean: true, q_fixed: None },
            entries: ScalarKind::Eq,
        }
    }
}

/// The shared FWQ config for the column-masked downlink.
fn downlink_fwq_cfg(
    use_mean: bool,
    q_fixed: Option<u64>,
    b: usize,
    c_ava: f64,
    params: &CodecParams,
) -> FwqConfig {
    let mut cfg = FwqConfig::paper_default(b, c_ava);
    cfg.q_ep = params.q_ep;
    cfg.use_mean = use_mean;
    cfg.q_fixed = q_fixed;
    cfg
}

/// Downlink: compress the intermediate gradient matrix G at the PS,
/// honouring the uplink coupling (eq. 8). `params.bits_per_entry` is C_e,s;
/// 32.0 means lossless (the Table-I setting). The returned frame is NOT yet
/// codec-stamped — the calling codec stamps it.
pub fn encode_downlink_styled(
    style: &DownlinkStyle,
    g: &Matrix,
    mask: &GradMask,
    params: &CodecParams,
) -> EncodedDownlink {
    encode_downlink_styled_with(style, g, mask, params, &mut WireScratch::new())
}

/// [`encode_downlink_styled`] running against a session-owned scratch
/// arena: frame buffers, FWQ staging and the `g_hat` reconstruction all
/// come from (and return to) `ws`.
pub fn encode_downlink_styled_with(
    style: &DownlinkStyle,
    g: &Matrix,
    mask: &GradMask,
    params: &CodecParams,
    ws: &mut WireScratch,
) -> EncodedDownlink {
    let (b, dbar) = (g.rows, g.cols);
    let lossless = params.bits_per_entry >= 32.0;
    match mask {
        GradMask::All => {
            ws.note_bytes_bound(4 * b * dbar + 8);
            let mut w = BitWriter::from_buf(ws.take_bytes());
            f32_dump(g, &mut w);
            let bits = w.bit_len();
            // pooled copy instead of the old `g.clone()` staging
            let mut data = ws.take_f32();
            data.extend_from_slice(&g.data);
            EncodedDownlink {
                frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits),
                g_hat: Matrix { rows: b, cols: dbar, data },
                nominal_bits: 32.0 * (b * dbar) as f64,
            }
        }
        GradMask::Columns { kept, .. } => {
            let c_ava = params.total_budget();
            // lossless dumps up to 4·B·D̄ bytes; lossy stays within ~C_ava/8
            let cap = if lossless {
                4 * b * dbar + 64
            } else {
                (c_ava / 4.0) as usize + 64
            };
            ws.note_bytes_bound(cap);
            let mut w = BitWriter::from_buf(ws.take_bytes());
            let (g_hat, nominal) = if lossless {
                // fused dump of the kept columns (no gathered staging), and
                // the reconstruction scattered in the same pass
                let mut g_hat = ws.take_matrix(b, dbar);
                for r in 0..b {
                    let src = g.row(r);
                    let dst = &mut g_hat.data[r * dbar..(r + 1) * dbar];
                    for &c in kept.iter() {
                        w.write_f32(src[c]);
                        dst[c] = src[c];
                    }
                }
                (g_hat, 32.0 * (b * kept.len()) as f64)
            } else {
                match style.columns {
                    ColumnQuant::Scalar { kind, r } => {
                        // gather into pooled staging, stream the frame into
                        // the open blob slot, and reconstruct inline — no
                        // intermediate byte buffer, no self-decode pass
                        let q = qbar_levels(c_ava, r.max(1.0), b, dbar);
                        crate::util::reserve_total(&mut ws.stage.data, b * dbar);
                        crate::util::reserve_total(&mut ws.scalar_syms, b * dbar);
                        let mut g_hat = ws.take_matrix(b, dbar);
                        let slot = begin_blob(&mut w);
                        let nominal = {
                            let WireScratch { stage, scalar_syms, .. } = &mut *ws;
                            g.gather_cols_into(kept, stage);
                            scalar_encode_into(
                                stage,
                                kind,
                                q,
                                params.noise_seed ^ 1,
                                &mut w,
                                scalar_syms,
                                Some((&mut g_hat, kept.as_slice())),
                            );
                            stage.len() as f64 * (q as f64).log2() + 96.0
                        };
                        end_blob(&mut w, slot);
                        (g_hat, nominal)
                    }
                    ColumnQuant::Fwq { use_mean, q_fixed } => {
                        let cfg = downlink_fwq_cfg(use_mean, q_fixed, b, c_ava, params);
                        let mut g_hat = ws.take_matrix(b, dbar);
                        let slot = begin_blob(&mut w);
                        let info = fwq_encode_view_recon(
                            &ColView::unscaled(g, kept),
                            &cfg,
                            &mut w,
                            &mut ws.fwq,
                            &mut g_hat,
                        );
                        end_blob(&mut w, slot);
                        (g_hat, info.nominal_bits)
                    }
                }
            };
            let bits = w.bit_len();
            EncodedDownlink {
                frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits),
                g_hat,
                nominal_bits: nominal,
            }
        }
        GradMask::Entries(masks) => {
            // the device knows the masks it sent: only values travel back
            let mut w = BitWriter::from_buf(ws.take_bytes());
            let mut g_hat = ws.take_matrix(b, dbar);
            if lossless {
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        w.write_f32(g.at(r_i, c));
                        *g_hat.at_mut(r_i, c) = g.at(r_i, c);
                    }
                }
                let bits = w.bit_len();
                let n: usize = masks.iter().map(|m| m.len()).sum();
                EncodedDownlink {
                    frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits),
                    g_hat,
                    nominal_bits: 32.0 * n as f64,
                }
            } else {
                // gather masked values into a dense vector, scalar-quantize
                let vals: Vec<f32> = masks
                    .iter()
                    .enumerate()
                    .flat_map(|(r_i, kept)| kept.iter().map(move |&c| (r_i, c)))
                    .map(|(r_i, c)| g.at(r_i, c))
                    .collect();
                let q = qbar_levels(params.total_budget(), 16.0, b, dbar);
                let vm = Matrix::from_vec(1, vals.len(), vals);
                let (bytes, bits) = scalar_encode(&vm, style.entries, q, params.noise_seed ^ 2);
                write_blob(&mut w, &bytes, bits);
                let deq = scalar_decode(&bytes, style.entries, params.noise_seed ^ 2);
                let mut it = deq.data.iter();
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        *g_hat.at_mut(r_i, c) = *it.next().expect("mask/value count");
                    }
                }
                let bits_total = w.bit_len();
                EncodedDownlink {
                    frame: Frame::new(FrameKind::GradientsDown, w.into_bytes(), bits_total),
                    g_hat,
                    nominal_bits: deq.len() as f64 * (q as f64).log2(),
                }
            }
        }
    }
}

/// Device-side inverse of [`encode_downlink_styled`] from the frame bytes
/// alone (plus the mask the device itself sent uplink).
pub fn decode_downlink_styled(
    style: &DownlinkStyle,
    frame: &Frame,
    mask: &GradMask,
    params: &CodecParams,
) -> Result<Matrix> {
    decode_downlink_styled_with(style, frame, mask, params, &mut WireScratch::new())
}

/// [`decode_downlink_styled`] against a session-owned scratch arena.
pub fn decode_downlink_styled_with(
    style: &DownlinkStyle,
    frame: &Frame,
    mask: &GradMask,
    params: &CodecParams,
    ws: &mut WireScratch,
) -> Result<Matrix> {
    ensure!(
        frame.kind == FrameKind::GradientsDown,
        "downlink decode on a {:?} frame",
        frame.kind
    );
    let (b, dbar) = (params.batch, params.dbar);
    let lossless = params.bits_per_entry >= 32.0;
    let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
    match mask {
        GradMask::All => {
            let mut out = ws.take_matrix(b, dbar);
            f32_undump_into(&mut rd, &mut out);
            Ok(out)
        }
        GradMask::Columns { kept, .. } => {
            if lossless {
                // read straight into the scattered positions (same read
                // order as undump-then-scatter)
                let mut g_hat = ws.take_matrix(b, dbar);
                for r in 0..b {
                    let dst = &mut g_hat.data[r * dbar..(r + 1) * dbar];
                    for &c in kept.iter() {
                        dst[c] = rd.read_f32();
                    }
                }
                return Ok(g_hat);
            }
            crate::util::reserve_total(&mut ws.blob, (params.total_budget() / 4.0) as usize + 64);
            read_blob_into(&mut rd, &mut ws.blob);
            match style.columns {
                ColumnQuant::Scalar { kind, .. } => {
                    crate::util::reserve_total(&mut ws.stage.data, b * dbar);
                    crate::util::reserve_total(&mut ws.scalar_syms, b * dbar);
                    {
                        let WireScratch { blob, stage, scalar_syms, .. } = &mut *ws;
                        scalar_decode_into(blob, kind, params.noise_seed ^ 1, scalar_syms, stage);
                    }
                    let mut g_hat = ws.take_matrix(b, dbar);
                    ws.stage.scatter_cols_into(kept, &mut g_hat);
                    Ok(g_hat)
                }
                ColumnQuant::Fwq { use_mean, q_fixed } => {
                    let cfg =
                        downlink_fwq_cfg(use_mean, q_fixed, b, params.total_budget(), params);
                    ws.fwq.reserve(b, dbar);
                    crate::util::reserve_total(&mut ws.stage.data, b * dbar);
                    {
                        let WireScratch { blob, fwq, stage, .. } = &mut *ws;
                        fwq_decode_into(blob, &cfg, fwq, stage);
                    }
                    let mut g_hat = ws.take_matrix(b, dbar);
                    ws.stage.scatter_cols_into(kept, &mut g_hat);
                    Ok(g_hat)
                }
            }
        }
        GradMask::Entries(masks) => {
            let mut g_hat = ws.take_matrix(b, dbar);
            if lossless {
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        *g_hat.at_mut(r_i, c) = rd.read_f32();
                    }
                }
            } else {
                read_blob_into(&mut rd, &mut ws.blob);
                let deq = scalar_decode(&ws.blob, style.entries, params.noise_seed ^ 2);
                let mut it = deq.data.iter();
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        *g_hat.at_mut(r_i, c) = *it
                            .next()
                            .ok_or_else(|| crate::err!("downlink frame short of mask entries"))?;
                    }
                }
            }
            Ok(g_hat)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn blob_slot_matches_write_blob() {
        // begin/end_blob must reproduce encode-to-buffer + write_blob bytes
        // exactly, at aligned and unaligned outer positions, including the
        // empty blob
        for inner_len in [0usize, 1, 7, 13, 40, 129] {
            for pre in [0u32, 3, 32] {
                let mut wi = BitWriter::new();
                for i in 0..inner_len {
                    wi.write_bits((i % 2) as u64, 1);
                    wi.write_bits((i * 37 % 251) as u64, 11);
                }
                let bits = wi.bit_len();
                let bytes = wi.into_bytes();
                let mut w_ref = BitWriter::new();
                w_ref.write_bits(0x5, pre.min(3));
                if pre == 32 {
                    w_ref.write_bits(0xABCD_1234 >> 3, 29);
                }
                write_blob(&mut w_ref, &bytes, bits);
                w_ref.write_bits(0x2A, 6);

                let mut w = BitWriter::new();
                w.write_bits(0x5, pre.min(3));
                if pre == 32 {
                    w.write_bits(0xABCD_1234 >> 3, 29);
                }
                let slot = begin_blob(&mut w);
                for i in 0..inner_len {
                    w.write_bits((i % 2) as u64, 1);
                    w.write_bits((i * 37 % 251) as u64, 11);
                }
                end_blob(&mut w, slot);
                w.write_bits(0x2A, 6);
                assert_eq!(w.bit_len(), w_ref.bit_len(), "len={inner_len} pre={pre}");
                assert_eq!(w.into_bytes(), w_ref.into_bytes(), "len={inner_len} pre={pre}");
            }
        }
    }
}
