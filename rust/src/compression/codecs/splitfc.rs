//! The paper's own framework as a [`Codec`]: adaptive feature-wise dropout
//! (FWDP, Alg. 2) + feature-wise quantization (FWQ, Alg. 3), covering every
//! SplitFC row of Tables I-III and Figs. 3-5, with an optional sessionful
//! error-feedback extension (`splitfc[...,ef]`).
//!
//! The FWQ/fp32 uplink runs **fused**: the dropout plan, the per-column
//! statistics and the quantized symbols are all computed straight off the
//! feature matrix through a [`ColView`] (kept columns + 1/(1-p) rescale on
//! the fly) and emitted directly into the frame writer — no gathered
//! intermediate matrix, no per-column staging vectors. Every reusable
//! buffer lives in the session's [`WireScratch`] arena, so steady-state
//! encode/decode rounds perform zero heap allocations (the `alloc-count`
//! harness locks this). The emitted bitstream is byte-identical to the
//! pre-fusion gather-then-encode pipeline.

use std::sync::Mutex;

use crate::bitio::{BitReader, BitWriter};
use crate::compression::baselines::{
    qbar_levels, scalar_decode_into, scalar_encode_into, ScalarKind,
};
use crate::compression::codec::{
    codec_id, Codec, CodecParams, CodecRequirements, DecodedUplink, EncodedDownlink,
    EncodedUplink, GradMask, Reclaim, SigmaStats,
};
use crate::compression::codecs::common::{
    begin_blob, decode_downlink_styled_with, encode_downlink_styled_with, end_blob,
    read_blob_into, ColumnQuant, DownlinkStyle,
};
use crate::compression::dropout::{self, DropKind};
use crate::compression::feedback::ErrorFeedback;
use crate::compression::quant::{fwq_decode_into, fwq_encode_view_recon, ColView, FwqConfig};
use crate::compression::scratch::WireScratch;
use crate::ensure;
use crate::tensor::{column_stats, normalized_sigma, Matrix};
use crate::transport::wire::{ByteCursor, Frame, FrameKind};
use crate::util::error::Result;
use crate::util::Rng;

/// How the (post-dropout) matrix entries are represented on the wire.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FwqMode {
    /// raw f32 entries (SplitFC-AD, Fig. 3)
    NoQuant,
    /// the paper's FWQ with optimal level allocation; `use_mean = false` is
    /// ablation Case 3 (two-stage only)
    Optimal { use_mean: bool },
    /// Fig. 5: fixed levels, no optimization
    Fixed { q: u64 },
    /// SplitFC-AD + {PQ, EQ, NQ} rows of Tables I/II
    Scalar(ScalarKind),
}

/// SplitFC as a codec session. `drop = None` is the quantization-only
/// ablation (Table III Case 2); `with_error_feedback` arms the per-device
/// residual memory (SplitFC-EF).
#[derive(Debug)]
pub struct SplitFcCodec {
    pub drop: Option<DropKind>,
    /// dimensionality-reduction ratio R = D̄/D (ignored if drop = None)
    pub r: f64,
    pub quant: FwqMode,
    ef_decay: Option<f32>,
    ef: Option<ErrorFeedback>,
    /// session scratch arena (Mutex so the `&self` decode paths share it;
    /// one session serves one link, so the lock is never contended)
    scratch: Mutex<WireScratch>,
    /// cached (configuration, codec id) pair: stamping a frame must not
    /// re-format the canonical name, but the pub config fields are
    /// mutable, so the cache is keyed on a config snapshot and refreshes
    /// whenever the configuration changed since the last stamp
    id: Mutex<Option<(IdKey, u32)>>,
}

/// Everything `SplitFcCodec::name()` depends on, as a comparable snapshot.
type IdKey = (Option<DropKind>, u64, FwqMode, bool);

impl SplitFcCodec {
    pub fn new(drop: Option<DropKind>, r: f64, quant: FwqMode) -> SplitFcCodec {
        SplitFcCodec {
            drop,
            r,
            quant,
            ef_decay: None,
            ef: None,
            scratch: Mutex::new(WireScratch::new()),
            id: Mutex::new(None),
        }
    }

    /// The paper's full framework at ratio R (AD dropout + optimal FWQ).
    pub fn paper_default(r: f64) -> SplitFcCodec {
        SplitFcCodec::new(Some(DropKind::Adaptive), r, FwqMode::Optimal { use_mean: true })
    }

    /// Arm the error-feedback session state: the residual F - F̂ of what the
    /// codec destroyed is carried to the next round's encode (decay 1.0 =
    /// classic EF; < 1 damps staleness).
    pub fn with_error_feedback(mut self, decay: f32) -> SplitFcCodec {
        self.ef_decay = Some(decay);
        self
    }

    /// Current error-feedback residual norm (None until the first EF encode).
    pub fn ef_residual_norm(&self) -> Option<f64> {
        self.ef.as_ref().map(|e| e.residual_norm())
    }

    fn cached_id(&self) -> u32 {
        let key: IdKey = (self.drop, self.r.to_bits(), self.quant, self.ef_decay.is_some());
        let mut cache = self.id.lock().expect("codec id cache poisoned");
        match &*cache {
            Some((k, id)) if *k == key => *id,
            _ => {
                let id = codec_id(&self.name());
                *cache = Some((key, id));
                id
            }
        }
    }

    /// The shared FWQ config for the uplink, per quant mode.
    fn fwq_cfg(&self, b: usize, c_ava: f64, params: &CodecParams) -> FwqConfig {
        let mut cfg = FwqConfig::paper_default(b, c_ava);
        cfg.q_ep = params.q_ep;
        match self.quant {
            FwqMode::Optimal { use_mean } => cfg.use_mean = use_mean,
            FwqMode::Fixed { q } => cfg.q_fixed = Some(q),
            FwqMode::NoQuant | FwqMode::Scalar(_) => {}
        }
        cfg
    }

    /// One memoryless encode round — the fused wire path. Bitstream is
    /// byte-identical to the legacy gather → encode → blob pipeline (locked
    /// by the codec golden tests and the quant-level fusion oracles).
    fn encode_core(
        &mut self,
        f: &Matrix,
        sigma_norm: Option<&[f32]>,
        params: &CodecParams,
        rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        let (b, dbar) = (f.rows, f.cols);
        ensure!(b == params.batch, "batch {b} != params.batch {}", params.batch);
        ensure!(dbar == params.dbar, "dbar {dbar} != params.dbar {}", params.dbar);
        let (drop, r, quant) = (self.drop, self.r, self.quant);
        let cfg = match quant {
            FwqMode::Optimal { .. } | FwqMode::Fixed { .. } => {
                let delta_bits = if drop.is_some() { dbar as f64 } else { 0.0 };
                Some(self.fwq_cfg(b, params.total_budget() - delta_bits, params))
            }
            _ => None,
        };

        let ws = self.scratch.get_mut().expect("codec scratch poisoned");
        // σ fallback for variants that never read the values (Random / no
        // dropout): an arena-backed zero vector, not a per-step allocation
        let sigma_norm: &[f32] = match sigma_norm {
            Some(s) => s,
            None => {
                ws.sigma_zeros.clear();
                ws.sigma_zeros.resize(dbar, 0.0);
                &ws.sigma_zeros
            }
        };
        match drop {
            Some(kind) => dropout::plan_into(kind, sigma_norm, r, rng, &mut ws.plan),
            None => dropout::keep_all_into(dbar, &mut ws.plan),
        }
        // worst-case frame bound (NOT this round's need): kept sets
        // fluctuate, and a post-warm-up high-water mark must not realloc
        let cap_bytes = match quant {
            FwqMode::NoQuant => 4 * b * dbar + dbar / 4 + 64,
            _ => (params.total_budget() / 4.0) as usize + dbar / 4 + 64,
        };
        ws.note_bytes_bound(cap_bytes);
        ws.note_usize_bound(dbar);
        let mut w = BitWriter::from_buf(ws.take_bytes());
        // δ index vector (D̄ bits) — only when dropout is active
        let delta_bits = if drop.is_some() { dbar as f64 } else { 0.0 };
        if drop.is_some() {
            for &d in &ws.plan.delta {
                w.write_bits(d as u64, 1);
            }
        }
        let c_ava = params.total_budget() - delta_bits;
        let (f_hat, nominal, m_star) = match quant {
            FwqMode::NoQuant => {
                // fused dump: gather + 1/(1-p) rescale + f32 serialization
                // + reconstruction scatter in one row-major pass
                let mut f_hat = ws.take_matrix(b, dbar);
                for r_i in 0..b {
                    let src = f.row(r_i);
                    let dst = &mut f_hat.data[r_i * dbar..(r_i + 1) * dbar];
                    for (&c, &s) in ws.plan.kept.iter().zip(&ws.plan.scale) {
                        let v = src[c] * s;
                        w.write_f32(v);
                        dst[c] = v;
                    }
                }
                let n = b * ws.plan.kept.len();
                (f_hat, delta_bits + 32.0 * n as f64, None)
            }
            FwqMode::Optimal { .. } | FwqMode::Fixed { .. } => {
                // stream the FWQ frame straight into the open blob slot and
                // reconstruct F̂ inline — no inner byte buffer, no
                // decode-own-frame pass, no scatter memcpy
                let cfg = cfg.expect("fwq config built above");
                let mut f_hat = ws.take_matrix(b, dbar);
                let slot = begin_blob(&mut w);
                let info = {
                    let WireScratch { plan, fwq, .. } = &mut *ws;
                    fwq_encode_view_recon(
                        &ColView::scaled(f, &plan.kept, &plan.scale),
                        &cfg,
                        &mut w,
                        fwq,
                        &mut f_hat,
                    )
                };
                end_blob(&mut w, slot);
                (f_hat, delta_bits + info.nominal_bits, Some(info.m_star))
            }
            FwqMode::Scalar(kind) => {
                let q = qbar_levels(c_ava, r.max(1.0), b, dbar);
                crate::util::reserve_total(&mut ws.stage.data, b * dbar);
                crate::util::reserve_total(&mut ws.scalar_syms, b * dbar);
                let mut f_hat = ws.take_matrix(b, dbar);
                let slot = begin_blob(&mut w);
                let nominal = {
                    let WireScratch { plan, stage, scalar_syms, .. } = &mut *ws;
                    f.gather_cols_scaled_into(&plan.kept, &plan.scale, stage);
                    scalar_encode_into(
                        stage,
                        kind,
                        q,
                        params.noise_seed,
                        &mut w,
                        scalar_syms,
                        Some((&mut f_hat, plan.kept.as_slice())),
                    );
                    delta_bits + stage.len() as f64 * (q as f64).log2() + 96.0
                };
                end_blob(&mut w, slot);
                (f_hat, nominal, None)
            }
        };
        let bits = w.bit_len();
        let payload = w.into_bytes();
        let mut kept = ws.take_usize();
        kept.extend_from_slice(&ws.plan.kept);
        let mut scale = ws.take_f32();
        scale.extend_from_slice(&ws.plan.scale);
        Ok(EncodedUplink {
            frame: self.stamp(Frame::new(FrameKind::FeaturesUp, payload, bits)),
            f_hat,
            mask: GradMask::Columns { kept, scale },
            nominal_bits: nominal,
            m_star,
        })
    }
}

impl Codec for SplitFcCodec {
    fn name(&self) -> String {
        let d = match self.drop {
            None => "none",
            Some(DropKind::Adaptive) => "ad",
            Some(DropKind::Random) => "rand",
            Some(DropKind::Deterministic) => "det",
        };
        let q = match self.quant {
            FwqMode::NoQuant => "fp32".to_string(),
            FwqMode::Optimal { use_mean: true } => "fwq".to_string(),
            FwqMode::Optimal { use_mean: false } => "fwq-2stage".to_string(),
            FwqMode::Fixed { q } => format!("fixedQ{q}"),
            FwqMode::Scalar(k) => k.name().to_lowercase(),
        };
        let ef = if self.ef_decay.is_some() { ",ef" } else { "" };
        format!("splitfc[{d},R={},{q}{ef}]", self.r)
    }

    fn requirements(&self) -> CodecRequirements {
        CodecRequirements {
            needs_sigma: matches!(
                self.drop,
                Some(DropKind::Adaptive) | Some(DropKind::Deterministic)
            ),
            stateful: self.ef_decay.is_some(),
        }
    }

    fn downlink_style(&self) -> DownlinkStyle {
        let columns = match self.quant {
            FwqMode::Scalar(kind) => ColumnQuant::Scalar { kind, r: self.r },
            FwqMode::Fixed { q } => ColumnQuant::Fwq { use_mean: true, q_fixed: Some(q) },
            FwqMode::Optimal { use_mean } => ColumnQuant::Fwq { use_mean, q_fixed: None },
            FwqMode::NoQuant => ColumnQuant::Fwq { use_mean: true, q_fixed: None },
        };
        DownlinkStyle { columns, entries: ScalarKind::Eq }
    }

    fn wire_id(&self) -> u32 {
        self.cached_id()
    }

    fn reclaim(&mut self, buffers: Reclaim) {
        self.scratch.get_mut().expect("codec scratch poisoned").reclaim(buffers);
    }

    /// Session state for checkpointing: the error-feedback residual. As the
    /// mask-encoded-sparsification line of work (arXiv:2408.13787) stresses,
    /// the residual is *training state* — dropping it on restart biases the
    /// very next gradient — so `splitfc[...,ef]` serializes it. Non-EF
    /// configurations export empty (stateless).
    fn export_session(&self) -> Vec<u8> {
        let Some(decay) = self.ef_decay else { return Vec::new() };
        let mut out = Vec::new();
        match &self.ef {
            None => out.push(0u8), // armed but no encode yet
            Some(ef) => {
                out.push(1u8);
                out.extend_from_slice(&(ef.residual.rows as u64).to_le_bytes());
                out.extend_from_slice(&(ef.residual.cols as u64).to_le_bytes());
                out.extend_from_slice(&decay.to_bits().to_le_bytes());
                out.reserve(ef.residual.data.len() * 4);
                for &v in &ef.residual.data {
                    out.extend_from_slice(&v.to_bits().to_le_bytes());
                }
            }
        }
        out
    }

    fn restore_session(&mut self, bytes: &[u8]) -> Result<()> {
        if bytes.is_empty() {
            ensure!(
                self.ef_decay.is_none(),
                "codec {:?} carries an error-feedback residual but the \
                 checkpoint session state is empty",
                self.name()
            );
            return Ok(());
        }
        ensure!(
            self.ef_decay.is_some(),
            "checkpoint carries error-feedback session state but codec {:?} \
             has no EF armed",
            self.name()
        );
        let mut cur = ByteCursor::new(bytes);
        let ctx = |e: crate::compression::error::CodecError| {
            crate::err!("splitfc session state: {e}")
        };
        match cur.u8().map_err(ctx)? {
            0 => {
                ensure!(cur.is_empty(), "splitfc session state: trailing bytes");
                self.ef = None;
            }
            1 => {
                let rows = cur.u64().map_err(ctx)? as usize;
                let cols = cur.u64().map_err(ctx)? as usize;
                let decay = cur.f32().map_err(ctx)?;
                let n = rows
                    .checked_mul(cols)
                    .filter(|&n| n * 4 == cur.remaining())
                    .ok_or_else(|| {
                        crate::err!(
                            "splitfc session state: residual shape {rows}x{cols} \
                             does not match {} payload bytes",
                            cur.remaining()
                        )
                    })?;
                let mut ef = ErrorFeedback::new(rows, cols);
                ef.decay = decay;
                let raw = cur.take(n * 4).map_err(ctx)?;
                for (dst, b) in ef.residual.data.iter_mut().zip(raw.chunks_exact(4)) {
                    *dst = f32::from_le_bytes([b[0], b[1], b[2], b[3]]);
                }
                self.ef = Some(ef);
            }
            other => crate::bail!("splitfc session state: bad flag {other}"),
        }
        Ok(())
    }

    fn encode_uplink(
        &mut self,
        f: &Matrix,
        stats: Option<&SigmaStats>,
        params: &CodecParams,
        rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        // fail loudly rather than silently degrading adaptive/det dropout
        // to its all-constant fallback (callers must honor
        // requirements().needs_sigma)
        ensure!(
            stats.is_some() || !self.requirements().needs_sigma,
            "codec {:?} requires σ statistics (requirements().needs_sigma) \
             but encode_uplink got stats = None",
            self.name()
        );
        let sigma: Option<&[f32]> = stats.map(|s| s.sigma_norm.as_slice());
        let Some(decay) = self.ef_decay else {
            return self.encode_core(f, sigma, params, rng);
        };
        // sessionful error feedback: compensate, encode, update the residual
        let stale = self
            .ef
            .as_ref()
            .map_or(true, |e| e.residual.rows != f.rows || e.residual.cols != f.cols);
        if stale {
            let mut ef = ErrorFeedback::new(f.rows, f.cols);
            ef.decay = decay;
            self.ef = Some(ef);
        }
        let comp = self.ef.as_ref().expect("ef state").compensate(f);
        // σ statistics must be recomputed from the *compensated* matrix:
        // stat-driven dropout (AD / deterministic) has to see the residual,
        // or it keeps dropping the same columns every round and the error
        // in them never rotates back in (mirrors ErrorFeedback::encode_round)
        let sigma_comp;
        let sigma: Option<&[f32]> = if self.requirements().needs_sigma {
            sigma_comp = normalized_sigma(&column_stats(&comp), params.chan_size);
            Some(&sigma_comp)
        } else {
            sigma
        };
        let enc = self.encode_core(&comp, sigma, params, rng)?;
        self.ef.as_mut().expect("ef state").absorb(&comp, &enc);
        Ok(enc)
    }

    fn decode_uplink(&self, frame: &Frame, params: &CodecParams) -> Result<DecodedUplink> {
        self.check_frame(frame)?;
        ensure!(frame.kind == FrameKind::FeaturesUp, "uplink decode on {:?} frame", frame.kind);
        let (b, dbar) = (params.batch, params.dbar);
        let mut guard = self.scratch.lock().expect("codec scratch poisoned");
        let ws = &mut *guard;
        // bit-exact fence: reading past the declared payload length is a
        // codec bug and should fail loudly, not zero-fill from padding
        let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
        ws.note_usize_bound(dbar);
        let mut kept = ws.take_usize();
        let delta_bits: f64 = if self.drop.is_some() {
            for i in 0..dbar {
                if rd.read_bits(1) == 1 {
                    kept.push(i);
                }
            }
            dbar as f64
        } else {
            kept.extend(0..dbar);
            0.0
        };
        let c_ava = params.total_budget() - delta_bits;
        let f_hat = match self.quant {
            FwqMode::NoQuant => {
                // read straight into the scattered positions (same read
                // order as undump-then-scatter)
                let mut f_hat = ws.take_matrix(b, dbar);
                for r_i in 0..b {
                    let dst = &mut f_hat.data[r_i * dbar..(r_i + 1) * dbar];
                    for &c in kept.iter() {
                        dst[c] = rd.read_f32();
                    }
                }
                f_hat
            }
            FwqMode::Optimal { .. } | FwqMode::Fixed { .. } => {
                let cfg = self.fwq_cfg(b, c_ava, params);
                crate::util::reserve_total(&mut ws.blob, (c_ava.max(0.0) / 4.0) as usize + 64);
                read_blob_into(&mut rd, &mut ws.blob);
                ws.fwq.reserve(b, dbar);
                crate::util::reserve_total(&mut ws.stage.data, b * dbar);
                {
                    let WireScratch { blob, fwq, stage, .. } = &mut *ws;
                    fwq_decode_into(blob, &cfg, fwq, stage);
                }
                let mut f_hat = ws.take_matrix(b, dbar);
                ws.stage.scatter_cols_into(&kept, &mut f_hat);
                f_hat
            }
            FwqMode::Scalar(kind) => {
                crate::util::reserve_total(&mut ws.blob, (c_ava.max(0.0) / 4.0) as usize + 64);
                read_blob_into(&mut rd, &mut ws.blob);
                crate::util::reserve_total(&mut ws.stage.data, b * dbar);
                crate::util::reserve_total(&mut ws.scalar_syms, b * dbar);
                {
                    let WireScratch { blob, stage, scalar_syms, .. } = &mut *ws;
                    scalar_decode_into(blob, kind, params.noise_seed, scalar_syms, stage);
                }
                let mut f_hat = ws.take_matrix(b, dbar);
                ws.stage.scatter_cols_into(&kept, &mut f_hat);
                f_hat
            }
        };
        Ok(DecodedUplink { f_hat, kept })
    }

    fn encode_downlink(
        &mut self,
        g: &Matrix,
        mask: &GradMask,
        params: &CodecParams,
    ) -> Result<EncodedDownlink> {
        let style = self.downlink_style();
        let mut dn = {
            let ws = self.scratch.get_mut().expect("codec scratch poisoned");
            encode_downlink_styled_with(&style, g, mask, params, ws)
        };
        dn.frame = self.stamp(dn.frame);
        Ok(dn)
    }

    fn decode_downlink(
        &self,
        frame: &Frame,
        mask: &GradMask,
        params: &CodecParams,
    ) -> Result<Matrix> {
        self.check_frame(frame)?;
        let mut guard = self.scratch.lock().expect("codec scratch poisoned");
        decode_downlink_styled_with(&self.downlink_style(), frame, mask, params, &mut guard)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requirements_reflect_drop_kind() {
        let need = |d| SplitFcCodec::new(d, 8.0, FwqMode::NoQuant).requirements().needs_sigma;
        assert!(need(Some(DropKind::Adaptive)));
        assert!(need(Some(DropKind::Deterministic)));
        assert!(!need(Some(DropKind::Random)));
        assert!(!need(None));
    }

    #[test]
    fn ef_flag_shows_in_name_and_requirements() {
        let plain = SplitFcCodec::paper_default(8.0);
        assert!(!plain.requirements().stateful);
        assert_eq!(plain.name(), "splitfc[ad,R=8,fwq]");
        let ef = SplitFcCodec::paper_default(8.0).with_error_feedback(1.0);
        assert!(ef.requirements().stateful);
        assert_eq!(ef.name(), "splitfc[ad,R=8,fwq,ef]");
    }

    #[test]
    fn session_state_roundtrips_the_ef_residual() {
        // drive a real EF encode so the residual is non-trivial
        let params = CodecParams::new(4, 8, 2.0);
        let mut rng = Rng::new(5);
        let mut f = Matrix::zeros(4, 8);
        for (i, v) in f.data.iter_mut().enumerate() {
            *v = (i as f32 * 0.37).sin();
        }
        let mut a = SplitFcCodec::new(Some(DropKind::Random), 2.0, FwqMode::NoQuant)
            .with_error_feedback(0.9);
        a.encode_uplink(&f, None, &params, &mut rng).unwrap();
        let blob = a.export_session();
        assert!(!blob.is_empty());
        let mut b = SplitFcCodec::new(Some(DropKind::Random), 2.0, FwqMode::NoQuant)
            .with_error_feedback(0.9);
        b.restore_session(&blob).unwrap();
        assert_eq!(
            a.ef_residual_norm().unwrap().to_bits(),
            b.ef_residual_norm().unwrap().to_bits()
        );
        // the restored session continues identically: same input + same RNG
        // state must produce byte-identical frames
        let mut ra = Rng::new(6);
        let mut rb = Rng::new(6);
        let ea = a.encode_uplink(&f, None, &params, &mut ra).unwrap();
        let eb = b.encode_uplink(&f, None, &params, &mut rb).unwrap();
        assert_eq!(ea.frame.payload, eb.frame.payload);

        // an armed-but-unused session exports the 1-byte marker
        let c = SplitFcCodec::paper_default(8.0).with_error_feedback(1.0);
        assert_eq!(c.export_session(), vec![0u8]);
        // a stateless session exports empty and rejects stateful blobs
        let mut plain = SplitFcCodec::paper_default(8.0);
        assert!(plain.export_session().is_empty());
        assert!(plain.restore_session(&blob).is_err());
        assert!(plain.restore_session(&[]).is_ok());
        // truncated/garbled state is a typed error, not a panic
        let mut d = SplitFcCodec::paper_default(8.0).with_error_feedback(1.0);
        assert!(d.restore_session(&blob[..blob.len() - 3]).is_err());
        assert!(d.restore_session(&[7u8]).is_err());
    }

    #[test]
    fn cached_id_matches_name_hash_and_tracks_config_changes() {
        let mut codec = SplitFcCodec::paper_default(8.0);
        let f = Frame::new(FrameKind::FeaturesUp, vec![0u8], 8);
        let stamped = codec.stamp(f.clone());
        assert_eq!(stamped.codec_id, codec_id(&codec.name()));
        assert!(codec.check_frame(&stamped).is_ok());
        // mutating the (pub) configuration must refresh the cached id, so
        // old-config frames are rejected instead of misparsed
        codec.quant = FwqMode::NoQuant;
        assert_eq!(codec.stamp(f).codec_id, codec_id(&codec.name()));
        assert!(codec.check_frame(&stamped).is_err());
    }
}
