//! The paper's own framework as a [`Codec`]: adaptive feature-wise dropout
//! (FWDP, Alg. 2) + feature-wise quantization (FWQ, Alg. 3), covering every
//! SplitFC row of Tables I-III and Figs. 3-5, with an optional sessionful
//! error-feedback extension (`splitfc[...,ef]`).

use crate::bitio::BitReader;
use crate::bitio::BitWriter;
use crate::compression::baselines::{qbar_levels, scalar_decode, scalar_encode, ScalarKind};
use crate::compression::codec::{
    Codec, CodecParams, CodecRequirements, DecodedUplink, EncodedUplink, GradMask, SigmaStats,
};
use crate::compression::codecs::common::{
    f32_dump, f32_undump, read_blob, write_blob, ColumnQuant, DownlinkStyle,
};
use crate::compression::dropout::{self, DropKind, DropoutPlan};
use crate::compression::feedback::ErrorFeedback;
use crate::compression::quant::{fwq_decode, fwq_encode, FwqConfig};
use crate::ensure;
use crate::tensor::{column_stats, normalized_sigma, Matrix};
use crate::transport::wire::{Frame, FrameKind};
use crate::util::error::Result;
use crate::util::Rng;

/// How the (post-dropout) matrix entries are represented on the wire.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FwqMode {
    /// raw f32 entries (SplitFC-AD, Fig. 3)
    NoQuant,
    /// the paper's FWQ with optimal level allocation; `use_mean = false` is
    /// ablation Case 3 (two-stage only)
    Optimal { use_mean: bool },
    /// Fig. 5: fixed levels, no optimization
    Fixed { q: u64 },
    /// SplitFC-AD + {PQ, EQ, NQ} rows of Tables I/II
    Scalar(ScalarKind),
}

/// SplitFC as a codec session. `drop = None` is the quantization-only
/// ablation (Table III Case 2); `with_error_feedback` arms the per-device
/// residual memory (SplitFC-EF).
#[derive(Debug)]
pub struct SplitFcCodec {
    pub drop: Option<DropKind>,
    /// dimensionality-reduction ratio R = D̄/D (ignored if drop = None)
    pub r: f64,
    pub quant: FwqMode,
    ef_decay: Option<f32>,
    ef: Option<ErrorFeedback>,
}

impl SplitFcCodec {
    pub fn new(drop: Option<DropKind>, r: f64, quant: FwqMode) -> SplitFcCodec {
        SplitFcCodec { drop, r, quant, ef_decay: None, ef: None }
    }

    /// The paper's full framework at ratio R (AD dropout + optimal FWQ).
    pub fn paper_default(r: f64) -> SplitFcCodec {
        SplitFcCodec::new(Some(DropKind::Adaptive), r, FwqMode::Optimal { use_mean: true })
    }

    /// Arm the error-feedback session state: the residual F - F̂ of what the
    /// codec destroyed is carried to the next round's encode (decay 1.0 =
    /// classic EF; < 1 damps staleness).
    pub fn with_error_feedback(mut self, decay: f32) -> SplitFcCodec {
        self.ef_decay = Some(decay);
        self
    }

    /// Current error-feedback residual norm (None until the first EF encode).
    pub fn ef_residual_norm(&self) -> Option<f64> {
        self.ef.as_ref().map(|e| e.residual_norm())
    }

    /// One memoryless encode round (the pre-EF pipeline, ported verbatim so
    /// the bitstream stays byte-identical to the legacy `Scheme` path).
    fn encode_core(
        &self,
        f: &Matrix,
        sigma_norm: &[f32],
        params: &CodecParams,
        rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        let (b, dbar) = (f.rows, f.cols);
        ensure!(b == params.batch, "batch {b} != params.batch {}", params.batch);
        ensure!(dbar == params.dbar, "dbar {dbar} != params.dbar {}", params.dbar);
        let plan = match self.drop {
            Some(kind) => dropout::plan(kind, sigma_norm, self.r, rng),
            None => DropoutPlan::keep_all(dbar),
        };
        // gather + 1/(1-p_j) rescale fused into one row-major pass
        let ft = f.gather_cols_scaled(&plan.kept, &plan.scale);
        let mut w = BitWriter::new();
        // δ index vector (D̄ bits) — only when dropout is active
        let delta_bits = if self.drop.is_some() { dbar as f64 } else { 0.0 };
        if self.drop.is_some() {
            for &d in &plan.delta {
                w.write_bits(d as u64, 1);
            }
        }
        let c_ava = params.total_budget() - delta_bits;
        let (ft_hat, nominal, m_star) = match self.quant {
            FwqMode::NoQuant => {
                f32_dump(&ft, &mut w);
                (ft.clone(), delta_bits + 32.0 * ft.len() as f64, None)
            }
            FwqMode::Optimal { use_mean } => {
                let mut cfg = FwqConfig::paper_default(b, c_ava);
                cfg.q_ep = params.q_ep;
                cfg.use_mean = use_mean;
                let (bytes, bits, info) = fwq_encode(&ft, &cfg);
                write_blob(&mut w, &bytes, bits);
                let out = fwq_decode(&bytes, &cfg);
                (out, delta_bits + info.nominal_bits, Some(info.m_star))
            }
            FwqMode::Fixed { q } => {
                let mut cfg = FwqConfig::paper_default(b, c_ava);
                cfg.q_ep = params.q_ep;
                cfg.q_fixed = Some(q);
                let (bytes, bits, info) = fwq_encode(&ft, &cfg);
                write_blob(&mut w, &bytes, bits);
                let out = fwq_decode(&bytes, &cfg);
                (out, delta_bits + info.nominal_bits, Some(info.m_star))
            }
            FwqMode::Scalar(kind) => {
                let q = qbar_levels(c_ava, self.r.max(1.0), b, dbar);
                let (bytes, bits) = scalar_encode(&ft, kind, q, params.noise_seed);
                write_blob(&mut w, &bytes, bits);
                let out = scalar_decode(&bytes, kind, params.noise_seed);
                let nominal = delta_bits + ft.len() as f64 * (q as f64).log2() + 96.0;
                (out, nominal, None)
            }
        };
        let f_hat = ft_hat.scatter_cols(&plan.kept, dbar);
        let bits = w.bit_len();
        Ok(EncodedUplink {
            frame: self.stamp(Frame::new(FrameKind::FeaturesUp, w.into_bytes(), bits)),
            f_hat,
            mask: GradMask::Columns { kept: plan.kept, scale: plan.scale },
            nominal_bits: nominal,
            m_star,
        })
    }
}

impl Codec for SplitFcCodec {
    fn name(&self) -> String {
        let d = match self.drop {
            None => "none",
            Some(DropKind::Adaptive) => "ad",
            Some(DropKind::Random) => "rand",
            Some(DropKind::Deterministic) => "det",
        };
        let q = match self.quant {
            FwqMode::NoQuant => "fp32".to_string(),
            FwqMode::Optimal { use_mean: true } => "fwq".to_string(),
            FwqMode::Optimal { use_mean: false } => "fwq-2stage".to_string(),
            FwqMode::Fixed { q } => format!("fixedQ{q}"),
            FwqMode::Scalar(k) => k.name().to_lowercase(),
        };
        let ef = if self.ef_decay.is_some() { ",ef" } else { "" };
        format!("splitfc[{d},R={},{q}{ef}]", self.r)
    }

    fn requirements(&self) -> CodecRequirements {
        CodecRequirements {
            needs_sigma: matches!(
                self.drop,
                Some(DropKind::Adaptive) | Some(DropKind::Deterministic)
            ),
            stateful: self.ef_decay.is_some(),
        }
    }

    fn downlink_style(&self) -> DownlinkStyle {
        let columns = match self.quant {
            FwqMode::Scalar(kind) => ColumnQuant::Scalar { kind, r: self.r },
            FwqMode::Fixed { q } => ColumnQuant::Fwq { use_mean: true, q_fixed: Some(q) },
            FwqMode::Optimal { use_mean } => ColumnQuant::Fwq { use_mean, q_fixed: None },
            FwqMode::NoQuant => ColumnQuant::Fwq { use_mean: true, q_fixed: None },
        };
        DownlinkStyle { columns, entries: ScalarKind::Eq }
    }

    fn encode_uplink(
        &mut self,
        f: &Matrix,
        stats: Option<&SigmaStats>,
        params: &CodecParams,
        rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        let zeros;
        let sigma: &[f32] = match stats {
            Some(s) => &s.sigma_norm,
            None => {
                // fail loudly rather than silently degrading adaptive/det
                // dropout to its all-constant fallback (callers must honor
                // requirements().needs_sigma)
                ensure!(
                    !self.requirements().needs_sigma,
                    "codec {:?} requires σ statistics (requirements().needs_sigma) \
                     but encode_uplink got stats = None",
                    self.name()
                );
                zeros = vec![0.0f32; f.cols];
                &zeros
            }
        };
        let Some(decay) = self.ef_decay else {
            return self.encode_core(f, sigma, params, rng);
        };
        // sessionful error feedback: compensate, encode, update the residual
        let stale = self
            .ef
            .as_ref()
            .map_or(true, |e| e.residual.rows != f.rows || e.residual.cols != f.cols);
        if stale {
            let mut ef = ErrorFeedback::new(f.rows, f.cols);
            ef.decay = decay;
            self.ef = Some(ef);
        }
        let comp = self.ef.as_ref().expect("ef state").compensate(f);
        // σ statistics must be recomputed from the *compensated* matrix:
        // stat-driven dropout (AD / deterministic) has to see the residual,
        // or it keeps dropping the same columns every round and the error
        // in them never rotates back in (mirrors ErrorFeedback::encode_round)
        let sigma_comp;
        let sigma: &[f32] = if self.requirements().needs_sigma {
            sigma_comp = normalized_sigma(&column_stats(&comp), params.chan_size);
            &sigma_comp
        } else {
            sigma
        };
        let enc = self.encode_core(&comp, sigma, params, rng)?;
        self.ef.as_mut().expect("ef state").absorb(&comp, &enc);
        Ok(enc)
    }

    fn decode_uplink(&self, frame: &Frame, params: &CodecParams) -> Result<DecodedUplink> {
        self.check_frame(frame)?;
        ensure!(frame.kind == FrameKind::FeaturesUp, "uplink decode on {:?} frame", frame.kind);
        // bit-exact fence: reading past the declared payload length is a
        // codec bug and should fail loudly, not zero-fill from padding
        let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
        let dbar = params.dbar;
        let (kept, delta_bits): (Vec<usize>, f64) = if self.drop.is_some() {
            let delta: Vec<bool> = (0..dbar).map(|_| rd.read_bits(1) == 1).collect();
            ((0..dbar).filter(|&i| delta[i]).collect(), dbar as f64)
        } else {
            ((0..dbar).collect(), 0.0)
        };
        let c_ava = params.total_budget() - delta_bits;
        let ft_hat = match self.quant {
            FwqMode::NoQuant => f32_undump(&mut rd, params.batch, kept.len()),
            FwqMode::Optimal { use_mean } => {
                let (bytes, _) = read_blob(&mut rd);
                let mut cfg = FwqConfig::paper_default(params.batch, c_ava);
                cfg.q_ep = params.q_ep;
                cfg.use_mean = use_mean;
                fwq_decode(&bytes, &cfg)
            }
            FwqMode::Fixed { q } => {
                let (bytes, _) = read_blob(&mut rd);
                let mut cfg = FwqConfig::paper_default(params.batch, c_ava);
                cfg.q_ep = params.q_ep;
                cfg.q_fixed = Some(q);
                fwq_decode(&bytes, &cfg)
            }
            FwqMode::Scalar(kind) => {
                let (bytes, _) = read_blob(&mut rd);
                scalar_decode(&bytes, kind, params.noise_seed)
            }
        };
        Ok(DecodedUplink { f_hat: ft_hat.scatter_cols(&kept, dbar), kept })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn requirements_reflect_drop_kind() {
        let need = |d| SplitFcCodec::new(d, 8.0, FwqMode::NoQuant).requirements().needs_sigma;
        assert!(need(Some(DropKind::Adaptive)));
        assert!(need(Some(DropKind::Deterministic)));
        assert!(!need(Some(DropKind::Random)));
        assert!(!need(None));
    }

    #[test]
    fn ef_flag_shows_in_name_and_requirements() {
        let plain = SplitFcCodec::paper_default(8.0);
        assert!(!plain.requirements().stateful);
        assert_eq!(plain.name(), "splitfc[ad,R=8,fwq]");
        let ef = SplitFcCodec::paper_default(8.0).with_error_feedback(1.0);
        assert!(ef.requirements().stateful);
        assert_eq!(ef.name(), "splitfc[ad,R=8,fwq,ef]");
    }
}
