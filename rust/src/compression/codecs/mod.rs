//! Per-codec modules (one file per compressor family) plus the built-in
//! registry wiring and shared wire-format helpers (`common`).
//!
//! Adding a codec does NOT require touching this file: implement
//! [`crate::compression::Codec`] anywhere and call
//! [`crate::compression::register_codec`] — this module only wires the
//! built-in paper rows.

pub mod common;
pub mod fedlite;
pub mod splitfc;
pub mod tops;
pub mod vanilla;

use crate::bail;
use crate::compression::baselines::ScalarKind;
use crate::compression::codec::{Codec, CodecRegistry, CodecSpec};
use crate::compression::dropout::DropKind;
use crate::util::error::Result;

use self::fedlite::FedLiteCodec;
use self::splitfc::{FwqMode, SplitFcCodec};
use self::tops::TopSCodec;
use self::vanilla::VanillaCodec;

/// Build a SplitFC-family codec from a spec, starting from per-alias
/// defaults; bracket args (`ad|rand|det|none`, `R=<f64>`,
/// `fwq|fwq-2stage|fp32|fixedQ<q>|pq|eq|nq`, `ef[=<decay>]`) override them.
fn build_splitfc(
    spec: &CodecSpec,
    mut drop: Option<DropKind>,
    mut quant: FwqMode,
    force_r: Option<f64>,
) -> Result<Box<dyn Codec>> {
    let mut r = force_r.unwrap_or(spec.r);
    let mut ef: Option<f32> = None;
    for a in &spec.args {
        match a.as_str() {
            "ad" => drop = Some(DropKind::Adaptive),
            "rand" => drop = Some(DropKind::Random),
            "det" => drop = Some(DropKind::Deterministic),
            "none" => drop = None,
            "fwq" => quant = FwqMode::Optimal { use_mean: true },
            "fwq-2stage" => quant = FwqMode::Optimal { use_mean: false },
            "fp32" => quant = FwqMode::NoQuant,
            "pq" => quant = FwqMode::Scalar(ScalarKind::Pq),
            "eq" => quant = FwqMode::Scalar(ScalarKind::Eq),
            "nq" => quant = FwqMode::Scalar(ScalarKind::Nq),
            "ef" => ef = Some(1.0),
            other => {
                if let Some(v) = other.strip_prefix("R=") {
                    r = v.parse().map_err(|_| crate::err!("bad R value {v:?}"))?;
                } else if let Some(v) = other.strip_prefix("ef=") {
                    ef = Some(v.parse().map_err(|_| crate::err!("bad ef decay {v:?}"))?);
                } else if let Some(v) =
                    other.strip_prefix("fixedQ").or_else(|| other.strip_prefix("fixedq"))
                {
                    let q = v.parse().map_err(|_| crate::err!("bad fixedQ level {v:?}"))?;
                    quant = FwqMode::Fixed { q };
                } else {
                    bail!(
                        "unknown splitfc codec arg {other:?} \
                         (grammar: splitfc[ad|rand|det|none,R=<f64>,\
                         fwq|fwq-2stage|fp32|fixedQ<q>|pq|eq|nq,ef[=<decay>]])"
                    );
                }
            }
        }
    }
    let mut codec = SplitFcCodec::new(drop, r, quant);
    if let Some(decay) = ef {
        codec = codec.with_error_feedback(decay);
    }
    Ok(Box::new(codec))
}

/// Build a Top-S-family codec; args: `theta=<f64>`, `pq|eq|nq|plain`.
fn build_tops(
    spec: &CodecSpec,
    mut theta: f64,
    mut quant: Option<ScalarKind>,
) -> Result<Box<dyn Codec>> {
    for a in &spec.args {
        match a.as_str() {
            "pq" => quant = Some(ScalarKind::Pq),
            "eq" => quant = Some(ScalarKind::Eq),
            "nq" => quant = Some(ScalarKind::Nq),
            "plain" => quant = None,
            other => {
                if let Some(v) = other.strip_prefix("theta=") {
                    theta = v.parse().map_err(|_| crate::err!("bad theta {v:?}"))?;
                } else {
                    bail!(
                        "unknown tops codec arg {other:?} \
                         (grammar: tops[theta=<f64>,pq|eq|nq|plain])"
                    );
                }
            }
        }
    }
    Ok(Box::new(TopSCodec { theta, quant }))
}

/// Build FedLite; args: `s=<num_subvectors>`.
fn build_fedlite(spec: &CodecSpec) -> Result<Box<dyn Codec>> {
    let mut s = 16usize;
    for a in &spec.args {
        if let Some(v) = a.strip_prefix("s=") {
            s = v.parse().map_err(|_| crate::err!("bad subvector count {v:?}"))?;
        } else {
            bail!("unknown fedlite codec arg {a:?} (grammar: fedlite[s=<usize>])");
        }
    }
    Ok(Box::new(FedLiteCodec { num_subvectors: s }))
}

/// Register every built-in scheme: the generic families plus the legacy
/// Table-I/II/III row names as aliases with pre-seeded defaults.
pub fn register_builtins(reg: &mut CodecRegistry) {
    reg.register("vanilla", |spec: &CodecSpec| -> Result<Box<dyn Codec>> {
        if let Some(a) = spec.args.first() {
            bail!("vanilla takes no codec args (got {a:?})");
        }
        Ok(Box::new(VanillaCodec::default()))
    });

    let splitfc_rows: [(&str, Option<DropKind>, FwqMode, Option<f64>); 9] = [
        ("splitfc", Some(DropKind::Adaptive), FwqMode::Optimal { use_mean: true }, None),
        ("splitfc-ad", Some(DropKind::Adaptive), FwqMode::NoQuant, None),
        ("splitfc-rand", Some(DropKind::Random), FwqMode::NoQuant, None),
        ("splitfc-det", Some(DropKind::Deterministic), FwqMode::NoQuant, None),
        ("splitfc-quant-only", None, FwqMode::Optimal { use_mean: true }, Some(1.0)),
        ("splitfc-no-mean", Some(DropKind::Adaptive), FwqMode::Optimal { use_mean: false }, None),
        ("splitfc-ad+pq", Some(DropKind::Adaptive), FwqMode::Scalar(ScalarKind::Pq), None),
        ("splitfc-ad+eq", Some(DropKind::Adaptive), FwqMode::Scalar(ScalarKind::Eq), None),
        ("splitfc-ad+nq", Some(DropKind::Adaptive), FwqMode::Scalar(ScalarKind::Nq), None),
    ];
    for (name, drop, quant, force_r) in splitfc_rows {
        reg.register(name, move |spec: &CodecSpec| build_splitfc(spec, drop, quant, force_r));
    }

    let tops_rows: [(&str, f64, Option<ScalarKind>); 5] = [
        ("tops", 0.0, None),
        ("randtops", 0.2, None),
        ("tops+pq", 0.0, Some(ScalarKind::Pq)),
        ("tops+eq", 0.0, Some(ScalarKind::Eq)),
        ("tops+nq", 0.0, Some(ScalarKind::Nq)),
    ];
    for (name, theta, quant) in tops_rows {
        reg.register(name, move |spec: &CodecSpec| build_tops(spec, theta, quant));
    }

    reg.register("fedlite", build_fedlite);
}
