//! Top-S [16] / RandTop-S [17] entry-sparsification baselines as a
//! [`Codec`], optionally composed with a scalar quantizer (the
//! `topS+{PQ,EQ,NQ}` rows of Tables I/II).

use crate::bitio::{BitReader, BitWriter};
use crate::compression::baselines::{
    qbar_levels, scalar_decode, scalar_encode, sparsity_level, top_s_decode, top_s_encode,
    ScalarKind, TopSConfig,
};
use crate::compression::baselines::topk::{log2_binomial, top_s_mask};
use crate::compression::codec::{
    Codec, CodecParams, CodecRequirements, DecodedUplink, EncodedUplink, GradMask, SigmaStats,
};
use crate::compression::codecs::common::{read_blob, write_blob, DownlinkStyle};
use crate::ensure;
use crate::tensor::Matrix;
use crate::transport::wire::{Frame, FrameKind};
use crate::util::error::Result;
use crate::util::Rng;

/// Top-S entry sparsification; `theta > 0` randomizes the kept set
/// (RandTop-S), `quant` scalar-quantizes the surviving entries.
#[derive(Debug, Clone)]
pub struct TopSCodec {
    /// RandTop-S randomization θ (0 ⇒ plain Top-S)
    pub theta: f64,
    pub quant: Option<ScalarKind>,
}

fn index_width(dbar: usize) -> u32 {
    (usize::BITS - (dbar.max(2) - 1).leading_zeros()).max(1)
}

impl Codec for TopSCodec {
    fn name(&self) -> String {
        // spec-grammar canonical name: pasteable straight back into --scheme
        let q = match self.quant {
            Some(k) => format!(",{}", k.name().to_lowercase()),
            None => String::new(),
        };
        format!("tops[theta={}{q}]", self.theta)
    }

    fn requirements(&self) -> CodecRequirements {
        CodecRequirements::default()
    }

    fn downlink_style(&self) -> DownlinkStyle {
        DownlinkStyle { entries: self.quant.unwrap_or(ScalarKind::Eq), ..Default::default() }
    }

    fn encode_uplink(
        &mut self,
        f: &Matrix,
        _stats: Option<&SigmaStats>,
        params: &CodecParams,
        rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        let (b, dbar) = (f.rows, f.cols);
        ensure!(b == params.batch, "batch {b} != params.batch {}", params.batch);
        ensure!(dbar == params.dbar, "dbar {dbar} != params.dbar {}", params.dbar);
        let value_bits = match self.quant {
            None => 32.0,
            Some(_) => {
                let q = qbar_levels(params.total_budget(), 16.0, b, dbar);
                (q as f64).log2()
            }
        };
        let s = sparsity_level(dbar, params.bits_per_entry, value_bits).max(1);
        let cfg = TopSConfig { s, theta: self.theta };
        match self.quant {
            None => {
                let (bytes, bits, masks) = top_s_encode(f, &cfg, rng);
                let f_hat = top_s_decode(&bytes);
                let nominal = b as f64 * (s as f64 * 32.0 + log2_binomial(dbar, s));
                Ok(EncodedUplink {
                    frame: self.stamp(Frame::new(FrameKind::FeaturesUp, bytes, bits)),
                    f_hat,
                    mask: GradMask::Entries(masks),
                    nominal_bits: nominal,
                    m_star: None,
                })
            }
            Some(kind) => {
                // sparse + scalar: sparsify first, quantize the masked matrix
                let masks = top_s_mask(f, &cfg, rng);
                let mut sparse = Matrix::zeros(b, dbar);
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        *sparse.at_mut(r_i, c) = f.at(r_i, c);
                    }
                }
                let q = qbar_levels(params.total_budget(), 16.0, b, dbar);
                let mut w = BitWriter::new();
                // indices per row (device-side mask must reach the PS)
                let iw = index_width(dbar);
                w.write_u32(s as u32);
                for kept in &masks {
                    for &c in kept {
                        w.write_bits(c as u64, iw);
                    }
                }
                let (bytes, bits) = scalar_encode(&sparse, kind, q, params.noise_seed);
                write_blob(&mut w, &bytes, bits);
                let f_hat = scalar_decode(&bytes, kind, params.noise_seed);
                // zero out the entries the mask dropped (quantizer noise)
                let mut f_hat_sp = Matrix::zeros(b, dbar);
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        *f_hat_sp.at_mut(r_i, c) = f_hat.at(r_i, c);
                    }
                }
                let nominal =
                    b as f64 * (s as f64 * (q as f64).log2() + log2_binomial(dbar, s));
                let bits_total = w.bit_len();
                Ok(EncodedUplink {
                    frame: self
                        .stamp(Frame::new(FrameKind::FeaturesUp, w.into_bytes(), bits_total)),
                    f_hat: f_hat_sp,
                    mask: GradMask::Entries(masks),
                    nominal_bits: nominal,
                    m_star: None,
                })
            }
        }
    }

    fn decode_uplink(&self, frame: &Frame, params: &CodecParams) -> Result<DecodedUplink> {
        self.check_frame(frame)?;
        ensure!(frame.kind == FrameKind::FeaturesUp, "uplink decode on {:?} frame", frame.kind);
        let (b, dbar) = (params.batch, params.dbar);
        let f_hat = match self.quant {
            None => {
                let out = top_s_decode(&frame.payload);
                ensure!(
                    (out.rows, out.cols) == (b, dbar),
                    "topS frame shape {:?} != ({b}, {dbar})",
                    (out.rows, out.cols)
                );
                out
            }
            Some(kind) => {
                let mut rd = BitReader::with_bit_len(&frame.payload, frame.payload_bits);
                let s = rd.read_u32() as usize;
                let iw = index_width(dbar);
                let masks: Vec<Vec<usize>> = (0..b)
                    .map(|_| (0..s).map(|_| rd.read_bits(iw) as usize).collect())
                    .collect();
                let (bytes, _) = read_blob(&mut rd);
                let dense = scalar_decode(&bytes, kind, params.noise_seed);
                let mut out = Matrix::zeros(b, dbar);
                for (r_i, kept) in masks.iter().enumerate() {
                    for &c in kept {
                        ensure!(c < dbar, "topS index {c} out of range {dbar}");
                        *out.at_mut(r_i, c) = dense.at(r_i, c);
                    }
                }
                out
            }
        };
        Ok(DecodedUplink { f_hat, kept: (0..dbar).collect() })
    }
}
