//! FedLite [18] product-quantization baseline as a [`Codec`]: subvector
//! k-means on the uplink, uncompressed downlink (paper Sec. VII).

use crate::compression::baselines::{fedlite_decode, fedlite_encode, FedLiteConfig};
use crate::compression::codec::{
    Codec, CodecParams, CodecRequirements, DecodedUplink, EncodedUplink, GradMask, SigmaStats,
};
use crate::ensure;
use crate::tensor::Matrix;
use crate::transport::wire::{Frame, FrameKind};
use crate::util::error::Result;
use crate::util::Rng;

#[derive(Debug, Clone, Copy)]
pub struct FedLiteCodec {
    pub num_subvectors: usize,
}

impl Codec for FedLiteCodec {
    fn name(&self) -> String {
        // spec-grammar canonical name: pasteable straight back into --scheme
        format!("fedlite[s={}]", self.num_subvectors)
    }

    fn requirements(&self) -> CodecRequirements {
        CodecRequirements::default()
    }

    fn encode_uplink(
        &mut self,
        f: &Matrix,
        _stats: Option<&SigmaStats>,
        params: &CodecParams,
        rng: &mut Rng,
    ) -> Result<EncodedUplink> {
        let (b, dbar) = (f.rows, f.cols);
        ensure!(b == params.batch, "batch {b} != params.batch {}", params.batch);
        ensure!(dbar == params.dbar, "dbar {dbar} != params.dbar {}", params.dbar);
        let cfg = FedLiteConfig { num_subvectors: self.num_subvectors, iters: 10 };
        let (bytes, bits) = fedlite_encode(f, &cfg, params.total_budget(), rng);
        let f_hat = fedlite_decode(&bytes);
        Ok(EncodedUplink {
            frame: self.stamp(Frame::new(FrameKind::FeaturesUp, bytes, bits)),
            f_hat,
            mask: GradMask::All, // FedLite leaves G uncompressed (Sec. VII)
            nominal_bits: bits as f64,
            m_star: None,
        })
    }

    fn decode_uplink(&self, frame: &Frame, params: &CodecParams) -> Result<DecodedUplink> {
        self.check_frame(frame)?;
        ensure!(frame.kind == FrameKind::FeaturesUp, "uplink decode on {:?} frame", frame.kind);
        let f_hat = fedlite_decode(&frame.payload);
        ensure!(
            (f_hat.rows, f_hat.cols) == (params.batch, params.dbar),
            "fedlite frame shape {:?} != ({}, {})",
            (f_hat.rows, f_hat.cols),
            params.batch,
            params.dbar
        );
        Ok(DecodedUplink { f_hat, kept: (0..params.dbar).collect() })
    }
}
