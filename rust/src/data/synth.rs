//! Procedural class-structured image generator.
//!
//! Each class owns a smooth low-frequency template (random coarse grid,
//! bilinearly upsampled, plus a class-keyed sinusoidal pattern). Each sample
//! draws a "writer" identity (CelebA-style grouping), which contributes a
//! small spatial shift + gain, then adds pixel noise. The result is a
//! learnable classification task whose intermediate features exhibit the
//! heterogeneous per-column dispersion the paper's Fig. 1 demonstrates —
//! which is the property SplitFC exploits.

use crate::util::Rng;

#[derive(Debug, Clone)]
pub struct SynthSpec {
    pub classes: usize,
    pub channels: usize,
    pub height: usize,
    pub width: usize,
    pub writers: usize,
    pub noise: f32,
    pub seed: u64,
}

impl SynthSpec {
    pub fn mnist_like() -> SynthSpec {
        SynthSpec { classes: 10, channels: 1, height: 28, width: 28, writers: 64, noise: 0.15, seed: 11 }
    }

    pub fn cifar_like() -> SynthSpec {
        SynthSpec { classes: 100, channels: 3, height: 32, width: 32, writers: 64, noise: 0.12, seed: 12 }
    }

    pub fn celeba_like() -> SynthSpec {
        SynthSpec { classes: 2, channels: 3, height: 32, width: 32, writers: 200, noise: 0.12, seed: 13 }
    }

    pub fn tiny() -> SynthSpec {
        SynthSpec { classes: 4, channels: 1, height: 8, width: 8, writers: 8, noise: 0.1, seed: 14 }
    }

    pub fn sample_dim(&self) -> usize {
        self.channels * self.height * self.width
    }
}

#[derive(Debug, Clone)]
pub struct Dataset {
    pub spec: SynthSpec,
    /// n * (C*H*W), row-major per sample, NCHW within a sample.
    pub x: Vec<f32>,
    pub y: Vec<u32>,
    /// writer identity per sample (for CelebA-style partitioning).
    pub writer: Vec<u32>,
    pub n: usize,
}

struct ClassTemplate {
    /// coarse 5x5 grid per channel
    grid: Vec<f32>,
    freq: (f32, f32, f32),
}

const GRID: usize = 5;

fn bilinear(grid: &[f32], gy: f32, gx: f32) -> f32 {
    let y0 = gy.floor().min((GRID - 1) as f32).max(0.0);
    let x0 = gx.floor().min((GRID - 1) as f32).max(0.0);
    let y1 = (y0 + 1.0).min((GRID - 1) as f32);
    let x1 = (x0 + 1.0).min((GRID - 1) as f32);
    let fy = gy - y0;
    let fx = gx - x0;
    let g = |y: f32, x: f32| grid[y as usize * GRID + x as usize];
    g(y0, x0) * (1.0 - fy) * (1.0 - fx)
        + g(y0, x1) * (1.0 - fy) * fx
        + g(y1, x0) * fy * (1.0 - fx)
        + g(y1, x1) * fy * fx
}

impl Dataset {
    /// Generate `n` samples. Balanced classes; writer sampled per example and
    /// biased to favour a subset of classes (so writer grouping is non-IID).
    pub fn generate(spec: &SynthSpec, n: usize, seed_offset: u64) -> Dataset {
        let mut rng = Rng::new(spec.seed.wrapping_add(seed_offset.wrapping_mul(0x9E37)));
        let templates: Vec<Vec<ClassTemplate>> = (0..spec.classes)
            .map(|cls| {
                let mut crng = Rng::new(spec.seed ^ (cls as u64 * 7919 + 1));
                (0..spec.channels)
                    .map(|_| ClassTemplate {
                        grid: (0..GRID * GRID).map(|_| crng.normal_f32(0.0, 1.0)).collect(),
                        freq: (
                            0.5 + 2.5 * crng.next_f32(),
                            0.5 + 2.5 * crng.next_f32(),
                            std::f32::consts::TAU * crng.next_f32(),
                        ),
                    })
                    .collect()
            })
            .collect();
        // per-writer deformation
        let wshift: Vec<(f32, f32, f32)> = {
            let mut wrng = Rng::new(spec.seed ^ 0xABCD);
            (0..spec.writers)
                .map(|_| {
                    (
                        wrng.normal_f32(0.0, 0.6),
                        wrng.normal_f32(0.0, 0.6),
                        1.0 + 0.2 * wrng.normal_f32(0.0, 1.0),
                    )
                })
                .collect()
        };

        let dim = spec.sample_dim();
        let mut x = Vec::with_capacity(n * dim);
        let mut y = Vec::with_capacity(n);
        let mut writer = Vec::with_capacity(n);
        for i in 0..n {
            let cls = i % spec.classes; // balanced
            // writers preferentially produce a subset of classes
            let w = (cls * spec.writers / spec.classes
                + rng.gen_range((spec.writers / spec.classes).max(1)))
                % spec.writers;
            let (dy, dx, gain) = wshift[w];
            for ch in 0..spec.channels {
                let t = &templates[cls][ch];
                let (fa, fb, ph) = t.freq;
                for py in 0..spec.height {
                    for px in 0..spec.width {
                        let gy = (py as f32 + dy) / (spec.height - 1).max(1) as f32
                            * (GRID - 1) as f32;
                        let gx = (px as f32 + dx) / (spec.width - 1).max(1) as f32
                            * (GRID - 1) as f32;
                        let base = bilinear(&t.grid, gy, gx);
                        let wave = 0.5
                            * (fa * py as f32 / spec.height as f32 * std::f32::consts::TAU
                                + fb * px as f32 / spec.width as f32 * std::f32::consts::TAU
                                + ph)
                                .sin();
                        let v = gain * (base + wave) + spec.noise * rng.normal_f32(0.0, 1.0);
                        x.push(v);
                    }
                }
            }
            y.push(cls as u32);
            writer.push(w as u32);
        }
        Dataset { spec: spec.clone(), x, y, writer, n }
    }

    pub fn sample(&self, i: usize) -> &[f32] {
        let d = self.spec.sample_dim();
        &self.x[i * d..(i + 1) * d]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn generation_shapes() {
        let spec = SynthSpec::tiny();
        let ds = Dataset::generate(&spec, 40, 0);
        assert_eq!(ds.n, 40);
        assert_eq!(ds.x.len(), 40 * spec.sample_dim());
        assert_eq!(ds.y.len(), 40);
        assert!(ds.y.iter().all(|&c| (c as usize) < spec.classes));
        assert!(ds.writer.iter().all(|&w| (w as usize) < spec.writers));
    }

    #[test]
    fn balanced_classes() {
        let spec = SynthSpec::tiny();
        let ds = Dataset::generate(&spec, 400, 0);
        let mut counts = vec![0usize; spec.classes];
        for &c in &ds.y {
            counts[c as usize] += 1;
        }
        assert!(counts.iter().all(|&c| c == 100));
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SynthSpec::tiny();
        let a = Dataset::generate(&spec, 16, 3);
        let b = Dataset::generate(&spec, 16, 3);
        assert_eq!(a.x, b.x);
        let c = Dataset::generate(&spec, 16, 4);
        assert_ne!(a.x, c.x);
    }

    #[test]
    fn classes_are_separable_by_template() {
        // Mean intra-class distance should be well below inter-class distance.
        let spec = SynthSpec::tiny();
        let ds = Dataset::generate(&spec, 80, 0);
        let d = spec.sample_dim();
        let dist = |a: &[f32], b: &[f32]| -> f32 {
            a.iter().zip(b).map(|(x, y)| (x - y) * (x - y)).sum::<f32>() / d as f32
        };
        let (mut intra, mut inter, mut ni, mut nx) = (0.0, 0.0, 0, 0);
        for i in 0..40 {
            for j in (i + 1)..40 {
                let dd = dist(ds.sample(i), ds.sample(j));
                if ds.y[i] == ds.y[j] {
                    intra += dd;
                    ni += 1;
                } else {
                    inter += dd;
                    nx += 1;
                }
            }
        }
        let (intra, inter) = (intra / ni as f32, inter / nx as f32);
        assert!(inter > 1.5 * intra, "inter={inter} intra={intra}");
    }

    #[test]
    fn values_are_finite_and_bounded() {
        let ds = Dataset::generate(&SynthSpec::mnist_like(), 8, 0);
        assert!(ds.x.iter().all(|v| v.is_finite() && v.abs() < 20.0));
    }
}
