//! Per-device minibatch loader: shuffled cycling over the device's partition,
//! producing NCHW f32 batches and one-hot label matrices ready for PJRT.
//! The loader owns only indices + RNG; the dataset is passed per call so one
//! dataset can back all K device loaders.

use super::synth::Dataset;
use crate::util::error::Result;
use crate::util::rng::RngState;
use crate::util::Rng;

/// The serializable loader state: the *shuffled* index order, the cursor
/// into it, the batch size and the shuffle RNG — restoring it continues the
/// exact epoch sequence (no reshuffle on restore).
#[derive(Debug, Clone, PartialEq)]
pub struct LoaderState {
    pub indices: Vec<u64>,
    pub cursor: u64,
    pub batch: u64,
    pub rng: RngState,
}

pub struct MiniBatchLoader {
    indices: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Rng,
}

impl MiniBatchLoader {
    pub fn new(partition: Vec<usize>, batch: usize, rng: Rng) -> Self {
        assert!(!partition.is_empty(), "empty device partition");
        let mut s = Self { indices: partition, cursor: 0, batch, rng };
        s.reshuffle();
        s
    }

    /// Snapshot the full loader state for checkpointing.
    pub fn export_state(&self) -> LoaderState {
        LoaderState {
            indices: self.indices.iter().map(|&i| i as u64).collect(),
            cursor: self.cursor as u64,
            batch: self.batch as u64,
            rng: self.rng.export_state(),
        }
    }

    /// Rebuild a loader that continues exactly from `st`. Unlike
    /// [`MiniBatchLoader::new`] this does **not** reshuffle: the snapshot
    /// already holds the in-epoch order and position.
    pub fn from_state(st: &LoaderState) -> Result<Self> {
        crate::ensure!(!st.indices.is_empty(), "loader snapshot has an empty partition");
        crate::ensure!(
            st.cursor <= st.indices.len() as u64 && st.batch > 0,
            "loader snapshot is inconsistent (cursor {} over {} indices, batch {})",
            st.cursor,
            st.indices.len(),
            st.batch
        );
        Ok(Self {
            indices: st.indices.iter().map(|&i| i as usize).collect(),
            cursor: st.cursor as usize,
            batch: st.batch as usize,
            rng: Rng::from_state(&st.rng),
        })
    }

    fn reshuffle(&mut self) {
        let mut idx = std::mem::take(&mut self.indices);
        self.rng.shuffle(&mut idx);
        self.indices = idx;
        self.cursor = 0;
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Next minibatch: (x: batch * C*H*W, y_onehot: batch * classes, labels).
    /// Wraps around (with reshuffle) when the partition is exhausted.
    pub fn next_batch(&mut self, ds: &Dataset, classes: usize) -> (Vec<f32>, Vec<f32>, Vec<u32>) {
        let dim = ds.spec.sample_dim();
        let mut x = Vec::with_capacity(self.batch * dim);
        let mut y = vec![0.0f32; self.batch * classes];
        let mut labels = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            if self.cursor >= self.indices.len() {
                self.reshuffle();
            }
            let i = self.indices[self.cursor];
            self.cursor += 1;
            x.extend_from_slice(ds.sample(i));
            let c = ds.y[i];
            y[b * classes + c as usize] = 1.0;
            labels.push(c);
        }
        (x, y, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn batch_shapes_and_onehot() {
        let ds = Dataset::generate(&SynthSpec::tiny(), 64, 0);
        let mut loader = MiniBatchLoader::new((0..64).collect(), 8, Rng::new(0));
        let (x, y, labels) = loader.next_batch(&ds, 4);
        assert_eq!(x.len(), 8 * ds.spec.sample_dim());
        assert_eq!(y.len(), 8 * 4);
        assert_eq!(labels.len(), 8);
        for (b, &c) in labels.iter().enumerate() {
            let row = &y[b * 4..(b + 1) * 4];
            assert_eq!(row[c as usize], 1.0);
            assert_eq!(row.iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn cycles_through_all_samples() {
        let ds = Dataset::generate(&SynthSpec::tiny(), 20, 0);
        let mut loader = MiniBatchLoader::new((0..20).collect(), 5, Rng::new(1));
        let mut seen = vec![0usize; 4];
        for _ in 0..4 {
            let (_, _, labels) = loader.next_batch(&ds, 4);
            for &c in &labels {
                seen[c as usize] += 1;
            }
        }
        // one full epoch: balanced tiny dataset has 5 samples/class
        assert_eq!(seen.iter().sum::<usize>(), 20);
        assert!(seen.iter().all(|&c| c == 5), "{seen:?}");
    }

    #[test]
    fn partition_smaller_than_batch_repeats() {
        let ds = Dataset::generate(&SynthSpec::tiny(), 12, 0);
        let mut loader = MiniBatchLoader::new(vec![0, 1, 2], 8, Rng::new(2));
        let (x, _, _) = loader.next_batch(&ds, 4);
        assert_eq!(x.len(), 8 * ds.spec.sample_dim());
    }

    #[test]
    #[should_panic]
    fn empty_partition_panics() {
        MiniBatchLoader::new(vec![], 2, Rng::new(0));
    }

    #[test]
    fn state_roundtrip_continues_the_epoch_sequence() {
        let ds = Dataset::generate(&SynthSpec::tiny(), 20, 0);
        let mut a = MiniBatchLoader::new((0..20).collect(), 6, Rng::new(3));
        a.next_batch(&ds, 4); // advance into the epoch (wrap state matters)
        let st = a.export_state();
        let mut b = MiniBatchLoader::from_state(&st).unwrap();
        // the continuation must be identical batch-for-batch, including the
        // mid-run reshuffle both loaders perform from the same RNG state
        for _ in 0..8 {
            let (xa, ya, la) = a.next_batch(&ds, 4);
            let (xb, yb, lb) = b.next_batch(&ds, 4);
            assert_eq!(la, lb);
            assert_eq!(xa, xb);
            assert_eq!(ya, yb);
        }
    }

    #[test]
    fn inconsistent_state_is_rejected() {
        let st = LoaderState {
            indices: vec![0, 1, 2],
            cursor: 9,
            batch: 2,
            rng: Rng::new(0).export_state(),
        };
        assert!(MiniBatchLoader::from_state(&st).is_err());
        let empty = LoaderState { indices: vec![], cursor: 0, batch: 2, rng: st.rng };
        assert!(MiniBatchLoader::from_state(&empty).is_err());
    }
}
