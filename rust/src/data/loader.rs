//! Per-device minibatch loader: shuffled cycling over the device's partition,
//! producing NCHW f32 batches and one-hot label matrices ready for PJRT.
//! The loader owns only indices + RNG; the dataset is passed per call so one
//! dataset can back all K device loaders.

use super::synth::Dataset;
use crate::util::Rng;

pub struct MiniBatchLoader {
    indices: Vec<usize>,
    cursor: usize,
    batch: usize,
    rng: Rng,
}

impl MiniBatchLoader {
    pub fn new(partition: Vec<usize>, batch: usize, rng: Rng) -> Self {
        assert!(!partition.is_empty(), "empty device partition");
        let mut s = Self { indices: partition, cursor: 0, batch, rng };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        let mut idx = std::mem::take(&mut self.indices);
        self.rng.shuffle(&mut idx);
        self.indices = idx;
        self.cursor = 0;
    }

    pub fn len(&self) -> usize {
        self.indices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.indices.is_empty()
    }

    /// Next minibatch: (x: batch * C*H*W, y_onehot: batch * classes, labels).
    /// Wraps around (with reshuffle) when the partition is exhausted.
    pub fn next_batch(&mut self, ds: &Dataset, classes: usize) -> (Vec<f32>, Vec<f32>, Vec<u32>) {
        let dim = ds.spec.sample_dim();
        let mut x = Vec::with_capacity(self.batch * dim);
        let mut y = vec![0.0f32; self.batch * classes];
        let mut labels = Vec::with_capacity(self.batch);
        for b in 0..self.batch {
            if self.cursor >= self.indices.len() {
                self.reshuffle();
            }
            let i = self.indices[self.cursor];
            self.cursor += 1;
            x.extend_from_slice(ds.sample(i));
            let c = ds.y[i];
            y[b * classes + c as usize] = 1.0;
            labels.push(c);
        }
        (x, y, labels)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    #[test]
    fn batch_shapes_and_onehot() {
        let ds = Dataset::generate(&SynthSpec::tiny(), 64, 0);
        let mut loader = MiniBatchLoader::new((0..64).collect(), 8, Rng::new(0));
        let (x, y, labels) = loader.next_batch(&ds, 4);
        assert_eq!(x.len(), 8 * ds.spec.sample_dim());
        assert_eq!(y.len(), 8 * 4);
        assert_eq!(labels.len(), 8);
        for (b, &c) in labels.iter().enumerate() {
            let row = &y[b * 4..(b + 1) * 4];
            assert_eq!(row[c as usize], 1.0);
            assert_eq!(row.iter().sum::<f32>(), 1.0);
        }
    }

    #[test]
    fn cycles_through_all_samples() {
        let ds = Dataset::generate(&SynthSpec::tiny(), 20, 0);
        let mut loader = MiniBatchLoader::new((0..20).collect(), 5, Rng::new(1));
        let mut seen = vec![0usize; 4];
        for _ in 0..4 {
            let (_, _, labels) = loader.next_batch(&ds, 4);
            for &c in &labels {
                seen[c as usize] += 1;
            }
        }
        // one full epoch: balanced tiny dataset has 5 samples/class
        assert_eq!(seen.iter().sum::<usize>(), 20);
        assert!(seen.iter().all(|&c| c == 5), "{seen:?}");
    }

    #[test]
    fn partition_smaller_than_batch_repeats() {
        let ds = Dataset::generate(&SynthSpec::tiny(), 12, 0);
        let mut loader = MiniBatchLoader::new(vec![0, 1, 2], 8, Rng::new(2));
        let (x, _, _) = loader.next_batch(&ds, 4);
        assert_eq!(x.len(), 8 * ds.spec.sample_dim());
    }

    #[test]
    #[should_panic]
    fn empty_partition_panics() {
        MiniBatchLoader::new(vec![], 2, Rng::new(0));
    }
}
