//! Non-IID partitioners from Sec. VII:
//!   * MNIST: label-sharding — samples of each label split into shards, each
//!     device gets `shards_per_device` shards of different labels [52];
//!   * CIFAR-100: Dirichlet(beta) label distribution per device [52];
//!   * CelebA: grouping by writer identity [36].

use super::synth::Dataset;
use crate::util::Rng;

/// MNIST-style: 2 shards of distinct labels per device.
pub fn label_shards(
    ds: &Dataset,
    devices: usize,
    shards_per_device: usize,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let total_shards = devices * shards_per_device;
    // group sample indices by label
    let mut by_label: Vec<Vec<usize>> = vec![Vec::new(); ds.spec.classes];
    for (i, &c) in ds.y.iter().enumerate() {
        by_label[c as usize].push(i);
    }
    // build shards: split each label's pool into equal chunks
    let shards_per_label = (total_shards + ds.spec.classes - 1) / ds.spec.classes;
    let mut shards: Vec<Vec<usize>> = Vec::with_capacity(total_shards);
    for pool in &by_label {
        let chunk = (pool.len() / shards_per_label).max(1);
        for s in 0..shards_per_label {
            let lo = s * chunk;
            let hi = if s == shards_per_label - 1 { pool.len() } else { ((s + 1) * chunk).min(pool.len()) };
            if lo < hi {
                shards.push(pool[lo..hi].to_vec());
            }
        }
    }
    rng.shuffle(&mut shards);
    let mut out = vec![Vec::new(); devices];
    for (si, shard) in shards.into_iter().enumerate() {
        out[si % devices].extend(shard);
    }
    out
}

/// CIFAR-style: per-class Dirichlet(beta) split across devices.
pub fn dirichlet_partition(
    ds: &Dataset,
    devices: usize,
    beta: f64,
    rng: &mut Rng,
) -> Vec<Vec<usize>> {
    let mut out = vec![Vec::new(); devices];
    let mut by_label: Vec<Vec<usize>> = vec![Vec::new(); ds.spec.classes];
    for (i, &c) in ds.y.iter().enumerate() {
        by_label[c as usize].push(i);
    }
    for pool in &mut by_label {
        rng.shuffle(pool);
        let p = rng.dirichlet(beta, devices);
        // cumulative split
        let mut start = 0usize;
        let mut acc = 0.0f64;
        for (d, &pd) in p.iter().enumerate() {
            acc += pd;
            let end = if d == devices - 1 { pool.len() } else { (acc * pool.len() as f64).round() as usize };
            let end = end.clamp(start, pool.len());
            out[d].extend(&pool[start..end]);
            start = end;
        }
    }
    out
}

/// CelebA-style: group `writers_per_device` writers per device [36].
pub fn writer_groups(ds: &Dataset, devices: usize, rng: &mut Rng) -> Vec<Vec<usize>> {
    let writers = ds.spec.writers;
    let mut ids: Vec<usize> = (0..writers).collect();
    rng.shuffle(&mut ids);
    // writer -> device
    let mut owner = vec![0usize; writers];
    for (rank, &w) in ids.iter().enumerate() {
        owner[w] = rank % devices;
    }
    let mut out = vec![Vec::new(); devices];
    for (i, &w) in ds.writer.iter().enumerate() {
        out[owner[w as usize]].push(i);
    }
    out
}

/// Label-distribution skew measure: mean over devices of the max class share.
/// 1/classes for IID, → 1.0 for single-label devices. Used by tests.
pub fn skewness(ds: &Dataset, parts: &[Vec<usize>]) -> f64 {
    let mut total = 0.0;
    let mut n = 0;
    for p in parts {
        if p.is_empty() {
            continue;
        }
        let mut counts = vec![0usize; ds.spec.classes];
        for &i in p {
            counts[ds.y[i] as usize] += 1;
        }
        let mx = *counts.iter().max().unwrap() as f64;
        total += mx / p.len() as f64;
        n += 1;
    }
    total / n.max(1) as f64
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth::SynthSpec;

    fn ds() -> Dataset {
        Dataset::generate(&SynthSpec::tiny(), 800, 0)
    }

    #[test]
    fn label_shards_cover_disjoint() {
        let d = ds();
        let mut rng = Rng::new(0);
        let parts = label_shards(&d, 8, 2, &mut rng);
        assert_eq!(parts.len(), 8);
        let mut all: Vec<usize> = parts.iter().flatten().copied().collect();
        all.sort_unstable();
        let n = all.len();
        all.dedup();
        assert_eq!(all.len(), n, "partitions must be disjoint");
        assert!(n >= d.n * 9 / 10, "most samples assigned (got {n}/{})", d.n);
    }

    #[test]
    fn label_shards_are_skewed() {
        let d = ds();
        let mut rng = Rng::new(1);
        let parts = label_shards(&d, 8, 2, &mut rng);
        // 4 classes, 2 shards/device -> each device sees at most 2 labels
        assert!(skewness(&d, &parts) >= 0.45, "skew={}", skewness(&d, &parts));
    }

    #[test]
    fn dirichlet_covers_all_and_skews_with_low_beta() {
        let d = ds();
        let mut rng = Rng::new(2);
        let lo = dirichlet_partition(&d, 10, 0.3, &mut rng);
        let hi = dirichlet_partition(&d, 10, 1000.0, &mut rng);
        let n_lo: usize = lo.iter().map(|p| p.len()).sum();
        assert_eq!(n_lo, d.n);
        assert!(
            skewness(&d, &lo) > skewness(&d, &hi),
            "beta=0.3 must be more skewed than beta=1000"
        );
    }

    #[test]
    fn writer_groups_keep_writers_together() {
        let d = ds();
        let mut rng = Rng::new(3);
        let parts = writer_groups(&d, 4, &mut rng);
        let n: usize = parts.iter().map(|p| p.len()).sum();
        assert_eq!(n, d.n);
        // every writer's samples land on exactly one device
        for w in 0..d.spec.writers {
            let mut devices_seen = Vec::new();
            for (di, p) in parts.iter().enumerate() {
                if p.iter().any(|&i| d.writer[i] as usize == w) {
                    devices_seen.push(di);
                }
            }
            assert!(devices_seen.len() <= 1, "writer {w} split across {devices_seen:?}");
        }
    }

    #[test]
    fn skewness_bounds() {
        let d = ds();
        let mut rng = Rng::new(4);
        for parts in [
            label_shards(&d, 8, 2, &mut rng),
            dirichlet_partition(&d, 8, 0.3, &mut rng),
            writer_groups(&d, 8, &mut rng),
        ] {
            let s = skewness(&d, &parts);
            assert!((0.2..=1.0).contains(&s), "s={s}");
        }
    }
}
