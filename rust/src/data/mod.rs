//! Dataset substrate: procedural class-structured image datasets standing in
//! for MNIST / CIFAR-100 / CelebA (no dataset downloads offline — see
//! DESIGN.md §3), plus the paper's three non-IID partitioners and a
//! per-device minibatch loader.

pub mod loader;
pub mod partition;
pub mod synth;

pub use loader::{LoaderState, MiniBatchLoader};
pub use partition::{dirichlet_partition, label_shards, writer_groups};
pub use synth::{Dataset, SynthSpec};
