//! Dependency-free substrates: PRNG, JSON, CLI parsing, logging.

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;

pub use cli::Args;
pub use json::Json;
pub use rng::Rng;
