//! Dependency-free substrates: PRNG, JSON, CLI parsing, logging, errors,
//! and the scoped-thread parallel runtime (`par`).

pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod par;
pub mod rng;

pub use cli::Args;
pub use error::{Context, Error, Result};
pub use json::Json;
pub use rng::Rng;
