//! Dependency-free substrates: PRNG, JSON, CLI parsing, logging, errors,
//! and the scoped-thread parallel runtime (`par`).

pub mod alloc_count;
pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod par;
pub mod rng;
pub mod simd;
pub mod sort;

pub use cli::Args;
pub use error::{Context, Error, Result};
pub use json::Json;
pub use rng::{Rng, RngState};

/// Grow `v`'s capacity to at least `cap` **total** elements. `Vec::reserve`
/// is relative to the current length, so calling it on a scratch buffer
/// that still holds last round's contents over-allocates toward `len +
/// cap`; this pins capacity at the intended absolute bound instead.
pub fn reserve_total<T>(v: &mut Vec<T>, cap: usize) {
    v.reserve(cap.saturating_sub(v.len()));
}
