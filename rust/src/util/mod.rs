//! Dependency-free substrates: PRNG, JSON, CLI parsing, logging, errors.

pub mod cli;
pub mod error;
pub mod json;
pub mod logging;
pub mod rng;

pub use cli::Args;
pub use error::{Context, Error, Result};
pub use json::Json;
pub use rng::Rng;
