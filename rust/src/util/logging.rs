//! Minimal leveled logger with monotonic elapsed-time stamps.

use std::sync::atomic::{AtomicU8, Ordering};
use std::time::Instant;

static LEVEL: AtomicU8 = AtomicU8::new(2); // 0 quiet, 1 warn, 2 info, 3 debug

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn level() -> u8 {
    LEVEL.load(Ordering::Relaxed)
}

fn start() -> Instant {
    use std::sync::OnceLock;
    static START: OnceLock<Instant> = OnceLock::new();
    *START.get_or_init(Instant::now)
}

pub fn stamp() -> String {
    let e = start().elapsed();
    format!("{:>8.3}s", e.as_secs_f64())
}

#[macro_export]
macro_rules! log_info {
    ($($arg:tt)*) => {
        if $crate::util::logging::level() >= 2 {
            eprintln!("[{} INFO ] {}", $crate::util::logging::stamp(), format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_warn {
    ($($arg:tt)*) => {
        if $crate::util::logging::level() >= 1 {
            eprintln!("[{} WARN ] {}", $crate::util::logging::stamp(), format!($($arg)*));
        }
    };
}

#[macro_export]
macro_rules! log_debug {
    ($($arg:tt)*) => {
        if $crate::util::logging::level() >= 3 {
            eprintln!("[{} DEBUG] {}", $crate::util::logging::stamp(), format!($($arg)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_roundtrip() {
        let old = level();
        set_level(3);
        assert_eq!(level(), 3);
        set_level(old);
    }

    #[test]
    fn stamp_is_monotonic_format() {
        let s = stamp();
        assert!(s.ends_with('s'));
    }
}
