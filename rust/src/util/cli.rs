//! Tiny CLI argument substrate (no `clap` offline).
//!
//! Grammar: `prog <subcommand> [positional...] [--key value | --key=value | --flag]`.
//! Unknown keys are kept so experiment binaries can forward overrides into
//! `config::TrainConfig::apply_overrides`.

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    pub options: BTreeMap<String, String>,
    pub flags: Vec<String>,
}

impl Args {
    pub fn parse(argv: &[String]) -> Args {
        let mut out = Args::default();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(rest) = a.strip_prefix("--") {
                if let Some(eq) = rest.find('=') {
                    out.options
                        .insert(rest[..eq].to_string(), rest[eq + 1..].to_string());
                } else if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    out.options.insert(rest.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    out.flags.push(rest.to_string());
                }
            } else {
                out.positional.push(a.clone());
            }
            i += 1;
        }
        out
    }

    pub fn from_env() -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        Args::parse(&argv)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn get_or<'a>(&'a self, key: &str, default: &'a str) -> &'a str {
        self.get(key).unwrap_or(default)
    }

    pub fn get_f64(&self, key: &str, default: f64) -> f64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_usize(&self, key: &str, default: usize) -> usize {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, key: &str, default: u64) -> u64 {
        self.get(key).and_then(|s| s.parse().ok()).unwrap_or(default)
    }

    pub fn has_flag(&self, key: &str) -> bool {
        self.flags.iter().any(|f| f == key)
    }

    pub fn subcommand(&self) -> Option<&str> {
        self.positional.first().map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn positional_and_options() {
        let a = parse("train --rounds 20 --preset mnist extra");
        assert_eq!(a.subcommand(), Some("train"));
        assert_eq!(a.get_usize("rounds", 0), 20);
        assert_eq!(a.get("preset"), Some("mnist"));
        assert_eq!(a.positional, vec!["train", "extra"]);
    }

    #[test]
    fn equals_form() {
        let a = parse("x --lr=0.001 --name=a=b");
        assert_eq!(a.get_f64("lr", 0.0), 0.001);
        assert_eq!(a.get("name"), Some("a=b"));
    }

    #[test]
    fn bare_flags() {
        let a = parse("run --verbose --k 3 --dry-run");
        assert!(a.has_flag("verbose"));
        assert!(a.has_flag("dry-run"));
        assert_eq!(a.get_usize("k", 0), 3);
    }

    #[test]
    fn defaults() {
        let a = parse("run");
        assert_eq!(a.get_f64("missing", 1.5), 1.5);
        assert_eq!(a.get_or("missing", "d"), "d");
        assert!(!a.has_flag("missing"));
    }

    #[test]
    fn negative_number_value() {
        // "--key value" where value starts with '-' but not '--'
        let a = parse("x --offset -3");
        assert_eq!(a.get_f64("offset", 0.0), -3.0);
    }
}
