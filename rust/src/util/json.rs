//! Minimal JSON substrate (no `serde` offline): value model, recursive-descent
//! parser, compact + pretty writers. Used for the artifact manifest, run
//! configs, and metrics JSONL.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub pos: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing garbage"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that panics with a useful message — for required fields.
    pub fn req(&self, key: &str) -> &Json {
        self.get(key)
            .unwrap_or_else(|| panic!("missing required json key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    pub fn usize_arr(&self) -> Option<Vec<usize>> {
        self.as_arr()
            .map(|a| a.iter().filter_map(|x| x.as_usize()).collect())
    }

    // -- builders ----------------------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn num(x: f64) -> Json {
        Json::Num(x)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // -- writers -----------------------------------------------------------

    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(1), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    if let Some(w) = indent {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * (depth + 1)));
                    }
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                if let Some(w) = indent {
                    if !m.is_empty() {
                        out.push('\n');
                        out.push_str(&" ".repeat(w * depth));
                    }
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), pos: self.i }
    }

    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("bad literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.skip_ws();
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // advance over one UTF-8 char
                    let start = self.i;
                    self.i += 1;
                    while self.i < self.b.len() && (self.b[self.i] & 0xC0) == 0x80 {
                        self.i += 1;
                    }
                    s.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("invalid utf8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(v.req("a").as_arr().unwrap().len(), 3);
        assert_eq!(
            v.req("a").as_arr().unwrap()[2].req("b").as_str(),
            Some("c")
        );
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A"));
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,-3],"nested":{"x":true,"y":"s\"tr"},"z":null}"#;
        let v = Json::parse(src).unwrap();
        for s in [v.to_string_compact(), v.to_string_pretty()] {
            assert_eq!(Json::parse(&s).unwrap(), v);
        }
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn integers_print_without_decimal() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
    }

    #[test]
    fn usize_arr_helper() {
        let v = Json::parse("[1, 2, 3]").unwrap();
        assert_eq!(v.usize_arr().unwrap(), vec![1, 2, 3]);
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::parse("\"héllo ∀\"").unwrap();
        assert_eq!(v.as_str(), Some("héllo ∀"));
        assert_eq!(Json::parse(&v.to_string_compact()).unwrap(), v);
    }
}
