//! Allocation-free stable sorting for the wire hot path.
//!
//! `slice::sort_by` (std's stable sort) allocates its merge buffer on every
//! call, which would break the steady-state zero-allocation invariant of
//! the codec sessions. [`stable_sort_desc_by`] is a bottom-up merge sort
//! over an index slice with a caller-owned auxiliary buffer; being a stable
//! sort under the same comparator, it produces **exactly** the permutation
//! `idx.sort_by(|&x, &y| key[y].partial_cmp(&key[x]).unwrap_or(Equal))`
//! would — the FWQ column order (and therefore the bitstream) is unchanged.

use std::cmp::Ordering;

/// Stable descending sort of `idx` by `key[i]` (ties keep their input
/// order), using `aux` as merge scratch. `aux` is resized to `idx.len()`;
/// with reserved capacity the call performs zero heap allocations.
pub fn stable_sort_desc_by(idx: &mut [usize], aux: &mut Vec<usize>, key: &[f32]) {
    let n = idx.len();
    if n < 2 {
        return;
    }
    aux.clear();
    aux.resize(n, 0);
    let mut width = 1usize;
    let mut in_idx = true; // which buffer currently holds the runs
    while width < n {
        if in_idx {
            merge_pass(idx, aux, width, key);
        } else {
            merge_pass(aux, idx, width, key);
        }
        in_idx = !in_idx;
        width *= 2;
    }
    if !in_idx {
        idx.copy_from_slice(aux);
    }
}

/// One bottom-up pass: merge adjacent sorted runs of `width` from `src`
/// into `dst`. Takes from the left run on ties (stability) and on
/// incomparable keys (matching `partial_cmp(..).unwrap_or(Equal)`).
fn merge_pass(src: &[usize], dst: &mut [usize], width: usize, key: &[f32]) {
    let n = src.len();
    let mut i = 0;
    while i < n {
        let mid = (i + width).min(n);
        let end = (i + 2 * width).min(n);
        let (mut a, mut b, mut k) = (i, mid, i);
        while a < mid && b < end {
            // descending: the right element goes first only when its key is
            // strictly greater
            let take_right =
                matches!(key[src[b]].partial_cmp(&key[src[a]]), Some(Ordering::Greater));
            if take_right {
                dst[k] = src[b];
                b += 1;
            } else {
                dst[k] = src[a];
                a += 1;
            }
            k += 1;
        }
        while a < mid {
            dst[k] = src[a];
            a += 1;
            k += 1;
        }
        while b < end {
            dst[k] = src[b];
            b += 1;
            k += 1;
        }
        i = end;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::Rng;

    fn std_sorted(key: &[f32]) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..key.len()).collect();
        idx.sort_by(|&x, &y| key[y].partial_cmp(&key[x]).unwrap_or(Ordering::Equal));
        idx
    }

    #[test]
    fn matches_std_stable_sort_including_ties() {
        let mut rng = Rng::new(61);
        let mut aux = Vec::new();
        for n in [0usize, 1, 2, 3, 7, 20, 64, 127, 1000] {
            // coarse quantization forces many ties (the zero-range columns
            // of real feature matrices)
            let key: Vec<f32> = (0..n).map(|_| (rng.gen_range(5) as f32) * 0.5).collect();
            let mut idx: Vec<usize> = (0..n).collect();
            stable_sort_desc_by(&mut idx, &mut aux, &key);
            assert_eq!(idx, std_sorted(&key), "n={n}");
        }
    }

    #[test]
    fn all_equal_keys_keep_input_order() {
        let key = vec![1.25f32; 33];
        let mut idx: Vec<usize> = (0..33).collect();
        let mut aux = Vec::new();
        stable_sort_desc_by(&mut idx, &mut aux, &key);
        assert_eq!(idx, (0..33).collect::<Vec<_>>());
    }

    #[test]
    fn reused_aux_is_allocation_compatible() {
        // same aux across differently-sized sorts: correctness must hold
        let mut aux = Vec::new();
        for n in [50usize, 10, 50, 3] {
            let key: Vec<f32> = (0..n).map(|i| ((i * 7) % 13) as f32).collect();
            let mut idx: Vec<usize> = (0..n).collect();
            stable_sort_desc_by(&mut idx, &mut aux, &key);
            assert_eq!(idx, std_sorted(&key), "n={n}");
        }
    }
}
