//! Dependency-free data-parallel runtime: a scoped-thread worker pool with
//! chunked self-scheduling (the registry has no rayon).
//!
//! Workers claim index ranges off a shared atomic cursor — a work-stealing
//! discipline in the "steal the next chunk" sense — so uneven per-item cost
//! (FWQ candidate plans, matmul row blocks) balances without static
//! partitioning. Threads are `std::thread::scope`d per call: borrows of the
//! caller's data need no `'static` bound and panics propagate at scope exit.
//!
//! Every helper is **output-deterministic in the thread count**: chunks are
//! identified by index and write disjoint, position-stable results, so
//! `threads = 1` and `threads = N` produce bit-identical outputs. The FWQ
//! encoder's byte-identical-bitstream guarantee rests on this.
//!
//! The pool size comes from [`set_threads`] (plumbed from `--threads` through
//! config/CLI/trainer); `0` means `available_parallelism`. Calls whose item
//! count doesn't cover `min_chunk` run inline on the caller's thread, so tiny
//! workloads never pay a spawn.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Configured worker count; 0 = auto (`available_parallelism`).
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Set the pool size for subsequent parallel calls (0 = auto). Process-wide.
pub fn set_threads(n: usize) {
    THREADS.store(n, Ordering::Relaxed);
}

/// Effective worker count for the current configuration.
pub fn threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1),
        n => n,
    }
}

/// Resolve a requested pool size for tools that accept both forms: a
/// `THREADS=<n>` environment variable wins over the given `--threads` flag
/// value (benches use this; 0 = auto either way).
pub fn thread_request(flag_value: usize) -> usize {
    std::env::var("THREADS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(flag_value)
}

/// Raw `*mut T` that may cross thread boundaries. Soundness is the caller's
/// obligation: every helper below hands each worker a disjoint index range,
/// so no two threads ever touch the same element.
struct SendPtr<T>(*mut T);
// unconditional (derives would bound on T: Clone, which the pointee of a
// raw pointer never needs)
impl<T> Clone for SendPtr<T> {
    fn clone(&self) -> Self {
        *self
    }
}
impl<T> Copy for SendPtr<T> {}
unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

/// Run `f(start, end)` over disjoint subranges covering `0..n` on the pool.
///
/// `min_chunk` bounds the scheduling granularity from below: no chunk is
/// smaller, and if `n <= min_chunk` the whole range runs inline (no spawn).
pub fn par_for<F>(n: usize, min_chunk: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    if n == 0 {
        return;
    }
    let min_chunk = min_chunk.max(1);
    let max_workers = (n + min_chunk - 1) / min_chunk;
    let t = threads().min(max_workers);
    if t <= 1 {
        f(0, n);
        return;
    }
    // ~4 chunks per worker so stragglers rebalance, never below min_chunk
    let chunk = ((n + 4 * t - 1) / (4 * t)).max(min_chunk);
    let cursor = AtomicUsize::new(0);
    let worker = || loop {
        let start = cursor.fetch_add(chunk, Ordering::Relaxed);
        if start >= n {
            break;
        }
        f(start, (start + chunk).min(n));
    };
    std::thread::scope(|s| {
        for _ in 1..t {
            s.spawn(worker);
        }
        worker(); // the caller's thread is worker 0
    });
}

/// Parallel `(0..n).map(f).collect()` with deterministic (index) ordering.
pub fn par_map_idx<R, F>(n: usize, min_chunk: usize, f: F) -> Vec<R>
where
    R: Send,
    F: Fn(usize) -> R + Sync,
{
    let mut out: Vec<Option<R>> = Vec::with_capacity(n);
    out.resize_with(n, || None);
    let slots = SendPtr(out.as_mut_ptr());
    par_for(n, min_chunk, |start, end| {
        for i in start..end {
            // SAFETY: par_for hands out disjoint [start, end) ranges, so
            // slot i is written by exactly one worker; `out` outlives the
            // scoped threads (par_for joins before returning).
            unsafe { *slots.0.add(i) = Some(f(i)) };
        }
    });
    out.into_iter()
        .map(|r| r.expect("par_for covered 0..n"))
        .collect()
}

/// Run `f(chunk_index, chunk)` over `chunk_len`-sized windows of `data`
/// (last chunk may be shorter), workers claiming chunks off a shared cursor.
/// The mutable-slice analogue of `chunks_mut` + pool dispatch.
pub fn par_chunks_mut<T, F>(data: &mut [T], chunk_len: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = data.len();
    if n == 0 {
        return;
    }
    let chunk_len = chunk_len.max(1);
    let nchunks = (n + chunk_len - 1) / chunk_len;
    let t = threads().min(nchunks);
    if t <= 1 {
        for (i, c) in data.chunks_mut(chunk_len).enumerate() {
            f(i, c);
        }
        return;
    }
    let base = SendPtr(data.as_mut_ptr());
    let cursor = AtomicUsize::new(0);
    let worker = || loop {
        let i = cursor.fetch_add(1, Ordering::Relaxed);
        if i >= nchunks {
            break;
        }
        let start = i * chunk_len;
        let len = chunk_len.min(n - start);
        // SAFETY: chunk i covers [start, start + len), disjoint across i;
        // `data` outlives the scope (joined before par_chunks_mut returns).
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.0.add(start), len) };
        f(i, chunk);
    };
    std::thread::scope(|s| {
        for _ in 1..t {
            s.spawn(worker);
        }
        worker();
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_for_covers_every_index_once() {
        let n = 1003;
        let hits: Vec<AtomicUsize> = (0..n).map(|_| AtomicUsize::new(0)).collect();
        par_for(n, 1, |start, end| {
            for h in &hits[start..end] {
                h.fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn par_map_idx_preserves_order() {
        let out = par_map_idx(517, 8, |i| i * i);
        assert_eq!(out.len(), 517);
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i * i);
        }
    }

    #[test]
    fn par_map_idx_empty_and_tiny() {
        assert_eq!(par_map_idx(0, 4, |i| i), Vec::<usize>::new());
        assert_eq!(par_map_idx(1, 64, |i| i + 7), vec![7]);
    }

    #[test]
    fn par_chunks_mut_writes_disjoint_chunks() {
        let mut data = vec![0usize; 777];
        par_chunks_mut(&mut data, 50, |i, chunk| {
            for v in chunk.iter_mut() {
                *v = i + 1;
            }
        });
        for (j, &v) in data.iter().enumerate() {
            assert_eq!(v, j / 50 + 1, "element {j}");
        }
    }

    #[test]
    fn thread_request_falls_back_to_flag() {
        // mutating the process env is unsound under the concurrent test
        // harness, so only the no-env fallback is asserted
        if std::env::var("THREADS").is_err() {
            assert_eq!(thread_request(5), 5);
            assert_eq!(thread_request(0), 0);
        }
    }

    // NOTE: tests that mutate the global pool size only ever assert on
    // *outputs* (which are thread-count invariant), never on `threads()`
    // itself — the harness runs tests concurrently and the global races.

    #[test]
    fn results_identical_across_thread_counts() {
        let run = || par_map_idx(256, 4, |i| (i as f64).sqrt().sin());
        set_threads(1);
        let a = run();
        set_threads(5);
        let b = run();
        set_threads(0);
        assert_eq!(a, b);
    }
}
