//! Deterministic PRNG substrate (the offline registry has no `rand` crate).
//!
//! `SplitMix64` is used for seeding/stream-splitting; `Xoshiro256ss`
//! (xoshiro256**) is the workhorse generator. Gaussian variates come from a
//! cached Box-Muller transform. Everything is reproducible from a single
//! `u64` seed, and `fork(stream)` derives independent streams for devices /
//! columns / experiments so results do not depend on scheduling order.

/// SplitMix64 — used to expand seeds and derive stream keys.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }
}

/// The full serializable state of an [`Rng`]: the xoshiro256** word state
/// plus the Box-Muller cache. Exporting/restoring the state lets the
/// Algorithm-1 shared encode stream travel across a transport boundary (the
/// PS hands the stream to the device for the step, the device hands the
/// advanced state back) without perturbing the sequence.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct RngState {
    pub s: [u64; 4],
    pub gauss: Option<f64>,
}

/// xoshiro256** — main generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
    gauss_cache: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        Self {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_cache: None,
        }
    }

    /// Snapshot the full generator state (wire-transferable).
    pub fn export_state(&self) -> RngState {
        RngState { s: self.s, gauss: self.gauss_cache }
    }

    /// Rebuild a generator that continues exactly from `st`.
    pub fn from_state(st: &RngState) -> Rng {
        Rng { s: st.s, gauss_cache: st.gauss }
    }

    /// Overwrite this generator's state with `st` (the PS re-adopting the
    /// stream a device advanced).
    pub fn restore_state(&mut self, st: &RngState) {
        self.s = st.s;
        self.gauss_cache = st.gauss;
    }

    /// Derive an independent stream (device id, experiment id, ...).
    pub fn fork(&mut self, stream: u64) -> Rng {
        let mut sm = SplitMix64::new(self.next_u64() ^ stream.wrapping_mul(0xA24BAED4963EE407));
        Rng {
            s: [sm.next_u64(), sm.next_u64(), sm.next_u64(), sm.next_u64()],
            gauss_cache: None,
        }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in [0, 1).
    #[inline]
    pub fn next_f32(&mut self) -> f32 {
        self.next_f64() as f32
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn gen_range(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire rejection-free-enough: 128-bit multiply-shift.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Bernoulli(p) — true with probability p.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.next_f64() < p
    }

    /// Standard normal via Box-Muller (cached pair).
    pub fn normal(&mut self) -> f64 {
        if let Some(z) = self.gauss_cache.take() {
            return z;
        }
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            let (s, c) = (2.0 * std::f64::consts::PI * u2).sin_cos();
            self.gauss_cache = Some(r * s);
            return r * c;
        }
    }

    pub fn normal_f32(&mut self, mean: f32, std: f32) -> f32 {
        mean + std * self.normal() as f32
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.gen_range(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        let k = k.min(n);
        for i in 0..k {
            let j = i + self.gen_range(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// Dirichlet(beta * 1_k) via Gamma(beta) marginals
    /// (Marsaglia-Tsang for beta >= 1; boost trick for beta < 1).
    pub fn dirichlet(&mut self, beta: f64, k: usize) -> Vec<f64> {
        let mut g: Vec<f64> = (0..k).map(|_| self.gamma(beta)).collect();
        let s: f64 = g.iter().sum();
        if s <= 0.0 {
            return vec![1.0 / k as f64; k];
        }
        for v in &mut g {
            *v /= s;
        }
        g
    }

    fn gamma(&mut self, alpha: f64) -> f64 {
        if alpha < 1.0 {
            // Gamma(a) = Gamma(a+1) * U^(1/a)
            let u = self.next_f64().max(f64::MIN_POSITIVE);
            return self.gamma(alpha + 1.0) * u.powf(1.0 / alpha);
        }
        let d = alpha - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.next_f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn state_roundtrip_continues_stream() {
        let mut a = Rng::new(42);
        for _ in 0..13 {
            a.next_u64();
        }
        a.normal(); // leaves a gauss cache entry behind
        let st = a.export_state();
        let mut b = Rng::from_state(&st);
        let mut c = Rng::new(1);
        c.restore_state(&st);
        for _ in 0..8 {
            let x = a.normal();
            assert_eq!(x.to_bits(), b.normal().to_bits());
            assert_eq!(x.to_bits(), c.normal().to_bits());
        }
        for _ in 0..8 {
            let x = a.next_u64();
            assert_eq!(x, b.next_u64());
            assert_eq!(x, c.next_u64());
        }
    }

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(7);
        let mut a = root.fork(0);
        let mut b = root.fork(1);
        let xa: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let xb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(xa, xb);
    }

    #[test]
    fn uniform_mean_and_bounds() {
        let mut r = Rng::new(3);
        let n = 20_000;
        let mut s = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            s += x;
        }
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(4);
        let n = 50_000;
        let (mut s, mut ss) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s += x;
            ss += x * x;
        }
        let mean = s / n as f64;
        let var = ss / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn bernoulli_frequency() {
        let mut r = Rng::new(5);
        for &p in &[0.1, 0.5, 0.9] {
            let hits = (0..20_000).filter(|_| r.bernoulli(p)).count();
            let f = hits as f64 / 20_000.0;
            assert!((f - p).abs() < 0.02, "p={p} f={f}");
        }
    }

    #[test]
    fn gen_range_covers_and_bounds() {
        let mut r = Rng::new(6);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.gen_range(10);
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&x| x));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(8);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(v, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(9);
        let s = r.sample_indices(50, 20);
        assert_eq!(s.len(), 20);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 20);
    }

    #[test]
    fn dirichlet_sums_to_one() {
        let mut r = Rng::new(10);
        for &beta in &[0.3, 1.0, 5.0] {
            let p = r.dirichlet(beta, 8);
            let s: f64 = p.iter().sum();
            assert!((s - 1.0).abs() < 1e-9);
            assert!(p.iter().all(|&x| x >= 0.0));
        }
    }

    #[test]
    fn dirichlet_low_beta_is_spiky() {
        let mut r = Rng::new(11);
        let mut max_lo = 0.0_f64;
        let mut max_hi = 0.0_f64;
        for _ in 0..50 {
            max_lo += r.dirichlet(0.1, 10).iter().cloned().fold(0.0, f64::max);
            max_hi += r.dirichlet(100.0, 10).iter().cloned().fold(0.0, f64::max);
        }
        assert!(max_lo > max_hi, "low beta should concentrate mass");
    }
}
