//! Crate-wide error substrate (the offline registry has no `anyhow`).
//!
//! A single string-backed error type plus the ergonomics the coordinator
//! layers actually use: `Result<T>`, a `Context` extension trait for
//! `Result`/`Option`, and the `err!` / `bail!` / `ensure!` macros. Contexts
//! chain outermost-first, so `{e}` prints `outer: inner: root cause` just
//! like `anyhow`'s `{e:#}`.

use std::fmt;

/// String-backed error with pre-rendered context chain.
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl Into<String>) -> Error {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Error {
        Error::msg(e.to_string())
    }
}

impl From<String> for Error {
    fn from(msg: String) -> Error {
        Error { msg }
    }
}

pub type Result<T, E = Error> = std::result::Result<T, E>;

/// `anyhow`-style context attachment for `Result` and `Option`.
pub trait Context<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T>;
    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T>;
}

impl<T, E: fmt::Display> Context<T> for std::result::Result<T, E> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{msg}: {e}")))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::msg(format!("{}: {e}", f())))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<D: fmt::Display>(self, msg: D) -> Result<T> {
        self.ok_or_else(|| Error::msg(msg.to_string()))
    }

    fn with_context<D: fmt::Display, F: FnOnce() -> D>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f().to_string()))
    }
}

/// Build an [`Error`] from a format string.
#[macro_export]
macro_rules! err {
    ($($arg:tt)*) => {
        $crate::util::error::Error::msg(format!($($arg)*))
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::err!($($arg)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fails() -> Result<()> {
        Err(Error::msg("root cause"))
    }

    #[test]
    fn context_chains_outermost_first() {
        let e = fails().context("outer").unwrap_err();
        assert_eq!(e.to_string(), "outer: root cause");
        let e = fails().with_context(|| format!("step {}", 3)).unwrap_err();
        assert_eq!(e.to_string(), "step 3: root cause");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        assert_eq!(v.context("missing").unwrap_err().to_string(), "missing");
        assert_eq!(Some(7u32).context("missing").unwrap(), 7);
    }

    #[test]
    fn macros_build_errors() {
        fn inner(x: usize) -> Result<usize> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(inner(3).unwrap(), 3);
        assert_eq!(inner(12).unwrap_err().to_string(), "x too big: 12");
        assert_eq!(inner(5).unwrap_err().to_string(), "five is right out");
        assert_eq!(err!("n={}", 2).to_string(), "n=2");
    }

    #[test]
    fn io_error_converts() {
        fn read() -> Result<Vec<u8>> {
            Ok(std::fs::read("/nonexistent-path-xyz")?)
        }
        assert!(read().is_err());
    }
}
