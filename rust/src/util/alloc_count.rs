//! Feature-gated counting global allocator (`--features alloc-count`).
//!
//! Wraps the system allocator and counts every allocation (alloc,
//! alloc_zeroed, realloc — frees are not counted) in a relaxed atomic. The
//! wire-path benches and the steady-state integration test use the delta of
//! [`allocations`] across a measured window to assert that warm codec
//! sessions perform **zero** heap allocations per encode/decode step.
//!
//! The counter is process-global: measure on a single thread with the
//! parallel pool pinned to one worker (`par::set_threads(1)`), or
//! concurrent work pollutes the count.

#[cfg(feature = "alloc-count")]
mod imp {
    use std::alloc::{GlobalAlloc, Layout, System};
    use std::sync::atomic::{AtomicU64, Ordering};

    static ALLOCS: AtomicU64 = AtomicU64::new(0);

    pub struct CountingAlloc;

    // SAFETY: defers to the system allocator for every operation; the
    // counter bump has no effect on layout or pointer validity.
    unsafe impl GlobalAlloc for CountingAlloc {
        unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc(layout)
        }

        unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.alloc_zeroed(layout)
        }

        unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
            ALLOCS.fetch_add(1, Ordering::Relaxed);
            System.realloc(ptr, layout, new_size)
        }

        unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
            System.dealloc(ptr, layout)
        }
    }

    #[global_allocator]
    static GLOBAL: CountingAlloc = CountingAlloc;

    pub fn count() -> u64 {
        ALLOCS.load(Ordering::Relaxed)
    }
}

/// Total heap allocations performed by this process so far, or `None` when
/// the crate was built without the `alloc-count` feature (callers skip
/// their assertions in that case).
pub fn allocations() -> Option<u64> {
    #[cfg(feature = "alloc-count")]
    {
        Some(imp::count())
    }
    #[cfg(not(feature = "alloc-count"))]
    {
        None
    }
}
