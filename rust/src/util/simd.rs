//! Runtime-dispatched SIMD kernels for the four hottest inner loops:
//! the MR-blocked matmul micro-kernels, per-row `column_stats`
//! accumulation, and the FWQ symbol quantize / dequantize columns.
//!
//! # The bit-exactness contract
//!
//! Every kernel here has two implementations — a portable scalar loop (the
//! reference) and an AVX2 path (`std::arch` x86_64 intrinsics behind
//! `is_x86_feature_detected!`) — and the two are **bit-identical**, not just
//! close. That holds because the vector paths obey two rules:
//!
//! 1. **Lanes run across independent outputs** (output columns, feature
//!    columns, symbols) — never across a reduction dimension. Each lane
//!    performs the scalar op sequence for its output verbatim, so no
//!    floating-point reassociation happens.
//! 2. **Separate mul + add, never FMA.** IEEE-754 add/sub/mul/div/convert
//!    are exactly rounded, so per-lane results match the scalar ops bit for
//!    bit; a fused multiply-add would not.
//!
//! The one non-trivial emulation is `f64::round` (half away from zero),
//! which AVX2 lacks: we round to nearest-even and apply a conditioned
//! half-step fix-up (see `fwq_quant_col`). Trajectory-level determinism is
//! enforced by `splitfc metrics-diff` over full training runs with
//! `SPLITFC_SIMD=off` vs `avx2` (ci.sh), plus the kernel-parity property
//! tests in `rust/tests/prop_simd.rs`.
//!
//! # Dispatch
//!
//! The mode resolves **once** (first use) from the `SPLITFC_SIMD` env knob
//! (`off` | `avx2` | anything-else ⇒ auto-detect), overridable via
//! [`force_mode`] / [`configure`] (the `--simd` CLI flag). [`kernels`]
//! returns a `'static` function-pointer table; hot loops hoist it out of
//! their inner loops. On non-x86_64 targets the scalar table is the only
//! one that exists.

use std::sync::atomic::{AtomicU8, Ordering};

use crate::compression::quant::{dequant, quant_code};

/// Which kernel table is active. The two modes produce bit-identical
/// results; the choice is purely about speed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SimdMode {
    /// Portable scalar kernels only.
    Off,
    /// AVX2 vector kernels (x86_64, runtime-detected).
    Avx2,
}

/// 0 = unresolved, 1 = off, 2 = avx2.
static MODE: AtomicU8 = AtomicU8::new(0);

fn detect() -> SimdMode {
    #[cfg(target_arch = "x86_64")]
    {
        if is_x86_feature_detected!("avx2") {
            return SimdMode::Avx2;
        }
    }
    SimdMode::Off
}

/// True when this host can run the AVX2 kernel table (runtime detection,
/// independent of the currently forced mode).
pub fn avx2_available() -> bool {
    detect() == SimdMode::Avx2
}

/// The active mode, resolved once: `SPLITFC_SIMD=off` pins the scalar
/// kernels, `=avx2` requests the vector table (degrading to `Off` when the
/// host lacks AVX2), anything else auto-detects.
pub fn mode() -> SimdMode {
    match MODE.load(Ordering::Relaxed) {
        1 => SimdMode::Off,
        2 => SimdMode::Avx2,
        _ => {
            let m = match std::env::var("SPLITFC_SIMD").ok().as_deref() {
                Some("off") => SimdMode::Off,
                _ => detect(),
            };
            force_mode(m);
            m
        }
    }
}

/// Pin the mode, overriding env/detection (tests, benches, `--simd`).
/// Callers must not force [`SimdMode::Avx2`] on hosts where
/// [`avx2_available`] is false.
pub fn force_mode(m: SimdMode) {
    MODE.store(
        match m {
            SimdMode::Off => 1,
            SimdMode::Avx2 => 2,
        },
        Ordering::Relaxed,
    );
}

/// Apply a `--simd off|avx2|auto` knob (config/CLI). `avx2` degrades to
/// the scalar table on hosts without AVX2 rather than erroring: the two
/// paths are bit-identical, so the request is about speed, not semantics.
pub fn configure(s: &str) -> Result<(), String> {
    match s {
        "off" => force_mode(SimdMode::Off),
        "avx2" | "auto" => force_mode(detect()),
        other => return Err(format!("unknown --simd mode {other:?} (expected off|avx2|auto)")),
    }
    Ok(())
}

/// A strided source column for the FWQ symbol kernels: element `r` lives
/// at `src[offset + r * stride]`, optionally scaled by a per-column factor
/// (the σ-normalization of `ColView::scaled`) — the f32 multiply happens
/// *before* widening to f64, exactly like `ColView::at`.
#[derive(Clone, Copy)]
pub struct ColSrc<'a> {
    pub src: &'a [f32],
    pub offset: usize,
    pub stride: usize,
    pub scale: Option<f32>,
}

impl ColSrc<'_> {
    #[inline]
    fn at(&self, r: usize) -> f32 {
        let x = self.src[self.offset + r * self.stride];
        match self.scale {
            Some(s) => x * s,
            None => x,
        }
    }
}

/// The dispatch table. All six kernels are leaf inner loops; the blocked /
/// tiled / threaded structure around them lives at the call sites and is
/// identical for both tables.
pub struct Kernels {
    /// matmul micro-kernel: `o{0..3}[j] += x[{0..3}] * bk[j]` over all `j`.
    pub mm4: fn(&mut [f32], &mut [f32], &mut [f32], &mut [f32], [f32; 4], &[f32]),
    /// single-row update: `o[j] += x * b[j]` (matmul/tn tail rows).
    pub axpy: fn(&mut [f32], f32, &[f32]),
    /// transposed-A micro-kernel:
    /// `o[j] += x[0]*b0[j] + x[1]*b1[j] + x[2]*b2[j] + x[3]*b3[j]`.
    pub tn4: fn(&mut [f32], [f32; 4], &[f32], &[f32], &[f32], &[f32]),
    /// one row of `column_stats`: per column `c`, fold `row[c]` into
    /// f32 min/max and f64 sum/sum-of-squares accumulators.
    pub stats_row: fn(&[f32], &mut [f32], &mut [f32], &mut [f64], &mut [f64]),
    /// FWQ symbol quantize of one strided column:
    /// `out[r] = quant_code(col.at(r) as f64, lo, span, q)` for `r < rows`.
    pub fwq_quant_col: fn(ColSrc, usize, f64, f64, u64, &mut [u64]),
    /// FWQ symbol dequantize into a strided destination column:
    /// `dst[offset + r*stride] = dequant(syms[r], lo, span, q)`.
    pub fwq_dequant_col: fn(&[u64], f64, f64, u64, &mut [f32], usize, usize),
}

/// The table for the active [`mode`]. Resolve once per blocked kernel, not
/// per element.
#[inline]
pub fn kernels() -> &'static Kernels {
    kernels_for(mode())
}

/// The table for an explicit mode (benches and parity tests compare the
/// two tables head to head without touching the global mode).
pub fn kernels_for(m: SimdMode) -> &'static Kernels {
    #[cfg(target_arch = "x86_64")]
    {
        if m == SimdMode::Avx2 {
            return &AVX2;
        }
    }
    let _ = m;
    &SCALAR
}

static SCALAR: Kernels = Kernels {
    mm4: mm4_scalar,
    axpy: axpy_scalar,
    tn4: tn4_scalar,
    stats_row: stats_row_scalar,
    fwq_quant_col: fwq_quant_col_scalar,
    fwq_dequant_col: fwq_dequant_col_scalar,
};

// ---- scalar kernels: the portable reference op sequences ----

fn mm4_scalar(o0: &mut [f32], o1: &mut [f32], o2: &mut [f32], o3: &mut [f32], x: [f32; 4], bk: &[f32]) {
    for (j, &b) in bk.iter().enumerate() {
        o0[j] += x[0] * b;
        o1[j] += x[1] * b;
        o2[j] += x[2] * b;
        o3[j] += x[3] * b;
    }
}

fn axpy_scalar(o: &mut [f32], x: f32, b: &[f32]) {
    for (o, &bj) in o.iter_mut().zip(b) {
        *o += x * bj;
    }
}

fn tn4_scalar(o: &mut [f32], x: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
    for j in 0..o.len() {
        o[j] += x[0] * b0[j] + x[1] * b1[j] + x[2] * b2[j] + x[3] * b3[j];
    }
}

fn stats_row_scalar(row: &[f32], mn: &mut [f32], mx: &mut [f32], sum: &mut [f64], sumsq: &mut [f64]) {
    for (c, &v) in row.iter().enumerate() {
        if v < mn[c] {
            mn[c] = v;
        }
        if v > mx[c] {
            mx[c] = v;
        }
        sum[c] += v as f64;
        sumsq[c] += (v as f64) * (v as f64);
    }
}

fn fwq_quant_col_scalar(col: ColSrc, rows: usize, lo: f64, span: f64, q: u64, out: &mut [u64]) {
    for (r, o) in out[..rows].iter_mut().enumerate() {
        *o = quant_code(col.at(r) as f64, lo, span, q);
    }
}

fn fwq_dequant_col_scalar(
    syms: &[u64],
    lo: f64,
    span: f64,
    q: u64,
    dst: &mut [f32],
    offset: usize,
    stride: usize,
) {
    for (r, &s) in syms.iter().enumerate() {
        dst[offset + r * stride] = dequant(s, lo, span, q);
    }
}

// ---- AVX2 kernels (x86_64 only; selected strictly after runtime detection) ----

#[cfg(target_arch = "x86_64")]
static AVX2: Kernels = Kernels {
    mm4: avx2::mm4,
    axpy: avx2::axpy,
    tn4: avx2::tn4,
    stats_row: avx2::stats_row,
    fwq_quant_col: avx2::fwq_quant_col,
    fwq_dequant_col: avx2::fwq_dequant_col,
};

#[cfg(target_arch = "x86_64")]
mod avx2 {
    use super::ColSrc;
    use std::arch::x86_64::*;

    // Safe shims: `#[target_feature]` fns cannot coerce to fn pointers, so
    // each table entry is a plain fn that enters the vectorized body.
    // SAFETY (all shims): the AVX2 table is only reachable through
    // `kernels_for(SimdMode::Avx2)`, which callers select after
    // `is_x86_feature_detected!("avx2")` (see `mode` / `avx2_available`).

    pub(super) fn mm4(o0: &mut [f32], o1: &mut [f32], o2: &mut [f32], o3: &mut [f32], x: [f32; 4], bk: &[f32]) {
        unsafe { mm4_impl(o0, o1, o2, o3, x, bk) }
    }

    pub(super) fn axpy(o: &mut [f32], x: f32, b: &[f32]) {
        unsafe { axpy_impl(o, x, b) }
    }

    pub(super) fn tn4(o: &mut [f32], x: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
        unsafe { tn4_impl(o, x, b0, b1, b2, b3) }
    }

    pub(super) fn stats_row(row: &[f32], mn: &mut [f32], mx: &mut [f32], sum: &mut [f64], sumsq: &mut [f64]) {
        unsafe { stats_row_impl(row, mn, mx, sum, sumsq) }
    }

    pub(super) fn fwq_quant_col(col: ColSrc, rows: usize, lo: f64, span: f64, q: u64, out: &mut [u64]) {
        unsafe { fwq_quant_col_impl(col, rows, lo, span, q, out) }
    }

    pub(super) fn fwq_dequant_col(
        syms: &[u64],
        lo: f64,
        span: f64,
        q: u64,
        dst: &mut [f32],
        offset: usize,
        stride: usize,
    ) {
        unsafe { fwq_dequant_col_impl(syms, lo, span, q, dst, offset, stride) }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn mm4_impl(o0: &mut [f32], o1: &mut [f32], o2: &mut [f32], o3: &mut [f32], x: [f32; 4], bk: &[f32]) {
        let p = bk.len();
        let x0 = _mm256_set1_ps(x[0]);
        let x1 = _mm256_set1_ps(x[1]);
        let x2 = _mm256_set1_ps(x[2]);
        let x3 = _mm256_set1_ps(x[3]);
        let mut j = 0usize;
        // lanes = output columns; per lane this is exactly `o += x * b`
        // (separate mul + add: both exactly rounded, so bit-equal to scalar)
        while j + 8 <= p {
            let b = _mm256_loadu_ps(bk.as_ptr().add(j));
            _mm256_storeu_ps(
                o0.as_mut_ptr().add(j),
                _mm256_add_ps(_mm256_loadu_ps(o0.as_ptr().add(j)), _mm256_mul_ps(x0, b)),
            );
            _mm256_storeu_ps(
                o1.as_mut_ptr().add(j),
                _mm256_add_ps(_mm256_loadu_ps(o1.as_ptr().add(j)), _mm256_mul_ps(x1, b)),
            );
            _mm256_storeu_ps(
                o2.as_mut_ptr().add(j),
                _mm256_add_ps(_mm256_loadu_ps(o2.as_ptr().add(j)), _mm256_mul_ps(x2, b)),
            );
            _mm256_storeu_ps(
                o3.as_mut_ptr().add(j),
                _mm256_add_ps(_mm256_loadu_ps(o3.as_ptr().add(j)), _mm256_mul_ps(x3, b)),
            );
            j += 8;
        }
        while j < p {
            let b = bk[j];
            o0[j] += x[0] * b;
            o1[j] += x[1] * b;
            o2[j] += x[2] * b;
            o3[j] += x[3] * b;
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn axpy_impl(o: &mut [f32], x: f32, b: &[f32]) {
        let p = o.len().min(b.len());
        let xv = _mm256_set1_ps(x);
        let mut j = 0usize;
        while j + 8 <= p {
            let bv = _mm256_loadu_ps(b.as_ptr().add(j));
            _mm256_storeu_ps(
                o.as_mut_ptr().add(j),
                _mm256_add_ps(_mm256_loadu_ps(o.as_ptr().add(j)), _mm256_mul_ps(xv, bv)),
            );
            j += 8;
        }
        while j < p {
            o[j] += x * b[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn tn4_impl(o: &mut [f32], x: [f32; 4], b0: &[f32], b1: &[f32], b2: &[f32], b3: &[f32]) {
        let p = o.len();
        let x0 = _mm256_set1_ps(x[0]);
        let x1 = _mm256_set1_ps(x[1]);
        let x2 = _mm256_set1_ps(x[2]);
        let x3 = _mm256_set1_ps(x[3]);
        let mut j = 0usize;
        // per lane: o + (((x0*b0 + x1*b1) + x2*b2) + x3*b3) — the scalar
        // expression's exact association
        while j + 8 <= p {
            let t = _mm256_add_ps(
                _mm256_mul_ps(x0, _mm256_loadu_ps(b0.as_ptr().add(j))),
                _mm256_mul_ps(x1, _mm256_loadu_ps(b1.as_ptr().add(j))),
            );
            let t = _mm256_add_ps(t, _mm256_mul_ps(x2, _mm256_loadu_ps(b2.as_ptr().add(j))));
            let t = _mm256_add_ps(t, _mm256_mul_ps(x3, _mm256_loadu_ps(b3.as_ptr().add(j))));
            _mm256_storeu_ps(
                o.as_mut_ptr().add(j),
                _mm256_add_ps(_mm256_loadu_ps(o.as_ptr().add(j)), t),
            );
            j += 8;
        }
        while j < p {
            o[j] += x[0] * b0[j] + x[1] * b1[j] + x[2] * b2[j] + x[3] * b3[j];
            j += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn stats_row_impl(row: &[f32], mn: &mut [f32], mx: &mut [f32], sum: &mut [f64], sumsq: &mut [f64]) {
        let d = row.len();
        let mut c = 0usize;
        // MINPS/MAXPS return the second operand on NaN or equality, which is
        // exactly the scalar `if v < mn { mn = v }` / `if v > mx { mx = v }`
        // keep-old behavior (including -0.0 vs 0.0 and NaN inputs)
        while c + 4 <= d {
            let v = _mm_loadu_ps(row.as_ptr().add(c));
            _mm_storeu_ps(mn.as_mut_ptr().add(c), _mm_min_ps(v, _mm_loadu_ps(mn.as_ptr().add(c))));
            _mm_storeu_ps(mx.as_mut_ptr().add(c), _mm_max_ps(v, _mm_loadu_ps(mx.as_ptr().add(c))));
            let vd = _mm256_cvtps_pd(v);
            _mm256_storeu_pd(
                sum.as_mut_ptr().add(c),
                _mm256_add_pd(_mm256_loadu_pd(sum.as_ptr().add(c)), vd),
            );
            _mm256_storeu_pd(
                sumsq.as_mut_ptr().add(c),
                _mm256_add_pd(_mm256_loadu_pd(sumsq.as_ptr().add(c)), _mm256_mul_pd(vd, vd)),
            );
            c += 4;
        }
        while c < d {
            let v = row[c];
            if v < mn[c] {
                mn[c] = v;
            }
            if v > mx[c] {
                mx[c] = v;
            }
            sum[c] += v as f64;
            sumsq[c] += (v as f64) * (v as f64);
            c += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn fwq_quant_col_impl(col: ColSrc, rows: usize, lo: f64, span: f64, q: u64, out: &mut [u64]) {
        if span <= 0.0 || q < 2 {
            for o in out[..rows].iter_mut() {
                *o = 0;
            }
            return;
        }
        if q - 1 > i32::MAX as u64 {
            // cvttpd_epi32 cannot produce codes past i32::MAX; level counts
            // this large never occur under the 2^16/2^17 clamps, but stay
            // correct anyway
            super::fwq_quant_col_scalar(col, rows, lo, span, q, out);
            return;
        }
        let s = col.scale.unwrap_or(1.0);
        let vs = _mm_set1_ps(s);
        let vlo = _mm256_set1_pd(lo);
        let vspan = _mm256_set1_pd(span);
        let vqm1 = _mm256_set1_pd((q - 1) as f64);
        let half = _mm256_set1_pd(0.5);
        let nhalf = _mm256_set1_pd(-0.5);
        let one = _mm256_set1_pd(1.0);
        let zero = _mm256_setzero_pd();
        let mut r = 0usize;
        while r + 4 <= rows {
            let i = col.offset + r * col.stride;
            let v = _mm_set_ps(
                col.src[i + 3 * col.stride],
                col.src[i + 2 * col.stride],
                col.src[i + col.stride],
                col.src[i],
            );
            // σ-scale in f32 before widening, exactly like `ColView::at`
            let v = if col.scale.is_some() { _mm_mul_ps(v, vs) } else { v };
            // t = (v - lo) / span * (q - 1): the scalar op order exactly
            let t = _mm256_cvtps_pd(v);
            let t = _mm256_mul_pd(_mm256_div_pd(_mm256_sub_pd(t, vlo), vspan), vqm1);
            // f64::round (half AWAY from zero) from nearest-even + fix-up.
            // d = t - rr is exact (Sterbenz for |t| >= 1, exact below 1,
            // integral at/above 2^53), and the fix-up must be conditioned on
            // the sign of t: at t=1.5 nearest-even already gives 2 (d=-0.5)
            // and must NOT be decremented.
            let rr = _mm256_round_pd::<{ _MM_FROUND_TO_NEAREST_INT | _MM_FROUND_NO_EXC }>(t);
            let d = _mm256_sub_pd(t, rr);
            let up = _mm256_and_pd(
                _mm256_cmp_pd::<_CMP_EQ_OQ>(d, half),
                _mm256_cmp_pd::<_CMP_GT_OQ>(t, zero),
            );
            let dn = _mm256_and_pd(
                _mm256_cmp_pd::<_CMP_EQ_OQ>(d, nhalf),
                _mm256_cmp_pd::<_CMP_LT_OQ>(t, zero),
            );
            let rr = _mm256_add_pd(rr, _mm256_and_pd(up, one));
            let rr = _mm256_sub_pd(rr, _mm256_and_pd(dn, one));
            // clamp in the float domain: maxpd(rr, 0) sends NaN to 0 exactly
            // like `f64::max(NaN, 0.0)`, and min against q-1 matches the
            // scalar `(t.max(0.0) as u64).min(q-1)` saturation for any
            // overflow-range value; the clamped result is integral and
            // <= i32::MAX, so truncating conversion is exact
            let rr = _mm256_min_pd(_mm256_max_pd(rr, zero), vqm1);
            let c = _mm256_cvttpd_epi32(rr);
            let mut lanes = [0i32; 4];
            _mm_storeu_si128(lanes.as_mut_ptr() as *mut __m128i, c);
            out[r] = lanes[0] as u64;
            out[r + 1] = lanes[1] as u64;
            out[r + 2] = lanes[2] as u64;
            out[r + 3] = lanes[3] as u64;
            r += 4;
        }
        while r < rows {
            out[r] = crate::compression::quant::quant_code(col.at(r) as f64, lo, span, q);
            r += 1;
        }
    }

    #[target_feature(enable = "avx2")]
    unsafe fn fwq_dequant_col_impl(
        syms: &[u64],
        lo: f64,
        span: f64,
        q: u64,
        dst: &mut [f32],
        offset: usize,
        stride: usize,
    ) {
        let n = syms.len();
        if q < 2 || span <= 0.0 {
            let v = lo as f32;
            let mut r = 0usize;
            while r < n {
                dst[offset + r * stride] = v;
                r += 1;
            }
            return;
        }
        if q - 1 > i32::MAX as u64 {
            super::fwq_dequant_col_scalar(syms, lo, span, q, dst, offset, stride);
            return;
        }
        let vlo = _mm256_set1_pd(lo);
        let vspan = _mm256_set1_pd(span);
        let vqm1 = _mm256_set1_pd((q - 1) as f64);
        let mut r = 0usize;
        while r + 4 <= n {
            // codes < q <= 2^31 so the i32 narrowing is lossless
            let c = _mm_set_epi32(
                syms[r + 3] as i32,
                syms[r + 2] as i32,
                syms[r + 1] as i32,
                syms[r] as i32,
            );
            let cd = _mm256_cvtepi32_pd(c);
            // lo + code * span / (q - 1): the scalar op order exactly;
            // cvtpd_ps rounds to nearest like `as f32`
            let val = _mm256_add_pd(vlo, _mm256_div_pd(_mm256_mul_pd(cd, vspan), vqm1));
            let vf = _mm256_cvtpd_ps(val);
            let mut lanes = [0f32; 4];
            _mm_storeu_ps(lanes.as_mut_ptr(), vf);
            let i = offset + r * stride;
            dst[i] = lanes[0];
            dst[i + stride] = lanes[1];
            dst[i + 2 * stride] = lanes[2];
            dst[i + 3 * stride] = lanes[3];
            r += 4;
        }
        while r < n {
            dst[offset + r * stride] = crate::compression::quant::dequant(syms[r], lo, span, q);
            r += 1;
        }
    }
}
