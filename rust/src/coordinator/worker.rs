//! The device-side role of the split protocol.
//!
//! One `DeviceWorker` per client k owns everything local to that device: its
//! minibatch loader over the device's partition, its own RNG fork, its own
//! uplink/downlink [`Link`] (per-device accounting, aggregated by
//! [`LinkReport::aggregate`]), and its **codec session** — a
//! [`Codec`] instance built from the configured spec through the registry,
//! which also owns any cross-round compression state (e.g. the
//! error-feedback residual of `splitfc[...,ef]`). A worker runs the device
//! half of a protocol step — forward, σ statistics (only when the codec's
//! [`Codec::requirements`] ask for them), uplink encode, downlink decode
//! with the chain-rule rescale δ_j/(1 - p_j), and the device backward pass —
//! and talks to the [`ParameterServer`] only through its thread-safe
//! methods, so K workers can execute steps concurrently under the
//! scheduler's staleness window.

use std::time::Instant;

use crate::compression::{Codec, CodecParams, EncodedDownlink, GradMask, Reclaim, SigmaStats};
use crate::coordinator::metrics::StepRecord;
use crate::coordinator::server::ParameterServer;
use crate::data::{Dataset, MiniBatchLoader};
use crate::model::PresetInfo;
use crate::tensor::Matrix;
use crate::transport::{Direction, Link, LinkReport};
use crate::util::error::Result;
use crate::util::Rng;

/// Where a step draws its uplink-encode randomness.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RngMode {
    /// The PS-held Algorithm-1 stream, consumed in global step order.
    /// Requires strict (staleness = 0) scheduling; reproduces the
    /// monolithic round-robin trainer's trajectory exactly.
    SharedSequential,
    /// This worker's own deterministic fork — the concurrent (staleness
    /// > 0) mode, where a shared stream would be consumed in racy order.
    PerDevice,
}

pub struct DeviceWorker {
    pub device: usize,
    loader: MiniBatchLoader,
    rng: Rng,
    link: Link,
    /// this device's codec session (uplink encode + downlink decode state)
    codec: Box<dyn Codec>,
    up_params: CodecParams,
    down_params: CodecParams,
    batch: usize,
    classes: usize,
    /// from `codec.requirements()`: run the feature_stats kernel per step?
    use_sigma: bool,
    /// reusable w_d snapshot buffer (filled by the PS each step)
    wd_snapshot: Option<crate::model::ParamSet>,
}

impl DeviceWorker {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        device: usize,
        loader: MiniBatchLoader,
        rng: Rng,
        link: Link,
        codec: Box<dyn Codec>,
        preset: &PresetInfo,
        up_params: CodecParams,
        down_params: CodecParams,
    ) -> DeviceWorker {
        DeviceWorker {
            device,
            loader,
            rng,
            link,
            up_params,
            down_params,
            batch: preset.batch,
            classes: preset.classes,
            use_sigma: codec.requirements().needs_sigma,
            codec,
            wd_snapshot: None,
        }
    }

    /// This device's link accounting (uplink/downlink bits, frames, modeled
    /// transfer time).
    pub fn link_report(&self) -> LinkReport {
        self.link.report()
    }

    /// This device's codec session (capability report, canonical name).
    pub fn codec(&self) -> &dyn Codec {
        self.codec.as_ref()
    }

    /// Run one full protocol step (t, k) for this device against the PS.
    ///
    /// `global_step` is the step's position in the strict round-robin order
    /// (the scheduler's first-step offset + (t-1)·K + k); it tags the
    /// metrics record so concurrent traces stay attributable.
    pub fn run_step(
        &mut self,
        round: usize,
        global_step: usize,
        server: &ParameterServer,
        train: &Dataset,
        rng_mode: RngMode,
    ) -> Result<StepRecord> {
        let t_step = Instant::now();
        // backend time spent on this worker's thread (device fwd/stats/bwd);
        // the PS half's time is returned by process_uplink
        let mut device_exec_s = 0.0;

        // 1. minibatch + device forward on a w_d snapshot (eq. 3); under
        //    staleness > 0 the snapshot may lag in-flight updates
        let (x, y, _) = self.loader.next_batch(train, self.classes);
        server.snapshot_device_params_into(&mut self.wd_snapshot);
        let wd = self.wd_snapshot.as_ref().expect("snapshot populated");
        let t0 = Instant::now();
        let f = server.backend().device_fwd(wd, &x)?;
        device_exec_s += t0.elapsed().as_secs_f64();

        // 2. feature statistics (σ of the channel-normalized columns,
        //    eq. 10) — only when the codec's capability report asks for them
        let stats: Option<SigmaStats> = if self.use_sigma {
            let t0 = Instant::now();
            let s = server.backend().feature_stats(&f)?;
            device_exec_s += t0.elapsed().as_secs_f64();
            Some(SigmaStats::new(s))
        } else {
            None
        };

        // 3. uplink compression + transmit over this device's link
        let enc = match rng_mode {
            RngMode::SharedSequential => server.with_rng(|rng| {
                self.codec.encode_uplink(&f, stats.as_ref(), &self.up_params, rng)
            })?,
            RngMode::PerDevice => {
                self.codec.encode_uplink(&f, stats.as_ref(), &self.up_params, &mut self.rng)?
            }
        };
        self.link.transmit(Direction::Uplink, &enc.frame);

        // 4./5. the PS half: server forward/backward + w_s update (one PS
        //       critical section), then the mask-coupled downlink encode.
        //       The PS execution time counts into this step's exec_s (the
        //       monolithic trainer's per-step accounting) but reaches the
        //       run total through process_uplink itself.
        let (out, server_dt) = server.process_uplink(&enc.f_hat, &y)?;
        let dn = self.codec.encode_downlink(&out.g, &enc.mask, &self.down_params)?;
        self.link.transmit(Direction::Downlink, &dn.frame);

        // 6. downlink decode + chain-rule scale δ_j/(1-p_j), device backward
        //    (eq. 7 backward path); the PS-held optimizer applies the update
        let EncodedDownlink { frame: dn_frame, mut g_hat, nominal_bits: down_nominal } = dn;
        if let GradMask::Columns { kept, scale } = &enc.mask {
            g_hat.scale_cols(kept, scale);
        }
        let t0 = Instant::now();
        let grad_wd = server.backend().device_bwd(wd, &x, &g_hat)?;
        device_exec_s += t0.elapsed().as_secs_f64();
        server.apply_device_grad(self.device, &grad_wd);
        server.add_exec(device_exec_s);

        let rec = StepRecord {
            round,
            device: self.device,
            global_step,
            loss: out.loss,
            train_acc: out.correct / self.batch as f32,
            up_bits: enc.frame.payload_bits,
            down_bits: dn_frame.payload_bits,
            up_nominal: enc.nominal_bits,
            down_nominal,
            step_s: t_step.elapsed().as_secs_f64(),
            // per-step execution time spans both halves, like the monolith's
            exec_s: device_exec_s + server_dt,
        };
        // hand the round's buffers back to the codec session — arena-backed
        // codecs reuse them next step (steady-state zero allocation)
        self.codec.reclaim(Reclaim::Frame(dn_frame));
        self.codec.reclaim(Reclaim::Grad(g_hat));
        self.codec.reclaim(Reclaim::Uplink(enc));
        server.write_metrics(&rec.to_json());
        Ok(rec)
    }

    /// The features + σ stats of one fresh batch (Fig.-1 dispersion bench).
    pub fn probe_features(
        &mut self,
        server: &ParameterServer,
        train: &Dataset,
    ) -> Result<(Matrix, Vec<f32>)> {
        let (x, _, _) = self.loader.next_batch(train, self.classes);
        server.snapshot_device_params_into(&mut self.wd_snapshot);
        let wd = self.wd_snapshot.as_ref().expect("snapshot populated");
        let t0 = Instant::now();
        let f = server.backend().device_fwd(wd, &x)?;
        let sigma = server.backend().feature_stats(&f)?;
        server.add_exec(t0.elapsed().as_secs_f64());
        Ok((f, sigma))
    }
}
