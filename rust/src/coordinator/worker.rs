//! The device-side role of the split protocol.
//!
//! One `DeviceWorker` per client k owns everything local to that device: its
//! minibatch loader over the device's partition, its own RNG fork, its own
//! uplink/downlink [`Link`] (per-device accounting, aggregated by
//! [`LinkReport::aggregate`]), its **codec session** — a [`Codec`] instance
//! built from the configured spec through the registry, which also owns any
//! cross-round compression state (e.g. the error-feedback residual of
//! `splitfc[...,ef]`) — and, since the transport refactor, a
//! [`Connection`] to the parameter server. The worker holds **no**
//! `ParameterServer` reference: every exchange is an explicit protocol
//! message (`StepStart`/`Uplink`/`Commit` and their replies), identical
//! over in-process channels and TCP sockets.
//!
//! A step is three request/reply pairs. On the shared-stream path
//! (staleness 0), `StepGo` carries the PS's Algorithm-1 RNG state; the
//! worker encodes with it and hands the advanced state back in `Uplink`,
//! so the PS-held stream advances exactly as if the encode had run inside
//! the PS — the monolithic trainer's trajectory, bit for bit.
//!
//! **Reconnect.** When a request fails with a transport io error on a
//! reconnectable connection, the worker re-dials, replays the handshake,
//! and resends *the same message* — never re-encoding, so the bytes the PS
//! sees are independent of where the cut happened. The PS-side courier
//! deduplicates; protocol rejections (`Abort`) are never retried.

use std::sync::Arc;
use std::time::{Duration, Instant};

use crate::checkpoint::DeviceSnap;
use crate::compression::{Codec, CodecParams, GradMask, Reclaim, SigmaStats};
use crate::coordinator::metrics::StepRecord;
use crate::coordinator::protocol::model_sync_frame;
use crate::data::{Dataset, MiniBatchLoader};
use crate::model::{f32_from_le_bytes, ParamSet, PresetInfo};
use crate::runtime::Backend;
use crate::scenario::DeviceScript;
use crate::tensor::Matrix;
use crate::transport::wire::{Frame, FrameKind};
use crate::transport::{tcp, Connection, Direction, Link, LinkReport, Msg, StepReport};
use crate::util::error::Result;
use crate::util::Rng;

/// Seeded, capped exponential backoff for transport-fault retries: the
/// delay before retry `n` is `min(cap, base·2^(n-1))`, jittered by a
/// uniform factor in `[0.5, 1.5)` drawn from a dedicated RNG stream (so
/// retry timing never perturbs the training trajectory), and a request is
/// abandoned once its cumulative backoff sleep exceeds `deadline`.
#[derive(Debug, Clone, Copy)]
pub struct RetryPolicy {
    pub base: Duration,
    pub cap: Duration,
    /// overall per-request budget of backoff sleep before giving up
    pub deadline: Duration,
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy::new(10, 500, 15.0)
    }
}

impl RetryPolicy {
    pub fn new(base_ms: u64, cap_ms: u64, deadline_s: f64) -> RetryPolicy {
        let base = Duration::from_millis(base_ms.max(1));
        RetryPolicy {
            base,
            cap: Duration::from_millis(cap_ms).max(base),
            deadline: Duration::from_secs_f64(deadline_s.max(0.0)),
        }
    }

    /// Jittered delay before 1-based retry `attempt`.
    pub fn delay(&self, attempt: u32, rng: &mut Rng) -> Duration {
        let exp = attempt.saturating_sub(1).min(20);
        let nominal = self.base.as_secs_f64() * (1u64 << exp) as f64;
        let jitter = 0.5 + rng.next_f64();
        Duration::from_secs_f64(nominal.min(self.cap.as_secs_f64()) * jitter)
    }
}

pub struct DeviceWorker {
    pub device: usize,
    loader: MiniBatchLoader,
    rng: Rng,
    link: Link,
    /// this device's codec session (uplink encode + downlink decode state)
    codec: Box<dyn Codec>,
    up_params: CodecParams,
    down_params: CodecParams,
    batch: usize,
    classes: usize,
    /// from `codec.requirements()`: run the feature_stats kernel per step?
    use_sigma: bool,
    /// the device's local execution engine (shared instance in-process;
    /// a remote device process builds its own)
    backend: Arc<dyn Backend>,
    /// message pipe to the PS (in-process channel or TCP socket)
    conn: Box<dyn Connection>,
    /// reusable decode target for `ModelSync` w_d frames
    wd_set: Option<ParamSet>,
    /// handshake done on this connection?
    greeted: bool,
    /// backoff schedule for transport-fault retries
    retry: RetryPolicy,
    /// dedicated jitter stream — never the trajectory-critical `rng`
    backoff_rng: Rng,
    /// totals surfaced through `link_report()`
    retry_attempts: u64,
    backoff_s: f64,
    /// this device's compiled failure script (calm by default)
    script: DeviceScript,
    /// protocol steps started on this worker (1-based; drives `cut_steps`)
    steps_run: u64,
    /// snapshot cadence the PS announced in the handshake (0 = none); when
    /// set, every `Commit` carries this worker's encoded [`DeviceSnap`]
    ckpt_every: usize,
    /// schedule round the run starts at (1 fresh, checkpoint round + 1)
    first_round: usize,
    /// a restored state blob is applied only at the *first* handshake —
    /// reconnect re-greets must not rewind a worker that has advanced
    restored: bool,
}

impl DeviceWorker {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        device: usize,
        loader: MiniBatchLoader,
        rng: Rng,
        link: Link,
        codec: Box<dyn Codec>,
        preset: &PresetInfo,
        up_params: CodecParams,
        down_params: CodecParams,
        backend: Arc<dyn Backend>,
        conn: Box<dyn Connection>,
    ) -> DeviceWorker {
        DeviceWorker {
            device,
            loader,
            rng,
            link,
            up_params,
            down_params,
            batch: preset.batch,
            classes: preset.classes,
            use_sigma: codec.requirements().needs_sigma,
            codec,
            backend,
            conn,
            wd_set: None,
            greeted: false,
            retry: RetryPolicy::default(),
            backoff_rng: Rng::new(0xBAC0_FF5E ^ device as u64),
            retry_attempts: 0,
            backoff_s: 0.0,
            script: DeviceScript::default(),
            steps_run: 0,
            ckpt_every: 0,
            first_round: 1,
            restored: false,
        }
    }

    /// Schedule round the run starts at, learned from the handshake (1
    /// unless the PS resumed from a checkpoint).
    pub fn first_round(&self) -> usize {
        self.first_round
    }

    /// Everything local to this device that a checkpoint must capture,
    /// encoded as a [`DeviceSnap`] blob: both RNG streams, the loader
    /// position, the codec session (e.g. the error-feedback residual),
    /// and the step counter driving scenario cuts.
    pub fn export_state(&self) -> Vec<u8> {
        DeviceSnap {
            rng: self.rng.export_state(),
            backoff_rng: self.backoff_rng.export_state(),
            loader: self.loader.export_state(),
            codec: self.codec.export_session(),
            steps_run: self.steps_run,
        }
        .encode()
    }

    /// Restore this worker from a [`DeviceSnap`] blob (the handshake's
    /// `state` field). Validates fully before mutating anything.
    fn apply_state(&mut self, blob: &[u8]) -> Result<()> {
        let snap = DeviceSnap::decode(blob)?;
        let loader = MiniBatchLoader::from_state(&snap.loader)?;
        self.codec.restore_session(&snap.codec)?;
        self.loader = loader;
        self.rng = Rng::from_state(&snap.rng);
        self.backoff_rng = Rng::from_state(&snap.backoff_rng);
        self.steps_run = snap.steps_run;
        Ok(())
    }

    /// This device's link accounting (uplink/downlink bits, frames, modeled
    /// transfer time), plus the transport-fault retry counters.
    pub fn link_report(&self) -> LinkReport {
        let mut rep = self.link.report();
        rep.retry_attempts = self.retry_attempts;
        rep.backoff_s = self.backoff_s;
        rep
    }

    /// Install the backoff schedule; the jitter stream is forked from
    /// `seed` per device so fleets don't retry in lockstep.
    pub fn set_retry_policy(&mut self, policy: RetryPolicy, seed: u64) {
        self.retry = policy;
        self.backoff_rng =
            Rng::new(seed ^ 0xBAC0_FF5E ^ (self.device as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15));
    }

    /// Install this device's compiled failure script (slowdowns + cuts).
    pub fn set_script(&mut self, script: DeviceScript) {
        self.script = script;
    }

    pub fn script(&self) -> &DeviceScript {
        &self.script
    }

    /// Bound how long any single reply may be awaited (0/None = forever).
    /// Off by default: with strict round-robin gating a device may
    /// legitimately block in `StepStart` while its peers run.
    pub fn set_rpc_deadline(&mut self, deadline: Option<Duration>) {
        self.conn.set_recv_deadline(deadline);
    }

    /// This device's codec session (capability report, canonical name).
    pub fn codec(&self) -> &dyn Codec {
        self.codec.as_ref()
    }

    /// Handshake: identify this device and its codec session; the PS
    /// rejects a codec id/version mismatch before any step runs.
    fn hello(&mut self) -> Result<()> {
        self.conn.send(Msg::Hello {
            device: self.device as u32,
            codec_id: self.codec.wire_id(),
            codec_version: self.codec.wire_version(),
        })?;
        match self.conn.recv()? {
            Msg::HelloAck { err: Some(reason), .. } => {
                Err(crate::err!("handshake rejected: {reason}"))
            }
            Msg::HelloAck { first_round, ckpt_every, state, .. } => {
                self.greeted = true;
                self.first_round = (first_round as usize).max(1);
                self.ckpt_every = ckpt_every as usize;
                if !self.restored {
                    // first handshake only: a re-greet after a reconnect
                    // must not rewind state that advanced since the stash
                    self.restored = true;
                    if let Some(blob) = state {
                        self.apply_state(&blob)?;
                    }
                }
                Ok(())
            }
            other => Err(crate::err!("expected HelloAck, got {}", other.name())),
        }
    }

    /// One request/reply exchange with transport-fault recovery: on an io
    /// error over a reconnectable link, sleep per the seeded backoff
    /// schedule, re-dial, replay the handshake, and resend the *same*
    /// message (the PS courier deduplicates). The retry loop gives up once
    /// its cumulative backoff sleep exceeds the policy deadline. Protocol
    /// `Abort` replies are returned as errors and never retried.
    fn rpc(&mut self, msg: Msg) -> Result<Msg> {
        let retriable = self.conn.is_reconnectable();
        let backup = if retriable { Some(msg.clone()) } else { None };
        let mut outcome = self.greet_and_exchange(msg);
        if let Some(backup) = backup {
            let mut attempt: u32 = 0;
            let mut slept = Duration::ZERO;
            while let Err(e) = &outcome {
                if !tcp::is_io_error(e) || slept >= self.retry.deadline {
                    break;
                }
                attempt += 1;
                let delay = self.retry.delay(attempt, &mut self.backoff_rng);
                std::thread::sleep(delay);
                slept += delay;
                self.retry_attempts += 1;
                self.backoff_s += delay.as_secs_f64();
                self.greeted = false;
                if self.conn.reconnect().is_err() {
                    continue; // PS may still be tearing down the old handler
                }
                outcome = self.greet_and_exchange(backup.clone());
            }
        }
        outcome
    }

    fn greet_and_exchange(&mut self, msg: Msg) -> Result<Msg> {
        if !self.greeted {
            self.hello()?;
        }
        self.exchange(msg)
    }

    fn exchange(&mut self, msg: Msg) -> Result<Msg> {
        self.conn.send(msg)?;
        match self.conn.recv()? {
            Msg::Abort { reason } => Err(crate::err!("{reason}")),
            reply => Ok(reply),
        }
    }

    /// Run one full protocol step (t, k) for this device.
    ///
    /// `local` is the step's schedule-local index ((t-1)·K + k within a
    /// run) — the PS gates entry and deduplicates on it; `global_step` is
    /// the metrics tag (the run's first-step offset + `local`).
    pub fn run_step(
        &mut self,
        round: usize,
        local: usize,
        global_step: usize,
        train: &Dataset,
    ) -> Result<StepRecord> {
        let t_step = Instant::now();
        self.steps_run += 1;
        if self.script.cut_steps.binary_search(&self.steps_run).is_ok() {
            // scenario `cut[dev=K,step=N]`: the link dies at entry of this
            // device's N-th step; the next request goes down the
            // backoff/reconnect/replay path
            self.conn.inject_cut();
        }
        // backend time spent on this device (fwd/stats/bwd); the PS half's
        // time arrives in the Downlink reply
        let mut device_exec_s = 0.0;

        // 1. request step entry (blocks PS-side in the staleness gate) and
        //    receive the current w_d as a ModelSync frame + the shared
        //    Algorithm-1 RNG state (staleness-0 only)
        let (wd_frame, rng_state) = match self.rpc(Msg::StepStart {
            device: self.device as u32,
            round: round as u32,
            local: local as u64,
        })? {
            Msg::StepGo { wd, rng } => (wd, rng),
            other => return Err(crate::err!("expected StepGo, got {}", other.name())),
        };
        self.link.transmit_sync(Direction::Downlink, &wd_frame);
        self.decode_wd(&wd_frame)?;
        // moved out of the slot for the step: `rpc` needs `&mut self` while
        // the snapshot stays live across both exchanges below
        let wd = self.wd_set.take().expect("w_d decoded");

        // 2. minibatch + device forward (eq. 3); under staleness > 0 the
        //    snapshot may lag in-flight updates
        let (x, y, _) = self.loader.next_batch(train, self.classes);
        let t0 = Instant::now();
        let f = self.backend.device_fwd(&wd, &x)?;
        device_exec_s += t0.elapsed().as_secs_f64();

        // 3. feature statistics (σ of the channel-normalized columns,
        //    eq. 10) — only when the codec's capability report asks for them
        let stats: Option<SigmaStats> = if self.use_sigma {
            let t0 = Instant::now();
            let s = self.backend.feature_stats(&f)?;
            device_exec_s += t0.elapsed().as_secs_f64();
            Some(SigmaStats::new(s))
        } else {
            None
        };

        // 4. uplink compression — with the PS's shared stream (handing the
        //    advanced state back) or this worker's own fork — and transmit
        let (mut enc, advanced) = match &rng_state {
            Some(st) => {
                let mut shared = Rng::from_state(st);
                let enc =
                    self.codec.encode_uplink(&f, stats.as_ref(), &self.up_params, &mut shared)?;
                (enc, Some(shared.export_state()))
            }
            None => {
                let enc = self
                    .codec
                    .encode_uplink(&f, stats.as_ref(), &self.up_params, &mut self.rng)?;
                (enc, None)
            }
        };
        self.link.transmit(Direction::Uplink, &enc.frame);
        let up_frame = std::mem::replace(
            &mut enc.frame,
            Frame::new(FrameKind::FeaturesUp, Vec::new(), 0),
        );
        let up_bits = up_frame.payload_bits;

        // 5. ship the frame + labels + mask to the PS; receive the
        //    mask-coupled downlink (the server half ran in between)
        let reply = self.rpc(Msg::Uplink {
            device: self.device as u32,
            local: local as u64,
            frame: up_frame,
            labels: y,
            mask: enc.mask.clone(),
            up_nominal: enc.nominal_bits,
            rng: advanced,
        })?;
        let (dn_frame, loss, correct, server_dt, down_nominal) = match reply {
            Msg::Downlink { frame, loss, correct, server_exec_s, down_nominal } => {
                (frame, loss, correct, server_exec_s, down_nominal)
            }
            other => return Err(crate::err!("expected Downlink, got {}", other.name())),
        };
        self.link.transmit(Direction::Downlink, &dn_frame);

        // 6. downlink decode + chain-rule scale δ_j/(1-p_j), device backward
        //    (eq. 7 backward path)
        let mut g_hat = self.codec.decode_downlink(&dn_frame, &enc.mask, &self.down_params)?;
        if let GradMask::Columns { kept, scale } = &enc.mask {
            g_hat.scale_cols(kept, scale);
        }
        let t0 = Instant::now();
        let grad_wd = self.backend.device_bwd(&wd, &x, &g_hat)?;
        device_exec_s += t0.elapsed().as_secs_f64();
        self.wd_set = Some(wd); // return the buffer for the next step
        if self.script.slow > 1.0 {
            // scenario straggler: stretch this device's compute to `slow`×
            // wall clock. Only step_s/exec_s see it — the deterministic
            // metrics fields (loss, bits, ...) are untouched.
            let extra = (device_exec_s * (self.script.slow - 1.0)).clamp(0.0, 5.0);
            std::thread::sleep(Duration::from_secs_f64(extra));
            device_exec_s += extra;
        }

        // 7. commit: hand ∇w_d back as a ModelSync frame with the step
        //    report; the PS applies the update, writes the metrics record,
        //    and advances the watermark
        let grad_frame = model_sync_frame(&grad_wd);
        self.link.transmit_sync(Direction::Uplink, &grad_frame);
        let rec = StepRecord {
            round,
            device: self.device,
            global_step,
            loss,
            train_acc: correct / self.batch as f32,
            up_bits,
            down_bits: dn_frame.payload_bits,
            up_nominal: enc.nominal_bits,
            down_nominal,
            step_s: t_step.elapsed().as_secs_f64(),
            // per-step execution time spans both halves, like the monolith's
            exec_s: device_exec_s + server_dt,
        };
        let report = StepReport {
            loss,
            train_acc: rec.train_acc,
            up_bits,
            down_bits: rec.down_bits,
            up_nominal: enc.nominal_bits,
            down_nominal,
            step_s: rec.step_s,
            device_exec_s,
        };
        // while checkpointing, every Commit carries this worker's post-step
        // state blob so the PS always holds fresh device state at a
        // snapshot barrier; the bytes ride the control channel and are
        // never counted by the link model, so metrics stay identical
        let state = (self.ckpt_every > 0).then(|| self.export_state());
        match self.rpc(Msg::Commit {
            device: self.device as u32,
            round: round as u32,
            local: local as u64,
            grad: grad_frame,
            report,
            state,
        })? {
            Msg::CommitAck => {}
            other => return Err(crate::err!("expected CommitAck, got {}", other.name())),
        }

        // hand the round's buffers back to the codec session — arena-backed
        // codecs reuse them next step
        self.codec.reclaim(Reclaim::Frame(dn_frame));
        self.codec.reclaim(Reclaim::Grad(g_hat));
        self.codec.reclaim(Reclaim::Uplink(enc));
        Ok(rec)
    }

    /// Decode a `ModelSync` w_d frame into the reusable parameter set.
    fn decode_wd(&mut self, frame: &Frame) -> Result<()> {
        match &mut self.wd_set {
            Some(p) => {
                crate::ensure!(
                    frame.payload.len() == p.data.len() * 4,
                    "w_d frame is {} bytes, expected {}",
                    frame.payload.len(),
                    p.data.len() * 4
                );
                for (dst, chunk) in p.data.iter_mut().zip(frame.payload.chunks_exact(4)) {
                    *dst = f32::from_le_bytes([chunk[0], chunk[1], chunk[2], chunk[3]]);
                }
            }
            None => {
                // first step: adopt the backend's parameter layout, then
                // overwrite the values with the wire payload
                let (mut wd, _) = self.backend.init_params()?;
                crate::ensure!(
                    frame.payload.len() == wd.data.len() * 4,
                    "w_d frame is {} bytes, expected {}",
                    frame.payload.len(),
                    wd.data.len() * 4
                );
                wd.data = f32_from_le_bytes(&frame.payload);
                self.wd_set = Some(wd);
            }
        }
        Ok(())
    }

    /// The features + σ stats of one fresh batch (Fig.-1 dispersion bench).
    /// Fetches w_d over the transport without link/exec accounting — a
    /// diagnostic probe, not a protocol step.
    pub fn probe_features(&mut self, train: &Dataset) -> Result<(Matrix, Vec<f32>)> {
        let wd_frame = match self.rpc(Msg::FetchModel { device: self.device as u32 })? {
            Msg::ModelReply { wd } => wd,
            other => return Err(crate::err!("expected ModelReply, got {}", other.name())),
        };
        self.decode_wd(&wd_frame)?;
        let wd = self.wd_set.as_ref().expect("w_d decoded");
        let (x, _, _) = self.loader.next_batch(train, self.classes);
        let f = self.backend.device_fwd(wd, &x)?;
        let sigma = self.backend.feature_stats(&f)?;
        Ok((f, sigma))
    }
}

impl Drop for DeviceWorker {
    fn drop(&mut self) {
        // best-effort clean leave; the PS treats a silent drop the same way
        if self.greeted {
            let _ = self.conn.send(Msg::Bye { device: self.device as u32 });
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The rpc loop's give-up rule is `slept >= deadline` checked *before*
    /// sleeping again: an attempt whose cumulative backoff lands exactly on
    /// the deadline is the last one — the next fault must not retry.
    #[test]
    fn deadline_boundary_is_the_last_retry() {
        let p = RetryPolicy::new(100, 100, 0.5);
        let mut rng = Rng::new(3);
        let mut slept = Duration::ZERO;
        let mut attempts = 0u32;
        // replicate the rpc loop's accounting with the real delay() draws
        while slept < p.deadline {
            attempts += 1;
            slept += p.delay(attempts, &mut rng);
            assert!(attempts < 1000, "backoff must make progress");
        }
        assert!(attempts >= 1, "a positive deadline allows at least one retry");
        // once the budget is consumed the loop condition must refuse
        // another round, even when slept == deadline exactly
        let exactly = p.deadline;
        assert!(exactly >= p.deadline, "slept == deadline must stop retrying");
        // and a zero deadline never sleeps at all
        let z = RetryPolicy::new(100, 100, 0.0);
        assert!(Duration::ZERO >= z.deadline, "zero budget means zero retries");
    }

    /// delay(n) = min(cap, base·2^(n-1)) · jitter with jitter ∈ [0.5, 1.5):
    /// every draw stays inside that band and never exceeds 1.5× the cap.
    #[test]
    fn jitter_stays_inside_the_band_and_under_the_cap() {
        let p = RetryPolicy::new(10, 500, 15.0);
        let mut rng = Rng::new(7);
        for attempt in 1..=40u32 {
            let exp = attempt.saturating_sub(1).min(20);
            let nominal = p.base.as_secs_f64() * (1u64 << exp) as f64;
            let capped = nominal.min(p.cap.as_secs_f64());
            let d = p.delay(attempt, &mut rng).as_secs_f64();
            assert!(
                d >= capped * 0.5 && d < capped * 1.5,
                "attempt {attempt}: delay {d} outside [{}, {})",
                capped * 0.5,
                capped * 1.5
            );
            assert!(d < p.cap.as_secs_f64() * 1.5, "delay must respect the cap band");
        }
    }

    /// The exponent saturates at 2^20, so huge attempt counts neither
    /// overflow nor grow the nominal past the cap.
    #[test]
    fn exponent_saturates_without_overflow() {
        let p = RetryPolicy::new(1, 250, 15.0);
        let mut rng = Rng::new(11);
        for attempt in [21u32, 100, 10_000, u32::MAX] {
            let d = p.delay(attempt, &mut rng).as_secs_f64();
            assert!(d.is_finite() && d < p.cap.as_secs_f64() * 1.5);
        }
        // base 1ms · 2^20 ≈ 1048s dwarfs the 250ms cap, so the capped
        // nominal is exactly the cap for every saturated attempt
        let mut rng = Rng::new(12);
        let d = p.delay(u32::MAX, &mut rng).as_secs_f64();
        assert!(d >= p.cap.as_secs_f64() * 0.5);
    }
}
