//! The parameter-server role of the split protocol.
//!
//! The PS owns everything that is global to a run and must be updated in a
//! serialized critical section: the server-side model `w_s` and its ADAM
//! state, the (PS-held, Sec. III-A) device-side model `w_d` and its
//! optimizer slots, the legacy Algorithm-1 uplink-encode RNG stream, and the
//! metrics writer. Device workers hold only a `&ParameterServer` and go
//! through the methods below, so K workers can drive the PS concurrently:
//!
//! * [`ParameterServer::snapshot_device_params`] — a worker's read of `w_d`
//!   at step start (possibly stale under `--staleness > 0`);
//! * [`ParameterServer::process_uplink`] — the PS half of a step (eqs. 4-5
//!   forward/backward + the `w_s` ADAM update) as one critical section;
//! * [`ParameterServer::apply_device_grad`] — the PS applying a device
//!   gradient through the shared or per-device optimizer slot.
//!
//! The model itself executes through the shared [`Backend`] (`&self`
//! methods, `Send + Sync`), so no backend state is duplicated per worker.

use std::sync::{Arc, Mutex};
use std::time::Instant;

use crate::coordinator::metrics::MetricsWriter;
use crate::data::Dataset;
use crate::model::{ParamSet, PresetInfo};
use crate::optim::{Adam, AdamState, Optimizer};
use crate::runtime::{Backend, ServerOutput};
use crate::tensor::Matrix;
use crate::util::error::Result;
use crate::util::rng::RngState;
use crate::util::{Json, Rng};

/// PS-held ADAM state for the device-side model. Algorithm 1 shares one
/// moment set across every device; `--per-device-opt` gives each device an
/// independent copy (useful under staleness, but a different trajectory).
pub enum DeviceOpt {
    Shared(Adam),
    PerDevice(Vec<Adam>),
}

impl DeviceOpt {
    fn step(&mut self, device: usize, params: &mut [f32], grad: &[f32]) {
        match self {
            DeviceOpt::Shared(opt) => opt.step(params, grad),
            DeviceOpt::PerDevice(opts) => opts[device].step(params, grad),
        }
    }
}

/// Serializable [`DeviceOpt`] state, mirroring its shared/per-device shape.
#[derive(Debug, Clone, PartialEq)]
pub enum DeviceOptState {
    Shared(AdamState),
    PerDevice(Vec<AdamState>),
}

/// The serializable PS state: both parameter sets, both optimizers, the
/// shared Algorithm-1 RNG stream, and the cumulative backend time.
#[derive(Debug, Clone)]
pub struct ServerSnap {
    pub wd: Vec<f32>,
    pub ws: Vec<f32>,
    pub opt_s: AdamState,
    pub opt_d: DeviceOptState,
    pub rng: RngState,
    pub exec_s: f64,
}

/// Everything behind the PS lock: both parameter sets, both optimizers, and
/// the cumulative backend-execution time of the run.
struct ServerState {
    wd: ParamSet,
    ws: ParamSet,
    opt_s: Adam,
    opt_d: DeviceOpt,
    exec_s: f64,
}

pub struct ParameterServer {
    /// shared with in-process device workers (one engine, no duplicated
    /// backend state); a remote device process builds its own instance
    backend: Arc<dyn Backend>,
    preset: PresetInfo,
    state: Mutex<ServerState>,
    /// the single Algorithm-1 uplink-encode stream; under strict (S = 0)
    /// scheduling it is consumed in global step order, reproducing the
    /// monolithic trainer's trajectory bit-for-bit
    rng: Mutex<Rng>,
    metrics: Mutex<MetricsWriter>,
}

impl ParameterServer {
    pub fn new(
        backend: Arc<dyn Backend>,
        wd: ParamSet,
        ws: ParamSet,
        lr: f32,
        devices: usize,
        per_device_opt: bool,
        shared_rng: Rng,
        metrics: MetricsWriter,
    ) -> ParameterServer {
        let preset = backend.preset().clone();
        let opt_d = if per_device_opt {
            DeviceOpt::PerDevice((0..devices).map(|_| Adam::new(lr, wd.n_params())).collect())
        } else {
            DeviceOpt::Shared(Adam::new(lr, wd.n_params()))
        };
        let opt_s = Adam::new(lr, ws.n_params());
        ParameterServer {
            backend,
            preset,
            state: Mutex::new(ServerState { wd, ws, opt_s, opt_d, exec_s: 0.0 }),
            rng: Mutex::new(shared_rng),
            metrics: Mutex::new(metrics),
        }
    }

    pub fn preset(&self) -> &PresetInfo {
        &self.preset
    }

    /// The shared execution backend (device workers run their sub-model
    /// halves through this same instance).
    pub fn backend(&self) -> &dyn Backend {
        self.backend.as_ref()
    }

    /// A worker's view of the device-side model at step start. Under
    /// bounded staleness this clone may lag the live `w_d` by in-flight
    /// updates — that lag is exactly what `--staleness` bounds.
    pub fn snapshot_device_params(&self) -> ParamSet {
        self.state.lock().unwrap().wd.clone()
    }

    /// Refresh a worker's reusable `w_d` snapshot in place: allocates only
    /// on first use, afterwards a flat copy under the lock. The copy is the
    /// price of running device compute outside the PS critical section.
    pub fn snapshot_device_params_into(&self, dst: &mut Option<ParamSet>) {
        let st = self.state.lock().unwrap();
        match dst {
            Some(p) => p.data.copy_from_slice(&st.wd.data),
            None => *dst = Some(st.wd.clone()),
        }
    }

    /// Consistent `(w_d, w_s)` snapshot for evaluation.
    pub fn snapshot_models(&self) -> (ParamSet, ParamSet) {
        let st = self.state.lock().unwrap();
        (st.wd.clone(), st.ws.clone())
    }

    /// The PS half of one protocol step (one critical section): server
    /// forward/backward on the reconstructed features (eqs. 4-5) followed by
    /// the `w_s` ADAM update. Returns the loss, correct count, the
    /// intermediate gradient G for the downlink, and the backend execution
    /// time of this call (already counted into the run total — callers fold
    /// it into their per-step accounting only).
    pub fn process_uplink(&self, f_hat: &Matrix, y: &[f32]) -> Result<(ServerOutput, f64)> {
        let mut st = self.state.lock().unwrap();
        let t0 = Instant::now();
        let out = self.backend.server_fwd_bwd(&st.ws, f_hat, y)?;
        let dt = t0.elapsed().as_secs_f64();
        st.exec_s += dt;
        let ServerState { ws, opt_s, .. } = &mut *st;
        opt_s.step(&mut ws.data, &out.grad_ws);
        Ok((out, dt))
    }

    /// Apply a device-side gradient through this device's optimizer slot
    /// (the PS holds the device optimizer, Sec. III-A).
    pub fn apply_device_grad(&self, device: usize, grad: &[f32]) {
        let mut st = self.state.lock().unwrap();
        let ServerState { wd, opt_d, .. } = &mut *st;
        opt_d.step(device, &mut wd.data, grad);
    }

    /// Run `f` with exclusive access to the legacy shared RNG stream.
    pub fn with_rng<T>(&self, f: impl FnOnce(&mut Rng) -> T) -> T {
        f(&mut self.rng.lock().unwrap())
    }

    /// Snapshot the full PS state for checkpointing. Taken at a quiesced
    /// round barrier, so the lock sees no step mid-flight.
    pub fn export_snap(&self) -> ServerSnap {
        let st = self.state.lock().unwrap();
        let opt_d = match &st.opt_d {
            DeviceOpt::Shared(a) => DeviceOptState::Shared(a.export_state()),
            DeviceOpt::PerDevice(opts) => {
                DeviceOptState::PerDevice(opts.iter().map(Adam::export_state).collect())
            }
        };
        ServerSnap {
            wd: st.wd.data.clone(),
            ws: st.ws.data.clone(),
            opt_s: st.opt_s.export_state(),
            opt_d,
            rng: self.rng.lock().unwrap().export_state(),
            exec_s: st.exec_s,
        }
    }

    /// Overwrite the full PS state from a snapshot, validating every shape
    /// against the live run (a snapshot from a different preset or
    /// `--per-device-opt` setting is rejected before any field is touched).
    pub fn restore_snap(&self, snap: &ServerSnap) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        crate::ensure!(
            snap.wd.len() == st.wd.data.len() && snap.ws.len() == st.ws.data.len(),
            "checkpoint model shapes ({}/{}) do not match the run ({}/{})",
            snap.wd.len(),
            snap.ws.len(),
            st.wd.data.len(),
            st.ws.data.len()
        );
        match (&snap.opt_d, &st.opt_d) {
            (DeviceOptState::Shared(_), DeviceOpt::Shared(_)) => {}
            (DeviceOptState::PerDevice(a), DeviceOpt::PerDevice(b)) => {
                crate::ensure!(
                    a.len() == b.len(),
                    "checkpoint has {} per-device optimizer slots, the run has {}",
                    a.len(),
                    b.len()
                );
            }
            _ => crate::bail!(
                "checkpoint optimizer layout does not match --per-device-opt"
            ),
        }
        // validate every moment-vector shape up front, so the mutation below
        // is all-or-nothing
        let d_adams: Vec<&AdamState> = match &snap.opt_d {
            DeviceOptState::Shared(a) => vec![a],
            DeviceOptState::PerDevice(v) => v.iter().collect(),
        };
        crate::ensure!(
            snap.opt_s.m.len() == st.ws.data.len()
                && snap.opt_s.v.len() == st.ws.data.len()
                && d_adams.iter().all(|a| {
                    a.m.len() == st.wd.data.len() && a.v.len() == st.wd.data.len()
                }),
            "checkpoint optimizer moment shapes do not match the run's models"
        );
        st.opt_s.restore_state(&snap.opt_s)?;
        match (&snap.opt_d, &mut st.opt_d) {
            (DeviceOptState::Shared(a), DeviceOpt::Shared(opt)) => opt.restore_state(a)?,
            (DeviceOptState::PerDevice(snaps), DeviceOpt::PerDevice(opts)) => {
                for (s, o) in snaps.iter().zip(opts.iter_mut()) {
                    o.restore_state(s)?;
                }
            }
            _ => unreachable!("layout validated above"),
        }
        st.wd.data.copy_from_slice(&snap.wd);
        st.ws.data.copy_from_slice(&snap.ws);
        st.exec_s = snap.exec_s;
        self.rng.lock().unwrap().restore_state(&snap.rng);
        Ok(())
    }

    /// Add worker-side backend execution time to the run total.
    pub fn add_exec(&self, dt: f64) {
        self.state.lock().unwrap().exec_s += dt;
    }

    /// Cumulative backend execution time across PS and workers.
    pub fn exec_s(&self) -> f64 {
        self.state.lock().unwrap().exec_s
    }

    /// Append one record to the metrics stream (serialized across workers).
    pub fn write_metrics(&self, j: &Json) {
        self.metrics.lock().unwrap().write(j);
    }

    pub fn flush_metrics(&self) {
        self.metrics.lock().unwrap().flush();
    }

    /// Test-set accuracy of the full split model on the current parameter
    /// snapshot (the batches run outside the PS lock).
    pub fn evaluate(&self, test: &Dataset) -> Result<f32> {
        let (wd, ws) = self.snapshot_models();
        let p = &self.preset;
        let dim = p.sample_dim();
        let n_batches = (test.n / p.batch).max(1);
        let mut correct = 0usize;
        let mut total = 0usize;
        let mut exec_s = 0.0;
        for bi in 0..n_batches {
            let mut x = Vec::with_capacity(p.batch * dim);
            let mut labels = Vec::with_capacity(p.batch);
            for j in 0..p.batch {
                let i = (bi * p.batch + j) % test.n;
                x.extend_from_slice(test.sample(i));
                labels.push(test.y[i]);
            }
            let t0 = Instant::now();
            let logits = self.backend.eval_logits(&wd, &ws, &x)?;
            exec_s += t0.elapsed().as_secs_f64();
            for (j, &lab) in labels.iter().enumerate() {
                let row = &logits[j * p.classes..(j + 1) * p.classes];
                // total_cmp: NaN logits (a diverged run) must not panic the
                // evaluation; they sort above every real value and simply
                // count as a (mis)prediction
                let pred = row
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, _)| i)
                    .unwrap();
                correct += (pred == lab as usize) as usize;
                total += 1;
            }
        }
        self.add_exec(exec_s);
        Ok(correct as f32 / total as f32)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::create_backend;

    fn tiny_server(per_device_opt: bool) -> ParameterServer {
        let backend: Arc<dyn crate::runtime::Backend> =
            Arc::from(create_backend(Default::default(), "artifacts", "tiny").unwrap());
        let (wd, ws) = backend.init_params().unwrap();
        ParameterServer::new(
            backend,
            wd,
            ws,
            1e-2,
            3,
            per_device_opt,
            Rng::new(7),
            MetricsWriter::create(""),
        )
    }

    #[test]
    fn snapshot_is_decoupled_from_updates() {
        let srv = tiny_server(false);
        let before = srv.snapshot_device_params();
        let grad = vec![1.0f32; before.n_params()];
        srv.apply_device_grad(0, &grad);
        let after = srv.snapshot_device_params();
        assert_ne!(before.data, after.data, "update must move w_d");
        // the earlier snapshot is untouched (workers own their copy)
        assert_eq!(before.data.len(), after.data.len());
    }

    #[test]
    fn snapshot_into_reuses_buffer_and_tracks_updates() {
        let srv = tiny_server(false);
        let mut buf = None;
        srv.snapshot_device_params_into(&mut buf);
        let first = buf.as_ref().unwrap().data.clone();
        let grad = vec![1.0f32; first.len()];
        srv.apply_device_grad(0, &grad);
        srv.snapshot_device_params_into(&mut buf);
        assert_ne!(buf.as_ref().unwrap().data, first, "refresh must see the update");
        assert_eq!(buf.as_ref().unwrap().data.len(), first.len());
    }

    #[test]
    fn shared_opt_accumulates_moments_across_devices() {
        let srv = tiny_server(false);
        let n = srv.snapshot_device_params().n_params();
        let grad = vec![0.5f32; n];
        srv.apply_device_grad(0, &grad);
        srv.apply_device_grad(1, &grad);
        let st = srv.state.lock().unwrap();
        match &st.opt_d {
            DeviceOpt::Shared(opt) => assert_eq!(opt.t(), 2),
            _ => panic!("expected shared slot"),
        }
    }

    #[test]
    fn per_device_opt_keeps_independent_moments() {
        let srv = tiny_server(true);
        let n = srv.snapshot_device_params().n_params();
        let grad = vec![0.5f32; n];
        srv.apply_device_grad(0, &grad);
        srv.apply_device_grad(0, &grad);
        srv.apply_device_grad(2, &grad);
        let st = srv.state.lock().unwrap();
        match &st.opt_d {
            DeviceOpt::PerDevice(opts) => {
                assert_eq!(opts.len(), 3);
                assert_eq!(opts[0].t(), 2);
                assert_eq!(opts[1].t(), 0);
                assert_eq!(opts[2].t(), 1);
            }
            _ => panic!("expected per-device slots"),
        }
    }

    #[test]
    fn process_uplink_steps_server_optimizer() {
        let srv = tiny_server(false);
        let p = srv.preset().clone();
        let f_hat = Matrix::zeros(p.batch, p.dbar);
        let mut y = vec![0.0f32; p.batch * p.classes];
        for b in 0..p.batch {
            y[b * p.classes] = 1.0;
        }
        let ws_before = srv.snapshot_models().1;
        let (out, dt) = srv.process_uplink(&f_hat, &y).unwrap();
        assert!(out.loss.is_finite());
        let ws_after = srv.snapshot_models().1;
        assert_ne!(ws_before.data, ws_after.data, "w_s must be updated");
        // the returned execution time is the same one added to the run total
        assert!(dt > 0.0);
        assert!((srv.exec_s() - dt).abs() < 1e-12);
    }

    #[test]
    fn snap_roundtrip_restores_exactly() {
        let a = tiny_server(true);
        let n = a.snapshot_device_params().n_params();
        a.apply_device_grad(0, &vec![0.5; n]);
        a.apply_device_grad(2, &vec![-0.25; n]);
        a.with_rng(|r| r.next_u64());
        let snap = a.export_snap();
        let b = tiny_server(true);
        b.restore_snap(&snap).unwrap();
        assert_eq!(
            a.snapshot_device_params().data,
            b.snapshot_device_params().data
        );
        assert_eq!(a.snapshot_models().1.data, b.snapshot_models().1.data);
        // both RNG streams continue identically after restore
        assert_eq!(a.with_rng(|r| r.next_u64()), b.with_rng(|r| r.next_u64()));
        // identical gradients keep the trajectories locked together
        a.apply_device_grad(1, &vec![1.0; n]);
        b.apply_device_grad(1, &vec![1.0; n]);
        assert_eq!(
            a.snapshot_device_params().data,
            b.snapshot_device_params().data
        );
        // an optimizer-layout mismatch is rejected
        let c = tiny_server(false);
        assert!(c.restore_snap(&snap).is_err());
    }

    #[test]
    fn shared_rng_stream_is_exclusive_and_ordered() {
        let srv = tiny_server(false);
        let a = srv.with_rng(|r| r.next_u64());
        let b = srv.with_rng(|r| r.next_u64());
        let mut reference = Rng::new(7);
        assert_eq!(a, reference.next_u64());
        assert_eq!(b, reference.next_u64());
    }
}
