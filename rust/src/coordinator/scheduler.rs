//! Concurrent multi-device scheduling with a bounded-staleness window.
//!
//! Algorithm 1 visits devices strictly round-robin: global step
//! g = (t-1)·K + k runs after every step with a smaller index. The
//! scheduler generalizes that order with one knob, `staleness` (S, in
//! rounds): **step g may start once every step with index < g - S·K has
//! completed**. Consequences:
//!
//! * `S = 0` degenerates to the exact sequential round-robin order — even
//!   when K workers run on separate threads, whole steps are serialized in
//!   the monolithic trainer's order, and (with the PS-held shared RNG
//!   stream) the metrics are byte-identical to the sequential path.
//! * `S > 0` lets up to S·K protocol steps overlap: a device may run at
//!   most S rounds ahead of the slowest outstanding step, the classic
//!   bounded-staleness regime. Workers then use their own RNG forks and
//!   the PS applies updates in completion order.
//!
//! Progress is tracked by a watermark monitor (`done` bitmap + condvar):
//! completion may arrive out of order, the watermark advances over the
//! longest finished prefix. Evaluation rounds are barriers: the scheduler
//! thread waits for the watermark to reach the round boundary, evaluates on
//! the frozen snapshot, then releases the next round — so eval accuracy
//! lands at exactly the same model state as in the sequential path.

use std::sync::{Condvar, Mutex};
use std::time::Instant;

use crate::coordinator::metrics::TrainSummary;
use crate::coordinator::server::ParameterServer;
use crate::coordinator::worker::{DeviceWorker, RngMode};
use crate::data::Dataset;
use crate::transport::LinkReport;
use crate::util::error::{Context, Result};
use crate::{log_debug, log_info};

pub struct Scheduler {
    pub rounds: usize,
    /// global-step tag of this run's first step (a facade that already ran
    /// manual steps offsets the schedule so `g` tags stay unique per record)
    pub first_step: usize,
    /// bounded-staleness window S in rounds (0 = strict round-robin)
    pub staleness: usize,
    /// worker threads driving the devices (1 = inline on the caller thread)
    pub concurrency: usize,
    /// evaluate every this many rounds (0 = only at the end)
    pub eval_every: usize,
}

/// Per-device totals a worker thread hands back to the scheduler.
struct DeviceStats {
    device: usize,
    up_bits: u64,
    down_bits: u64,
    steps: usize,
    last_round_loss: f32,
}

impl DeviceStats {
    fn new(device: usize) -> DeviceStats {
        DeviceStats { device, up_bits: 0, down_bits: 0, steps: 0, last_round_loss: f32::NAN }
    }
}

fn mean_loss(losses: &[f32]) -> f32 {
    if losses.is_empty() {
        f32::NAN
    } else {
        losses.iter().sum::<f32>() / losses.len() as f32
    }
}

/// Watermark monitor: tracks out-of-order step completion, the longest
/// finished prefix, eval barriers, and abort propagation.
struct Progress {
    state: Mutex<ProgressState>,
    cv: Condvar,
}

struct ProgressState {
    done: Vec<bool>,
    /// every step with index < watermark has completed
    watermark: usize,
    /// last round whose eval barrier has been released
    eval_done_round: usize,
    aborted: bool,
}

impl Progress {
    fn new(total_steps: usize) -> Progress {
        Progress {
            state: Mutex::new(ProgressState {
                done: vec![false; total_steps],
                watermark: 0,
                eval_done_round: 0,
                aborted: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Block until step `g` may start: the watermark covers g - window and
    /// the eval barrier for `gate_round` has been released.
    fn wait_start(&self, g: usize, window: usize, gate_round: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return Err(crate::err!("scheduler aborted (another worker failed)"));
            }
            if st.watermark + window >= g && st.eval_done_round >= gate_round {
                return Ok(());
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn complete(&self, g: usize) {
        let mut st = self.state.lock().unwrap();
        st.done[g] = true;
        while st.watermark < st.done.len() && st.done[st.watermark] {
            st.watermark += 1;
        }
        self.cv.notify_all();
    }

    /// Block until the watermark reaches `target` (an eval round boundary).
    fn wait_watermark(&self, target: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return Err(crate::err!("scheduler aborted (a worker failed)"));
            }
            if st.watermark >= target {
                return Ok(());
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    fn eval_done(&self, round: usize) {
        let mut st = self.state.lock().unwrap();
        st.eval_done_round = round;
        self.cv.notify_all();
    }

    fn abort(&self) {
        self.state.lock().unwrap().aborted = true;
        self.cv.notify_all();
    }
}

/// Aborts the schedule on drop unless disarmed — so a worker that errors or
/// panics mid-step unblocks every peer waiting on the watermark instead of
/// deadlocking the scope join.
struct AbortOnDrop<'a> {
    progress: &'a Progress,
    armed: bool,
}

impl Drop for AbortOnDrop<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.progress.abort();
        }
    }
}

/// The eval barrier a step of round `t` must wait for: the latest eval
/// boundary strictly before its round.
fn eval_gate(t: usize, eval_every: usize) -> usize {
    if eval_every == 0 {
        0
    } else {
        ((t - 1) / eval_every) * eval_every
    }
}

/// One worker thread's loop: drive a disjoint set of devices through all
/// rounds, entering each step through the staleness window.
#[allow(clippy::too_many_arguments)]
fn drive_devices(
    chunk: &mut [DeviceWorker],
    server: &ParameterServer,
    train: &Dataset,
    progress: &Progress,
    first_step: usize,
    rounds: usize,
    devices: usize,
    window: usize,
    eval_every: usize,
    rng_mode: RngMode,
) -> Result<Vec<DeviceStats>> {
    let mut stats: Vec<DeviceStats> =
        chunk.iter().map(|w| DeviceStats::new(w.device)).collect();
    for t in 1..=rounds {
        let gate = eval_gate(t, eval_every);
        for (i, w) in chunk.iter_mut().enumerate() {
            // schedule-local index gates progress; the record tag is global
            let l = (t - 1) * devices + w.device;
            progress.wait_start(l, window, gate)?;
            let rec = w
                .run_step(t, first_step + l, server, train, rng_mode)
                .with_context(|| format!("step t={t} k={}", w.device))?;
            let st = &mut stats[i];
            st.up_bits += rec.up_bits;
            st.down_bits += rec.down_bits;
            st.steps += 1;
            if t == rounds {
                st.last_round_loss = rec.loss;
            }
            log_debug!(
                "t={t} k={} g={} loss={:.4} acc={:.3} up={}b down={}b",
                w.device,
                rec.global_step,
                rec.loss,
                rec.train_acc,
                rec.up_bits,
                rec.down_bits
            );
            progress.complete(l);
        }
    }
    Ok(stats)
}

impl Scheduler {
    /// Train `rounds` rounds over the workers' devices; fills everything in
    /// the summary except the final evaluation and wall/exec/link times
    /// (the [`Trainer`](crate::coordinator::Trainer) facade adds those).
    pub fn run(
        &self,
        server: &ParameterServer,
        workers: &mut [DeviceWorker],
        train: &Dataset,
        test: &Dataset,
    ) -> Result<TrainSummary> {
        let t0 = Instant::now();
        let mut summary = if self.concurrency <= 1 {
            self.run_sequential(server, workers, train, test)?
        } else {
            self.run_concurrent(server, workers, train, test)?
        };
        summary.final_acc = server.evaluate(test)?;
        summary.eval_history.push((self.rounds, summary.final_acc));
        summary.wall_s = t0.elapsed().as_secs_f64();
        summary.exec_s = server.exec_s();
        summary.link_s =
            LinkReport::aggregate(workers.iter().map(|w| w.link_report())).elapsed_s;
        Ok(summary)
    }

    /// The reference path: Algorithm 1's sequential round-robin, inline on
    /// the caller thread. The concurrent path at staleness 0 must produce
    /// byte-identical metrics to this loop.
    fn run_sequential(
        &self,
        server: &ParameterServer,
        workers: &mut [DeviceWorker],
        train: &Dataset,
        test: &Dataset,
    ) -> Result<TrainSummary> {
        let devices = workers.len();
        let mut summary = TrainSummary::default();
        let mut last_round_losses = Vec::with_capacity(devices);
        for t in 1..=self.rounds {
            last_round_losses.clear();
            for (k, w) in workers.iter_mut().enumerate() {
                let g = self.first_step + (t - 1) * devices + k;
                let rec = w
                    .run_step(t, g, server, train, RngMode::SharedSequential)
                    .with_context(|| format!("step t={t} k={k}"))?;
                summary.total_up_bits += rec.up_bits;
                summary.total_down_bits += rec.down_bits;
                summary.steps += 1;
                last_round_losses.push(rec.loss);
                log_debug!(
                    "t={t} k={k} loss={:.4} acc={:.3} up={}b down={}b",
                    rec.loss,
                    rec.train_acc,
                    rec.up_bits,
                    rec.down_bits
                );
            }
            if self.eval_every > 0 && t % self.eval_every == 0 {
                let acc = server.evaluate(test)?;
                summary.eval_history.push((t, acc));
                log_info!("round {t}: eval acc {:.4}", acc);
            }
        }
        summary.mean_loss_last_round = mean_loss(&last_round_losses);
        Ok(summary)
    }

    /// The threaded path: contiguous device chunks on `concurrency` scoped
    /// threads, step entry gated by the staleness window, the scheduler
    /// thread serving eval barriers.
    fn run_concurrent(
        &self,
        server: &ParameterServer,
        workers: &mut [DeviceWorker],
        train: &Dataset,
        test: &Dataset,
    ) -> Result<TrainSummary> {
        let devices = workers.len();
        let total_steps = self.rounds * devices;
        let window = self.staleness * devices;
        let rng_mode = if self.staleness == 0 {
            RngMode::SharedSequential
        } else {
            RngMode::PerDevice
        };
        let conc = self.concurrency.max(1);
        let chunk_len = (devices + conc - 1) / conc;
        let (rounds, eval_every) = (self.rounds, self.eval_every);
        let first_step = self.first_step;
        let progress = Progress::new(total_steps);

        let mut eval_history: Vec<(usize, f32)> = Vec::new();
        let mut eval_err: Option<crate::util::Error> = None;
        let results: Vec<Result<Vec<DeviceStats>>> = std::thread::scope(|s| {
            let progress = &progress;
            // released only after every worker handle is joined: if the
            // scheduler thread itself panics, the workers still unblock
            let mut scope_guard = AbortOnDrop { progress, armed: true };
            let handles: Vec<_> = workers
                .chunks_mut(chunk_len)
                .map(|chunk| {
                    s.spawn(move || {
                        let mut guard = AbortOnDrop { progress, armed: true };
                        let res = drive_devices(
                            chunk, server, train, progress, first_step, rounds, devices,
                            window, eval_every, rng_mode,
                        );
                        guard.armed = res.is_err();
                        res
                    })
                })
                .collect();

            // eval rounds are barriers: wait for the boundary watermark,
            // evaluate the frozen snapshot, release the next round
            if eval_every > 0 {
                let mut t = eval_every;
                while t <= rounds {
                    if progress.wait_watermark(t * devices).is_err() {
                        break; // a worker aborted; its error is joined below
                    }
                    match server.evaluate(test) {
                        Ok(acc) => {
                            eval_history.push((t, acc));
                            log_info!("round {t}: eval acc {:.4}", acc);
                            progress.eval_done(t);
                        }
                        Err(e) => {
                            eval_err = Some(e);
                            progress.abort();
                            break;
                        }
                    }
                    t += eval_every;
                }
            }

            let joined: Vec<_> = handles
                .into_iter()
                .map(|h| h.join().expect("device worker thread panicked"))
                .collect();
            scope_guard.armed = false;
            joined
        });
        if let Some(e) = eval_err {
            return Err(e);
        }

        // surface the root cause: a failing worker aborts the schedule, which
        // makes its peers fail with a generic "scheduler aborted" error —
        // prefer the first error that is NOT one of those secondary victims
        let mut per_device: Vec<Option<DeviceStats>> = (0..devices).map(|_| None).collect();
        let mut first_err: Option<crate::util::Error> = None;
        for res in results {
            match res {
                Ok(stats) => {
                    for stat in stats {
                        per_device[stat.device] = Some(stat);
                    }
                }
                Err(e) => {
                    let keep_current = matches!(
                        &first_err,
                        Some(cur) if !cur.to_string().contains("scheduler aborted")
                    );
                    if !keep_current
                        && (first_err.is_none()
                            || !e.to_string().contains("scheduler aborted"))
                    {
                        first_err = Some(e);
                    }
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        // fold per-device totals in device order so float sums match the
        // sequential path exactly
        let mut summary = TrainSummary::default();
        let mut last_losses = Vec::with_capacity(devices);
        for (k, stat) in per_device.into_iter().enumerate() {
            let stat = stat.with_context(|| format!("device {k} reported no stats"))?;
            summary.total_up_bits += stat.up_bits;
            summary.total_down_bits += stat.down_bits;
            summary.steps += stat.steps;
            last_losses.push(stat.last_round_loss);
        }
        summary.eval_history = eval_history;
        summary.mean_loss_last_round = mean_loss(&last_losses);
        Ok(summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn watermark_advances_over_out_of_order_completion() {
        let p = Progress::new(4);
        p.complete(2);
        assert_eq!(p.state.lock().unwrap().watermark, 0);
        p.complete(0);
        assert_eq!(p.state.lock().unwrap().watermark, 1);
        p.complete(1);
        // 0,1,2 done -> watermark jumps past the out-of-order step
        assert_eq!(p.state.lock().unwrap().watermark, 3);
        p.complete(3);
        assert_eq!(p.state.lock().unwrap().watermark, 4);
    }

    #[test]
    fn strict_window_blocks_and_releases() {
        // S=0 (window 0): step 1 must wait for step 0; once 0 completes the
        // start gate opens without blocking
        let p = Progress::new(2);
        p.complete(0);
        assert!(p.wait_start(1, 0, 0).is_ok());
    }

    #[test]
    fn stale_window_admits_lookahead() {
        // window 2: steps 1 and 2 may start with nothing completed, step 3
        // may not until the watermark reaches 1
        let p = Progress::new(8);
        assert!(p.wait_start(2, 2, 0).is_ok());
        p.complete(0);
        assert!(p.wait_start(3, 2, 0).is_ok());
    }

    #[test]
    fn abort_unblocks_waiters_with_error() {
        let p = Progress::new(4);
        p.abort();
        assert!(p.wait_start(3, 0, 0).is_err());
        assert!(p.wait_watermark(4).is_err());
    }

    #[test]
    fn eval_gate_is_latest_boundary_before_round() {
        assert_eq!(eval_gate(1, 0), 0);
        assert_eq!(eval_gate(1, 2), 0);
        assert_eq!(eval_gate(2, 2), 0);
        assert_eq!(eval_gate(3, 2), 2);
        assert_eq!(eval_gate(4, 2), 2);
        assert_eq!(eval_gate(5, 2), 4);
    }

    #[test]
    fn mean_loss_matches_sequential_accumulation() {
        assert!(mean_loss(&[]).is_nan());
        let m = mean_loss(&[1.0, 2.0, 4.0]);
        assert!((m - (7.0 / 3.0)).abs() < 1e-6);
    }
}
