//! Concurrent multi-device scheduling over the message transport.
//!
//! Algorithm 1 visits devices strictly round-robin: schedule-local step
//! l = (t-1)·K + k runs after every step with a smaller index. The
//! scheduler generalizes that order with one knob, `staleness` (S, in
//! rounds): **step l may start once every step with index < l - S·K has
//! committed**. Consequences:
//!
//! * `S = 0` degenerates to the exact sequential round-robin order — even
//!   when K workers run on separate threads (or sockets), whole steps are
//!   serialized in the monolithic trainer's order, and (with the PS-held
//!   shared RNG stream travelling in `StepGo`/`Uplink`) the metrics are
//!   byte-identical to the sequential path.
//! * `S > 0` lets up to S·K protocol steps overlap: a device may run at
//!   most S rounds ahead of the slowest outstanding step, the classic
//!   bounded-staleness regime. Workers then use their own RNG forks and
//!   the PS applies updates in completion order.
//!
//! Since the transport refactor the gating itself lives PS-side in
//! [`PsEndpoint`]'s [`RunGate`](crate::coordinator::protocol::RunGate):
//! a worker simply sends `StepStart` and blocks in the reply, so remote
//! (socket) devices obey the same staleness window as local threads. The
//! scheduler's remaining jobs are driving the local workers, serving eval
//! barriers (evaluate on the frozen snapshot at round boundaries, then
//! release the next round), and folding the endpoint's per-device totals
//! into the run summary in device order.

use std::time::{Duration, Instant};

use crate::coordinator::metrics::TrainSummary;
use crate::coordinator::protocol::{AbortOnDrop, PsEndpoint};
use crate::coordinator::server::ParameterServer;
use crate::coordinator::worker::DeviceWorker;
use crate::data::Dataset;
use crate::transport::LinkReport;
use crate::util::error::{Context, Result};
use crate::{log_debug, log_info};

pub struct Scheduler {
    pub rounds: usize,
    /// global-step tag of this run's first step (a facade that already ran
    /// manual steps offsets the schedule so `g` tags stay unique per record)
    pub first_step: usize,
    /// bounded-staleness window S in rounds (0 = strict round-robin);
    /// informational here — the window itself is enforced by the endpoint
    pub staleness: usize,
    /// worker threads driving the local devices (1 = inline on the caller)
    pub concurrency: usize,
    /// evaluate every this many rounds (0 = only at the end)
    pub eval_every: usize,
    /// snapshot every this many rounds (0 = no checkpointing); the
    /// snapshot itself is taken by the hook passed to [`Scheduler::run`]
    pub ckpt_every: usize,
    /// first round to drive (1 on a fresh run, checkpoint round + 1 after
    /// a resume — the endpoint pre-completes the earlier rounds)
    pub first_round: usize,
    /// schedule-local steps no device will run (scenario departures,
    /// delayed joins, dropout windows) — pre-completed at `begin_run`
    pub skips: Vec<usize>,
    /// PS liveness window: a disconnected device silent this long is
    /// marked departed and the run proceeds without it (`None` = wait
    /// forever, today's behavior)
    pub liveness: Option<Duration>,
}

/// Mean of the finite last-round losses. Departed / absent devices leave
/// NaN behind — they must not poison the survivors' mean; on a full run
/// every loss is finite and this is the plain sequential sum.
fn mean_loss(losses: &[f32]) -> f32 {
    let mut sum = 0.0f32;
    let mut n = 0usize;
    for &l in losses {
        if l.is_finite() {
            sum += l;
            n += 1;
        }
    }
    if n == 0 {
        f32::NAN
    } else {
        sum / n as f32
    }
}

/// One worker thread's loop: drive a disjoint set of local devices through
/// all rounds. Step entry blocks inside `run_step` (the PS-side gate), so
/// this loop carries no synchronization of its own.
fn drive_devices(
    chunk: &mut [DeviceWorker],
    train: &Dataset,
    first_step: usize,
    first_round: usize,
    rounds: usize,
    devices: usize,
) -> Result<()> {
    for t in first_round..=rounds {
        for w in chunk.iter_mut() {
            if !w.script().participates(t) {
                continue; // scenario: not joined yet, dropped out, or departed
            }
            let l = (t - 1) * devices + w.device;
            let rec = w
                .run_step(t, l, first_step + l, train)
                .with_context(|| format!("step t={t} k={}", w.device))?;
            log_debug!(
                "t={t} k={} g={} loss={:.4} acc={:.3} up={}b down={}b",
                w.device,
                rec.global_step,
                rec.loss,
                rec.train_acc,
                rec.up_bits,
                rec.down_bits
            );
        }
    }
    Ok(())
}

/// The round barriers a run must serve: every eval and checkpoint boundary
/// in `(first_round - 1, rounds]`, sorted and deduplicated (a round that is
/// both evaluates first, then snapshots, then releases once).
fn barrier_rounds(
    first_round: usize,
    rounds: usize,
    eval_every: usize,
    ckpt_every: usize,
) -> Vec<usize> {
    let mut out = Vec::new();
    for every in [eval_every, ckpt_every] {
        if every == 0 {
            continue;
        }
        let mut t = every;
        while t <= rounds {
            if t >= first_round {
                out.push(t);
            }
            t += every;
        }
    }
    out.sort_unstable();
    out.dedup();
    out
}

impl Scheduler {
    /// Train `rounds` rounds over the endpoint's fleet; local devices are
    /// driven by `workers`, remote devices (if any) connect over the
    /// listening transport and are awaited at the watermark. `snapshot` is
    /// the checkpoint hook, called with the boundary round at every
    /// `ckpt_every` multiple while the fleet is quiesced at the barrier.
    pub fn run(
        &self,
        endpoint: &PsEndpoint,
        server: &ParameterServer,
        workers: &mut [DeviceWorker],
        train: &Dataset,
        test: &Dataset,
        snapshot: Option<&(dyn Fn(usize) -> Result<()> + Sync)>,
    ) -> Result<TrainSummary> {
        let t0 = Instant::now();
        let devices = endpoint.devices();
        let sequential = self.concurrency <= 1 && workers.len() == devices;
        // the sequential driver evaluates and snapshots inline between
        // rounds, so its gate needs no barriers
        let (eval_gate_every, ckpt_gate_every) =
            if sequential { (0, 0) } else { (self.eval_every, self.ckpt_every) };
        endpoint.begin_run(
            self.rounds,
            self.first_step,
            eval_gate_every,
            ckpt_gate_every,
            &self.skips,
        );
        let res = if sequential {
            self.run_sequential(server, workers, devices, train, test, snapshot)
        } else {
            self.run_concurrent(endpoint, server, workers, devices, train, test, snapshot)
        };
        let totals = endpoint.finish_run();
        let mut summary = res?;
        // fold per-device totals in device order so float sums match the
        // sequential path exactly
        let mut last_losses = Vec::with_capacity(devices);
        for t in &totals {
            summary.total_up_bits += t.up_bits;
            summary.total_down_bits += t.down_bits;
            summary.steps += t.steps;
            if t.departed {
                summary.departed += 1;
            }
            last_losses.push(t.last_round_loss);
        }
        summary.mean_loss_last_round = mean_loss(&last_losses);
        summary.final_acc = server.evaluate(test)?;
        summary.eval_history.push((self.rounds, summary.final_acc));
        summary.wall_s = t0.elapsed().as_secs_f64();
        summary.exec_s = server.exec_s();
        summary.link_s =
            LinkReport::aggregate(workers.iter().map(|w| w.link_report())).elapsed_s;
        Ok(summary)
    }

    /// The reference path: Algorithm 1's sequential round-robin, inline on
    /// the caller thread. The concurrent path at staleness 0 must produce
    /// byte-identical metrics to this loop.
    fn run_sequential(
        &self,
        server: &ParameterServer,
        workers: &mut [DeviceWorker],
        devices: usize,
        train: &Dataset,
        test: &Dataset,
        snapshot: Option<&(dyn Fn(usize) -> Result<()> + Sync)>,
    ) -> Result<TrainSummary> {
        let mut summary = TrainSummary::default();
        for t in self.first_round..=self.rounds {
            for w in workers.iter_mut() {
                if !w.script().participates(t) {
                    continue; // scenario: not joined yet, dropped out, or departed
                }
                let l = (t - 1) * devices + w.device;
                let rec = w
                    .run_step(t, l, self.first_step + l, train)
                    .with_context(|| format!("step t={t} k={}", w.device))?;
                log_debug!(
                    "t={t} k={} loss={:.4} acc={:.3} up={}b down={}b",
                    w.device,
                    rec.loss,
                    rec.train_acc,
                    rec.up_bits,
                    rec.down_bits
                );
            }
            if self.eval_every > 0 && t % self.eval_every == 0 {
                let acc = server.evaluate(test)?;
                summary.eval_history.push((t, acc));
                log_info!("round {t}: eval acc {:.4}", acc);
            }
            if self.ckpt_every > 0 && t % self.ckpt_every == 0 {
                if let Some(hook) = snapshot {
                    hook(t).with_context(|| format!("checkpoint at round {t}"))?;
                }
            }
        }
        Ok(summary)
    }

    /// The threaded path: contiguous device chunks on `concurrency` scoped
    /// threads, step entry gated PS-side by the staleness window, the
    /// scheduler thread serving eval barriers. Devices beyond the local
    /// workers are remote — their steps arrive over the listening
    /// transport and are awaited at the final watermark.
    fn run_concurrent(
        &self,
        endpoint: &PsEndpoint,
        server: &ParameterServer,
        workers: &mut [DeviceWorker],
        devices: usize,
        train: &Dataset,
        test: &Dataset,
        snapshot: Option<&(dyn Fn(usize) -> Result<()> + Sync)>,
    ) -> Result<TrainSummary> {
        let conc = self.concurrency.max(1);
        let chunk_len = ((workers.len() + conc - 1) / conc).max(1);
        let (rounds, eval_every, ckpt_every) = (self.rounds, self.eval_every, self.ckpt_every);
        let (first_step, first_round) = (self.first_step, self.first_round);
        let liveness = self.liveness;
        let gate = &endpoint.gate;

        let mut eval_history: Vec<(usize, f32)> = Vec::new();
        let mut eval_err: Option<crate::util::Error> = None;
        let results: Vec<Result<()>> = std::thread::scope(|s| {
            // released only after every worker handle is joined: if the
            // scheduler thread itself panics, the workers still unblock
            let mut scope_guard = AbortOnDrop { gate, armed: true };
            let handles: Vec<_> = workers
                .chunks_mut(chunk_len)
                .map(|chunk| {
                    s.spawn(move || {
                        let mut guard = AbortOnDrop { gate, armed: true };
                        let res =
                            drive_devices(chunk, train, first_step, first_round, rounds, devices);
                        guard.armed = res.is_err();
                        res
                    })
                })
                .collect();

            // liveness monitor: watches the watermark with a timeout; a
            // remote device that stays disconnected and silent past the
            // window is marked departed and its remaining steps skipped, so
            // the surviving cohort (and the waits below) make progress.
            // Exits on its own once the final watermark is reached (or the
            // gate aborts / the run is finished).
            if liveness.is_some() {
                s.spawn(move || {
                    let _ = endpoint.await_watermark_degraded(rounds * devices, liveness);
                });
            }

            // eval and checkpoint rounds are barriers: wait for the
            // boundary watermark (the fleet quiesces — no step of a later
            // round may start), evaluate / snapshot the frozen state, then
            // release the next round
            for t in barrier_rounds(first_round, rounds, eval_every, ckpt_every) {
                if gate.wait_watermark(t * devices).is_err() {
                    break; // a worker aborted; its error is joined below
                }
                if eval_every > 0 && t % eval_every == 0 {
                    match server.evaluate(test) {
                        Ok(acc) => {
                            eval_history.push((t, acc));
                            log_info!("round {t}: eval acc {:.4}", acc);
                        }
                        Err(e) => {
                            eval_err = Some(e);
                            gate.abort();
                            break;
                        }
                    }
                }
                if ckpt_every > 0 && t % ckpt_every == 0 {
                    if let Some(hook) = snapshot {
                        if let Err(e) = hook(t) {
                            eval_err = Some(e);
                            gate.abort();
                            break;
                        }
                    }
                }
                gate.eval_done(t);
            }

            let joined: Vec<_> = handles
                .into_iter()
                .map(|h| h.join().expect("device worker thread panicked"))
                .collect();
            scope_guard.armed = false;
            joined
        });
        if let Some(e) = eval_err {
            return Err(e);
        }

        // surface the root cause: a failing worker aborts the schedule, which
        // makes its peers fail with a generic "scheduler aborted" error —
        // prefer the first error that is NOT one of those secondary victims
        let mut first_err: Option<crate::util::Error> = None;
        for res in results {
            if let Err(e) = res {
                let keep_current = matches!(
                    &first_err,
                    Some(cur) if !cur.to_string().contains("scheduler aborted")
                );
                if !keep_current
                    && (first_err.is_none() || !e.to_string().contains("scheduler aborted"))
                {
                    first_err = Some(e);
                }
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }

        // remote devices: their commits advance the same watermark — block
        // until the whole schedule has committed
        if workers.len() < devices {
            gate.wait_watermark(rounds * devices)?;
        }

        let mut summary = TrainSummary::default();
        summary.eval_history = eval_history;
        Ok(summary)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_loss_matches_sequential_accumulation() {
        assert!(mean_loss(&[]).is_nan());
        let m = mean_loss(&[1.0, 2.0, 4.0]);
        assert!((m - (7.0 / 3.0)).abs() < 1e-6);
    }

    #[test]
    fn barrier_rounds_unions_eval_and_checkpoint_boundaries() {
        assert_eq!(barrier_rounds(1, 10, 0, 0), Vec::<usize>::new());
        assert_eq!(barrier_rounds(1, 10, 4, 0), vec![4, 8]);
        assert_eq!(barrier_rounds(1, 10, 0, 3), vec![3, 6, 9]);
        // shared boundary 6 served once
        assert_eq!(barrier_rounds(1, 12, 4, 6), vec![4, 6, 8, 12]);
        // resume from round 6: earlier boundaries are already released
        assert_eq!(barrier_rounds(7, 12, 4, 6), vec![8, 12]);
    }
}
