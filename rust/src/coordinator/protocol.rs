//! PS-side protocol logic, split from scheduling.
//!
//! [`PsEndpoint`] is the parameter server's message-level face: it owns the
//! per-device codec sessions, the staleness gate, the reply couriers that
//! make the protocol safe to replay across reconnects, and the per-device
//! run totals. One `serve` loop runs per connection — a thread with an
//! in-process channel or a thread with an accepted TCP socket — and every
//! loop is stateless, so a device that drops its connection mid-training
//! can come back on a fresh socket and resume exactly where it left off.
//!
//! **Gating.** [`RunGate`] generalizes the old scheduler-internal watermark
//! monitor: step entry (`StepStart`) blocks until every step with a
//! schedule-local index below `local - S·K` has committed and the eval
//! barrier for the step's round has been released. Because the gate lives
//! behind the endpoint, the staleness window works identically whether the
//! step request arrived from a thread or a socket.
//!
//! **At-most-once replay.** The worker resends its in-flight request after
//! a reconnect, so every handler must be idempotent. The per-device
//! [`Courier`] keys the cached `Downlink` reply on the step's local index
//! (a duplicate `Uplink` is answered from cache without re-running the
//! server pass) and remembers the last committed step (a duplicate
//! `Commit` is acked without re-applying the gradient). The shared
//! Algorithm-1 RNG stream is committed only when a *non-duplicate*
//! `Uplink` arrives, so a step re-granted after a disconnect re-exports
//! the identical state — byte-identity survives arbitrary mid-step cuts.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::checkpoint::LinkSnap;
use crate::compression::{Codec, CodecParams, Reclaim};
use crate::coordinator::metrics::StepRecord;
use crate::coordinator::server::ParameterServer;
use crate::model::f32_from_le_bytes;
use crate::transport::wire::{Frame, FrameKind};
use crate::transport::{Connection, Msg};
use crate::util::error::Result;
use std::sync::Arc;

/// The eval barrier a step of round `t` must wait for: the latest eval
/// boundary strictly before its round.
pub fn eval_gate(t: usize, eval_every: usize) -> usize {
    if eval_every == 0 {
        0
    } else {
        ((t - 1) / eval_every) * eval_every
    }
}

/// Serialize a parameter/gradient vector as a `ModelSync` wire frame
/// (little-endian f32), so model hand-offs cross the transport as real
/// bytes and get counted by the link model like any other frame.
pub fn model_sync_frame(data: &[f32]) -> Frame {
    let mut payload = Vec::with_capacity(data.len() * 4);
    for v in data {
        payload.extend_from_slice(&v.to_le_bytes());
    }
    let bits = payload.len() as u64 * 8;
    Frame::new(FrameKind::ModelSync, payload, bits)
}

struct GateState {
    /// false between runs: every gate call is then a no-op, which is what
    /// the manual single-step facade needs
    active: bool,
    done: Vec<bool>,
    /// every step with schedule-local index < watermark has committed
    watermark: usize,
    /// staleness window in steps (S·K); 0 = strict round-robin
    window: usize,
    eval_every: usize,
    /// snapshot cadence in rounds; checkpoint barriers gate step entry
    /// exactly like eval barriers so the fleet quiesces at the boundary
    ckpt_every: usize,
    /// last round whose eval/checkpoint barrier has been released
    eval_done_round: usize,
    aborted: bool,
}

/// Watermark monitor gating step entry: tracks out-of-order completion,
/// the longest finished prefix, eval barriers, and abort propagation.
/// Successor of the scheduler-internal `Progress` monitor — now PS-side,
/// so it gates socket peers exactly like thread peers.
pub struct RunGate {
    state: Mutex<GateState>,
    cv: Condvar,
}

impl Default for RunGate {
    fn default() -> RunGate {
        RunGate::new()
    }
}

impl RunGate {
    /// An inactive gate: all operations are no-ops until [`RunGate::begin`].
    pub fn new() -> RunGate {
        RunGate {
            state: Mutex::new(GateState {
                active: false,
                done: Vec::new(),
                watermark: 0,
                window: 0,
                eval_every: 0,
                ckpt_every: 0,
                eval_done_round: 0,
                aborted: false,
            }),
            cv: Condvar::new(),
        }
    }

    /// Arm the gate for a run of `total_steps` schedule-local steps.
    pub fn begin(&self, total_steps: usize, window: usize, eval_every: usize, ckpt_every: usize) {
        let mut st = self.state.lock().unwrap();
        st.active = true;
        st.done.clear();
        st.done.resize(total_steps, false);
        st.watermark = 0;
        st.window = window;
        st.eval_every = eval_every;
        st.ckpt_every = ckpt_every;
        st.eval_done_round = 0;
        st.aborted = false;
        self.cv.notify_all();
    }

    /// Disarm after a run; pending waiters are released.
    pub fn finish(&self) {
        let mut st = self.state.lock().unwrap();
        st.active = false;
        self.cv.notify_all();
    }

    /// Block until schedule-local step `local` of `round` may start: the
    /// watermark covers `local - window` and the eval barrier for the
    /// round's gate has been released.
    pub fn wait_start(&self, local: usize, round: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if !st.active {
                return Ok(());
            }
            if st.aborted {
                return Err(crate::err!("scheduler aborted (another worker failed)"));
            }
            let gate_round =
                eval_gate(round, st.eval_every).max(eval_gate(round, st.ckpt_every));
            if st.watermark + st.window >= local && st.eval_done_round >= gate_round {
                return Ok(());
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    pub fn complete(&self, local: usize) {
        let mut st = self.state.lock().unwrap();
        if !st.active || local >= st.done.len() {
            return;
        }
        st.done[local] = true;
        while st.watermark < st.done.len() && st.done[st.watermark] {
            st.watermark += 1;
        }
        self.cv.notify_all();
    }

    /// Pre-complete steps no device will run (scenario departures, delayed
    /// joins, dropout windows) so the watermark flows past absent peers.
    /// Idempotent; completing a skipped step later is harmless.
    pub fn skip(&self, locals: &[usize]) {
        if locals.is_empty() {
            return;
        }
        let mut st = self.state.lock().unwrap();
        if !st.active {
            return;
        }
        for &l in locals {
            if l < st.done.len() {
                st.done[l] = true;
            }
        }
        while st.watermark < st.done.len() && st.done[st.watermark] {
            st.watermark += 1;
        }
        self.cv.notify_all();
    }

    /// Graceful degradation: mark every remaining step owned by `device`
    /// (schedule-local indices ≡ device mod `devices`) as done, so the
    /// surviving cohort proceeds without it.
    pub fn skip_remaining_of_device(&self, device: usize, devices: usize) {
        if devices == 0 {
            return;
        }
        let mut st = self.state.lock().unwrap();
        if !st.active {
            return;
        }
        let mut l = device;
        while l < st.done.len() {
            st.done[l] = true;
            l += devices;
        }
        while st.watermark < st.done.len() && st.done[st.watermark] {
            st.watermark += 1;
        }
        self.cv.notify_all();
    }

    /// Block until the watermark reaches `target` (an eval round boundary
    /// or the end of the schedule).
    pub fn wait_watermark(&self, target: usize) -> Result<()> {
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return Err(crate::err!("scheduler aborted (a worker failed)"));
            }
            if !st.active || st.watermark >= target {
                return Ok(());
            }
            st = self.cv.wait(st).unwrap();
        }
    }

    /// Like [`RunGate::wait_watermark`] but bounded: returns `Ok(false)` if
    /// `timeout` elapses first — the liveness monitor's polling primitive.
    pub fn wait_watermark_for(&self, target: usize, timeout: Duration) -> Result<bool> {
        let deadline = Instant::now() + timeout;
        let mut st = self.state.lock().unwrap();
        loop {
            if st.aborted {
                return Err(crate::err!("scheduler aborted (a worker failed)"));
            }
            if !st.active || st.watermark >= target {
                return Ok(true);
            }
            let now = Instant::now();
            if now >= deadline {
                return Ok(false);
            }
            let (guard, _) = self.cv.wait_timeout(st, deadline - now).unwrap();
            st = guard;
        }
    }

    pub fn eval_done(&self, round: usize) {
        let mut st = self.state.lock().unwrap();
        st.eval_done_round = round;
        self.cv.notify_all();
    }

    pub fn abort(&self) {
        self.state.lock().unwrap().aborted = true;
        self.cv.notify_all();
    }

    /// Longest committed (or skipped) prefix of the schedule.
    pub fn watermark(&self) -> usize {
        self.state.lock().unwrap().watermark
    }
}

/// Aborts the gate on drop unless disarmed — so a worker that errors or
/// panics mid-step unblocks every peer waiting on the watermark instead of
/// deadlocking the scope join.
pub struct AbortOnDrop<'a> {
    pub gate: &'a RunGate,
    pub armed: bool,
}

impl Drop for AbortOnDrop<'_> {
    fn drop(&mut self) {
        if self.armed {
            self.gate.abort();
        }
    }
}

/// Per-device reply courier: the replay cache that makes the protocol
/// at-most-once under reconnects, plus the server-half execution time the
/// `Commit` handler folds into the step's metrics record.
#[derive(Default)]
struct Courier {
    /// schedule-local index of the last committed step (duplicate `Commit`
    /// detection)
    last_committed: Option<u64>,
    /// the step whose `Downlink` reply is cached (duplicate `Uplink`
    /// detection)
    cached_uplink_local: Option<u64>,
    cached_downlink: Option<Msg>,
    /// server backend time of the in-flight step's `process_uplink`
    server_dt: f64,
}

/// Per-device totals accumulated PS-side at `Commit` (so they exist even
/// for devices on remote processes).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeviceTotals {
    pub up_bits: u64,
    pub down_bits: u64,
    pub steps: usize,
    pub last_round_loss: f32,
    /// marked by the liveness policy: this device went silent while
    /// disconnected and the run proceeded without it
    pub departed: bool,
}

impl Default for DeviceTotals {
    fn default() -> DeviceTotals {
        DeviceTotals {
            up_bits: 0,
            down_bits: 0,
            steps: 0,
            last_round_loss: f32::NAN,
            departed: false,
        }
    }
}

/// Per-device liveness the PS tracks to degrade gracefully instead of
/// deadlocking on a vanished peer.
struct DevLive {
    /// open `serve` loops currently bound to this device
    connections: usize,
    /// last time a bound connection delivered a message (or closed)
    last_seen: Instant,
    departed: bool,
}

impl DevLive {
    fn fresh() -> DevLive {
        DevLive { connections: 0, last_seen: Instant::now(), departed: false }
    }
}

struct RunInfo {
    rounds: usize,
    /// global-step tag of the run's first schedule-local step
    first_step: usize,
}

/// Recovery telemetry surfaced in the run summary and the MTTR bench row.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct RecoveryStats {
    /// PS incarnations beyond the first: in-process `pscrash[...]`
    /// restarts plus a process-level `--resume`.
    pub ps_restarts: usize,
    /// Cumulative wall time from each restart to the first step message
    /// handled afterwards — the run's observed time-to-recover.
    pub recover_s: f64,
    /// Replay absorbed after recovery: duplicate requests answered from
    /// the couriers plus metrics records rolled back by `--resume`.
    pub steps_replayed: usize,
}

#[derive(Default)]
struct RecoveryState {
    stats: RecoveryStats,
    /// armed by a restart, consumed by the next handled step message
    pending: Option<Instant>,
}

/// The parameter server's message-level endpoint: protocol handlers +
/// per-device sessions, independent of which transport carries the bytes.
pub struct PsEndpoint {
    server: Arc<ParameterServer>,
    devices: usize,
    staleness: usize,
    up_params: CodecParams,
    down_params: CodecParams,
    /// PS-side codec sessions, one per device link (uplink decode +
    /// downlink encode)
    codecs: Vec<Mutex<Box<dyn Codec>>>,
    couriers: Vec<Mutex<Courier>>,
    pub gate: RunGate,
    totals: Mutex<Vec<DeviceTotals>>,
    liveness: Mutex<Vec<DevLive>>,
    run: Mutex<RunInfo>,
    /// expected ∇w_d payload length (bytes) for `Commit` validation
    nd_bytes: usize,
    /// latest per-device state blob, refreshed at every `Commit` while
    /// checkpointing and replayed to devices that re-`Hello` after a resume
    dev_states: Vec<Mutex<Option<Vec<u8>>>>,
    /// snapshot cadence in rounds (0 = no checkpointing); set before the
    /// endpoint is shared
    ckpt_every: usize,
    /// schedule round the next run starts at: 1 fresh, `round + 1` resumed
    first_round: usize,
    /// run totals restored from a checkpoint, seeded into `begin_run`
    resume_totals: Option<Vec<DeviceTotals>>,
    /// restart/MTTR/replay bookkeeping (see [`RecoveryStats`])
    recovery: Mutex<RecoveryState>,
    /// step replies (StepGo/Downlink/CommitAck) written to a connection —
    /// the ordinal the scenario `pscrash[send=N]` form triggers on
    step_sends: AtomicU64,
}

impl PsEndpoint {
    pub fn new(
        server: Arc<ParameterServer>,
        staleness: usize,
        up_params: CodecParams,
        down_params: CodecParams,
        codecs: Vec<Box<dyn Codec>>,
        nd_params: usize,
    ) -> PsEndpoint {
        let devices = codecs.len();
        PsEndpoint {
            server,
            devices,
            staleness,
            up_params,
            down_params,
            codecs: codecs.into_iter().map(Mutex::new).collect(),
            couriers: (0..devices).map(|_| Mutex::new(Courier::default())).collect(),
            gate: RunGate::new(),
            totals: Mutex::new(vec![DeviceTotals::default(); devices]),
            liveness: Mutex::new((0..devices).map(|_| DevLive::fresh()).collect()),
            run: Mutex::new(RunInfo { rounds: usize::MAX, first_step: 0 }),
            nd_bytes: nd_params * 4,
            dev_states: (0..devices).map(|_| Mutex::new(None)).collect(),
            ckpt_every: 0,
            first_round: 1,
            resume_totals: None,
            recovery: Mutex::new(RecoveryState::default()),
            step_sends: AtomicU64::new(0),
        }
    }

    /// Enable checkpointing: devices are told (via the handshake) to attach
    /// their state blob at every `Commit`, and snapshot barriers gate step
    /// entry every `ckpt_every` rounds. Call before sharing the endpoint.
    pub fn set_checkpoint(&mut self, ckpt_every: usize) {
        self.ckpt_every = ckpt_every;
    }

    /// Schedule round the next run starts at (1 unless resumed).
    pub fn first_round(&self) -> usize {
        self.first_round
    }

    /// Prime the endpoint with a restored checkpoint taken after `round`
    /// completed rounds: the next [`PsEndpoint::begin_run`] pre-completes
    /// those rounds and seeds their totals, the PS-side codec sessions are
    /// restored, and devices that (re-)`Hello` receive their state blob
    /// through the handshake. Call before sharing the endpoint — a failure
    /// here aborts startup before any run state exists.
    pub fn prime_resume(
        &mut self,
        round: usize,
        totals: Vec<DeviceTotals>,
        links: &[LinkSnap],
    ) -> Result<()> {
        crate::ensure!(round >= 1, "cannot resume from a checkpoint at round 0");
        crate::ensure!(
            totals.len() == self.devices && links.len() == self.devices,
            "checkpoint fleet shape mismatch: {} totals / {} links for {} devices",
            totals.len(),
            links.len(),
            self.devices
        );
        for (d, link) in links.iter().enumerate() {
            self.codecs[d]
                .lock()
                .unwrap()
                .restore_session(&link.ps_session)
                .map_err(|e| crate::err!("device {d} PS codec session: {e}"))?;
            *self.dev_states[d].lock().unwrap() = link.device.clone();
        }
        self.first_round = round + 1;
        self.resume_totals = Some(totals);
        Ok(())
    }

    /// Restore the endpoint mid-run from a just-reloaded checkpoint, after
    /// an in-process PS crash (`pscrash[...]`): PS codec sessions and
    /// device state blobs come back from the snapshot, totals roll back to
    /// the barrier values, and every courier resets — exactly the state a
    /// freshly-resumed process would build. The gate needs no re-arm:
    /// crashes fire only at quiesced checkpoint barriers, where the
    /// watermark already equals `round · devices`. Increments
    /// `ps_restarts` and starts the time-to-recover clock.
    pub fn crash_restore(&self, totals: Vec<DeviceTotals>, links: &[LinkSnap]) -> Result<()> {
        crate::ensure!(
            totals.len() == self.devices && links.len() == self.devices,
            "checkpoint fleet shape mismatch: {} totals / {} links for {} devices",
            totals.len(),
            links.len(),
            self.devices
        );
        for (d, link) in links.iter().enumerate() {
            self.codecs[d]
                .lock()
                .unwrap()
                .restore_session(&link.ps_session)
                .map_err(|e| crate::err!("device {d} PS codec session: {e}"))?;
            *self.dev_states[d].lock().unwrap() = link.device.clone();
        }
        self.totals.lock().unwrap().clone_from(&totals);
        for c in &self.couriers {
            *c.lock().unwrap() = Courier::default();
        }
        self.note_restart();
        Ok(())
    }

    /// Record a PS restart (in-process crash, or a process-level `--resume`
    /// — the trainer calls this after priming one) and start the
    /// time-to-recover clock; the next handled step message stops it.
    pub fn note_restart(&self) {
        let mut r = self.recovery.lock().unwrap();
        r.stats.ps_restarts += 1;
        r.pending = Some(Instant::now());
    }

    /// Fold externally-observed replay into the telemetry (the trainer adds
    /// the metrics records a `--resume` rolled back).
    pub fn add_replayed(&self, n: usize) {
        self.recovery.lock().unwrap().stats.steps_replayed += n;
    }

    /// Read the recovery telemetry; a clock still pending (crash with no
    /// step handled afterwards) is closed at readout.
    pub fn recovery_stats(&self) -> RecoveryStats {
        let mut r = self.recovery.lock().unwrap();
        if let Some(t0) = r.pending.take() {
            r.stats.recover_s += t0.elapsed().as_secs_f64();
        }
        r.stats
    }

    fn note_step_activity(&self) {
        let mut r = self.recovery.lock().unwrap();
        if let Some(t0) = r.pending.take() {
            r.stats.recover_s += t0.elapsed().as_secs_f64();
        }
    }

    fn note_replayed(&self) {
        self.recovery.lock().unwrap().stats.steps_replayed += 1;
    }

    /// Cumulative step replies (StepGo/Downlink/CommitAck) written to a
    /// connection. Counted at quiesced barriers this is deterministic, so
    /// `pscrash[send=N]` (crash at the first checkpoint barrier with at
    /// least N step replies out) replays exactly across identical runs.
    pub fn step_sends(&self) -> u64 {
        self.step_sends.load(Ordering::Relaxed)
    }

    /// Per-link checkpoint state: the PS codec session plus the latest
    /// device blob, in device order.
    pub fn export_links(&self) -> Vec<LinkSnap> {
        (0..self.devices)
            .map(|d| LinkSnap {
                ps_session: self.codecs[d].lock().unwrap().export_session(),
                device: self.dev_states[d].lock().unwrap().clone(),
            })
            .collect()
    }

    pub fn devices(&self) -> usize {
        self.devices
    }

    /// Arm the endpoint for a `rounds`-round scheduled run: reset couriers,
    /// totals, and liveness, record the global-step origin, arm the gate,
    /// and pre-complete `skips` — schedule-local steps the scenario
    /// timeline says no device will run (departures, delayed joins,
    /// dropout windows).
    /// `eval_every` / `ckpt_every` arm the gate's round barriers — pass 0
    /// when the caller serves that boundary inline (the sequential driver).
    pub fn begin_run(
        &self,
        rounds: usize,
        first_step: usize,
        eval_every: usize,
        ckpt_every: usize,
        skips: &[usize],
    ) {
        *self.run.lock().unwrap() = RunInfo { rounds, first_step };
        {
            let mut totals = self.totals.lock().unwrap();
            match &self.resume_totals {
                Some(seed) => totals.clone_from(seed),
                None => totals.iter_mut().for_each(|t| *t = DeviceTotals::default()),
            }
        }
        for c in &self.couriers {
            *c.lock().unwrap() = Courier::default();
        }
        for l in self.liveness.lock().unwrap().iter_mut() {
            l.departed = false;
            l.last_seen = Instant::now();
        }
        self.gate.begin(
            rounds * self.devices,
            self.staleness * self.devices,
            eval_every,
            ckpt_every,
        );
        // resume: the checkpointed rounds are already committed — their
        // schedule-local steps pre-complete and their barriers are released
        let resumed = self.first_round - 1;
        if resumed > 0 {
            let done: Vec<usize> = (0..resumed * self.devices).collect();
            self.gate.skip(&done);
            self.gate.eval_done(resumed);
        }
        self.gate.skip(skips);
    }

    /// Disarm the gate and hand back the run's per-device totals (callers
    /// fold them in device order so float sums stay deterministic).
    pub fn finish_run(&self) -> Vec<DeviceTotals> {
        self.gate.finish();
        self.totals.lock().unwrap().clone()
    }

    /// The per-device totals as of now — read at a quiesced checkpoint
    /// barrier, where they are exact.
    pub fn totals_snapshot(&self) -> Vec<DeviceTotals> {
        self.totals.lock().unwrap().clone()
    }

    /// Configure for manual single-step driving (the `Trainer::step`
    /// facade): gate inactive, records tagged with the caller's raw step
    /// index.
    pub fn begin_manual(&self) {
        self.gate.finish();
        *self.run.lock().unwrap() = RunInfo { rounds: usize::MAX, first_step: 0 };
    }

    /// Serve one connection until the peer leaves or the link drops. A
    /// dead link is a normal return — the peer reconnects and a fresh
    /// `serve` loop picks up, with all state in the endpoint. Set
    /// `cache_replays` on transports whose peers can reconnect (TCP), so
    /// duplicate `Uplink`s can be answered from the courier cache.
    ///
    /// The loop also feeds the liveness tracker: the first device-carrying
    /// message binds the connection to that device, every further message
    /// refreshes its `last_seen`, and loop exit (peer gone, Bye, Abort)
    /// releases the binding — so "disconnected and silent" is observable.
    pub fn serve(&self, conn: &mut dyn Connection, cache_replays: bool) -> Result<()> {
        let mut bound: Option<usize> = None;
        loop {
            let msg = match conn.recv() {
                Ok(m) => m,
                Err(_) => break, // peer gone; reconnect spawns a new loop
            };
            match msg.device().map(|d| d as usize) {
                Some(dev) if dev < self.devices => {
                    if bound == Some(dev) {
                        self.touch(dev);
                    } else {
                        if let Some(old) = bound {
                            self.connection_closed(old);
                        }
                        self.connection_opened(dev);
                        bound = Some(dev);
                    }
                }
                _ => {}
            }
            let reply = match self.handle(msg, cache_replays) {
                Ok(Some(r)) => r,
                Ok(None) => break, // clean Bye
                Err(e) => Msg::Abort { reason: e.to_string() },
            };
            let fatal = matches!(reply, Msg::Abort { .. });
            let step_reply =
                matches!(reply, Msg::StepGo { .. } | Msg::Downlink { .. } | Msg::CommitAck);
            if conn.send(reply).is_err() || fatal {
                break;
            }
            if step_reply {
                self.step_sends.fetch_add(1, Ordering::Relaxed);
            }
        }
        if let Some(dev) = bound {
            self.connection_closed(dev);
        }
        Ok(())
    }

    fn connection_opened(&self, dev: usize) {
        let mut live = self.liveness.lock().unwrap();
        live[dev].connections += 1;
        live[dev].last_seen = Instant::now();
    }

    fn connection_closed(&self, dev: usize) {
        let mut live = self.liveness.lock().unwrap();
        live[dev].connections = live[dev].connections.saturating_sub(1);
        live[dev].last_seen = Instant::now();
    }

    fn touch(&self, dev: usize) {
        self.liveness.lock().unwrap()[dev].last_seen = Instant::now();
    }

    /// Wait for the watermark to reach `target`, degrading gracefully:
    /// whenever progress stalls on a device that has no open connection
    /// and has been silent for the `liveness` window, that device is
    /// marked departed and its remaining steps are skipped, so the
    /// surviving cohort finishes the run. `liveness` of `None` waits
    /// forever (today's behavior).
    ///
    /// The window must comfortably exceed the workers' retry deadline: a
    /// device mid-backoff is disconnected too, and departing it early
    /// turns a recoverable cut into a rejected resend.
    pub fn await_watermark_degraded(
        &self,
        target: usize,
        liveness: Option<Duration>,
    ) -> Result<()> {
        let window = match liveness {
            Some(w) => w,
            None => return self.gate.wait_watermark(target),
        };
        let tick = Duration::from_millis(50).min(window);
        loop {
            if self.gate.wait_watermark_for(target, tick)? {
                return Ok(());
            }
            // stalled: the watermark step's owner is `watermark % devices`
            let owner = self.gate.watermark() % self.devices;
            let silent = {
                let live = self.liveness.lock().unwrap();
                let l = &live[owner];
                !l.departed && l.connections == 0 && l.last_seen.elapsed() >= window
            };
            if silent {
                self.mark_departed(owner);
            }
        }
    }

    /// Mark `device` departed: reject its future requests, pre-complete its
    /// remaining steps, and record the departure in the run totals.
    pub fn mark_departed(&self, device: usize) {
        {
            let mut live = self.liveness.lock().unwrap();
            if live[device].departed {
                return;
            }
            live[device].departed = true;
        }
        crate::log_warn!(
            "device {device} departed (liveness timeout); continuing with the surviving cohort"
        );
        self.totals.lock().unwrap()[device].departed = true;
        self.gate.skip_remaining_of_device(device, self.devices);
    }

    fn handle(&self, msg: Msg, cache_replays: bool) -> Result<Option<Msg>> {
        match msg {
            Msg::Hello { device, codec_id, codec_version } => {
                Ok(Some(self.handle_hello(device, codec_id, codec_version)))
            }
            Msg::StepStart { device, round, local } => {
                self.check_device(device)?;
                self.note_step_activity();
                self.gate.wait_start(local as usize, round as usize)?;
                let wd = self.server.snapshot_device_params();
                let rng = if self.staleness == 0 {
                    // exported, NOT committed: a re-granted step after a
                    // disconnect re-exports the identical state
                    Some(self.server.with_rng(|r| r.export_state()))
                } else {
                    None
                };
                Ok(Some(Msg::StepGo { wd: model_sync_frame(&wd.data), rng }))
            }
            Msg::Uplink { device, local, frame, labels, mask, up_nominal, rng } => {
                let _ = up_nominal; // reported again in the Commit StepReport
                self.check_device(device)?;
                self.note_step_activity();
                let mut courier = self.couriers[device as usize].lock().unwrap();
                if courier.cached_uplink_local == Some(local) {
                    if let Some(cached) = courier.cached_downlink.clone() {
                        drop(courier);
                        self.note_replayed();
                        return Ok(Some(cached)); // duplicate after reconnect
                    }
                }
                let mut codec = self.codecs[device as usize].lock().unwrap();
                let dec = codec.decode_uplink(&frame, &self.up_params)?;
                // RNG commit point: the step's draws are now consumed
                if let Some(st) = rng {
                    self.server.with_rng(|r| r.restore_state(&st));
                }
                let (out, dt) = self.server.process_uplink(&dec.f_hat, &labels)?;
                courier.server_dt = dt;
                let dn = codec.encode_downlink(&out.g, &mask, &self.down_params)?;
                codec.reclaim(Reclaim::Frame(frame));
                codec.reclaim(Reclaim::Decoded(dec));
                let reply = Msg::Downlink {
                    frame: dn.frame,
                    loss: out.loss,
                    correct: out.correct,
                    server_exec_s: dt,
                    down_nominal: dn.nominal_bits,
                };
                codec.reclaim(Reclaim::Grad(dn.g_hat));
                if cache_replays {
                    courier.cached_uplink_local = Some(local);
                    courier.cached_downlink = Some(reply.clone());
                }
                Ok(Some(reply))
            }
            Msg::Commit { device, round, local, grad, report, state } => {
                self.check_device(device)?;
                if let Some(blob) = state {
                    // freshest post-step device state; a duplicate Commit
                    // after a reconnect carries the identical blob, so
                    // re-stashing is harmless
                    *self.dev_states[device as usize].lock().unwrap() = Some(blob);
                }
                self.note_step_activity();
                let mut courier = self.couriers[device as usize].lock().unwrap();
                if courier.last_committed == Some(local) {
                    drop(courier);
                    self.note_replayed();
                    return Ok(Some(Msg::CommitAck)); // duplicate after reconnect
                }
                crate::ensure!(
                    grad.payload.len() == self.nd_bytes,
                    "device {device} gradient payload is {} bytes, expected {}",
                    grad.payload.len(),
                    self.nd_bytes
                );
                let grad_wd = f32_from_le_bytes(&grad.payload);
                self.server.apply_device_grad(device as usize, &grad_wd);
                self.server.add_exec(report.device_exec_s);
                let (rounds, first_step) = {
                    let run = self.run.lock().unwrap();
                    (run.rounds, run.first_step)
                };
                let rec = StepRecord {
                    round: round as usize,
                    device: device as usize,
                    global_step: first_step + local as usize,
                    loss: report.loss,
                    train_acc: report.train_acc,
                    up_bits: report.up_bits,
                    down_bits: report.down_bits,
                    up_nominal: report.up_nominal,
                    down_nominal: report.down_nominal,
                    step_s: report.step_s,
                    exec_s: report.device_exec_s + courier.server_dt,
                };
                self.server.write_metrics(&rec.to_json());
                {
                    let mut totals = self.totals.lock().unwrap();
                    let t = &mut totals[device as usize];
                    t.up_bits += report.up_bits;
                    t.down_bits += report.down_bits;
                    t.steps += 1;
                    if round as usize == rounds {
                        t.last_round_loss = report.loss;
                    }
                }
                courier.last_committed = Some(local);
                courier.cached_uplink_local = None;
                courier.cached_downlink = None;
                drop(courier);
                self.gate.complete(local as usize);
                Ok(Some(Msg::CommitAck))
            }
            Msg::FetchModel { device } => {
                self.check_device(device)?;
                let wd = self.server.snapshot_device_params();
                Ok(Some(Msg::ModelReply { wd: model_sync_frame(&wd.data) }))
            }
            Msg::Bye { .. } => Ok(None),
            other => Err(crate::err!(
                "unexpected {} message at the parameter server",
                other.name()
            )),
        }
    }

    fn handle_hello(&self, device: u32, codec_id: u32, codec_version: u16) -> Msg {
        let rounds = self.run.lock().unwrap().rounds;
        let ack = |state: Option<Vec<u8>>, err: Option<String>| Msg::HelloAck {
            devices: self.devices as u32,
            rounds: rounds.min(u32::MAX as usize) as u32,
            staleness: self.staleness as u32,
            first_round: self.first_round as u32,
            ckpt_every: self.ckpt_every as u32,
            state,
            err,
        };
        if device as usize >= self.devices {
            return ack(
                None,
                Some(format!(
                    "device index {device} out of range (fleet has {})",
                    self.devices
                )),
            );
        }
        if self.liveness.lock().unwrap()[device as usize].departed {
            return ack(
                None,
                Some(format!(
                    "device {device} was marked departed after a liveness timeout; \
                     the run proceeded without it"
                )),
            );
        }
        let codec = self.codecs[device as usize].lock().unwrap();
        let (want_id, want_ver) = (codec.wire_id(), codec.wire_version());
        if (codec_id, codec_version) != (want_id, want_ver) {
            return ack(
                None,
                Some(format!(
                    "codec mismatch: device speaks {codec_id:#010x} v{codec_version}, \
                     server session is {want_id:#010x} v{want_ver}"
                )),
            );
        }
        ack(self.dev_states[device as usize].lock().unwrap().clone(), None)
    }

    fn check_device(&self, device: u32) -> Result<()> {
        crate::ensure!(
            (device as usize) < self.devices,
            "device index {device} out of range (fleet has {})",
            self.devices
        );
        crate::ensure!(
            !self.liveness.lock().unwrap()[device as usize].departed,
            "device {device} was marked departed after a liveness timeout; \
             the run proceeded without it"
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn armed_gate(total: usize, window: usize, eval_every: usize) -> RunGate {
        let g = RunGate::new();
        g.begin(total, window, eval_every, 0);
        g
    }

    #[test]
    fn watermark_advances_over_out_of_order_completion() {
        let g = armed_gate(4, 0, 0);
        g.complete(2);
        assert_eq!(g.watermark(), 0);
        g.complete(0);
        assert_eq!(g.watermark(), 1);
        g.complete(1);
        // 0,1,2 done -> watermark jumps past the out-of-order step
        assert_eq!(g.watermark(), 3);
        g.complete(3);
        assert_eq!(g.watermark(), 4);
    }

    #[test]
    fn strict_window_blocks_and_releases() {
        // S=0 (window 0): step 1 must wait for step 0; once 0 completes the
        // start gate opens without blocking
        let g = armed_gate(2, 0, 0);
        g.complete(0);
        assert!(g.wait_start(1, 1).is_ok());
    }

    #[test]
    fn stale_window_admits_lookahead() {
        // window 2: steps 1 and 2 may start with nothing completed, step 3
        // may not until the watermark reaches 1
        let g = armed_gate(8, 2, 0);
        assert!(g.wait_start(2, 1).is_ok());
        g.complete(0);
        assert!(g.wait_start(3, 1).is_ok());
    }

    #[test]
    fn abort_unblocks_waiters_with_error() {
        let g = armed_gate(4, 0, 0);
        g.abort();
        assert!(g.wait_start(3, 1).is_err());
        assert!(g.wait_watermark(4).is_err());
    }

    #[test]
    fn inactive_gate_is_a_no_op() {
        let g = RunGate::new();
        // no begin(): manual stepping must pass straight through
        assert!(g.wait_start(17, 3).is_ok());
        g.complete(17); // out of range of the (empty) done map: ignored
        assert!(g.wait_watermark(usize::MAX).is_ok());
    }

    #[test]
    fn finish_releases_and_begin_rearms() {
        let g = armed_gate(2, 0, 0);
        g.finish();
        assert!(g.wait_start(1, 1).is_ok(), "finished gate must not block");
        g.begin(2, 0, 0, 0);
        g.complete(0);
        assert_eq!(g.watermark(), 1);
    }

    #[test]
    fn checkpoint_barrier_gates_step_entry_like_eval() {
        // 1 device, window large enough that the watermark never blocks;
        // ckpt_every = 2 must still hold round 3 until barrier 2 releases
        let g = RunGate::new();
        g.begin(6, 100, 0, 2);
        g.complete(0);
        g.complete(1);
        assert!(!g.wait_watermark_for(3, Duration::from_millis(5)).unwrap());
        // round 3 is gated on the checkpoint barrier at round 2
        let blocked = std::sync::atomic::AtomicBool::new(true);
        std::thread::scope(|s| {
            s.spawn(|| {
                g.wait_start(2, 3).unwrap();
                blocked.store(false, std::sync::atomic::Ordering::SeqCst);
            });
            std::thread::sleep(Duration::from_millis(20));
            assert!(blocked.load(std::sync::atomic::Ordering::SeqCst));
            g.eval_done(2);
        });
        assert!(!blocked.load(std::sync::atomic::Ordering::SeqCst));
    }

    #[test]
    fn skips_pre_advance_the_watermark() {
        // 2 devices x 3 rounds; device 1 never runs -> its steps 1, 3, 5
        // are pre-completed and the watermark flows past them
        let g = armed_gate(6, 0, 0);
        g.skip(&[1, 3, 5]);
        assert_eq!(g.watermark(), 0);
        g.complete(0);
        assert_eq!(g.watermark(), 2);
        g.complete(2);
        assert_eq!(g.watermark(), 4);
        g.complete(4);
        assert_eq!(g.watermark(), 6);
    }

    #[test]
    fn skip_remaining_of_device_unblocks_the_cohort() {
        let g = armed_gate(8, 0, 0); // 4 devices x 2 rounds
        g.complete(0);
        g.complete(1);
        g.complete(2);
        assert_eq!(g.watermark(), 3); // stalled on device 3
        g.skip_remaining_of_device(3, 4);
        assert_eq!(g.watermark(), 4);
        assert!(g.wait_start(4, 2).is_ok());
    }

    #[test]
    fn wait_watermark_for_times_out_then_succeeds() {
        let g = armed_gate(2, 0, 0);
        assert!(!g.wait_watermark_for(2, Duration::from_millis(10)).unwrap());
        g.complete(0);
        g.complete(1);
        assert!(g.wait_watermark_for(2, Duration::from_millis(10)).unwrap());
        g.abort();
        assert!(g.wait_watermark_for(2, Duration::from_millis(10)).is_err());
    }

    #[test]
    fn eval_gate_is_latest_boundary_before_round() {
        assert_eq!(eval_gate(1, 0), 0);
        assert_eq!(eval_gate(1, 2), 0);
        assert_eq!(eval_gate(2, 2), 0);
        assert_eq!(eval_gate(3, 2), 2);
        assert_eq!(eval_gate(4, 2), 2);
        assert_eq!(eval_gate(5, 2), 4);
    }

    #[test]
    fn model_sync_frame_roundtrips_f32() {
        let data = vec![1.0f32, -2.5, 0.0, f32::MIN_POSITIVE];
        let f = model_sync_frame(&data);
        assert_eq!(f.kind, FrameKind::ModelSync);
        assert_eq!(f.payload_bits, data.len() as u64 * 32);
        assert_eq!(f32_from_le_bytes(&f.payload), data);
    }
}
