//! Per-step metrics, summaries and JSONL emission.

use std::io::{Seek, Write};

use crate::checkpoint::CkptError;
use crate::util::error::Result;
use crate::util::Json;

#[derive(Debug, Clone)]
pub struct StepRecord {
    pub round: usize,
    pub device: usize,
    /// global step index: the step's position in the strict round-robin
    /// order (a run offset + (round-1)*K + device), stable across concurrent
    /// and sequential execution and unique across a trainer's lifetime
    pub global_step: usize,
    pub loss: f32,
    pub train_acc: f32,
    /// measured payload bits
    pub up_bits: u64,
    pub down_bits: u64,
    /// paper-formula bits (for cross-checking the accounting)
    pub up_nominal: f64,
    pub down_nominal: f64,
    /// host wall time of the whole step / of PJRT execution within it
    pub step_s: f64,
    pub exec_s: f64,
}

impl StepRecord {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("t", Json::num(self.round as f64)),
            ("k", Json::num(self.device as f64)),
            ("g", Json::num(self.global_step as f64)),
            ("loss", Json::num(self.loss as f64)),
            ("train_acc", Json::num(self.train_acc as f64)),
            ("up_bits", Json::num(self.up_bits as f64)),
            ("down_bits", Json::num(self.down_bits as f64)),
            ("up_nominal", Json::num(self.up_nominal)),
            ("down_nominal", Json::num(self.down_nominal)),
            ("step_s", Json::num(self.step_s)),
            ("exec_s", Json::num(self.exec_s)),
        ])
    }
}

#[derive(Debug, Clone, Default)]
pub struct TrainSummary {
    pub final_acc: f32,
    pub eval_history: Vec<(usize, f32)>,
    pub mean_loss_last_round: f32,
    pub total_up_bits: u64,
    pub total_down_bits: u64,
    pub steps: usize,
    /// devices marked departed by the PS liveness policy (0 on calm runs)
    pub departed: usize,
    pub wall_s: f64,
    pub exec_s: f64,
    /// modeled transfer time over the simulated link
    pub link_s: f64,
    /// PS incarnations beyond the first: in-process `pscrash[...]`
    /// restarts plus a process-level `--resume` (0 on undisturbed runs)
    pub ps_restarts: usize,
    /// cumulative wall time from each PS restart to the first step message
    /// handled afterwards — the run's observed time-to-recover
    pub recover_s: f64,
    /// replay absorbed after recovery: duplicate requests answered from
    /// the couriers plus metrics records rolled back by `--resume`
    pub steps_replayed: usize,
}

impl TrainSummary {
    pub fn uplink_bits_per_entry(&self, batch: usize, dbar: usize) -> f64 {
        self.total_up_bits as f64 / (self.steps as f64 * (batch * dbar) as f64)
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("final_acc", Json::num(self.final_acc as f64)),
            ("mean_loss_last_round", Json::num(self.mean_loss_last_round as f64)),
            ("total_up_bits", Json::num(self.total_up_bits as f64)),
            ("total_down_bits", Json::num(self.total_down_bits as f64)),
            ("steps", Json::num(self.steps as f64)),
            ("departed", Json::num(self.departed as f64)),
            ("wall_s", Json::num(self.wall_s)),
            ("exec_s", Json::num(self.exec_s)),
            ("link_s", Json::num(self.link_s)),
            ("ps_restarts", Json::num(self.ps_restarts as f64)),
            ("recover_s", Json::num(self.recover_s)),
            ("steps_replayed", Json::num(self.steps_replayed as f64)),
            (
                "eval_history",
                Json::Arr(
                    self.eval_history
                        .iter()
                        .map(|&(t, a)| {
                            Json::Arr(vec![Json::num(t as f64), Json::num(a as f64)])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Line-per-record JSONL writer (metrics stream). Not internally locked:
/// the concurrent coordinator serializes access through a `Mutex` in
/// `ParameterServer`, so records from parallel device workers never tear.
pub struct MetricsWriter {
    out: Option<std::io::BufWriter<std::fs::File>>,
    /// complete step records [`MetricsWriter::resume`] rolled back — the
    /// steps the interrupted run had written past the checkpoint barrier,
    /// which the resumed run replays (recovery telemetry)
    pub truncated_records: usize,
}

impl MetricsWriter {
    pub fn create(path: &str) -> MetricsWriter {
        if path.is_empty() {
            return MetricsWriter { out: None, truncated_records: 0 };
        }
        let f = std::fs::File::create(path)
            .unwrap_or_else(|e| panic!("cannot create metrics file {path:?}: {e}"));
        MetricsWriter { out: Some(std::io::BufWriter::new(f)), truncated_records: 0 }
    }

    /// Reopen `path` for **appending** after `--resume` — the fix for the
    /// historical truncate-on-open: `create` would have wiped the records
    /// the interrupted run already earned. Verifies the file is at least as
    /// long as when the checkpoint was taken (`expect_len`, captured after
    /// a flush at the barrier), truncates everything past it — records the
    /// killed run wrote after the snapshot, including a torn trailing line
    /// — and cross-checks the surviving tail record's global step against
    /// the checkpoint boundary (`boundary_g` = steps committed at it).
    pub fn resume(path: &str, expect_len: u64, boundary_g: u64) -> Result<MetricsWriter> {
        if path.is_empty() {
            return Ok(MetricsWriter { out: None, truncated_records: 0 });
        }
        let mismatch = |reason: String| CkptError::MetricsMismatch { reason };
        let f = std::fs::OpenOptions::new()
            .read(true)
            .write(true)
            .open(path)
            .map_err(|e| mismatch(format!("cannot open metrics file {path:?}: {e}")))?;
        let len = f
            .metadata()
            .map_err(|e| mismatch(format!("cannot stat metrics file {path:?}: {e}")))?
            .len();
        if len < expect_len {
            return Err(mismatch(format!(
                "metrics file {path:?} is {len} bytes but the checkpoint recorded \
                 {expect_len} — resuming into the wrong file would corrupt it"
            ))
            .into());
        }
        let mut truncated_records = 0;
        if len > expect_len {
            // count the complete step records being rolled back: they are
            // the steps the resumed run will replay (a torn trailing line
            // is debris, not a step)
            let text = std::fs::read_to_string(path)
                .map_err(|e| mismatch(format!("cannot read {path:?}: {e}")))?;
            if (expect_len as usize) <= text.len() {
                truncated_records = text[expect_len as usize..]
                    .lines()
                    .filter(|l| Json::parse(l).map(|j| j.get("g").is_some()).unwrap_or(false))
                    .count();
            }
            f.set_len(expect_len)
                .map_err(|e| mismatch(format!("cannot truncate {path:?}: {e}")))?;
        }
        if expect_len > 0 {
            let text = std::fs::read_to_string(path)
                .map_err(|e| mismatch(format!("cannot read {path:?}: {e}")))?;
            let tail = text
                .lines()
                .rev()
                .find(|l| !l.trim().is_empty())
                .ok_or_else(|| mismatch(format!("metrics file {path:?} has no records")))?;
            let j = Json::parse(tail).map_err(|e| {
                mismatch(format!("metrics tail record is not valid JSON: {e}"))
            })?;
            if let Some(g) = j.get("g").and_then(|g| g.as_usize()) {
                if g as u64 >= boundary_g {
                    return Err(mismatch(format!(
                        "metrics tail record has g={g}, but the checkpoint was taken \
                         after step {boundary_g} boundary with g < {boundary_g}"
                    ))
                    .into());
                }
            }
        }
        let mut f = f;
        f.seek(std::io::SeekFrom::End(0))
            .map_err(|e| mismatch(format!("cannot seek {path:?}: {e}")))?;
        Ok(MetricsWriter { out: Some(std::io::BufWriter::new(f)), truncated_records })
    }

    pub fn write(&mut self, j: &Json) {
        if let Some(out) = &mut self.out {
            writeln!(out, "{}", j.to_string_compact()).expect("metrics write");
        }
    }

    pub fn flush(&mut self) {
        if let Some(out) = &mut self.out {
            out.flush().ok();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn record_json_fields() {
        let r = StepRecord {
            round: 3,
            device: 1,
            global_step: 7,
            loss: 0.5,
            train_acc: 0.75,
            up_bits: 1000,
            down_bits: 2000,
            up_nominal: 990.0,
            down_nominal: 1990.0,
            step_s: 0.1,
            exec_s: 0.08,
        };
        let j = r.to_json();
        assert_eq!(j.req("t").as_usize(), Some(3));
        assert_eq!(j.req("g").as_usize(), Some(7));
        assert_eq!(j.req("up_bits").as_f64(), Some(1000.0));
    }

    #[test]
    fn summary_bits_per_entry() {
        let s = TrainSummary {
            total_up_bits: 64_000,
            steps: 10,
            ..Default::default()
        };
        // 64000 bits / (10 steps * 100*20 entries) = 3.2 bits/entry
        assert!((s.uplink_bits_per_entry(100, 20) - 3.2).abs() < 1e-12);
    }

    #[test]
    fn jsonl_writer_to_file() {
        let path = std::env::temp_dir().join("splitfc_metrics_test.jsonl");
        let mut w = MetricsWriter::create(path.to_str().unwrap());
        w.write(&Json::obj(vec![("a", Json::num(1.0))]));
        w.write(&Json::obj(vec![("a", Json::num(2.0))]));
        w.flush();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_file(path).ok();
    }

    #[test]
    fn empty_path_is_noop() {
        let mut w = MetricsWriter::create("");
        w.write(&Json::Null);
        w.flush();
    }

    fn step_line(g: usize) -> Json {
        Json::obj(vec![("t", Json::num(1.0)), ("g", Json::num(g as f64))])
    }

    #[test]
    fn resume_appends_after_truncating_post_checkpoint_records() {
        let path = std::env::temp_dir().join("splitfc_metrics_resume_test.jsonl");
        let p = path.to_str().unwrap();
        let mut w = MetricsWriter::create(p);
        w.write(&step_line(0));
        w.write(&step_line(1));
        w.flush();
        let expect_len = std::fs::metadata(p).unwrap().len();
        // the killed run wrote two more records after the snapshot, the
        // second torn mid-line by the kill
        w.write(&step_line(2));
        w.flush();
        use std::io::Write as _;
        let mut f = std::fs::OpenOptions::new().append(true).open(p).unwrap();
        f.write_all(b"{\"t\":1,\"g\":3,\"lo").unwrap();
        drop(f);
        drop(w);

        let mut r = MetricsWriter::resume(p, expect_len, 2).unwrap();
        r.write(&step_line(2));
        r.flush();
        let text = std::fs::read_to_string(p).unwrap();
        let lines: Vec<&str> = text.lines().collect();
        assert_eq!(lines.len(), 3, "g=0, g=1 kept; post-checkpoint tail replaced");
        assert!(lines[2].contains("\"g\":2"), "{}", lines[2]);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn resume_rejects_short_file_and_inconsistent_tail() {
        let path = std::env::temp_dir().join("splitfc_metrics_resume_bad_test.jsonl");
        let p = path.to_str().unwrap();
        let mut w = MetricsWriter::create(p);
        w.write(&step_line(0));
        w.write(&step_line(1));
        w.flush();
        drop(w);
        let len = std::fs::metadata(p).unwrap().len();
        // shorter than the checkpoint recorded: wrong file
        let e = MetricsWriter::resume(p, len + 100, 2).unwrap_err().to_string();
        assert!(e.contains("metrics"), "{e}");
        // tail g=1 not < boundary 1: records past the boundary are missing
        let e = MetricsWriter::resume(p, len, 1).unwrap_err().to_string();
        assert!(e.contains("g=1"), "{e}");
        // consistent boundary passes and the file is untouched
        MetricsWriter::resume(p, len, 2).unwrap();
        assert_eq!(std::fs::metadata(p).unwrap().len(), len);
        // empty path stays a no-op writer
        MetricsWriter::resume("", 0, 0).unwrap();
        std::fs::remove_file(&path).ok();
    }
}
