//! Experiment harness: one function per paper table/figure (DESIGN.md §5).
//!
//! Each regenerates the corresponding artifact — same rows/series as the
//! paper — at a configurable scale (`--rounds/--devices/--n-train` shrink the
//! runs for CI; paper scales remain reachable). Results are printed as a
//! table and appended to `results/<id>.json`.

use crate::util::error::Result;

use crate::bench::print_table;
use crate::compression::CodecSpec;
use crate::config::{parse_scheme, table1_frameworks, table2_frameworks, TrainConfig};
use crate::coordinator::trainer::Trainer;
use crate::log_info;
use crate::tensor::{column_stats, dispersion_summary, normalized_sigma};
use crate::util::{Args, Json};

/// Build a config for (preset, scheme, budgets) with CLI overrides applied.
fn cfg_for(
    preset: &str,
    scheme_name: &str,
    r: f64,
    up_bpe: f64,
    down_bpe: f64,
    args: &Args,
) -> Result<TrainConfig> {
    let mut cfg = TrainConfig::for_preset(preset);
    cfg.scheme = parse_scheme(scheme_name, r)?;
    cfg.up_bits_per_entry = up_bpe;
    cfg.down_bits_per_entry = down_bpe;
    cfg.apply_overrides(args)?;
    // the scheme is this experiment's row: re-pin it over the generic
    // override (only --r passes through). The link budgets are re-pinned
    // only when the user did NOT override them explicitly — an explicit
    // --up-bpe/--down-bpe wins over the experiment's per-column budget.
    cfg.scheme = parse_scheme(scheme_name, args.get_f64("r", r))?;
    if args.get("up-bpe").is_none() {
        cfg.up_bits_per_entry = up_bpe;
    }
    if args.get("down-bpe").is_none() {
        cfg.down_bits_per_entry = down_bpe;
    }
    Ok(cfg)
}

fn run_one(cfg: TrainConfig) -> Result<(f32, f64, f64)> {
    let name = cfg.scheme.to_string();
    let preset = cfg.preset.clone();
    let (batch, dbar);
    let mut tr = Trainer::new(cfg)?;
    batch = tr.preset().batch;
    dbar = tr.preset().dbar;
    let s = tr.run()?;
    let up_bpe = s.uplink_bits_per_entry(batch, dbar);
    let down_bpe = s.total_down_bits as f64 / (s.steps as f64 * (batch * dbar) as f64);
    log_info!(
        "{preset}/{name}: acc={:.4} measured-up={:.4}b/e down={:.4}b/e wall={:.1}s",
        s.final_acc,
        up_bpe,
        down_bpe,
        s.wall_s
    );
    Ok((s.final_acc, up_bpe, down_bpe))
}

fn save_results(id: &str, j: Json) {
    std::fs::create_dir_all("results").ok();
    let path = format!("results/{id}.json");
    std::fs::write(&path, j.to_string_pretty()).expect("write results");
    println!("[saved {path}]");
}

fn presets_from(args: &Args, default: &str) -> Vec<String> {
    args.get_or("presets", default)
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect()
}

/// Fig. 1 — dispersion of intermediate feature columns, raw vs normalized.
pub fn fig1(args: &Args) -> Result<()> {
    let preset = args.get_or("presets", "mnist").split(',').next().unwrap().to_string();
    let mut cfg = cfg_for(&preset, "vanilla", 1.0, 32.0, 32.0, args)?;
    cfg.rounds = args.get_usize("rounds", 3); // short warmup like the paper's T
    let mut tr = Trainer::new(cfg)?;
    tr.run()?;
    let (f, sigma_norm) = tr.probe_features(0)?;
    let st = column_stats(&f);
    let raw = dispersion_summary(&st.std, &st.ranges());
    // normalized ranges: per-column range / channel range
    let chan = tr.preset().chan_size;
    let sig2 = normalized_sigma(&st, chan);
    let (cmn, cmx) = crate::tensor::channel_min_max(&st, chan);
    let nranges: Vec<f32> = (0..f.cols)
        .map(|c| {
            let r = cmx[c / chan] - cmn[c / chan];
            if r > 0.0 {
                st.range(c) / r
            } else {
                0.0
            }
        })
        .collect();
    let norm = dispersion_summary(&sig2, &nranges);
    // cross-check: artifact σ (Pallas kernel) vs host σ
    let max_dev = sigma_norm
        .iter()
        .zip(&sig2)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);

    let rows = vec![
        (
            "std (min / max / max-SNV ratio)".to_string(),
            vec![
                format!("{:.4} / {:.4} / {:.1}x", raw.std_min, raw.std_max, raw.std_snv_ratio),
                format!("{:.4} / {:.4} / {:.1}x", norm.std_min, norm.std_max, norm.std_snv_ratio),
            ],
        ),
        (
            "range (min / max / max-SNV ratio)".to_string(),
            vec![
                format!("{:.4} / {:.4} / {:.1}x", raw.range_min, raw.range_max, raw.range_snv_ratio),
                format!(
                    "{:.4} / {:.4} / {:.1}x",
                    norm.range_min, norm.range_max, norm.range_snv_ratio
                ),
            ],
        ),
    ];
    print_table(
        &format!("Fig. 1 — feature dispersion, {preset} (B={}, Dbar={})", f.rows, f.cols),
        &["original".into(), "normalized".into()],
        &rows,
    );
    println!(
        "kernel-vs-host sigma max deviation: {max_dev:.2e} (feature_stats artifact agrees)"
    );
    println!(
        "paper shape check: normalization shrinks the std SNV ratio ({:.1}x -> {:.1}x)",
        raw.std_snv_ratio, norm.std_snv_ratio
    );
    save_results(
        "fig1",
        Json::obj(vec![
            ("preset", Json::str(preset)),
            ("raw_std_snv", Json::num(raw.std_snv_ratio as f64)),
            ("norm_std_snv", Json::num(norm.std_snv_ratio as f64)),
            ("raw_range_snv", Json::num(raw.range_snv_ratio as f64)),
            ("norm_range_snv", Json::num(norm.range_snv_ratio as f64)),
            ("kernel_sigma_max_dev", Json::num(max_dev as f64)),
        ]),
    );
    Ok(())
}

/// Fig. 3 — dropout variants (AD / Rand / Deterministic) vs R, no quantization.
pub fn fig3(args: &Args) -> Result<()> {
    let preset = presets_from(args, "mnist")[0].clone();
    let rs: Vec<f64> = args
        .get_or("rs", "4,8,16,32,64")
        .split(',')
        .map(|s| s.trim().parse().unwrap())
        .collect();
    let schemes = ["splitfc-ad", "splitfc-rand", "splitfc-det"];
    let vanilla = run_one(cfg_for(&preset, "vanilla", 1.0, 32.0, 32.0, args)?)?.0;
    let mut rows = Vec::new();
    let mut out = vec![("vanilla".to_string(), Json::num(vanilla as f64))];
    for scheme in schemes {
        let mut cols = Vec::new();
        for &r in &rs {
            let (acc, _, _) = run_one(cfg_for(&preset, scheme, r, 32.0, 32.0, args)?)?;
            cols.push(format!("{:.2}", acc * 100.0));
            out.push((format!("{scheme}@R{r}"), Json::num(acc as f64)));
        }
        rows.push((scheme.to_string(), cols));
    }
    rows.push((
        "vanilla (R=1)".to_string(),
        vec![format!("{:.2}", vanilla * 100.0); rs.len()],
    ));
    print_table(
        &format!("Fig. 3 — accuracy vs R, {preset} (dropout only)"),
        &rs.iter().map(|r| format!("R={r}")).collect::<Vec<_>>(),
        &rows,
    );
    save_results("fig3", Json::Obj(out.into_iter().map(|(k, v)| (k, v)).collect()));
    Ok(())
}

/// Table I — accuracy vs uplink compression (downlink lossless).
pub fn table1(args: &Args) -> Result<()> {
    let budgets: Vec<(String, f64)> = vec![
        ("160x".into(), 0.2),
        ("240x".into(), 32.0 / 240.0),
        ("320x".into(), 0.1),
    ];
    let r = args.get_f64("r", 16.0);
    let mut results = Vec::new();
    for preset in presets_from(args, "mnist") {
        let vanilla = run_one(cfg_for(&preset, "vanilla", 1.0, 32.0, 32.0, args)?)?.0;
        let mut rows = vec![(
            "vanilla (1x)".to_string(),
            vec![format!("{:.2}", vanilla * 100.0); budgets.len()],
        )];
        results.push((format!("{preset}/vanilla"), Json::num(vanilla as f64)));
        for fw in table1_frameworks() {
            let mut cols = Vec::new();
            for (_, bpe) in &budgets {
                let (acc, _, _) = run_one(cfg_for(&preset, fw, r, *bpe, 32.0, args)?)?;
                cols.push(format!("{:.2}", acc * 100.0));
                results.push((format!("{preset}/{fw}@{bpe:.4}"), Json::num(acc as f64)));
            }
            rows.push((fw.to_string(), cols));
        }
        print_table(
            &format!("Table I — accuracy vs uplink compression, {preset}"),
            &budgets.iter().map(|(n, b)| format!("{n} ({b:.3}b)")).collect::<Vec<_>>(),
            &rows,
        );
    }
    save_results("table1", Json::Obj(results.into_iter().collect()));
    Ok(())
}

/// Table II — accuracy vs downlink compression with C_e,d = C_e,s / 2.
pub fn table2(args: &Args) -> Result<()> {
    let budgets: Vec<(String, f64)> = vec![
        ("80x".into(), 0.4),
        ("120x".into(), 32.0 / 120.0),
        ("160x".into(), 0.2),
    ];
    let r = args.get_f64("r", 16.0);
    let mut results = Vec::new();
    for preset in presets_from(args, "mnist") {
        let vanilla = run_one(cfg_for(&preset, "vanilla", 1.0, 32.0, 32.0, args)?)?.0;
        let mut rows = vec![(
            "vanilla (1x)".to_string(),
            vec![format!("{:.2}", vanilla * 100.0); budgets.len()],
        )];
        results.push((format!("{preset}/vanilla"), Json::num(vanilla as f64)));
        for fw in table2_frameworks() {
            let mut cols = Vec::new();
            for (_, down_bpe) in &budgets {
                let up_bpe = down_bpe / 2.0;
                let (acc, _, _) = run_one(cfg_for(&preset, fw, r, up_bpe, *down_bpe, args)?)?;
                cols.push(format!("{:.2}", acc * 100.0));
                results
                    .push((format!("{preset}/{fw}@dn{down_bpe:.4}"), Json::num(acc as f64)));
            }
            rows.push((fw.to_string(), cols));
        }
        print_table(
            &format!("Table II — accuracy vs downlink compression, {preset} (C_e,d = C_e,s/2)"),
            &budgets.iter().map(|(n, b)| format!("{n} ({b:.3}b)")).collect::<Vec<_>>(),
            &rows,
        );
    }
    save_results("table2", Json::Obj(results.into_iter().collect()));
    Ok(())
}

/// Fig. 4 — accuracy of full SplitFC vs R at fixed C_e,d = 0.4.
pub fn fig4(args: &Args) -> Result<()> {
    let preset = presets_from(args, "mnist")[0].clone();
    let rs: Vec<f64> = args
        .get_or("rs", "2,4,8,16,32")
        .split(',')
        .map(|s| s.trim().parse().unwrap())
        .collect();
    let bpe = args.get_f64("ce", 0.4);
    let mut rows = Vec::new();
    let mut cols = Vec::new();
    let mut results = Vec::new();
    for &r in &rs {
        let (acc, _, _) = run_one(cfg_for(&preset, "splitfc", r, bpe, 32.0, args)?)?;
        cols.push(format!("{:.2}", acc * 100.0));
        results.push((format!("R{r}"), Json::num(acc as f64)));
    }
    rows.push(("SplitFC".to_string(), cols));
    print_table(
        &format!("Fig. 4 — accuracy vs R at C_e,d={bpe}, {preset}"),
        &rs.iter().map(|r| format!("R={r}")).collect::<Vec<_>>(),
        &rows,
    );
    save_results("fig4", Json::Obj(results.into_iter().collect()));
    Ok(())
}

/// Fig. 5 — optimal level allocation vs fixed Q at C_e,d = 0.2, R = 8.
pub fn fig5(args: &Args) -> Result<()> {
    let preset = presets_from(args, "mnist")[0].clone();
    let bpe = args.get_f64("ce", 0.2);
    let r = args.get_f64("r", 8.0);
    let mut results = Vec::new();
    let (opt_acc, _, _) = run_one(cfg_for(&preset, "splitfc", r, bpe, 32.0, args)?)?;
    results.push(("optimal".to_string(), Json::num(opt_acc as f64)));
    let mut rows = vec![("optimal levels".to_string(), vec![format!("{:.2}", opt_acc * 100.0)])];
    for q in [2u64, 4, 8, 16, 32] {
        let mut cfg = cfg_for(&preset, "splitfc", r, bpe, 32.0, args)?;
        cfg.scheme = CodecSpec::parse_with_r(&format!("splitfc[ad,R={r},fixedQ{q}]"), r)?;
        let (acc, _, _) = run_one(cfg)?;
        rows.push((format!("fixed Q={q}"), vec![format!("{:.2}", acc * 100.0)]));
        results.push((format!("fixedQ{q}"), Json::num(acc as f64)));
    }
    print_table(
        &format!("Fig. 5 — level optimization ablation, {preset} (C_e,d={bpe}, R={r})"),
        &["accuracy %".into()],
        &rows,
    );
    save_results("fig5", Json::Obj(results.into_iter().collect()));
    Ok(())
}

/// Table III — ablation: dropout / quantizers on-off (4 cases).
pub fn table3(args: &Args) -> Result<()> {
    let r = args.get_f64("r", 16.0);
    let mut results = Vec::new();
    for preset in presets_from(args, "mnist") {
        let cases: Vec<(&str, &str, f64, f64)> = vec![
            // (label, scheme, R, bits/entry for both links)
            ("case1: AD only (65x)", "splitfc-ad", 65.0, 32.0 / 65.0),
            ("case2: FWQ only (260x)", "splitfc-quant-only", 1.0, 32.0 / 260.0),
            ("case3: AD + two-stage (260x)", "splitfc-no-mean", r, 32.0 / 260.0),
            ("case4: full SplitFC (260x)", "splitfc", r, 32.0 / 260.0),
        ];
        let mut rows = Vec::new();
        for (label, scheme, rr, bpe) in cases {
            let (acc, _, _) = run_one(cfg_for(&preset, scheme, rr, bpe, bpe, args)?)?;
            rows.push((label.to_string(), vec![format!("{:.2}", acc * 100.0)]));
            results.push((format!("{preset}/{label}"), Json::num(acc as f64)));
        }
        print_table(
            &format!("Table III — ablation, {preset}"),
            &["accuracy %".into()],
            &rows,
        );
    }
    save_results("table3", Json::Obj(results.into_iter().collect()));
    Ok(())
}

/// Dispatch by experiment id.
pub fn run(id: &str, args: &Args) -> Result<()> {
    // honor an explicit --threads for library callers too (the CLI already
    // set it); without the flag, leave the process-global pool alone
    if args.get("threads").is_some() {
        crate::util::par::set_threads(args.get_usize("threads", 0));
    }
    match id {
        "fig1" => fig1(args),
        "fig3" => fig3(args),
        "fig4" => fig4(args),
        "fig5" => fig5(args),
        "table1" => table1(args),
        "table2" => table2(args),
        "table3" => table3(args),
        "all" => {
            for id in ["fig1", "fig3", "fig4", "fig5", "table1", "table2", "table3"] {
                run(id, args)?;
            }
            Ok(())
        }
        other => crate::bail!("unknown experiment {other:?} (fig1|fig3|fig4|fig5|table1|table2|table3|all)"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse(&s.split_whitespace().map(String::from).collect::<Vec<_>>())
    }

    #[test]
    fn cfg_for_pins_experiment_budgets_by_default() {
        let c = cfg_for("tiny", "splitfc", 8.0, 0.2, 0.4, &args("x --rounds 2")).unwrap();
        assert_eq!(c.up_bits_per_entry, 0.2);
        assert_eq!(c.down_bits_per_entry, 0.4);
        assert_eq!(c.rounds, 2);
    }

    #[test]
    fn cfg_for_honors_explicit_budget_overrides() {
        let c = cfg_for(
            "tiny",
            "splitfc",
            8.0,
            0.2,
            0.4,
            &args("x --up-bpe 1.5 --down-bpe 2.5"),
        )
        .unwrap();
        assert_eq!(c.up_bits_per_entry, 1.5);
        assert_eq!(c.down_bits_per_entry, 2.5);
    }

    #[test]
    fn cfg_for_repins_scheme_with_r_override() {
        let c =
            cfg_for("tiny", "splitfc", 8.0, 0.2, 0.4, &args("x --r 32 --scheme tops")).unwrap();
        // the scheme is the experiment row — --scheme must not leak in,
        // but --r parameterizes the pinned scheme
        assert_eq!(c.scheme, parse_scheme("splitfc", 32.0).unwrap());
        assert_eq!(c.scheme.r, 32.0);
    }
}
