//! L3 coordinator — the paper's Algorithm 1 plus the experiment harness.
//!
//! * `trainer` — round-robin split-learning protocol over PJRT artifacts
//! * `metrics` — per-step records, summaries, JSONL
//! * `experiments` — one entry per paper table/figure
//! * `cli` — the `splitfc` binary front-end

pub mod cli;
pub mod experiments;
pub mod metrics;
pub mod trainer;

pub use metrics::{StepRecord, TrainSummary};
pub use trainer::Trainer;
