//! L3 coordinator — the paper's Algorithm 1 split into concurrent roles,
//! plus the experiment harness.
//!
//! * `server` — the parameter-server role: `w_s`/`w_d`, both optimizers,
//!   the shared encode stream, serialized metrics
//! * `worker` — one device-side role per client: loader, RNG fork,
//!   per-device link, uplink encode / downlink decode + chain-rule rescale
//! * `scheduler` — drives K workers sequentially or concurrently under a
//!   bounded-staleness window (S = 0 ⇒ exact round-robin)
//! * `trainer` — thin facade wiring the roles from a `TrainConfig`
//! * `metrics` — per-step records, summaries, JSONL
//! * `experiments` — one entry per paper table/figure
//! * `cli` — the `splitfc` binary front-end

pub mod cli;
pub mod experiments;
pub mod metrics;
pub mod scheduler;
pub mod server;
pub mod trainer;
pub mod worker;

pub use metrics::{StepRecord, TrainSummary};
pub use scheduler::Scheduler;
pub use server::{DeviceOpt, ParameterServer};
pub use trainer::Trainer;
pub use worker::{DeviceWorker, RngMode};
