//! L3 coordinator — the paper's Algorithm 1 split into concurrent roles
//! that talk only through protocol messages, plus the experiment harness.
//!
//! * `server` — the parameter-server role: `w_s`/`w_d`, both optimizers,
//!   the shared encode stream, serialized metrics
//! * `protocol` — the PS's message-level endpoint: per-device codec
//!   sessions, the staleness gate, replay couriers (reconnect safety)
//! * `worker` — one device-side role per client: loader, RNG fork,
//!   per-device link, uplink encode / downlink decode + chain-rule
//!   rescale, all over a transport `Connection`
//! * `scheduler` — drives K workers sequentially or concurrently under a
//!   bounded-staleness window (S = 0 ⇒ exact round-robin)
//! * `trainer` — facade wiring the roles from a `TrainConfig` over the
//!   in-process or TCP transport
//! * `metrics` — per-step records, summaries, JSONL
//! * `experiments` — one entry per paper table/figure
//! * `cli` — the `splitfc` binary front-end

pub mod cli;
pub mod experiments;
pub mod metrics;
pub mod protocol;
pub mod scheduler;
pub mod server;
pub mod trainer;
pub mod worker;

pub use metrics::{StepRecord, TrainSummary};
pub use protocol::{PsEndpoint, RunGate};
pub use scheduler::Scheduler;
pub use server::{DeviceOpt, DeviceOptState, ParameterServer, ServerSnap};
pub use trainer::{build_parts, run_remote_device, FleetParts, Trainer};
pub use worker::{DeviceWorker, RetryPolicy};
