//! The paper's Algorithm 1 as a thin facade over the concurrent coordinator.
//!
//! One step (t, k) — the halves now live in their roles:
//!   1. device k draws a minibatch, runs `device_fwd` → F                (eq. 3)
//!   2. `feature_stats` (the σ-statistics kernel) → σ_norm              (eq. 10)
//!   3. FWDP + FWQ encode → uplink frame → PS decodes F̂            (Alg. 2/3)
//!   4. PS runs `server_fwd_bwd` → loss, ∇w_s, G = ∇_F̂ h          (eqs. 4, 5)
//!   5. PS ADAM-steps w_s; PS drops non-kept gradient columns, FWQ-encodes,
//!      downlink frame → device decodes Ĝ                             (eq. 8)
//!   6. device applies the chain-rule scale δ_j/(1-p_j) to Ĝ, runs
//!      `device_bwd` → ∇w_d; the (PS-held) device ADAM steps w_d (Sec. III-A)
//!
//! Steps 1-3 and 6 are the [`DeviceWorker`] half, 4-5 the
//! [`ParameterServer`] half — and since the transport refactor the two
//! halves only ever talk through protocol messages over a [`Connection`].
//! `Trainer` wires the fleet from a [`TrainConfig`]: it builds the PS
//! message endpoint ([`PsEndpoint`]) plus one serve loop per device link,
//! over bounded in-process channels (`--transport inproc`, the default) or
//! real TCP sockets (`--transport tcp`), and keeps the original
//! `new`/`step`/`run`/`evaluate`/`probe_features` surface. With
//! `--devices-remote R` the last R devices are *not* built locally — they
//! join over the listening socket from separate processes (`splitfc
//! device`), and the scheduler awaits their commits at the watermark.

use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::checkpoint::{Checkpoint, CkptError, CkptHeader, SchedSnap, FORMAT_VERSION};
use crate::compression::{Codec, CodecParams};
use crate::config::{PartitionKind, TrainConfig};
use crate::coordinator::metrics::{MetricsWriter, StepRecord, TrainSummary};
use crate::coordinator::protocol::PsEndpoint;
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::server::ParameterServer;
use crate::coordinator::worker::{DeviceWorker, RetryPolicy};
use crate::data::{
    dirichlet_partition, label_shards, writer_groups, Dataset, MiniBatchLoader, SynthSpec,
};
use crate::ensure;
use crate::model::{ParamSet, PresetInfo};
use crate::runtime::{create_backend, Backend};
use crate::scenario::Timeline;
use crate::tensor::Matrix;
use crate::transport::{
    fading_capacities, inproc_pair, tcp, Connection, Link, LinkReport, Msg, TcpConn,
    TransportKind, WireLimits,
};
use crate::util::error::Result;
use crate::util::Rng;

pub struct Trainer {
    pub cfg: TrainConfig,
    preset: PresetInfo,
    server: Arc<ParameterServer>,
    endpoint: Arc<PsEndpoint>,
    workers: Vec<DeviceWorker>,
    train: Dataset,
    test: Dataset,
    /// the compiled failure scenario (calm scripts when `--scenario` is
    /// empty — the machinery then changes nothing about the run)
    timeline: Timeline,
    /// global index tag for facade-driven (manual) steps
    steps_taken: usize,
    /// the run codec's (wire id, wire version) — stamped into checkpoints
    codec_wire: (u32, u16),
    /// bound address of the TCP listener (`--transport tcp` only)
    listen_addr: Option<String>,
    /// tells the acceptor loop to wind down on drop
    stop: Arc<AtomicBool>,
    /// PS-side serve/acceptor threads, joined on drop
    handles: Vec<JoinHandle<()>>,
    /// every socket the acceptor has handed to a serve loop — the
    /// `pscrash[...]` scenario severs these to simulate the PS dying
    /// under its devices (empty on inproc transport)
    accepted: Arc<Mutex<Vec<TcpStream>>>,
}

/// Apply the config's failure-handling knobs and the device's compiled
/// scenario script to a freshly built worker (local threads and remote
/// `splitfc device` processes go through the same path).
fn arm_worker(w: &mut DeviceWorker, cfg: &TrainConfig, timeline: &Timeline) {
    w.set_retry_policy(
        RetryPolicy::new(cfg.retry_base_ms, cfg.retry_cap_ms, cfg.retry_deadline_s),
        cfg.seed,
    );
    w.set_script(timeline.scripts[w.device].clone());
    if cfg.rpc_deadline_s > 0.0 {
        w.set_rpc_deadline(Some(std::time::Duration::from_secs_f64(cfg.rpc_deadline_s)));
    }
}

fn synth_spec_for(preset: &str) -> SynthSpec {
    match preset {
        "mnist" => SynthSpec::mnist_like(),
        "cifar" => SynthSpec::cifar_like(),
        "celeba" => SynthSpec::celeba_like(),
        _ => SynthSpec::tiny(),
    }
}

/// Everything both sides of the fleet derive deterministically from the
/// config: backend + initial parameters, datasets, per-device loaders and
/// RNG forks, codec parameters, link capacities, wire limits. A remote
/// device process (`splitfc device`) rebuilds the *same* parts from the
/// same flags — the fork order below is trajectory-critical, so device
/// identity holds across process boundaries.
pub struct FleetParts {
    pub backend: Arc<dyn Backend>,
    pub preset: PresetInfo,
    pub wd: ParamSet,
    pub ws: ParamSet,
    pub train: Dataset,
    pub test: Dataset,
    pub loaders: Vec<MiniBatchLoader>,
    /// the PS-held Algorithm-1 encode stream
    pub shared_rng: Rng,
    /// per-device worker streams (used when staleness > 0)
    pub worker_rngs: Vec<Rng>,
    pub up_params: CodecParams,
    pub down_params: CodecParams,
    /// per-device link capacity in bits/s (log-normal draw around the
    /// nominal when `--fading-sigma` > 0, else uniform)
    pub capacities: Vec<f64>,
    pub limits: WireLimits,
}

/// Build the deterministic fleet parts. RNG discipline: every fork below
/// happens in the exact order of the pre-refactor monolithic trainer
/// (partitions → K loader forks → shared stream → K worker forks), so
/// sequential runs reproduce its trajectories bit for bit.
pub fn build_parts(cfg: &TrainConfig) -> Result<FleetParts> {
    // size the parallel runtime (matmul blocks, FWQ planning) for this
    // run; 0 = unset, which leaves the process-global pool alone (auto
    // by default) so library callers' explicit set_threads survives.
    // Exception: with concurrent device workers active, an auto-sized
    // inner pool would spawn `workers × cores` threads (every backend
    // call in every worker fans out over the whole machine) — divide
    // the cores between the two layers instead.
    let worker_threads = cfg.resolved_concurrency();
    if cfg.threads > 0 {
        crate::util::par::set_threads(cfg.threads);
    } else if worker_threads > 1 {
        let cores = std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1);
        crate::util::par::set_threads((cores / worker_threads).max(1));
    }
    let backend: Arc<dyn Backend> =
        Arc::from(create_backend(cfg.backend, &cfg.artifacts_dir, &cfg.preset)?);
    let preset = backend.preset().clone();
    let (wd, ws) = backend.init_params()?;
    ensure!(wd.n_params() == preset.nd_params);
    ensure!(ws.n_params() == preset.ns_params);

    let mut rng = Rng::new(cfg.seed.wrapping_mul(0x9E3779B9).wrapping_add(7));
    let spec = synth_spec_for(&cfg.preset);
    // consistency between model input shape and dataset spec
    ensure!(
        spec.sample_dim() == preset.sample_dim(),
        "dataset spec {:?} vs model input {:?}",
        (spec.channels, spec.height, spec.width),
        preset.in_shape
    );
    let train = Dataset::generate(&spec, cfg.n_train, cfg.seed);
    let test = Dataset::generate(&spec, cfg.n_test, cfg.seed.wrapping_add(0xE7A1));

    let parts = match cfg.partition {
        PartitionKind::LabelShards => label_shards(&train, cfg.devices, 2, &mut rng),
        PartitionKind::Dirichlet => dirichlet_partition(&train, cfg.devices, 0.3, &mut rng),
        PartitionKind::Writers => writer_groups(&train, cfg.devices, &mut rng),
    };
    let loaders: Vec<MiniBatchLoader> = parts
        .into_iter()
        .enumerate()
        .map(|(k, mut p)| {
            if p.is_empty() {
                // degenerate partition (tiny runs): give it one sample
                p.push(k % train.n);
            }
            MiniBatchLoader::new(p, preset.batch, rng.fork(k as u64))
        })
        .collect();

    // the Algorithm-1 encode stream forks exactly where the monolithic
    // trainer forked it (after the K loader forks); per-device streams
    // for staleness > 0 fork afterwards and don't perturb it
    let shared_rng = rng.fork(0xFFFF);
    let worker_rngs: Vec<Rng> =
        (0..cfg.devices).map(|k| rng.fork(0x1_0000 + k as u64)).collect();

    // codec parameters shared by device and PS sides of every link
    let up_params = CodecParams::new(preset.batch, preset.dbar, cfg.up_bits_per_entry)
        .with_q_ep(cfg.q_ep)
        .with_noise_seed(cfg.noise_seed)
        .with_chan_size(preset.chan_size);
    let down_params = CodecParams::new(preset.batch, preset.dbar, cfg.down_bits_per_entry)
        .with_q_ep(cfg.q_ep)
        .with_noise_seed(cfg.noise_seed)
        .with_chan_size(preset.chan_size);

    // heterogeneous link capacities draw from a dedicated generator so
    // turning fading on cannot perturb the training RNG chain
    let capacities = if cfg.fading_sigma > 0.0 {
        fading_capacities(
            cfg.devices,
            cfg.link_capacity_bps,
            cfg.fading_sigma,
            cfg.seed ^ 0xFAD1_0CEA,
        )
    } else {
        vec![cfg.link_capacity_bps; cfg.devices]
    };
    let limits =
        WireLimits::for_shapes(preset.batch, preset.dbar, preset.nd_params, preset.classes);

    Ok(FleetParts {
        backend,
        preset,
        wd,
        ws,
        train,
        test,
        loaders,
        shared_rng,
        worker_rngs,
        up_params,
        down_params,
        capacities,
        limits,
    })
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        ensure!(
            cfg.devices_remote <= cfg.devices,
            "--devices-remote {} exceeds the fleet size {}",
            cfg.devices_remote,
            cfg.devices
        );
        ensure!(
            cfg.devices_remote == 0 || cfg.transport == TransportKind::Tcp,
            "--devices-remote needs --transport tcp (a remote process cannot \
             join an in-process channel)"
        );
        let timeline = Timeline::compile(&cfg.scenario, cfg.devices, cfg.rounds, cfg.seed)?;
        ensure!(
            !timeline.has_cuts() || cfg.transport == TransportKind::Tcp,
            "scenario cut[] clauses need --transport tcp (in-process links \
             cannot reconnect)"
        );
        if timeline.has_ps_crashes() {
            ensure!(
                cfg.transport == TransportKind::Tcp,
                "scenario pscrash[] clauses need --transport tcp (devices \
                 survive the crash by reconnecting, which in-process links \
                 cannot do)"
            );
            ensure!(
                cfg.checkpoint_every > 0,
                "scenario pscrash[] clauses need --checkpoint-every > 0 (the \
                 PS restarts from the round-barrier checkpoint it just wrote)"
            );
            for &t in &timeline.ps_crash_rounds {
                ensure!(
                    t % cfg.checkpoint_every == 0,
                    "pscrash[round={t}] does not land on a checkpoint barrier \
                     (--checkpoint-every {})",
                    cfg.checkpoint_every
                );
            }
        }
        // a stale `*.tmp` is a checkpoint write the previous incarnation
        // died inside of — sweep it before this run writes or resumes
        if !cfg.checkpoint_dir.is_empty()
            && (cfg.checkpoint_every > 0 || !cfg.resume.is_empty())
        {
            let swept = crate::checkpoint::sweep_tmp(&cfg.checkpoint_dir)?;
            if swept > 0 {
                crate::log_warn!(
                    "swept {swept} stale partial checkpoint write(s) from {}",
                    cfg.checkpoint_dir
                );
            }
        }
        let FleetParts {
            backend,
            preset,
            wd,
            ws,
            train,
            test,
            loaders,
            shared_rng,
            worker_rngs,
            up_params,
            down_params,
            capacities,
            limits,
        } = build_parts(&cfg)?;

        // one codec *session* per device on EACH side of the link:
        // device-side sessions own uplink-encode state (error feedback),
        // PS-side sessions own uplink-decode/downlink-encode state —
        // instances are never shared across links or across the wire
        let ps_codecs: Vec<Box<dyn Codec>> = (0..cfg.devices)
            .map(|_| cfg.scheme.build())
            .collect::<Result<Vec<_>>>()?;
        let codec_wire = (ps_codecs[0].wire_id(), ps_codecs[0].wire_version());

        // `--resume`: load + fully validate the checkpoint before touching
        // anything on disk or in memory — a corrupt / truncated /
        // wrong-version / mismatched-config file aborts here with no state
        // mutated (the metrics file included)
        let resume_ckpt = if cfg.resume.is_empty() {
            None
        } else {
            Some(load_resume(&cfg, codec_wire)?)
        };

        let metrics = match &resume_ckpt {
            None => MetricsWriter::create(&cfg.metrics_path),
            Some(c) => {
                MetricsWriter::resume(&cfg.metrics_path, c.sched.metrics_len, c.sched.boundary_g)?
            }
        };
        // step records rolled back past the barrier = steps this
        // incarnation replays (recovery telemetry, folded in below)
        let resumed_replay = metrics.truncated_records;
        let server = Arc::new(ParameterServer::new(
            backend.clone(),
            wd,
            ws,
            cfg.lr,
            cfg.devices,
            cfg.per_device_opt,
            shared_rng,
            metrics,
        ));
        let mut endpoint = PsEndpoint::new(
            server.clone(),
            cfg.staleness,
            up_params.clone(),
            down_params.clone(),
            ps_codecs,
            preset.nd_params,
        );
        endpoint.set_checkpoint(cfg.checkpoint_every);
        if let Some(c) = &resume_ckpt {
            server.restore_snap(&c.server)?;
            endpoint.prime_resume(c.header.round as usize, c.sched.totals.clone(), &c.links)?;
            // a process-level resume IS a PS restart: start the
            // time-to-recover clock and book the rolled-back records
            endpoint.note_restart();
            endpoint.add_replayed(resumed_replay);
        }
        let endpoint = Arc::new(endpoint);

        let stop = Arc::new(AtomicBool::new(false));
        let mut handles: Vec<JoinHandle<()>> = Vec::new();
        let mut listen_addr = None;
        let accepted: Arc<Mutex<Vec<TcpStream>>> = Arc::new(Mutex::new(Vec::new()));
        let local_n = cfg.devices - cfg.devices_remote;

        // one Connection per local device, plus the PS-side serve loops
        let mut conns: Vec<Box<dyn Connection>> = Vec::with_capacity(local_n);
        match cfg.transport {
            TransportKind::InProc => {
                for _ in 0..local_n {
                    let (dev_end, ps_end) = inproc_pair(4);
                    let ep = endpoint.clone();
                    handles.push(std::thread::spawn(move || {
                        let mut conn = ps_end;
                        let _ = ep.serve(&mut conn, false);
                    }));
                    conns.push(Box::new(dev_end));
                }
            }
            TransportKind::Tcp => {
                // SO_REUSEADDR: a restarted PS must rebind its well-known
                // port immediately, even with predecessor connections
                // still draining in TIME_WAIT
                let listener = tcp::bind_reuse(&cfg.listen)?;
                let addr = listener
                    .local_addr()
                    .map_err(|e| crate::err!("local_addr: {e}"))?
                    .to_string();
                listener
                    .set_nonblocking(true)
                    .map_err(|e| crate::err!("set_nonblocking: {e}"))?;
                let ep = endpoint.clone();
                let stop2 = stop.clone();
                let reg = accepted.clone();
                handles.push(std::thread::spawn(move || {
                    accept_loop(listener, ep, limits, &stop2, &reg)
                }));
                for k in 0..local_n {
                    let mut conn = TcpConn::connect(&addr, limits)?;
                    let cut_sends = &timeline.scripts[k].cut_sends;
                    if !cut_sends.is_empty() {
                        conn.set_fault_at_sends(cut_sends);
                    }
                    conns.push(Box::new(conn));
                }
                listen_addr = Some(addr);
            }
        }

        let mut workers: Vec<DeviceWorker> = Vec::with_capacity(local_n);
        for (((k, loader), rng), conn) in loaders
            .into_iter()
            .enumerate()
            .zip(worker_rngs)
            .zip(conns)
            .take(local_n)
        {
            let mut w = DeviceWorker::new(
                k,
                loader,
                rng,
                Link::new(capacities[k], cfg.link_latency_s),
                cfg.scheme.build()?,
                &preset,
                up_params.clone(),
                down_params.clone(),
                backend.clone(),
                conn,
            );
            arm_worker(&mut w, &cfg, &timeline);
            workers.push(w);
        }

        Ok(Trainer {
            cfg,
            preset,
            server,
            endpoint,
            workers,
            train,
            test,
            timeline,
            steps_taken: 0,
            codec_wire,
            listen_addr,
            stop,
            handles,
            accepted,
        })
    }

    /// Static description of the loaded model (shapes, parameter layout).
    pub fn preset(&self) -> &PresetInfo {
        &self.preset
    }

    /// The shared execution backend.
    pub fn backend(&self) -> &dyn Backend {
        self.server.backend()
    }

    /// The parameter-server role (snapshots, metrics, evaluation).
    pub fn server(&self) -> &ParameterServer {
        &self.server
    }

    /// Where the TCP transport is listening (None on inproc). Remote
    /// device processes dial this with `splitfc device --connect`.
    pub fn listen_addr(&self) -> Option<&str> {
        self.listen_addr.as_deref()
    }

    /// Aggregate communication accounting across every *local* device
    /// link (remote devices account on their own side).
    pub fn link_report(&self) -> LinkReport {
        LinkReport::aggregate(self.workers.iter().map(|w| w.link_report()))
    }

    /// Run one (t, k) protocol step, sequential Algorithm-1 semantics
    /// (shared encode stream, updates applied in call order).
    pub fn step(&mut self, round: usize, device: usize) -> Result<StepRecord> {
        ensure!(device < self.workers.len(), "device {device} is not local");
        ensure!(
            self.endpoint.first_round() == 1,
            "manual stepping after --resume is not supported"
        );
        self.endpoint.begin_manual();
        let g = self.steps_taken;
        self.steps_taken += 1;
        self.workers[device].run_step(round, g, g, &self.train)
    }

    /// Test-set accuracy via the backend's full-model forward.
    pub fn evaluate(&mut self) -> Result<f32> {
        self.server.evaluate(&self.test)
    }

    /// Full training run: T rounds over K devices (Alg. 1), driven by the
    /// scheduler — sequentially by default, concurrently when the config
    /// asks for worker threads (`staleness`/`concurrent_devices`), with
    /// remote devices joining over the listening transport.
    pub fn run(&mut self) -> Result<TrainSummary> {
        let liveness = if self.cfg.liveness_timeout_s > 0.0 {
            Some(std::time::Duration::from_secs_f64(self.cfg.liveness_timeout_s))
        } else {
            None
        };
        let sched = Scheduler {
            rounds: self.cfg.rounds,
            first_step: self.steps_taken,
            first_round: self.endpoint.first_round(),
            staleness: self.cfg.staleness,
            concurrency: self.cfg.resolved_concurrency(),
            eval_every: self.cfg.eval_every,
            ckpt_every: self.cfg.checkpoint_every,
            skips: self.timeline.skipped_locals(),
            liveness,
        };
        // snapshot authority lives here: at a checkpoint barrier the
        // watermark has quiesced, every Commit (and its device-state blob)
        // up to the boundary is applied, and nothing for later rounds has
        // started — so one closure can capture the entire run
        let (server, endpoint) = (self.server.clone(), self.endpoint.clone());
        let (cfg, codec_wire, first_step) = (self.cfg.clone(), self.codec_wire, self.steps_taken);
        let crash_rounds = self.timeline.ps_crash_rounds.clone();
        let crash_sends = self.timeline.ps_crash_sends.clone();
        let send_fired = Mutex::new(vec![false; crash_sends.len()]);
        let accepted = self.accepted.clone();
        let snapshot_hook = move |round: usize| -> Result<()> {
            server.flush_metrics();
            let metrics_len = if cfg.metrics_path.is_empty() {
                0
            } else {
                std::fs::metadata(&cfg.metrics_path).map(|m| m.len()).unwrap_or(0)
            };
            let ckpt = Checkpoint {
                header: CkptHeader {
                    format: FORMAT_VERSION,
                    codec_id: codec_wire.0,
                    codec_version: codec_wire.1,
                    scheme: cfg.scheme.canonical_name(),
                    preset: cfg.preset.clone(),
                    devices: cfg.devices as u32,
                    rounds: cfg.rounds as u32,
                    round: round as u32,
                    seed: cfg.seed,
                    fingerprint: cfg.trajectory_fingerprint(),
                    scenario: cfg.scenario.to_string(),
                },
                server: server.export_snap(),
                sched: SchedSnap {
                    boundary_g: (first_step + round * cfg.devices) as u64,
                    metrics_len,
                    totals: endpoint.totals_snapshot(),
                },
                links: endpoint.export_links(),
            };
            let path = ckpt.save(&cfg.checkpoint_dir, cfg.checkpoint_keep)?;
            crate::log_info!("checkpoint round {round} -> {}", path.display());
            // deterministic server-side chaos: the PS "dies" right after
            // writing this barrier's snapshot. `round=T` forms fire at
            // their own barrier; `send=N` forms fire at the first barrier
            // once N step replies have gone out (each at most once).
            let crash_here = crash_rounds.contains(&round) || {
                let mut fired = send_fired.lock().unwrap();
                let sent = endpoint.step_sends();
                let mut hit = false;
                for (f, &n) in fired.iter_mut().zip(&crash_sends) {
                    if !*f && n <= sent {
                        *f = true;
                        hit = true;
                    }
                }
                hit
            };
            if crash_here {
                crate::log_warn!(
                    "scenario: crashing the PS at the round-{round} barrier \
                     ({} step replies sent)",
                    endpoint.step_sends()
                );
                // sever every accepted socket: the serve loops exit on
                // their dead connections and live devices drop into their
                // reconnect loops, exactly as if the process had died
                for s in accepted.lock().unwrap().drain(..) {
                    let _ = s.shutdown(std::net::Shutdown::Both);
                }
                // restart: reload the snapshot just written, through the
                // real CRC-checked decode path a process restart would use
                let ck = Checkpoint::load(&path)?;
                server.restore_snap(&ck.server)?;
                endpoint.crash_restore(ck.sched.totals, &ck.links)?;
            }
            Ok(())
        };
        let hook: Option<&(dyn Fn(usize) -> Result<()> + Sync)> =
            if self.cfg.checkpoint_every > 0 { Some(&snapshot_hook) } else { None };
        let mut summary = sched.run(
            &self.endpoint,
            &self.server,
            &mut self.workers,
            &self.train,
            &self.test,
            hook,
        )?;
        self.steps_taken += summary.steps;
        let rec = self.endpoint.recovery_stats();
        summary.ps_restarts = rec.ps_restarts;
        summary.recover_s = rec.recover_s;
        summary.steps_replayed = rec.steps_replayed;
        self.server.write_metrics(&summary.to_json());
        self.server.flush_metrics();
        Ok(summary)
    }

    /// The features + σ stats of one fresh batch (Fig.-1 dispersion bench).
    pub fn probe_features(&mut self, device: usize) -> Result<(Matrix, Vec<f32>)> {
        ensure!(device < self.workers.len(), "device {device} is not local");
        self.workers[device].probe_features(&self.train)
    }
}

/// Load and fully validate a `--resume` checkpoint against the current
/// config. Every check is named, so a mismatch tells the operator exactly
/// which flag disagrees with the snapshot; nothing — file, metrics, model
/// state — is mutated before this returns `Ok`.
fn load_resume(cfg: &TrainConfig, codec_wire: (u32, u16)) -> Result<Checkpoint> {
    let ckpt = Checkpoint::load(&cfg.resume)?;
    let h = &ckpt.header;
    let check = |field: &str, same: bool, in_ckpt: String, in_run: String| -> Result<()> {
        if same {
            Ok(())
        } else {
            Err(CkptError::ConfigMismatch {
                field: field.into(),
                ckpt: in_ckpt,
                run: in_run,
            }
            .into())
        }
    };
    check("preset", h.preset == cfg.preset, h.preset.clone(), cfg.preset.clone())?;
    check(
        "devices",
        h.devices as usize == cfg.devices,
        h.devices.to_string(),
        cfg.devices.to_string(),
    )?;
    check(
        "rounds",
        h.rounds as usize == cfg.rounds,
        h.rounds.to_string(),
        cfg.rounds.to_string(),
    )?;
    check("seed", h.seed == cfg.seed, h.seed.to_string(), cfg.seed.to_string())?;
    let scheme = cfg.scheme.canonical_name();
    check("scheme", h.scheme == scheme, h.scheme.clone(), scheme)?;
    check(
        "codec",
        (h.codec_id, h.codec_version) == codec_wire,
        format!("{}v{}", h.codec_id, h.codec_version),
        format!("{}v{}", codec_wire.0, codec_wire.1),
    )?;
    let fp = cfg.trajectory_fingerprint();
    check(
        "fingerprint",
        h.fingerprint == fp,
        format!("{:016x}", h.fingerprint),
        format!("{fp:016x}"),
    )?;
    ensure!(
        h.round >= 1 && (h.round as usize) < cfg.rounds,
        "checkpoint at round {} leaves nothing to resume (run has {} rounds)",
        h.round,
        cfg.rounds
    );
    Ok(ckpt)
}

impl Drop for Trainer {
    fn drop(&mut self) {
        // workers send Bye and close their connections, which winds down
        // the per-link serve loops; then stop the acceptor and join
        self.workers.clear();
        self.stop.store(true, Ordering::Relaxed);
        for h in self.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// PS-side accept loop: poll the nonblocking listener, hand every accepted
/// socket its own detached serve thread (replay caching on — TCP peers
/// reconnect) and register it for the pscrash severing hook. Transient
/// accept errors (EMFILE, ECONNABORTED, EINTR, ...) are logged and backed
/// off, not treated as fatal — a fleet's listener must outlive fd-pressure
/// spikes and peers that vanish mid-handshake. Runs until the trainer
/// drops.
fn accept_loop(
    listener: TcpListener,
    endpoint: Arc<PsEndpoint>,
    limits: WireLimits,
    stop: &AtomicBool,
    accepted: &Mutex<Vec<TcpStream>>,
) {
    while !stop.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((sock, _)) => {
                let _ = sock.set_nonblocking(false);
                if let Ok(clone) = sock.try_clone() {
                    accepted.lock().unwrap().push(clone);
                }
                let ep = endpoint.clone();
                std::thread::spawn(move || {
                    let mut conn = TcpConn::from_stream(sock, limits);
                    let _ = ep.serve(&mut conn, true);
                });
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(std::time::Duration::from_millis(2));
            }
            Err(e) => {
                crate::log_warn!("accept: {e} (backing off, listener stays up)");
                std::thread::sleep(std::time::Duration::from_millis(50));
            }
        }
    }
}

/// Device-side main for a remote process (`splitfc device`): rebuild the
/// deterministic fleet parts from the *same* preset + flags as the server
/// run, dial the PS, and drive this one device through every round. The
/// pre-flight handshake polls until a server has armed its run (the
/// `HelloAck` then reports a finite round count), so start order doesn't
/// race; it also cross-checks the fleet size so a mis-matched config fails
/// loudly instead of corrupting the trajectory.
///
/// `addrs` is an ordered PS address list: the device dials the first that
/// answers and, on a broken link, its reconnect loop rotates through the
/// rest — so it can *migrate* to a fallback PS mid-run. The adopting PS
/// restores the device's courier/codec state from its loaded snapshot, so
/// the handover is invisible to the trajectory.
pub fn run_remote_device(cfg: &TrainConfig, device: usize, addrs: &[String]) -> Result<LinkReport> {
    ensure!(
        device < cfg.devices,
        "--device {device} out of range (fleet has {})",
        cfg.devices
    );
    ensure!(!addrs.is_empty(), "device {device} needs at least one PS address");
    let FleetParts {
        backend,
        preset,
        train,
        loaders,
        worker_rngs,
        up_params,
        down_params,
        capacities,
        limits,
        ..
    } = build_parts(cfg)?;
    let codec = cfg.scheme.build()?;

    // pre-flight: wait for the PS to arm the run; a resumed PS reports the
    // first round still to execute, so re-joining devices skip completed
    // work and pick their restored state up at the first real handshake
    let (devices, rounds, first_round) = wait_for_run(addrs, limits, device, codec.as_ref())?;
    ensure!(
        devices == cfg.devices,
        "fleet-size mismatch: server has {devices} devices, local config has {}",
        cfg.devices
    );
    let loader = loaders
        .into_iter()
        .nth(device)
        .ok_or_else(|| crate::err!("no loader for device {device}"))?;
    let rng = worker_rngs
        .into_iter()
        .nth(device)
        .ok_or_else(|| crate::err!("no rng fork for device {device}"))?;
    // the scenario timeline must match the server's skip set exactly, so
    // compile it against the *acked* round count, not the local flag
    let timeline = Timeline::compile(&cfg.scenario, devices, rounds, cfg.seed)?;
    let mut conn = TcpConn::connect_any(addrs, limits)?;
    let cut_sends = &timeline.scripts[device].cut_sends;
    if !cut_sends.is_empty() {
        conn.set_fault_at_sends(cut_sends);
    }
    let mut worker = DeviceWorker::new(
        device,
        loader,
        rng,
        Link::new(capacities[device], cfg.link_latency_s),
        codec,
        &preset,
        up_params,
        down_params,
        backend,
        Box::new(conn),
    );
    arm_worker(&mut worker, cfg, &timeline);
    for t in first_round..=rounds {
        if !worker.script().participates(t) {
            continue; // scenario: not joined yet, dropped out, or departed
        }
        let l = (t - 1) * devices + device;
        worker.run_step(t, l, l, &train)?;
    }
    Ok(worker.link_report())
}

/// Poll `Hello` on short-lived connections until a PS in `addrs` reports
/// an armed run (finite round count); returns (fleet size, rounds, first
/// round). A server that is down or mid-restart is not an error yet — the
/// poll rotates to the next address and keeps trying until the deadline;
/// only protocol-level rejections abort immediately.
fn wait_for_run(
    addrs: &[String],
    limits: WireLimits,
    device: usize,
    codec: &dyn Codec,
) -> Result<(usize, usize, usize)> {
    for attempt in 0..600usize {
        let addr = &addrs[attempt % addrs.len()];
        let probe = || -> Result<Option<(usize, usize, usize)>> {
            let mut conn = TcpConn::connect(addr, limits)?;
            conn.send(Msg::Hello {
                device: device as u32,
                codec_id: codec.wire_id(),
                codec_version: codec.wire_version(),
            })?;
            match conn.recv()? {
                Msg::HelloAck { err: Some(reason), .. } => {
                    Err(crate::err!("handshake rejected: {reason}"))
                }
                Msg::HelloAck { devices, rounds, first_round, .. } => {
                    let _ = conn.send(Msg::Bye { device: device as u32 });
                    if rounds != u32::MAX {
                        Ok(Some((
                            devices as usize,
                            rounds as usize,
                            (first_round as usize).max(1),
                        )))
                    } else {
                        Ok(None)
                    }
                }
                other => Err(crate::err!("expected HelloAck, got {}", other.name())),
            }
        };
        match probe() {
            Ok(Some(armed)) => return Ok(armed),
            Ok(None) => {}
            Err(e) if tcp::is_io_error(&e) => {}
            Err(e) => return Err(e),
        }
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    Err(crate::err!(
        "timed out waiting for a server at {addrs:?} to start its run"
    ))
}
