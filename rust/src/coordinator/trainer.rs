//! The paper's Algorithm 1 as a thin facade over the concurrent coordinator.
//!
//! One step (t, k) — the halves now live in their roles:
//!   1. device k draws a minibatch, runs `device_fwd` → F                (eq. 3)
//!   2. `feature_stats` (the σ-statistics kernel) → σ_norm              (eq. 10)
//!   3. FWDP + FWQ encode → uplink frame → PS decodes F̂            (Alg. 2/3)
//!   4. PS runs `server_fwd_bwd` → loss, ∇w_s, G = ∇_F̂ h          (eqs. 4, 5)
//!   5. PS ADAM-steps w_s; PS drops non-kept gradient columns, FWQ-encodes,
//!      downlink frame → device decodes Ĝ                             (eq. 8)
//!   6. device applies the chain-rule scale δ_j/(1-p_j) to Ĝ, runs
//!      `device_bwd` → ∇w_d; the (PS-held) device ADAM steps w_d (Sec. III-A)
//!
//! Steps 1-3 and 6 are the [`DeviceWorker`] half, 4-5 the
//! [`ParameterServer`] half; the [`Scheduler`] drives K workers over them —
//! sequentially (the default, exactly Algorithm 1) or concurrently with a
//! bounded-staleness window (`--staleness S`, `--concurrent-devices N`).
//! `Trainer` wires the three roles up from a [`TrainConfig`] and keeps the
//! original `new`/`step`/`run`/`evaluate`/`probe_features` surface.

use crate::compression::CodecParams;
use crate::config::{PartitionKind, TrainConfig};
use crate::coordinator::metrics::{MetricsWriter, StepRecord, TrainSummary};
use crate::coordinator::scheduler::Scheduler;
use crate::coordinator::server::ParameterServer;
use crate::coordinator::worker::{DeviceWorker, RngMode};
use crate::data::{
    dirichlet_partition, label_shards, writer_groups, Dataset, MiniBatchLoader, SynthSpec,
};
use crate::ensure;
use crate::model::PresetInfo;
use crate::runtime::{create_backend, Backend};
use crate::tensor::Matrix;
use crate::transport::{Link, LinkReport};
use crate::util::error::Result;
use crate::util::Rng;

pub struct Trainer {
    pub cfg: TrainConfig,
    preset: PresetInfo,
    server: ParameterServer,
    workers: Vec<DeviceWorker>,
    train: Dataset,
    test: Dataset,
    /// global index tag for facade-driven (manual) steps
    steps_taken: usize,
}

fn synth_spec_for(preset: &str) -> SynthSpec {
    match preset {
        "mnist" => SynthSpec::mnist_like(),
        "cifar" => SynthSpec::cifar_like(),
        "celeba" => SynthSpec::celeba_like(),
        _ => SynthSpec::tiny(),
    }
}

impl Trainer {
    pub fn new(cfg: TrainConfig) -> Result<Trainer> {
        // size the parallel runtime (matmul blocks, FWQ planning) for this
        // run; 0 = unset, which leaves the process-global pool alone (auto
        // by default) so library callers' explicit set_threads survives.
        // Exception: with concurrent device workers active, an auto-sized
        // inner pool would spawn `workers × cores` threads (every backend
        // call in every worker fans out over the whole machine) — divide
        // the cores between the two layers instead.
        let worker_threads = cfg.resolved_concurrency();
        if cfg.threads > 0 {
            crate::util::par::set_threads(cfg.threads);
        } else if worker_threads > 1 {
            let cores = std::thread::available_parallelism()
                .map(|n| n.get())
                .unwrap_or(1);
            crate::util::par::set_threads((cores / worker_threads).max(1));
        }
        let backend = create_backend(cfg.backend, &cfg.artifacts_dir, &cfg.preset)?;
        let preset = backend.preset().clone();
        let (wd, ws) = backend.init_params()?;
        ensure!(wd.n_params() == preset.nd_params);
        ensure!(ws.n_params() == preset.ns_params);

        let mut rng = Rng::new(cfg.seed.wrapping_mul(0x9E3779B9).wrapping_add(7));
        let spec = synth_spec_for(&cfg.preset);
        // consistency between model input shape and dataset spec
        ensure!(
            spec.sample_dim() == preset.sample_dim(),
            "dataset spec {:?} vs model input {:?}",
            (spec.channels, spec.height, spec.width),
            preset.in_shape
        );
        let train = Dataset::generate(&spec, cfg.n_train, cfg.seed);
        let test = Dataset::generate(&spec, cfg.n_test, cfg.seed.wrapping_add(0xE7A1));

        let parts = match cfg.partition {
            PartitionKind::LabelShards => label_shards(&train, cfg.devices, 2, &mut rng),
            PartitionKind::Dirichlet => dirichlet_partition(&train, cfg.devices, 0.3, &mut rng),
            PartitionKind::Writers => writer_groups(&train, cfg.devices, &mut rng),
        };
        let loaders: Vec<MiniBatchLoader> = parts
            .into_iter()
            .enumerate()
            .map(|(k, mut p)| {
                if p.is_empty() {
                    // degenerate partition (tiny runs): give it one sample
                    p.push(k % train.n);
                }
                MiniBatchLoader::new(p, preset.batch, rng.fork(k as u64))
            })
            .collect();

        // the Algorithm-1 encode stream forks exactly where the monolithic
        // trainer forked it (after the K loader forks), so sequential runs
        // reproduce the pre-refactor trajectories bit-for-bit; per-device
        // streams for staleness > 0 fork afterwards and don't perturb it
        let shared_rng = rng.fork(0xFFFF);
        let metrics = MetricsWriter::create(&cfg.metrics_path);
        let server = ParameterServer::new(
            backend,
            wd,
            ws,
            cfg.lr,
            cfg.devices,
            cfg.per_device_opt,
            shared_rng,
            metrics,
        );
        // codec parameters shared by device and PS sides of every link
        let up_params = CodecParams::new(preset.batch, preset.dbar, cfg.up_bits_per_entry)
            .with_q_ep(cfg.q_ep)
            .with_noise_seed(cfg.noise_seed)
            .with_chan_size(preset.chan_size);
        let down_params = CodecParams::new(preset.batch, preset.dbar, cfg.down_bits_per_entry)
            .with_q_ep(cfg.q_ep)
            .with_noise_seed(cfg.noise_seed)
            .with_chan_size(preset.chan_size);
        // one codec *session* per device: sessionful codecs (error feedback)
        // keep per-device state, so instances are never shared across links
        let mut workers: Vec<DeviceWorker> = Vec::with_capacity(loaders.len());
        for (k, loader) in loaders.into_iter().enumerate() {
            workers.push(DeviceWorker::new(
                k,
                loader,
                rng.fork(0x1_0000 + k as u64),
                Link::new(cfg.link_capacity_bps, cfg.link_latency_s),
                cfg.scheme.build()?,
                &preset,
                up_params.clone(),
                down_params.clone(),
            ));
        }

        Ok(Trainer { cfg, preset, server, workers, train, test, steps_taken: 0 })
    }

    /// Static description of the loaded model (shapes, parameter layout).
    pub fn preset(&self) -> &PresetInfo {
        &self.preset
    }

    /// The shared execution backend.
    pub fn backend(&self) -> &dyn Backend {
        self.server.backend()
    }

    /// The parameter-server role (snapshots, metrics, evaluation).
    pub fn server(&self) -> &ParameterServer {
        &self.server
    }

    /// Aggregate communication accounting across every device link.
    pub fn link_report(&self) -> LinkReport {
        LinkReport::aggregate(self.workers.iter().map(|w| w.link_report()))
    }

    /// Run one (t, k) protocol step, sequential Algorithm-1 semantics
    /// (shared encode stream, updates applied in call order).
    pub fn step(&mut self, round: usize, device: usize) -> Result<StepRecord> {
        let g = self.steps_taken;
        self.steps_taken += 1;
        self.workers[device].run_step(
            round,
            g,
            &self.server,
            &self.train,
            RngMode::SharedSequential,
        )
    }

    /// Test-set accuracy via the backend's full-model forward.
    pub fn evaluate(&mut self) -> Result<f32> {
        self.server.evaluate(&self.test)
    }

    /// Full training run: T rounds over K devices (Alg. 1), driven by the
    /// scheduler — sequentially by default, concurrently when the config
    /// asks for worker threads (`staleness`/`concurrent_devices`).
    pub fn run(&mut self) -> Result<TrainSummary> {
        let sched = Scheduler {
            rounds: self.cfg.rounds,
            first_step: self.steps_taken,
            staleness: self.cfg.staleness,
            concurrency: self.cfg.resolved_concurrency(),
            eval_every: self.cfg.eval_every,
        };
        let summary = sched.run(&self.server, &mut self.workers, &self.train, &self.test)?;
        self.steps_taken += summary.steps;
        self.server.write_metrics(&summary.to_json());
        self.server.flush_metrics();
        Ok(summary)
    }

    /// The features + σ stats of one fresh batch (Fig.-1 dispersion bench).
    pub fn probe_features(&mut self, device: usize) -> Result<(Matrix, Vec<f32>)> {
        self.workers[device].probe_features(&self.server, &self.train)
    }
}
